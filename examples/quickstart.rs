//! Quickstart: build a (k, ε)-coreset of a signal, query it with
//! decision-tree models, and verify the 1±ε approximation empirically.
//!
//!     cargo run --release --example quickstart

use sigtree::coreset::fitting_loss::relative_error;
use sigtree::coreset::{Coreset, SignalCoreset};
use sigtree::rng::Rng;
use sigtree::segmentation::{greedy::greedy_tree, random_segmentation};
use sigtree::signal::{generate, PrefixStats};

fn main() {
    let mut rng = Rng::new(7);

    // 1. A 512×512 signal (think: image / sensor grid / dataset matrix).
    let signal = generate::image_like(512, 512, 4, &mut rng);
    let stats = PrefixStats::new(&signal);
    println!("signal: {}x{} = {} cells", signal.rows(), signal.cols(), signal.len());

    // 2. Build the coreset (Algorithm 3). k bounds the leaf count of the
    //    trees we want the guarantee for; ε is the target error.
    let (k, eps) = (32, 0.2);
    let t0 = std::time::Instant::now();
    let coreset = SignalCoreset::build(&signal, k, eps);
    println!(
        "coreset: {} points = {:.2}% of the present cells, built in {:?}",
        coreset.stored_points(),
        100.0 * coreset.compression_ratio(),
        t0.elapsed()
    );

    // 3. Query ANY k-segmentation / k-leaf decision tree against the
    //    coreset (Algorithm 5) — no access to the original signal.
    let mut worst = 0.0f64;
    let queries = 200;
    for _ in 0..queries {
        let mut s = random_segmentation(signal.bounds(), k, &mut rng);
        s.refit_values(&stats);
        let exact = s.loss(&stats); // ground truth (needs the full signal)
        let approx = coreset.fitting_loss(&s); // coreset only
        worst = worst.max(relative_error(approx, exact));
    }
    println!("worst relative loss error over {queries} random {k}-trees: {worst:.4} (ε = {eps})");

    // 4. The headline use: run an expensive solver on the coreset instead
    //    of the data. Greedy k-tree on full data vs. evaluated via coreset.
    let tree = greedy_tree(&stats, k);
    let exact = tree.loss(&stats);
    let approx = coreset.fitting_loss(&tree);
    println!(
        "greedy {k}-tree loss: exact {exact:.1}, coreset estimate {approx:.1} ({:+.2}%)",
        100.0 * (approx - exact) / exact
    );
    assert!(worst <= 2.0 * eps, "approximation blew past the ε budget");
    println!("quickstart OK");
}
