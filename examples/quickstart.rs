//! Quickstart: bring up the one front door (`sigtree::engine`), build a
//! (k, ε)-coreset, query it with decision-tree models, and verify the
//! 1±ε approximation empirically.
//!
//!     cargo run --release --example quickstart

use sigtree::coreset::fitting_loss::relative_error;
use sigtree::prelude::*;
use sigtree::segmentation::{greedy::greedy_tree, random_segmentation};
use sigtree::signal::generate;

fn main() {
    let mut rng = Rng::new(7);

    // 1. One validated config, one long-lived engine. The engine owns
    //    the worker pool (reused by every call below) and the kernel
    //    backend; k bounds the leaf count of the trees we want the
    //    guarantee for, ε is the target error.
    let (k, eps) = (32, 0.2);
    let engine = Engine::new(EngineConfig::new(k, eps).with_threads(0)).expect("valid config");

    // 2. A 512×512 signal (think: image / sensor grid / dataset matrix),
    //    attached as a session: the shared prefix statistics are built
    //    once and reused by every exact-loss query below.
    let signal = generate::image_like(512, 512, 4, &mut rng);
    let session = engine.session(&signal);
    println!("signal: {}x{} = {} cells", signal.rows(), signal.cols(), signal.len());

    // 3. Build the coreset (Algorithm 3, sharded on the engine pool).
    let t0 = std::time::Instant::now();
    let coreset = session.coreset();
    println!(
        "coreset: {} points = {:.2}% of the present cells, built in {:?}",
        coreset.stored_points(),
        100.0 * coreset.compression_ratio(),
        t0.elapsed()
    );

    // 4. Query ANY k-segmentation / k-leaf decision tree against the
    //    coreset (Algorithm 5) — no access to the original signal. The
    //    whole batch runs on the engine's pool in one call.
    let queries: Vec<KSegmentation> = (0..200)
        .map(|_| {
            let mut s = random_segmentation(signal.bounds(), k, &mut rng);
            session.refit(&mut s);
            s
        })
        .collect();
    let approx = engine.fitting_loss(&coreset, &queries);
    let mut worst = 0.0f64;
    for (s, a) in queries.iter().zip(approx) {
        worst = worst.max(relative_error(a, session.exact_loss(s)));
    }
    println!(
        "worst relative loss error over {} random {k}-trees: {worst:.4} (ε = {eps})",
        queries.len()
    );

    // 5. The headline use: run an expensive solver on the coreset instead
    //    of the data. Greedy k-tree on full data vs. evaluated via coreset.
    let tree = greedy_tree(session.stats(), k);
    let exact = session.exact_loss(&tree);
    let approx = engine.fitting_loss(&coreset, std::slice::from_ref(&tree))[0];
    println!(
        "greedy {k}-tree loss: exact {exact:.1}, coreset estimate {approx:.1} ({:+.2}%)",
        100.0 * (approx - exact) / exact
    );
    assert!(worst <= 2.0 * eps, "approximation blew past the ε budget");
    println!("quickstart OK");
}
