//! AutoML demo — contribution (iv) of the paper: tune the leaf budget k
//! of a random forest on the coreset instead of the full data. The
//! coreset is built once; every candidate k reuses it, so the whole sweep
//! costs roughly one compression plus |grid| cheap trainings.
//!
//!     cargo run --release --example automl_tuning

use sigtree::datasets;
use sigtree::experiments::tuning::{log_grid, tune_coreset, tune_full, tune_uniform};
use sigtree::experiments::Solver;
use sigtree::rng::Rng;

fn main() {
    let mut rng = Rng::new(44);
    // Air-Quality-like matrix at 20% scale (≈1870×15) to keep the demo
    // quick; bench_fig4 runs the full-scale version.
    let signal = datasets::air_quality_like(0.2, &mut rng);
    let (masked, held) = datasets::holdout_patches(&signal, 0.3, 5, &mut rng);
    println!(
        "dataset: {}x{}  train cells {}  held-out {}",
        signal.rows(),
        signal.cols(),
        masked.present(),
        held.len()
    );

    let grid = log_grid(4, 512, 8);
    println!("candidate k grid: {grid:?}");

    let full = tune_full(&masked, &held, &grid, Solver::RandomForest, 5);
    let core = tune_coreset(&masked, &held, &grid, 500, 0.3, Solver::RandomForest, 5);
    let uni = tune_uniform(&masked, &held, &grid, core.compression_size, Solver::RandomForest, 5);

    for curve in [&full, &core, &uni] {
        println!(
            "\n{:<26} size {:>7}  total time {:>10?}  best k = {}",
            curve.scheme,
            curve.compression_size,
            curve.total_time,
            curve.best_k()
        );
        for (k, loss) in &curve.points {
            println!("  k={k:<6} test SSE {loss:>12.2}");
        }
    }

    let speedup = full.total_time.as_secs_f64() / core.total_time.as_secs_f64().max(1e-9);
    println!("\ntuning speedup (full / coreset): x{speedup:.1}");
    println!(
        "best-k agreement: full={} coreset={} uniform={}",
        full.best_k(),
        core.best_k(),
        uni.best_k()
    );
}
