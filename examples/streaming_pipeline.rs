//! Streaming pipeline demo — the L3 coordinator on a signal too "large"
//! to process monolithically: bands stream through bounded queues into
//! worker threads, partial coresets merge-and-reduce, and backpressure
//! keeps memory flat. Everything runs through one `sigtree::engine`
//! session (shared statistics, one worker pool).
//!
//!     cargo run --release --example streaming_pipeline

use sigtree::coreset::Coreset;
use sigtree::pipeline::{run_streaming, PipelineConfig};
use sigtree::prelude::*;
use sigtree::segmentation::random_segmentation;
use sigtree::signal::generate;

fn main() {
    let mut rng = Rng::new(33);
    let (n, m) = (4096, 256);
    let signal = generate::smooth(n, m, 5, &mut rng);
    println!("streaming a {n}x{m} signal ({} cells)", n * m);

    let engine = Engine::new(EngineConfig::new(16, 0.25).with_band_rows(256).with_threads(2))
        .expect("valid config");

    // In-memory banded pipeline through the engine (shared stats built
    // on the engine pool; band geometry from the config)…
    let t0 = std::time::Instant::now();
    let (coreset, metrics) = engine.pipeline(&signal);
    println!(
        "pipeline: {} blocks ({:.2}%) in {:?}",
        coreset.blocks.len(),
        100.0 * coreset.compression_ratio(),
        t0.elapsed()
    );
    println!("metrics: {}", metrics.summary());

    // …the band-push handle for sources that feed bands as they arrive…
    let mut stream = engine.stream(m);
    for r0 in (0..n).step_by(512) {
        stream.push_band(&signal.view(Rect::new(r0, r0 + 511, 0, m - 1)));
    }
    let pushed = stream.finish().expect("bands were pushed");
    println!(
        "band-push stream: {} blocks, weight {:.0} (= {} cells)",
        pushed.blocks.len(),
        pushed.total_weight(),
        n * m
    );

    // …and the true streaming entry point: bands materialized lazily by
    // a generator that never holds the full signal (e.g. a sensor feed).
    let band_rows = 512;
    let bands = (0..n / band_rows).map(move |i| {
        let mut band_rng = Rng::new(1000 + i as u64);
        let band: Signal = generate::smooth(band_rows, m, 4, &mut band_rng);
        (i * band_rows, band)
    });
    let config = PipelineConfig::new(engine.config().coreset_config())
        .with_band_rows(engine.config().band_rows)
        .with_workers(engine.threads());
    let (streamed, metrics2) = run_streaming(m, bands, config);
    println!(
        "generator-fed stream: {} blocks, weight {:.0} (= {} cells)",
        streamed.blocks.len(),
        streamed.total_weight(),
        n * m
    );
    println!("metrics: {}", metrics2.summary());

    // Validate the pipeline coreset against exact losses (shared stats
    // from the engine session).
    let session = engine.session(&signal);
    let mut worst = 0.0f64;
    for _ in 0..50 {
        let mut s = random_segmentation(signal.bounds(), 16, &mut rng);
        session.refit(&mut s);
        let exact = session.exact_loss(&s);
        let approx = coreset.fitting_loss(&s);
        worst = worst.max((approx - exact).abs() / exact.max(1e-9));
    }
    println!("worst relative error vs exact over 50 queries: {worst:.4}");

    // Batch-vs-pipeline sanity: same weight budget.
    let batch = engine.coreset(&signal);
    println!(
        "batch coreset: {} blocks (pipeline produced {})",
        batch.blocks.len(),
        coreset.blocks.len()
    );
    assert!((coreset.total_weight() - (n * m) as f64).abs() < 1e-6 * (n * m) as f64);
    assert!((pushed.total_weight() - (n * m) as f64).abs() < 1e-6 * (n * m) as f64);
    println!("streaming pipeline OK");
}
