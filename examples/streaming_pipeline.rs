//! Streaming pipeline demo — the L3 coordinator on a signal too "large"
//! to process monolithically: bands stream through bounded queues into
//! worker threads, partial coresets merge-and-reduce, and backpressure
//! keeps memory flat.
//!
//!     cargo run --release --example streaming_pipeline

use sigtree::coreset::{Coreset, CoresetConfig, SignalCoreset};
use sigtree::pipeline::{run, run_streaming, PipelineConfig};
use sigtree::rng::Rng;
use sigtree::segmentation::random_segmentation;
use sigtree::signal::{generate, PrefixStats, Signal};

fn main() {
    let mut rng = Rng::new(33);
    let (n, m) = (4096, 256);
    let signal = generate::smooth(n, m, 5, &mut rng);
    let stats = PrefixStats::new(&signal);
    println!("streaming a {n}x{m} signal ({} cells)", n * m);

    let config = PipelineConfig::new(CoresetConfig::new(16, 0.25))
        .with_band_rows(256)
        .with_workers(2);

    // In-memory convenience wrapper…
    let t0 = std::time::Instant::now();
    let (coreset, metrics) = run(&signal, config);
    println!(
        "pipeline: {} blocks ({:.2}%) in {:?}",
        coreset.blocks.len(),
        100.0 * coreset.compression_ratio(),
        t0.elapsed()
    );
    println!("metrics: {}", metrics.summary());

    // …and the true streaming entry point: bands materialized lazily by a
    // generator (here: re-synthesized per band — e.g. a sensor feed).
    let band_rows = 512;
    let bands = (0..n / band_rows).map(move |i| {
        let mut band_rng = Rng::new(1000 + i as u64);
        let band: Signal = generate::smooth(band_rows, m, 4, &mut band_rng);
        (i * band_rows, band)
    });
    let (streamed, metrics2) = run_streaming(m, bands, config);
    println!(
        "generator-fed stream: {} blocks, weight {:.0} (= {} cells)",
        streamed.blocks.len(),
        streamed.total_weight(),
        n * m
    );
    println!("metrics: {}", metrics2.summary());

    // Validate the pipeline coreset against exact losses.
    let mut worst = 0.0f64;
    for _ in 0..50 {
        let mut s = random_segmentation(signal.bounds(), 16, &mut rng);
        s.refit_values(&stats);
        let exact = s.loss(&stats);
        let approx = coreset.fitting_loss(&s);
        worst = worst.max((approx - exact).abs() / exact.max(1e-9));
    }
    println!("worst relative error vs exact over 50 queries: {worst:.4}");

    // Batch-vs-pipeline sanity: same weight budget.
    let batch = SignalCoreset::build(&signal, 16, 0.25);
    println!(
        "batch coreset: {} blocks (pipeline produced {})",
        batch.blocks.len(),
        coreset.blocks.len()
    );
    assert!((coreset.total_weight() - (n * m) as f64).abs() < 1e-6 * (n * m) as f64);
    println!("streaming pipeline OK");
}
