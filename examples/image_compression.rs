//! Image compression — the paper's MPEG4/quadtree motivation (§1, [46,
//! 55]): compress a synthetic image with (a) a quadtree codec and (b) a
//! greedy k-tree, both run on the full image and on the coreset, showing
//! the coreset preserves codec quality decisions at a fraction of the
//! data.
//!
//!     cargo run --release --example image_compression

use sigtree::benchkit::{fmt_f, Table};
use sigtree::coreset::{Coreset, SignalCoreset};
use sigtree::rng::Rng;
use sigtree::segmentation::greedy::greedy_tree;
use sigtree::segmentation::quadtree::{quadtree_compress, report};
use sigtree::signal::{generate, PrefixStats};

fn main() {
    let mut rng = Rng::new(21);
    let image = generate::image_like(256, 256, 6, &mut rng);
    let stats = PrefixStats::new(&image);

    // Quadtree codec at several leaf budgets (the MPEG4-style smooth-block
    // compressor).
    let mut table = Table::new(&["leaves", "MSE", "compression x"]);
    for budget in [16, 64, 256, 1024] {
        let seg = quadtree_compress(&stats, 0.0, budget);
        let rep = report(&stats, &seg);
        table.row(&[
            rep.leaves.to_string(),
            fmt_f(rep.mse),
            format!("{:.1}", rep.ratio),
        ]);
    }
    table.print("quadtree codec on full image");

    // Coreset route: evaluate candidate codecs via the coreset only.
    let k = 256;
    let coreset = SignalCoreset::construct(&image, k, 0.2);
    println!(
        "\ncoreset: {:.2}% of present image cells",
        100.0 * coreset.compression_ratio()
    );
    let mut table = Table::new(&["codec", "exact SSE", "coreset SSE", "err %"]);
    for (name, seg) in [
        ("quadtree-64", quadtree_compress(&stats, 0.0, 64)),
        ("quadtree-256", quadtree_compress(&stats, 0.0, 256)),
        ("greedy-64", greedy_tree(&stats, 64)),
        ("greedy-256", greedy_tree(&stats, 256)),
    ] {
        let exact = seg.loss(&stats);
        let approx = coreset.fitting_loss(&seg);
        table.row(&[
            name.to_string(),
            fmt_f(exact),
            fmt_f(approx),
            format!("{:+.2}", 100.0 * (approx - exact) / exact.max(1e-9)),
        ]);
    }
    table.print("codec selection via coreset");

    // The selection decision (which codec wins) must agree.
    let a = quadtree_compress(&stats, 0.0, 256);
    let b = greedy_tree(&stats, 256);
    let exact_winner = a.loss(&stats) < b.loss(&stats);
    let coreset_winner = coreset.fitting_loss(&a) < coreset.fitting_loss(&b);
    assert_eq!(exact_winner, coreset_winner, "coreset must rank codecs like the full image");
    println!("\ncodec ranking preserved by coreset: OK");
}
