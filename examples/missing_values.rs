//! END-TO-END driver — the paper's §5 experiment on a real (simulated)
//! workload, exercising every layer of the system:
//!
//! * L3 streaming pipeline builds the coreset of the masked dataset,
//! * the kernel backend (pure-Rust native by default; PJRT with
//!   `--features pjrt` + artifacts) cross-checks block statistics,
//! * forests (sklearn substitute) and GBDT (LightGBM substitute) train on
//!   full data / coreset / uniform sample,
//! * hyperparameter k is tuned on each compression,
//! * the headline metrics — test-set SSE and total time — are reported
//!   exactly like Fig. 4.
//!
//!     cargo run --release --example missing_values
//!
//! The run is recorded in EXPERIMENTS.md.

use sigtree::benchkit::{fmt_duration, fmt_f, Table};
use sigtree::coreset::{Coreset, CoresetConfig};
use sigtree::datasets;
use sigtree::experiments::tuning::{log_grid, tune_coreset, tune_full, tune_uniform};
use sigtree::experiments::Solver;
use sigtree::pipeline::{run, PipelineConfig};
use sigtree::rng::Rng;
use sigtree::signal::Rect;

fn main() {
    let scale = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.25f64);
    let mut rng = Rng::new(2021);

    for (name, signal) in [
        ("air-quality-like", datasets::air_quality_like(scale, &mut rng)),
        ("gesture-phase-like", datasets::gesture_phase_like(scale, &mut rng)),
    ] {
        println!("\n################ {name} ({}x{}) ################", signal.rows(), signal.cols());
        let (masked, held) = datasets::holdout_patches(&signal, 0.3, 5, &mut rng);
        println!("train cells {}  held-out {}", masked.present(), held.len());

        // --- L3 pipeline: stream the masked dataset into a coreset. ---
        let cfg = PipelineConfig::new(CoresetConfig::new(500, 0.3))
            .with_band_rows(512)
            .with_workers(2);
        let t0 = std::time::Instant::now();
        let (pipeline_cs, metrics) = run(&masked, cfg);
        println!(
            "pipeline coreset: {} pts ({:.2}%) in {:?}  [{}]",
            pipeline_cs.stored_points(),
            100.0 * pipeline_cs.compression_ratio(),
            t0.elapsed(),
            metrics.summary()
        );

        // --- Kernel-backend cross-check (PJRT when compiled in + the
        // artifacts exist, the pure-Rust native backend otherwise). ---
        {
            let backend = sigtree::runtime::default_backend();
            let tp = sigtree::runtime::TiledPrefix::build(backend.as_ref(), &masked)
                .expect("tiled prefix build");
            let stats = sigtree::signal::PrefixStats::new(&masked);
            let probe = Rect::new(0, masked.rows().min(200) - 1, 0, masked.cols() - 1);
            let (s, q) = tp.moments(&probe);
            let exact = stats.moments(&probe);
            println!(
                "kernel parity: sum {:.3} vs {:.3}, sumsq {:.3} vs {:.3} (backend {})",
                s,
                exact.sum,
                q,
                exact.sum_sq,
                tp.backend_name()
            );
        }

        // --- Fig. 4 protocol: tune k on full vs coreset vs uniform. ---
        let grid = log_grid(8, 512, 6);
        let full = tune_full(&masked, &held, &grid, Solver::RandomForest, 9);
        let core = tune_coreset(&masked, &held, &grid, 500, 0.3, Solver::RandomForest, 9);
        let uni =
            tune_uniform(&masked, &held, &grid, core.compression_size, Solver::RandomForest, 9);

        let mut table = Table::new(&["scheme", "size", "time", "best k", "best test SSE"]);
        for curve in [&full, &core, &uni] {
            let best_k = curve.best_k();
            let best_sse = curve
                .points
                .iter()
                .find(|(k, _)| *k == best_k)
                .map(|&(_, l)| l)
                .unwrap();
            table.row(&[
                curve.scheme.clone(),
                curve.compression_size.to_string(),
                fmt_duration(curve.total_time),
                best_k.to_string(),
                fmt_f(best_sse),
            ]);
        }
        table.print(&format!("{name}: hyperparameter tuning (Fig. 4 protocol)"));
        let speedup = full.total_time.as_secs_f64() / core.total_time.as_secs_f64().max(1e-9);
        println!("tuning speedup full/coreset: x{speedup:.1}");

        // --- GBDT (LightGBM substitute) sanity at the tuned k. ---
        let (cs_out, us_out) = sigtree::experiments::missing_values_experiment(
            &signal,
            500,
            0.3,
            core.best_k().clamp(2, 64),
            Solver::Gbdt,
            13,
        );
        println!(
            "GBDT: coreset SSE {:.2} ({} pts), uniform SSE {:.2}",
            cs_out.test_sse, cs_out.size, us_out.test_sse
        );
    }
    println!("\nmissing_values end-to-end OK");
}
