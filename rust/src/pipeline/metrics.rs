//! Pipeline metrics: lock-free counters for stage throughput, queue
//! behaviour, and latency, exported by the CLI and asserted in tests.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Duration;

/// Aggregated pipeline counters. All methods are thread-safe; reads give
/// a consistent-enough snapshot for reporting (no cross-counter
/// atomicity needed).
#[derive(Debug, Default)]
pub struct PipelineMetrics {
    bands_built: AtomicUsize,
    cells_processed: AtomicUsize,
    build_nanos: AtomicU64,
    merge_nanos: AtomicU64,
    merges: AtomicUsize,
    reduces: AtomicUsize,
    source_wait_nanos: AtomicU64,
}

impl PipelineMetrics {
    pub fn record_build(&self, took: Duration, cells: usize) {
        self.bands_built.fetch_add(1, Ordering::Relaxed);
        self.cells_processed.fetch_add(cells, Ordering::Relaxed);
        self.build_nanos
            .fetch_add(took.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn record_merge(&self, took: Duration) {
        self.merges.fetch_add(1, Ordering::Relaxed);
        self.merge_nanos
            .fetch_add(took.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn record_reduce(&self) {
        self.reduces.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_source_wait(&self, took: Duration) {
        self.source_wait_nanos
            .fetch_add(took.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn bands_built(&self) -> usize {
        self.bands_built.load(Ordering::Relaxed)
    }

    pub fn cells_processed(&self) -> usize {
        self.cells_processed.load(Ordering::Relaxed)
    }

    pub fn merges(&self) -> usize {
        self.merges.load(Ordering::Relaxed)
    }

    pub fn reduces(&self) -> usize {
        self.reduces.load(Ordering::Relaxed)
    }

    pub fn total_build_time(&self) -> Duration {
        Duration::from_nanos(self.build_nanos.load(Ordering::Relaxed))
    }

    pub fn total_merge_time(&self) -> Duration {
        Duration::from_nanos(self.merge_nanos.load(Ordering::Relaxed))
    }

    /// Time the source spent blocked on the bounded queue — the direct
    /// measure of backpressure.
    pub fn source_wait(&self) -> Duration {
        Duration::from_nanos(self.source_wait_nanos.load(Ordering::Relaxed))
    }

    /// Cells per second across all workers (wall-clock-free: uses summed
    /// worker build time, i.e. CPU throughput of the build stage).
    pub fn build_throughput(&self) -> f64 {
        let t = self.total_build_time().as_secs_f64();
        if t <= 0.0 {
            0.0
        } else {
            self.cells_processed() as f64 / t
        }
    }

    /// Human-readable summary block.
    pub fn summary(&self) -> String {
        format!(
            "bands={} cells={} merges={} reduces={} build={:?} merge={:?} src_wait={:?} throughput={:.2e} cells/s",
            self.bands_built(),
            self.cells_processed(),
            self.merges(),
            self.reduces(),
            self.total_build_time(),
            self.total_merge_time(),
            self.source_wait(),
            self.build_throughput(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = PipelineMetrics::default();
        m.record_build(Duration::from_millis(2), 100);
        m.record_build(Duration::from_millis(3), 200);
        m.record_merge(Duration::from_millis(1));
        m.record_reduce();
        assert_eq!(m.bands_built(), 2);
        assert_eq!(m.cells_processed(), 300);
        assert_eq!(m.merges(), 1);
        assert_eq!(m.reduces(), 1);
        assert!(m.total_build_time() >= Duration::from_millis(5));
        assert!(m.build_throughput() > 0.0);
        assert!(m.summary().contains("bands=2"));
    }

    #[test]
    fn thread_safety_smoke() {
        use std::sync::Arc;
        let m = Arc::new(PipelineMetrics::default());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let m = Arc::clone(&m);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.record_build(Duration::from_nanos(10), 1);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.bands_built(), 4000);
        assert_eq!(m.cells_processed(), 4000);
    }
}
