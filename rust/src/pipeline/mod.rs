//! The L3 streaming coordinator — the data-pipeline layer of the stack.
//!
//! The coreset is a pre-processing compression stage, so the system
//! contribution at this layer is a streaming orchestrator:
//!
//! * a **source** streams the signal as horizontal row-bands,
//! * a **sharder** places bands on a bounded work queue (backpressure: the
//!   source blocks when workers lag),
//! * **workers** (std::thread; tokio is unavailable offline) pull bands
//!   work-stealing-style and build partial coresets,
//! * a **reducer** folds partial coresets in completion order through a
//!   [`crate::coreset::merge_tree::MergeTree`] (the same structure behind
//!   [`crate::coreset::merge_reduce::StreamingCoreset`]), periodically
//!   re-compacting via [`crate::coreset::merge_reduce::reduce`],
//! * **metrics** track queue depths, per-stage latency, and throughput.
//!
//! Two entry points with different ownership models (DESIGN.md §Views &
//! Memory):
//!
//! * [`run`] — the in-memory path: the signal already exists, so one
//!   shared [`PrefixStats`] is built up front and each window job is just
//!   a `Rect`; workers run [`SignalCoreset::construct_in`] against the shared
//!   statistics — **zero per-window copies or integral-image rebuilds**.
//! * [`run_streaming`] — true streaming: bands arrive as owned
//!   [`Signal`]s from a source that may never hold the full signal, so
//!   each band necessarily builds its own band-local statistics.

pub mod metrics;

use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Instant;

use crate::coreset::merge_tree::MergeTree;
use crate::coreset::{CoresetConfig, SignalCoreset};
use crate::signal::{PrefixStats, Rect, Signal, SignalSource};

pub use metrics::PipelineMetrics;

/// Pipeline configuration.
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    pub coreset: CoresetConfig,
    /// Rows per streamed band.
    pub band_rows: usize,
    /// Worker threads.
    pub workers: usize,
    /// Bounded queue capacity between source and workers (backpressure).
    pub queue_capacity: usize,
    /// Reduce when accumulated blocks exceed this multiple of last size.
    pub reduce_factor: f64,
}

impl PipelineConfig {
    pub fn new(coreset: CoresetConfig) -> Self {
        Self {
            coreset,
            band_rows: 64,
            workers: thread::available_parallelism().map_or(1, |p| p.get()),
            queue_capacity: 4,
            reduce_factor: 2.0,
        }
    }

    pub fn with_band_rows(mut self, rows: usize) -> Self {
        self.band_rows = rows.max(1);
        self
    }

    pub fn with_workers(mut self, w: usize) -> Self {
        self.workers = w.max(1);
        self
    }

    /// Alias for [`Self::with_workers`] matching the CLI's `--threads`
    /// convention: `0` means all available cores
    /// ([`crate::par::resolve_threads`]).
    pub fn with_threads(self, threads: usize) -> Self {
        self.with_workers(crate::par::resolve_threads(threads))
    }
}

/// A band job: global row offset + the band data.
struct BandJob {
    seq: usize,
    row_offset: usize,
    band: Signal,
}

/// A worker result: sequence number, the band's rectangle in global
/// coordinates, and its coreset.
#[allow(dead_code)] // seq kept for debugging / ordered-merge variants
struct BandResult {
    seq: usize,
    rect: Rect,
    coreset: SignalCoreset,
}

/// Run the full pipeline over an in-memory signal, streaming it in bands.
/// Returns the final coreset and the collected metrics. This is the
/// entry point the CLI, examples, and benches use; `run_streaming` below
/// accepts an arbitrary band iterator (true streaming).
///
/// Zero-copy: one shared [`PrefixStats`] is built up front (via the
/// thread-invariant [`PrefixStats::new_par`]) and every window job on
/// the queue is a bare `Rect` — workers answer all statistics queries
/// from the shared object and read cell labels straight from `signal`,
/// so no band is ever cropped and no per-band integral image is ever
/// rebuilt. Peak memory is O(N) regardless of worker count.
pub fn run<S: SignalSource>(
    signal: &S,
    config: PipelineConfig,
) -> (SignalCoreset, PipelineMetrics) {
    let stats = PrefixStats::new_par(signal, config.workers);
    run_with_stats(signal, &stats, config)
}

/// [`run`] against a caller-owned shared [`PrefixStats`] — the
/// [`crate::engine::Engine::pipeline`] path, where the engine builds
/// the statistics on its long-lived pool and the banded workers here
/// only answer queries from it. `stats` must cover `signal`'s
/// coordinate frame.
pub fn run_with_stats<S: SignalSource>(
    signal: &S,
    stats: &PrefixStats,
    config: PipelineConfig,
) -> (SignalCoreset, PipelineMetrics) {
    let m = signal.cols();
    let bands = band_rects(signal.rows(), m, config.band_rows);
    let metrics = Arc::new(PipelineMetrics::default());
    let (job_tx, job_rx) = sync_channel::<(usize, Rect)>(config.queue_capacity);
    let job_rx = Arc::new(Mutex::new(job_rx));
    let (res_tx, res_rx) = sync_channel::<BandResult>(config.queue_capacity.max(16));

    let coreset = thread::scope(|scope| {
        // Workers: pull window rects from the shared bounded queue and
        // build against the shared statistics (blocks come out directly
        // in global coordinates — no offset fixups).
        for _ in 0..config.workers {
            let rx = Arc::clone(&job_rx);
            let tx = res_tx.clone();
            let met = Arc::clone(&metrics);
            let ccfg = config.coreset;
            let stats = &stats;
            scope.spawn(move || loop {
                let job = {
                    let guard = crate::par::lock(&rx);
                    guard.recv()
                };
                let Ok((seq, rect)) = job else { break };
                let t0 = Instant::now();
                let cs = SignalCoreset::construct_in(signal, stats, rect, ccfg);
                met.record_build(t0.elapsed(), rect.area());
                if tx.send(BandResult { seq, rect, coreset: cs }).is_err() {
                    break;
                }
            });
        }
        drop(res_tx);

        // Source thread: feeds window rects (blocks on the bounded
        // channel when the workers are behind — that IS the
        // backpressure).
        let src_metrics = Arc::clone(&metrics);
        scope.spawn(move || {
            for (seq, rect) in bands.into_iter().enumerate() {
                let t0 = Instant::now();
                if job_tx.send((seq, rect)).is_err() {
                    break;
                }
                src_metrics.record_source_wait(t0.elapsed());
            }
            // Dropping job_tx closes the queue; workers drain and exit.
        });

        // Reducer (this thread): merge results in completion order.
        let reducer = Reducer::new(m, config, Arc::clone(&metrics));
        reducer.drain(res_rx)
    });

    let metrics = Arc::try_unwrap(metrics).unwrap_or_default();
    (coreset, metrics)
}

/// Rectangles of each streamed band of an n×m signal.
pub fn band_rects(n: usize, m: usize, band_rows: usize) -> Vec<Rect> {
    let mut out = Vec::new();
    let mut r0 = 0;
    while r0 < n {
        let r1 = (r0 + band_rows - 1).min(n - 1);
        out.push(Rect::new(r0, r1, 0, m - 1));
        r0 = r1 + 1;
    }
    out
}

/// Streaming entry point: `bands` yields `(row_offset, band_signal)` in
/// row order; band widths must equal `m`.
pub fn run_streaming(
    m: usize,
    bands: impl Iterator<Item = (usize, Signal)> + Send,
    config: PipelineConfig,
) -> (SignalCoreset, PipelineMetrics) {
    let metrics = Arc::new(PipelineMetrics::default());
    let (job_tx, job_rx) = sync_channel::<BandJob>(config.queue_capacity);
    let job_rx = Arc::new(Mutex::new(job_rx));
    let (res_tx, res_rx) = sync_channel::<BandResult>(config.queue_capacity.max(16));

    let coreset = thread::scope(|scope| {
        // Workers: pull from the shared bounded queue (work-stealing by
        // construction — an idle worker takes the next band regardless of
        // who processed the previous one).
        for _ in 0..config.workers {
            let rx = Arc::clone(&job_rx);
            let tx = res_tx.clone();
            let met = Arc::clone(&metrics);
            let ccfg = config.coreset;
            scope.spawn(move || loop {
                let job = {
                    let guard = crate::par::lock(&rx);
                    guard.recv()
                };
                let Ok(job) = job else { break };
                let t0 = Instant::now();
                let cs = SignalCoreset::construct_with(&job.band, ccfg);
                let cs = crate::coreset::merge_tree::translate_rows(cs, job.row_offset);
                let rect = Rect::new(
                    job.row_offset,
                    job.row_offset + job.band.rows() - 1,
                    0,
                    job.band.cols() - 1,
                );
                met.record_build(t0.elapsed(), job.band.len());
                if tx
                    .send(BandResult { seq: job.seq, rect, coreset: cs })
                    .is_err()
                {
                    break;
                }
            });
        }
        drop(res_tx);

        // Source thread: feeds jobs (blocks on the bounded channel when
        // the workers are behind — that IS the backpressure).
        let src_metrics = Arc::clone(&metrics);
        scope.spawn(move || {
            for (seq, (row_offset, band)) in bands.enumerate() {
                let t0 = Instant::now();
                let job = BandJob { seq, row_offset, band };
                if job_tx.send(job).is_err() {
                    break;
                }
                src_metrics.record_source_wait(t0.elapsed());
            }
            // Dropping job_tx closes the queue; workers drain and exit.
        });

        // Reducer (this thread): merge results in completion order (the
        // block lists are coordinate-tagged so order does not matter),
        // compacting periodically.
        let reducer = Reducer::new(m, config, Arc::clone(&metrics));
        reducer.drain(res_rx)
    });

    let metrics = Arc::try_unwrap(metrics).unwrap_or_default();
    (coreset, metrics)
}

struct Reducer {
    m: usize,
    config: PipelineConfig,
    metrics: Arc<PipelineMetrics>,
}

impl Reducer {
    fn new(m: usize, config: PipelineConfig, metrics: Arc<PipelineMetrics>) -> Self {
        Self { m, config, metrics }
    }

    fn drain(self, rx: Receiver<BandResult>) -> SignalCoreset {
        // The completion-order fold lives in the merge tree — the same
        // structure behind StreamingCoreset — configured with the
        // pipeline's reduce factor and its first-band passthrough guard
        // (a single band's coreset is already the batch answer and must
        // pass through unchanged: the degenerate-equivalence invariant).
        let mut tree = MergeTree::for_stream(self.m, self.config.coreset)
            .with_reduce_factor(self.config.reduce_factor)
            .with_first_part_passthrough();
        let mut rows_total = 0usize;
        for res in rx {
            let t0 = Instant::now();
            rows_total += res.coreset.rows();
            if tree.push_part(res.rect, res.coreset) {
                self.metrics.record_reduce();
            }
            self.metrics.record_merge(t0.elapsed());
        }
        let cs = tree.into_streamed().unwrap_or_else(|_| {
            // Empty stream: the documented empty coreset.
            SignalCoreset::from_blocks(0, self.m, self.config.coreset, 0.0, 1.0, Vec::new())
        });
        // Fix the row count (merge() sums band heights; completion order
        // may interleave, the sum is invariant).
        SignalCoreset::from_blocks(
            rows_total,
            self.m,
            cs.config,
            cs.sigma,
            cs.gamma,
            cs.blocks,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coreset::Coreset;
    use crate::rng::Rng;
    use crate::segmentation::random_segmentation;
    use crate::signal::{generate, PrefixStats};

    #[test]
    fn pipeline_weight_matches_signal() {
        let mut rng = Rng::new(40);
        let sig = generate::smooth(100, 40, 3, &mut rng);
        let cfg = PipelineConfig::new(CoresetConfig::new(5, 0.3))
            .with_band_rows(16)
            .with_workers(2);
        let (cs, metrics) = run(&sig, cfg);
        assert!((cs.total_weight() - 4000.0).abs() < 1e-6 * 4000.0);
        assert_eq!(cs.rows(), 100);
        assert!(metrics.bands_built() >= 7);
    }

    #[test]
    fn pipeline_quality_close_to_monolithic() {
        let mut rng = Rng::new(41);
        let sig = generate::smooth(120, 50, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let cfg = PipelineConfig::new(CoresetConfig::new(6, 0.25)).with_band_rows(24);
        let (cs, _) = run(&sig, cfg);
        for _ in 0..10 {
            let mut s = random_segmentation(sig.bounds(), 6, &mut rng);
            s.refit_values(&stats);
            let exact = s.loss(&stats);
            let approx = cs.fitting_loss(&s);
            assert!(
                (approx - exact).abs() <= 0.35 * exact + 1e-6,
                "{approx} vs {exact}"
            );
        }
    }

    #[test]
    fn single_worker_single_band_degenerates_to_batch() {
        let mut rng = Rng::new(42);
        let sig = generate::image_like(40, 40, 2, &mut rng);
        let cfg = PipelineConfig::new(CoresetConfig::new(4, 0.3))
            .with_band_rows(1000)
            .with_workers(1);
        let (cs, _) = run(&sig, cfg);
        let batch = SignalCoreset::construct(&sig, 4, 0.3);
        assert_eq!(cs.blocks.len(), batch.blocks.len());
        assert!((cs.total_weight() - batch.total_weight()).abs() < 1e-9);
    }

    #[test]
    fn metrics_capture_stages() {
        let mut rng = Rng::new(43);
        let sig = generate::smooth(64, 32, 2, &mut rng);
        let cfg = PipelineConfig::new(CoresetConfig::new(3, 0.3)).with_band_rows(8);
        let (_, metrics) = run(&sig, cfg);
        assert_eq!(metrics.bands_built(), 8);
        assert!(metrics.cells_processed() == 64 * 32);
        assert!(metrics.total_build_time().as_nanos() > 0);
    }
}
