//! Dataset generators reproducing the paper's experimental inputs.
//!
//! * [`blobs`], [`moons`], [`circles`] — reimplementations of
//!   `sklearn.datasets.make_{blobs,moons,circles}` at the sizes used in
//!   Figs. 5–7 (17k / 24k / 26k labeled points in the plane).
//! * [`rasterize`] — the point-cloud → signal bridge: the paper's coreset
//!   operates on signals, so the planar datasets are binned onto a grid
//!   whose cell label is the mean point label (empty cells masked).
//! * [`air_quality_like`], [`gesture_phase_like`] — UCI-dataset
//!   substitutes with matching shapes (9358×15, 9900×18), see DESIGN.md
//!   §Substitutions.
//! * [`holdout_patches`] — the missing-values protocol of §5: mask random
//!   5×5 patches totalling a target fraction of the matrix.

use crate::rng::Rng;
use crate::signal::{generate, Rect, Signal};

/// A planar labeled point (the sklearn-style datasets).
#[derive(Clone, Copy, Debug)]
pub struct Point2 {
    pub x: f64,
    pub y: f64,
    pub label: f64,
}

/// `make_blobs`-like: 3 gaussian clusters with sizes 8500/5800/2700 as in
/// Fig. 5 (sizes scaled by `scale` for tests).
pub fn blobs(scale: f64, rng: &mut Rng) -> Vec<Point2> {
    let sizes = [8500usize, 5800, 2700].map(|s| ((s as f64 * scale) as usize).max(10));
    let centers = [(-5.0, -2.0), (3.0, 4.0), (6.0, -4.0)];
    let std = 1.6;
    let mut out = Vec::new();
    for (i, (&n, &(cx, cy))) in sizes.iter().zip(centers.iter()).enumerate() {
        for _ in 0..n {
            out.push(Point2 {
                x: rng.normal_ms(cx, std),
                y: rng.normal_ms(cy, std),
                label: i as f64,
            });
        }
    }
    out
}

/// `make_moons`-like: two interleaving half circles, 12k points each in
/// Fig. 6.
pub fn moons(scale: f64, noise: f64, rng: &mut Rng) -> Vec<Point2> {
    let per = ((12_000.0 * scale) as usize).max(10);
    let mut out = Vec::with_capacity(2 * per);
    for i in 0..per {
        let t = std::f64::consts::PI * i as f64 / per as f64;
        out.push(Point2 {
            x: t.cos() + rng.normal_ms(0.0, noise),
            y: t.sin() + rng.normal_ms(0.0, noise),
            label: 0.0,
        });
        out.push(Point2 {
            x: 1.0 - t.cos() + rng.normal_ms(0.0, noise),
            y: 0.5 - t.sin() + rng.normal_ms(0.0, noise),
            label: 1.0,
        });
    }
    out
}

/// `make_circles`-like: concentric circles, 14k outer / 12k inner in
/// Fig. 7.
pub fn circles(scale: f64, noise: f64, rng: &mut Rng) -> Vec<Point2> {
    let outer = ((14_000.0 * scale) as usize).max(10);
    let inner = ((12_000.0 * scale) as usize).max(10);
    let mut out = Vec::with_capacity(outer + inner);
    for i in 0..outer {
        let t = std::f64::consts::TAU * i as f64 / outer as f64;
        out.push(Point2 {
            x: t.cos() + rng.normal_ms(0.0, noise),
            y: t.sin() + rng.normal_ms(0.0, noise),
            label: 0.0,
        });
    }
    for i in 0..inner {
        let t = std::f64::consts::TAU * i as f64 / inner as f64;
        out.push(Point2 {
            x: 0.5 * t.cos() + rng.normal_ms(0.0, noise),
            y: 0.5 * t.sin() + rng.normal_ms(0.0, noise),
            label: 1.0,
        });
    }
    out
}

/// Bin planar points onto an n×m grid; each cell's label is the mean
/// label of its points, empty cells are masked. This is how the paper's
/// appendix experiments feed point datasets to the signal coreset.
pub fn rasterize(points: &[Point2], n: usize, m: usize) -> Signal {
    assert!(!points.is_empty());
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for p in points {
        xmin = xmin.min(p.x);
        xmax = xmax.max(p.x);
        ymin = ymin.min(p.y);
        ymax = ymax.max(p.y);
    }
    let xr = (xmax - xmin).max(1e-9);
    let yr = (ymax - ymin).max(1e-9);
    let mut sums = vec![0.0f64; n * m];
    let mut counts = vec![0usize; n * m];
    for p in points {
        let r = (((p.y - ymin) / yr) * (n as f64 - 1e-9)).floor() as usize;
        let c = (((p.x - xmin) / xr) * (m as f64 - 1e-9)).floor() as usize;
        let idx = r.min(n - 1) * m + c.min(m - 1);
        sums[idx] += p.label;
        counts[idx] += 1;
    }
    let mut values = vec![0.0f64; n * m];
    let mut mask = vec![false; n * m];
    for i in 0..n * m {
        if counts[i] > 0 {
            values[i] = sums[i] / counts[i] as f64;
            mask[i] = true;
        }
    }
    Signal::from_values(n, m, values).with_mask(mask)
}

/// Air Quality substitute: 9358 instances × 15 features (UCI shape),
/// scaled by `scale` for tests. Sensor-panel structure: slow daily
/// periodicities + correlated channels + noise, z-normalized.
pub fn air_quality_like(scale: f64, rng: &mut Rng) -> Signal {
    let n = ((9358.0 * scale) as usize).max(40);
    let m = 15;
    // Sensor panels are smoother than generic tabular data: overlay a
    // periodic component on the low-rank factors.
    let mut sig = generate::tabular_like(n, m, 4, 0.1, rng);
    for r in 0..n {
        let day = (r as f64) * std::f64::consts::TAU / 24.0;
        for c in 0..m {
            let v = sig.get(r, c) + 0.5 * ((day + c as f64).sin());
            sig.set(r, c, v);
        }
    }
    generate::znormalize_columns(&mut sig);
    sig
}

/// Gesture Phase substitute: 9900 instances × 18 features. Gesture data
/// has segment structure (rest / gesture phases) — stronger regime
/// switching, less periodicity.
pub fn gesture_phase_like(scale: f64, rng: &mut Rng) -> Signal {
    let n = ((9900.0 * scale) as usize).max(40);
    let m = 18;
    let mut sig = generate::tabular_like(n, m, 5, 0.05, rng);
    // Inject phase segments: blocks of rows share an offset per feature.
    let mut r0 = 0usize;
    while r0 < n {
        let len = rng.range(20, 120).min(n - r0);
        let active = rng.bool(0.5);
        if active {
            for c in 0..m {
                let off = rng.normal_ms(0.0, 0.8);
                for r in r0..r0 + len {
                    let v = sig.get(r, c) + off;
                    sig.set(r, c, v);
                }
            }
        }
        r0 += len;
    }
    generate::znormalize_columns(&mut sig);
    sig
}

/// The §5 protocol: mask random 5×5 patches until ≥ `fraction` of cells
/// are held out; returns the masked signal plus the list of held-out
/// cells with their ground-truth labels (the test set).
pub fn holdout_patches(
    signal: &Signal,
    fraction: f64,
    patch: usize,
    rng: &mut Rng,
) -> (Signal, Vec<(usize, usize, f64)>) {
    assert!(fraction > 0.0 && fraction < 1.0);
    let n = signal.rows();
    let m = signal.cols();
    let target = ((n * m) as f64 * fraction) as usize;
    let mut masked = signal.clone();
    let mut held: Vec<(usize, usize, f64)> = Vec::new();
    let mut is_held = vec![false; n * m];
    let ph = patch.min(n);
    let pw = patch.min(m);
    let mut guard = 0usize;
    while held.len() < target && guard < 100 * target {
        guard += 1;
        let r0 = rng.usize(n - ph + 1);
        let c0 = rng.usize(m - pw + 1);
        for r in r0..r0 + ph {
            for c in c0..c0 + pw {
                let idx = r * m + c;
                if !is_held[idx] && signal.is_present(r, c) {
                    is_held[idx] = true;
                    held.push((r, c, signal.get(r, c)));
                }
            }
        }
        masked.mask_rect(Rect::new(r0, r0 + ph - 1, c0, c0 + pw - 1));
    }
    (masked, held)
}

/// Convert the *present* cells of a signal into training samples with
/// features (row, col).
pub fn signal_to_samples(signal: &Signal) -> Vec<crate::tree::Sample> {
    let mut out = Vec::with_capacity(signal.present());
    for r in 0..signal.rows() {
        for c in 0..signal.cols() {
            if signal.is_present(r, c) {
                out.push(crate::tree::Sample::new(
                    vec![r as f64, c as f64],
                    signal.get(r, c),
                    1.0,
                ));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blob_sizes_match_paper() {
        let mut rng = Rng::new(1);
        let pts = blobs(1.0, &mut rng);
        assert_eq!(pts.len(), 17_000);
        let c0 = pts.iter().filter(|p| p.label == 0.0).count();
        assert_eq!(c0, 8500);
    }

    #[test]
    fn moons_and_circles_sizes() {
        let mut rng = Rng::new(2);
        assert_eq!(moons(1.0, 0.05, &mut rng).len(), 24_000);
        assert_eq!(circles(1.0, 0.05, &mut rng).len(), 26_000);
    }

    #[test]
    fn rasterize_covers_and_masks() {
        let mut rng = Rng::new(3);
        let pts = blobs(0.05, &mut rng);
        let sig = rasterize(&pts, 40, 40);
        let present = sig.present();
        assert!(present > 0 && present < 1600);
        // Labels are in [0, 2].
        for r in 0..40 {
            for c in 0..40 {
                if sig.is_present(r, c) {
                    let v = sig.get(r, c);
                    assert!((0.0..=2.0).contains(&v));
                }
            }
        }
    }

    #[test]
    fn uci_like_shapes() {
        let mut rng = Rng::new(4);
        let air = air_quality_like(0.02, &mut rng);
        assert_eq!(air.cols(), 15);
        assert!(air.rows() >= 40);
        let ges = gesture_phase_like(0.02, &mut rng);
        assert_eq!(ges.cols(), 18);
    }

    #[test]
    fn holdout_reaches_fraction() {
        let mut rng = Rng::new(5);
        let sig = air_quality_like(0.05, &mut rng);
        let (masked, held) = holdout_patches(&sig, 0.3, 5, &mut rng);
        let total = sig.rows() * sig.cols();
        assert!(held.len() >= (total as f64 * 0.3) as usize);
        assert_eq!(masked.present() + held.len(), sig.present());
        // Held-out cells are masked and retain ground truth.
        for &(r, c, y) in held.iter().take(50) {
            assert!(!masked.is_present(r, c));
            assert_eq!(sig.get(r, c), y);
        }
    }

    #[test]
    fn signal_to_samples_skips_masked() {
        let mut rng = Rng::new(6);
        let sig = air_quality_like(0.02, &mut rng);
        let (masked, _) = holdout_patches(&sig, 0.2, 5, &mut rng);
        let samples = signal_to_samples(&masked);
        assert_eq!(samples.len(), masked.present());
    }
}
