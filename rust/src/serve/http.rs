//! Hand-rolled HTTP/1.1 request/response framing for [`crate::serve`]
//! (hyper/axum are unavailable offline — DESIGN.md §Substitutions, same
//! policy as `sigtree::json`/`sigtree::cli`).
//!
//! Deliberately minimal and hostile-input-first:
//!
//! * **`Content-Length` bodies only** — `Transfer-Encoding` (chunked)
//!   is rejected with `501`, a missing `Content-Length` means an empty
//!   body. Every frame boundary is therefore known before any body
//!   byte is read.
//! * Hard caps before allocation: request line + headers together are
//!   capped at [`MAX_HEAD_BYTES`] (`431` beyond), the declared body
//!   length is checked against the server's `max_body` (`413`) before
//!   the body buffer is allocated — an oversized `Content-Length` can
//!   never balloon memory or hang the connection.
//! * Keep-alive follows HTTP/1.1 defaults (`Connection: close` opts
//!   out; HTTP/1.0 must opt in with `keep-alive`).
//!
//! Parsing is generic over [`BufRead`] so the unit tests drive it from
//! byte slices without sockets; the connection loop in `serve::mod`
//! hands it a `BufReader<TcpStream>`.

use std::io::{BufRead, Write};

/// Cap on the request line + headers, combined. Far above any client
/// this crate ships, far below memory-pressure territory.
pub const MAX_HEAD_BYTES: usize = 8 * 1024;

/// One parsed request frame.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    /// Whether the client may reuse the connection after the response.
    pub keep_alive: bool,
}

/// Outcome of reading one frame off a connection.
#[derive(Debug)]
pub enum ReadOutcome {
    /// A well-formed request.
    Request(Request),
    /// Clean close (EOF before any byte) or an I/O failure — either
    /// way the connection is done and nothing can be written back.
    Closed,
    /// Malformed or over-limit input: respond with this status +
    /// message, then close.
    Reject(u16, String),
}

/// Read one `\n`-terminated line, enforcing the remaining head budget.
/// `Ok(None)` is clean EOF; `Err(true)` means over budget, `Err(false)`
/// an I/O error.
fn read_line<R: BufRead>(
    reader: &mut R,
    budget: &mut usize,
    line: &mut Vec<u8>,
) -> Result<Option<()>, bool> {
    line.clear();
    // +1 so a line exactly on the budget still terminates.
    let mut limited = reader.take(*budget as u64 + 1);
    match limited.read_until(b'\n', line) {
        Ok(0) => Ok(None),
        Ok(n) if n > *budget => Err(true),
        Ok(n) => {
            *budget -= n;
            if line.last() != Some(&b'\n') {
                // EOF mid-line: treat as close (nothing to answer).
                return Ok(None);
            }
            Ok(Some(()))
        }
        Err(_) => Err(false),
    }
}

fn trim_crlf(line: &[u8]) -> &[u8] {
    let mut end = line.len();
    while end > 0 && (line[end - 1] == b'\n' || line[end - 1] == b'\r') {
        end -= 1;
    }
    &line[..end]
}

/// Read and validate one request frame. `max_body` caps the declared
/// `Content-Length` (checked *before* the body buffer is allocated).
pub fn read_request<R: BufRead>(reader: &mut R, max_body: usize) -> ReadOutcome {
    let mut budget = MAX_HEAD_BYTES;
    let mut line = Vec::new();

    // Request line.
    match read_line(reader, &mut budget, &mut line) {
        Ok(None) => return ReadOutcome::Closed,
        Err(true) => {
            return ReadOutcome::Reject(431, format!("request head exceeds {MAX_HEAD_BYTES} bytes"))
        }
        Err(false) => return ReadOutcome::Closed,
        Ok(Some(())) => {}
    }
    let Ok(request_line) = std::str::from_utf8(trim_crlf(&line)) else {
        return ReadOutcome::Reject(400, "request line is not UTF-8".to_string());
    };
    let mut parts = request_line.split_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v), None) => (m.to_string(), p.to_string(), v),
        _ => {
            return ReadOutcome::Reject(400, format!("malformed request line '{request_line}'"));
        }
    };
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        other => {
            return ReadOutcome::Reject(400, format!("unsupported protocol version '{other}'"));
        }
    };

    // Headers (the serving API only consumes framing-relevant ones; the
    // rest are tolerated and ignored).
    let mut content_length: Option<usize> = None;
    let mut keep_alive = http11;
    loop {
        match read_line(reader, &mut budget, &mut line) {
            Ok(None) => return ReadOutcome::Closed,
            Err(true) => {
                return ReadOutcome::Reject(
                    431,
                    format!("request head exceeds {MAX_HEAD_BYTES} bytes"),
                )
            }
            Err(false) => return ReadOutcome::Closed,
            Ok(Some(())) => {}
        }
        let raw = trim_crlf(&line);
        if raw.is_empty() {
            break;
        }
        let Ok(header) = std::str::from_utf8(raw) else {
            return ReadOutcome::Reject(400, "header is not UTF-8".to_string());
        };
        let Some((name, value)) = header.split_once(':') else {
            return ReadOutcome::Reject(400, format!("malformed header '{header}'"));
        };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim();
        match name.as_str() {
            "content-length" => {
                let Ok(len) = value.parse::<usize>() else {
                    return ReadOutcome::Reject(400, format!("invalid Content-Length '{value}'"));
                };
                if content_length.is_some_and(|prev| prev != len) {
                    return ReadOutcome::Reject(400, "conflicting Content-Length".to_string());
                }
                content_length = Some(len);
            }
            "transfer-encoding" => {
                return ReadOutcome::Reject(
                    501,
                    "Transfer-Encoding is unsupported; send a Content-Length body".to_string(),
                );
            }
            "connection" => {
                let v = value.to_ascii_lowercase();
                if v.split(',').any(|t| t.trim() == "close") {
                    keep_alive = false;
                } else if v.split(',').any(|t| t.trim() == "keep-alive") {
                    keep_alive = true;
                }
            }
            _ => {}
        }
    }

    // Body: length known up front, capped before allocation.
    let len = content_length.unwrap_or(0);
    if len > max_body {
        return ReadOutcome::Reject(
            413,
            format!("Content-Length {len} exceeds the {max_body}-byte body limit"),
        );
    }
    let mut body = vec![0u8; len];
    if reader.read_exact(&mut body).is_err() {
        return ReadOutcome::Closed;
    }
    ReadOutcome::Request(Request { method, path, body, keep_alive })
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        411 => "Length Required",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "",
    }
}

/// Write one response frame (JSON body, explicit length, explicit
/// connection disposition).
pub fn write_response(
    writer: &mut impl Write,
    status: u16,
    body: &str,
    keep_alive: bool,
) -> std::io::Result<()> {
    let disposition = if keep_alive { "keep-alive" } else { "close" };
    write!(
        writer,
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {disposition}\r\n\r\n",
        reason(status),
        body.len(),
    )?;
    writer.write_all(body.as_bytes())?;
    writer.flush()
}

/// Client-side counterpart (integration tests, `bench_serve`, smoke
/// checks): read one response frame, returning `(status, body)`.
/// Responses are trusted — this is a test/bench convenience, not a
/// hardened parser — but it still refuses frames it cannot frame.
pub fn read_response<R: BufRead>(reader: &mut R) -> std::io::Result<(u16, String)> {
    use std::io::{Error, ErrorKind};

    let bad = |msg: &str| Error::new(ErrorKind::InvalidData, msg.to_string());
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| bad("malformed status line"))?;
    let mut content_length: Option<usize> = None;
    loop {
        line.clear();
        reader.read_line(&mut line)?;
        let trimmed = line.trim_end_matches(['\r', '\n']);
        if trimmed.is_empty() {
            break;
        }
        if let Some((name, value)) = trimmed.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length =
                    Some(value.trim().parse().map_err(|_| bad("bad Content-Length"))?);
            }
        }
    }
    let len = content_length.ok_or_else(|| bad("response without Content-Length"))?;
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    String::from_utf8(body)
        .map(|text| (status, text))
        .map_err(|_| bad("response body is not UTF-8"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn read(input: &[u8], max_body: usize) -> ReadOutcome {
        let mut reader = input;
        read_request(&mut reader, max_body)
    }

    #[test]
    fn parses_get_without_body() {
        let out = read(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n", 1024);
        let ReadOutcome::Request(req) = out else { panic!("{out:?}") };
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert!(req.body.is_empty());
        assert!(req.keep_alive, "HTTP/1.1 defaults to keep-alive");
    }

    #[test]
    fn parses_post_with_content_length_body() {
        let out = read(
            b"POST /coreset HTTP/1.1\r\nContent-Length: 4\r\nConnection: close\r\n\r\n{\"a\"",
            1024,
        );
        let ReadOutcome::Request(req) = out else { panic!("{out:?}") };
        assert_eq!(req.body, b"{\"a\"");
        assert!(!req.keep_alive);
    }

    #[test]
    fn http10_defaults_to_close_unless_keep_alive() {
        let out = read(b"GET / HTTP/1.0\r\n\r\n", 64);
        let ReadOutcome::Request(req) = out else { panic!("{out:?}") };
        assert!(!req.keep_alive);
        let out = read(b"GET / HTTP/1.0\r\nConnection: Keep-Alive\r\n\r\n", 64);
        let ReadOutcome::Request(req) = out else { panic!("{out:?}") };
        assert!(req.keep_alive);
    }

    #[test]
    fn clean_eof_is_closed_not_an_error() {
        assert!(matches!(read(b"", 64), ReadOutcome::Closed));
        // EOF mid-request-line: nothing well-formed to answer.
        assert!(matches!(read(b"GET /x HT", 64), ReadOutcome::Closed));
    }

    #[test]
    fn garbage_request_line_is_400() {
        let ReadOutcome::Reject(status, _) = read(b"BLAH\r\n\r\n", 64) else {
            panic!("expected reject")
        };
        assert_eq!(status, 400);
        let ReadOutcome::Reject(status, _) = read(b"GET /x SPDY/3\r\n\r\n", 64) else {
            panic!("expected reject")
        };
        assert_eq!(status, 400);
    }

    #[test]
    fn oversized_content_length_is_413_before_any_allocation() {
        // The declared length is absurd and the body bytes are absent —
        // the reject must fire from the header alone.
        let out = read(
            b"POST /coreset HTTP/1.1\r\nContent-Length: 999999999999\r\n\r\n",
            1024,
        );
        // usize parse succeeds on 64-bit; either way it must reject.
        let ReadOutcome::Reject(status, msg) = out else { panic!("{out:?}") };
        assert!(status == 413 || status == 400, "{status} {msg}");
    }

    #[test]
    fn invalid_and_conflicting_content_length_are_400() {
        let ReadOutcome::Reject(status, _) =
            read(b"POST / HTTP/1.1\r\nContent-Length: ten\r\n\r\n", 64)
        else {
            panic!("expected reject")
        };
        assert_eq!(status, 400);
        let ReadOutcome::Reject(status, _) = read(
            b"POST / HTTP/1.1\r\nContent-Length: 1\r\nContent-Length: 2\r\n\r\nx",
            64,
        ) else {
            panic!("expected reject")
        };
        assert_eq!(status, 400);
    }

    #[test]
    fn transfer_encoding_is_501() {
        let ReadOutcome::Reject(status, _) =
            read(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n", 64)
        else {
            panic!("expected reject")
        };
        assert_eq!(status, 501);
    }

    #[test]
    fn oversized_head_is_431() {
        let mut input = b"GET / HTTP/1.1\r\n".to_vec();
        input.extend_from_slice(format!("X-Pad: {}\r\n", "y".repeat(MAX_HEAD_BYTES)).as_bytes());
        input.extend_from_slice(b"\r\n");
        let ReadOutcome::Reject(status, _) = read(&input, 64) else { panic!("expected reject") };
        assert_eq!(status, 431);
    }

    #[test]
    fn response_frame_shape() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "{\"ok\": true}", true).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: 12\r\n"), "{text}");
        assert!(text.contains("Connection: keep-alive\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{\"ok\": true}"), "{text}");
        let mut out = Vec::new();
        write_response(&mut out, 404, "{}", false).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 404 Not Found\r\n"), "{text}");
        assert!(text.contains("Connection: close\r\n"), "{text}");
    }

    #[test]
    fn read_response_round_trips_write_response() {
        let mut out = Vec::new();
        write_response(&mut out, 503, "{\"error\": \"draining\"}", false).unwrap();
        let mut reader: &[u8] = &out;
        let (status, body) = read_response(&mut reader).unwrap();
        assert_eq!(status, 503);
        assert_eq!(body, "{\"error\": \"draining\"}");
    }

    #[test]
    fn keep_alive_frames_parse_back_to_back() {
        let mut input: &[u8] =
            b"GET /a HTTP/1.1\r\n\r\nPOST /b HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi";
        let ReadOutcome::Request(a) = read_request(&mut input, 64) else { panic!() };
        assert_eq!(a.path, "/a");
        let ReadOutcome::Request(b) = read_request(&mut input, 64) else { panic!() };
        assert_eq!(b.path, "/b");
        assert_eq!(b.body, b"hi");
        assert!(matches!(read_request(&mut input, 64), ReadOutcome::Closed));
    }
}
