//! `sigtree serve` — a long-lived coreset-query daemon over one shared
//! [`Engine`].
//!
//! The CLI pipeline (`coreset` → `evaluate` → …) pays the full engine
//! bring-up — worker-pool spawn, prefix statistics, coreset build — on
//! every invocation. The serving workflow inverts that: bring the
//! engine up once, keep built coresets hot, and answer many small
//! queries cheaply. Everything is `std`-only (hand-rolled HTTP/1.1 in
//! [`http`], [`crate::json`] for bodies — DESIGN.md §Substitutions).
//!
//! ## Architecture
//!
//! ```text
//!  TcpListener (acceptor, caller thread)
//!      │ accepted connections, mpsc
//!      ▼
//!  N connection threads ──────────────┐
//!      │ parse + validate (wire)      │ /coreset, /optimal_tree,
//!      │ /fitting_loss jobs, bounded  │ /stats … run on the
//!      ▼ mpsc                         │ connection thread
//!  collector thread ── gathers jobs within the batch window,
//!      │               concatenates queries per coreset
//!      ▼
//!  Engine::fitting_loss (persistent WorkerPool) ── scatter slices
//!      ▲                                            back per job
//!  LRU CoresetCache (keyed by signal digest × config digest)
//! ```
//!
//! **Batching is invisible to callers.** `Engine::fitting_loss` maps a
//! pure function over its query slice — query `i`'s loss depends on
//! nothing but `(coreset, queries[i])` — so evaluating a concatenation
//! and re-slicing the result is *bit-identical* to evaluating each
//! request alone (the integration tests assert this at 1/2/4/8 server
//! threads). The collector drains its queue with a quiet-gap timeout
//! ([`ServeConfig::batch_window_ms`]) and never reads a clock, so the
//! window bounds added latency without entangling results with timing.
//!
//! **Shutdown is a request, not a signal.** `POST /shutdown` answers
//! `200`, flips the drain flag, and wakes the acceptor with a loopback
//! connection; in-flight requests finish, keep-alive connections close
//! after their current response, worker threads join, and
//! [`Server::run`] returns. No SIGTERM handling — signal-safe teardown
//! without `unsafe` handlers, and exercisable from plain tests.
//!
//! Hostile input is the normal case: framing caps heads and bodies
//! before allocating ([`http`]), [`wire`] re-validates every invariant
//! the library's constructors only `assert!`, and handler threads are
//! panic-free by construction (`sigtree lint` enforces the no-panic
//! rule here as on the rest of the crate).

pub mod http;

mod cache;
mod wire;

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use crate::engine::Engine;
use crate::error::Result;
use crate::json::Json;
use crate::par::lock;
use crate::segmentation::KSegmentation;
use crate::signal::{content_digest, Fnv1a};

use cache::{CachedCoreset, CoresetCache};
use http::{ReadOutcome, Request};

/// Upper bound on queries in one `/fitting_loss` request.
pub const MAX_REQUEST_QUERIES: usize = 4096;

/// Upper bound on `k` for `/optimal_tree` — the guillotine DP over the
/// coreset grid is exponential-ish in `k`; this keeps one request from
/// monopolising the daemon.
pub const MAX_TREE_K: usize = 32;

/// Pending `/fitting_loss` jobs the collector queue will hold before
/// senders block (backpressure, not unbounded growth).
const FIT_QUEUE_BOUND: usize = 1024;

/// Daemon knobs, separate from the [`crate::engine::EngineConfig`] the
/// wrapped engine runs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address; port 0 picks an ephemeral port (read it back via
    /// [`Server::local_addr`]).
    pub addr: String,
    /// Connection-handler threads (clamped to ≥ 1). These only parse,
    /// validate and route; numeric work runs on the engine's pool.
    pub threads: usize,
    /// Quiet-gap batch window in milliseconds. After the first pending
    /// `/fitting_loss` job, the collector keeps gathering until the
    /// queue stays empty this long (or [`ServeConfig::batch_max`]
    /// queries accumulate). `0` disables gathering — every request
    /// evaluates alone (the bench's "unbatched" baseline).
    pub batch_window_ms: u64,
    /// Cap on concatenated queries per engine call.
    pub batch_max: usize,
    /// LRU capacity of the coreset cache (entries, clamped to ≥ 1).
    pub cache_cap: usize,
    /// Request-body cap in bytes (`413` beyond).
    pub max_body: usize,
    /// Per-connection read timeout in milliseconds; idle keep-alive
    /// connections are dropped after this long so they cannot pin
    /// handler threads. `0` waits forever.
    pub read_timeout_ms: u64,
    /// Log one line per request to stderr (`serve --foreground`).
    pub log_requests: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            threads: 4,
            batch_window_ms: 2,
            batch_max: 1024,
            cache_cap: 16,
            max_body: 8 * 1024 * 1024,
            read_timeout_ms: 5000,
            log_requests: false,
        }
    }
}

/// Monotone counters for `/stats` (relaxed ordering throughout — they
/// are operational telemetry, not synchronisation).
#[derive(Debug, Default)]
struct ServeStats {
    requests: AtomicU64,
    http_errors: AtomicU64,
    coreset: AtomicU64,
    fitting_loss: AtomicU64,
    optimal_tree: AtomicU64,
    healthz: AtomicU64,
    stats: AtomicU64,
    shutdown: AtomicU64,
    queries: AtomicU64,
    batches: AtomicU64,
    max_batch: AtomicU64,
    coreset_builds: AtomicU64,
}

fn bump(counter: &AtomicU64) {
    counter.fetch_add(1, Ordering::Relaxed);
}

/// One pending `/fitting_loss` request, parked on its rendezvous
/// channel until the collector scatters the batch result back.
struct FitJob {
    coreset: Arc<CachedCoreset>,
    queries: Vec<KSegmentation>,
    reply: SyncSender<Vec<f64>>,
}

/// Shared server state (one per [`Server::run`], `Arc`ed across the
/// connection threads).
struct Ctx {
    engine: Arc<Engine>,
    cache: Mutex<CoresetCache>,
    stats: ServeStats,
    shutdown: AtomicBool,
    cfg: ServeConfig,
    /// FNV-1a over the engine config's canonical JSON — half of every
    /// cache key, so a parameter change can never serve stale coresets.
    config_digest: u64,
    /// Loopback address for the shutdown self-connect wake-up.
    addr: SocketAddr,
}

/// The daemon: a bound listener plus the engine it serves.
pub struct Server {
    engine: Engine,
    listener: TcpListener,
    cfg: ServeConfig,
}

impl Server {
    /// Bind `cfg.addr` (port 0 = ephemeral). The engine is taken by
    /// value: the daemon owns it for its lifetime.
    pub fn bind(engine: Engine, cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        Ok(Server { engine, listener, cfg })
    }

    /// The bound address (read the ephemeral port back from here).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until a `POST /shutdown` drains the daemon. Blocks the
    /// calling thread (the acceptor loop runs here); returns after
    /// every connection thread and the batch collector have joined.
    pub fn run(self) -> Result<()> {
        let Server { engine, listener, cfg } = self;
        let addr = listener.local_addr()?;
        let config_digest = config_digest(&engine);
        let engine = Arc::new(engine);

        let ctx = Arc::new(Ctx {
            engine: Arc::clone(&engine),
            cache: Mutex::new(CoresetCache::new(cfg.cache_cap)),
            stats: ServeStats::default(),
            shutdown: AtomicBool::new(false),
            cfg: cfg.clone(),
            config_digest,
            addr,
        });

        let (fit_tx, fit_rx) = mpsc::sync_channel::<FitJob>(FIT_QUEUE_BOUND);
        let collector = {
            let ctx = Arc::clone(&ctx);
            std::thread::Builder::new()
                .name("sigtree-serve-batch".to_string())
                .spawn(move || collector_loop(&ctx, &fit_rx))?
        };

        let (conn_tx, conn_rx) = mpsc::channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut handlers = Vec::with_capacity(cfg.threads.max(1));
        for i in 0..cfg.threads.max(1) {
            let ctx = Arc::clone(&ctx);
            let rx = Arc::clone(&conn_rx);
            let tx = fit_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("sigtree-serve-conn-{i}"))
                .spawn(move || handler_loop(&ctx, &rx, &tx))?;
            handlers.push(handle);
        }
        // The collector must observe disconnect once every handler
        // exits; run() keeps no sender of its own.
        drop(fit_tx);

        for stream in listener.incoming() {
            if ctx.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            if conn_tx.send(stream).is_err() {
                break;
            }
        }

        // Drain: no new connections; handlers finish their queues and
        // exit, then the collector sees its senders disconnect.
        drop(conn_tx);
        for handle in handlers {
            let _ = handle.join();
        }
        let _ = collector.join();
        Ok(())
    }
}

/// FNV-1a over the canonical JSON rendering of the engine's config.
fn config_digest(engine: &Engine) -> u64 {
    let mut h = Fnv1a::new();
    h.write(engine.config().to_json().render().as_bytes());
    h.finish()
}

/// Connection-thread main: pull accepted sockets off the shared
/// receiver (lock held only for the `recv`, never while serving) until
/// the acceptor hangs up.
fn handler_loop(ctx: &Ctx, rx: &Mutex<Receiver<TcpStream>>, fit_tx: &SyncSender<FitJob>) {
    loop {
        let stream = match lock(rx).recv() {
            Ok(s) => s,
            Err(_) => return,
        };
        handle_connection(ctx, fit_tx, stream);
    }
}

/// Serve one connection until close, keep-alive exhaustion, or drain.
fn handle_connection(ctx: &Ctx, fit_tx: &SyncSender<FitJob>, stream: TcpStream) {
    if ctx.cfg.read_timeout_ms > 0 {
        let _ = stream.set_read_timeout(Some(Duration::from_millis(ctx.cfg.read_timeout_ms)));
    }
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    loop {
        match http::read_request(&mut reader, ctx.cfg.max_body) {
            ReadOutcome::Closed => return,
            ReadOutcome::Reject(status, msg) => {
                bump(&ctx.stats.http_errors);
                let body = error_body(&msg);
                let _ = http::write_response(&mut writer, status, &body, false);
                return;
            }
            ReadOutcome::Request(req) => {
                let routed = route(ctx, fit_tx, &req);
                if routed.status >= 400 {
                    bump(&ctx.stats.http_errors);
                }
                let keep = req.keep_alive
                    && !routed.shutdown
                    && !ctx.shutdown.load(Ordering::SeqCst);
                let write = http::write_response(&mut writer, routed.status, &routed.body, keep);
                if ctx.cfg.log_requests {
                    eprintln!("sigtree serve: {} {} -> {}", req.method, req.path, routed.status);
                }
                if routed.shutdown {
                    trigger_shutdown(ctx);
                }
                if write.is_err() || !keep {
                    return;
                }
            }
        }
    }
}

/// Flip the drain flag and wake the blocked acceptor with a loopback
/// self-connect (the accepted wake-up socket is discarded there).
fn trigger_shutdown(ctx: &Ctx) {
    ctx.shutdown.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(ctx.addr);
}

struct Routed {
    status: u16,
    body: String,
    shutdown: bool,
}

fn respond(status: u16, body: Json) -> Routed {
    Routed { status, body: body.render(), shutdown: false }
}

fn error_body(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).render()
}

fn fail(status: u16, msg: String) -> Routed {
    Routed { status, body: error_body(&msg), shutdown: false }
}

const ROUTES: &[(&str, &str)] = &[
    ("GET", "/healthz"),
    ("GET", "/stats"),
    ("POST", "/coreset"),
    ("POST", "/fitting_loss"),
    ("POST", "/optimal_tree"),
    ("POST", "/shutdown"),
];

fn route(ctx: &Ctx, fit_tx: &SyncSender<FitJob>, req: &Request) -> Routed {
    bump(&ctx.stats.requests);
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            bump(&ctx.stats.healthz);
            respond(200, Json::obj(vec![("ok", Json::Bool(true))]))
        }
        ("GET", "/stats") => {
            bump(&ctx.stats.stats);
            respond(200, stats_body(ctx))
        }
        ("POST", "/coreset") => {
            bump(&ctx.stats.coreset);
            post_coreset(ctx, &req.body)
        }
        ("POST", "/fitting_loss") => {
            bump(&ctx.stats.fitting_loss);
            post_fitting_loss(ctx, fit_tx, &req.body)
        }
        ("POST", "/optimal_tree") => {
            bump(&ctx.stats.optimal_tree);
            post_optimal_tree(ctx, &req.body)
        }
        ("POST", "/shutdown") => {
            bump(&ctx.stats.shutdown);
            Routed {
                status: 200,
                body: Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("draining", Json::Bool(true)),
                ])
                .render(),
                shutdown: true,
            }
        }
        (_, path) if ROUTES.iter().any(|(_, p)| *p == path) => {
            fail(405, format!("method {} not allowed on {path}", req.method))
        }
        (_, path) => fail(404, format!("unknown endpoint {path}")),
    }
}

fn parse_body(body: &[u8]) -> Result<Json, (u16, String)> {
    let text = std::str::from_utf8(body)
        .map_err(|_| (400, "request body is not UTF-8".to_string()))?;
    Json::parse(text).map_err(|e| (400, format!("request body is not valid JSON: {e}")))
}

/// Resolve the coreset a request addresses: by content (`"signal"`,
/// building + caching on miss) or by reference (`"digest"`, cache-only).
/// Returns the entry, whether it was served from cache, and its digest.
fn resolve_coreset(
    ctx: &Ctx,
    doc: &Json,
) -> Result<(Arc<CachedCoreset>, bool, u64), (u16, String)> {
    if let Some(d) = doc.get("digest") {
        let Some(digest) = d.as_str().and_then(wire::parse_digest) else {
            return Err((400, "\"digest\" must be a hex string like \"0x1b3\"".to_string()));
        };
        let key = (digest, ctx.config_digest);
        return match lock(&ctx.cache).lookup(key) {
            Some(entry) => Ok((entry, true, digest)),
            None => Err((
                404,
                format!(
                    "no cached coreset for digest {digest:#x}; POST the signal to /coreset first"
                ),
            )),
        };
    }
    let Some(sig_doc) = doc.get("signal") else {
        return Err((400, "body must carry a \"signal\" object or a \"digest\"".to_string()));
    };
    let signal = wire::signal_from_json(sig_doc).map_err(|e| (400, format!("signal: {e}")))?;
    let digest = content_digest(&signal);
    let key = (digest, ctx.config_digest);
    if let Some(entry) = lock(&ctx.cache).lookup(key) {
        return Ok((entry, true, digest));
    }
    // Build outside the cache lock: a slow build must not stall hits
    // on other keys. A racing duplicate build returns identical bits
    // (determinism — both families are seeded and thread-invariant),
    // and `insert` keeps the incumbent. `compress` builds whichever
    // family the engine config selects; the family rides the config
    // digest, so the two families can never share a cache line.
    let coreset = ctx.engine.compress(&signal);
    bump(&ctx.stats.coreset_builds);
    let entry = Arc::new(CachedCoreset {
        coreset,
        rows: signal.rows(),
        cols: signal.cols(),
    });
    let entry = lock(&ctx.cache).insert(key, entry);
    Ok((entry, false, digest))
}

fn post_coreset(ctx: &Ctx, body: &[u8]) -> Routed {
    let doc = match parse_body(body) {
        Ok(doc) => doc,
        Err((status, msg)) => return fail(status, msg),
    };
    let (entry, cached, digest) = match resolve_coreset(ctx, &doc) {
        Ok(r) => r,
        Err((status, msg)) => return fail(status, msg),
    };
    respond(200, wire::coreset_summary_json(&entry, digest, cached))
}

fn post_fitting_loss(ctx: &Ctx, fit_tx: &SyncSender<FitJob>, body: &[u8]) -> Routed {
    let doc = match parse_body(body) {
        Ok(doc) => doc,
        Err((status, msg)) => return fail(status, msg),
    };
    let (entry, cached, digest) = match resolve_coreset(ctx, &doc) {
        Ok(r) => r,
        Err((status, msg)) => return fail(status, msg),
    };
    let Some(Json::Arr(raw)) = doc.get("queries") else {
        return fail(400, "body needs a \"queries\" array of segmentations".to_string());
    };
    if raw.len() > MAX_REQUEST_QUERIES {
        return fail(
            400,
            format!("{} queries in one request, limit is {MAX_REQUEST_QUERIES}", raw.len()),
        );
    }
    let mut queries = Vec::with_capacity(raw.len());
    for (i, q) in raw.iter().enumerate() {
        match wire::segmentation_from_json(q, entry.rows, entry.cols) {
            Ok(seg) => queries.push(seg),
            Err(e) => return fail(400, format!("query {i}: {e}")),
        }
    }
    let n = queries.len();
    ctx.stats.queries.fetch_add(n as u64, Ordering::Relaxed);
    let losses = if n == 0 {
        Vec::new()
    } else {
        let (reply_tx, reply_rx) = mpsc::sync_channel::<Vec<f64>>(1);
        let job = FitJob { coreset: Arc::clone(&entry), queries, reply: reply_tx };
        if fit_tx.send(job).is_err() {
            return fail(503, "server is draining".to_string());
        }
        match reply_rx.recv() {
            Ok(losses) => losses,
            Err(_) => return fail(503, "server is draining".to_string()),
        }
    };
    respond(
        200,
        Json::obj(vec![
            ("digest", wire::digest_to_json(digest)),
            ("cached", Json::Bool(cached)),
            ("losses", Json::Arr(losses.into_iter().map(Json::num).collect())),
        ]),
    )
}

fn post_optimal_tree(ctx: &Ctx, body: &[u8]) -> Routed {
    let doc = match parse_body(body) {
        Ok(doc) => doc,
        Err((status, msg)) => return fail(status, msg),
    };
    let (entry, cached, digest) = match resolve_coreset(ctx, &doc) {
        Ok(r) => r,
        Err((status, msg)) => return fail(status, msg),
    };
    let k = match doc.get("k").and_then(Json::as_usize) {
        Some(k) if (1..=MAX_TREE_K).contains(&k) => k,
        Some(k) => return fail(400, format!("k = {k} outside 1..={MAX_TREE_K}")),
        None => return fail(400, "body needs an integer \"k\"".to_string()),
    };
    // The smoothed-density oracle needs the deterministic family's
    // block structure; a sensitivity-family engine cannot answer this.
    let Some(coreset) = entry.coreset.as_caratheodory() else {
        return fail(
            400,
            "optimal_tree requires the caratheodory coreset family (engine is configured for sensitivity sampling)"
                .to_string(),
        );
    };
    let (seg, loss) = ctx.engine.optimal_tree_of_coreset(coreset, k);
    respond(
        200,
        Json::obj(vec![
            ("digest", wire::digest_to_json(digest)),
            ("cached", Json::Bool(cached)),
            ("k", Json::int(k)),
            ("loss", Json::num(loss)),
            ("pieces", wire::segmentation_to_json(&seg)),
        ]),
    )
}

fn stats_body(ctx: &Ctx) -> Json {
    let count = |c: &AtomicU64| Json::Num(c.load(Ordering::Relaxed) as f64);
    let s = &ctx.stats;
    let cache = lock(&ctx.cache);
    Json::obj(vec![
        ("requests", count(&s.requests)),
        ("http_errors", count(&s.http_errors)),
        (
            "endpoints",
            Json::obj(vec![
                ("coreset", count(&s.coreset)),
                ("fitting_loss", count(&s.fitting_loss)),
                ("optimal_tree", count(&s.optimal_tree)),
                ("healthz", count(&s.healthz)),
                ("stats", count(&s.stats)),
                ("shutdown", count(&s.shutdown)),
            ]),
        ),
        ("queries", count(&s.queries)),
        ("batches", count(&s.batches)),
        ("max_batch", count(&s.max_batch)),
        ("coreset_builds", count(&s.coreset_builds)),
        (
            "cache",
            Json::obj(vec![
                ("entries", Json::int(cache.len())),
                ("capacity", Json::int(cache.cap())),
                ("hits", Json::Num(cache.hits() as f64)),
                ("misses", Json::Num(cache.misses() as f64)),
                ("evictions", Json::Num(cache.evictions() as f64)),
            ]),
        ),
        (
            "engine",
            Json::obj(vec![
                ("threads", Json::int(ctx.engine.threads())),
                ("config_digest", wire::digest_to_json(ctx.config_digest)),
            ]),
        ),
    ])
}

/// Collector-thread main: gather `/fitting_loss` jobs within the batch
/// window, evaluate each coreset's concatenated queries in ONE engine
/// call, scatter result slices back in arrival order. Exits when every
/// handler (sender) is gone.
fn collector_loop(ctx: &Ctx, rx: &Receiver<FitJob>) {
    let window = Duration::from_millis(ctx.cfg.batch_window_ms);
    let batch_max = ctx.cfg.batch_max.max(1);
    loop {
        let first = match rx.recv() {
            Ok(job) => job,
            Err(_) => return,
        };
        let mut jobs = vec![first];
        let mut total = jobs.iter().map(|j| j.queries.len()).sum::<usize>();
        if !window.is_zero() {
            while total < batch_max {
                match rx.recv_timeout(window) {
                    Ok(job) => {
                        total += job.queries.len();
                        jobs.push(job);
                    }
                    Err(RecvTimeoutError::Timeout | RecvTimeoutError::Disconnected) => break,
                }
            }
        }
        ctx.stats.max_batch.fetch_max(total as u64, Ordering::Relaxed);

        // Group by coreset identity (Arc pointer — entries are unique
        // per cache key), preserving arrival order within each group.
        let mut groups: Vec<(*const CachedCoreset, Vec<FitJob>)> = Vec::new();
        for job in jobs {
            let key = Arc::as_ptr(&job.coreset);
            match groups.iter_mut().find(|(k, _)| *k == key) {
                Some((_, group)) => group.push(job),
                None => groups.push((key, vec![job])),
            }
        }
        for (_, group) in groups {
            bump(&ctx.stats.batches);
            let Some(coreset) = group.first().map(|j| Arc::clone(&j.coreset)) else { continue };
            let mut replies = Vec::with_capacity(group.len());
            let mut flat: Vec<KSegmentation> = Vec::with_capacity(
                group.iter().map(|j| j.queries.len()).sum::<usize>(),
            );
            for job in group {
                replies.push((job.reply, job.queries.len()));
                flat.extend(job.queries);
            }
            let losses = ctx.engine.fitting_loss(&coreset.coreset, &flat);
            let mut offset = 0;
            for (reply, n) in replies {
                let slice = losses.get(offset..offset + n).map(<[f64]>::to_vec);
                offset += n;
                // A handler that vanished mid-flight (should not
                // happen; handlers always await) just drops the slice.
                let _ = reply.send(slice.unwrap_or_default());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineConfig;

    fn test_engine() -> Engine {
        let mut cfg = EngineConfig::new(2, 0.5);
        cfg.threads = 1;
        Engine::new(cfg).expect("engine")
    }

    #[test]
    fn config_digest_tracks_every_engine_knob() {
        let a = config_digest(&test_engine());
        let mut cfg = EngineConfig::new(2, 0.5);
        cfg.threads = 1;
        cfg.seed = cfg.seed.wrapping_add(1);
        let b = config_digest(&Engine::new(cfg).expect("engine"));
        assert_ne!(a, b, "seed change must isolate its own cache lines");
    }

    #[test]
    fn serve_config_defaults_are_sane() {
        let cfg = ServeConfig::default();
        assert!(cfg.threads >= 1);
        assert!(cfg.batch_max >= 1);
        assert!(cfg.cache_cap >= 1);
        assert!(cfg.max_body >= 1024);
    }

    #[test]
    fn bind_on_ephemeral_port_reports_an_address() {
        let server = Server::bind(test_engine(), ServeConfig::default()).expect("bind");
        let addr = server.local_addr().expect("addr");
        assert_ne!(addr.port(), 0);
    }
}
