//! LRU cache of built coresets, keyed by *content*, not connection.
//!
//! The expensive operation the daemon guards is `Engine::coreset` — a
//! full bicriteria + partition + Caratheodory pipeline over the input
//! signal. Two requests carrying the same signal under the same engine
//! configuration provably produce the bit-identical coreset (the whole
//! pipeline is deterministic by construction — DESIGN.md
//! §Determinism), so the daemon caches by
//! `(signal content digest, engine-config digest)`:
//!
//! * the signal digest is [`crate::signal::content_digest`] — FNV-1a
//!   over dimensions, mask, and the exact value bits;
//! * the config digest is FNV-1a over the canonical JSON rendering of
//!   the [`crate::engine::EngineConfig`], so *any* parameter change
//!   (ε, k, seed, backend…) isolates its own cache line.
//!
//! Entries are `Arc`-shared: a hit hands out a clone of the pointer,
//! so eviction never invalidates a coreset an in-flight request is
//! still reading. The store is a plain vector in MRU-first order —
//! capacities are tens of entries, where a linear scan beats any
//! hashed structure and keeps recency bookkeeping trivial.
//!
//! The cache itself is not synchronised; `serve::mod` wraps it in a
//! `Mutex` and — deliberately — builds missing coresets *outside* the
//! lock so a slow build never stalls hits on other keys.

use std::sync::Arc;

use crate::engine::Compression;

/// `(signal content digest, engine-config digest)`.
pub type CacheKey = (u64, u64);

/// A built compression (either coreset family — the config digest keys
/// the family, since `coreset_family` rides the canonical config JSON)
/// plus the source-signal dimensions, which requests that address the
/// entry by digest alone still need for validating query-segmentation
/// bounds.
#[derive(Debug)]
pub struct CachedCoreset {
    pub coreset: Compression,
    pub rows: usize,
    pub cols: usize,
}

/// Fixed-capacity LRU map, MRU-first vector order.
#[derive(Debug)]
pub struct CoresetCache {
    cap: usize,
    entries: Vec<(CacheKey, Arc<CachedCoreset>)>,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl CoresetCache {
    /// A zero capacity is clamped to 1: the daemon always keeps at
    /// least the most recent coreset alive.
    pub fn new(cap: usize) -> Self {
        CoresetCache { cap: cap.max(1), entries: Vec::new(), hits: 0, misses: 0, evictions: 0 }
    }

    /// Look `key` up, refreshing its recency and counting a hit or a
    /// miss. Misses include digest-only requests for entries that were
    /// never built (or already evicted).
    pub fn lookup(&mut self, key: CacheKey) -> Option<Arc<CachedCoreset>> {
        match self.entries.iter().position(|(k, _)| *k == key) {
            Some(pos) => {
                self.hits += 1;
                let entry = self.entries.remove(pos);
                let value = Arc::clone(&entry.1);
                self.entries.insert(0, entry);
                Some(value)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert a freshly built entry, evicting the LRU tail beyond
    /// capacity. If another thread raced the same build in, the
    /// incumbent wins and is returned — both builds are bit-identical
    /// (determinism), so which `Arc` survives is unobservable.
    pub fn insert(&mut self, key: CacheKey, value: Arc<CachedCoreset>) -> Arc<CachedCoreset> {
        if let Some(pos) = self.entries.iter().position(|(k, _)| *k == key) {
            let entry = self.entries.remove(pos);
            let incumbent = Arc::clone(&entry.1);
            self.entries.insert(0, entry);
            return incumbent;
        }
        self.entries.insert(0, (key, Arc::clone(&value)));
        while self.entries.len() > self.cap {
            self.entries.pop();
            self.evictions += 1;
        }
        value
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coreset::SignalCoreset;

    fn entry() -> Arc<CachedCoreset> {
        let signal = crate::signal::Signal::from_fn(4, 4, |r, c| (r + 2 * c) as f64);
        let coreset = Compression::Caratheodory(SignalCoreset::construct(&signal, 1, 0.5));
        Arc::new(CachedCoreset { coreset, rows: 4, cols: 4 })
    }

    #[test]
    fn lookup_counts_hits_and_misses() {
        let mut cache = CoresetCache::new(4);
        assert!(cache.lookup((1, 1)).is_none());
        cache.insert((1, 1), entry());
        assert!(cache.lookup((1, 1)).is_some());
        assert!(cache.lookup((2, 1)).is_none());
        assert_eq!((cache.hits(), cache.misses()), (1, 2));
    }

    #[test]
    fn evicts_least_recently_used_beyond_capacity() {
        let mut cache = CoresetCache::new(2);
        cache.insert((1, 0), entry());
        cache.insert((2, 0), entry());
        // Touch (1, 0) so (2, 0) becomes the LRU tail.
        assert!(cache.lookup((1, 0)).is_some());
        cache.insert((3, 0), entry());
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1);
        assert!(cache.lookup((2, 0)).is_none(), "LRU entry must be the one evicted");
        assert!(cache.lookup((1, 0)).is_some());
        assert!(cache.lookup((3, 0)).is_some());
    }

    #[test]
    fn racing_insert_keeps_the_incumbent() {
        let mut cache = CoresetCache::new(2);
        let first = cache.insert((7, 7), entry());
        let second = cache.insert((7, 7), entry());
        assert!(Arc::ptr_eq(&first, &second), "incumbent entry must win the race");
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn zero_capacity_is_clamped_to_one() {
        let mut cache = CoresetCache::new(0);
        assert_eq!(cache.cap(), 1);
        cache.insert((1, 0), entry());
        cache.insert((2, 0), entry());
        assert_eq!(cache.len(), 1);
        assert!(cache.lookup((2, 0)).is_some());
    }

    #[test]
    fn eviction_does_not_invalidate_outstanding_handles() {
        let mut cache = CoresetCache::new(1);
        let held = cache.insert((1, 0), entry());
        cache.insert((2, 0), entry());
        assert!(cache.lookup((1, 0)).is_none());
        // The Arc handed out before eviction still works.
        assert_eq!(held.rows, 4);
    }
}
