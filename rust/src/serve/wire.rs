//! JSON ⇄ domain-object conversion for the serving API, with the
//! validation posture the library itself deliberately does not have.
//!
//! Library constructors (`Signal::from_values`, `Rect::new`,
//! `KSegmentation::new`) `assert!`/`debug_assert!` their invariants —
//! correct for trusted in-process callers, but a panic path when the
//! bytes came off a socket. Every function here therefore re-validates
//! *before* touching a constructor, so malformed network input surfaces
//! as a `400`-able `Err(String)` and can never take a handler thread
//! down. Conversions are exact: values travel as JSON numbers rendered
//! by `Json::render` (shortest round-trip form) and re-parsed by the
//! strict grammar, so `f64` bits survive the wire unchanged — the
//! property the batched-vs-sequential bit-identity tests assert
//! end-to-end.

use crate::json::Json;
use crate::segmentation::KSegmentation;
use crate::signal::{Rect, Signal};

/// Hard cap on `rows * cols` for a signal received over the wire
/// (16.7M cells ≈ 128 MiB of JSON text, far above the default body
/// limit — this is defence in depth for operators who raise it).
pub const MAX_SIGNAL_CELLS: usize = 1 << 24;

/// Hard cap on pieces per query segmentation; disjointness validation
/// is O(pieces²), so this bounds per-request CPU as well as memory.
pub const MAX_QUERY_PIECES: usize = 1024;

/// Decode `{"rows": n, "cols": m, "values": [...], "mask": [...]}` —
/// `values` row-major with `n * m` finite numbers, `mask` optional
/// booleans of the same length (false = missing cell).
pub(crate) fn signal_from_json(doc: &Json) -> Result<Signal, String> {
    let rows = field_usize(doc, "rows")?;
    let cols = field_usize(doc, "cols")?;
    if rows == 0 || cols == 0 {
        return Err("signal dimensions must be positive".to_string());
    }
    let cells = rows
        .checked_mul(cols)
        .filter(|&c| c <= MAX_SIGNAL_CELLS)
        .ok_or_else(|| format!("signal exceeds {MAX_SIGNAL_CELLS} cells"))?;
    let Some(Json::Arr(raw)) = doc.get("values") else {
        return Err("signal needs a \"values\" array".to_string());
    };
    if raw.len() != cells {
        return Err(format!(
            "\"values\" holds {} entries, expected rows*cols = {cells}",
            raw.len()
        ));
    }
    let mut values = Vec::with_capacity(cells);
    for (i, v) in raw.iter().enumerate() {
        match v.as_f64() {
            Some(x) if x.is_finite() => values.push(x),
            _ => return Err(format!("\"values\"[{i}] is not a finite number")),
        }
    }
    let mut signal = Signal::from_values(rows, cols, values);
    match doc.get("mask") {
        None => {}
        Some(Json::Arr(raw_mask)) => {
            if raw_mask.len() != cells {
                return Err(format!(
                    "\"mask\" holds {} entries, expected rows*cols = {cells}",
                    raw_mask.len()
                ));
            }
            let mut mask = Vec::with_capacity(cells);
            for (i, b) in raw_mask.iter().enumerate() {
                match b.as_bool() {
                    Some(present) => mask.push(present),
                    None => return Err(format!("\"mask\"[{i}] is not a boolean")),
                }
            }
            if !mask.iter().any(|&p| p) {
                return Err("\"mask\" leaves no present cells".to_string());
            }
            signal = signal.with_mask(mask);
        }
        Some(_) => return Err("\"mask\" must be an array of booleans".to_string()),
    }
    Ok(signal)
}

/// Decode `{"pieces": [{"r0", "r1", "c0", "c1", "value"}, ...]}` into a
/// [`KSegmentation`] whose rectangles fit inside `rows × cols` and are
/// pairwise disjoint (inclusive coordinates, as everywhere in the
/// crate). Partial coverage is fine — `fitting_loss` treats uncovered
/// area as zero contribution.
pub(crate) fn segmentation_from_json(
    doc: &Json,
    rows: usize,
    cols: usize,
) -> Result<KSegmentation, String> {
    let Some(Json::Arr(raw)) = doc.get("pieces") else {
        return Err("query needs a \"pieces\" array".to_string());
    };
    if raw.is_empty() {
        return Err("query needs at least one piece".to_string());
    }
    if raw.len() > MAX_QUERY_PIECES {
        return Err(format!(
            "query holds {} pieces, limit is {MAX_QUERY_PIECES}",
            raw.len()
        ));
    }
    let mut pieces = Vec::with_capacity(raw.len());
    for (i, p) in raw.iter().enumerate() {
        let r0 = field_usize(p, "r0").map_err(|e| format!("piece {i}: {e}"))?;
        let r1 = field_usize(p, "r1").map_err(|e| format!("piece {i}: {e}"))?;
        let c0 = field_usize(p, "c0").map_err(|e| format!("piece {i}: {e}"))?;
        let c1 = field_usize(p, "c1").map_err(|e| format!("piece {i}: {e}"))?;
        if r0 > r1 || c0 > c1 {
            return Err(format!("piece {i}: degenerate rectangle {r0}..{r1} x {c0}..{c1}"));
        }
        if r1 >= rows || c1 >= cols {
            return Err(format!(
                "piece {i}: rectangle {r0}..{r1} x {c0}..{c1} exceeds the {rows}x{cols} signal"
            ));
        }
        let value = match p.get("value").and_then(Json::as_f64) {
            Some(x) if x.is_finite() => x,
            _ => return Err(format!("piece {i}: \"value\" is not a finite number")),
        };
        pieces.push((Rect { r0, r1, c0, c1 }, value));
    }
    if !KSegmentation::pairwise_disjoint(&pieces) {
        return Err("pieces overlap; a k-segmentation needs disjoint rectangles".to_string());
    }
    Ok(KSegmentation::new(pieces))
}

/// Render a segmentation as the same shape [`segmentation_from_json`]
/// reads, so `/optimal_tree` output can be replayed as a
/// `/fitting_loss` query verbatim.
pub(crate) fn segmentation_to_json(seg: &KSegmentation) -> Json {
    Json::Arr(
        seg.pieces()
            .iter()
            .map(|(rect, value)| {
                Json::obj(vec![
                    ("r0", Json::int(rect.r0)),
                    ("r1", Json::int(rect.r1)),
                    ("c0", Json::int(rect.c0)),
                    ("c1", Json::int(rect.c1)),
                    ("value", Json::num(*value)),
                ])
            })
            .collect(),
    )
}

/// The `/coreset` response body, family-aware: the shared fields
/// (digest, cached, dims, family, size, total weight) plus the
/// family's own structure — block count and σ for the deterministic
/// family (the historical response shape, unchanged), the algorithm,
/// τ budget, and seed for the sensitivity family.
pub(crate) fn coreset_summary_json(
    entry: &super::cache::CachedCoreset,
    digest: u64,
    cached: bool,
) -> Json {
    let mut fields = vec![
        ("digest", digest_to_json(digest)),
        ("cached", Json::Bool(cached)),
        ("rows", Json::int(entry.rows)),
        ("cols", Json::int(entry.cols)),
        ("family", Json::str(entry.coreset.family())),
    ];
    match &entry.coreset {
        crate::engine::Compression::Caratheodory(cs) => {
            fields.push(("blocks", Json::int(cs.blocks.len())));
            fields.push(("stored_points", Json::int(cs.stored_points())));
            fields.push(("sigma", Json::num(cs.sigma)));
            fields.push(("total_weight", Json::num(cs.total_weight())));
        }
        crate::engine::Compression::Sensitivity(cs) => {
            fields.push(("algorithm", Json::str(cs.algorithm.name())));
            fields.push(("tau", Json::int(cs.tau)));
            fields.push(("stored_points", Json::int(cs.points.len())));
            fields.push(("seed", Json::str(format!("{:#x}", cs.seed))));
            fields.push(("total_weight", Json::num(cs.total_weight())));
        }
    }
    Json::obj(fields)
}

/// Digests travel as `0x`-prefixed hex strings — JSON numbers are f64
/// and cannot carry 64 bits exactly.
pub(crate) fn digest_to_json(digest: u64) -> Json {
    Json::str(format!("{digest:#x}"))
}

pub(crate) fn parse_digest(s: &str) -> Option<u64> {
    crate::cli::parse_u64(s)
}

fn field_usize(doc: &Json, key: &str) -> Result<usize, String> {
    doc.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| format!("\"{key}\" must be a non-negative integer"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::signal::{content_digest, SignalSource};

    fn signal_doc(rows: usize, cols: usize) -> Json {
        let values: Vec<Json> = (0..rows * cols).map(|i| Json::num(i as f64 * 0.5)).collect();
        Json::obj(vec![
            ("rows", Json::int(rows)),
            ("cols", Json::int(cols)),
            ("values", Json::Arr(values)),
        ])
    }

    #[test]
    fn signal_round_trips_exact_bits_through_render_and_parse() {
        // Awkward values: shortest-roundtrip rendering + the strict
        // parser must reproduce identical bits.
        let values = [0.1, -0.3, 1.0 / 3.0, 1e-300, 123456789.123456, f64::MIN_POSITIVE];
        let doc = Json::obj(vec![
            ("rows", Json::int(2)),
            ("cols", Json::int(3)),
            ("values", Json::Arr(values.iter().map(|&v| Json::num(v)).collect())),
        ]);
        let reparsed = Json::parse(&doc.render()).unwrap();
        let signal = signal_from_json(&reparsed).unwrap();
        for (i, &v) in values.iter().enumerate() {
            assert_eq!(signal.row_values(i / 3)[i % 3].to_bits(), v.to_bits());
        }
        let direct = signal_from_json(&doc).unwrap();
        assert_eq!(content_digest(&signal), content_digest(&direct));
    }

    #[test]
    fn signal_mask_is_decoded_and_validated() {
        let mut doc = signal_doc(2, 2);
        let Json::Obj(pairs) = &mut doc else { unreachable!() };
        pairs.push((
            "mask".to_string(),
            Json::Arr(vec![
                Json::Bool(true),
                Json::Bool(false),
                Json::Bool(true),
                Json::Bool(true),
            ]),
        ));
        let signal = signal_from_json(&doc).unwrap();
        assert_eq!(signal.present(), 3);

        let Json::Obj(pairs) = &mut doc else { unreachable!() };
        pairs.retain(|(k, _)| k != "mask");
        pairs.push(("mask".to_string(), Json::Arr(vec![Json::Bool(true)])));
        assert!(signal_from_json(&doc).unwrap_err().contains("mask"));

        // A mask with zero present cells would hand the engine an empty
        // signal — rejected at the wire, not discovered mid-build.
        let Json::Obj(pairs) = &mut doc else { unreachable!() };
        pairs.retain(|(k, _)| k != "mask");
        pairs.push(("mask".to_string(), Json::Arr(vec![Json::Bool(false); 4])));
        assert!(signal_from_json(&doc).unwrap_err().contains("no present cells"));
    }

    #[test]
    fn signal_rejections_name_the_offending_field() {
        let err = signal_from_json(&Json::obj(vec![("rows", Json::int(2))])).unwrap_err();
        assert!(err.contains("cols"), "{err}");

        let mut doc = signal_doc(2, 2);
        let Json::Obj(pairs) = &mut doc else { unreachable!() };
        pairs.retain(|(k, _)| k != "values");
        pairs.push(("values".to_string(), Json::Arr(vec![Json::num(1.0)])));
        let err = signal_from_json(&doc).unwrap_err();
        assert!(err.contains("expected rows*cols"), "{err}");

        let zero = Json::obj(vec![
            ("rows", Json::int(0)),
            ("cols", Json::int(5)),
            ("values", Json::Arr(vec![])),
        ]);
        assert!(signal_from_json(&zero).unwrap_err().contains("positive"));

        let huge = Json::obj(vec![
            ("rows", Json::int(1 << 20)),
            ("cols", Json::int(1 << 20)),
            ("values", Json::Arr(vec![])),
        ]);
        assert!(signal_from_json(&huge).unwrap_err().contains("cells"));
    }

    fn piece(r0: usize, r1: usize, c0: usize, c1: usize, value: f64) -> Json {
        Json::obj(vec![
            ("r0", Json::int(r0)),
            ("r1", Json::int(r1)),
            ("c0", Json::int(c0)),
            ("c1", Json::int(c1)),
            ("value", Json::num(value)),
        ])
    }

    #[test]
    fn segmentation_round_trips_and_validates() {
        let doc = Json::obj(vec![(
            "pieces",
            Json::Arr(vec![piece(0, 3, 0, 1, 2.5), piece(0, 3, 2, 7, -1.0)]),
        )]);
        let seg = segmentation_from_json(&doc, 8, 8).unwrap();
        assert_eq!(seg.k(), 2);
        let replay = Json::obj(vec![("pieces", segmentation_to_json(&seg))]);
        let again = segmentation_from_json(&replay, 8, 8).unwrap();
        assert_eq!(again.pieces(), seg.pieces());
    }

    #[test]
    fn segmentation_rejects_overlap_out_of_bounds_and_degenerate() {
        let overlap = Json::obj(vec![(
            "pieces",
            Json::Arr(vec![piece(0, 3, 0, 3, 1.0), piece(2, 5, 2, 5, 2.0)]),
        )]);
        let err = segmentation_from_json(&overlap, 8, 8).unwrap_err();
        assert!(err.contains("overlap"), "{err}");

        let oob = Json::obj(vec![("pieces", Json::Arr(vec![piece(0, 8, 0, 3, 1.0)]))]);
        let err = segmentation_from_json(&oob, 8, 8).unwrap_err();
        assert!(err.contains("exceeds"), "{err}");

        let degenerate = Json::obj(vec![("pieces", Json::Arr(vec![piece(3, 1, 0, 3, 1.0)]))]);
        let err = segmentation_from_json(&degenerate, 8, 8).unwrap_err();
        assert!(err.contains("degenerate"), "{err}");

        let empty = Json::obj(vec![("pieces", Json::Arr(vec![]))]);
        assert!(segmentation_from_json(&empty, 8, 8).is_err());

        let infinite = Json::obj(vec![(
            "pieces",
            Json::Arr(vec![Json::obj(vec![
                ("r0", Json::int(0)),
                ("r1", Json::int(1)),
                ("c0", Json::int(0)),
                ("c1", Json::int(1)),
                ("value", Json::Str("inf".to_string())),
            ])]),
        )]);
        let err = segmentation_from_json(&infinite, 8, 8).unwrap_err();
        assert!(err.contains("finite"), "{err}");
    }

    #[test]
    fn digest_hex_round_trips() {
        for d in [0u64, 1, 0xdead_beef, u64::MAX] {
            let rendered = digest_to_json(d);
            let parsed = parse_digest(rendered.as_str().unwrap()).unwrap();
            assert_eq!(parsed, d);
        }
        assert!(parse_digest("not hex").is_none());
    }
}
