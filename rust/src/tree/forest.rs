//! Random forest regressor — the `sklearn.ensemble.RandomForestRegressor`
//! substitute: bagging over weighted CART trees with feature subsampling,
//! predictions averaged.
//!
//! Bootstrap on *weighted* samples resamples indices with probability
//! proportional to weight (weighted bootstrap), so a forest trained on a
//! coreset sees the same expected sample distribution as one trained on
//! the full data — the property the paper's experiments rely on.
//! Resampling is by index with per-index weight accumulation: each tree
//! fits via [`DecisionTree::fit_reweighted`], borrowing the caller's
//! samples instead of cloning one feature vector per draw.

use crate::rng::Rng;

use super::{DecisionTree, Sample, TreeParams};

/// Forest hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct ForestParams {
    pub n_trees: usize,
    pub tree: TreeParams,
    /// Fraction of total weight drawn per bootstrap (1.0 = classic).
    pub subsample: f64,
    /// Feature subsampling per split (None = all features; forests
    /// typically use sqrt(d) for classification, d/3 or all for
    /// regression — sklearn's regressor default is all).
    pub max_features: Option<usize>,
}

impl Default for ForestParams {
    fn default() -> Self {
        Self {
            n_trees: 20,
            tree: TreeParams::default(),
            subsample: 1.0,
            max_features: None,
        }
    }
}

impl ForestParams {
    pub fn with_trees(mut self, n: usize) -> Self {
        self.n_trees = n.max(1);
        self
    }

    pub fn with_max_leaves(mut self, k: usize) -> Self {
        self.tree = self.tree.with_max_leaves(k);
        self
    }
}

/// A trained random forest.
#[derive(Clone, Debug)]
pub struct RandomForest {
    trees: Vec<DecisionTree>,
}

impl RandomForest {
    /// Fit with weighted bootstrap aggregation.
    pub fn fit(samples: &[Sample], params: &ForestParams, rng: &mut Rng) -> Self {
        assert!(!samples.is_empty());
        let mut tree_params = params.tree;
        tree_params.max_features = params.max_features;
        // Cumulative weights for O(log n) weighted index sampling.
        let cum: Vec<f64> = {
            let mut acc = 0.0;
            samples
                .iter()
                .map(|s| {
                    acc += s.w.max(0.0);
                    acc
                })
                .collect()
        };
        let total_w = cum.last().copied().unwrap_or(0.0);
        assert!(total_w > 0.0, "total weight must be positive");
        let draws = ((samples.len() as f64) * params.subsample).ceil() as usize;
        let draws = draws.max(1);
        let trees = (0..params.n_trees)
            .map(|t| {
                let mut trng = Rng::new(rng.next_u64() ^ (t as u64).wrapping_mul(0x9E37));
                // Weighted bootstrap by *index*: draw indices ∝ weight and
                // accumulate per-index bootstrap weight (each draw adds
                // total_w/draws, so the bootstrap totals the original
                // weight). Fitting then borrows the original samples via
                // `fit_reweighted` — no per-draw feature-vector clones,
                // O(n) scratch per tree instead of O(draws · d).
                let mut boot_w = vec![0.0f64; samples.len()];
                let per_draw_w = total_w / draws as f64;
                for _ in 0..draws {
                    let u = trng.f64() * total_w;
                    let idx = match cum.binary_search_by(|c| c.total_cmp(&u)) {
                        Ok(i) => i,
                        Err(i) => i.min(samples.len() - 1),
                    };
                    boot_w[idx] += per_draw_w;
                }
                DecisionTree::fit_reweighted(samples, &boot_w, &tree_params, Some(&mut trng))
            })
            .collect();
        Self { trees }
    }

    /// Average prediction over the ensemble.
    pub fn predict(&self, x: &[f64]) -> f64 {
        let sum: f64 = self.trees.iter().map(|t| t.predict(x)).sum();
        sum / self.trees.len() as f64
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }

    /// Weighted SSE on a sample set.
    pub fn sse(&self, samples: &[Sample]) -> f64 {
        samples
            .iter()
            .map(|s| {
                let d = self.predict(&s.x) - s.y;
                s.w * d * d
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_samples(n: usize, m: usize, f: impl Fn(usize, usize) -> f64) -> Vec<Sample> {
        let mut out = Vec::new();
        for r in 0..n {
            for c in 0..m {
                out.push(Sample::new(vec![r as f64, c as f64], f(r, c), 1.0));
            }
        }
        out
    }

    #[test]
    fn learns_step_function() {
        let samples = grid_samples(10, 10, |r, _| if r < 5 { 0.0 } else { 4.0 });
        let mut rng = Rng::new(1);
        let forest = RandomForest::fit(
            &samples,
            &ForestParams::default().with_trees(10).with_max_leaves(4),
            &mut rng,
        );
        assert_eq!(forest.n_trees(), 10);
        assert!((forest.predict(&[1.0, 5.0]) - 0.0).abs() < 0.5);
        assert!((forest.predict(&[8.0, 5.0]) - 4.0).abs() < 0.5);
    }

    #[test]
    fn ensemble_beats_or_matches_single_tree_oob() {
        // On noisy data the forest generalizes at least as well as a
        // single deep tree (classic variance reduction).
        let mut rng = Rng::new(7);
        let truth = |r: usize, c: usize| ((r as f64) / 4.0).sin() + ((c as f64) / 5.0).cos();
        let train: Vec<Sample> = grid_samples(20, 20, |r, c| truth(r, c))
            .into_iter()
            .map(|mut s| {
                s.y += 0.5 * rng.normal();
                s
            })
            .collect();
        let test = grid_samples(20, 20, truth);
        let tree = DecisionTree::fit(
            &train,
            &TreeParams::default().with_max_leaves(200),
            None,
        );
        let forest = RandomForest::fit(
            &train,
            &ForestParams {
                n_trees: 30,
                tree: TreeParams::default().with_max_leaves(200),
                subsample: 1.0,
                max_features: None,
            },
            &mut rng,
        );
        let tree_err = tree.sse(&test);
        let forest_err = forest.sse(&test);
        assert!(
            forest_err < tree_err * 1.05,
            "forest {forest_err} vs tree {tree_err}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let samples = grid_samples(8, 8, |r, c| (r + c) as f64);
        let p = ForestParams::default().with_trees(5).with_max_leaves(8);
        let f1 = RandomForest::fit(&samples, &p, &mut Rng::new(9));
        let f2 = RandomForest::fit(&samples, &p, &mut Rng::new(9));
        for r in 0..8 {
            for c in 0..8 {
                let x = [r as f64, c as f64];
                assert_eq!(f1.predict(&x), f2.predict(&x));
            }
        }
    }

    #[test]
    fn weighted_coreset_like_training() {
        // A few heavily-weighted points approximate a dense region.
        let mut samples = vec![
            Sample::new(vec![0.0, 0.0], 1.0, 50.0),
            Sample::new(vec![0.0, 9.0], 1.0, 50.0),
            Sample::new(vec![9.0, 0.0], 5.0, 50.0),
            Sample::new(vec![9.0, 9.0], 5.0, 50.0),
        ];
        samples.push(Sample::new(vec![4.5, 4.5], 3.0, 1.0));
        let mut rng = Rng::new(11);
        let forest = RandomForest::fit(
            &samples,
            &ForestParams::default().with_trees(20).with_max_leaves(4),
            &mut rng,
        );
        let lo = forest.predict(&[0.0, 4.0]);
        let hi = forest.predict(&[9.0, 4.0]);
        assert!(lo < hi, "lo {lo} hi {hi}");
    }
}
