//! Weighted CART regression trees — the `sklearn.tree.DecisionTreeRegressor`
//! substitute (DESIGN.md §Substitutions).
//!
//! The trainer consumes **weighted** samples, which is what makes it able
//! to train directly on a coreset: each coreset point carries the weight
//! of the cells it represents, and variance-reduction splitting on
//! weighted samples optimizes exactly the weighted SSE the coreset
//! preserves.
//!
//! Features are generic d-dimensional `f64` vectors; for signal problems
//! d = 2 (the grid coordinates). Splits are axis-parallel thresholds
//! chosen to maximize weighted SSE reduction, leaves predict the weighted
//! mean — precisely CART with the MSE criterion.

pub mod forest;
pub mod gbdt;

use crate::coreset::WeightedPoint;

/// A training sample: feature vector, target, weight.
#[derive(Clone, Debug)]
pub struct Sample {
    pub x: Vec<f64>,
    pub y: f64,
    pub w: f64,
}

impl Sample {
    pub fn new(x: Vec<f64>, y: f64, w: f64) -> Self {
        Self { x, y, w }
    }

    /// From a coreset point: features = (row, col).
    pub fn from_point(p: &WeightedPoint) -> Self {
        Self { x: vec![p.row as f64, p.col as f64], y: p.y, w: p.w }
    }
}

/// Training hyperparameters (mirroring sklearn's names where sensible).
#[derive(Clone, Copy, Debug)]
pub struct TreeParams {
    /// Maximum number of leaves (the paper's k).
    pub max_leaves: usize,
    /// Maximum depth (usize::MAX = unbounded).
    pub max_depth: usize,
    /// Minimum total weight to consider splitting a node.
    pub min_weight_split: f64,
    /// Minimum weighted SSE improvement to accept a split.
    pub min_impurity_decrease: f64,
    /// Number of features examined per split; `None` = all (set by the
    /// forest for feature subsampling).
    pub max_features: Option<usize>,
}

impl Default for TreeParams {
    fn default() -> Self {
        Self {
            max_leaves: usize::MAX,
            max_depth: usize::MAX,
            min_weight_split: 2.0,
            min_impurity_decrease: 1e-12,
            max_features: None,
        }
    }
}

impl TreeParams {
    pub fn with_max_leaves(mut self, k: usize) -> Self {
        self.max_leaves = k.max(1);
        self
    }

    pub fn with_max_depth(mut self, d: usize) -> Self {
        self.max_depth = d;
        self
    }
}

#[derive(Clone, Debug)]
enum Node {
    Leaf {
        value: f64,
    },
    Split {
        feature: usize,
        threshold: f64,
        left: usize,
        right: usize,
    },
}

/// A trained regression tree.
#[derive(Clone, Debug)]
pub struct DecisionTree {
    nodes: Vec<Node>,
    n_features: usize,
    leaves: usize,
}

/// Candidate split found for one node.
struct BestSplit {
    feature: usize,
    threshold: f64,
    gain: f64,
}

/// A node awaiting processing in best-first growth.
struct Work {
    node_idx: usize,
    indices: Vec<usize>,
    depth: usize,
    sse: f64,
}

impl DecisionTree {
    /// Fit a tree on weighted samples (best-first leaf growth, so
    /// `max_leaves` cuts the *globally* least useful splits first,
    /// matching sklearn's `max_leaf_nodes` behaviour).
    pub fn fit(samples: &[Sample], params: &TreeParams, rng: Option<&mut crate::rng::Rng>) -> Self {
        assert!(!samples.is_empty(), "cannot fit on empty data");
        let weights: Vec<f64> = samples.iter().map(|s| s.w).collect();
        let all: Vec<usize> = (0..samples.len()).collect();
        Self::fit_core(samples, &weights, all, params, rng)
    }

    /// Fit on a *borrowed* sample set with per-sample override weights —
    /// the bootstrap path: resampling assigns new weights to existing
    /// samples (zero-weight = not drawn), so no feature vector is ever
    /// cloned. `weights` must have `samples.len()` entries with at least
    /// one positive; samples' own `w` fields are ignored.
    pub fn fit_reweighted(
        samples: &[Sample],
        weights: &[f64],
        params: &TreeParams,
        rng: Option<&mut crate::rng::Rng>,
    ) -> Self {
        assert_eq!(samples.len(), weights.len(), "one weight per sample");
        let active: Vec<usize> = (0..samples.len()).filter(|&i| weights[i] > 0.0).collect();
        assert!(!active.is_empty(), "cannot fit on zero total weight");
        Self::fit_core(samples, weights, active, params, rng)
    }

    fn fit_core(
        samples: &[Sample],
        weights: &[f64],
        all: Vec<usize>,
        params: &TreeParams,
        rng: Option<&mut crate::rng::Rng>,
    ) -> Self {
        let n_features = samples[0].x.len();
        debug_assert!(samples.iter().all(|s| s.x.len() == n_features));
        let mut tree = Self { nodes: Vec::new(), n_features, leaves: 0 };
        let (value, sse) = weighted_stats(samples, weights, &all);
        tree.nodes.push(Node::Leaf { value });
        tree.leaves = 1;
        // Best-first frontier ordered by achievable gain.
        let mut rng_local = crate::rng::Rng::new(0x5eed);
        let rng = match rng {
            Some(r) => r,
            None => &mut rng_local,
        };
        let mut frontier: Vec<(Work, Option<BestSplit>)> = Vec::new();
        let work = Work { node_idx: 0, indices: all, depth: 0, sse };
        let split = find_best_split(samples, weights, &work, params, rng);
        frontier.push((work, split));
        while tree.leaves < params.max_leaves {
            // Pop the frontier entry with the largest gain.
            let best_idx = frontier
                .iter()
                .enumerate()
                .filter_map(|(i, (_, s))| s.as_ref().map(|s| (i, s.gain)))
                .max_by(|a, b| a.1.total_cmp(&b.1))
                .map(|(i, _)| i);
            let Some(best_idx) = best_idx else { break };
            let (work, split) = frontier.swap_remove(best_idx);
            let Some(split) = split else { break };
            if split.gain < params.min_impurity_decrease {
                break;
            }
            // Partition the indices.
            let (left_idx, right_idx): (Vec<usize>, Vec<usize>) = work
                .indices
                .iter()
                .partition(|&&i| samples[i].x[split.feature] <= split.threshold);
            if left_idx.is_empty() || right_idx.is_empty() {
                continue; // numerically degenerate; skip this split
            }
            let (lv, lsse) = weighted_stats(samples, weights, &left_idx);
            let (rv, rsse) = weighted_stats(samples, weights, &right_idx);
            let li = tree.nodes.len();
            tree.nodes.push(Node::Leaf { value: lv });
            let ri = tree.nodes.len();
            tree.nodes.push(Node::Leaf { value: rv });
            tree.nodes[work.node_idx] = Node::Split {
                feature: split.feature,
                threshold: split.threshold,
                left: li,
                right: ri,
            };
            tree.leaves += 1; // replaced 1 leaf by 2
            let depth = work.depth + 1;
            for (idx, indices, sse) in [(li, left_idx, lsse), (ri, right_idx, rsse)] {
                let w = Work { node_idx: idx, indices, depth, sse };
                let s = if depth < params.max_depth {
                    find_best_split(samples, weights, &w, params, rng)
                } else {
                    None
                };
                frontier.push((w, s));
            }
        }
        tree
    }

    /// Predict a single feature vector.
    pub fn predict(&self, x: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), self.n_features);
        let mut idx = 0usize;
        loop {
            match &self.nodes[idx] {
                Node::Leaf { value } => return *value,
                Node::Split { feature, threshold, left, right } => {
                    idx = if x[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    pub fn n_leaves(&self) -> usize {
        self.leaves
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Weighted SSE on a sample set.
    pub fn sse(&self, samples: &[Sample]) -> f64 {
        samples
            .iter()
            .map(|s| {
                let d = self.predict(&s.x) - s.y;
                s.w * d * d
            })
            .sum()
    }
}

/// Weighted mean and SSE-about-mean of a subset (`weights` overrides the
/// samples' own `w` — the indirection that lets bootstrap reweighting
/// borrow samples instead of duplicating them).
fn weighted_stats(samples: &[Sample], weights: &[f64], idx: &[usize]) -> (f64, f64) {
    let mut w = 0.0;
    let mut wy = 0.0;
    let mut wyy = 0.0;
    for &i in idx {
        let (sw, sy) = (weights[i], samples[i].y);
        w += sw;
        wy += sw * sy;
        wyy += sw * sy * sy;
    }
    if w <= 0.0 {
        return (0.0, 0.0);
    }
    let mean = wy / w;
    ((mean), (wyy - wy * wy / w).max(0.0))
}

/// Exact best split on one node: for each candidate feature, sort the
/// node's samples and scan thresholds between consecutive distinct
/// values, tracking weighted prefix moments. O(d · n log n).
fn find_best_split(
    samples: &[Sample],
    weights: &[f64],
    work: &Work,
    params: &TreeParams,
    rng: &mut crate::rng::Rng,
) -> Option<BestSplit> {
    let idx = &work.indices;
    if idx.len() < 2 {
        return None;
    }
    let total_w: f64 = idx.iter().map(|&i| weights[i]).sum();
    if total_w < params.min_weight_split {
        return None;
    }
    if work.sse <= 0.0 {
        return None; // already pure
    }
    let d = samples[0].x.len();
    // Feature subsampling (forests).
    let features: Vec<usize> = match params.max_features {
        Some(k) if k < d => rng.sample_indices(d, k),
        _ => (0..d).collect(),
    };
    let mut best: Option<BestSplit> = None;
    let mut order: Vec<usize> = Vec::with_capacity(idx.len());
    for &f in &features {
        order.clear();
        order.extend_from_slice(idx);
        order.sort_by(|&a, &b| {
            samples[a].x[f]
                .partial_cmp(&samples[b].x[f])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut lw = 0.0;
        let mut lwy = 0.0;
        let mut lwyy = 0.0;
        let (mut tw, mut twy, mut twyy) = (0.0, 0.0, 0.0);
        for &i in order.iter() {
            let (sw, sy) = (weights[i], samples[i].y);
            tw += sw;
            twy += sw * sy;
            twyy += sw * sy * sy;
        }
        let parent_sse = (twyy - twy * twy / tw).max(0.0);
        for win in 0..order.len() - 1 {
            let s = &samples[order[win]];
            let sw = weights[order[win]];
            lw += sw;
            lwy += sw * s.y;
            lwyy += sw * s.y * s.y;
            let xv = s.x[f];
            let xn = samples[order[win + 1]].x[f];
            if xn <= xv {
                continue; // same value — not a valid threshold
            }
            let rw = tw - lw;
            if lw <= 0.0 || rw <= 0.0 {
                continue;
            }
            let lsse = (lwyy - lwy * lwy / lw).max(0.0);
            let rwy = twy - lwy;
            let rwyy = twyy - lwyy;
            let rsse = (rwyy - rwy * rwy / rw).max(0.0);
            let gain = parent_sse - lsse - rsse;
            if best.as_ref().map_or(true, |b| gain > b.gain) {
                best = Some(BestSplit { feature: f, threshold: 0.5 * (xv + xn), gain });
            }
        }
    }
    best.filter(|b| b.gain > 0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn grid_samples(n: usize, m: usize, f: impl Fn(usize, usize) -> f64) -> Vec<Sample> {
        let mut out = Vec::new();
        for r in 0..n {
            for c in 0..m {
                out.push(Sample::new(vec![r as f64, c as f64], f(r, c), 1.0));
            }
        }
        out
    }

    #[test]
    fn fits_axis_aligned_step_exactly() {
        let samples = grid_samples(8, 8, |r, _| if r < 4 { 1.0 } else { 5.0 });
        let tree = DecisionTree::fit(&samples, &TreeParams::default().with_max_leaves(2), None);
        assert_eq!(tree.n_leaves(), 2);
        assert!(tree.sse(&samples) < 1e-18);
        assert!((tree.predict(&[0.0, 3.0]) - 1.0).abs() < 1e-12);
        assert!((tree.predict(&[7.0, 3.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn fits_quadrants_with_four_leaves() {
        let samples = grid_samples(8, 8, |r, c| match (r < 4, c < 4) {
            (true, true) => 1.0,
            (true, false) => 2.0,
            (false, true) => 3.0,
            (false, false) => 4.0,
        });
        let tree = DecisionTree::fit(&samples, &TreeParams::default().with_max_leaves(4), None);
        assert_eq!(tree.n_leaves(), 4);
        assert!(tree.sse(&samples) < 1e-18);
    }

    #[test]
    fn respects_max_leaves() {
        let mut rng = Rng::new(2);
        let samples: Vec<Sample> = (0..200)
            .map(|i| Sample::new(vec![i as f64, rng.f64()], rng.normal(), 1.0))
            .collect();
        for k in [1, 3, 10, 50] {
            let tree =
                DecisionTree::fit(&samples, &TreeParams::default().with_max_leaves(k), None);
            assert!(tree.n_leaves() <= k, "k={k} got {}", tree.n_leaves());
        }
    }

    #[test]
    fn more_leaves_monotone_loss() {
        let mut rng = Rng::new(3);
        let samples: Vec<Sample> = (0..300)
            .map(|i| {
                Sample::new(
                    vec![(i % 20) as f64, (i / 20) as f64],
                    ((i % 20) as f64 / 3.0).sin() + 0.1 * rng.normal(),
                    1.0,
                )
            })
            .collect();
        let mut prev = f64::INFINITY;
        for k in [1, 2, 4, 8, 16] {
            let tree =
                DecisionTree::fit(&samples, &TreeParams::default().with_max_leaves(k), None);
            let sse = tree.sse(&samples);
            assert!(sse <= prev + 1e-9, "k={k}: {sse} > {prev}");
            prev = sse;
        }
    }

    #[test]
    fn weights_matter() {
        // Two clusters; the heavy one dominates the root prediction.
        let samples = vec![
            Sample::new(vec![0.0], 0.0, 100.0),
            Sample::new(vec![1.0], 10.0, 1.0),
        ];
        let tree = DecisionTree::fit(&samples, &TreeParams::default().with_max_leaves(1), None);
        let pred = tree.predict(&[0.5]);
        assert!((pred - (10.0 / 101.0)).abs() < 1e-9, "pred {pred}");
    }

    #[test]
    fn weighted_duplicate_equals_replication() {
        // Training on (x, w=3) must equal training on x repeated 3 times.
        let mut rng = Rng::new(4);
        let base: Vec<(f64, f64)> = (0..50).map(|_| (rng.f64() * 10.0, rng.normal())).collect();
        let weighted: Vec<Sample> = base
            .iter()
            .map(|&(x, y)| Sample::new(vec![x], y, 3.0))
            .collect();
        let replicated: Vec<Sample> = base
            .iter()
            .flat_map(|&(x, y)| (0..3).map(move |_| Sample::new(vec![x], y, 1.0)))
            .collect();
        let p = TreeParams::default().with_max_leaves(8);
        let tw = DecisionTree::fit(&weighted, &p, None);
        let tr = DecisionTree::fit(&replicated, &p, None);
        for i in 0..20 {
            let x = [i as f64 / 2.0];
            assert!(
                (tw.predict(&x) - tr.predict(&x)).abs() < 1e-9,
                "x={x:?}"
            );
        }
    }

    #[test]
    fn fit_reweighted_matches_materialized_fit() {
        // Overriding weights on borrowed samples (zero = not drawn) must
        // train the same tree as materializing the weighted subset.
        let base = grid_samples(10, 10, |r, c| ((r * 3 + c) % 5) as f64);
        let weights: Vec<f64> = (0..base.len()).map(|i| ((i * 7) % 4) as f64).collect();
        let materialized: Vec<Sample> = base
            .iter()
            .zip(&weights)
            .filter_map(|(s, &w)| (w > 0.0).then(|| Sample::new(s.x.clone(), s.y, w)))
            .collect();
        let p = TreeParams::default().with_max_leaves(8);
        let a = DecisionTree::fit_reweighted(&base, &weights, &p, None);
        let b = DecisionTree::fit(&materialized, &p, None);
        assert_eq!(a.n_leaves(), b.n_leaves());
        for r in 0..10 {
            for c in 0..10 {
                let x = [r as f64, c as f64];
                assert!(
                    (a.predict(&x) - b.predict(&x)).abs() < 1e-12,
                    "x={x:?}"
                );
            }
        }
    }

    #[test]
    fn max_depth_limits_structure() {
        let samples = grid_samples(16, 16, |r, c| (r * 16 + c) as f64);
        let tree = DecisionTree::fit(
            &samples,
            &TreeParams::default().with_max_depth(2).with_max_leaves(1000),
            None,
        );
        // Depth-2 binary tree has at most 4 leaves.
        assert!(tree.n_leaves() <= 4);
    }

    #[test]
    fn pure_node_not_split() {
        let samples = grid_samples(6, 6, |_, _| 1.23);
        let tree = DecisionTree::fit(&samples, &TreeParams::default().with_max_leaves(10), None);
        assert_eq!(tree.n_leaves(), 1);
    }
}
