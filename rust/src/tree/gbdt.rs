//! Gradient-boosted regression trees — the LightGBM `LGBMRegressor`
//! substitute (DESIGN.md §Substitutions).
//!
//! Squared loss boosting: each stage fits a shallow weighted CART tree to
//! the current residuals and is added with a learning rate. Leaf-wise
//! (best-first) growth — the trait that distinguishes LightGBM from
//! depth-wise XGBoost — comes for free from our tree's best-first
//! frontier.

use crate::rng::Rng;

use super::{DecisionTree, Sample, TreeParams};

#[derive(Clone, Copy, Debug)]
pub struct GbdtParams {
    pub n_stages: usize,
    pub learning_rate: f64,
    /// Leaves per stage tree (LightGBM's `num_leaves`, default 31).
    pub num_leaves: usize,
    pub max_depth: usize,
    /// Row subsampling per stage (stochastic gradient boosting).
    pub subsample: f64,
}

impl Default for GbdtParams {
    fn default() -> Self {
        Self {
            n_stages: 100,
            learning_rate: 0.1,
            num_leaves: 31,
            max_depth: usize::MAX,
            subsample: 1.0,
        }
    }
}

impl GbdtParams {
    pub fn with_stages(mut self, n: usize) -> Self {
        self.n_stages = n.max(1);
        self
    }

    pub fn with_leaves(mut self, k: usize) -> Self {
        self.num_leaves = k.max(2);
        self
    }
}

/// A trained gradient-boosted ensemble.
#[derive(Clone, Debug)]
pub struct Gbdt {
    base: f64,
    learning_rate: f64,
    stages: Vec<DecisionTree>,
}

impl Gbdt {
    pub fn fit(samples: &[Sample], params: &GbdtParams, rng: &mut Rng) -> Self {
        assert!(!samples.is_empty());
        let total_w: f64 = samples.iter().map(|s| s.w).sum();
        let base = samples.iter().map(|s| s.w * s.y).sum::<f64>() / total_w;
        let tree_params = TreeParams::default()
            .with_max_leaves(params.num_leaves)
            .with_max_depth(params.max_depth);
        let mut residuals: Vec<f64> = samples.iter().map(|s| s.y - base).collect();
        let mut stages = Vec::with_capacity(params.n_stages);
        let mut work: Vec<Sample> = samples.to_vec();
        for _ in 0..params.n_stages {
            // Residual targets (optionally row-subsampled).
            for (w, (s, r)) in work.iter_mut().zip(samples.iter().zip(residuals.iter())) {
                w.y = *r;
                w.w = s.w;
            }
            let fit_set: Vec<Sample> = if params.subsample < 1.0 {
                work.iter()
                    .filter(|_| rng.f64() < params.subsample)
                    .cloned()
                    .collect()
            } else {
                work.clone()
            };
            if fit_set.is_empty() {
                break;
            }
            let tree = DecisionTree::fit(&fit_set, &tree_params, Some(rng));
            // Update residuals.
            for (r, s) in residuals.iter_mut().zip(samples.iter()) {
                *r -= params.learning_rate * tree.predict(&s.x);
            }
            stages.push(tree);
        }
        Self { base, learning_rate: params.learning_rate, stages }
    }

    pub fn predict(&self, x: &[f64]) -> f64 {
        self.base
            + self.learning_rate
                * self.stages.iter().map(|t| t.predict(x)).sum::<f64>()
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Weighted SSE on a sample set.
    pub fn sse(&self, samples: &[Sample]) -> f64 {
        samples
            .iter()
            .map(|s| {
                let d = self.predict(&s.x) - s.y;
                s.w * d * d
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wave_samples(n: usize) -> Vec<Sample> {
        (0..n)
            .map(|i| {
                let x = i as f64 / n as f64 * 10.0;
                Sample::new(vec![x], x.sin() * 3.0, 1.0)
            })
            .collect()
    }

    #[test]
    fn training_loss_decreases_with_stages() {
        let samples = wave_samples(200);
        let mut prev = f64::INFINITY;
        for stages in [1, 5, 20, 80] {
            let mut rng = Rng::new(1);
            let g = Gbdt::fit(
                &samples,
                &GbdtParams::default().with_stages(stages).with_leaves(8),
                &mut rng,
            );
            let sse = g.sse(&samples);
            assert!(sse <= prev * 1.001, "stages {stages}: {sse} > {prev}");
            prev = sse;
        }
        assert!(prev < 1.0, "final training SSE {prev}");
    }

    #[test]
    fn base_only_predicts_mean() {
        let samples = vec![
            Sample::new(vec![0.0], 2.0, 1.0),
            Sample::new(vec![1.0], 4.0, 3.0),
        ];
        let mut rng = Rng::new(2);
        let g = Gbdt::fit(
            &samples,
            &GbdtParams { n_stages: 1, learning_rate: 0.0, ..Default::default() },
            &mut rng,
        );
        // lr = 0 → prediction is the weighted base mean everywhere.
        let expect = (2.0 + 12.0) / 4.0;
        assert!((g.predict(&[0.5]) - expect).abs() < 1e-12);
    }

    #[test]
    fn learns_2d_structure() {
        let mut samples = Vec::new();
        for r in 0..15 {
            for c in 0..15 {
                let y = if r < 8 && c < 8 { 1.0 } else { -1.0 };
                samples.push(Sample::new(vec![r as f64, c as f64], y, 1.0));
            }
        }
        let mut rng = Rng::new(3);
        let g = Gbdt::fit(
            &samples,
            &GbdtParams::default().with_stages(30).with_leaves(4),
            &mut rng,
        );
        assert!(g.predict(&[2.0, 2.0]) > 0.5);
        assert!(g.predict(&[12.0, 12.0]) < -0.5);
    }

    #[test]
    fn deterministic_given_seed() {
        let samples = wave_samples(50);
        let p = GbdtParams::default().with_stages(10);
        let a = Gbdt::fit(&samples, &p, &mut Rng::new(5));
        let b = Gbdt::fit(&samples, &p, &mut Rng::new(5));
        for i in 0..10 {
            let x = [i as f64];
            assert_eq!(a.predict(&x), b.predict(&x));
        }
    }
}
