//! Exact 1D k-segmentation by dynamic programming.
//!
//! For a length-n vector signal (a single row), the optimal partition into
//! k contiguous segments under SSE is computed in O(k n²) time / O(kn)
//! memory — the classical segmented-least-squares DP. This is the exact
//! baseline the paper's 1D predecessors ([54, 24, 62]) solve, and our
//! tests use it as ground truth for `opt_k` on rows/columns.

/// Prefix sums over a 1D sequence for O(1) segment SSE queries.
#[derive(Clone, Debug)]
pub struct Prefix1D {
    sum: Vec<f64>,
    sum_sq: Vec<f64>,
}

impl Prefix1D {
    pub fn new(ys: &[f64]) -> Self {
        let mut sum = Vec::with_capacity(ys.len() + 1);
        let mut sum_sq = Vec::with_capacity(ys.len() + 1);
        // Running left-fold accumulators: same float order as the
        // former `last() + y` form, bit for bit.
        let (mut s, mut sq) = (0.0f64, 0.0f64);
        sum.push(s);
        sum_sq.push(sq);
        for &y in ys {
            s += y;
            sq += y * y;
            sum.push(s);
            sum_sq.push(sq);
        }
        Self { sum, sum_sq }
    }

    /// SSE of segment `[i, j)` fitted by its mean, O(1).
    #[inline]
    pub fn seg_cost(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < j);
        let cnt = (j - i) as f64;
        let s = self.sum[j] - self.sum[i];
        let sq = self.sum_sq[j] - self.sum_sq[i];
        (sq - s * s / cnt).max(0.0)
    }

    /// Mean of segment `[i, j)`.
    #[inline]
    pub fn seg_mean(&self, i: usize, j: usize) -> f64 {
        (self.sum[j] - self.sum[i]) / (j - i) as f64
    }
}

/// Result of the exact DP: total loss and the segment boundaries.
#[derive(Clone, Debug)]
pub struct Segmentation1D {
    /// Segment boundaries: k+1 indices, `0 = b[0] < b[1] < ... < b[k] = n`;
    /// segment i covers `[b[i], b[i+1])`.
    pub boundaries: Vec<usize>,
    /// Fitted mean per segment.
    pub values: Vec<f64>,
    pub loss: f64,
}

/// Exact optimal k-segmentation of `ys` under SSE. O(k n²).
///
/// `k` is clamped to `n` (opt_n = 0 trivially).
pub fn optimal_1d(ys: &[f64], k: usize) -> Segmentation1D {
    let n = ys.len();
    assert!(n > 0 && k > 0);
    let k = k.min(n);
    let pre = Prefix1D::new(ys);

    // dp[j][i] = optimal loss of first i points using j segments.
    // back[j][i] = start index of the last segment.
    let mut dp = vec![vec![f64::INFINITY; n + 1]; k + 1];
    let mut back = vec![vec![0usize; n + 1]; k + 1];
    dp[0][0] = 0.0;
    for j in 1..=k {
        for i in j..=n {
            // last segment [t, i), t >= j-1
            let mut best = f64::INFINITY;
            let mut best_t = j - 1;
            for t in (j - 1)..i {
                let cand = dp[j - 1][t] + pre.seg_cost(t, i);
                if cand < best {
                    best = cand;
                    best_t = t;
                }
            }
            dp[j][i] = best;
            back[j][i] = best_t;
        }
    }

    // Reconstruct boundaries.
    let mut boundaries = vec![n];
    let mut i = n;
    for j in (1..=k).rev() {
        let t = back[j][i];
        boundaries.push(t);
        i = t;
    }
    boundaries.reverse();
    let values = boundaries
        .windows(2)
        .map(|w| pre.seg_mean(w[0], w[1]))
        .collect();
    Segmentation1D { boundaries, values, loss: dp[k][n] }
}

/// `opt_k` for a 1D signal without reconstruction (same DP, less memory).
pub fn opt_k_1d(ys: &[f64], k: usize) -> f64 {
    optimal_1d(ys, k).loss
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn k1_is_variance() {
        let ys = [1.0, 2.0, 3.0, 4.0];
        let seg = optimal_1d(&ys, 1);
        // mean 2.5, SSE = 2*(1.5^2 + 0.5^2) = 5
        assert!((seg.loss - 5.0).abs() < 1e-12);
        assert_eq!(seg.boundaries, vec![0, 4]);
    }

    #[test]
    fn kn_is_zero() {
        let ys = [3.0, 1.0, 4.0, 1.0, 5.0];
        let seg = optimal_1d(&ys, 5);
        assert!(seg.loss < 1e-15);
    }

    #[test]
    fn recovers_planted_step() {
        // Two clean levels → k=2 must cut exactly at the step and get 0.
        let mut ys = vec![2.0; 10];
        ys.extend(vec![7.0; 15]);
        let seg = optimal_1d(&ys, 2);
        assert!(seg.loss < 1e-15);
        assert_eq!(seg.boundaries, vec![0, 10, 25]);
        assert!((seg.values[0] - 2.0).abs() < 1e-12);
        assert!((seg.values[1] - 7.0).abs() < 1e-12);
    }

    #[test]
    fn loss_monotone_in_k() {
        let mut rng = Rng::new(8);
        let ys: Vec<f64> = (0..60).map(|_| rng.normal()).collect();
        let mut prev = f64::INFINITY;
        for k in 1..=10 {
            let l = opt_k_1d(&ys, k);
            assert!(l <= prev + 1e-12, "k={k}");
            prev = l;
        }
    }

    #[test]
    fn dp_beats_every_random_partition() {
        let mut rng = Rng::new(13);
        let ys: Vec<f64> = (0..40).map(|i| ((i / 7) as f64) + 0.1 * rng.normal()).collect();
        let k = 4;
        let opt = opt_k_1d(&ys, k);
        let pre = Prefix1D::new(&ys);
        for _ in 0..200 {
            // Random k-partition boundaries.
            let mut cuts = rng.sample_indices(39, k - 1);
            cuts.iter_mut().for_each(|c| *c += 1);
            cuts.sort_unstable();
            let mut bounds = vec![0];
            bounds.extend(cuts);
            bounds.push(40);
            let loss: f64 = bounds.windows(2).map(|w| pre.seg_cost(w[0], w[1])).sum();
            assert!(opt <= loss + 1e-9);
        }
    }

    #[test]
    fn boundaries_are_strictly_increasing() {
        let mut rng = Rng::new(21);
        let ys: Vec<f64> = (0..30).map(|_| rng.f64()).collect();
        for k in [1, 3, 7, 30] {
            let seg = optimal_1d(&ys, k);
            for w in seg.boundaries.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert_eq!(seg.boundaries.len(), seg.values.len() + 1);
        }
    }
}
