//! Greedy top-down k-tree — the CART-style heuristic applied directly to
//! a signal (axis cuts chosen to minimize the sum of child opt₁ losses,
//! always splitting the worst leaf first). O(k·(n+m)) with O(1) opt₁
//! queries.
//!
//! Three roles in the repo:
//! * the concrete (α, β)_k-approximation inside the bicriteria stage,
//! * a fast baseline solver for the examples (image compression),
//! * ground truth for "greedy ≥ optimal DP" sanity tests.

use crate::signal::{PrefixStats, Rect};

use super::KSegmentation;

/// A leaf candidate with its best split precomputed.
struct Leaf {
    rect: Rect,
    loss: f64,
    /// (gain, is_row_cut, cut) — split after row/col `cut`.
    best: Option<(f64, bool, usize)>,
}

/// Find the best single guillotine cut of `rect`: minimizes
/// opt₁(left) + opt₁(right). Returns (gain, is_row_cut, cut_index).
fn best_cut(stats: &PrefixStats, rect: &Rect) -> Option<(f64, bool, usize)> {
    let parent = stats.opt1(rect);
    if parent <= 0.0 {
        return None;
    }
    // Candidate subsampling (perf pass, EXPERIMENTS.md §Perf): for large
    // rects evaluate every `stride`-th cut (≤128 candidates per axis),
    // then refine around the winner at stride 1. The SSE-vs-cut curve is
    // smooth for the signals this greedy targets, so the coarse-to-fine
    // search loses almost nothing while cutting the dominant cost of the
    // bicriteria stage ~8×.
    let mut best: Option<(f64, bool, usize)> = None;
    let mut scan = |is_row: bool, lo: usize, hi: usize, best: &mut Option<(f64, bool, usize)>| {
        if lo >= hi {
            return;
        }
        let len = hi - lo;
        let stride = (len / 128).max(1);
        let eval = |cut: usize| -> f64 {
            let (a, b) = if is_row {
                (
                    Rect::new(rect.r0, cut, rect.c0, rect.c1),
                    Rect::new(cut + 1, rect.r1, rect.c0, rect.c1),
                )
            } else {
                (
                    Rect::new(rect.r0, rect.r1, rect.c0, cut),
                    Rect::new(rect.r0, rect.r1, cut + 1, rect.c1),
                )
            };
            parent - stats.opt1(&a) - stats.opt1(&b)
        };
        let mut local: Option<(f64, usize)> = None;
        let mut cut = lo;
        while cut < hi {
            let gain = eval(cut);
            if local.map_or(true, |(g, _)| gain > g) {
                local = Some((gain, cut));
            }
            cut += stride;
        }
        if stride > 1 {
            // Refine ±stride around the coarse winner (always present:
            // the coarse scan above saw at least one cut).
            if let Some((_, center)) = local {
                let from = center.saturating_sub(stride).max(lo);
                let to = (center + stride).min(hi - 1);
                for cut in from..=to {
                    let gain = eval(cut);
                    if local.map_or(true, |(g, _)| gain > g) {
                        local = Some((gain, cut));
                    }
                }
            }
        }
        if let Some((gain, cut)) = local {
            if best.map_or(true, |(g, _, _)| gain > g) {
                *best = Some((gain, is_row, cut));
            }
        }
    };
    scan(true, rect.r0, rect.r1, &mut best);
    scan(false, rect.c0, rect.c1, &mut best);
    best.filter(|&(g, _, _)| g > 0.0)
}

/// Greedy k-leaf tree over the whole signal (values = block means).
pub fn greedy_tree(stats: &PrefixStats, k: usize) -> KSegmentation {
    let bounds = Rect::new(0, stats.rows() - 1, 0, stats.cols() - 1);
    greedy_tree_on(stats, bounds, k)
}

/// Greedy k-leaf tree restricted to `bounds`.
pub fn greedy_tree_on(stats: &PrefixStats, bounds: Rect, k: usize) -> KSegmentation {
    assert!(k >= 1);
    let mut leaves = vec![Leaf {
        rect: bounds,
        loss: stats.opt1(&bounds),
        best: best_cut(stats, &bounds),
    }];
    while leaves.len() < k {
        // Split the leaf with the largest achievable gain.
        let Some((idx, _)) = leaves
            .iter()
            .enumerate()
            .filter_map(|(i, l)| l.best.map(|(g, _, _)| (i, g)))
            .max_by(|a, b| a.1.total_cmp(&b.1))
        else {
            break; // nothing splittable (all leaves pure)
        };
        let leaf = leaves.swap_remove(idx);
        let Some((_, is_row, cut)) = leaf.best else { break };
        let (a, b) = if is_row {
            (
                Rect::new(leaf.rect.r0, cut, leaf.rect.c0, leaf.rect.c1),
                Rect::new(cut + 1, leaf.rect.r1, leaf.rect.c0, leaf.rect.c1),
            )
        } else {
            (
                Rect::new(leaf.rect.r0, leaf.rect.r1, leaf.rect.c0, cut),
                Rect::new(leaf.rect.r0, leaf.rect.r1, cut + 1, leaf.rect.c1),
            )
        };
        for rect in [a, b] {
            leaves.push(Leaf {
                rect,
                loss: stats.opt1(&rect),
                best: best_cut(stats, &rect),
            });
        }
    }
    let pieces = leaves
        .into_iter()
        .map(|l| (l.rect, stats.mean(&l.rect)))
        .collect();
    let _ = |l: &Leaf| l.loss; // loss kept for debugging/inspection
    KSegmentation::new(pieces)
}

/// Total loss of the greedy k-tree (convenience for bicriteria).
pub fn greedy_tree_loss(stats: &PrefixStats, k: usize) -> f64 {
    greedy_tree_loss_on(stats, stats.bounds(), k)
}

/// Total loss of the greedy k-tree restricted to `bounds` — the
/// region-scoped flavour the shared-stats bicriteria stage uses, so a
/// shard's greedy estimate never needs shard-local statistics.
pub fn greedy_tree_loss_on(stats: &PrefixStats, bounds: Rect, k: usize) -> f64 {
    greedy_tree_on(stats, bounds, k).loss(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::segmentation::dp2d::opt_k_tree;
    use crate::signal::{generate, PrefixStats, Signal};

    #[test]
    fn greedy_recovers_noiseless_pieces() {
        let mut rng = Rng::new(50);
        for trial in 0..5 {
            let (sig, pieces) = generate::piecewise_constant(24, 24, 5, 0.0, &mut rng);
            let stats = PrefixStats::new(&sig);
            // Guillotine-generated pieces are recoverable greedily with
            // some slack in k (greedy cuts may fragment).
            let seg = greedy_tree(&stats, 4 * pieces.len());
            assert!(
                seg.loss(&stats) < 1e-9,
                "trial {trial}: loss {}",
                seg.loss(&stats)
            );
        }
    }

    #[test]
    fn greedy_is_partition_and_monotone() {
        let mut rng = Rng::new(51);
        let sig = generate::smooth(30, 30, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let mut prev = f64::INFINITY;
        for k in [1, 2, 4, 8, 16, 32] {
            let seg = greedy_tree(&stats, k);
            assert!(seg.k() <= k);
            assert!(seg.is_partition_of(sig.bounds()));
            let loss = seg.loss(&stats);
            assert!(loss <= prev + 1e-9, "k={k}");
            prev = loss;
        }
    }

    #[test]
    fn greedy_at_least_optimal_dp() {
        let mut rng = Rng::new(52);
        let sig = generate::noise(10, 10, 1.0, &mut rng);
        let stats = PrefixStats::new(&sig);
        for k in [2, 3, 4] {
            let greedy = greedy_tree_loss(&stats, k);
            let opt = opt_k_tree(&stats, k);
            assert!(greedy >= opt - 1e-9, "greedy {greedy} < opt {opt}");
        }
    }

    #[test]
    fn greedy_pure_signal_single_leaf() {
        let sig = Signal::constant(12, 12, 2.0);
        let stats = PrefixStats::new(&sig);
        let seg = greedy_tree(&stats, 10);
        assert_eq!(seg.k(), 1);
    }
}
