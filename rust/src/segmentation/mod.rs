//! k-segmentation models (Definition 1): a partition of the grid into k
//! axis-parallel rectangles, each carrying one real value. Decision trees
//! with k leaves over the two grid coordinates are a strict subset of this
//! class, so every guarantee against `KSegmentation` holds for k-trees.

pub mod dp1d;
pub mod dp2d;
pub mod greedy;
pub mod quadtree;

use crate::rng::Rng;
use crate::signal::{PrefixStats, Rect, Signal};

/// A k-segmentation: disjoint rectangles covering (a subset of) the grid,
/// each with an assigned value. Constructors validate disjointness; full
/// coverage is validated separately (`is_partition_of`) because some
/// intermediate objects (bicriteria output) are legitimately partial.
#[derive(Clone, Debug)]
pub struct KSegmentation {
    pieces: Vec<(Rect, f64)>,
}

impl KSegmentation {
    /// Build from pieces, asserting pairwise disjointness (debug builds
    /// check exhaustively; release trusts the caller for O(k²) savings).
    pub fn new(pieces: Vec<(Rect, f64)>) -> Self {
        debug_assert!(
            Self::pairwise_disjoint(&pieces),
            "k-segmentation pieces must be disjoint"
        );
        Self { pieces }
    }

    pub fn pairwise_disjoint(pieces: &[(Rect, f64)]) -> bool {
        for i in 0..pieces.len() {
            for j in (i + 1)..pieces.len() {
                if pieces[i].0.intersects(&pieces[j].0) {
                    return false;
                }
            }
        }
        true
    }

    /// The trivial 1-segmentation: one rectangle, one value.
    pub fn constant(bounds: Rect, value: f64) -> Self {
        Self { pieces: vec![(bounds, value)] }
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.pieces.len()
    }

    #[inline]
    pub fn pieces(&self) -> &[(Rect, f64)] {
        &self.pieces
    }

    /// Value assigned to cell (r, c); `None` if uncovered.
    pub fn value_at(&self, r: usize, c: usize) -> Option<f64> {
        self.pieces
            .iter()
            .find(|(rect, _)| rect.contains(r, c))
            .map(|&(_, v)| v)
    }

    /// Does this segmentation exactly partition `bounds` (disjoint + full
    /// coverage by area)?
    pub fn is_partition_of(&self, bounds: Rect) -> bool {
        if !Self::pairwise_disjoint(&self.pieces) {
            return false;
        }
        if !self.pieces.iter().all(|(r, _)| bounds.contains_rect(r)) {
            return false;
        }
        let area: usize = self.pieces.iter().map(|(r, _)| r.area()).sum();
        area == bounds.area()
    }

    /// Does `s` intersect rectangle `B` in the paper's sense — i.e. does it
    /// assign ≥ 2 distinct values to B's cells? Equivalent (for a
    /// partitioning segmentation) to B not being contained in one piece.
    pub fn intersects_rect(&self, b: &Rect) -> bool {
        !self.pieces.iter().any(|(rect, _)| rect.contains_rect(b))
    }

    /// SSE loss ℓ(D, s) against a signal (Definition 2), computed exactly
    /// in O(k) from prefix statistics: for each piece, Σ(y − v)² over
    /// present cells. Pieces must cover the signal for this to equal the
    /// full loss; uncovered cells contribute nothing.
    pub fn loss(&self, stats: &PrefixStats) -> f64 {
        self.pieces
            .iter()
            .map(|(rect, v)| stats.sse_to(rect, *v))
            .sum()
    }

    /// Brute-force SSE against the signal — O(N); used by tests as oracle.
    pub fn loss_bruteforce(&self, signal: &Signal) -> f64 {
        signal.sse_against(|r, c| self.value_at(r, c).unwrap_or(0.0))
    }

    /// Replace each piece's value with the signal mean of its rectangle —
    /// the optimal values for this fixed partition.
    pub fn refit_values(&mut self, stats: &PrefixStats) {
        for (rect, v) in &mut self.pieces {
            *v = stats.mean(rect);
        }
    }

    /// Render into a dense signal (uncovered cells → 0). Used by examples
    /// and the image codec.
    pub fn render(&self, n: usize, m: usize) -> Signal {
        let mut sig = Signal::constant(n, m, 0.0);
        for (rect, v) in &self.pieces {
            for (r, c) in rect.cells() {
                sig.set(r, c, *v);
            }
        }
        sig
    }
}

/// Generate a *random* k-segmentation of `bounds` by recursive random
/// guillotine cuts with values fitted or random. These are the query
/// models used to validate the coreset's for-all-s guarantee empirically.
pub fn random_segmentation(bounds: Rect, k: usize, rng: &mut Rng) -> KSegmentation {
    let mut rects = vec![bounds];
    while rects.len() < k {
        let candidates: Vec<usize> = rects
            .iter()
            .enumerate()
            .filter(|(_, r)| r.height() > 1 || r.width() > 1)
            .map(|(i, _)| i)
            .collect();
        if candidates.is_empty() {
            break; // grid exhausted: fewer than k cells
        }
        let idx = candidates[rng.usize(candidates.len())];
        let rect = rects.swap_remove(idx);
        let split_rows = rect.height() > 1 && (rect.width() <= 1 || rng.bool(0.5));
        if split_rows {
            let cut = rng.range(rect.r0, rect.r1);
            rects.push(Rect::new(rect.r0, cut, rect.c0, rect.c1));
            rects.push(Rect::new(cut + 1, rect.r1, rect.c0, rect.c1));
        } else {
            let cut = rng.range(rect.c0, rect.c1);
            rects.push(Rect::new(rect.r0, rect.r1, rect.c0, cut));
            rects.push(Rect::new(rect.r0, rect.r1, cut + 1, rect.c1));
        }
    }
    let pieces = rects
        .into_iter()
        .map(|r| (r, rng.uniform(-10.0, 10.0)))
        .collect();
    KSegmentation::new(pieces)
}

/// Axis-aligned strip k-segmentation: `k` near-equal horizontal bands
/// (`horizontal == true`) or vertical bands of `bounds`, zero-valued
/// (callers refit). The degenerate query family of the guarantee audit:
/// strips are the worst case for row-slab-shaped partitions because a
/// single strip boundary crosses every block of a slab it splits.
pub fn strip_segmentation(bounds: Rect, k: usize, horizontal: bool) -> KSegmentation {
    let n = if horizontal { bounds.height() } else { bounds.width() };
    let k = k.clamp(1, n);
    let mut pieces = Vec::with_capacity(k);
    let mut prev = 0;
    for i in 1..=k {
        let next = i * n / k; // strictly increasing because k ≤ n
        let piece = if horizontal {
            Rect::new(bounds.r0 + prev, bounds.r0 + next - 1, bounds.c0, bounds.c1)
        } else {
            Rect::new(bounds.r0, bounds.r1, bounds.c0 + prev, bounds.c0 + next - 1)
        };
        pieces.push((piece, 0.0));
        prev = next;
    }
    KSegmentation::new(pieces)
}

/// A boundary-adversarial k-segmentation: recursive guillotine cuts like
/// [`random_segmentation`], except every cut snaps to one of the supplied
/// edge positions (a coreset's partition-block boundaries) when any falls
/// inside the rectangle being split — and is then jittered ±1 with
/// probability ½. On-edge cuts maximize the exactly-covered (Case (i))
/// blocks; the ±1 jitter instead produces 1-cell-wide slivers straddling
/// a block boundary, the smoothing regime (Case (ii)) a coreset handles
/// worst. `row_edges`/`col_edges` hold "first row/col of the next block"
/// positions in signal coordinates (interior edges only are used).
pub fn boundary_adversarial_segmentation(
    bounds: Rect,
    k: usize,
    row_edges: &[usize],
    col_edges: &[usize],
    rng: &mut Rng,
) -> KSegmentation {
    // Pick a split-after position in [lo, hi): snapped to an interior
    // edge when possible, jittered, else uniform.
    fn pick_cut(lo: usize, hi: usize, edges: &[usize], rng: &mut Rng) -> usize {
        let candidates: Vec<usize> = edges
            .iter()
            .filter(|&&e| e > lo && e <= hi)
            .map(|&e| e - 1) // edge e ⇒ split after row/col e − 1
            .collect();
        let mut cut = if candidates.is_empty() {
            rng.range(lo, hi)
        } else {
            candidates[rng.usize(candidates.len())]
        };
        if rng.bool(0.5) {
            cut = if rng.bool(0.5) { cut + 1 } else { cut.saturating_sub(1) };
        }
        cut.clamp(lo, hi - 1)
    }
    let mut rects = vec![bounds];
    while rects.len() < k {
        let candidates: Vec<usize> = rects
            .iter()
            .enumerate()
            .filter(|(_, r)| r.height() > 1 || r.width() > 1)
            .map(|(i, _)| i)
            .collect();
        if candidates.is_empty() {
            break;
        }
        let idx = candidates[rng.usize(candidates.len())];
        let rect = rects.swap_remove(idx);
        let split_rows = rect.height() > 1 && (rect.width() <= 1 || rng.bool(0.5));
        if split_rows {
            let cut = pick_cut(rect.r0, rect.r1, row_edges, rng);
            rects.push(Rect::new(rect.r0, cut, rect.c0, rect.c1));
            rects.push(Rect::new(cut + 1, rect.r1, rect.c0, rect.c1));
        } else {
            let cut = pick_cut(rect.c0, rect.c1, col_edges, rng);
            rects.push(Rect::new(rect.r0, rect.r1, rect.c0, cut));
            rects.push(Rect::new(rect.r0, rect.r1, cut + 1, rect.c1));
        }
    }
    KSegmentation::new(rects.into_iter().map(|r| (r, 0.0)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid() -> Rect {
        Rect::new(0, 9, 0, 9)
    }

    #[test]
    fn constant_segmentation_covers() {
        let s = KSegmentation::constant(grid(), 1.0);
        assert!(s.is_partition_of(grid()));
        assert_eq!(s.k(), 1);
        assert_eq!(s.value_at(5, 5), Some(1.0));
    }

    #[test]
    fn random_segmentation_is_partition() {
        let mut rng = Rng::new(123);
        for k in [1, 2, 5, 17, 40] {
            let s = random_segmentation(grid(), k, &mut rng);
            assert_eq!(s.k(), k);
            assert!(s.is_partition_of(grid()), "k={k}");
        }
    }

    #[test]
    fn loss_prefix_matches_bruteforce() {
        let mut rng = Rng::new(42);
        let sig = Signal::from_fn(10, 10, |r, c| ((r * 3 + c) % 7) as f64);
        let stats = PrefixStats::new(&sig);
        for k in [1, 4, 9] {
            let s = random_segmentation(grid(), k, &mut rng);
            let fast = s.loss(&stats);
            let slow = s.loss_bruteforce(&sig);
            assert!((fast - slow).abs() < 1e-8 * (1.0 + slow), "k={k}");
        }
    }

    #[test]
    fn intersects_rect_detects_straddling() {
        // Two vertical halves.
        let s = KSegmentation::new(vec![
            (Rect::new(0, 9, 0, 4), 0.0),
            (Rect::new(0, 9, 5, 9), 1.0),
        ]);
        assert!(!s.intersects_rect(&Rect::new(0, 3, 0, 3))); // inside left
        assert!(s.intersects_rect(&Rect::new(0, 3, 3, 6))); // straddles cut
    }

    #[test]
    fn refit_values_minimizes_loss() {
        let mut rng = Rng::new(9);
        let sig = Signal::from_fn(10, 10, |r, c| (r as f64 - c as f64).powi(2) / 10.0);
        let stats = PrefixStats::new(&sig);
        let mut s = random_segmentation(grid(), 6, &mut rng);
        let before = s.loss(&stats);
        s.refit_values(&stats);
        let after = s.loss(&stats);
        assert!(after <= before + 1e-12);
        // Perturbing any value increases loss (local optimality of means).
        let mut worse = s.clone();
        let pieces: Vec<(Rect, f64)> = worse
            .pieces()
            .iter()
            .map(|&(r, v)| (r, v + 0.1))
            .collect();
        worse = KSegmentation::new(pieces);
        assert!(worse.loss(&stats) >= after);
    }

    #[test]
    fn strip_segmentation_partitions_both_axes() {
        let bounds = Rect::new(2, 11, 3, 9);
        for k in [1, 3, 7, 10] {
            let rows = strip_segmentation(bounds, k, true);
            assert_eq!(rows.k(), k.min(bounds.height()));
            assert!(rows.is_partition_of(bounds), "rows k={k}");
            let cols = strip_segmentation(bounds, k, false);
            assert_eq!(cols.k(), k.min(bounds.width()));
            assert!(cols.is_partition_of(bounds), "cols k={k}");
        }
        // k beyond the axis length clamps to one strip per row/col.
        assert_eq!(strip_segmentation(bounds, 99, true).k(), 10);
    }

    #[test]
    fn boundary_adversarial_is_partition_and_deterministic() {
        let bounds = grid();
        let row_edges = [3, 7];
        let col_edges = [5];
        for k in [1, 2, 5, 9] {
            let mut rng = Rng::new(11);
            let s = boundary_adversarial_segmentation(bounds, k, &row_edges, &col_edges, &mut rng);
            assert_eq!(s.k(), k);
            assert!(s.is_partition_of(bounds), "k={k}");
            let mut rng2 = Rng::new(11);
            let s2 =
                boundary_adversarial_segmentation(bounds, k, &row_edges, &col_edges, &mut rng2);
            for (a, b) in s.pieces().iter().zip(s2.pieces()) {
                assert_eq!(a.0, b.0);
            }
        }
        // No interior edges at all → falls back to random cuts, still valid.
        let mut rng = Rng::new(5);
        let s = boundary_adversarial_segmentation(bounds, 4, &[], &[], &mut rng);
        assert!(s.is_partition_of(bounds));
    }

    #[test]
    fn render_roundtrip_loss_zero() {
        let mut rng = Rng::new(4);
        let s = random_segmentation(grid(), 5, &mut rng);
        let rendered = s.render(10, 10);
        assert!(s.loss_bruteforce(&rendered) < 1e-18);
    }
}
