//! Quadtree segmentation — the MPEG4-style image-compression use case the
//! paper's introduction motivates ([46, 55]): recursively split a block
//! into 4 quadrants while its opt₁ exceeds a tolerance (or a leaf budget
//! is exhausted). A quadtree with k leaves is a special k-segmentation, so
//! the coreset guarantee covers it.

use crate::signal::{PrefixStats, Rect};

use super::KSegmentation;

/// Greedy quadtree compression: always split the leaf with the largest
/// opt₁ until either every leaf is within `tolerance` or `max_leaves` is
/// reached. Returns the resulting segmentation with mean-fitted values.
pub fn quadtree_compress(
    stats: &PrefixStats,
    tolerance: f64,
    max_leaves: usize,
) -> KSegmentation {
    assert!(max_leaves >= 1);
    let bounds = Rect::new(0, stats.rows() - 1, 0, stats.cols() - 1);
    // Max-heap by opt1 — a simple Vec with linear max scan is fine at the
    // scales involved (≤ max_leaves entries); keeps us dependency-free.
    let mut leaves: Vec<(Rect, f64)> = vec![(bounds, stats.opt1(&bounds))];
    loop {
        if leaves.len() >= max_leaves {
            break;
        }
        // Worst leaf that is still splittable.
        let worst = leaves
            .iter()
            .enumerate()
            .filter(|(_, (r, loss))| *loss > tolerance && (r.height() > 1 || r.width() > 1))
            .max_by(|a, b| a.1 .1.total_cmp(&b.1 .1));
        let Some((idx, _)) = worst else { break };
        let (rect, _) = leaves.swap_remove(idx);
        let budget = max_leaves - leaves.len();
        for q in quadrants(&rect).into_iter().take(budget.max(2)) {
            leaves.push((q, stats.opt1(&q)));
        }
    }
    let pieces = leaves
        .into_iter()
        .map(|(r, _)| (r, stats.mean(&r)))
        .collect();
    KSegmentation::new(pieces)
}

/// Split a rectangle into its (up to 4) quadrants. Degenerate axes yield
/// fewer pieces (a 1×w rect splits into 2 halves, etc.).
pub fn quadrants(rect: &Rect) -> Vec<Rect> {
    let mut out = Vec::with_capacity(4);
    let rsplit = rect.height() > 1;
    let csplit = rect.width() > 1;
    let rmid = rect.r0 + (rect.height() - 1) / 2; // last row of top half
    let cmid = rect.c0 + (rect.width() - 1) / 2;
    match (rsplit, csplit) {
        (true, true) => {
            out.push(Rect::new(rect.r0, rmid, rect.c0, cmid));
            out.push(Rect::new(rect.r0, rmid, cmid + 1, rect.c1));
            out.push(Rect::new(rmid + 1, rect.r1, rect.c0, cmid));
            out.push(Rect::new(rmid + 1, rect.r1, cmid + 1, rect.c1));
        }
        (true, false) => {
            out.push(Rect::new(rect.r0, rmid, rect.c0, rect.c1));
            out.push(Rect::new(rmid + 1, rect.r1, rect.c0, rect.c1));
        }
        (false, true) => {
            out.push(Rect::new(rect.r0, rect.r1, rect.c0, cmid));
            out.push(Rect::new(rect.r0, rect.r1, cmid + 1, rect.c1));
        }
        (false, false) => out.push(*rect),
    }
    out
}

/// PSNR-style compression report for the image example.
#[derive(Clone, Copy, Debug)]
pub struct CompressionReport {
    pub leaves: usize,
    pub sse: f64,
    pub mse: f64,
    /// Compression ratio: original cells / (leaves × 5 numbers per leaf).
    pub ratio: f64,
}

pub fn report(stats: &PrefixStats, seg: &KSegmentation) -> CompressionReport {
    let n = stats.rows() * stats.cols();
    let sse = seg.loss(stats);
    CompressionReport {
        leaves: seg.k(),
        sse,
        mse: sse / n as f64,
        ratio: n as f64 / (seg.k() as f64 * 5.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::signal::{generate, Signal, PrefixStats};

    #[test]
    fn quadrants_tile_parent() {
        for rect in [
            Rect::new(0, 7, 0, 7),
            Rect::new(2, 2, 0, 5),
            Rect::new(1, 6, 3, 3),
            Rect::new(4, 4, 4, 4),
        ] {
            let qs = quadrants(&rect);
            let total: usize = qs.iter().map(|q| q.area()).sum();
            assert_eq!(total, rect.area(), "{rect:?}");
            for i in 0..qs.len() {
                assert!(rect.contains_rect(&qs[i]));
                for j in (i + 1)..qs.len() {
                    assert!(!qs[i].intersects(&qs[j]));
                }
            }
        }
    }

    #[test]
    fn compress_constant_image_is_one_leaf() {
        let sig = Signal::constant(16, 16, 5.0);
        let stats = PrefixStats::new(&sig);
        let seg = quadtree_compress(&stats, 1e-9, 100);
        assert_eq!(seg.k(), 1);
        assert!(seg.loss(&stats) < 1e-12);
    }

    #[test]
    fn compress_respects_budget_and_partitions() {
        let mut rng = Rng::new(5);
        let sig = generate::image_like(32, 32, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let seg = quadtree_compress(&stats, 0.0, 40);
        assert!(seg.k() <= 40 + 3, "k={}", seg.k()); // split adds ≤3 net leaves
        assert!(seg.is_partition_of(sig.bounds()));
    }

    #[test]
    fn more_leaves_never_hurts() {
        let mut rng = Rng::new(6);
        let sig = generate::image_like(32, 32, 4, &mut rng);
        let stats = PrefixStats::new(&sig);
        let mut prev = f64::INFINITY;
        for budget in [1, 4, 16, 64, 256] {
            let seg = quadtree_compress(&stats, 0.0, budget);
            let loss = seg.loss(&stats);
            assert!(loss <= prev + 1e-9, "budget {budget}");
            prev = loss;
        }
    }

    #[test]
    fn tolerance_is_enforced_when_budget_allows() {
        let mut rng = Rng::new(7);
        let sig = generate::image_like(32, 32, 2, &mut rng);
        let stats = PrefixStats::new(&sig);
        let tol = 1.0;
        let seg = quadtree_compress(&stats, tol, 100_000);
        for (rect, _) in seg.pieces() {
            if rect.area() > 1 {
                assert!(stats.opt1(rect) <= tol + 1e-9);
            }
        }
    }
}
