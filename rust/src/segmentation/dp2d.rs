//! Exact optimal k-tree of a 2D signal by dynamic programming over
//! guillotine (recursive binary) partitions — the O(k²n⁵)-flavour DP the
//! paper cites ([5], Bellman) and calls "impractical even for small
//! datasets, unless applied on a small coreset". We implement it (a)
//! because the paper's pipeline is exactly "run the expensive solver on
//! the coreset", and (b) as ground-truth `opt_k` for small instances in
//! tests.
//!
//! State: (rectangle, k) → minimal SSE of a k-leaf decision tree on that
//! rectangle. Transition: either k = 1 (fit the mean), or split the
//! rectangle horizontally/vertically at any cut and distribute the leaf
//! budget. Memoized over the O(n²m²) rectangles; feasible for signals up
//! to ~32×32 with small k — precisely the "on the coreset" regime.

// lint:allow(det-order) -- memo cache: keyed get/insert only, never
// iterated, so its order cannot leak into any result.
use std::collections::HashMap;

use crate::signal::{PrefixStats, Rect};

use super::KSegmentation;

/// The rectangle-statistics oracle the k-tree DP runs on. The DP itself
/// only ever asks three questions about a rectangle, so abstracting them
/// lets the *same exact solver* run both on a signal's [`PrefixStats`]
/// (ground truth) and on a coreset's smoothed density
/// ([`crate::audit::CoresetOracle`]) — the paper's actual pipeline,
/// "run the expensive solver on the coreset", and the optimal-tree-
/// transfer check the audit engine performs.
pub trait RectOracle {
    /// opt₁(rect): minimal loss of fitting one constant to the rect.
    fn opt1(&self, rect: &Rect) -> f64;

    /// The optimal constant for the rect (its mass-weighted mean label).
    fn mean(&self, rect: &Rect) -> f64;

    /// Loss when every cell of `rect` is its own leaf — the `k ≥ area`
    /// saturation floor. Zero for per-cell-exact signal statistics; the
    /// coreset density oracle overrides it with the irreducible per-cell
    /// variance its smoothing spreads across each block.
    fn saturated(&self, _rect: &Rect) -> f64 {
        0.0
    }
}

impl RectOracle for PrefixStats {
    #[inline]
    fn opt1(&self, rect: &Rect) -> f64 {
        PrefixStats::opt1(self, rect)
    }

    #[inline]
    fn mean(&self, rect: &Rect) -> f64 {
        PrefixStats::mean(self, rect)
    }
}

/// Exact k-tree DP solver with memoization, generic over the statistics
/// oracle (defaults to [`PrefixStats`] — the ground-truth solver).
pub struct TreeDP<'a, O: RectOracle = PrefixStats> {
    stats: &'a O,
    // lint:allow(det-order) -- keyed lookups only (see the import note).
    memo: HashMap<(Rect, usize), f64>,
}

impl<'a, O: RectOracle> TreeDP<'a, O> {
    pub fn new(stats: &'a O) -> Self {
        // lint:allow(det-order) -- keyed lookups only.
        Self { stats, memo: HashMap::new() }
    }

    /// Minimal SSE of a decision tree with at most `k` leaves on `rect`.
    pub fn opt(&mut self, rect: Rect, k: usize) -> f64 {
        assert!(k >= 1);
        if k == 1 {
            return self.stats.opt1(&rect);
        }
        if let Some(&v) = self.memo.get(&(rect, k)) {
            return v;
        }
        // A rect of `a` cells never needs more than `a` leaves; the floor
        // is the oracle's saturated (one-leaf-per-cell) loss.
        let area = rect.area();
        if k >= area {
            let v = self.stats.saturated(&rect);
            self.memo.insert((rect, k), v);
            return v;
        }
        let mut best = self.stats.opt1(&rect);
        // 2-leaf pre-pass: every guillotine cut's opt₁(a) + opt₁(b) is
        // itself an achievable tree (k ≥ 2 here), so its minimum is a
        // valid upper bound that tightens `best` *before* the recursive
        // search — the `la >= best` prune in `best_split` then fires much
        // earlier. The DP value is unchanged: each bound dominates some
        // candidate the split loop examines anyway (opt(·, k') ≤ opt₁(·)
        // for k' ≥ 1), and a tighter `best` only skips candidates that
        // cannot beat the minimum. Each bound is two O(1) prefix queries,
        // batched per cut direction — the `padded_prefix_query`-heavy
        // loop the blocked prefix layout below serves.
        for cut in rect.r0..rect.r1 {
            let top = Rect::new(rect.r0, cut, rect.c0, rect.c1);
            let bot = Rect::new(cut + 1, rect.r1, rect.c0, rect.c1);
            best = best.min(self.stats.opt1(&top) + self.stats.opt1(&bot));
        }
        for cut in rect.c0..rect.c1 {
            let left = Rect::new(rect.r0, rect.r1, rect.c0, cut);
            let right = Rect::new(rect.r0, rect.r1, cut + 1, rect.c1);
            best = best.min(self.stats.opt1(&left) + self.stats.opt1(&right));
        }
        // Horizontal cuts (split rows).
        for cut in rect.r0..rect.r1 {
            let top = Rect::new(rect.r0, cut, rect.c0, rect.c1);
            let bot = Rect::new(cut + 1, rect.r1, rect.c0, rect.c1);
            best = best.min(self.best_split(top, bot, k, best));
        }
        // Vertical cuts (split cols).
        for cut in rect.c0..rect.c1 {
            let left = Rect::new(rect.r0, rect.r1, rect.c0, cut);
            let right = Rect::new(rect.r0, rect.r1, cut + 1, rect.c1);
            best = best.min(self.best_split(left, right, k, best));
        }
        self.memo.insert((rect, k), best);
        best
    }

    /// Optimal distribution of the leaf budget over a fixed split.
    fn best_split(&mut self, a: Rect, b: Rect, k: usize, upper: f64) -> f64 {
        let mut best = upper;
        let ka_max = (k - 1).min(a.area());
        for ka in 1..=ka_max {
            let kb = k - ka;
            if kb < 1 {
                break;
            }
            let la = self.opt(a, ka);
            if la >= best {
                continue; // prune: left side alone already too costly
            }
            let lb = self.opt(b, kb.min(b.area()));
            if la + lb < best {
                best = la + lb;
            }
        }
        best
    }

    /// Reconstruct an optimal k-tree as a `KSegmentation` (re-running the
    /// argmin search using memoized values; O(same) but no extra state).
    pub fn solve(&mut self, rect: Rect, k: usize) -> KSegmentation {
        let mut pieces = Vec::new();
        self.reconstruct(rect, k, &mut pieces);
        KSegmentation::new(pieces)
    }

    fn reconstruct(&mut self, rect: Rect, k: usize, out: &mut Vec<(Rect, f64)>) {
        let target = self.opt(rect, k);
        let leaf = self.stats.opt1(&rect);
        if k == 1 || (leaf - target).abs() <= 1e-9 * (1.0 + target) {
            out.push((rect, self.stats.mean(&rect)));
            return;
        }
        // Find a split achieving `target`.
        let tol = 1e-9 * (1.0 + target);
        for horizontal in [true, false] {
            let (lo, hi) = if horizontal { (rect.r0, rect.r1) } else { (rect.c0, rect.c1) };
            for cut in lo..hi {
                let (a, b) = if horizontal {
                    (
                        Rect::new(rect.r0, cut, rect.c0, rect.c1),
                        Rect::new(cut + 1, rect.r1, rect.c0, rect.c1),
                    )
                } else {
                    (
                        Rect::new(rect.r0, rect.r1, rect.c0, cut),
                        Rect::new(rect.r0, rect.r1, cut + 1, rect.c1),
                    )
                };
                for ka in 1..k {
                    let kb = k - ka;
                    let la = self.opt(a, ka.min(a.area()));
                    let lb = self.opt(b, kb.min(b.area()));
                    if (la + lb - target).abs() <= tol {
                        self.reconstruct(a, ka.min(a.area()), out);
                        self.reconstruct(b, kb.min(b.area()), out);
                        return;
                    }
                }
            }
        }
        // Fallback (numerically ambiguous): emit as a single leaf.
        out.push((rect, self.stats.mean(&rect)));
    }
}

/// Convenience: optimal k-tree loss of a whole signal.
pub fn opt_k_tree(stats: &PrefixStats, k: usize) -> f64 {
    let rect = Rect::new(0, stats.rows() - 1, 0, stats.cols() - 1);
    TreeDP::new(stats).opt(rect, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::segmentation::random_segmentation;
    use crate::signal::{generate, Signal};

    #[test]
    fn k1_equals_opt1() {
        let sig = Signal::from_fn(6, 6, |r, c| (r * c) as f64);
        let stats = PrefixStats::new(&sig);
        let whole = sig.bounds();
        assert_eq!(opt_k_tree(&stats, 1), stats.opt1(&whole));
    }

    #[test]
    fn recovers_planted_quadrants() {
        // 4 constant quadrants → k=4 achieves 0.
        let sig = Signal::from_fn(8, 8, |r, c| {
            match (r < 4, c < 4) {
                (true, true) => 1.0,
                (true, false) => 2.0,
                (false, true) => 3.0,
                (false, false) => 4.0,
            }
        });
        let stats = PrefixStats::new(&sig);
        assert!(opt_k_tree(&stats, 4) < 1e-12);
        assert!(opt_k_tree(&stats, 3) > 1e-6);
        let seg = TreeDP::new(&stats).solve(sig.bounds(), 4);
        assert_eq!(seg.k(), 4);
        assert!(seg.is_partition_of(sig.bounds()));
        assert!(seg.loss(&stats) < 1e-12);
    }

    #[test]
    fn monotone_in_k() {
        let mut rng = Rng::new(3);
        let sig = generate::noise(7, 7, 1.0, &mut rng);
        let stats = PrefixStats::new(&sig);
        let mut prev = f64::INFINITY;
        for k in 1..=6 {
            let l = opt_k_tree(&stats, k);
            assert!(l <= prev + 1e-12);
            prev = l;
        }
    }

    #[test]
    fn dp_lower_bounds_random_segmentations() {
        // opt over trees lower-bounds loss of any guillotine k-segmentation
        // (random_segmentation builds guillotine partitions).
        let mut rng = Rng::new(10);
        let sig = generate::smooth(9, 9, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let k = 5;
        let opt = opt_k_tree(&stats, k);
        for _ in 0..50 {
            let mut s = random_segmentation(sig.bounds(), k, &mut rng);
            s.refit_values(&stats);
            assert!(opt <= s.loss(&stats) + 1e-9);
        }
    }

    #[test]
    fn solve_matches_opt_value() {
        let mut rng = Rng::new(99);
        let sig = generate::image_like(10, 10, 2, &mut rng);
        let stats = PrefixStats::new(&sig);
        for k in [2, 3, 5] {
            let mut dp = TreeDP::new(&stats);
            let target = dp.opt(sig.bounds(), k);
            let seg = dp.solve(sig.bounds(), k);
            assert!(seg.k() <= k);
            assert!(seg.is_partition_of(sig.bounds()));
            assert!((seg.loss(&stats) - target).abs() <= 1e-6 * (1.0 + target));
        }
    }
}
