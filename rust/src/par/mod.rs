//! `sigtree::par` — the std-only parallel construction engine.
//!
//! The paper's construction is "embarrassingly" shardable: the
//! merge-and-reduce property (§1.1, Challenge (iv)) makes every per-block
//! guarantee local to its row-band, so band-sharded construction composes
//! through [`crate::coreset::merge_reduce`] with zero loss of
//! correctness (the same observation behind the streaming/distributed
//! compositions in Bachem et al., *Practical Coreset Constructions for
//! Machine Learning*). This module provides the worker pool those
//! compositions run on:
//!
//! * [`parallel_map`] — order-preserving map over a slice on a scoped
//!   worker pool with atomic work-stealing (an idle worker always takes
//!   the next unclaimed item, so ragged per-item costs balance out).
//! * [`WorkerPool`] — the same map semantics on **long-lived** worker
//!   threads: spawned once (by [`crate::engine::Engine`]) and reused for
//!   every map, so repeated small batches — the serving workload — pay
//!   no per-call thread spinup.
//! * [`Exec`] — the executor seam the sharded builders are generic over:
//!   `Exec::Spawn(threads)` (scoped threads per call, the classic
//!   [`parallel_map`]) or `Exec::Pool(&pool)` (the engine path). Both
//!   produce bit-identical results for the same input.
//! * [`resolve_threads`] / [`available_threads`] — the `--threads`
//!   convention: `0` means "all available cores".
//!
//! Everything is `std::thread`-based — no external crates (the default
//! build is std-only, see DESIGN.md §Substitutions); the scoped variant
//! has no `'static` bounds, so workers borrow the signal directly
//! instead of cloning it, and the pool variant erases the borrow behind
//! a completion latch that is always waited on before `map` returns.
//!
//! **Determinism.** `parallel_map` and [`WorkerPool::map`] return
//! results in input order, and the higher-level users
//! ([`crate::coreset::SignalCoreset::construct_sharded`],
//! [`crate::signal::PrefixStats::new_par`]) derive their shard plans
//! from the input alone — never from `threads` or the executor — so any
//! thread count and either executor produce bit-identical output for
//! the same input.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Number of hardware threads available to this process (≥ 1).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map_or(1, |p| p.get())
}

/// Resolve a `--threads` request: `0` → [`available_threads`], anything
/// else is taken literally.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        available_threads()
    } else {
        threads
    }
}

/// Lock `m`, treating a poisoned lock as the worker panic it records:
/// the panic payload is already captured (or about to be re-raised by
/// the caller's latch protocol), so propagating the poison here is the
/// correct — and only — response. Routing every pool lock through this
/// one audited helper keeps the rest of the crate free of bare
/// `lock().unwrap()` calls.
pub fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    // lint:allow(panic) -- a poisoned pool lock means a worker already
    // panicked; propagating that panic is this helper's contract.
    m.lock().unwrap()
}

/// Map `f` over `items` on `threads` scoped workers, returning results in
/// input order. Work distribution is a shared atomic cursor: each worker
/// repeatedly claims the next unprocessed index, so uneven per-item costs
/// (ragged shards, heterogeneous queries) self-balance.
///
/// `threads == 0` uses all available cores; `threads <= 1` (or a 0/1-item
/// input) degenerates to a plain sequential map with no thread spawned,
/// so callers can pass user-supplied values straight through.
///
/// Panics in `f` are propagated (the pool does not swallow worker
/// panics).
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = resolve_threads(threads).min(items.len().max(1));
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut tagged: Vec<(usize, R)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(|| {
                    let mut local: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        local.push((i, f(i, &items[i])));
                    }
                    local
                })
            })
            .collect();
        let mut all = Vec::with_capacity(items.len());
        for h in handles {
            match h.join() {
                Ok(local) => all.extend(local),
                // Rethrow the original payload so the caller sees the
                // worker's actual panic message, not a generic wrapper.
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        all
    });
    tagged.sort_unstable_by_key(|&(i, _)| i);
    tagged.into_iter().map(|(_, r)| r).collect()
}

/// A type-erased unit of work queued on the pool. Tasks are `'static`
/// from the queue's point of view; [`WorkerPool::map`] erases the
/// caller's borrow and re-establishes safety by blocking on a
/// completion latch before returning (see the safety note there).
type Task = Box<dyn FnOnce() + Send + 'static>;

/// What a panicking worker leaves behind for the caller to re-throw.
type PanicPayload = Box<dyn std::any::Any + Send>;

struct PoolShared {
    queue: Mutex<VecDeque<Task>>,
    task_ready: Condvar,
    shutdown: AtomicBool,
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let task = {
            let mut queue = lock(&shared.queue);
            loop {
                if let Some(task) = queue.pop_front() {
                    break Some(task);
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                // lint:allow(panic) -- poison on the queue lock re-raises
                // a worker panic (see `lock`); the Condvar wait itself
                // cannot fail otherwise.
                queue = shared.task_ready.wait(queue).unwrap();
            }
        };
        match task {
            Some(task) => task(),
            None => return,
        }
    }
}

/// Long-lived worker pool with [`parallel_map`] semantics: results in
/// input order, atomic work-stealing cursor, worker panics propagated.
/// Unlike the scoped `parallel_map`, threads are spawned **once** (at
/// [`WorkerPool::new`]) and parked between calls, so repeated small
/// batches — one [`crate::engine::Engine`] serving many
/// `fitting_loss` / build requests — pay no per-call thread spinup.
///
/// The calling thread always participates in the map (it drains the
/// same work cursor the workers do), so `new(t)` spawns `t − 1` helper
/// threads for a total concurrency of `t`, and a map never deadlocks
/// even when every helper is busy with another caller's work.
pub struct WorkerPool {
    threads: usize,
    shared: Option<Arc<PoolShared>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool of `threads` total workers (`0` = all available
    /// cores). `threads <= 1` spawns nothing: every map degenerates to
    /// a plain sequential loop on the caller's thread.
    pub fn new(threads: usize) -> Self {
        let threads = resolve_threads(threads);
        if threads <= 1 {
            return Self { threads, shared: None, workers: Vec::new() };
        }
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            task_ready: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..threads - 1)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        Self { threads, shared: Some(shared), workers }
    }

    /// Total concurrency of this pool (caller + helpers; ≥ 1).
    #[inline]
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Map `f` over `items`, returning results in input order —
    /// bit-identical to [`parallel_map`] with any thread count (both
    /// run the same `f` per item; only scheduling differs).
    ///
    /// Worker panics are re-thrown on the calling thread after every
    /// outstanding task has finished.
    ///
    /// `f` must not call `map` on the **same** pool (shards/queries
    /// never do — fan-out is single-level by construction): a nested
    /// map's queued helpers could wait behind the very tasks waiting
    /// on them. Distinct pools, or the scoped [`parallel_map`], nest
    /// freely.
    pub fn map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        struct MapState<'a, T, R, F> {
            items: &'a [T],
            f: &'a F,
            cursor: AtomicUsize,
            out: Mutex<Vec<(usize, R)>>,
            /// Helper tasks not yet finished; the caller blocks until 0.
            pending: AtomicUsize,
            done_lock: Mutex<bool>,
            done_cv: Condvar,
            panic: Mutex<Option<PanicPayload>>,
        }

        fn drain<T, R, F: Fn(usize, &T) -> R>(state: &MapState<'_, T, R, F>) {
            let result = catch_unwind(AssertUnwindSafe(|| {
                let mut local: Vec<(usize, R)> = Vec::new();
                loop {
                    let i = state.cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= state.items.len() {
                        break;
                    }
                    local.push((i, (state.f)(i, &state.items[i])));
                }
                if !local.is_empty() {
                    lock(&state.out).extend(local);
                }
            }));
            if let Err(payload) = result {
                *lock(&state.panic) = Some(payload);
            }
        }

        let n = items.len();
        let workers = self.threads.min(n.max(1));
        let Some(shared) = self.shared.as_ref() else {
            return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
        };
        if workers <= 1 || n <= 1 {
            return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
        }

        let helpers = workers - 1;
        let state = MapState {
            items,
            f: &f,
            cursor: AtomicUsize::new(0),
            out: Mutex::new(Vec::with_capacity(n)),
            pending: AtomicUsize::new(helpers),
            done_lock: Mutex::new(false),
            done_cv: Condvar::new(),
            panic: Mutex::new(None),
        };

        {
            let state_ref = &state;
            let mut queue = lock(&shared.queue);
            for _ in 0..helpers {
                let task: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    drain(state_ref);
                    if state_ref.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
                        let mut done = lock(&state_ref.done_lock);
                        *done = true;
                        state_ref.done_cv.notify_all();
                    }
                });
                // SAFETY: the task borrows `state` (and through it
                // `items` / `f`), which live on this stack frame. The
                // borrow is erased to `'static` so the task can sit on
                // the long-lived queue, and re-established by the latch
                // below: `map` does not return until `pending` hits 0,
                // i.e. until every enqueued task has *finished running*
                // (tasks that start after the cursor is exhausted finish
                // immediately). The pool cannot shut down mid-map —
                // `Drop` needs `&mut self` while `map` holds `&self` —
                // and workers always drain the queue before exiting.
                let task: Task = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Task>(task)
                };
                queue.push_back(task);
            }
            drop(queue);
            shared.task_ready.notify_all();
        }

        // The caller works the same cursor, then waits for the helpers.
        drain(&state);
        let mut done = lock(&state.done_lock);
        while !*done {
            // lint:allow(panic) -- same poison-propagation contract as
            // `lock`: a poisoned latch lock re-raises a worker panic.
            done = state.done_cv.wait(done).unwrap();
        }
        drop(done);

        if let Some(payload) = lock(&state.panic).take() {
            resume_unwind(payload);
        }
        // A poisoned out-buffer can only mean a helper panicked, and
        // that panic was re-raised just above — recover the data either
        // way instead of double-panicking.
        let mut tagged = match state.out.into_inner() {
            Ok(tagged) => tagged,
            Err(poisoned) => poisoned.into_inner(),
        };
        tagged.sort_unstable_by_key(|&(i, _)| i);
        tagged.into_iter().map(|(_, r)| r).collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        if let Some(shared) = &self.shared {
            // The store + notify must happen under the queue lock:
            // otherwise they can interleave inside a worker's
            // checked-empty-queue → not-yet-waiting window (the worker
            // loaded `shutdown == false` while holding the lock, the
            // notify lands before it enters `wait`, and the join below
            // hangs forever on a worker nobody will ever wake again).
            let guard = lock(&shared.queue);
            shared.shutdown.store(true, Ordering::Release);
            shared.task_ready.notify_all();
            drop(guard);
        }
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

/// The executor seam: how a sharded builder should fan its shards out.
/// Both variants run the identical per-item function in input order, so
/// the produced values are bit-identical; only thread lifecycle differs.
#[derive(Clone, Copy)]
pub enum Exec<'p> {
    /// Spawn scoped threads for this call (the classic [`parallel_map`];
    /// `0` = all available cores).
    Spawn(usize),
    /// Reuse a long-lived [`WorkerPool`] (the
    /// [`crate::engine::Engine`] path — no per-call spinup).
    Pool(&'p WorkerPool),
}

impl Exec<'_> {
    /// The resolved concurrency this executor maps with (≥ 1).
    pub fn threads(&self) -> usize {
        match self {
            Exec::Spawn(t) => resolve_threads(*t),
            Exec::Pool(pool) => pool.threads(),
        }
    }

    /// Order-preserving map with this executor's threads.
    pub fn map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        match self {
            Exec::Spawn(t) => parallel_map(items, *t, f),
            Exec::Pool(pool) => pool.map(items, f),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_zero_is_all_cores() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(resolve_threads(0), available_threads());
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..257).collect();
        let expect: Vec<usize> = items.iter().map(|&x| x * x + 1).collect();
        for threads in [0, 1, 2, 3, 4, 8] {
            let got = parallel_map(&items, threads, |_, &x| x * x + 1);
            assert_eq!(got, expect, "threads {threads}");
        }
    }

    #[test]
    fn parallel_map_passes_index() {
        let items = vec!["a"; 64];
        let got = parallel_map(&items, 4, |i, _| i);
        assert_eq!(got, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_handles_small_inputs() {
        let empty: Vec<u32> = Vec::new();
        assert!(parallel_map(&empty, 4, |_, &x| x).is_empty());
        assert_eq!(parallel_map(&[7u32], 4, |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn worker_pool_matches_parallel_map() {
        let items: Vec<usize> = (0..257).collect();
        let expect: Vec<usize> = items.iter().map(|&x| x * x + 1).collect();
        for threads in [0, 1, 2, 3, 4, 8] {
            let pool = WorkerPool::new(threads);
            assert!(pool.threads() >= 1);
            // Reuse across calls is the whole point: map repeatedly.
            for _ in 0..3 {
                let got = pool.map(&items, |_, &x| x * x + 1);
                assert_eq!(got, expect, "threads {threads}");
            }
        }
    }

    #[test]
    fn worker_pool_handles_small_inputs() {
        let pool = WorkerPool::new(4);
        let empty: Vec<u32> = Vec::new();
        assert!(pool.map(&empty, |_, &x| x).is_empty());
        assert_eq!(pool.map(&[7u32], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn worker_pool_propagates_panics_and_survives_them() {
        let pool = WorkerPool::new(4);
        let items: Vec<usize> = (0..64).collect();
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.map(&items, |_, &x| {
                assert!(x != 40, "boom at {x}");
                x
            })
        }));
        assert!(result.is_err());
        // The pool is still usable after a panicking map.
        let got = pool.map(&items, |_, &x| x + 1);
        assert_eq!(got, (1..=64).collect::<Vec<_>>());
    }

    #[test]
    fn exec_variants_agree() {
        let items: Vec<usize> = (0..100).collect();
        let pool = WorkerPool::new(3);
        let spawned = Exec::Spawn(3).map(&items, |i, &x| i * 1000 + x);
        let pooled = Exec::Pool(&pool).map(&items, |i, &x| i * 1000 + x);
        assert_eq!(spawned, pooled);
        assert_eq!(Exec::Spawn(3).threads(), 3);
        assert_eq!(Exec::Pool(&pool).threads(), 3);
        assert!(Exec::Spawn(0).threads() >= 1);
    }

    #[test]
    fn parallel_map_balances_ragged_work() {
        // Ragged per-item cost: results must still be exact and ordered.
        let items: Vec<usize> = (0..40).collect();
        let got = parallel_map(&items, 4, |_, &x| {
            let mut acc = 0u64;
            for i in 0..(x * 1000) {
                acc = acc.wrapping_add(i as u64);
            }
            (x, acc)
        });
        for (i, &(x, _)) in got.iter().enumerate() {
            assert_eq!(i, x);
        }
    }
}
