//! `sigtree::par` — the std-only parallel construction engine.
//!
//! The paper's construction is "embarrassingly" shardable: the
//! merge-and-reduce property (§1.1, Challenge (iv)) makes every per-block
//! guarantee local to its row-band, so band-sharded construction composes
//! through [`crate::coreset::merge_reduce`] with zero loss of
//! correctness (the same observation behind the streaming/distributed
//! compositions in Bachem et al., *Practical Coreset Constructions for
//! Machine Learning*). This module provides the worker pool those
//! compositions run on:
//!
//! * [`parallel_map`] — order-preserving map over a slice on a scoped
//!   worker pool with atomic work-stealing (an idle worker always takes
//!   the next unclaimed item, so ragged per-item costs balance out).
//! * [`resolve_threads`] / [`available_threads`] — the `--threads`
//!   convention: `0` means "all available cores".
//!
//! Everything is `std::thread::scope`-based — no external crates (the
//! default build is std-only, see DESIGN.md §Substitutions) and no
//! `'static` bounds, so workers borrow the signal directly instead of
//! cloning it.
//!
//! **Determinism.** `parallel_map` returns results in input order, and
//! the higher-level users ([`crate::coreset::SignalCoreset::build_par`],
//! [`crate::signal::PrefixStats::new_par`]) derive their shard plans from
//! the input alone — never from `threads` — so any thread count produces
//! bit-identical output for the same input.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of hardware threads available to this process (≥ 1).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map_or(1, |p| p.get())
}

/// Resolve a `--threads` request: `0` → [`available_threads`], anything
/// else is taken literally.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        available_threads()
    } else {
        threads
    }
}

/// Map `f` over `items` on `threads` scoped workers, returning results in
/// input order. Work distribution is a shared atomic cursor: each worker
/// repeatedly claims the next unprocessed index, so uneven per-item costs
/// (ragged shards, heterogeneous queries) self-balance.
///
/// `threads == 0` uses all available cores; `threads <= 1` (or a 0/1-item
/// input) degenerates to a plain sequential map with no thread spawned,
/// so callers can pass user-supplied values straight through.
///
/// Panics in `f` are propagated (the pool does not swallow worker
/// panics).
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = resolve_threads(threads).min(items.len().max(1));
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut tagged: Vec<(usize, R)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(|| {
                    let mut local: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        local.push((i, f(i, &items[i])));
                    }
                    local
                })
            })
            .collect();
        let mut all = Vec::with_capacity(items.len());
        for h in handles {
            match h.join() {
                Ok(local) => all.extend(local),
                // Rethrow the original payload so the caller sees the
                // worker's actual panic message, not a generic wrapper.
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        all
    });
    tagged.sort_unstable_by_key(|&(i, _)| i);
    tagged.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_zero_is_all_cores() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(resolve_threads(0), available_threads());
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..257).collect();
        let expect: Vec<usize> = items.iter().map(|&x| x * x + 1).collect();
        for threads in [0, 1, 2, 3, 4, 8] {
            let got = parallel_map(&items, threads, |_, &x| x * x + 1);
            assert_eq!(got, expect, "threads {threads}");
        }
    }

    #[test]
    fn parallel_map_passes_index() {
        let items = vec!["a"; 64];
        let got = parallel_map(&items, 4, |i, _| i);
        assert_eq!(got, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_handles_small_inputs() {
        let empty: Vec<u32> = Vec::new();
        assert!(parallel_map(&empty, 4, |_, &x| x).is_empty());
        assert_eq!(parallel_map(&[7u32], 4, |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn parallel_map_balances_ragged_work() {
        // Ragged per-item cost: results must still be exact and ordered.
        let items: Vec<usize> = (0..40).collect();
        let got = parallel_map(&items, 4, |_, &x| {
            let mut acc = 0u64;
            for i in 0..(x * 1000) {
                acc = acc.wrapping_add(i as u64);
            }
            (x, acc)
        });
        for (i, &(x, _)) in got.iter().enumerate() {
            assert_eq!(i, x);
        }
    }
}
