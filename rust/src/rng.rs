//! Deterministic pseudo-random number generation.
//!
//! The offline crate registry has no `rand`, so we ship our own small,
//! well-tested generator: SplitMix64 for seeding and Xoshiro256++ for the
//! stream (public-domain reference algorithms by Blackman & Vigna).
//! Everything in the repo that needs randomness threads one of these
//! through explicitly, which also buys us exact reproducibility of every
//! experiment in EXPERIMENTS.md.

/// SplitMix64: used to expand a single `u64` seed into Xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — fast, high-quality 64-bit PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = sm.next_u64();
        }
        // All-zero state is invalid; SplitMix64 cannot produce 4 zeros from
        // any seed, but be defensive.
        if s == [0, 0, 0, 0] {
            s[0] = 0x1;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` via Lemire's bounded rejection method.
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        assert!(n > 0, "usize(0) is meaningless");
        let n = n as u64;
        // Standard unbiased bounded sampling.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.usize(hi - lo)
    }

    /// Standard normal via Box–Muller (polar form avoided for determinism
    /// across platforms; the trig form uses only `ln`, `sqrt`, `cos`, `sin`).
    pub fn normal(&mut self) -> f64 {
        // Guard against ln(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.len() < 2 {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `count` distinct indices from `[0, n)` (count <= n).
    /// Uses partial Fisher–Yates over an index vector: O(n) memory, O(count)
    /// swaps — fine for the sizes in this repo.
    pub fn sample_indices(&mut self, n: usize, count: usize) -> Vec<usize> {
        assert!(count <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..count {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(count);
        idx
    }

    /// Split off an independent child RNG (for per-worker determinism).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference outputs for seed 1234567 from the public-domain
        // splitmix64.c reference implementation.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism: same seed, same stream.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::new(42);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn usize_bounds_and_coverage() {
        let mut rng = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = rng.usize(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(99);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let x = rng.normal();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(3);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::new(11);
        let s = rng.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 20);
        assert!(t.iter().all(|&i| i < 50));
    }

    #[test]
    fn split_streams_differ() {
        let mut rng = Rng::new(1);
        let mut a = rng.split();
        let mut b = rng.split();
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn uniform_range_respected() {
        let mut rng = Rng::new(5);
        for _ in 0..1000 {
            let x = rng.uniform(-3.0, 7.5);
            assert!((-3.0..7.5).contains(&x));
        }
    }
}
