//! [`EngineConfig`] — the one serializable knob set of the crate.
//!
//! Every layer used to re-encode the same handful of knobs its own way
//! (`CoresetConfig::new(k, eps).theory(beta)`, `PipelineConfig::
//! {with_band_rows, with_workers}`, `StreamingCoreset::with_threads`,
//! per-call `threads` arguments, hand-parsed CLI flags). `EngineConfig`
//! unifies them behind one struct with **one validator**: the CLI
//! (`EngineConfig::from_args`), JSON config files
//! (`EngineConfig::from_json_str`, written by [`EngineConfig::to_json`]
//! through [`crate::json`]), and programmatic construction all funnel
//! through [`EngineConfig::validate`], which returns
//! [`crate::error::Result`] instead of panicking.

use crate::cli::Args;
use crate::coreset::{CoresetConfig, SignalCoreset};
use crate::error::{Context, Error, Result};
use crate::json::Json;
use crate::sample::SampleAlgorithm;
use crate::{bail, ensure};

/// Which kernel backend an [`crate::engine::Engine`] executes on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// The pure-Rust f32 kernels (always available, the default).
    Native,
    /// The cache-blocked auto-vectorizing f32 kernels
    /// ([`crate::runtime::BlockedBackend`]; also routes `PrefixStats`
    /// construction through the blocked fill).
    Blocked,
    /// PJRT execution of the AOT-compiled artifacts (`pjrt` feature).
    Pjrt,
}

impl BackendChoice {
    /// The CLI / JSON spelling.
    pub fn name(self) -> &'static str {
        match self {
            BackendChoice::Native => "native",
            BackendChoice::Blocked => "blocked",
            BackendChoice::Pjrt => "pjrt",
        }
    }

    /// Parse the CLI / JSON spelling.
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "native" => Ok(BackendChoice::Native),
            "blocked" => Ok(BackendChoice::Blocked),
            "pjrt" => Ok(BackendChoice::Pjrt),
            other => Err(Error::msg(format!(
                "unknown backend '{other}' (expected 'native', 'blocked', or 'pjrt')"
            ))),
        }
    }
}

/// Which coreset family [`crate::engine::Engine::compress`] builds.
///
/// `caratheodory` is the paper's deterministic (k, ε)-construction
/// ([`crate::coreset::SignalCoreset`], the default and the only family
/// with the worst-case guarantee); `sensitivity(algorithm, tau)` is the
/// importance-sampling family ([`crate::sample::SensitivityCoreset`])
/// with a fixed draw budget τ and a pluggable scoring algorithm.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoresetFamily {
    /// Deterministic Caratheodory (k, ε)-coreset.
    Caratheodory,
    /// Seeded importance sampling: τ draws scored by `algorithm`.
    Sensitivity { algorithm: SampleAlgorithm, tau: usize },
}

impl CoresetFamily {
    /// The CLI / JSON spelling: `caratheodory` or
    /// `sensitivity(<algorithm>,<tau>)`.
    pub fn render(self) -> String {
        match self {
            CoresetFamily::Caratheodory => "caratheodory".to_string(),
            CoresetFamily::Sensitivity { algorithm, tau } => {
                format!("sensitivity({},{tau})", algorithm.name())
            }
        }
    }

    /// Parse the CLI / JSON spelling (see [`Self::render`]).
    pub fn from_name(name: &str) -> Result<Self> {
        let name = name.trim();
        if name == "caratheodory" {
            return Ok(CoresetFamily::Caratheodory);
        }
        if let Some(inner) = name
            .strip_prefix("sensitivity(")
            .and_then(|rest| rest.strip_suffix(')'))
        {
            let mut parts = inner.splitn(2, ',');
            let algorithm = SampleAlgorithm::from_name(parts.next().unwrap_or("").trim())?;
            let tau_text = parts
                .next()
                .ok_or_else(|| {
                    Error::msg(format!("coreset family '{name}' is missing the tau argument"))
                })?
                .trim();
            let tau: usize = tau_text.parse().map_err(|_| {
                Error::msg(format!("invalid tau '{tau_text}' in coreset family '{name}'"))
            })?;
            return Ok(CoresetFamily::Sensitivity { algorithm, tau });
        }
        Err(Error::msg(format!(
            "unknown coreset family '{name}' (expected 'caratheodory' or 'sensitivity(<unified|lightweight|uniform>,<tau>)')"
        )))
    }
}

/// The JSON field names `EngineConfig` understands — the JSON reader
/// rejects anything else, the same contract each CLI subcommand's
/// [`Args::expect_only`] allowlist enforces for flags. (The spellings
/// differ slightly: JSON uses `_` where the CLI uses `-`, and the
/// CLI's `--dir` is the JSON `artifacts_dir`.)
pub const CONFIG_KEYS: [&str; 14] = [
    "k",
    "eps",
    "beta",
    "threads",
    "band_rows",
    "shard_rows",
    "merge_fanout",
    "reduce_tol",
    "backend",
    "block_size",
    "artifacts_dir",
    "seed",
    "coreset_family",
    // Tolerated sub-object: the static-analysis knobs ride the same
    // config file, read by `sigtree lint` through
    // `analysis::LintConfig::apply_json` (the engine never consumes
    // them — one file can drive both `engine` and `lint` subcommands).
    "lint",
];

/// One serializable configuration for the whole stack: coreset
/// construction (k, ε, the β/theory calibration), execution (threads,
/// shard/band geometry, kernel backend), and reproducibility (seed).
/// Construct with [`EngineConfig::new`] + the `with_*` builders, from
/// CLI flags with [`EngineConfig::from_args`], or from a JSON file with
/// [`EngineConfig::from_json_str`]; hand the result to
/// [`crate::engine::Engine::new`], which validates it.
#[derive(Clone, Debug, PartialEq)]
pub struct EngineConfig {
    /// Tree/segmentation complexity the (k, ε)-guarantee covers.
    pub k: usize,
    /// Target relative error of FITTING-LOSS, in (0, 1).
    pub eps: f64,
    /// `None` → the practical calibration γ = ε/2 (EXPERIMENTS.md
    /// §Calibration); `Some(β)` → the worst-case theory γ = ε²/(βk)
    /// ([`CoresetConfig::theory`]).
    pub beta: Option<f64>,
    /// Worker threads (`0` = all available cores). A pure performance
    /// knob: every thread count produces bit-identical coresets.
    pub threads: usize,
    /// Rows per streamed band ([`crate::engine::Engine::pipeline`] /
    /// [`crate::engine::Engine::stream`]).
    pub band_rows: usize,
    /// Row-shard geometry of the sharded builder; the default
    /// [`SignalCoreset::SHARD_ROWS`] keeps the engine bit-identical to
    /// the classic `construct_sharded` plan.
    pub shard_rows: usize,
    /// Internal-node fanout of the engine's
    /// [`crate::coreset::merge_tree::MergeTree`] (≥ 2). A pure
    /// memoization-shape knob: the composed coreset is bit-identical
    /// for every value; larger fanouts trade shallower trees for wider
    /// re-merge paths on incremental updates.
    pub merge_fanout: usize,
    /// Root reduce tolerance override for the merge tree; `None` → the
    /// standard γ²σ of the merged parts (required for bit-identity with
    /// the classic sharded build). A real content knob: smaller values
    /// compact less, larger values compact more aggressively.
    pub reduce_tol: Option<f64>,
    /// Kernel backend for the runtime layer.
    pub backend: BackendChoice,
    /// Column-block width of the blocked backend / blocked stats fill
    /// (≥ 1). A pure performance knob: every block size produces
    /// bit-identical f64 statistics and bit-identical blocked-backend
    /// prefix images (DESIGN.md §Kernels). Ignored by the other
    /// backends.
    pub block_size: usize,
    /// Artifact directory override for the PJRT backend (`None` →
    /// `SIGTREE_ARTIFACTS` / `./artifacts`).
    pub artifacts_dir: Option<String>,
    /// Base seed for signal generation / audits driven by this engine
    /// (and the sensitivity family's draws).
    pub seed: u64,
    /// Which coreset family [`crate::engine::Engine::compress`] builds;
    /// the deterministic Caratheodory default keeps every existing
    /// surface bit-identical.
    pub coreset_family: CoresetFamily,
}

impl EngineConfig {
    /// Defaults for everything except the two mandatory knobs.
    pub fn new(k: usize, eps: f64) -> Self {
        Self {
            k,
            eps,
            beta: None,
            threads: 0,
            band_rows: 128,
            shard_rows: SignalCoreset::SHARD_ROWS,
            merge_fanout: 2,
            reduce_tol: None,
            backend: BackendChoice::Native,
            block_size: crate::runtime::blocked::BLOCK,
            artifacts_dir: None,
            seed: 7,
            coreset_family: CoresetFamily::Caratheodory,
        }
    }

    pub fn with_beta(mut self, beta: f64) -> Self {
        self.beta = Some(beta);
        self
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn with_band_rows(mut self, band_rows: usize) -> Self {
        self.band_rows = band_rows;
        self
    }

    pub fn with_shard_rows(mut self, shard_rows: usize) -> Self {
        self.shard_rows = shard_rows;
        self
    }

    pub fn with_merge_fanout(mut self, fanout: usize) -> Self {
        self.merge_fanout = fanout;
        self
    }

    pub fn with_reduce_tol(mut self, tol: f64) -> Self {
        self.reduce_tol = Some(tol);
        self
    }

    pub fn with_backend(mut self, backend: BackendChoice) -> Self {
        self.backend = backend;
        self
    }

    pub fn with_block_size(mut self, block_size: usize) -> Self {
        self.block_size = block_size;
        self
    }

    pub fn with_artifacts_dir(mut self, dir: impl Into<String>) -> Self {
        self.artifacts_dir = Some(dir.into());
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_coreset_family(mut self, family: CoresetFamily) -> Self {
        self.coreset_family = family;
        self
    }

    /// The one validator every construction surface funnels through.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.k >= 1, "k must be >= 1 (got {})", self.k);
        ensure!(
            self.eps > 0.0 && self.eps < 1.0,
            "eps must be in (0, 1) exclusive (got {})",
            self.eps
        );
        if let Some(beta) = self.beta {
            ensure!(
                beta.is_finite() && beta > 0.0,
                "beta must be a positive finite number (got {beta})"
            );
        }
        ensure!(
            self.band_rows >= 1,
            "band_rows must be >= 1 (got {})",
            self.band_rows
        );
        ensure!(
            self.shard_rows >= 1,
            "shard_rows must be >= 1 (got {})",
            self.shard_rows
        );
        ensure!(
            self.merge_fanout >= 2,
            "merge_fanout must be >= 2 (got {})",
            self.merge_fanout
        );
        if let Some(tol) = self.reduce_tol {
            ensure!(
                tol.is_finite() && tol >= 0.0,
                "reduce_tol must be a non-negative finite number (got {tol})"
            );
        }
        ensure!(
            self.block_size >= 1,
            "block_size must be >= 1 (got {})",
            self.block_size
        );
        if let CoresetFamily::Sensitivity { tau, .. } = self.coreset_family {
            ensure!(tau >= 1, "sensitivity tau must be >= 1 (got {tau})");
        }
        Ok(())
    }

    /// The coreset-layer view of this configuration. Call after
    /// [`Self::validate`] ([`crate::engine::Engine::new`] does): the
    /// field invariants this relies on are exactly the validated ones.
    pub fn coreset_config(&self) -> CoresetConfig {
        let base = CoresetConfig { k: self.k, eps: self.eps, gamma: None, sigma: None };
        match self.beta {
            None => base,
            Some(beta) => base.theory(beta),
        }
    }

    /// Serialize through [`crate::json`] — [`Self::from_json_str`]
    /// parses this exact shape back (the seed rides as a hex string,
    /// like every seed the repo writes: a u64 does not survive a JSON
    /// double above 2⁵³).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("k", Json::int(self.k)),
            ("eps", Json::num(self.eps)),
            ("beta", self.beta.map_or(Json::Null, Json::num)),
            ("threads", Json::int(self.threads)),
            ("band_rows", Json::int(self.band_rows)),
            ("shard_rows", Json::int(self.shard_rows)),
            ("merge_fanout", Json::int(self.merge_fanout)),
            ("reduce_tol", self.reduce_tol.map_or(Json::Null, Json::num)),
            ("backend", Json::str(self.backend.name())),
            ("block_size", Json::int(self.block_size)),
            (
                "artifacts_dir",
                self.artifacts_dir.as_deref().map_or(Json::Null, Json::str),
            ),
            ("seed", Json::str(format!("{:#x}", self.seed))),
            ("coreset_family", Json::str(self.coreset_family.render())),
        ])
    }

    /// Parse a self-contained JSON config document (see
    /// [`Self::to_json`]): `k`/`eps` are mandatory, missing optional
    /// keys keep the `EngineConfig::new` defaults, unknown keys are
    /// rejected with the valid set — the same contract the CLI's
    /// unknown-flag rejection enforces. The result is validated.
    pub fn from_json(doc: &Json) -> Result<Self> {
        ensure!(doc.get("k").is_some(), "engine config is missing 'k'");
        ensure!(doc.get("eps").is_some(), "engine config is missing 'eps'");
        // The placeholder k/eps are overwritten by the mandatory keys.
        Self::apply_json(doc, EngineConfig::new(1, 0.5))
    }

    /// Layer a (possibly partial) JSON config onto `base`: only the
    /// keys present in `doc` override; everything else keeps `base`'s
    /// value. This is what keeps per-subcommand defaults intact under
    /// `--config` — a file of just `{"k": 64, "eps": 0.2}` must not
    /// silently reset the subcommand's thread default to all-cores.
    /// Unknown keys are rejected; the merged result is validated.
    pub fn apply_json(doc: &Json, base: EngineConfig) -> Result<Self> {
        let Json::Obj(pairs) = doc else {
            bail!("engine config must be a JSON object");
        };
        for (key, _) in pairs {
            if !CONFIG_KEYS.contains(&key.as_str()) {
                bail!(
                    "unknown engine config key '{key}' (valid keys: {})",
                    CONFIG_KEYS.join(", ")
                );
            }
        }
        // The 'lint' section belongs to `crate::analysis::LintConfig`;
        // the engine only checks its shape so a malformed file still
        // fails loudly no matter which subcommand reads it first.
        if let Some(section) = doc.get("lint") {
            ensure!(
                matches!(section, Json::Obj(_)),
                "'lint' must be an object (see sigtree::analysis::LintConfig)"
            );
        }
        let usize_field = |key: &str, default: usize| -> Result<usize> {
            match doc.get(key) {
                None => Ok(default),
                Some(v) => v.as_usize().ok_or_else(|| {
                    Error::msg(format!("'{key}' must be a non-negative integer"))
                }),
            }
        };
        let mut config = base;
        config.k = usize_field("k", config.k)?;
        if let Some(v) = doc.get("eps") {
            config.eps = v
                .as_f64()
                .ok_or_else(|| Error::msg("'eps' must be a number"))?;
        }
        config.beta = match doc.get("beta") {
            None => config.beta,
            Some(Json::Null) => None,
            Some(v) => Some(
                v.as_f64()
                    .ok_or_else(|| Error::msg("'beta' must be a number or null"))?,
            ),
        };
        config.threads = usize_field("threads", config.threads)?;
        config.band_rows = usize_field("band_rows", config.band_rows)?;
        config.shard_rows = usize_field("shard_rows", config.shard_rows)?;
        config.merge_fanout = usize_field("merge_fanout", config.merge_fanout)?;
        config.reduce_tol = match doc.get("reduce_tol") {
            None => config.reduce_tol,
            Some(Json::Null) => None,
            Some(v) => Some(
                v.as_f64()
                    .ok_or_else(|| Error::msg("'reduce_tol' must be a number or null"))?,
            ),
        };
        if let Some(v) = doc.get("backend") {
            let name = v
                .as_str()
                .ok_or_else(|| Error::msg("'backend' must be a string"))?;
            config.backend = BackendChoice::from_name(name)?;
        }
        config.block_size = usize_field("block_size", config.block_size)?;
        config.artifacts_dir = match doc.get("artifacts_dir") {
            None => config.artifacts_dir,
            Some(Json::Null) => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or_else(|| Error::msg("'artifacts_dir' must be a string or null"))?
                    .to_string(),
            ),
        };
        if let Some(v) = doc.get("seed") {
            config.seed = parse_seed(v)?;
        }
        if let Some(v) = doc.get("coreset_family") {
            let name = v
                .as_str()
                .ok_or_else(|| Error::msg("'coreset_family' must be a string"))?;
            config.coreset_family = CoresetFamily::from_name(name)?;
        }
        config.validate()?;
        Ok(config)
    }

    /// [`Self::from_json`] on raw text.
    pub fn from_json_str(text: &str) -> Result<Self> {
        let doc = Json::parse(text).map_err(Error::msg).context("parsing engine config")?;
        Self::from_json(&doc)
    }

    /// Build from parsed CLI arguments, layered as
    /// **flags > `--config` file > `defaults`** (each subcommand passes
    /// its historical defaults). The file overrides only the keys it
    /// contains ([`Self::apply_json`]), so a partial file — even just
    /// `{"threads": 4}` — layers onto the defaults instead of resetting
    /// them. This is the single knob parser every subcommand routes
    /// through, so the CLI and JSON configs share one validator; pair
    /// it with [`Args::expect_only`] so unknown flags are rejected
    /// rather than silently ignored.
    pub fn from_args(args: &Args, defaults: EngineConfig) -> Result<Self> {
        let mut base = defaults;
        if let Some(path) = args.get("config") {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading engine config {path}"))?;
            let doc = Json::parse(&text)
                .map_err(Error::msg)
                .with_context(|| format!("parsing engine config {path}"))?;
            base = Self::apply_json(&doc, base).with_context(|| format!("in {path}"))?;
        }
        let config = EngineConfig {
            k: args.get_usize("k", base.k)?,
            eps: args.get_f64("eps", base.eps)?,
            beta: match args.get("beta") {
                None => base.beta,
                Some(_) => Some(args.get_f64("beta", 0.0)?),
            },
            threads: args.get_threads(base.threads)?,
            band_rows: args.get_usize("band-rows", base.band_rows)?,
            shard_rows: args.get_usize("shard-rows", base.shard_rows)?,
            merge_fanout: args.get_usize("merge-fanout", base.merge_fanout)?,
            reduce_tol: match args.get("reduce-tol") {
                None => base.reduce_tol,
                Some(_) => Some(args.get_f64("reduce-tol", 0.0)?),
            },
            backend: match args.get("backend") {
                None => base.backend,
                Some(name) => BackendChoice::from_name(name)?,
            },
            block_size: args.get_usize("block-size", base.block_size)?,
            artifacts_dir: args.get("dir").map(str::to_string).or(base.artifacts_dir),
            seed: args.get_u64("seed", base.seed)?,
            coreset_family: match args.get("coreset-family") {
                None => base.coreset_family,
                Some(name) => CoresetFamily::from_name(name)?,
            },
        };
        config.validate()?;
        Ok(config)
    }
}

/// Seeds serialize as `{:#x}` hex strings (the repo-wide convention,
/// [`crate::cli::parse_u64`]); accept decimal strings and exact-integer
/// numbers too, so hand-written configs stay forgiving.
fn parse_seed(v: &Json) -> Result<u64> {
    if let Some(s) = v.as_str() {
        return crate::cli::parse_u64(s)
            .ok_or_else(|| Error::msg(format!("invalid seed '{s}'")));
    }
    v.as_usize()
        .map(|x| x as u64)
        .ok_or_else(|| Error::msg("'seed' must be a hex string or non-negative integer"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn defaults_are_valid_and_json_round_trips() {
        let config = EngineConfig::new(8, 0.25)
            .with_beta(2.0)
            .with_threads(3)
            .with_band_rows(96)
            .with_merge_fanout(4)
            .with_reduce_tol(0.125)
            .with_seed(0x9e37_79b9_7f4a_7c15);
        config.validate().unwrap();
        let text = config.to_json().render();
        let back = EngineConfig::from_json_str(&text).unwrap();
        assert_eq!(back, config);
    }

    #[test]
    fn validation_rejects_bad_knobs() {
        assert!(EngineConfig::new(0, 0.3).validate().is_err());
        assert!(EngineConfig::new(4, 0.0).validate().is_err());
        assert!(EngineConfig::new(4, 1.0).validate().is_err());
        assert!(EngineConfig::new(4, -0.2).validate().is_err());
        assert!(EngineConfig::new(4, 1.5).validate().is_err());
        assert!(EngineConfig::new(4, 0.3).with_beta(0.0).validate().is_err());
        assert!(EngineConfig::new(4, 0.3).with_band_rows(0).validate().is_err());
        assert!(EngineConfig::new(4, 0.3).with_shard_rows(0).validate().is_err());
        assert!(EngineConfig::new(4, 0.3).with_merge_fanout(1).validate().is_err());
        assert!(EngineConfig::new(4, 0.3).with_reduce_tol(f64::NAN).validate().is_err());
        assert!(EngineConfig::new(4, 0.3).with_reduce_tol(-1.0).validate().is_err());
        EngineConfig::new(4, 0.3).with_merge_fanout(2).validate().unwrap();
        EngineConfig::new(4, 0.3).with_reduce_tol(0.0).validate().unwrap();
        EngineConfig::new(4, 0.3).with_threads(0).validate().unwrap();
    }

    #[test]
    fn from_json_rejects_unknown_keys_and_missing_mandatory() {
        let err = EngineConfig::from_json_str("{\"k\": 4, \"eps\": 0.3, \"theads\": 2}")
            .unwrap_err()
            .to_string();
        assert!(err.contains("theads"), "{err}");
        assert!(err.contains("threads"), "must list valid keys: {err}");
        assert!(EngineConfig::from_json_str("{\"eps\": 0.3}").is_err());
        assert!(EngineConfig::from_json_str("{\"k\": 4}").is_err());
        assert!(EngineConfig::from_json_str("[1, 2]").is_err());
        assert!(EngineConfig::from_json_str("{\"k\": 4, \"eps\": 2.0}").is_err());
    }

    #[test]
    fn lint_section_is_tolerated_but_shape_checked() {
        // One config file drives both the engine and `sigtree lint`:
        // the engine skips the 'lint' sub-object but still rejects a
        // malformed one.
        let cfg =
            EngineConfig::from_json_str("{\"k\": 4, \"eps\": 0.3, \"lint\": {\"disable\": []}}")
                .expect("lint sub-object is tolerated");
        assert_eq!(cfg.k, 4);
        assert!(EngineConfig::from_json_str("{\"k\": 4, \"eps\": 0.3, \"lint\": 7}").is_err());
    }

    #[test]
    fn from_args_layers_flags_over_defaults() {
        let defaults = EngineConfig::new(64, 0.2);
        let config = EngineConfig::from_args(&argv("coreset --k 5 --eps 0.4 --threads 2"), defaults)
            .unwrap();
        assert_eq!(config.k, 5);
        assert!((config.eps - 0.4).abs() < 1e-12);
        assert_eq!(config.threads, 2);
        assert_eq!(config.band_rows, 128);
        assert_eq!(config.merge_fanout, 2);
        assert_eq!(config.reduce_tol, None);
        assert_eq!(config.backend, BackendChoice::Native);
        // The tree knobs parse from flags through the same layering.
        let defaults = EngineConfig::new(64, 0.2);
        let config = EngineConfig::from_args(
            &argv("coreset --merge-fanout 4 --reduce-tol 0.5"),
            defaults,
        )
        .unwrap();
        assert_eq!(config.merge_fanout, 4);
        assert_eq!(config.reduce_tol, Some(0.5));
        let defaults = EngineConfig::new(64, 0.2);
        assert!(EngineConfig::from_args(&argv("coreset --merge-fanout 1"), defaults).is_err());
        // Bad values hit the same validator as JSON.
        let defaults = EngineConfig::new(64, 0.2);
        assert!(EngineConfig::from_args(&argv("coreset --eps 1.5"), defaults).is_err());
        let defaults = EngineConfig::new(64, 0.2);
        assert!(EngineConfig::from_args(&argv("coreset --k 0"), defaults).is_err());
        let defaults = EngineConfig::new(64, 0.2);
        assert!(EngineConfig::from_args(&argv("coreset --backend cuda"), defaults).is_err());
    }

    #[test]
    fn partial_config_file_layers_onto_subcommand_defaults() {
        // A file that omits optional keys must NOT reset them to the
        // global defaults — cmd_pipeline's threads=2 (and coreset's
        // threads=1) have to survive `--config {"k":…,"eps":…}`.
        let dir = std::env::temp_dir();
        let path = dir.join("sigtree_engine_partial_config_test.json");
        std::fs::write(&path, "{\"k\": 9, \"eps\": 0.35}").unwrap();
        let cli = format!("pipeline --config {}", path.display());
        let defaults = EngineConfig::new(64, 0.2).with_threads(2).with_band_rows(96);
        let config = EngineConfig::from_args(&argv(&cli), defaults).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(config.k, 9);
        assert!((config.eps - 0.35).abs() < 1e-12);
        assert_eq!(config.threads, 2, "absent file key keeps the subcommand default");
        assert_eq!(config.band_rows, 96, "absent file key keeps the subcommand default");
        // And a flags-only partial layering works the same way through
        // apply_json directly.
        let doc = crate::json::Json::parse("{\"threads\": 4}").unwrap();
        let merged = EngineConfig::apply_json(&doc, EngineConfig::new(5, 0.4)).unwrap();
        assert_eq!(merged.threads, 4);
        assert_eq!(merged.k, 5);
    }

    #[test]
    fn from_args_reads_config_file_then_overrides() {
        let dir = std::env::temp_dir();
        let path = dir.join("sigtree_engine_config_test.json");
        let on_disk = EngineConfig::new(10, 0.5).with_threads(4).with_seed(99);
        std::fs::write(&path, on_disk.to_json().render()).unwrap();
        let cli = format!("coreset --config {} --eps 0.25", path.display());
        let config = EngineConfig::from_args(&argv(&cli), EngineConfig::new(64, 0.2)).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(config.k, 10, "file value survives");
        assert!((config.eps - 0.25).abs() < 1e-12, "flag overrides file");
        assert_eq!(config.threads, 4);
        assert_eq!(config.seed, 99);
    }

    #[test]
    fn backend_names_round_trip() {
        for choice in [BackendChoice::Native, BackendChoice::Blocked, BackendChoice::Pjrt] {
            assert_eq!(BackendChoice::from_name(choice.name()).unwrap(), choice);
        }
        let err = BackendChoice::from_name("cuda").unwrap_err().to_string();
        assert!(err.contains("blocked"), "error must list all spellings: {err}");
    }

    #[test]
    fn block_size_knob_parses_and_validates() {
        let defaults = EngineConfig::new(64, 0.2);
        assert_eq!(defaults.block_size, crate::runtime::blocked::BLOCK);
        let config = EngineConfig::from_args(
            &argv("runtime --backend blocked --block-size 37"),
            EngineConfig::new(64, 0.2),
        )
        .unwrap();
        assert_eq!(config.backend, BackendChoice::Blocked);
        assert_eq!(config.block_size, 37);
        // JSON round-trip carries the knob.
        let back = EngineConfig::from_json_str(&config.to_json().render()).unwrap();
        assert_eq!(back.block_size, 37);
        assert_eq!(back.backend, BackendChoice::Blocked);
        // Zero is rejected by the shared validator, from both surfaces.
        assert!(EngineConfig::new(4, 0.3).with_block_size(0).validate().is_err());
        let defaults = EngineConfig::new(64, 0.2);
        assert!(EngineConfig::from_args(&argv("runtime --block-size 0"), defaults).is_err());
    }

    #[test]
    fn coreset_family_knob_parses_round_trips_and_validates() {
        // Default stays deterministic Caratheodory.
        assert_eq!(EngineConfig::new(4, 0.3).coreset_family, CoresetFamily::Caratheodory);
        // Spelling round-trips for every algorithm.
        for algorithm in SampleAlgorithm::ALL {
            let family = CoresetFamily::Sensitivity { algorithm, tau: 256 };
            assert_eq!(CoresetFamily::from_name(&family.render()).unwrap(), family);
        }
        assert_eq!(
            CoresetFamily::from_name("caratheodory").unwrap(),
            CoresetFamily::Caratheodory
        );
        // Whitespace-tolerant.
        assert_eq!(
            CoresetFamily::from_name("sensitivity( unified , 64 )").unwrap(),
            CoresetFamily::Sensitivity { algorithm: SampleAlgorithm::Unified, tau: 64 }
        );
        // Bad spellings are rejected with the valid shapes listed.
        let err = CoresetFamily::from_name("random").unwrap_err().to_string();
        assert!(err.contains("caratheodory"), "{err}");
        assert!(CoresetFamily::from_name("sensitivity(unified)").is_err());
        assert!(CoresetFamily::from_name("sensitivity(magic,5)").is_err());
        assert!(CoresetFamily::from_name("sensitivity(unified,five)").is_err());
        // JSON round-trip through the one serializer.
        let config = EngineConfig::new(8, 0.25).with_coreset_family(CoresetFamily::Sensitivity {
            algorithm: SampleAlgorithm::Lightweight,
            tau: 512,
        });
        let back = EngineConfig::from_json_str(&config.to_json().render()).unwrap();
        assert_eq!(back, config);
        // CLI flag routes through the same parser + validator.
        let parsed = EngineConfig::from_args(
            &argv("coreset --coreset-family sensitivity(uniform,32)"),
            EngineConfig::new(64, 0.2),
        )
        .unwrap();
        assert_eq!(
            parsed.coreset_family,
            CoresetFamily::Sensitivity { algorithm: SampleAlgorithm::Uniform, tau: 32 }
        );
        let defaults = EngineConfig::new(64, 0.2);
        assert!(EngineConfig::from_args(&argv("coreset --coreset-family bogus"), defaults).is_err());
        // τ = 0 dies in the shared validator from every surface.
        assert!(EngineConfig::new(4, 0.3)
            .with_coreset_family(CoresetFamily::Sensitivity {
                algorithm: SampleAlgorithm::Unified,
                tau: 0,
            })
            .validate()
            .is_err());
        assert!(EngineConfig::from_json_str(
            "{\"k\":4,\"eps\":0.3,\"coreset_family\":\"sensitivity(unified,0)\"}"
        )
        .is_err());
    }

    #[test]
    fn seed_forms_are_accepted() {
        let hex = EngineConfig::from_json_str("{\"k\":2,\"eps\":0.3,\"seed\":\"0xff\"}").unwrap();
        assert_eq!(hex.seed, 255);
        let dec = EngineConfig::from_json_str("{\"k\":2,\"eps\":0.3,\"seed\":\"12\"}").unwrap();
        assert_eq!(dec.seed, 12);
        let num = EngineConfig::from_json_str("{\"k\":2,\"eps\":0.3,\"seed\":12}").unwrap();
        assert_eq!(num.seed, 12);
        assert!(EngineConfig::from_json_str("{\"k\":2,\"eps\":0.3,\"seed\":\"zz\"}").is_err());
    }
}
