//! `sigtree::engine` — the one front door to the crate.
//!
//! The paper's value proposition is *build the (k, ε)-coreset once,
//! then answer every tree query cheaply* (Theorem 8), and coresets only
//! pay off in practice behind a reusable pipeline object, not one-shot
//! helper calls (Bachem–Lucic–Krause, *Practical Coreset Constructions
//! for Machine Learning*). [`Engine`] is that object: a long-lived
//! session constructed from one validated, serializable
//! [`EngineConfig`], owning
//!
//! * the **worker pool** ([`crate::par::WorkerPool`]) — spawned once,
//!   reused by every build, batch-evaluation, stream, and audit this
//!   engine runs (no per-call thread spinup on the serving hot path;
//!   the one exception is [`Engine::pipeline`], whose banded workers
//!   are dedicated scoped threads around a bounded backpressure queue
//!   by design — only its statistics build runs on the pool);
//! * the **kernel backend** ([`crate::runtime::KernelBackend`]) chosen
//!   by the config (`native` / `pjrt`);
//! * per attached signal, the **shared [`PrefixStats`]**
//!   ([`Engine::session`]) every region build and exact-loss query
//!   answers from.
//!
//! ```
//! use sigtree::engine::{Engine, EngineConfig};
//! use sigtree::prelude::*;
//!
//! let signal = Signal::from_fn(160, 48, |r, c| ((r + 2 * c) % 7) as f64);
//! let engine = Engine::new(EngineConfig::new(4, 0.3).with_threads(2)).unwrap();
//!
//! // Build once (sharded, on the engine's pool)…
//! let coreset = engine.coreset(&signal);
//! let cells = signal.len() as f64;
//! assert!((coreset.total_weight() - cells).abs() < 1e-6 * cells);
//!
//! // …then answer every tree query cheaply, pool reused per batch.
//! let session = engine.session(&signal);
//! let queries: Vec<KSegmentation> =
//!     vec![KSegmentation::constant(signal.bounds(), 1.0)];
//! let approx = engine.fitting_loss(&coreset, &queries);
//! let exact = session.exact_loss(&queries[0]);
//! assert!((approx[0] - exact).abs() <= 1e-6 * (1.0 + exact));
//! ```
//!
//! Layering (DESIGN.md §Engine & API layering):
//! `EngineConfig` → `Engine` → {[`Engine::coreset`],
//! [`Engine::coreset_region`], [`Engine::stream`], [`Engine::pipeline`],
//! [`Engine::fitting_loss`], [`Engine::optimal_tree`],
//! [`Engine::audit`]} — all driving the low-level
//! `SignalCoreset::construct*` kernels. The historical
//! `SignalCoreset::build*` entry points are `#[deprecated]` shims.

mod config;

pub use config::{BackendChoice, EngineConfig, CONFIG_KEYS};

use crate::audit::{self, AuditConfig, AuditReport, CoresetOracle};
use crate::coreset::merge_reduce::StreamingCoreset;
use crate::coreset::{fitting_loss, SignalCoreset};
use crate::error::Result;
use crate::par::{Exec, WorkerPool};
use crate::pipeline::{self, PipelineConfig, PipelineMetrics};
use crate::runtime::{backend_from_name, KernelBackend};
use crate::segmentation::dp2d::TreeDP;
use crate::segmentation::KSegmentation;
use crate::signal::{PrefixStats, Rect, SignalSource};

/// A long-lived build/query/audit session — see the module docs.
///
/// Construction ([`Engine::new`]) validates the config, spawns the
/// worker pool, and instantiates the kernel backend, so every
/// misconfiguration surfaces as one early [`crate::error::Error`]
/// instead of a panic deep in a build.
pub struct Engine {
    config: EngineConfig,
    /// `config.threads` resolved (`0` → all cores).
    threads: usize,
    pool: WorkerPool,
    backend: Box<dyn KernelBackend>,
}

impl Engine {
    /// Validate `config` and bring the session up (pool + backend).
    pub fn new(config: EngineConfig) -> Result<Engine> {
        config.validate()?;
        let backend = backend_from_name(
            config.backend.name(),
            config.artifacts_dir.as_ref().map(std::path::Path::new),
        )?;
        let pool = WorkerPool::new(config.threads);
        let threads = pool.threads();
        Ok(Engine { config, threads, pool, backend })
    }

    /// The validated configuration this engine runs.
    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// Resolved worker count (≥ 1; `threads: 0` resolved to all cores).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The kernel backend the runtime layer executes on.
    pub fn backend(&self) -> &dyn KernelBackend {
        self.backend.as_ref()
    }

    /// This engine's executor — the long-lived pool, for the low-level
    /// `construct*` / `run_audit_exec` entry points.
    pub fn exec(&self) -> Exec<'_> {
        Exec::Pool(&self.pool)
    }

    /// Shared prefix statistics of `signal`, built on the engine pool
    /// (thread-invariant: bit-identical to [`PrefixStats::new_par`] at
    /// any thread count).
    pub fn stats<S: SignalSource>(&self, signal: &S) -> PrefixStats {
        PrefixStats::new_par_exec(signal, self.exec())
    }

    /// Build the (k, ε)-coreset of `signal` — the sharded construction
    /// on the engine pool, bit-identical to the classic
    /// `SignalCoreset::construct_sharded` (née `build_par`) at every
    /// thread count.
    pub fn coreset<S: SignalSource>(&self, signal: &S) -> SignalCoreset {
        SignalCoreset::construct_sharded_exec(
            signal,
            self.config.coreset_config(),
            self.config.shard_rows,
            self.exec(),
        )
    }

    /// Build the partial coreset of a sub-rectangle of `signal` (blocks
    /// stay in `signal`'s frame — the merge-and-reduce shard
    /// primitive). Builds the shared statistics for this one call; use
    /// [`Engine::session`] to reuse them across several regions.
    pub fn coreset_region<S: SignalSource>(&self, signal: &S, region: Rect) -> SignalCoreset {
        self.session(signal).coreset_region(region)
    }

    /// Attach a signal: builds the shared [`PrefixStats`] once (on the
    /// pool) and returns the session handle every per-signal operation
    /// reuses it through. The borrow pins the signal for the session's
    /// lifetime, so the statistics can never go stale.
    pub fn session<'a, S: SignalSource>(&'a self, signal: &'a S) -> EngineSession<'a, S> {
        EngineSession { engine: self, signal, stats: self.stats(signal) }
    }

    /// The band-push handle for streaming ingestion: feed row-bands of
    /// width `cols` as they arrive ([`StreamingCoreset::push_band`]),
    /// then `finish()`. Bands build through the sharded builder on this
    /// engine's pool (no per-band thread spinup) with the config's
    /// shard geometry — the streamed content is identical for every
    /// thread count and executor, and agrees with [`Engine::coreset`]'s
    /// geometry for the same config.
    pub fn stream(&self, cols: usize) -> StreamingCoreset<'_> {
        StreamingCoreset::new(cols, self.config.coreset_config())
            .with_exec(self.exec())
            .with_shard_rows(self.config.shard_rows)
    }

    /// Run the banded pipeline (source → bounded queue → workers →
    /// reducer, with backpressure and metrics) over an in-memory
    /// signal, using the engine's band geometry and worker count and a
    /// shared statistics object built on the pool. The banded workers
    /// themselves are per-call scoped threads (the bounded-queue
    /// backpressure architecture), not pool workers — for repeated
    /// low-latency builds prefer [`Engine::coreset`], which runs
    /// entirely on the parked pool.
    pub fn pipeline<S: SignalSource>(&self, signal: &S) -> (SignalCoreset, PipelineMetrics) {
        let stats = self.stats(signal);
        let config = PipelineConfig::new(self.config.coreset_config())
            .with_band_rows(self.config.band_rows)
            .with_workers(self.threads);
        pipeline::run_with_stats(signal, &stats, config)
    }

    /// Batch FITTING-LOSS on the engine pool: identical results to
    /// [`SignalCoreset::fitting_loss_batch`] (query order, every
    /// thread count), but repeated batches reuse one set of parked
    /// workers instead of spawning threads per call — the serving
    /// hot path (`bench_runtime`'s engine-reuse rows measure it).
    pub fn fitting_loss(&self, coreset: &SignalCoreset, queries: &[KSegmentation]) -> Vec<f64> {
        self.pool.map(queries, |_, s| fitting_loss::fitting_loss(coreset, s))
    }

    /// Exact optimal k-tree of `signal` by the guillotine DP
    /// ([`TreeDP`]) — feasible for small instances (≲ 32×32); the
    /// serving-scale variant is [`Engine::optimal_tree_of_coreset`].
    /// Returns the tree and its loss.
    pub fn optimal_tree<S: SignalSource>(&self, signal: &S, k: usize) -> (KSegmentation, f64) {
        self.session(signal).optimal_tree(k)
    }

    /// The paper's headline pipeline, "run the expensive solver on the
    /// coreset": the exact minimizer of FITTING-LOSS over guillotine
    /// k-trees, via the smoothed-density oracle
    /// ([`CoresetOracle`]). Returns the tree and its FITTING-LOSS.
    pub fn optimal_tree_of_coreset(
        &self,
        coreset: &SignalCoreset,
        k: usize,
    ) -> (KSegmentation, f64) {
        let oracle = CoresetOracle::new(coreset);
        let bounds = Rect::new(0, coreset.rows() - 1, 0, coreset.cols() - 1);
        let mut dp = TreeDP::new(&oracle);
        let loss = dp.opt(bounds, k);
        (dp.solve(bounds, k), loss)
    }

    /// Run the empirical ε-guarantee audit for this engine's (k, ε,
    /// seed) on the engine pool. The evidence trail is bit-identical to
    /// [`audit::run_audit`] with the same knobs at any thread count.
    pub fn audit(&self, cases: usize, transfer_instances: usize) -> AuditReport {
        let config = AuditConfig::new(self.config.k, self.config.eps)
            .with_cases(cases)
            .with_seed(self.config.seed)
            .with_threads(self.threads)
            .with_transfer_instances(transfer_instances);
        audit::run_audit_exec(&config, self.exec())
    }
}

/// A signal attached to an [`Engine`]: owns the shared [`PrefixStats`]
/// and reuses it (and the engine pool) across builds, region builds,
/// exact-loss queries, and DP solves. Created by [`Engine::session`].
pub struct EngineSession<'a, S: SignalSource> {
    engine: &'a Engine,
    signal: &'a S,
    stats: PrefixStats,
}

impl<S: SignalSource> EngineSession<'_, S> {
    /// The engine this session runs on.
    pub fn engine(&self) -> &Engine {
        self.engine
    }

    /// The attached signal.
    pub fn signal(&self) -> &S {
        self.signal
    }

    /// The shared statistics (one object for every query this session
    /// answers).
    pub fn stats(&self) -> &PrefixStats {
        &self.stats
    }

    /// The (k, ε)-coreset of the attached signal — same bits as
    /// [`Engine::coreset`], but reusing this session's statistics
    /// (short signals take the same sequential fallback, so the
    /// equality is exact).
    pub fn coreset(&self) -> SignalCoreset {
        SignalCoreset::construct_sharded_with_stats(
            self.signal,
            &self.stats,
            self.engine.config.coreset_config(),
            self.engine.config.shard_rows,
            self.engine.exec(),
        )
    }

    /// Partial coreset of `region` (signal-frame blocks; the shard
    /// primitive), against the session's shared statistics.
    pub fn coreset_region(&self, region: Rect) -> SignalCoreset {
        SignalCoreset::construct_in(
            self.signal,
            &self.stats,
            region,
            self.engine.config.coreset_config(),
        )
    }

    /// Exact loss ℓ(D, s) from the shared statistics (the ground truth
    /// FITTING-LOSS approximates).
    pub fn exact_loss(&self, s: &KSegmentation) -> f64 {
        s.loss(&self.stats)
    }

    /// Refit a segmentation's piece values to the attached signal's
    /// per-piece means.
    pub fn refit(&self, s: &mut KSegmentation) {
        s.refit_values(&self.stats);
    }

    /// Batch FITTING-LOSS on the engine pool ([`Engine::fitting_loss`]).
    pub fn fitting_loss(&self, coreset: &SignalCoreset, queries: &[KSegmentation]) -> Vec<f64> {
        self.engine.fitting_loss(coreset, queries)
    }

    /// Exact optimal k-tree of the attached signal (guillotine DP on
    /// the shared statistics). Returns the tree and its loss.
    pub fn optimal_tree(&self, k: usize) -> (KSegmentation, f64) {
        let bounds = self.stats.bounds();
        let mut dp = TreeDP::new(&self.stats);
        let loss = dp.opt(bounds, k);
        (dp.solve(bounds, k), loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coreset::{Coreset, CoresetConfig};
    use crate::rng::Rng;
    use crate::segmentation::random_segmentation;
    use crate::signal::{generate, Signal};

    fn assert_same_coreset(a: &SignalCoreset, b: &SignalCoreset, label: &str) {
        assert_eq!(a.blocks.len(), b.blocks.len(), "{label}: block count");
        for (x, y) in a.blocks.iter().zip(&b.blocks) {
            assert_eq!(x.rect, y.rect, "{label}");
            assert_eq!(x.labels, y.labels, "{label}");
            assert_eq!(x.weights, y.weights, "{label}");
        }
    }

    #[test]
    fn engine_coreset_matches_sharded_builder_bitwise() {
        let mut rng = Rng::new(70);
        let sig = generate::smooth(192, 40, 3, &mut rng);
        let reference = SignalCoreset::construct_sharded(&sig, CoresetConfig::new(4, 0.3), 1);
        for threads in [1, 2, 4] {
            let engine = Engine::new(EngineConfig::new(4, 0.3).with_threads(threads)).unwrap();
            assert_same_coreset(&engine.coreset(&sig), &reference, "engine vs sharded");
            // The session path shares one stats object and still agrees.
            assert_same_coreset(&engine.session(&sig).coreset(), &reference, "session");
        }
    }

    #[test]
    fn engine_short_signal_takes_sequential_fallback() {
        let mut rng = Rng::new(71);
        let sig = generate::image_like(90, 30, 2, &mut rng);
        let engine = Engine::new(EngineConfig::new(3, 0.3).with_threads(2)).unwrap();
        let reference = SignalCoreset::construct_with(&sig, CoresetConfig::new(3, 0.3));
        assert_same_coreset(&engine.coreset(&sig), &reference, "fallback");
        assert_same_coreset(&engine.session(&sig).coreset(), &reference, "session fallback");
    }

    #[test]
    fn engine_fitting_loss_matches_batch_api() {
        let mut rng = Rng::new(72);
        let sig = generate::smooth(64, 48, 3, &mut rng);
        let engine = Engine::new(EngineConfig::new(6, 0.3).with_threads(3)).unwrap();
        let session = engine.session(&sig);
        let cs = session.coreset();
        let queries: Vec<KSegmentation> = (0..40)
            .map(|_| {
                let mut s = random_segmentation(sig.bounds(), 6, &mut rng);
                session.refit(&mut s);
                s
            })
            .collect();
        let via_engine = engine.fitting_loss(&cs, &queries);
        let via_batch = cs.fitting_loss_batch(&queries, 1);
        assert_eq!(via_engine, via_batch);
        // Repeated batches through the same engine stay identical.
        assert_eq!(engine.fitting_loss(&cs, &queries), via_batch);
    }

    #[test]
    fn session_region_and_stats_are_consistent() {
        let mut rng = Rng::new(73);
        let sig = generate::smooth(80, 40, 3, &mut rng);
        let engine = Engine::new(EngineConfig::new(4, 0.3).with_threads(2)).unwrap();
        let session = engine.session(&sig);
        let whole = session.coreset_region(sig.bounds());
        let direct = SignalCoreset::construct_with_stats(
            &sig,
            session.stats(),
            CoresetConfig::new(4, 0.3),
        );
        assert_same_coreset(&whole, &direct, "region == with_stats");
        let s = KSegmentation::constant(sig.bounds(), 0.5);
        let exact = session.exact_loss(&s);
        assert!((exact - s.loss(session.stats())).abs() < 1e-12);
    }

    #[test]
    fn engine_stream_matches_streaming_coreset() {
        let mut rng = Rng::new(74);
        let sig = generate::smooth(96, 30, 3, &mut rng);
        let engine = Engine::new(EngineConfig::new(4, 0.3).with_threads(2)).unwrap();
        let mut via_engine = engine.stream(30);
        let mut classic = StreamingCoreset::new(30, CoresetConfig::new(4, 0.3))
            .with_threads(engine.threads());
        for r0 in (0..96).step_by(32) {
            let band = sig.view(Rect::new(r0, r0 + 31, 0, 29));
            via_engine.push_band(&band);
            classic.push_band(&band);
        }
        let a = via_engine.finish().unwrap();
        let b = classic.finish().unwrap();
        assert_same_coreset(&a, &b, "engine stream");
        assert_eq!(a.rows(), 96);
    }

    #[test]
    fn engine_pipeline_covers_signal() {
        let mut rng = Rng::new(75);
        let sig = generate::smooth(100, 40, 3, &mut rng);
        let engine = Engine::new(EngineConfig::new(5, 0.3).with_threads(2).with_band_rows(16))
            .unwrap();
        let (cs, metrics) = engine.pipeline(&sig);
        assert!((cs.total_weight() - 4000.0).abs() < 1e-6 * 4000.0);
        assert_eq!(cs.rows(), 100);
        assert!(metrics.bands_built() >= 7);
    }

    #[test]
    fn engine_optimal_tree_agrees_with_treedp() {
        let sig = Signal::from_fn(8, 8, |r, c| match (r < 4, c < 4) {
            (true, true) => 1.0,
            (true, false) => 2.0,
            (false, true) => 3.0,
            (false, false) => 4.0,
        });
        let engine = Engine::new(EngineConfig::new(4, 0.3)).unwrap();
        let (tree, loss) = engine.optimal_tree(&sig, 4);
        assert!(loss < 1e-12);
        assert_eq!(tree.k(), 4);
        // The coreset-density variant reports its own fitting loss.
        let cs = engine.coreset(&sig);
        let (tree_c, loss_c) = engine.optimal_tree_of_coreset(&cs, 4);
        let fit = cs.fitting_loss(&tree_c);
        assert!((loss_c - fit).abs() <= 1e-6 * (1.0 + fit));
    }

    #[test]
    fn engine_audit_matches_run_audit() {
        let engine = Engine::new(EngineConfig::new(3, 0.5).with_threads(2).with_seed(11)).unwrap();
        let report = engine.audit(4, 3);
        assert!(report.pass, "\n{}", report.summary());
        let classic = audit::run_audit(
            &AuditConfig::new(3, 0.5)
                .with_cases(4)
                .with_seed(11)
                .with_threads(1)
                .with_transfer_instances(3),
        );
        assert_eq!(report.to_json().render(), classic.to_json().render());
    }

    #[test]
    fn engine_new_rejects_invalid_configs() {
        assert!(Engine::new(EngineConfig::new(0, 0.3)).is_err());
        assert!(Engine::new(EngineConfig::new(4, 1.0)).is_err());
        assert!(Engine::new(EngineConfig::new(4, 0.3).with_band_rows(0)).is_err());
        #[cfg(not(feature = "pjrt"))]
        assert!(
            Engine::new(EngineConfig::new(4, 0.3).with_backend(BackendChoice::Pjrt)).is_err(),
            "pjrt backend must fail fast when not compiled in"
        );
    }
}
