//! `sigtree::engine` — the one front door to the crate.
//!
//! The paper's value proposition is *build the (k, ε)-coreset once,
//! then answer every tree query cheaply* (Theorem 8), and coresets only
//! pay off in practice behind a reusable pipeline object, not one-shot
//! helper calls (Bachem–Lucic–Krause, *Practical Coreset Constructions
//! for Machine Learning*). [`Engine`] is that object: a long-lived
//! session constructed from one validated, serializable
//! [`EngineConfig`], owning
//!
//! * the **worker pool** ([`crate::par::WorkerPool`]) — spawned once,
//!   reused by every build, batch-evaluation, stream, and audit this
//!   engine runs (no per-call thread spinup on the serving hot path;
//!   the one exception is [`Engine::pipeline`], whose banded workers
//!   are dedicated scoped threads around a bounded backpressure queue
//!   by design — only its statistics build runs on the pool);
//! * the **kernel backend** ([`crate::runtime::KernelBackend`]) chosen
//!   by the config (`native` / `blocked` / `pjrt`; `blocked` also
//!   routes the shared statistics through the cache-blocked fill
//!   [`PrefixStats::new_blocked_exec`] — bit-identical f64 results,
//!   see DESIGN.md §Kernels);
//! * per attached signal, the **shared [`PrefixStats`]**
//!   ([`Engine::session`]) every region build and exact-loss query
//!   answers from.
//!
//! ```
//! use sigtree::engine::{Engine, EngineConfig};
//! use sigtree::prelude::*;
//!
//! let signal = Signal::from_fn(160, 48, |r, c| ((r + 2 * c) % 7) as f64);
//! let engine = Engine::new(EngineConfig::new(4, 0.3).with_threads(2)).unwrap();
//!
//! // Build once (sharded, on the engine's pool)…
//! let coreset = engine.coreset(&signal);
//! let cells = signal.len() as f64;
//! assert!((coreset.total_weight() - cells).abs() < 1e-6 * cells);
//!
//! // …then answer every tree query cheaply, pool reused per batch.
//! let session = engine.session(&signal);
//! let queries: Vec<KSegmentation> =
//!     vec![KSegmentation::constant(signal.bounds(), 1.0)];
//! let approx = engine.fitting_loss(&coreset, &queries);
//! let exact = session.exact_loss(&queries[0]);
//! assert!((approx[0] - exact).abs() <= 1e-6 * (1.0 + exact));
//! ```
//!
//! Layering (DESIGN.md §Engine & API layering):
//! `EngineConfig` → `Engine` → {[`Engine::coreset`],
//! [`Engine::coreset_region`], [`Engine::stream`], [`Engine::pipeline`],
//! [`Engine::fitting_loss`], [`Engine::optimal_tree`],
//! [`Engine::audit`]} — all driving the low-level
//! `SignalCoreset::construct*` kernels. The historical
//! `SignalCoreset::build*` entry points are `#[deprecated]` shims.

mod config;

pub use config::{BackendChoice, CoresetFamily, EngineConfig, CONFIG_KEYS};

use crate::audit::{self, AuditConfig, AuditReport, CoresetOracle};
use crate::coreset::merge_reduce::StreamingCoreset;
use crate::coreset::merge_tree::MergeTree;
use crate::coreset::{Coreset, SignalCoreset, WeightedPoint};
use crate::error::Result;
use crate::par::{Exec, WorkerPool};
use crate::pipeline::{self, PipelineConfig, PipelineMetrics};
use crate::runtime::{backend_from_name, KernelBackend};
use crate::sample::{SampleParams, SensitivityCoreset};
use crate::segmentation::dp2d::TreeDP;
use crate::segmentation::KSegmentation;
use crate::signal::{PrefixStats, Rect, Signal, SignalSource};

/// The result of [`Engine::compress`]: whichever coreset family the
/// config selected, behind one [`Coreset`]-implementing wrapper so
/// serving, batch evaluation, and forest training handle both families
/// uniformly.
#[derive(Clone, Debug)]
pub enum Compression {
    /// Deterministic (k, ε)-coreset ([`CoresetFamily::Caratheodory`]).
    Caratheodory(SignalCoreset),
    /// Seeded importance sample ([`CoresetFamily::Sensitivity`]).
    Sensitivity(SensitivityCoreset),
}

impl Compression {
    /// The family's CLI / JSON spelling ("caratheodory"/"sensitivity").
    pub fn family(&self) -> &'static str {
        match self {
            Compression::Caratheodory(_) => "caratheodory",
            Compression::Sensitivity(_) => "sensitivity",
        }
    }

    pub fn rows(&self) -> usize {
        match self {
            Compression::Caratheodory(cs) => cs.rows(),
            Compression::Sensitivity(cs) => cs.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Compression::Caratheodory(cs) => cs.cols(),
            Compression::Sensitivity(cs) => cs.cols(),
        }
    }

    /// Σ wᵢ — the present-cell count for both families (the shared
    /// total-weight invariant).
    pub fn total_weight(&self) -> f64 {
        match self {
            Compression::Caratheodory(cs) => cs.total_weight(),
            Compression::Sensitivity(cs) => cs.total_weight(),
        }
    }

    /// The deterministic coreset, when that family was built — the
    /// surfaces that need Caratheodory-only structure (the smoothed
    /// density oracle of `/optimal_tree`) gate on this.
    pub fn as_caratheodory(&self) -> Option<&SignalCoreset> {
        match self {
            Compression::Caratheodory(cs) => Some(cs),
            Compression::Sensitivity(_) => None,
        }
    }
}

impl Coreset for Compression {
    fn fitting_loss(&self, s: &KSegmentation) -> f64 {
        match self {
            Compression::Caratheodory(cs) => cs.fitting_loss(s),
            Compression::Sensitivity(cs) => cs.fitting_loss(s),
        }
    }

    fn weighted_points(&self) -> Vec<WeightedPoint> {
        match self {
            Compression::Caratheodory(cs) => cs.weighted_points(),
            Compression::Sensitivity(cs) => cs.weighted_points(),
        }
    }

    fn size(&self) -> usize {
        match self {
            Compression::Caratheodory(cs) => cs.size(),
            Compression::Sensitivity(cs) => cs.size(),
        }
    }
}

/// A long-lived build/query/audit session — see the module docs.
///
/// Construction ([`Engine::new`]) validates the config, spawns the
/// worker pool, and instantiates the kernel backend, so every
/// misconfiguration surfaces as one early [`crate::error::Error`]
/// instead of a panic deep in a build.
pub struct Engine {
    config: EngineConfig,
    /// `config.threads` resolved (`0` → all cores).
    threads: usize,
    pool: WorkerPool,
    backend: Box<dyn KernelBackend>,
}

impl Engine {
    /// Validate `config` and bring the session up (pool + backend).
    pub fn new(config: EngineConfig) -> Result<Engine> {
        config.validate()?;
        let backend: Box<dyn KernelBackend> = match config.backend {
            // The blocked backend takes the config's block width (the
            // name-based factory only knows the default).
            BackendChoice::Blocked => {
                Box::new(crate::runtime::BlockedBackend::with_block(config.block_size))
            }
            choice => backend_from_name(
                choice.name(),
                config.artifacts_dir.as_ref().map(std::path::Path::new),
            )?,
        };
        let pool = WorkerPool::new(config.threads);
        let threads = pool.threads();
        Ok(Engine { config, threads, pool, backend })
    }

    /// The validated configuration this engine runs.
    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// Resolved worker count (≥ 1; `threads: 0` resolved to all cores).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The kernel backend the runtime layer executes on.
    pub fn backend(&self) -> &dyn KernelBackend {
        self.backend.as_ref()
    }

    /// This engine's executor — the long-lived pool, for the low-level
    /// `construct*` / `run_audit_exec` entry points.
    pub fn exec(&self) -> Exec<'_> {
        Exec::Pool(&self.pool)
    }

    /// Shared prefix statistics of `signal`, built on the engine pool
    /// (thread-invariant: bit-identical to [`PrefixStats::new_par`] at
    /// any thread count). With the `blocked` backend the build goes
    /// through the cache-blocked fill
    /// ([`PrefixStats::new_blocked_exec`], block width =
    /// [`EngineConfig::block_size`]) — still bit-identical, so backend
    /// choice never changes a downstream coreset.
    pub fn stats<S: SignalSource>(&self, signal: &S) -> PrefixStats {
        match self.config.backend {
            BackendChoice::Blocked => {
                PrefixStats::new_blocked_exec(signal, self.exec(), self.config.block_size)
            }
            _ => PrefixStats::new_par_exec(signal, self.exec()),
        }
    }

    /// Build the (k, ε)-coreset of `signal` — the sharded construction
    /// on the engine pool, routed through the engine-configured
    /// [`MergeTree`] ([`EngineConfig::merge_fanout`] /
    /// [`EngineConfig::reduce_tol`]). With the default knobs this is
    /// bit-identical to the classic `SignalCoreset::construct_sharded`
    /// (née `build_par`) at every thread count — `merge_fanout` never
    /// changes the output (memoization shape only); a `Some` reduce
    /// tolerance does.
    pub fn coreset<S: SignalSource>(&self, signal: &S) -> SignalCoreset {
        let shard_rows = self.config.shard_rows.max(1);
        if signal.rows() / shard_rows <= 1 {
            return SignalCoreset::construct_with(signal, self.config.coreset_config());
        }
        let stats = self.stats(signal);
        self.tree_of(signal, &stats).full()
    }

    /// The engine-configured merge tree of `signal` against shared
    /// statistics: the persistent composition object behind
    /// [`Engine::coreset`] and the sessions' incremental updates. The
    /// caller keeps it alive to amortize rebuilds; [`Engine::edit_session`]
    /// packages the common "own the signal, edit, refresh" loop.
    pub fn tree_of<S: SignalSource>(&self, signal: &S, stats: &PrefixStats) -> MergeTree<'static> {
        MergeTree::build(
            signal,
            stats,
            self.config.coreset_config(),
            self.config.shard_rows,
            self.exec(),
        )
        .with_fanout(self.config.merge_fanout)
        .with_reduce_tol(self.config.reduce_tol)
    }

    /// Build the partial coreset of a sub-rectangle of `signal` (blocks
    /// stay in `signal`'s frame — the merge-and-reduce shard
    /// primitive). Builds the shared statistics for this one call; use
    /// [`Engine::session`] to reuse them across several regions.
    pub fn coreset_region<S: SignalSource>(&self, signal: &S, region: Rect) -> SignalCoreset {
        self.session(signal).coreset_region(region)
    }

    /// Attach a signal: builds the shared [`PrefixStats`] once (on the
    /// pool) and returns the session handle every per-signal operation
    /// reuses it through. The borrow pins the signal for the session's
    /// lifetime, so the statistics can never go stale.
    pub fn session<'a, S: SignalSource>(&'a self, signal: &'a S) -> EngineSession<'a, S> {
        EngineSession {
            engine: self,
            signal,
            stats: self.stats(signal),
            tree: None,
            dirty: Vec::new(),
        }
    }

    /// Attach an **owned** signal for an edit loop: the session owns the
    /// signal, its statistics, and the engine-configured [`MergeTree`],
    /// so in-place edits ([`EditSession::set`] / [`EditSession::edit`])
    /// can be folded into the standing coreset incrementally — only the
    /// leaves intersecting the dirty regions are rebuilt
    /// ([`MergeTree::update_dirty`] on the engine pool), everything else
    /// is reused. This is the session form the `update` CLI subcommand
    /// and mutating-signal workloads drive.
    pub fn edit_session(&self, signal: Signal) -> EditSession<'_> {
        let stats = self.stats(&signal);
        let tree = self.tree_of(&signal, &stats);
        EditSession { engine: self, signal, stats, tree, dirty: Vec::new() }
    }

    /// The band-push handle for streaming ingestion: feed row-bands of
    /// width `cols` as they arrive ([`StreamingCoreset::push_band`]),
    /// then `finish()`. Bands build through the sharded builder on this
    /// engine's pool (no per-band thread spinup) with the config's
    /// shard geometry — the streamed content is identical for every
    /// thread count and executor, and agrees with [`Engine::coreset`]'s
    /// geometry for the same config.
    pub fn stream(&self, cols: usize) -> StreamingCoreset<'_> {
        StreamingCoreset::new(cols, self.config.coreset_config())
            .with_exec(self.exec())
            .with_shard_rows(self.config.shard_rows)
    }

    /// Run the banded pipeline (source → bounded queue → workers →
    /// reducer, with backpressure and metrics) over an in-memory
    /// signal, using the engine's band geometry and worker count and a
    /// shared statistics object built on the pool. The banded workers
    /// themselves are per-call scoped threads (the bounded-queue
    /// backpressure architecture), not pool workers — for repeated
    /// low-latency builds prefer [`Engine::coreset`], which runs
    /// entirely on the parked pool.
    pub fn pipeline<S: SignalSource>(&self, signal: &S) -> (SignalCoreset, PipelineMetrics) {
        let stats = self.stats(signal);
        let config = PipelineConfig::new(self.config.coreset_config())
            .with_band_rows(self.config.band_rows)
            .with_workers(self.threads);
        pipeline::run_with_stats(signal, &stats, config)
    }

    /// Build whichever coreset family the config selects
    /// ([`EngineConfig::coreset_family`]): the deterministic
    /// Caratheodory construction ([`Engine::coreset`], the default) or
    /// the seeded sensitivity sample on the engine pool (bit-identical
    /// at every thread count; the draws consume the config seed). This
    /// is the family-aware front door `sigtree coreset` and the serve
    /// daemon route through.
    pub fn compress<S: SignalSource>(&self, signal: &S) -> Compression {
        match self.config.coreset_family {
            CoresetFamily::Caratheodory => Compression::Caratheodory(self.coreset(signal)),
            CoresetFamily::Sensitivity { algorithm, tau } => {
                let params =
                    SampleParams::new(self.config.k, self.config.eps, tau, self.config.seed);
                Compression::Sensitivity(SensitivityCoreset::build_exec(
                    signal,
                    algorithm,
                    &params,
                    self.exec(),
                ))
            }
        }
    }

    /// Batch FITTING-LOSS on the engine pool, for any [`Coreset`]
    /// family: identical results to
    /// [`SignalCoreset::fitting_loss_batch`] (query order, every
    /// thread count), but repeated batches reuse one set of parked
    /// workers instead of spawning threads per call — the serving
    /// hot path (`bench_runtime`'s engine-reuse rows measure it).
    pub fn fitting_loss<C: Coreset + Sync>(
        &self,
        coreset: &C,
        queries: &[KSegmentation],
    ) -> Vec<f64> {
        self.pool.map(queries, |_, s| coreset.fitting_loss(s))
    }

    /// Exact optimal k-tree of `signal` by the guillotine DP
    /// ([`TreeDP`]) — feasible for small instances (≲ 32×32); the
    /// serving-scale variant is [`Engine::optimal_tree_of_coreset`].
    /// Returns the tree and its loss.
    pub fn optimal_tree<S: SignalSource>(&self, signal: &S, k: usize) -> (KSegmentation, f64) {
        self.session(signal).optimal_tree(k)
    }

    /// The paper's headline pipeline, "run the expensive solver on the
    /// coreset": the exact minimizer of FITTING-LOSS over guillotine
    /// k-trees, via the smoothed-density oracle
    /// ([`CoresetOracle`]). Returns the tree and its FITTING-LOSS.
    pub fn optimal_tree_of_coreset(
        &self,
        coreset: &SignalCoreset,
        k: usize,
    ) -> (KSegmentation, f64) {
        let oracle = CoresetOracle::new(coreset);
        let bounds = Rect::new(0, coreset.rows() - 1, 0, coreset.cols() - 1);
        let mut dp = TreeDP::new(&oracle);
        let loss = dp.opt(bounds, k);
        (dp.solve(bounds, k), loss)
    }

    /// Run the empirical ε-guarantee audit for this engine's (k, ε,
    /// seed) on the engine pool. The evidence trail is bit-identical to
    /// [`audit::run_audit`] with the same knobs at any thread count.
    pub fn audit(&self, cases: usize, transfer_instances: usize) -> AuditReport {
        // The blocked backend audits through its own statistics fill
        // (bit-identical evidence — `AuditConfig::stats_block` docs).
        let stats_block = match self.config.backend {
            BackendChoice::Blocked => Some(self.config.block_size),
            _ => None,
        };
        let config = AuditConfig::new(self.config.k, self.config.eps)
            .with_cases(cases)
            .with_seed(self.config.seed)
            .with_threads(self.threads)
            .with_transfer_instances(transfer_instances)
            .with_stats_block(stats_block);
        audit::run_audit_exec(&config, self.exec())
    }
}

/// A signal attached to an [`Engine`]: owns the shared [`PrefixStats`]
/// and reuses it (and the engine pool) across builds, region builds,
/// exact-loss queries, and DP solves. Created by [`Engine::session`].
pub struct EngineSession<'a, S: SignalSource> {
    engine: &'a Engine,
    signal: &'a S,
    stats: PrefixStats,
    /// Lazily built engine-configured merge tree (see
    /// [`EngineSession::coreset_tree`]); kept across queries so update
    /// calls only rebuild dirty leaves.
    tree: Option<MergeTree<'static>>,
    /// Regions reported changed ([`EngineSession::invalidate`]) and not
    /// yet folded into `stats`/`tree`. Per-signal dirty tracking lives
    /// here in the session, not in the engine.
    dirty: Vec<Rect>,
}

impl<S: SignalSource> EngineSession<'_, S> {
    /// The engine this session runs on.
    pub fn engine(&self) -> &Engine {
        self.engine
    }

    /// The attached signal.
    pub fn signal(&self) -> &S {
        self.signal
    }

    /// The shared statistics (one object for every query this session
    /// answers).
    pub fn stats(&self) -> &PrefixStats {
        &self.stats
    }

    /// The (k, ε)-coreset of the attached signal — same bits as
    /// [`Engine::coreset`] (including the engine's merge-tree knobs),
    /// but reusing this session's statistics (short signals take the
    /// same sequential fallback, so the equality is exact).
    pub fn coreset(&self) -> SignalCoreset {
        let shard_rows = self.engine.config.shard_rows.max(1);
        if self.signal.rows() / shard_rows <= 1 {
            return SignalCoreset::construct_with(
                self.signal,
                self.engine.config.coreset_config(),
            );
        }
        self.engine.tree_of(self.signal, &self.stats).full()
    }

    /// The session's standing merge tree (built lazily, engine knobs
    /// applied), with any pending [`EngineSession::invalidate`] regions
    /// folded in first. Call `.full()` on it for the current coreset;
    /// it stays cached until the next invalidation.
    pub fn coreset_tree(&mut self) -> &mut MergeTree<'static> {
        self.refresh();
        let (engine, signal, stats) = (self.engine, self.signal, &self.stats);
        self.tree.get_or_insert_with(|| engine.tree_of(signal, stats))
    }

    /// Report that the attached signal's cells inside `rect` changed
    /// out-of-band (the session only holds `&S`, so the mutation
    /// happened through interior mutability or an external writer). The
    /// refresh is deferred: statistics and tree are reconciled on the
    /// next [`EngineSession::update_region`] / [`EngineSession::coreset_tree`].
    pub fn invalidate(&mut self, rect: Rect) {
        self.dirty.push(rect);
    }

    /// [`EngineSession::invalidate`] + immediate reconciliation:
    /// re-reads the attached signal (full statistics rebuild — prefix
    /// sums are global), rebuilds exactly the tree leaves intersecting
    /// the accumulated dirty regions on the engine pool, and re-merges
    /// their ancestor paths. Returns the number of leaves rebuilt (0
    /// when no tree has been materialized yet — the next
    /// [`EngineSession::coreset_tree`] builds from the fresh statistics).
    pub fn update_region(&mut self, rect: Rect) -> usize {
        self.invalidate(rect);
        self.refresh()
    }

    /// Fold pending dirty regions into the session state; see
    /// [`EngineSession::update_region`].
    fn refresh(&mut self) -> usize {
        if self.dirty.is_empty() {
            return 0;
        }
        self.stats = self.engine.stats(self.signal);
        let rebuilt = match self.tree.as_mut() {
            None => 0,
            Some(tree) => {
                tree.update_dirty(&self.dirty, self.signal, &self.stats, self.engine.exec())
            }
        };
        self.dirty.clear();
        rebuilt
    }

    /// Partial coreset of `region` (signal-frame blocks; the shard
    /// primitive), against the session's shared statistics.
    pub fn coreset_region(&self, region: Rect) -> SignalCoreset {
        SignalCoreset::construct_in(
            self.signal,
            &self.stats,
            region,
            self.engine.config.coreset_config(),
        )
    }

    /// Exact loss ℓ(D, s) from the shared statistics (the ground truth
    /// FITTING-LOSS approximates).
    pub fn exact_loss(&self, s: &KSegmentation) -> f64 {
        s.loss(&self.stats)
    }

    /// Refit a segmentation's piece values to the attached signal's
    /// per-piece means.
    pub fn refit(&self, s: &mut KSegmentation) {
        s.refit_values(&self.stats);
    }

    /// Batch FITTING-LOSS on the engine pool ([`Engine::fitting_loss`]).
    pub fn fitting_loss<C: Coreset + Sync>(
        &self,
        coreset: &C,
        queries: &[KSegmentation],
    ) -> Vec<f64> {
        self.engine.fitting_loss(coreset, queries)
    }

    /// Exact optimal k-tree of the attached signal (guillotine DP on
    /// the shared statistics). Returns the tree and its loss.
    pub fn optimal_tree(&self, k: usize) -> (KSegmentation, f64) {
        let bounds = self.stats.bounds();
        let mut dp = TreeDP::new(&self.stats);
        let loss = dp.opt(bounds, k);
        (dp.solve(bounds, k), loss)
    }
}

/// An **owned-signal** session for mutating workloads: edit cells in
/// place, then refresh the standing coreset incrementally — only the
/// merge-tree leaves intersecting the dirty regions are rebuilt (on the
/// engine pool); clean leaves and their memoized compositions are
/// reused. Created by [`Engine::edit_session`].
///
/// The statistics are rebuilt in full on every refresh (prefix sums are
/// global — O(N) but cheap); the savings come from skipping the
/// O(N·k) bicriteria → partition → Caratheodory pipeline on every
/// clean leaf. See DESIGN.md §Merge tree for the cost model.
pub struct EditSession<'e> {
    engine: &'e Engine,
    signal: Signal,
    stats: PrefixStats,
    tree: MergeTree<'static>,
    dirty: Vec<Rect>,
}

impl EditSession<'_> {
    /// The engine this session runs on.
    pub fn engine(&self) -> &Engine {
        self.engine
    }

    /// The owned signal in its current (possibly edited) state.
    pub fn signal(&self) -> &Signal {
        &self.signal
    }

    /// The shared statistics of the last refreshed state. Stale while
    /// edits are pending; [`EditSession::refresh`] reconciles.
    pub fn stats(&self) -> &PrefixStats {
        &self.stats
    }

    /// Leaf coresets built by the standing tree so far (initial build +
    /// every incremental rebuild) — the counter incremental tests and
    /// the `update` CLI report.
    pub fn leaf_builds(&self) -> usize {
        self.tree.leaf_builds()
    }

    /// Set one cell and mark it dirty.
    pub fn set(&mut self, r: usize, c: usize, value: f64) {
        self.signal.set(r, c, value);
        self.dirty.push(Rect::new(r, r, c, c));
    }

    /// Apply `f(r, c, old) -> new` over every **present** cell of
    /// `rect` and mark the rectangle dirty.
    pub fn edit(&mut self, rect: Rect, mut f: impl FnMut(usize, usize, f64) -> f64) {
        for (r, c) in rect.cells() {
            if self.signal.is_present(r, c) {
                let old = self.signal.get(r, c);
                self.signal.set(r, c, f(r, c, old));
            }
        }
        self.dirty.push(rect);
    }

    /// Mark `rect` dirty without editing through the session (the cells
    /// were changed by other means before the signal was handed over,
    /// or the caller wants a forced leaf rebuild).
    pub fn invalidate(&mut self, rect: Rect) {
        self.dirty.push(rect);
    }

    /// [`EditSession::invalidate`] + immediate [`EditSession::refresh`];
    /// returns the number of tree leaves rebuilt.
    pub fn update_region(&mut self, rect: Rect) -> usize {
        self.invalidate(rect);
        self.refresh()
    }

    /// Fold all pending edits into the session state: one full
    /// statistics rebuild on the engine pool, then rebuild exactly the
    /// tree leaves intersecting the dirty regions. Returns the number
    /// of leaves rebuilt (0 when nothing was pending).
    pub fn refresh(&mut self) -> usize {
        if self.dirty.is_empty() {
            return 0;
        }
        self.stats = self.engine.stats(&self.signal);
        let rebuilt =
            self.tree
                .update_dirty(&self.dirty, &self.signal, &self.stats, self.engine.exec());
        self.dirty.clear();
        rebuilt
    }

    /// The standing merge tree (pending edits folded in first).
    pub fn coreset_tree(&mut self) -> &mut MergeTree<'static> {
        self.refresh();
        &mut self.tree
    }

    /// The (k, ε)-coreset of the signal's current state — incremental:
    /// pending edits are folded in ([`EditSession::refresh`]) and the
    /// memoized root recomposed; clean leaves are never rebuilt.
    pub fn coreset(&mut self) -> SignalCoreset {
        self.refresh();
        self.tree.full()
    }

    /// Exact loss ℓ(D, s) of the signal's current state (pending edits
    /// folded in first).
    pub fn exact_loss(&mut self, s: &KSegmentation) -> f64 {
        self.refresh();
        s.loss(&self.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coreset::{Coreset, CoresetConfig};
    use crate::rng::Rng;
    use crate::segmentation::random_segmentation;
    use crate::signal::{generate, Signal};

    fn assert_same_coreset(a: &SignalCoreset, b: &SignalCoreset, label: &str) {
        assert_eq!(a.blocks.len(), b.blocks.len(), "{label}: block count");
        for (x, y) in a.blocks.iter().zip(&b.blocks) {
            assert_eq!(x.rect, y.rect, "{label}");
            assert_eq!(x.labels, y.labels, "{label}");
            assert_eq!(x.weights, y.weights, "{label}");
        }
    }

    #[test]
    fn engine_coreset_matches_sharded_builder_bitwise() {
        let mut rng = Rng::new(70);
        let sig = generate::smooth(192, 40, 3, &mut rng);
        let reference = SignalCoreset::construct_sharded(&sig, CoresetConfig::new(4, 0.3), 1);
        for threads in [1, 2, 4] {
            let engine = Engine::new(EngineConfig::new(4, 0.3).with_threads(threads)).unwrap();
            assert_same_coreset(&engine.coreset(&sig), &reference, "engine vs sharded");
            // The session path shares one stats object and still agrees.
            assert_same_coreset(&engine.session(&sig).coreset(), &reference, "session");
        }
        // merge_fanout is memoization shape only: any value, same bits.
        for fanout in [3, 8] {
            let engine = Engine::new(
                EngineConfig::new(4, 0.3).with_threads(2).with_merge_fanout(fanout),
            )
            .unwrap();
            assert_same_coreset(&engine.coreset(&sig), &reference, "fanout");
            let mut session = engine.session(&sig);
            assert_same_coreset(&session.coreset_tree().full(), &reference, "fanout tree");
        }
    }

    #[test]
    fn blocked_backend_engine_is_bit_identical_to_native() {
        // Backend choice is a pure execution knob: the blocked stats
        // fill is bit-identical to the scalar one, so the engine's
        // coresets must match bitwise for every block size — including
        // a non-divisor width.
        let mut rng = Rng::new(78);
        let sig = generate::smooth(192, 40, 3, &mut rng);
        let native = Engine::new(EngineConfig::new(4, 0.3).with_threads(2)).unwrap();
        let reference = native.coreset(&sig);
        for block in [8, 37, 64] {
            let engine = Engine::new(
                EngineConfig::new(4, 0.3)
                    .with_threads(2)
                    .with_backend(BackendChoice::Blocked)
                    .with_block_size(block),
            )
            .unwrap();
            assert_eq!(engine.backend().name(), "blocked");
            assert_same_coreset(&engine.coreset(&sig), &reference, "blocked engine");
            let stats = engine.stats(&sig);
            let s = KSegmentation::constant(sig.bounds(), 0.5);
            assert_eq!(s.loss(&stats), s.loss(&native.stats(&sig)), "stats loss");
        }
    }

    #[test]
    fn edit_session_rebuilds_only_dirty_leaves() {
        let mut rng = Rng::new(76);
        let sig = generate::smooth(256, 32, 3, &mut rng);
        let engine = Engine::new(EngineConfig::new(4, 0.3).with_threads(2)).unwrap();
        let mut session = engine.edit_session(sig.clone());
        let leaves = session.coreset_tree().leaf_count();
        assert!(leaves >= 4);
        assert_eq!(session.leaf_builds(), leaves);
        assert_same_coreset(&session.coreset(), &engine.coreset(&sig), "pre-edit");

        // Edit one tile inside the first shard; only that leaf rebuilds.
        let tile = Rect::new(4, 11, 2, 9);
        session.edit(tile, |_, _, v| v + 5.0);
        let cs = session.coreset();
        assert_eq!(session.leaf_builds(), leaves + 1, "one dirty leaf");

        // The incremental coreset matches a from-scratch build of the
        // mutated signal at tolerance level (stats ULPs can flip
        // partition decisions, so bit-equality is not guaranteed).
        let mut mutated = sig.clone();
        for (r, c) in tile.cells() {
            let v = mutated.get(r, c);
            mutated.set(r, c, v + 5.0);
        }
        let scratch = engine.coreset(&mutated);
        let cells = mutated.present() as f64;
        assert!((cs.total_weight() - cells).abs() < 1e-6 * cells);
        assert!((cs.total_weight() - scratch.total_weight()).abs() < 1e-6 * cells);
        let stats = PrefixStats::new(&mutated);
        let mut s = random_segmentation(mutated.bounds(), 4, &mut rng);
        s.refit_values(&stats);
        let exact = s.loss(&stats);
        assert!((cs.fitting_loss(&s) - exact).abs() <= 0.35 * exact + 1e-6);
        assert!((scratch.fitting_loss(&s) - exact).abs() <= 0.35 * exact + 1e-6);

        // A clean refresh is free; update_region forces a leaf rebuild.
        assert_eq!(session.refresh(), 0);
        assert_eq!(session.update_region(Rect::new(0, 0, 0, 0)), 1);
        assert_eq!(session.leaf_builds(), leaves + 2);
    }

    #[test]
    fn session_invalidate_defers_and_coreset_tree_reconciles() {
        let mut rng = Rng::new(77);
        let sig = generate::smooth(192, 24, 3, &mut rng);
        let engine = Engine::new(EngineConfig::new(3, 0.3).with_threads(2)).unwrap();
        let mut session = engine.session(&sig);
        let reference = engine.coreset(&sig);
        assert_same_coreset(&session.coreset_tree().full(), &reference, "tree");
        // No tree materialized yet → update_region reports 0 rebuilds…
        let mut fresh = engine.session(&sig);
        assert_eq!(fresh.update_region(Rect::new(0, 10, 0, 10)), 0);
        // …but once standing, an (unchanged-signal) invalidation rebuilds
        // the intersecting leaves and the root still agrees.
        session.invalidate(Rect::new(0, 10, 0, 10));
        let rebuilt = session.update_region(Rect::new(64, 70, 0, 5));
        assert!(rebuilt >= 2, "two dirty rects hit >= 2 leaves ({rebuilt})");
        assert_same_coreset(&session.coreset_tree().full(), &reference, "post-update");
    }

    #[test]
    fn engine_short_signal_takes_sequential_fallback() {
        let mut rng = Rng::new(71);
        let sig = generate::image_like(90, 30, 2, &mut rng);
        let engine = Engine::new(EngineConfig::new(3, 0.3).with_threads(2)).unwrap();
        let reference = SignalCoreset::construct_with(&sig, CoresetConfig::new(3, 0.3));
        assert_same_coreset(&engine.coreset(&sig), &reference, "fallback");
        assert_same_coreset(&engine.session(&sig).coreset(), &reference, "session fallback");
    }

    #[test]
    fn engine_fitting_loss_matches_batch_api() {
        let mut rng = Rng::new(72);
        let sig = generate::smooth(64, 48, 3, &mut rng);
        let engine = Engine::new(EngineConfig::new(6, 0.3).with_threads(3)).unwrap();
        let session = engine.session(&sig);
        let cs = session.coreset();
        let queries: Vec<KSegmentation> = (0..40)
            .map(|_| {
                let mut s = random_segmentation(sig.bounds(), 6, &mut rng);
                session.refit(&mut s);
                s
            })
            .collect();
        let via_engine = engine.fitting_loss(&cs, &queries);
        let via_batch = cs.fitting_loss_batch(&queries, 1);
        assert_eq!(via_engine, via_batch);
        // Repeated batches through the same engine stay identical.
        assert_eq!(engine.fitting_loss(&cs, &queries), via_batch);
    }

    #[test]
    fn session_region_and_stats_are_consistent() {
        let mut rng = Rng::new(73);
        let sig = generate::smooth(80, 40, 3, &mut rng);
        let engine = Engine::new(EngineConfig::new(4, 0.3).with_threads(2)).unwrap();
        let session = engine.session(&sig);
        let whole = session.coreset_region(sig.bounds());
        let direct = SignalCoreset::construct_with_stats(
            &sig,
            session.stats(),
            CoresetConfig::new(4, 0.3),
        );
        assert_same_coreset(&whole, &direct, "region == with_stats");
        let s = KSegmentation::constant(sig.bounds(), 0.5);
        let exact = session.exact_loss(&s);
        assert!((exact - s.loss(session.stats())).abs() < 1e-12);
    }

    #[test]
    fn engine_stream_matches_streaming_coreset() {
        let mut rng = Rng::new(74);
        let sig = generate::smooth(96, 30, 3, &mut rng);
        let engine = Engine::new(EngineConfig::new(4, 0.3).with_threads(2)).unwrap();
        let mut via_engine = engine.stream(30);
        let mut classic = StreamingCoreset::new(30, CoresetConfig::new(4, 0.3))
            .with_threads(engine.threads());
        for r0 in (0..96).step_by(32) {
            let band = sig.view(Rect::new(r0, r0 + 31, 0, 29));
            via_engine.push_band(&band);
            classic.push_band(&band);
        }
        let a = via_engine.finish().unwrap();
        let b = classic.finish().unwrap();
        assert_same_coreset(&a, &b, "engine stream");
        assert_eq!(a.rows(), 96);
    }

    #[test]
    fn engine_pipeline_covers_signal() {
        let mut rng = Rng::new(75);
        let sig = generate::smooth(100, 40, 3, &mut rng);
        let engine = Engine::new(EngineConfig::new(5, 0.3).with_threads(2).with_band_rows(16))
            .unwrap();
        let (cs, metrics) = engine.pipeline(&sig);
        assert!((cs.total_weight() - 4000.0).abs() < 1e-6 * 4000.0);
        assert_eq!(cs.rows(), 100);
        assert!(metrics.bands_built() >= 7);
    }

    #[test]
    fn engine_optimal_tree_agrees_with_treedp() {
        let sig = Signal::from_fn(8, 8, |r, c| match (r < 4, c < 4) {
            (true, true) => 1.0,
            (true, false) => 2.0,
            (false, true) => 3.0,
            (false, false) => 4.0,
        });
        let engine = Engine::new(EngineConfig::new(4, 0.3)).unwrap();
        let (tree, loss) = engine.optimal_tree(&sig, 4);
        assert!(loss < 1e-12);
        assert_eq!(tree.k(), 4);
        // The coreset-density variant reports its own fitting loss.
        let cs = engine.coreset(&sig);
        let (tree_c, loss_c) = engine.optimal_tree_of_coreset(&cs, 4);
        let fit = cs.fitting_loss(&tree_c);
        assert!((loss_c - fit).abs() <= 1e-6 * (1.0 + fit));
    }

    #[test]
    fn engine_audit_matches_run_audit() {
        let engine = Engine::new(EngineConfig::new(3, 0.5).with_threads(2).with_seed(11)).unwrap();
        let report = engine.audit(4, 3);
        assert!(report.pass, "\n{}", report.summary());
        let classic = audit::run_audit(
            &AuditConfig::new(3, 0.5)
                .with_cases(4)
                .with_seed(11)
                .with_threads(1)
                .with_transfer_instances(3),
        );
        assert_eq!(report.to_json().render(), classic.to_json().render());
    }

    #[test]
    fn engine_compress_dispatches_on_family() {
        use crate::sample::SampleAlgorithm;
        let mut rng = Rng::new(79);
        let sig = generate::smooth(96, 40, 3, &mut rng);
        let cells = sig.present() as f64;
        // Default family: bit-identical to the classic coreset path.
        let engine = Engine::new(EngineConfig::new(4, 0.3).with_threads(2)).unwrap();
        let compressed = engine.compress(&sig);
        assert_eq!(compressed.family(), "caratheodory");
        let direct = engine.coreset(&sig);
        assert_same_coreset(compressed.as_caratheodory().unwrap(), &direct, "compress");
        assert!((compressed.total_weight() - cells).abs() < 1e-6 * cells);
        // Sensitivity family: seeded, thread-invariant, weight parity.
        for algorithm in SampleAlgorithm::ALL {
            let family = CoresetFamily::Sensitivity { algorithm, tau: 300 };
            let build = |threads| {
                let engine = Engine::new(
                    EngineConfig::new(4, 0.3).with_threads(threads).with_coreset_family(family),
                )
                .unwrap();
                engine.compress(&sig)
            };
            let reference = build(1);
            assert_eq!(reference.family(), "sensitivity");
            assert!(reference.as_caratheodory().is_none());
            assert!((reference.total_weight() - cells).abs() <= 1e-9 * cells);
            assert!(reference.size() <= 300);
            for threads in [2, 4, 8] {
                let other = build(threads);
                match (&reference, &other) {
                    (Compression::Sensitivity(a), Compression::Sensitivity(b)) => {
                        assert_eq!(a, b, "{} at {threads} threads", algorithm.name());
                    }
                    _ => panic!("family mismatch"),
                }
            }
            // The generic batch API accepts the wrapper directly.
            let q = KSegmentation::constant(sig.bounds(), 1.0);
            let batch = engine.fitting_loss(&reference, std::slice::from_ref(&q));
            assert!((batch[0] - reference.fitting_loss(&q)).abs() <= 1e-9 * (1.0 + batch[0]));
        }
    }

    #[test]
    fn engine_new_rejects_invalid_configs() {
        assert!(Engine::new(EngineConfig::new(0, 0.3)).is_err());
        assert!(Engine::new(EngineConfig::new(4, 1.0)).is_err());
        assert!(Engine::new(EngineConfig::new(4, 0.3).with_band_rows(0)).is_err());
        #[cfg(not(feature = "pjrt"))]
        assert!(
            Engine::new(EngineConfig::new(4, 0.3).with_backend(BackendChoice::Pjrt)).is_err(),
            "pjrt backend must fail fast when not compiled in"
        );
    }
}
