//! Hand-rolled JSON (serde is unavailable offline — DESIGN.md
//! §Substitutions). The writer side emits the machine-readable evidence
//! trails (`AuditReport`, `BENCH_runtime.json`) CI archives; the reader
//! side ([`Json::parse`]) has two consumers — engine configuration
//! files ([`crate::engine::EngineConfig`]), so a config written with
//! [`Json::render`] round-trips through disk and the CLI's `--config`
//! flag, and the serving daemon ([`crate::serve`]), which parses
//! *untrusted network bodies*, so the grammar is strict RFC 8259 (see
//! [`MAX_PARSE_DEPTH`] and the number-grammar note on `Parser::number`).
//! Crate-level on purpose — it carries no
//! audit-specific logic, so any emitter (pipeline metrics, experiment
//! results) depends on `sigtree::json`, not on the audit subsystem
//! (which re-exports it as `audit::json` for the evidence-trail docs).
//!
//! Numbers are emitted as valid JSON: exact integers (|x| < 2⁵³) print
//! without a fractional part, everything else uses Rust's shortest
//! round-trip `f64` formatting, and non-finite values degrade to `null`
//! (JSON has no NaN/∞).

use std::fmt::Write as _;

/// A JSON value tree. Objects keep insertion order (`Vec` of pairs, not a
/// map) so the rendered evidence trail is stable and diffable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Numeric helper for integer-valued counts.
    pub fn int(x: usize) -> Json {
        Json::Num(x as f64)
    }

    /// Numeric helper (non-finite values render as `null`).
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// String helper.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Object helper taking `(key, value)` pairs in display order.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Object field lookup (first match; objects are ordered pairs).
    /// `None` for non-objects and missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The value as a non-negative exact integer (counts, sizes).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && *x == x.trunc() && *x < EXACT_INT => {
                Some(*x as usize)
            }
            _ => None,
        }
    }

    /// Parse a JSON document — the reader side of [`Json::render`].
    /// Strict on structure (one value, balanced, valid escapes) and
    /// returns a message with the byte offset on malformed input.
    /// `NaN`/`Infinity` are not JSON and are rejected, mirroring the
    /// writer's non-finite → `null` degradation. Nesting is capped at
    /// [`MAX_PARSE_DEPTH`] so a corrupt config (`[[[[…`) errors instead
    /// of overflowing the stack — every misparse must surface as `Err`.
    // lint:allow(error-discipline) -- the byte-offset String diagnostics
    // are this parser's public contract; the engine-config boundary wraps
    // them into sigtree::error::Error with file context.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let value = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing content at byte {}", p.pos));
        }
        Ok(value)
    }

    /// Render as pretty-printed JSON (2-space indent, trailing newline) —
    /// the on-disk format of every evidence trail the repo writes.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out, 0);
        out.push('\n');
        out
    }

    fn render_into(&self, out: &mut String, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_number(out, *x),
            Json::Str(s) => write_string(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, depth + 1);
                    item.render_into(out, depth + 1);
                }
                newline_indent(out, depth);
                out.push(']');
            }
            Json::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, depth + 1);
                    write_string(out, k);
                    out.push_str(": ");
                    v.render_into(out, depth + 1);
                }
                newline_indent(out, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, depth: usize) {
    out.push('\n');
    for _ in 0..depth {
        out.push_str("  ");
    }
}

/// 2⁵³ — the largest magnitude below which every integer is exact in f64.
const EXACT_INT: f64 = 9_007_199_254_740_992.0;

fn write_number(out: &mut String, x: f64) {
    if !x.is_finite() {
        out.push_str("null");
    } else if x == x.trunc() && x.abs() < EXACT_INT {
        let _ = write!(out, "{}", x as i64);
    } else {
        // Rust's shortest-roundtrip Debug form ("0.1", "1.5e-9") is valid
        // JSON for every finite non-integer f64.
        let _ = write!(out, "{x:?}");
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Maximum container nesting [`Json::parse`] accepts. Far above any
/// config/evidence document the repo writes (≤ 4 levels), far below
/// stack-overflow territory for the recursive descent.
pub const MAX_PARSE_DEPTH: usize = 128;

/// Recursive-descent JSON reader over raw bytes (UTF-8 handled via the
/// escape and string paths; structural characters are all ASCII).
struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Current container nesting, guarded against [`MAX_PARSE_DEPTH`].
    depth: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}",
                b as char, self.pos
            ))
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            None => Err("unexpected end of input".to_string()),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            Some(other) => Err(format!(
                "unexpected '{}' at byte {}",
                other as char, self.pos
            )),
        }
    }

    /// Strict JSON number grammar:
    /// `-?(0|[1-9][0-9]*)(\.[0-9]+)?([eE][+-]?[0-9]+)?`.
    ///
    /// Rust's `f64::from_str` is deliberately laxer (`01`, `1.`, `+1`,
    /// `.5`, `inf` all parse), which was harmless while the only input
    /// was the crate's own `render` output but is wrong at the serving
    /// boundary (`sigtree::serve` feeds network bodies through here) —
    /// so the span is validated against the RFC 8259 grammar *before*
    /// the final `f64` conversion. Note `"01"` errors as trailing
    /// content rather than inside this method: the grammar says the
    /// number ends after `0`, and the container/top-level parse then
    /// rejects the dangling `1`.
    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        match self.peek() {
            Some(b'0') => self.pos += 1,
            Some(b'1'..=b'9') => {
                while matches!(self.peek(), Some(b'0'..=b'9')) {
                    self.pos += 1;
                }
            }
            _ => return Err(format!("invalid number at byte {start}: expected digit")),
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(format!(
                    "invalid number at byte {start}: expected digit after '.'"
                ));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(format!(
                    "invalid number at byte {start}: expected exponent digits"
                ));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| format!("invalid number at byte {start}"))?;
        text.parse::<f64>()
            .ok()
            .filter(|x| x.is_finite())
            .map(Json::Num)
            .ok_or_else(|| format!("invalid number '{text}' at byte {start}"))
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let start = self.pos;
        let end = start + 4;
        let slice = self
            .bytes
            .get(start..end)
            .ok_or_else(|| format!("truncated \\u escape at byte {start}"))?;
        // Exactly four hex digits — `from_str_radix` alone would also
        // accept a leading sign (`\u+041`), which is not JSON.
        if !slice.iter().all(u8::is_ascii_hexdigit) {
            return Err(format!("invalid \\u escape at byte {start}"));
        }
        let text = std::str::from_utf8(slice)
            .map_err(|_| format!("invalid \\u escape at byte {start}"))?;
        let code = u32::from_str_radix(text, 16)
            .map_err(|_| format!("invalid \\u escape at byte {start}"))?;
        self.pos = end;
        Ok(code)
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        let mut chunk_start = self.pos;
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    out.push_str(self.chunk(chunk_start)?);
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    out.push_str(self.chunk(chunk_start)?);
                    self.pos += 1;
                    let esc = self
                        .peek()
                        .ok_or_else(|| "unterminated escape".to_string())?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let mut code = self.hex4()?;
                            // Combine a UTF-16 surrogate pair.
                            if (0xD800..0xDC00).contains(&code) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let low = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&low) {
                                        return Err(format!(
                                            "unpaired surrogate at byte {}",
                                            self.pos
                                        ));
                                    }
                                    code = 0x10000
                                        + ((code - 0xD800) << 10)
                                        + (low - 0xDC00);
                                } else {
                                    return Err(format!(
                                        "unpaired surrogate at byte {}",
                                        self.pos
                                    ));
                                }
                            }
                            out.push(
                                char::from_u32(code).ok_or_else(|| {
                                    format!("invalid code point at byte {}", self.pos)
                                })?,
                            );
                        }
                        other => {
                            return Err(format!(
                                "invalid escape '\\{}' at byte {}",
                                other as char,
                                self.pos - 1
                            ))
                        }
                    }
                    chunk_start = self.pos;
                }
                Some(b) if b < 0x20 => {
                    return Err(format!(
                        "unescaped control character at byte {}",
                        self.pos
                    ))
                }
                Some(_) => self.pos += 1,
            }
        }
    }

    /// The raw (escape-free) string bytes accumulated since
    /// `chunk_start`, validated as UTF-8.
    fn chunk(&self, chunk_start: usize) -> Result<&str, String> {
        std::str::from_utf8(&self.bytes[chunk_start..self.pos])
            .map_err(|_| format!("invalid UTF-8 near byte {chunk_start}"))
    }

    fn enter(&mut self) -> Result<(), String> {
        self.depth += 1;
        if self.depth > MAX_PARSE_DEPTH {
            return Err(format!(
                "nesting deeper than {MAX_PARSE_DEPTH} at byte {}",
                self.pos
            ));
        }
        Ok(())
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        self.enter()?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        self.enter()?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            pairs.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_render() {
        assert_eq!(Json::Null.render(), "null\n");
        assert_eq!(Json::Bool(true).render(), "true\n");
        assert_eq!(Json::int(25).render(), "25\n");
        assert_eq!(Json::num(0.5).render(), "0.5\n");
        assert_eq!(Json::num(-3.0).render(), "-3\n");
        assert_eq!(Json::num(f64::NAN).render(), "null\n");
        assert_eq!(Json::num(f64::INFINITY).render(), "null\n");
    }

    #[test]
    fn strings_escape() {
        let s = Json::str("a\"b\\c\nd\te\u{1}");
        assert_eq!(s.render(), "\"a\\\"b\\\\c\\nd\\te\\u0001\"\n");
    }

    #[test]
    fn object_preserves_order_and_nests() {
        let j = Json::obj(vec![
            ("z", Json::int(1)),
            ("a", Json::Arr(vec![Json::int(2), Json::Null])),
            ("empty", Json::Obj(Vec::new())),
        ]);
        let rendered = j.render();
        // z must come before a (insertion order, not sorted).
        assert!(rendered.find("\"z\"").unwrap() < rendered.find("\"a\"").unwrap());
        assert!(rendered.contains("\"empty\": {}"));
        assert!(rendered.contains("[\n    2,\n    null\n  ]"));
    }

    #[test]
    fn parse_round_trips_render() {
        let doc = Json::obj(vec![
            ("k", Json::int(64)),
            ("eps", Json::num(0.2)),
            ("beta", Json::Null),
            ("name", Json::str("engine \"smoke\"\n")),
            ("flags", Json::Arr(vec![Json::Bool(true), Json::Bool(false)])),
            ("nested", Json::obj(vec![("empty", Json::Arr(Vec::new()))])),
        ]);
        let parsed = Json::parse(&doc.render()).unwrap();
        assert_eq!(parsed, doc);
        // And a second trip is stable.
        assert_eq!(parsed.render(), doc.render());
    }

    #[test]
    fn parse_accepts_compact_and_whitespace_forms() {
        let j = Json::parse("{\"a\":[1,2.5,-3e2],\"b\":null}").unwrap();
        assert_eq!(j.get("a").unwrap(), &Json::Arr(vec![
            Json::num(1.0),
            Json::num(2.5),
            Json::num(-300.0),
        ]));
        assert_eq!(j.get("b"), Some(&Json::Null));
        assert_eq!(j.get("missing"), None);
        let j = Json::parse(" \t\n[ ]\r\n").unwrap();
        assert_eq!(j, Json::Arr(Vec::new()));
    }

    #[test]
    fn parse_handles_escapes() {
        let j = Json::parse(r#""a\"b\\c\nd\u0041\u00e9\ud83d\ude00""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\"b\\c\ndAé😀");
    }

    #[test]
    fn parse_rejects_malformed_input() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "1..2", "nan", "Infinity",
            "[1] trailing", "\"unterminated", "{\"a\" 1}", "\"\\q\"",
            "\"\\ud800x\"", "1e999", "\"\\u+041\"", "\"\\u00g1\"",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted malformed {bad:?}");
        }
    }

    #[test]
    fn parse_enforces_the_json_number_grammar() {
        // Regression for the lenient-f64 inheritance: each of these is
        // accepted by Rust's `f64::from_str` (so the pre-fix parser let
        // them through) but is not a JSON number per RFC 8259.
        for bad in [
            "01", "007", "[01]", "1.", "[1.]", "{\"a\": 2.}", ".5", "+1",
            "1e", "1e+", "2E-", "1.e3", "-", "-.5", "[1, 02]", "1.5e",
            "0x10", "inf", "-inf",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted non-JSON number {bad:?}");
        }
        // …while every shape the grammar does allow still parses, with
        // exact values.
        for (ok, want) in [
            ("0", 0.0),
            ("-0", 0.0),
            ("10", 10.0),
            ("0.5", 0.5),
            ("-0.25", -0.25),
            ("1e9", 1e9),
            ("1E+9", 1e9),
            ("2.5e-3", 2.5e-3),
            ("123.456", 123.456),
            ("9007199254740991", 9_007_199_254_740_991.0),
        ] {
            let got = Json::parse(ok).unwrap().as_f64().unwrap();
            assert_eq!(got.to_bits(), want.to_bits(), "{ok}");
        }
    }

    #[test]
    fn parse_caps_nesting_depth() {
        // Within the cap: fine both ways.
        let deep_ok = format!("{}1{}", "[".repeat(64), "]".repeat(64));
        assert!(Json::parse(&deep_ok).is_ok());
        // Past the cap: a clean Err, never a stack overflow — a corrupt
        // --config file must not crash the CLI.
        let bomb = format!("{}1{}", "[".repeat(100_000), "]".repeat(100_000));
        let err = Json::parse(&bomb).unwrap_err();
        assert!(err.contains("nesting"), "{err}");
        let obj_bomb = "{\"a\":".repeat(MAX_PARSE_DEPTH + 8);
        assert!(Json::parse(&obj_bomb).is_err());
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse("{\"n\": 7, \"f\": 1.5, \"s\": \"x\", \"b\": true}").unwrap();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("f").unwrap().as_f64(), Some(1.5));
        assert_eq!(j.get("f").unwrap().as_usize(), None);
        assert_eq!(j.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(Json::num(-1.0).as_usize(), None);
    }

    #[test]
    fn exact_integers_have_no_fraction() {
        assert_eq!(Json::num(1200.0).render(), "1200\n");
        // Large non-exact magnitudes fall back to float formatting.
        let big = Json::num(1e300).render();
        assert!(big.starts_with('1'), "{big}");
        assert!(!big.contains("null"));
    }
}
