//! Hand-rolled JSON writer (serde is unavailable offline — DESIGN.md
//! §Substitutions). Write-only: the audit engine and the benchmarks emit
//! machine-readable evidence trails (`AuditReport`, `BENCH_runtime.json`)
//! and CI archives them; nothing in the repo needs to parse JSON back.
//! Crate-level on purpose — it carries no audit-specific logic, so any
//! future emitter (pipeline metrics, experiment results) depends on
//! `sigtree::json`, not on the audit subsystem (which re-exports it as
//! `audit::json` for the evidence-trail docs).
//!
//! Numbers are emitted as valid JSON: exact integers (|x| < 2⁵³) print
//! without a fractional part, everything else uses Rust's shortest
//! round-trip `f64` formatting, and non-finite values degrade to `null`
//! (JSON has no NaN/∞).

use std::fmt::Write as _;

/// A JSON value tree. Objects keep insertion order (`Vec` of pairs, not a
/// map) so the rendered evidence trail is stable and diffable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Numeric helper for integer-valued counts.
    pub fn int(x: usize) -> Json {
        Json::Num(x as f64)
    }

    /// Numeric helper (non-finite values render as `null`).
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// String helper.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Object helper taking `(key, value)` pairs in display order.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Render as pretty-printed JSON (2-space indent, trailing newline) —
    /// the on-disk format of every evidence trail the repo writes.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out, 0);
        out.push('\n');
        out
    }

    fn render_into(&self, out: &mut String, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_number(out, *x),
            Json::Str(s) => write_string(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, depth + 1);
                    item.render_into(out, depth + 1);
                }
                newline_indent(out, depth);
                out.push(']');
            }
            Json::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, depth + 1);
                    write_string(out, k);
                    out.push_str(": ");
                    v.render_into(out, depth + 1);
                }
                newline_indent(out, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, depth: usize) {
    out.push('\n');
    for _ in 0..depth {
        out.push_str("  ");
    }
}

/// 2⁵³ — the largest magnitude below which every integer is exact in f64.
const EXACT_INT: f64 = 9_007_199_254_740_992.0;

fn write_number(out: &mut String, x: f64) {
    if !x.is_finite() {
        out.push_str("null");
    } else if x == x.trunc() && x.abs() < EXACT_INT {
        let _ = write!(out, "{}", x as i64);
    } else {
        // Rust's shortest-roundtrip Debug form ("0.1", "1.5e-9") is valid
        // JSON for every finite non-integer f64.
        let _ = write!(out, "{x:?}");
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_render() {
        assert_eq!(Json::Null.render(), "null\n");
        assert_eq!(Json::Bool(true).render(), "true\n");
        assert_eq!(Json::int(25).render(), "25\n");
        assert_eq!(Json::num(0.5).render(), "0.5\n");
        assert_eq!(Json::num(-3.0).render(), "-3\n");
        assert_eq!(Json::num(f64::NAN).render(), "null\n");
        assert_eq!(Json::num(f64::INFINITY).render(), "null\n");
    }

    #[test]
    fn strings_escape() {
        let s = Json::str("a\"b\\c\nd\te\u{1}");
        assert_eq!(s.render(), "\"a\\\"b\\\\c\\nd\\te\\u0001\"\n");
    }

    #[test]
    fn object_preserves_order_and_nests() {
        let j = Json::obj(vec![
            ("z", Json::int(1)),
            ("a", Json::Arr(vec![Json::int(2), Json::Null])),
            ("empty", Json::Obj(Vec::new())),
        ]);
        let rendered = j.render();
        // z must come before a (insertion order, not sorted).
        assert!(rendered.find("\"z\"").unwrap() < rendered.find("\"a\"").unwrap());
        assert!(rendered.contains("\"empty\": {}"));
        assert!(rendered.contains("[\n    2,\n    null\n  ]"));
    }

    #[test]
    fn exact_integers_have_no_fraction() {
        assert_eq!(Json::num(1200.0).render(), "1200\n");
        // Large non-exact magnitudes fall back to float formatting.
        let big = Json::num(1e300).render();
        assert!(big.starts_with('1'), "{big}");
        assert!(!big.contains("null"));
    }
}
