//! Balanced partition — Section 3 of the paper (Algorithms 1 and 2).
//!
//! `slice_partition` (Algorithm 1) greedily cuts a horizontal slab into
//! column runs whose opt₁ stays below a tolerance σ, recursing on the
//! transpose when a single column already exceeds it.
//!
//! `partition` (Algorithm 2) grows row-slabs while `slice_partition` of
//! the slab uses at most ⌈1/γ⌉ pieces, emitting the last partition that
//! fit and restarting — producing the "simplicial partition for SSE"
//! (Definition 6): few blocks, each with small opt₁, such that any
//! k-segmentation intersects only a few of them.
//!
//! All opt₁ queries are O(1) via [`PrefixStats`]; `partition` additionally
//! uses exponential-growth + binary-search slab probing, bringing the
//! overall cost to O((|B| log n) · m_probe) instead of the naive
//! O(n_slab · m) per slab (see DESIGN.md §Perf).

use crate::signal::{PrefixStats, Rect};

/// Algorithm 1 — SLICEPARTITION(D, σ) restricted to `slab` (a rectangle
/// of contiguous rows of the original signal). Returns disjoint
/// rectangles covering `slab`, each with opt₁ ≤ σ (guaranteed for every
/// output block; single cells have opt₁ = 0 so recursion terminates).
pub fn slice_partition(stats: &PrefixStats, slab: Rect, sigma: f64) -> Vec<Rect> {
    let mut out = Vec::new();
    slice_partition_into(stats, slab, sigma, false, &mut out);
    out
}

/// Internal: `transposed == true` means `slab` is interpreted with axes
/// swapped (we never materialise a transposed signal; opt₁ queries are
/// symmetric, only the cut axis changes).
fn slice_partition_into(
    stats: &PrefixStats,
    slab: Rect,
    sigma: f64,
    transposed: bool,
    out: &mut Vec<Rect>,
) {
    // Columns of the (possibly transposed) slab.
    let (c_lo, c_hi) = if transposed { (slab.r0, slab.r1) } else { (slab.c0, slab.c1) };
    let mut c0 = c_lo;
    while c0 <= c_hi {
        let single = col_range(&slab, c0, c0, transposed);
        // Single-cell blocks are emitted unconditionally: their true opt₁
        // is 0, but inclusion–exclusion roundoff can report a tiny
        // positive value, which with σ = 0 would otherwise recurse
        // forever.
        if single.area() > 1 && stats.opt1(&single) > sigma {
            // A single column exceeds tolerance → recurse on its transpose
            // (cut it along the other axis). The recursion flips axes once;
            // a 1-wide strip cut along its long axis yields runs whose
            // single cells have opt₁ = 0, so depth is bounded by 2.
            slice_partition_into(stats, single, sigma, !transposed, out);
            c0 += 1;
            continue;
        }
        // Greedy grow: largest c1 with opt₁(cols c0..=c1) ≤ σ.
        // opt₁ is monotone non-decreasing when extending a block
        // (Observation 9 ⇒ opt₁(A∪B) ≥ opt₁(A)), so binary search applies.
        let mut lo = c0; // known good
        let mut hi = c_hi;
        while lo < hi {
            let mid = lo + (hi - lo + 1) / 2;
            let rect = col_range(&slab, c0, mid, transposed);
            if stats.opt1(&rect) <= sigma {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        out.push(col_range(&slab, c0, lo, transposed));
        c0 = lo + 1;
    }
}

/// The sub-rectangle of `slab` spanning (transposed-)columns `a..=b`.
#[inline]
fn col_range(slab: &Rect, a: usize, b: usize, transposed: bool) -> Rect {
    if transposed {
        Rect::new(a, b, slab.c0, slab.c1)
    } else {
        Rect::new(slab.r0, slab.r1, a, b)
    }
}

/// Count the pieces `slice_partition` would produce, stopping early once
/// the count exceeds `limit` (saves the Vec and the full scan).
pub fn slice_partition_count_exceeds(
    stats: &PrefixStats,
    slab: Rect,
    sigma: f64,
    limit: usize,
) -> bool {
    let mut count = 0usize;
    count_slices(stats, slab, sigma, false, limit, &mut count);
    count > limit
}

fn count_slices(
    stats: &PrefixStats,
    slab: Rect,
    sigma: f64,
    transposed: bool,
    limit: usize,
    count: &mut usize,
) {
    let (c_lo, c_hi) = if transposed { (slab.r0, slab.r1) } else { (slab.c0, slab.c1) };
    let mut c0 = c_lo;
    while c0 <= c_hi {
        if *count > limit {
            return;
        }
        let single = col_range(&slab, c0, c0, transposed);
        if single.area() > 1 && stats.opt1(&single) > sigma {
            count_slices(stats, single, sigma, !transposed, limit, count);
            c0 += 1;
            continue;
        }
        let mut lo = c0;
        let mut hi = c_hi;
        while lo < hi {
            let mid = lo + (hi - lo + 1) / 2;
            if stats.opt1(&col_range(&slab, c0, mid, transposed)) <= sigma {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        *count += 1;
        c0 = lo + 1;
    }
}

/// Report on a balanced partition (Definition 6's three constants,
/// measured rather than bounded).
#[derive(Clone, Debug)]
pub struct PartitionReport {
    pub blocks: usize,
    pub max_opt1: f64,
    pub tolerance: f64,
}

/// Algorithm 2 — PARTITION(D, γ, σ). Partitions the whole signal into
/// rectangles, each with opt₁ ≤ γ²σ, grouped into row-slabs such that any
/// k-segmentation intersects O(kα/γ) of them (Lemma 7).
///
/// `gamma` ∈ (0, 1); `sigma ≥ 0` (σ = 0 degrades gracefully: blocks are
/// maximal constant runs).
pub fn partition(stats: &PrefixStats, gamma: f64, sigma: f64) -> Vec<Rect> {
    partition_in(stats, stats.bounds(), gamma, sigma)
}

/// [`partition`] restricted to `region`: the sharded builders partition
/// each row-band in place against the one shared `PrefixStats`, emitting
/// blocks directly in global coordinates (no cropped signals, no
/// per-shard integral images, no row-offset fixups afterwards). For
/// `region == stats.bounds()` this is exactly [`partition`].
pub fn partition_in(stats: &PrefixStats, region: Rect, gamma: f64, sigma: f64) -> Vec<Rect> {
    assert!(gamma > 0.0 && gamma <= 1.0, "gamma must be in (0,1]");
    assert!(sigma >= 0.0);
    let tol = gamma * gamma * sigma;
    // Blocks allowed per slab. The theoretical 1/γ can fall below the
    // column count m; for narrow matrices with decorrelated columns
    // (tabular data) that forces every slab into the single-row fallback
    // and the partition degenerates to ~N blocks, so in the narrow regime
    // (m within 2× of 1/γ) we allow one block per column. Wide signals
    // keep the 1/γ limit — raising it there makes slabs so tall that
    // horizontal query boundaries cross hundreds of blocks (measured in
    // EXPERIMENTS.md §Calibration).
    let base = (1.0 / gamma).ceil() as usize;
    let m = region.width();
    let limit = if m <= 2 * base { base.max(m) } else { base };
    let slab = |r0: usize, r1: usize| Rect::new(r0, r1, region.c0, region.c1);
    let mut out: Vec<Rect> = Vec::new();
    let mut r0 = region.r0;
    while r0 <= region.r1 {
        // Single-row slab first (the unconditional base case).
        let first = slice_partition(stats, slab(r0, r0), tol);
        if first.len() > limit {
            // Yellow case in Fig. 2: emit the over-long single-row
            // partition itself and move on.
            out.extend(first);
            r0 += 1;
            continue;
        }
        // Grow the slab: exponential probe + binary search for the largest
        // r1 such that the slab partitions into ≤ limit pieces. Piece count
        // is monotone-ish in slab height for fixed tolerance (adding rows
        // only adds variance per Observation 9); exactness of the maximal
        // extent is not required for correctness — every emitted partition
        // is verified to fit the limit.
        let mut good_r1 = r0;
        let mut good_parts = first;
        let mut step = 1usize;
        loop {
            let probe = (good_r1 + step).min(region.r1);
            if probe == good_r1 {
                break;
            }
            let parts = slice_partition(stats, slab(r0, probe), tol);
            if parts.len() <= limit {
                good_r1 = probe;
                good_parts = parts;
                if probe == region.r1 {
                    break;
                }
                step *= 2;
            } else {
                break;
            }
        }
        // Binary refine between good_r1 and good_r1 + step.
        let mut hi = (good_r1 + step).min(region.r1);
        let mut lo = good_r1;
        while lo < hi {
            let mid = lo + (hi - lo + 1) / 2;
            let parts = slice_partition(stats, slab(r0, mid), tol);
            if parts.len() <= limit {
                lo = mid;
                good_parts = parts;
            } else {
                hi = mid - 1;
            }
        }
        out.extend(good_parts);
        r0 = lo + 1;
    }
    out
}

/// Validate Definition 6 on a concrete partition; used by tests and the
/// pipeline's self-checks.
pub fn report(stats: &PrefixStats, blocks: &[Rect], tol: f64) -> PartitionReport {
    let max_opt1 = blocks
        .iter()
        .map(|b| stats.opt1(b))
        .fold(0.0f64, f64::max);
    PartitionReport { blocks: blocks.len(), max_opt1, tolerance: tol }
}

/// Check that `blocks` exactly tile `bounds` (disjoint + full area).
pub fn is_exact_tiling(blocks: &[Rect], bounds: Rect) -> bool {
    let area: usize = blocks.iter().map(|b| b.area()).sum();
    if area != bounds.area() {
        return false;
    }
    if !blocks.iter().all(|b| bounds.contains_rect(b)) {
        return false;
    }
    // Disjointness via sweep: O(B²) is fine at our block counts for a
    // validation helper (tests / debug assertions only).
    for i in 0..blocks.len() {
        for j in (i + 1)..blocks.len() {
            if blocks[i].intersects(&blocks[j]) {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::segmentation::random_segmentation;
    use crate::signal::{generate, PrefixStats, Signal};

    #[test]
    fn slice_partition_tiles_and_respects_tolerance() {
        let mut rng = Rng::new(1);
        let sig = generate::smooth(20, 40, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let slab = Rect::new(3, 7, 0, 39);
        for sigma in [0.01, 0.5, 5.0] {
            let parts = slice_partition(&stats, slab, sigma);
            assert!(is_exact_tiling(&parts, slab), "sigma {sigma}");
            for p in &parts {
                assert!(stats.opt1(p) <= sigma + 1e-12, "sigma {sigma} block {p:?}");
            }
        }
    }

    #[test]
    fn slice_partition_constant_signal_single_block() {
        let sig = Signal::constant(10, 30, 4.0);
        let stats = PrefixStats::new(&sig);
        let slab = sig.bounds();
        let parts = slice_partition(&stats, slab, 0.0);
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0], slab);
    }

    #[test]
    fn slice_partition_handles_hot_column() {
        // One column with huge variance forces the transpose recursion.
        let sig = Signal::from_fn(16, 8, |r, c| if c == 3 { (r as f64) * 100.0 } else { 1.0 });
        let stats = PrefixStats::new(&sig);
        let parts = slice_partition(&stats, sig.bounds(), 0.5);
        assert!(is_exact_tiling(&parts, sig.bounds()));
        for p in &parts {
            assert!(stats.opt1(p) <= 0.5 + 1e-12);
        }
        // The hot column must have been split into multiple vertical runs.
        let hot: Vec<_> = parts.iter().filter(|p| p.c0 == 3 && p.c1 == 3).collect();
        assert!(hot.len() > 1);
    }

    #[test]
    fn partition_tiles_whole_signal() {
        let mut rng = Rng::new(5);
        let sig = generate::image_like(48, 36, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let blocks = partition(&stats, 0.25, 10.0);
        assert!(is_exact_tiling(&blocks, sig.bounds()));
        let rep = report(&stats, &blocks, 0.25 * 0.25 * 10.0);
        assert!(rep.max_opt1 <= rep.tolerance + 1e-9);
    }

    #[test]
    fn partition_zero_sigma_gives_constant_blocks() {
        let mut rng = Rng::new(6);
        let (sig, pieces) = generate::piecewise_constant(30, 30, 5, 0.0, &mut rng);
        let stats = PrefixStats::new(&sig);
        let blocks = partition(&stats, 0.5, 0.0);
        assert!(is_exact_tiling(&blocks, sig.bounds()));
        for b in &blocks {
            assert!(stats.opt1(b) < 1e-9);
        }
        // Far fewer blocks than cells: constant regions merge.
        assert!(blocks.len() < sig.len() / 4, "{} blocks", blocks.len());
        let _ = pieces;
    }

    #[test]
    fn partition_in_tiles_the_region_only() {
        // Region-scoped partitioning against shared stats: blocks tile
        // exactly the band (in global coordinates) and respect the
        // tolerance — the shard path's invariant.
        let mut rng = Rng::new(21);
        let sig = generate::smooth(60, 36, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let region = Rect::new(12, 47, 0, 35);
        let tol = 0.25 * 0.25 * 5.0;
        let blocks = partition_in(&stats, region, 0.25, 5.0);
        assert!(is_exact_tiling(&blocks, region));
        for b in &blocks {
            assert!(region.contains_rect(b));
            assert!(stats.opt1(b) <= tol + 1e-9);
        }
    }

    #[test]
    fn partition_smaller_sigma_more_blocks() {
        let mut rng = Rng::new(9);
        let sig = generate::smooth(40, 40, 4, &mut rng);
        let stats = PrefixStats::new(&sig);
        let coarse = partition(&stats, 0.25, 100.0).len();
        let fine = partition(&stats, 0.25, 0.1).len();
        assert!(fine >= coarse, "fine {fine} coarse {coarse}");
    }

    #[test]
    fn intersection_count_is_small() {
        // Empirical Definition 6(iii): random k-segmentations intersect a
        // small fraction of blocks.
        let mut rng = Rng::new(12);
        let sig = generate::smooth(50, 50, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let gamma = 0.2;
        let sigma = stats.opt1(&sig.bounds()) / 50.0;
        let blocks = partition(&stats, gamma, sigma);
        assert!(blocks.len() >= 4);
        let k = 5;
        let mut worst = 0usize;
        for _ in 0..20 {
            let s = random_segmentation(sig.bounds(), k, &mut rng);
            let hit = blocks.iter().filter(|b| s.intersects_rect(b)).count();
            worst = worst.max(hit);
        }
        // Any guillotine k-segmentation has ≤ 2(k−1) cut lines; blocks are
        // grouped in row slabs — the bound from Lemma 7 is O(kα/γ). We
        // check the much simpler empirical property: < half the blocks.
        assert!(
            worst <= (blocks.len() / 2).max(4 * k),
            "worst {worst} of {}",
            blocks.len()
        );
    }

    #[test]
    fn count_exceeds_matches_full_run() {
        let mut rng = Rng::new(15);
        let sig = generate::smooth(16, 30, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let slab = Rect::new(0, 15, 0, 29);
        for sigma in [0.05, 0.5, 5.0] {
            let full = slice_partition(&stats, slab, sigma).len();
            for limit in [1, 3, full.saturating_sub(1).max(1), full, full + 3] {
                assert_eq!(
                    slice_partition_count_exceeds(&stats, slab, sigma, limit),
                    full > limit,
                    "sigma {sigma} limit {limit} full {full}"
                );
            }
        }
    }
}
