//! Static analysis for the crate's own sources: the determinism &
//! panic-freedom lint behind `sigtree lint`.
//!
//! The paper's guarantee (PAPER.md, Theorem 10) and the repo's standing
//! bit-identity constraint (ROADMAP) are enforced *dynamically* by the
//! [`crate::audit`] engine and the differential integration suites. This
//! module adds the missing *static* layer: a std-only, hand-rolled pass
//! over `rust/src/**` (comment/string-aware line scanner, no external
//! crates — the same offline discipline as [`crate::json`]) that denies
//! the constructs those dynamic checks cannot see until they fire:
//!
//! * `panic` — `.unwrap()` / `.expect(..)` / `panic!`-family in non-test
//!   library code. Serving-grade engines return [`crate::error::Result`].
//! * `det-order` / `det-clock` / `det-thread` — `HashMap`/`HashSet`,
//!   wall-clock / thread-id / env reads, and raw `std::thread` inside
//!   the deterministic modules ([`DETERMINISTIC_MODULES`]). Float
//!   reductions must go through the order-preserving
//!   [`crate::par::parallel_map`] / left-fold idiom; raw threads are how
//!   nondeterministic reduction orders sneak in.
//! * `unsafe-safety` — every `unsafe` needs an adjacent `// SAFETY:`.
//! * `error-discipline` — public fns must not return `Result<_, String>`
//!   (the PR-6 `StreamingCoreset::finish` lesson, generalized).
//! * `shim-delegation` — `#[deprecated]` `build*` shims must still
//!   delegate to their `construct*` twins.
//! * `allow-hygiene` — escape hatches must be well-formed and earn
//!   their keep.
//! * `index-hot` — per-element slice/array indexing on the hot kernel
//!   paths (`runtime/`, `signal/stats.rs`), where it is both a panic
//!   path and a bounds check the autovectorizer must hoist; range
//!   slices (`&xs[a..b]`) are exempt.
//!
//! Any match can be waived inline with
//! `// lint:allow(<rule>) -- <reason>` on the same line or in the
//! comment block directly above; the directive must open its comment
//! (mid-sentence mentions are prose), a reason is mandatory, and a
//! waiver that suppresses nothing is itself a finding. Reports are
//! deterministic: sorted walk order, relative paths, no timestamps —
//! byte-identical across runs by construction.

mod rules;
mod scanner;

use std::collections::BTreeSet;
use std::fs;
use std::path::{Path, PathBuf};

use crate::cli::Args;
use crate::error::{Context, Result};
use crate::json::Json;
use crate::{bail, ensure};

pub use rules::{is_test_path, rule_id, RuleInfo, DETERMINISTIC_MODULES, RULES};

/// One lint finding: rule, file (relative to the lint root, `/`
/// separators), 1-based line, and a human-readable message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
}

/// Configuration for one lint run. Layering matches the engine config:
/// CLI flags override the `--config` file, which overrides defaults
/// ([`RULES`]); in the shared JSON config file the knobs live under a
/// `"lint"` key next to the engine keys (see
/// [`crate::engine::EngineConfig`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LintConfig {
    /// Directory to scan; `None` auto-detects `rust/src` then `src`.
    pub root: Option<String>,
    /// Rules to force on (wins over `disable`; turns on opt-in rules).
    pub enable: Vec<String>,
    /// Rules to turn off.
    pub disable: Vec<String>,
}

impl LintConfig {
    pub fn new() -> Self {
        Self::default()
    }

    /// Builder-style root override.
    pub fn with_root(mut self, root: &str) -> Self {
        self.root = Some(root.to_string());
        self
    }

    /// Builder-style per-rule toggle.
    pub fn with_rule(mut self, id: &str, on: bool) -> Self {
        if on {
            self.enable.push(id.to_string());
        } else {
            self.disable.push(id.to_string());
        }
        self
    }

    /// Reject unknown rule names early, listing the valid ids.
    pub fn validate(&self) -> Result<()> {
        for name in self.enable.iter().chain(self.disable.iter()) {
            ensure!(
                rules::rule_id(name).is_some(),
                "unknown lint rule '{name}'; valid rules: {}",
                RULES.iter().map(|r| r.id).collect::<Vec<_>>().join(", ")
            );
        }
        Ok(())
    }

    /// The effective rule set: defaults, minus `disable`, plus `enable`
    /// (an explicit enable wins over a disable of the same rule).
    pub fn enabled_rules(&self) -> BTreeSet<&'static str> {
        let mut set: BTreeSet<&'static str> =
            RULES.iter().filter(|r| r.default_on).map(|r| r.id).collect();
        for name in &self.disable {
            if let Some(id) = rules::rule_id(name) {
                set.remove(id);
            }
        }
        for name in &self.enable {
            if let Some(id) = rules::rule_id(name) {
                set.insert(id);
            }
        }
        set
    }

    /// Apply a JSON document: either a bare lint object
    /// (`{"root": .., "enable": [..], "disable": [..]}`) or an engine
    /// config file carrying the same object under its `"lint"` key.
    pub fn apply_json(&mut self, doc: &Json) -> Result<()> {
        let section = doc.get("lint").unwrap_or(doc);
        let Json::Obj(pairs) = section else {
            bail!("lint config must be a JSON object");
        };
        for (key, value) in pairs {
            match key.as_str() {
                "root" => match value.as_str() {
                    Some(s) => self.root = Some(s.to_string()),
                    None => bail!("lint config 'root' must be a string"),
                },
                "enable" => self.enable.extend(str_list(value, "enable")?),
                "disable" => self.disable.extend(str_list(value, "disable")?),
                other => bail!(
                    "unknown lint config key '{other}' (valid: root, enable, disable; \
                     engine keys belong beside a nested \"lint\" object)"
                ),
            }
        }
        Ok(())
    }

    /// CLI layering: `--config <file>` first, then `--root`,
    /// `--enable a,b`, `--disable a,b` on top.
    pub fn from_args(args: &Args) -> Result<Self> {
        let mut config = LintConfig::default();
        if let Some(path) = args.get("config") {
            let text = fs::read_to_string(path)
                .with_context(|| format!("reading lint config {path}"))?;
            let doc = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
            config.apply_json(&doc)?;
        }
        if let Some(root) = args.get("root") {
            config.root = Some(root.to_string());
        }
        if let Some(list) = args.get("enable") {
            config.enable.extend(split_list(list));
        }
        if let Some(list) = args.get("disable") {
            config.disable.extend(split_list(list));
        }
        config.validate()?;
        Ok(config)
    }

    fn resolved_root(&self) -> Result<PathBuf> {
        if let Some(root) = &self.root {
            let path = PathBuf::from(root);
            ensure!(path.is_dir(), "lint root '{root}' is not a directory");
            return Ok(path);
        }
        for candidate in ["rust/src", "src"] {
            let path = PathBuf::from(candidate);
            if path.is_dir() {
                return Ok(path);
            }
        }
        bail!("no lint root found: pass --root <dir> or run from the repo root (rust/src)")
    }
}

fn split_list(list: &str) -> Vec<String> {
    list.split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect()
}

fn str_list(value: &Json, key: &str) -> Result<Vec<String>> {
    let Json::Arr(items) = value else {
        bail!("lint config '{key}' must be an array of strings");
    };
    let mut out = Vec::new();
    for item in items {
        match item.as_str() {
            Some(s) => out.push(s.to_string()),
            None => bail!("lint config '{key}' must be an array of strings"),
        }
    }
    Ok(out)
}

/// Outcome of linting a single source file.
#[derive(Debug)]
pub struct FileReport {
    pub findings: Vec<Finding>,
    /// Matches waived by a well-formed `lint:allow`.
    pub suppressed: usize,
    /// Physical lines scanned.
    pub lines: usize,
}

/// Lint one in-memory source file — the seam `run` and the fixture
/// tests share. `rel_path` uses `/` separators relative to the lint
/// root; it drives the module classification (deterministic modules,
/// test exemptions).
pub fn lint_source(rel_path: &str, text: &str, enabled: &BTreeSet<&'static str>) -> FileReport {
    let lines = scanner::scan(text);
    let file = rules::lint_lines(rel_path, &lines, enabled);
    FileReport { findings: file.findings, suppressed: file.suppressed, lines: lines.len() }
}

/// The deterministic, JSON-serializable result of one lint run.
#[derive(Debug, Clone, PartialEq)]
pub struct LintReport {
    /// Root that was scanned, as configured (normalized separators).
    pub root: String,
    /// Number of `.rs` files scanned.
    pub files: usize,
    /// Total physical lines scanned.
    pub lines: usize,
    /// Matches waived by well-formed `lint:allow` directives.
    pub suppressed: usize,
    /// The rule ids that were active, sorted.
    pub enabled: Vec<&'static str>,
    /// All findings, sorted by (file, line, rule, message).
    pub findings: Vec<Finding>,
}

impl LintReport {
    /// True when the tree lints clean.
    pub fn pass(&self) -> bool {
        self.findings.is_empty()
    }

    /// Machine-readable report (rendered with [`crate::json`]); contains
    /// no timestamps or absolute finding paths, so repeated runs are
    /// byte-identical.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str("sigtree-lint-v1")),
            ("root", Json::str(self.root.clone())),
            ("files", Json::int(self.files)),
            ("lines", Json::int(self.lines)),
            ("rules", Json::Arr(self.enabled.iter().map(|r| Json::str(*r)).collect())),
            ("suppressed", Json::int(self.suppressed)),
            ("pass", Json::Bool(self.pass())),
            (
                "findings",
                Json::Arr(
                    self.findings
                        .iter()
                        .map(|f| {
                            Json::obj(vec![
                                ("rule", Json::str(f.rule)),
                                ("file", Json::str(f.file.clone())),
                                ("line", Json::int(f.line)),
                                ("message", Json::str(f.message.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Human-readable summary, one line per finding.
    pub fn summary(&self) -> String {
        let mut parts = vec![format!(
            "lint: {} file(s), {} line(s), {} finding(s), {} suppressed by lint:allow",
            self.files,
            self.lines,
            self.findings.len(),
            self.suppressed
        )];
        for f in &self.findings {
            parts.push(format!("  [{}] {}:{} — {}", f.rule, f.file, f.line, f.message));
        }
        parts.join("\n")
    }
}

/// Run the lint over every `.rs` file under the configured root.
/// Deterministic by construction: files are walked in sorted order and
/// findings are globally sorted.
pub fn run(config: &LintConfig) -> Result<LintReport> {
    config.validate()?;
    let root = config.resolved_root()?;
    let mut files = Vec::new();
    collect_sources(&root, &root, &mut files)?;
    files.sort();
    let enabled = config.enabled_rules();
    let mut findings = Vec::new();
    let mut lines = 0usize;
    let mut suppressed = 0usize;
    for rel in &files {
        let text =
            fs::read_to_string(root.join(rel)).with_context(|| format!("reading {rel}"))?;
        let file = lint_source(rel, &text, &enabled);
        findings.extend(file.findings);
        lines += file.lines;
        suppressed += file.suppressed;
    }
    findings.sort_by(|a, b| {
        (&a.file, a.line, a.rule, &a.message).cmp(&(&b.file, b.line, b.rule, &b.message))
    });
    Ok(LintReport {
        root: root.to_string_lossy().replace('\\', "/"),
        files: files.len(),
        lines,
        suppressed,
        enabled: enabled.into_iter().collect(),
        findings,
    })
}

fn collect_sources(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
    let mut entries = Vec::new();
    for entry in fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))? {
        entries.push(entry.with_context(|| format!("reading {}", dir.display()))?.path());
    }
    entries.sort();
    for path in entries {
        if path.is_dir() {
            if path.file_name().map_or(false, |n| n == "target") {
                continue;
            }
            collect_sources(root, &path, out)?;
        } else if path.extension().map_or(false, |e| e == "rs") {
            let rel = path.strip_prefix(root).unwrap_or(&path);
            let rel: Vec<String> =
                rel.components().map(|c| c.as_os_str().to_string_lossy().into_owned()).collect();
            out.push(rel.join("/"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings(rel: &str, text: &str) -> Vec<Finding> {
        lint_source(rel, text, &LintConfig::default().enabled_rules()).findings
    }

    fn rules_of(found: &[Finding]) -> Vec<&'static str> {
        found.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn panic_rule_catches_unwrap_expect_and_macros() {
        let src = "pub fn f(v: Option<u32>) -> u32 {\n    v.unwrap()\n}\nfn g() {\n    panic!(\"boom\");\n}\n";
        let found = findings("tree/mod.rs", src);
        assert_eq!(rules_of(&found), vec!["panic", "panic"]);
        assert_eq!((found[0].line, found[1].line), (2, 5));
    }

    #[test]
    fn panic_rule_skips_json_parser_cursor_helper() {
        assert!(findings("json.rs", "fn f(&mut self) { self.expect(b) }\n").is_empty());
        assert_eq!(rules_of(&findings("json.rs", "fn f(p: &mut P) { p.expect(b) }\n")), ["panic"]);
    }

    #[test]
    fn cfg_test_and_test_paths_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { None::<u32>.unwrap(); }\n}\n";
        assert!(findings("coreset/mod.rs", src).is_empty());
        assert!(findings("proptest.rs", "fn f() { panic!(\"x\") }\n").is_empty());
        assert!(findings("tests/helper.rs", "fn f() { panic!(\"x\") }\n").is_empty());
    }

    #[test]
    fn allow_suppresses_and_is_counted() {
        let src = "fn f(v: Option<u32>) {\n    // lint:allow(panic) -- documented invariant\n    v.unwrap();\n}\n";
        let report = lint_source("par/mod.rs", src, &LintConfig::default().enabled_rules());
        assert!(report.findings.is_empty());
        assert_eq!(report.suppressed, 1);
        let same_line = "fn f(v: Option<u32>) { v.unwrap() } // lint:allow(panic) -- invariant\n";
        assert!(findings("par/mod.rs", same_line).is_empty());
    }

    #[test]
    fn allow_hygiene_flags_malformed_unknown_and_dangling() {
        let missing = "fn f(v: Option<u32>) {\n    // lint:allow(panic)\n    v.unwrap();\n}\n";
        assert_eq!(rules_of(&findings("a.rs", missing)), vec!["allow-hygiene", "panic"]);
        let unknown = "// lint:allow(bogus) -- why\nfn f() {}\n";
        assert_eq!(rules_of(&findings("a.rs", unknown)), vec!["allow-hygiene"]);
        let dangling = "// lint:allow(panic) -- nothing here panics\nfn f() {}\n";
        let found = findings("a.rs", dangling);
        assert_eq!(rules_of(&found), vec!["allow-hygiene"]);
        assert!(found[0].message.contains("dangling"));
    }

    #[test]
    fn det_rules_fire_only_in_deterministic_modules() {
        let src = "use std::collections::HashMap;\nfn f() { let t = std::time::Instant::now(); }\nfn g() { std::thread::spawn(|| {}); }\n";
        let found = findings("coreset/x.rs", src);
        assert_eq!(rules_of(&found), vec!["det-order", "det-clock", "det-thread"]);
        assert!(findings("runtime/x.rs", src).is_empty());
    }

    #[test]
    fn unsafe_requires_adjacent_safety_comment() {
        let bad = "fn f() {\n    let x = unsafe { core() };\n}\n";
        assert_eq!(rules_of(&findings("par/mod.rs", bad)), vec!["unsafe-safety"]);
        let good = "fn f() {\n    // SAFETY: justified at length.\n    let x = unsafe { core() };\n}\n";
        assert!(findings("par/mod.rs", good).is_empty());
        let same_line = "fn f() { unsafe { core() } } // SAFETY: fits on one line\n";
        assert!(findings("par/mod.rs", same_line).is_empty());
    }

    #[test]
    fn error_discipline_flags_public_stringly_results() {
        let bad = "pub fn load() -> Result<(), String> {\n    Ok(())\n}\n";
        assert_eq!(rules_of(&findings("audit/mod.rs", bad)), vec!["error-discipline"]);
        let private = "fn load() -> Result<(), String> {\n    Ok(())\n}\n";
        assert!(findings("audit/mod.rs", private).is_empty());
    }

    #[test]
    fn shim_delegation_checks_deprecated_build_fns() {
        let bad = "#[deprecated(note = \"use construct\")]\npub fn build_x(v: u32) -> u32 {\n    other(v)\n}\n";
        assert_eq!(rules_of(&findings("coreset/mod.rs", bad)), vec!["shim-delegation"]);
        let good = "#[deprecated(note = \"renamed\")]\npub fn build_x(v: u32) -> u32 {\n    Self::construct_x(v)\n}\n";
        assert!(findings("coreset/mod.rs", good).is_empty());
    }

    #[test]
    fn index_rule_scopes_to_hot_kernel_paths() {
        let src = "fn f(v: &[f64]) -> f64 { v[0] }\n";
        // On by default on the hot kernel paths…
        assert_eq!(rules_of(&findings("runtime/x.rs", src)), vec!["index-hot"]);
        assert_eq!(rules_of(&findings("signal/stats.rs", src)), vec!["index-hot"]);
        // …but nowhere else — not even the deterministic modules.
        assert!(findings("coreset/x.rs", src).is_empty());
        assert!(findings("signal/mod.rs", src).is_empty());
        // Range slices are one bounds check per slice, not per element.
        let ranged = "fn f(v: &[f64]) -> f64 { sum(&v[1..4]) }\n";
        assert!(findings("runtime/x.rs", ranged).is_empty());
        // An unmatched bracket on the line is conservatively flagged.
        let open = "fn f(v: &[f64], i: usize) -> f64 {\n    v[long(\n        i)]\n}\n";
        assert_eq!(rules_of(&findings("runtime/x.rs", open)), vec!["index-hot"]);
        let disabled = LintConfig::default().with_rule("index-hot", false).enabled_rules();
        assert!(lint_source("runtime/x.rs", src, &disabled).findings.is_empty());
    }

    #[test]
    fn literals_and_comments_never_match() {
        let src = "fn f() -> &'static str {\n    // calling .unwrap() here would be bad\n    \"panic!(no) .unwrap()\"\n}\n";
        assert!(findings("coreset/x.rs", src).is_empty());
    }

    #[test]
    fn config_validation_and_layering() {
        assert!(LintConfig::default().with_rule("bogus", true).validate().is_err());
        let disabled = LintConfig::default().with_rule("panic", false).enabled_rules();
        assert!(!disabled.contains("panic"));

        let mut config = LintConfig::default();
        let doc = Json::parse(
            "{\"k\": 4, \"lint\": {\"root\": \"rust/src\", \"disable\": [\"panic\"]}}",
        )
        .expect("valid json");
        config.apply_json(&doc).expect("nested lint section applies");
        assert_eq!(config.root.as_deref(), Some("rust/src"));
        assert_eq!(config.disable, vec!["panic".to_string()]);

        let mut config = LintConfig::default();
        let doc = Json::parse("{\"enable\": [\"index-hot\"]}").expect("valid json");
        config.apply_json(&doc).expect("bare lint object applies");
        assert!(config.enabled_rules().contains("index-hot"));

        let mut config = LintConfig::default();
        let doc = Json::parse("{\"k\": 4}").expect("valid json");
        assert!(config.apply_json(&doc).is_err());
    }

    #[test]
    fn rule_table_is_consistent() {
        for rule in RULES {
            assert_eq!(rule_id(rule.id), Some(rule.id));
        }
        assert!(rule_id("index-hot").is_some());
        assert!(rule_id("nope").is_none());
    }
}
