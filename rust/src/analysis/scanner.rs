//! Comment/string-aware source scanner for [`crate::analysis`].
//!
//! Hand-rolled and std-only (same offline discipline as [`crate::json`]):
//! the scanner walks a Rust source file character by character and emits
//! one [`ScannedLine`] per physical line, where
//!
//! * `code` holds the line with comments removed and the *contents* of
//!   string / char literals dropped (the delimiting quotes are kept), so
//!   rule patterns never match inside literals or prose;
//! * `comment` holds the text of the trailing `//` comment, which is
//!   where `lint:allow(...)` directives and `// SAFETY:` justifications
//!   live;
//! * `in_test` marks lines inside a `#[cfg(test)]` item, which every
//!   rule skips.
//!
//! Handled literal forms: `"…"`, `b"…"`, `r"…"`, `r#"…"#` (any hash
//! depth), `br#"…"#`, `'x'`, `'\n'`-style escapes, and the
//! lifetime-vs-char-literal ambiguity (`'a` in `<'a>` is not a literal).
//! Block comments `/* … */` nest, span lines, and are discarded (a
//! `SAFETY:` note must be a `//` comment to be seen). Known limits are
//! documented in DESIGN.md §Static analysis.

/// One physical source line after masking.
#[derive(Debug, Clone, Default)]
pub struct ScannedLine {
    /// Code with comments stripped and literal contents dropped.
    pub code: String,
    /// Text of the trailing `//` comment (without the slashes), if any.
    pub comment: Option<String>,
    /// True when the line sits inside a `#[cfg(test)]` item.
    pub in_test: bool,
}

impl ScannedLine {
    /// A line that carries no code after masking (blank or comment-only).
    pub fn is_code_free(&self) -> bool {
        self.code.trim().is_empty()
    }
}

#[derive(Clone, Copy)]
enum Mode {
    /// Ordinary code.
    Code,
    /// Inside a (nestable) block comment, at the given depth.
    Block(u32),
    /// Inside a string literal; `Some(h)` is a raw string closed by
    /// `"` followed by `h` hashes, `None` a normal escaped string.
    Str(Option<u32>),
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
}

/// Scan `text` into masked lines (state persists across lines, so
/// multi-line strings and block comments are handled).
pub fn scan(text: &str) -> Vec<ScannedLine> {
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut lines: Vec<ScannedLine> = Vec::new();
    let mut code = String::new();
    let mut comment: Option<String> = None;
    let mut mode = Mode::Code;
    let mut i = 0;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            lines.push(ScannedLine {
                code: std::mem::take(&mut code),
                comment: comment.take(),
                in_test: false,
            });
            i += 1;
            continue;
        }
        match mode {
            Mode::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    // Line comment: capture its text for directive parsing.
                    let mut txt = String::new();
                    i += 2;
                    while i < n && chars[i] != '\n' {
                        txt.push(chars[i]);
                        i += 1;
                    }
                    comment = Some(txt);
                    continue;
                }
                if c == '/' && next == Some('*') {
                    mode = Mode::Block(1);
                    code.push(' ');
                    i += 2;
                    continue;
                }
                if c == '"' {
                    code.push('"');
                    mode = Mode::Str(None);
                    i += 1;
                    continue;
                }
                // Raw strings: r"…", r#"…"#, br#"…"# (the plain b"…"
                // prefix needs no special care — `b` is emitted as code
                // and the quote takes the normal-string path above).
                if (c == 'r' || c == 'b') && !prev_is_ident(&chars, i) {
                    let mut j = i + 1;
                    if c == 'b' && chars.get(j) == Some(&'r') {
                        j += 1;
                    }
                    if j > i + 1 || c == 'r' {
                        let mut hashes = 0u32;
                        while chars.get(j) == Some(&'#') {
                            hashes += 1;
                            j += 1;
                        }
                        if chars.get(j) == Some(&'"') {
                            for &p in &chars[i..=j] {
                                code.push(p);
                            }
                            mode = Mode::Str(Some(hashes));
                            i = j + 1;
                            continue;
                        }
                    }
                }
                if c == '\'' {
                    // Char literal vs lifetime.
                    if next == Some('\\') {
                        // Escaped char literal: skip to the closing quote.
                        code.push('\'');
                        let mut j = i + 3; // past the escaped character
                        while j < n && chars[j] != '\'' && chars[j] != '\n' {
                            j += 1;
                        }
                        if chars.get(j) == Some(&'\'') {
                            code.push('\'');
                            j += 1;
                        }
                        i = j;
                        continue;
                    }
                    if chars.get(i + 2) == Some(&'\'') && next != Some('\'') {
                        // Plain one-character literal 'x'.
                        code.push_str("''");
                        i += 3;
                        continue;
                    }
                    // Lifetime: emit the tick, the name follows as code.
                    code.push('\'');
                    i += 1;
                    continue;
                }
                code.push(c);
                i += 1;
            }
            Mode::Block(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    mode = Mode::Block(depth + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    mode = if depth == 1 { Mode::Code } else { Mode::Block(depth - 1) };
                    i += 2;
                } else {
                    i += 1;
                }
            }
            Mode::Str(None) => {
                if c == '\\' && chars.get(i + 1) == Some(&'\n') {
                    // Line-continuation escape: let the newline be seen
                    // by the top of the loop so line counts stay right.
                    i += 1;
                } else if c == '\\' {
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    mode = Mode::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            Mode::Str(Some(hashes)) => {
                if c == '"' {
                    let h = hashes as usize;
                    let closed = (0..h).all(|k| chars.get(i + 1 + k) == Some(&'#'));
                    if closed {
                        code.push('"');
                        for _ in 0..h {
                            code.push('#');
                        }
                        mode = Mode::Code;
                        i += 1 + h;
                    } else {
                        i += 1;
                    }
                } else {
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || comment.is_some() {
        lines.push(ScannedLine { code, comment, in_test: false });
    }
    mark_cfg_test(&mut lines);
    lines
}

/// Mark every line belonging to a `#[cfg(test)]` item by balancing the
/// braces of the item that follows the attribute. `#[cfg(test)] use …;`
/// (no braces) ends at the semicolon.
fn mark_cfg_test(lines: &mut [ScannedLine]) {
    let n = lines.len();
    let mut i = 0;
    while i < n {
        if !lines[i].code.contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        let mut depth: i64 = 0;
        let mut started = false;
        let mut j = i;
        while j < n {
            lines[j].in_test = true;
            let mut semi = false;
            for b in lines[j].code.bytes() {
                match b {
                    b'{' => {
                        depth += 1;
                        started = true;
                    }
                    b'}' => depth -= 1,
                    b';' if !started => semi = true,
                    _ => {}
                }
            }
            if (started && depth <= 0) || (!started && semi) {
                break;
            }
            j += 1;
        }
        i = j + 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(text: &str) -> Vec<String> {
        scan(text).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn strips_line_comments_and_keeps_text() {
        let lines = scan("let x = 1; // lint:allow(panic) -- why\n");
        assert_eq!(lines[0].code, "let x = 1; ");
        assert_eq!(lines[0].comment.as_deref(), Some(" lint:allow(panic) -- why"));
    }

    #[test]
    fn masks_string_contents() {
        let c = codes("let s = \"a.unwrap() // not a comment\";\n");
        assert_eq!(c[0], "let s = \"\";");
    }

    #[test]
    fn masks_raw_strings_across_lines() {
        let c = codes("let s = r#\"one\ntwo.unwrap()\nthree\"#;\nafter();\n");
        assert_eq!(c, vec!["let s = r#\"", "", "\"#;", "after();"]);
    }

    #[test]
    fn escaped_quotes_do_not_close_strings() {
        let c = codes("let s = \"he said \\\"hi\\\".unwrap()\"; x();\n");
        assert_eq!(c[0], "let s = \"\"; x();");
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let c = codes("a(); /* outer /* inner */ still */ b();\n/*\nmulti.unwrap()\n*/ c();\n");
        assert_eq!(c[0], "a();   b();");
        assert_eq!(c[1], " ");
        assert_eq!(c[2], "");
        assert_eq!(c[3], " c();");
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let c = codes("fn f<'a>(x: &'a str) -> &'a str { x }\nlet c = 'q'; let nl = '\\n';\n");
        assert_eq!(c[0], "fn f<'a>(x: &'a str) -> &'a str { x }");
        assert_eq!(c[1], "let c = ''; let nl = '';");
    }

    #[test]
    fn cfg_test_items_are_marked() {
        let text = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\nfn after() {}\n";
        let lines = scan(text);
        let flags: Vec<bool> = lines.iter().map(|l| l.in_test).collect();
        assert_eq!(flags, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn cfg_test_statement_without_braces_ends_at_semicolon() {
        let lines = scan("#[cfg(test)]\nuse std::collections::HashMap;\nfn live() {}\n");
        assert!(lines[0].in_test && lines[1].in_test);
        assert!(!lines[2].in_test);
    }
}
