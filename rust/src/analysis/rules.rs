//! The lint rules for [`crate::analysis`]: each one is a line-oriented
//! pattern over the masked code produced by the scanner, with a uniform
//! `// lint:allow(<rule>) -- <reason>` escape hatch.
//!
//! Rule design notes live in DESIGN.md §Static analysis. The important
//! contract here: every check runs on [`ScannedLine::code`] (comments
//! stripped, literal contents dropped), skips `#[cfg(test)]` items, and
//! reports at most one finding per (rule, line) so counts are stable.

use std::collections::BTreeSet;

use super::scanner::ScannedLine;
use super::Finding;

/// Static description of one lint rule.
#[derive(Debug, Clone, Copy)]
pub struct RuleInfo {
    /// Stable identifier, used in reports, config, and `lint:allow(..)`.
    pub id: &'static str,
    /// Whether the rule is on without any configuration.
    pub default_on: bool,
    /// One-line summary for `sigtree lint --rules`.
    pub summary: &'static str,
}

/// The rule table, in report order.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "panic",
        default_on: true,
        summary: ".unwrap()/.expect()/panic!-family in non-test library code",
    },
    RuleInfo {
        id: "index-hot",
        default_on: true,
        summary: "slice/array indexing on hot kernel paths (runtime/, signal/stats.rs); \
                  range slices exempt",
    },
    RuleInfo {
        id: "det-order",
        default_on: true,
        summary: "HashMap/HashSet inside deterministic modules (iteration order can leak)",
    },
    RuleInfo {
        id: "det-clock",
        default_on: true,
        summary: "wall-clock, thread-id, or environment reads inside deterministic modules",
    },
    RuleInfo {
        id: "det-thread",
        default_on: true,
        summary: "raw std::thread in deterministic modules — use par::parallel_map / par::Exec",
    },
    RuleInfo {
        id: "unsafe-safety",
        default_on: true,
        summary: "`unsafe` without an adjacent // SAFETY: justification",
    },
    RuleInfo {
        id: "error-discipline",
        default_on: true,
        summary: "pub fn returning Result<_, String> instead of sigtree::error::Result",
    },
    RuleInfo {
        id: "shim-delegation",
        default_on: true,
        summary: "#[deprecated] build* shim that no longer delegates to its construct* twin",
    },
    RuleInfo {
        id: "allow-hygiene",
        default_on: true,
        summary: "malformed, unknown-rule, or dangling lint:allow directives",
    },
];

/// Modules whose build/query paths must be bit-identical at any thread
/// count and fanout (ROADMAP "standing constraint"); the det-* rules
/// apply only here.
pub const DETERMINISTIC_MODULES: &[&str] =
    &["audit", "bicriteria", "coreset", "partition", "sample", "segmentation", "signal"];

/// Resolve a user-supplied rule name to its static id.
pub fn rule_id(name: &str) -> Option<&'static str> {
    RULES.iter().find(|r| r.id == name).map(|r| r.id)
}

/// An inline `lint:allow` directive parsed out of a `//` comment.
struct Allow {
    rule: String,
    known: bool,
    has_reason: bool,
    /// 0-based line of the directive itself.
    line: usize,
    /// 0-based code line the directive covers (same line, or the first
    /// code line after a contiguous comment block), if any.
    covered: Option<usize>,
    used: bool,
}

fn first_component(rel: &str) -> &str {
    rel.split('/').next().unwrap_or(rel)
}

fn is_deterministic_module(rel: &str) -> bool {
    DETERMINISTIC_MODULES.contains(&first_component(rel))
}

/// Hot kernel paths where `index-hot` applies: the `runtime` execution
/// backends and the prefix-statistics fill. These are the cache-blocked
/// inner loops — indexing there is both a panic path and a per-element
/// bounds check the autovectorizer has to hoist, so the rule is on by
/// default and satisfied structurally (zips, `split_at_mut`, slice
/// patterns, range slices), with `lint:allow` reserved for O(1) corner
/// reads.
fn is_hot_kernel_path(rel: &str) -> bool {
    first_component(rel) == "runtime" || rel == "signal/stats.rs"
}

/// Test-only source is exempt from every rule: anything under a `tests/`
/// or `benches/` path component, and the `proptest.rs` shrinking harness
/// (its whole job is panicking on failure).
pub fn is_test_path(rel: &str) -> bool {
    let base = rel.rsplit('/').next().unwrap_or(rel);
    rel.split('/').any(|c| c == "tests" || c == "benches") || base == "proptest.rs"
}

/// Find `pat` in `code`; with `word_start`, the match must not be
/// preceded by an identifier character.
fn find_token(code: &str, pat: &str, word_start: bool) -> Option<usize> {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(seg) = code.get(from..) {
        let off = seg.find(pat)?;
        let at = from + off;
        let boundary = !word_start
            || at == 0
            || !(bytes[at - 1].is_ascii_alphanumeric() || bytes[at - 1] == b'_');
        if boundary {
            return Some(at);
        }
        from = at + pat.len();
    }
    None
}

/// `.expect(` occurrences that are not the JSON parser's internal
/// `self.expect(b'..')` cursor helper.
fn has_expect_call(code: &str) -> bool {
    let mut from = 0;
    while let Some(seg) = code.get(from..) {
        let Some(off) = seg.find(".expect(") else { return false };
        let at = from + off;
        if !code[..at].ends_with("self") {
            return true;
        }
        from = at + ".expect(".len();
    }
    false
}

fn parse_directives(comment: &str) -> Vec<(String, bool)> {
    const KEY: &str = "lint:allow(";
    let mut out = Vec::new();
    // A directive must open its comment; `lint:allow(...)` mid-sentence
    // (docs *talking about* the linter) is prose, not a directive.
    let mut rest = comment.trim_start();
    if !rest.starts_with(KEY) {
        return out;
    }
    while let Some(pos) = rest.find(KEY) {
        let after = &rest[pos + KEY.len()..];
        let Some(end) = after.find(')') else { break };
        let rule = after[..end].trim().to_string();
        let tail = &after[end + 1..];
        let has_reason = tail
            .trim_start()
            .strip_prefix("--")
            .map_or(false, |r| !r.trim().is_empty());
        out.push((rule, has_reason));
        rest = tail;
    }
    out
}

/// The code line a directive on `idx` covers: its own line if it carries
/// code, else the first code line after the contiguous comment block
/// below it (a blank line breaks the chain).
fn covered_line(lines: &[ScannedLine], idx: usize) -> Option<usize> {
    if !lines[idx].is_code_free() {
        return Some(idx);
    }
    let mut j = idx + 1;
    while j < lines.len() {
        if !lines[j].is_code_free() {
            return Some(j);
        }
        if lines[j].comment.is_none() {
            return None;
        }
        j += 1;
    }
    None
}

fn collect_allows(lines: &[ScannedLine]) -> Vec<Allow> {
    let mut out = Vec::new();
    for (idx, l) in lines.iter().enumerate() {
        if l.in_test {
            continue;
        }
        let Some(comment) = l.comment.as_deref() else { continue };
        for (rule, has_reason) in parse_directives(comment) {
            let known = rule_id(&rule).is_some();
            out.push(Allow {
                known,
                has_reason,
                line: idx,
                covered: covered_line(lines, idx),
                used: false,
                rule,
            });
        }
    }
    out
}

/// True when line `idx` has a `// SAFETY:` note on the same line or in
/// the contiguous comment block directly above it.
fn has_safety_comment(lines: &[ScannedLine], idx: usize) -> bool {
    let safety = |l: &ScannedLine| l.comment.as_deref().map_or(false, |c| c.contains("SAFETY:"));
    if safety(&lines[idx]) {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        if !(lines[j].is_code_free() && lines[j].comment.is_some()) {
            return false;
        }
        if safety(&lines[j]) {
            return true;
        }
    }
    false
}

/// Either suppress a match through a matching, well-formed allow on the
/// covered line, or record a finding.
#[allow(clippy::too_many_arguments)]
fn emit(
    findings: &mut Vec<Finding>,
    suppressed: &mut usize,
    allows: &mut [Allow],
    rel: &str,
    rule: &'static str,
    idx: usize,
    message: String,
) {
    for a in allows.iter_mut() {
        if a.covered == Some(idx) && a.known && a.has_reason && a.rule == rule {
            a.used = true;
            *suppressed += 1;
            return;
        }
    }
    findings.push(Finding { rule, file: rel.to_string(), line: idx + 1, message });
}

/// Outcome of linting one file.
pub(crate) struct FileLint {
    pub findings: Vec<Finding>,
    pub suppressed: usize,
}

const PANIC_MACROS: &[&str] = &["panic!", "unreachable!", "todo!", "unimplemented!"];
const CLOCK_TOKENS: &[&str] =
    &["Instant::now", "SystemTime", "thread::current", "env::var", "env::args"];
const THREAD_TOKENS: &[&str] = &["thread::spawn", "thread::scope", "thread::Builder"];
const ORDER_TOKENS: &[&str] = &["HashMap", "HashSet"];

/// Run every enabled rule over one scanned file.
pub(crate) fn lint_lines(
    rel: &str,
    lines: &[ScannedLine],
    enabled: &BTreeSet<&'static str>,
) -> FileLint {
    let mut findings = Vec::new();
    let mut suppressed = 0usize;
    if is_test_path(rel) {
        return FileLint { findings, suppressed };
    }
    let mut allows = collect_allows(lines);
    let det = is_deterministic_module(rel);
    let on = |id: &str| enabled.contains(id);

    for (idx, l) in lines.iter().enumerate() {
        if l.in_test || l.is_code_free() {
            continue;
        }
        let code = l.code.as_str();

        if on("panic") {
            let token = if find_token(code, ".unwrap()", false).is_some() {
                Some(".unwrap()")
            } else if has_expect_call(code) {
                Some(".expect(..)")
            } else {
                PANIC_MACROS
                    .iter()
                    .copied()
                    .find(|m| find_token(code, m, true).is_some())
            };
            if let Some(token) = token {
                emit(
                    &mut findings,
                    &mut suppressed,
                    &mut allows,
                    rel,
                    "panic",
                    idx,
                    format!("`{token}` in library code — return error::Result instead"),
                );
            }
        }

        if on("index-hot") && is_hot_kernel_path(rel) && has_indexing(code) {
            emit(
                &mut findings,
                &mut suppressed,
                &mut allows,
                rel,
                "index-hot",
                idx,
                "slice/array indexing on a hot kernel path (can panic; prefer zips/splits)"
                    .to_string(),
            );
        }

        if det {
            for (rule, tokens) in [
                ("det-order", ORDER_TOKENS),
                ("det-clock", CLOCK_TOKENS),
                ("det-thread", THREAD_TOKENS),
            ] {
                if !on(rule) {
                    continue;
                }
                if let Some(tok) =
                    tokens.iter().copied().find(|t| find_token(code, t, true).is_some())
                {
                    emit(
                        &mut findings,
                        &mut suppressed,
                        &mut allows,
                        rel,
                        rule,
                        idx,
                        format!("`{tok}` inside deterministic module `{}`", first_component(rel)),
                    );
                }
            }
        }

        if on("unsafe-safety") {
            if let Some(at) = find_token(code, "unsafe", true) {
                let end = at + "unsafe".len();
                let word_end = code
                    .as_bytes()
                    .get(end)
                    .map_or(true, |b| !(b.is_ascii_alphanumeric() || *b == b'_'));
                if word_end && !has_safety_comment(lines, idx) {
                    emit(
                        &mut findings,
                        &mut suppressed,
                        &mut allows,
                        rel,
                        "unsafe-safety",
                        idx,
                        "`unsafe` without an adjacent `// SAFETY:` justification".to_string(),
                    );
                }
            }
        }

        if on("error-discipline") && code.contains("pub fn ") {
            if let Some(at) = code.find("-> Result<") {
                let tail = &code[at..];
                if tail.contains(", String>") || tail.contains(",String>") {
                    emit(
                        &mut findings,
                        &mut suppressed,
                        &mut allows,
                        rel,
                        "error-discipline",
                        idx,
                        "public fn returns Result<_, String>; use sigtree::error::Result"
                            .to_string(),
                    );
                }
            }
        }
    }

    if on("shim-delegation") {
        check_shims(rel, lines, &mut findings, &mut suppressed, &mut allows);
    }

    if on("allow-hygiene") {
        for a in &allows {
            let (line, message) = if !a.known {
                (a.line, format!("unknown rule `{}` in lint:allow", a.rule))
            } else if !a.has_reason {
                (a.line, format!("lint:allow({}) is missing ` -- <reason>`", a.rule))
            } else if !enabled.contains(a.rule.as_str()) {
                continue;
            } else if !a.used {
                (a.line, format!("dangling lint:allow({}) — it suppresses nothing", a.rule))
            } else {
                continue;
            };
            findings.push(Finding {
                rule: "allow-hygiene",
                file: rel.to_string(),
                line: line + 1,
                message,
            });
        }
    }

    findings.sort_by(|a, b| (a.line, a.rule, &a.message).cmp(&(b.line, b.rule, &b.message)));
    FileLint { findings, suppressed }
}

/// Indexing detector for the hot-path rule: an `ident[` / `)[` / `][`
/// opener whose bracket content (at the bracket's own nesting depth)
/// does *not* contain `..`. Range slicing (`&xs[a..b]`, `[off..]`) is
/// idiomatic on the blocked kernel paths — one bounds check per slice,
/// not per element — so it is exempt; a bracket left unmatched on the
/// line is conservatively flagged.
fn has_indexing(code: &str) -> bool {
    let bytes = code.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        let opener = bytes[i] == b'['
            && i > 0
            && (bytes[i - 1].is_ascii_alphanumeric()
                || bytes[i - 1] == b'_'
                || bytes[i - 1] == b')'
                || bytes[i - 1] == b']');
        if !opener {
            i += 1;
            continue;
        }
        let mut depth = 1usize;
        let mut j = i + 1;
        let mut has_range = false;
        while j < bytes.len() && depth > 0 {
            match bytes[j] {
                b'[' => depth += 1,
                b']' => depth -= 1,
                b'.' if depth == 1 && bytes.get(j + 1) == Some(&b'.') => has_range = true,
                _ => {}
            }
            j += 1;
        }
        if depth > 0 || !has_range {
            return true; // unmatched bracket → conservative; no `..` → indexing
        }
        i = j;
    }
    false
}

/// Every `#[deprecated]` `build*` shim must still call into a
/// `construct*` twin (the rename contract from the PR-4 API redesign).
fn check_shims(
    rel: &str,
    lines: &[ScannedLine],
    findings: &mut Vec<Finding>,
    suppressed: &mut usize,
    allows: &mut [Allow],
) {
    let mut pending = false;
    let mut idx = 0;
    while idx < lines.len() {
        let l = &lines[idx];
        if l.in_test || l.is_code_free() {
            idx += 1;
            continue;
        }
        let code = l.code.as_str();
        let is_attr_line = code.contains("#[deprecated");
        if is_attr_line {
            pending = true;
        }
        if pending {
            if let Some(at) = find_token(code, "fn ", true) {
                let name: String = code[at + 3..]
                    .chars()
                    .take_while(|c| c.is_alphanumeric() || *c == '_')
                    .collect();
                if name.starts_with("build") && !shim_body_delegates(lines, idx) {
                    emit(
                        findings,
                        suppressed,
                        allows,
                        rel,
                        "shim-delegation",
                        idx,
                        format!("deprecated shim `{name}` does not delegate to a construct* twin"),
                    );
                }
                pending = false;
            } else if !is_attr_line
                && ["struct ", "enum ", "trait ", "impl ", "mod ", "use "]
                    .iter()
                    .any(|t| code.contains(t))
            {
                // The attribute decorated something that is not a fn.
                pending = false;
            }
        }
        idx += 1;
    }
}

/// Walk the brace-balanced body starting at the shim's `fn` line and
/// look for a `construct` call.
fn shim_body_delegates(lines: &[ScannedLine], fn_idx: usize) -> bool {
    let mut depth: i64 = 0;
    let mut started = false;
    for l in lines.iter().skip(fn_idx) {
        if started && depth <= 0 {
            break;
        }
        if (started || depth > 0 || l.code.contains('{')) && l.code.contains("construct") {
            return true;
        }
        for b in l.code.bytes() {
            match b {
                b'{' => {
                    depth += 1;
                    started = true;
                }
                b'}' => depth -= 1,
                b';' if !started => return true, // declaration only — nothing to check
                _ => {}
            }
        }
        if started && depth <= 0 {
            break;
        }
    }
    false
}
