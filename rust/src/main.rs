//! `sigtree` CLI — the L3 launcher.
//!
//! Every subcommand is a thin shell around **one**
//! [`sigtree::engine::Engine`]: flags (and optional `--config <json>`
//! files) parse into one validated [`EngineConfig`], the engine owns
//! the worker pool / shared statistics / kernel backend, and unknown
//! flags are rejected with the valid set (`cli::Args::expect_only`).
//!
//! Subcommands:
//!
//! * `coreset`    — build a coreset of a synthetic signal, print stats.
//! * `pipeline`   — run the streaming pipeline (bands/workers/backpressure).
//! * `evaluate`   — coreset-vs-exact loss validation on random queries.
//! * `audit`      — the empirical ε-guarantee audit: adversarial query
//!   families + optimal-tree-transfer checks, JSON evidence trail.
//! * `experiment` — the paper's §5 missing-values experiment.
//! * `tune`       — hyperparameter sweep on full data vs coreset.
//! * `x10`        — the ×10 reproduction ([`sigtree::experiments::x10`]):
//!   tuning-on-compression vs tuning-on-full across the (k, ε) sweep for
//!   both solvers and both coreset families, emitting the
//!   `BENCH_forest.json` rows of the bench gate.
//! * `update`     — incremental-rebuild demo: seeded tile edits through an
//!   [`sigtree::engine::EditSession`], incremental vs from-scratch timings.
//! * `runtime`    — run kernel-backend parity checks
//!   (`--backend native|blocked|pjrt`).
//! * `serve`      — the batched coreset-query daemon
//!   ([`sigtree::serve`]): std-only HTTP/1.1 over one shared engine,
//!   cross-request fitting-loss batching, LRU coreset cache; drains on
//!   `POST /shutdown`.
//! * `lint`       — the determinism & panic-freedom static-analysis pass
//!   over `rust/src` ([`sigtree::analysis`]); non-zero exit on findings.
//! * `help`       — this text.

use std::process::ExitCode;

use sigtree::cli::Args;
use sigtree::coreset::SignalCoreset;
use sigtree::datasets;
use sigtree::engine::{Compression, Engine, EngineConfig};
use sigtree::error::{Error, Result};
use sigtree::experiments::{self, Solver};
use sigtree::rng::Rng;
use sigtree::runtime::{
    pad_integral, BlockedBackend, KernelBackend, NativeBackend, TiledPrefix, TILE,
};
use sigtree::segmentation::random_segmentation;
use sigtree::signal::{generate, PrefixStats, Rect, Signal};

fn main() -> ExitCode {
    let args = Args::from_env();
    let result = match args.command.as_str() {
        "coreset" => cmd_coreset(&args),
        "pipeline" => cmd_pipeline(&args),
        "evaluate" => cmd_evaluate(&args),
        "audit" => cmd_audit(&args),
        "experiment" => cmd_experiment(&args),
        "tune" => cmd_tune(&args),
        "x10" => cmd_x10(&args),
        "update" => cmd_update(&args),
        "runtime" => cmd_runtime(&args),
        "serve" => cmd_serve(&args),
        "lint" => cmd_lint(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            Err(sigtree::cli::CliError::UnknownCommand(other.to_string()).into())
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!(
        "sigtree — Coresets for Decision Trees of Signals (NeurIPS 2021)\n\
         \n\
         USAGE: sigtree <command> [--flag value ...]\n\
         \n\
         COMMANDS\n\
           coreset     --n 512 --m 512 --k 64 --eps 0.2 --seed 7 [--signal smooth|image|noise|piecewise]\n\
           pipeline    --n 2048 --m 512 --k 64 --eps 0.2 --band-rows 128 [--workers 2]\n\
           evaluate    --n 256 --m 256 --k 16 --eps 0.2 --queries 100\n\
           audit       --k 5 --eps 0.5 --cases 25 --seed 7 [--transfer-instances 4] [--json audit.json]\n\
           experiment  --dataset air|gesture --scale 0.1 --k 200 --eps 0.3 [--solver forest|gbdt]\n\
           tune        --dataset air|gesture --scale 0.1 --grid 8 --eps 0.3\n\
           x10         [--quick] [--scale 0.25] [--grid 6] [--seed 7] [--json BENCH_forest.json]\n\
           update      --n 512 --m 512 --k 64 --eps 0.2 --edits 8 --tile 64\n\
           runtime     [--backend native|blocked|pjrt] [--block-size B] [--dir artifacts]\n\
           serve       [config.json] [--addr 127.0.0.1:0 | --port P] [--serve-threads 4]\n\
                       [--batch-window-ms 2] [--batch-max 1024] [--cache-cap 16]\n\
                       [--max-body BYTES] [--read-timeout-ms 5000] [--port-file PATH]\n\
                       [--foreground]\n\
           lint        [--root rust/src] [--enable a,b] [--disable a,b] [--json lint.json] [--rules]\n\
           help\n\
         \n\
         ENGINE FLAGS (each subcommand accepts exactly the subset it\n\
         consumes — anything else, typo'd or merely inert, is rejected)\n\
           --threads N      worker threads; 0 or 'auto' = all cores. Coresets are\n\
                            bit-identical for every N (pipeline merge order excepted).\n\
           --beta B         worst-case theory calibration gamma = eps^2/(B*k)\n\
                            (default: the practical gamma = eps/2).\n\
           --band-rows R    rows per streamed band (pipeline/stream).\n\
           --shard-rows R   rows per build shard (default 64).\n\
           --merge-fanout F merge-tree fanout (>= 2; memoization shape only,\n\
                            never changes the composed coreset's bits).\n\
           --reduce-tol T   override the root reduce tolerance (default:\n\
                            the guarantee-preserving gamma^2*sigma).\n\
           --backend NAME   kernel backend: native (default), blocked, or pjrt.\n\
           --block-size B   column-block width of the blocked backend/stats\n\
                            fill (>= 1; bit-identical results for every B).\n\
           --dir PATH       artifacts directory for the pjrt backend.\n\
           --seed S         base seed (decimal or 0x-hex).\n\
           --coreset-family F  compression family: caratheodory (default) or\n\
                            sensitivity(ALG,TAU) with ALG unified|lightweight|uniform\n\
                            (importance sampling, TAU draws).\n\
           --config FILE    JSON engine config (sigtree::engine::EngineConfig);\n\
                            explicit flags override file values.\n\
         \n\
         Unknown flags are rejected with the valid set for the subcommand\n\
         (a typo like --theads no longer runs silently with defaults)."
    );
}

/// Generate the synthetic input signal, consuming draws from `rng` —
/// callers thread ONE rng through signal generation and any subsequent
/// query generation, so queries never replay the stream that produced
/// the signal.
fn make_signal(args: &Args, rng: &mut Rng) -> Result<Signal> {
    let n = args.get_usize("n", 512)?;
    let m = args.get_usize("m", 512)?;
    Ok(match args.get_str("signal", "smooth").as_str() {
        "image" => generate::image_like(n, m, 4, rng),
        "noise" => generate::noise(n, m, 1.0, rng),
        "piecewise" => generate::piecewise_constant(n, m, 32, 0.05, rng).0,
        _ => generate::smooth(n, m, 4, rng),
    })
}

fn cmd_coreset(args: &Args) -> Result<()> {
    // Per-subcommand allowlists name exactly the flags the subcommand
    // consumes — an accepted-but-inert flag (e.g. `--band-rows` on a
    // non-banded build) is the silent-ignore failure mode expect_only
    // exists to prevent, so every list below is consumed-knobs-only.
    args.expect_only(&[
        "k",
        "eps",
        "beta",
        "threads",
        "shard-rows",
        "merge-fanout",
        "reduce-tol",
        "backend",
        "block-size",
        "seed",
        "config",
        "coreset-family",
        "n",
        "m",
        "signal",
    ])?;
    // Historical default: a bare `coreset` ran single-threaded; the
    // sharded engine build is bit-identical at any thread count, so
    // threads=1 preserves the resource footprint too.
    let engine =
        Engine::new(EngineConfig::from_args(args, EngineConfig::new(64, 0.2).with_threads(1))?)?;
    let mut rng = Rng::new(engine.config().seed);
    let signal = make_signal(args, &mut rng)?;
    let t0 = std::time::Instant::now();
    let compression = engine.compress(&signal);
    let took = t0.elapsed();
    println!(
        "signal {}x{} ({} cells)  k={} eps={}  family={}  engine=pool({} threads)",
        signal.rows(),
        signal.cols(),
        signal.len(),
        engine.config().k,
        engine.config().eps,
        engine.config().coreset_family.render(),
        engine.threads()
    );
    match &compression {
        Compression::Caratheodory(cs) => println!(
            "coreset: {} blocks, {} stored points ({:.2}% of present cells), sigma={:.4e}, built in {:?} ({:.2e} cells/s)",
            cs.blocks.len(),
            cs.stored_points(),
            100.0 * cs.compression_ratio(),
            cs.sigma,
            took,
            signal.len() as f64 / took.as_secs_f64()
        ),
        Compression::Sensitivity(sc) => println!(
            "coreset: {} sampling, tau={}, {} stored points ({:.2}% of present cells), weight {:.1}, built in {:?} ({:.2e} cells/s)",
            sc.algorithm.name(),
            sc.tau,
            sc.points.len(),
            100.0 * sc.points.len() as f64 / signal.present().max(1) as f64,
            sc.total_weight(),
            took,
            signal.len() as f64 / took.as_secs_f64()
        ),
    }
    Ok(())
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    args.expect_only(&[
        "k", "eps", "beta", "threads", "band-rows", "seed", "config", "n", "m", "signal", "workers",
    ])?;
    // Historical default: 2 workers when neither --workers nor
    // --threads is given (a bare `pipeline` must not saturate the host).
    let mut config = EngineConfig::from_args(args, EngineConfig::new(64, 0.2).with_threads(2))?;
    // `--workers` is the historical spelling of the pipeline's worker
    // count, taken literally (clamped to ≥ 1, like `with_workers`); it
    // wins over `--threads` when both are given.
    if args.get("workers").is_some() {
        config.threads = args.get_usize("workers", 2)?.max(1);
    }
    let engine = Engine::new(config)?;
    let mut rng = Rng::new(engine.config().seed);
    let signal = make_signal(args, &mut rng)?;
    let t0 = std::time::Instant::now();
    let (cs, metrics) = engine.pipeline(&signal);
    println!(
        "pipeline done in {:?}: {} blocks, {:.2}% of present cells",
        t0.elapsed(),
        cs.blocks.len(),
        100.0 * cs.compression_ratio()
    );
    println!("metrics: {}", metrics.summary());
    Ok(())
}

fn cmd_evaluate(args: &Args) -> Result<()> {
    args.expect_only(&[
        "k", "eps", "beta", "threads", "shard-rows", "seed", "config", "n", "m", "signal",
        "queries",
    ])?;
    // Historical default: single-threaded (see cmd_coreset).
    let engine =
        Engine::new(EngineConfig::from_args(args, EngineConfig::new(16, 0.2).with_threads(1))?)?;
    // One rng thread through signal AND queries (seed-reuse would
    // correlate the measured queries with the data).
    let mut rng = Rng::new(engine.config().seed);
    let signal = make_signal(args, &mut rng)?;
    let queries = args.get_usize("queries", 100)?;
    let session = engine.session(&signal);
    let cs = session.coreset();
    let qs: Vec<_> = (0..queries)
        .map(|_| {
            let mut s = random_segmentation(signal.bounds(), engine.config().k, &mut rng);
            session.refit(&mut s);
            s
        })
        .collect();
    // Batch evaluation runs the queries concurrently on the engine pool.
    let approxs = engine.fitting_loss(&cs, &qs);
    let mut worst = 0.0f64;
    let mut mean = 0.0f64;
    for (s, approx) in qs.iter().zip(approxs) {
        let exact = session.exact_loss(s);
        let err = sigtree::coreset::fitting_loss::relative_error(approx, exact);
        worst = worst.max(err);
        mean += err;
    }
    mean /= queries.max(1) as f64;
    println!(
        "coreset size {:.2}%  queries={queries}  mean rel err {:.4}  worst {:.4}  (target eps {})",
        100.0 * cs.compression_ratio(),
        mean,
        worst,
        engine.config().eps
    );
    Ok(())
}

/// The empirical ε-guarantee audit (`sigtree::audit`) through the
/// engine: sweep adversarial query families against freshly built
/// coresets, run the optimal-tree-transfer check on DP-feasible
/// instances, optionally write the JSON evidence trail, and exit
/// non-zero on any violated gate.
fn cmd_audit(args: &Args) -> Result<()> {
    // The audit builds practically-calibrated coresets internally, so
    // --beta/--shard-rows/--band-rows would be inert here — rejected.
    args.expect_only(&[
        "k", "eps", "threads", "backend", "block-size", "seed", "config", "cases",
        "transfer-instances", "json",
    ])?;
    let engine = Engine::new(EngineConfig::from_args(args, EngineConfig::new(5, 0.5))?)?;
    let cases = args.get_usize("cases", 25)?;
    let transfer_instances = args.get_usize("transfer-instances", 4)?;
    let t0 = std::time::Instant::now();
    let report = engine.audit(cases, transfer_instances);
    println!("{}", report.summary());
    println!("audit completed in {:?}", t0.elapsed());
    if let Some(path) = args.get("json") {
        std::fs::write(path, report.to_json().render())
            .map_err(|e| Error::msg(format!("writing {path}: {e}")))?;
        println!("evidence trail written to {path}");
    }
    if !report.pass {
        return Err(Error::msg(
            "audit FAILED: empirical guarantee violated (see report above)",
        ));
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    // Only the knobs this harness actually consumes are accepted —
    // engine flags like --threads/--backend would be silently ignored
    // here, which is exactly what expect_only exists to prevent.
    args.expect_only(&["k", "eps", "seed", "dataset", "scale", "k-train", "solver"])?;
    // Engine-validated knobs (k, eps, seed) — the harness itself drives
    // the experiments module directly.
    let config = EngineConfig::from_args(args, EngineConfig::new(200, 0.3))?;
    let mut rng = Rng::new(config.seed);
    let scale = args.get_f64("scale", 0.1)?;
    let signal = match args.get_str("dataset", "air").as_str() {
        "gesture" => datasets::gesture_phase_like(scale, &mut rng),
        _ => datasets::air_quality_like(scale, &mut rng),
    };
    let k_train = args.get_usize("k-train", 64)?;
    let solver = match args.get_str("solver", "forest").as_str() {
        "gbdt" => Solver::Gbdt,
        _ => Solver::RandomForest,
    };
    let (cs, us) = experiments::missing_values_experiment(
        &signal, config.k, config.eps, k_train, solver, 11,
    );
    let full = experiments::full_data_baseline(&signal, k_train, solver, 11);
    for o in [&full, &cs, &us] {
        println!(
            "{:>14}  size {:>8} ({:>6.2}%)  build {:>10?}  train {:>10?}  test SSE {:.4}",
            o.scheme,
            o.size,
            100.0 * o.compression_ratio,
            o.build_time,
            o.train_time,
            o.test_sse
        );
    }
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    use sigtree::experiments::tuning;
    // Same contract as cmd_experiment: accept only consumed knobs.
    args.expect_only(&["k", "eps", "seed", "dataset", "scale", "grid"])?;
    let config = EngineConfig::from_args(args, EngineConfig::new(200, 0.3))?;
    let mut rng = Rng::new(config.seed);
    let scale = args.get_f64("scale", 0.1)?;
    let signal = match args.get_str("dataset", "air").as_str() {
        "gesture" => datasets::gesture_phase_like(scale, &mut rng),
        _ => datasets::air_quality_like(scale, &mut rng),
    };
    let (masked, held) = datasets::holdout_patches(&signal, 0.3, 5, &mut rng);
    let grid = tuning::log_grid(4, 256, args.get_usize("grid", 8)?);
    let full = tuning::tune_full(&masked, &held, &grid, Solver::RandomForest, 3);
    let core = tuning::tune_coreset(
        &masked,
        &held,
        &grid,
        config.k,
        config.eps,
        Solver::RandomForest,
        3,
    );
    let uni = tuning::tune_uniform(
        &masked,
        &held,
        &grid,
        core.compression_size,
        Solver::RandomForest,
        3,
    );
    for curve in [&full, &core, &uni] {
        println!(
            "{:<24} size {:>8}  time {:>10?}  best_k {}",
            curve.scheme,
            curve.compression_size,
            curve.total_time,
            curve.best_k()
        );
        for (k, l) in &curve.points {
            println!("    k={k:<6} test SSE {l:.4}");
        }
    }
    println!(
        "speedup (full/coreset tuning time): x{:.1}",
        full.total_time.as_secs_f64() / core.total_time.as_secs_f64().max(1e-9)
    );
    Ok(())
}

/// The ×10 reproduction sweep ([`sigtree::experiments::x10`]):
/// tuning-on-compression vs tuning-on-full for both solvers and both
/// coreset families at matched sample budgets, optionally writing the
/// `BENCH_forest.json` document the bench gate consumes.
fn cmd_x10(args: &Args) -> Result<()> {
    use sigtree::experiments::x10;
    args.expect_only(&["seed", "scale", "grid", "quick", "json"])?;
    let base = if args.get_flag("quick") { x10::X10Config::quick() } else { x10::X10Config::full() };
    let scale = args.get_f64("scale", base.scale)?;
    if scale <= 0.0 {
        return Err(Error::msg("--scale must be positive"));
    }
    let config = base
        .with_seed(args.get_u64("seed", base.seed)?)
        .with_scale(scale)
        .with_grid(args.get_usize("grid", base.grid)?);
    let t0 = std::time::Instant::now();
    let rows = x10::run(&config);
    print!("{}", x10::summary(&rows));
    println!("x10 sweep completed in {:?}", t0.elapsed());
    if let Some(path) = args.get("json") {
        std::fs::write(path, x10::report_json(&config, &rows).render() + "\n")
            .map_err(|e| Error::msg(format!("writing {path}: {e}")))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Incremental-rebuild demo: drive a seeded sequence of tile edits
/// through an [`sigtree::engine::EditSession`] and report the
/// amortized incremental cost (dirty-leaf rebuild + O(log S) re-merge)
/// against a from-scratch rebuild of the mutated signal.
fn cmd_update(args: &Args) -> Result<()> {
    args.expect_only(&[
        "k",
        "eps",
        "beta",
        "threads",
        "shard-rows",
        "merge-fanout",
        "reduce-tol",
        "seed",
        "config",
        "n",
        "m",
        "signal",
        "edits",
        "tile",
    ])?;
    let engine =
        Engine::new(EngineConfig::from_args(args, EngineConfig::new(64, 0.2).with_threads(1))?)?;
    let mut rng = Rng::new(engine.config().seed);
    let signal = make_signal(args, &mut rng)?;
    let edits = args.get_usize("edits", 8)?;
    let tile = args.get_usize("tile", 64)?.max(1);
    let th = tile.min(signal.rows());
    let tw = tile.min(signal.cols());

    let t0 = std::time::Instant::now();
    let mut session = engine.edit_session(signal);
    let built = t0.elapsed();
    let initial_builds = session.leaf_builds();
    println!(
        "session: {} leaves over {}x{}, tree height {}, initial build {:?}",
        session.coreset_tree().leaf_count(),
        session.signal().rows(),
        session.signal().cols(),
        session.coreset_tree().height(),
        built
    );

    // Seeded edit loop: each iteration bumps one random tile by a
    // Gaussian offset, then re-derives the root coreset incrementally
    // (only leaves intersecting the tile are rebuilt).
    let mut incremental = std::time::Duration::ZERO;
    for edit in 0..edits {
        let r0 = rng.usize(session.signal().rows() - th + 1);
        let c0 = rng.usize(session.signal().cols() - tw + 1);
        let rect = Rect::new(r0, r0 + th - 1, c0, c0 + tw - 1);
        let delta = rng.normal();
        let before = session.leaf_builds();
        session.edit(rect, |_, _, v| v + delta);
        let t = std::time::Instant::now();
        let cs = session.coreset();
        let took = t.elapsed();
        incremental += took;
        println!(
            "edit {edit}: tile {rect:?} delta {delta:+.3} -> {} leaf rebuilds, {} blocks, {took:?}",
            session.leaf_builds() - before,
            cs.blocks.len()
        );
    }
    let rebuilt_leaves = session.leaf_builds() - initial_builds;

    // From-scratch rebuild of the *mutated* signal for comparison: the
    // incremental coreset matches it at the reduce-tolerance level and
    // carries the identical total weight (block moments are exact).
    let t1 = std::time::Instant::now();
    let scratch = engine.coreset(session.signal());
    let scratch_time = t1.elapsed();
    let cs = session.coreset();
    let (w_inc, w_scr) = (cs.total_weight(), scratch.total_weight());
    if (w_inc - w_scr).abs() > 1e-6 * (1.0 + w_scr) {
        return Err(Error::msg(format!(
            "incremental/from-scratch weight mismatch: {w_inc} vs {w_scr}"
        )));
    }
    let per_edit = incremental.as_secs_f64() / edits.max(1) as f64;
    println!(
        "{edits} edits: {rebuilt_leaves} leaf rebuilds total, incremental {:.3} ms/edit vs from-scratch {:.3} ms (speedup x{:.1})",
        1e3 * per_edit,
        1e3 * scratch_time.as_secs_f64(),
        scratch_time.as_secs_f64() / per_edit.max(1e-9)
    );
    println!(
        "weights agree: incremental {w_inc:.1} vs from-scratch {w_scr:.1} ({} vs {} blocks)",
        cs.blocks.len(),
        scratch.blocks.len()
    );
    Ok(())
}

fn cmd_runtime(args: &Args) -> Result<()> {
    args.expect_only(&[
        "k", "eps", "beta", "threads", "shard-rows", "backend", "block-size", "dir", "seed",
        "config",
    ])?;
    // Historical default: threads=1 runs the kernel parity checks only;
    // any other value adds the engine-vs-sequential parity section.
    let engine =
        Engine::new(EngineConfig::from_args(args, EngineConfig::new(8, 0.3).with_threads(1))?)?;
    let backend = engine.backend();
    println!("backend: {}", backend.name());

    // Parity smoke: prefix2d + block_sse against the exact f64 prefix
    // statistics on a random tile.
    let mut rng = Rng::new(1);
    let tile: Vec<f32> = (0..TILE * TILE).map(|_| rng.normal() as f32).collect();
    let (ii_y, ii_y2) = backend.prefix2d(&tile)?;
    let p_y = pad_integral(&ii_y);
    let p_y2 = pad_integral(&ii_y2);
    let rects = [[0i32, 31, 0, 31], [10, 200, 5, 250]];
    let opt1 = backend.block_sse(&p_y, &p_y2, &rects)?;
    let sig = Signal::from_fn(TILE, TILE, |r, c| tile[r * TILE + c] as f64);
    let stats = PrefixStats::new(&sig);
    for (got, r) in opt1.iter().zip(rects.iter()) {
        let rect = Rect::new(r[0] as usize, r[1] as usize, r[2] as usize, r[3] as usize);
        let exact = stats.opt1(&rect);
        let err = (*got as f64 - exact).abs() / (1.0 + exact.abs());
        println!("block_sse parity {rect:?}: kernel {got:.4} vs exact {exact:.4} (rel {err:.2e})");
        if err > 0.05 {
            return Err(Error::msg(format!(
                "block_sse parity failure on {rect:?}: {got} vs {exact}"
            )));
        }
    }

    // Tiled path over a non-TILE-aligned signal.
    let signal = generate::smooth(300, 280, 3, &mut rng);
    let tp = TiledPrefix::build(backend, &signal)?;
    let probe = Rect::new(0, 299, 0, 279);
    let (s, q) = tp.moments(&probe);
    let exact = PrefixStats::new(&signal).moments(&probe);
    println!(
        "tiled moments parity: sum {s:.3} vs {:.3}, sumsq {q:.3} vs {:.3}",
        exact.sum, exact.sum_sq
    );

    // Blocked-kernel bit-identity (always checked; the gate `--backend
    // blocked` runs through end-to-end): the cache-blocked backend must
    // reproduce the native prefix images exactly, and the blocked
    // statistics fill must reproduce the scalar fill exactly, at the
    // configured --block-size.
    let block = engine.config().block_size;
    let blocked = BlockedBackend::with_block(block);
    let (by, by2) = blocked.prefix2d(&tile)?;
    let (ny, ny2) = NativeBackend::new().prefix2d(&tile)?;
    if by != ny || by2 != ny2 {
        return Err(Error::msg(format!(
            "blocked prefix2d is not bit-identical to native at block {block}"
        )));
    }
    let blk_stats = PrefixStats::new_blocked(&signal, engine.threads(), block);
    let seq_stats = PrefixStats::new(&signal);
    let (bm, sm) = (blk_stats.moments(&probe), seq_stats.moments(&probe));
    if bm != sm {
        return Err(Error::msg(format!(
            "blocked stats parity failure at block {block}: {bm:?} vs {sm:?}"
        )));
    }
    println!("blocked kernel/stats bit-identity OK (block {block})");

    // Engine parity (--threads N, 0/auto = all cores): the engine's
    // pool-built statistics and sharded coreset must agree with their
    // sequential baselines.
    if engine.threads() != 1 {
        let sig = generate::smooth(320, 200, 3, &mut rng);
        let seq = PrefixStats::new(&sig);
        let par = engine.stats(&sig);
        let probe = Rect::new(3, 311, 11, 189);
        let (a, b) = (seq.moments(&probe), par.moments(&probe));
        let scale = 1.0 + a.sum_sq.abs();
        if (a.sum - b.sum).abs() > 1e-9 * scale || (a.sum_sq - b.sum_sq).abs() > 1e-9 * scale {
            return Err(Error::msg(format!(
                "engine PrefixStats parity failure: {a:?} vs {b:?}"
            )));
        }
        println!("engine PrefixStats parity OK ({} threads)", engine.threads());
        let cs_seq = SignalCoreset::construct(&sig, engine.config().k, engine.config().eps);
        let cs_par = engine.coreset(&sig);
        let (w_seq, w_par) = (cs_seq.total_weight(), cs_par.total_weight());
        if (w_seq - w_par).abs() > 1e-6 * (1.0 + w_seq) {
            return Err(Error::msg(format!(
                "engine coreset weight parity failure: {w_par} vs {w_seq}"
            )));
        }
        println!(
            "engine coreset parity OK ({} blocks engine vs {} seq, weight {w_par:.1})",
            cs_par.blocks.len(),
            cs_seq.blocks.len()
        );
    }
    println!("runtime OK");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.expect_only(&[
        "k",
        "eps",
        "beta",
        "threads",
        "shard-rows",
        "merge-fanout",
        "reduce-tol",
        "backend",
        "block-size",
        "seed",
        "config",
        "coreset-family",
        "addr",
        "port",
        "serve-threads",
        "batch-window-ms",
        "batch-max",
        "cache-cap",
        "max-body",
        "read-timeout-ms",
        "port-file",
        "foreground",
    ])?;
    // `serve config.json` is sugar for `serve --config config.json`
    // (the daemon's config file is its primary interface; `--foreground`
    // next to the positional is why `serve` declares boolean flags in
    // `cli::boolean_flags_for`). An explicit --config wins.
    let mut args = args.clone();
    if args.get("config").is_none() {
        if let Some(path) = args.positionals.first().cloned() {
            args.options.insert("config".to_string(), path);
        }
    }
    let engine = Engine::new(EngineConfig::from_args(&args, EngineConfig::new(16, 0.3))?)?;

    let defaults = sigtree::serve::ServeConfig::default();
    let addr = match args.get("addr") {
        Some(a) => a.to_string(),
        None => format!("127.0.0.1:{}", args.get_usize("port", 0)?),
    };
    let cfg = sigtree::serve::ServeConfig {
        addr,
        threads: args.get_usize("serve-threads", defaults.threads)?.max(1),
        batch_window_ms: args.get_u64("batch-window-ms", defaults.batch_window_ms)?,
        batch_max: args.get_usize("batch-max", defaults.batch_max)?.max(1),
        cache_cap: args.get_usize("cache-cap", defaults.cache_cap)?,
        max_body: args.get_usize("max-body", defaults.max_body)?,
        read_timeout_ms: args.get_u64("read-timeout-ms", defaults.read_timeout_ms)?,
        log_requests: args.get_flag("foreground"),
    };
    let server = sigtree::serve::Server::bind(engine, cfg)?;
    let bound = server.local_addr()?;
    // The ephemeral-port handshake scripts rely on: the port file (when
    // asked for) appears only after the listener is accepting.
    if let Some(path) = args.get("port-file") {
        std::fs::write(path, format!("{}\n", bound.port()))?;
    }
    println!("sigtree serve: listening on {bound} (POST /shutdown to drain)");
    server.run()
}

fn cmd_lint(args: &Args) -> Result<()> {
    args.expect_only(&["root", "enable", "disable", "json", "rules", "config"])?;
    if args.get_flag("rules") {
        println!("{:<16} {:<8} SUMMARY", "RULE", "DEFAULT");
        for rule in sigtree::analysis::RULES {
            let default = if rule.default_on { "on" } else { "off" };
            println!("{:<16} {default:<8} {}", rule.id, rule.summary);
        }
        return Ok(());
    }
    let config = sigtree::analysis::LintConfig::from_args(args)?;
    let report = sigtree::analysis::run(&config)?;
    println!("{}", report.summary());
    if let Some(path) = args.get("json") {
        std::fs::write(path, report.to_json().render())?;
        println!("wrote {path}");
    }
    if !report.pass() {
        return Err(Error::msg(format!(
            "lint failed with {} finding(s)",
            report.findings.len()
        )));
    }
    Ok(())
}
