//! `sigtree` CLI — the L3 launcher.
//!
//! Subcommands:
//!
//! * `coreset`    — build a coreset of a synthetic signal, print stats.
//! * `pipeline`   — run the streaming pipeline (bands/workers/backpressure).
//! * `evaluate`   — coreset-vs-exact loss validation on random queries.
//! * `audit`      — the empirical ε-guarantee audit: adversarial query
//!   families + optimal-tree-transfer checks, JSON evidence trail.
//! * `experiment` — the paper's §5 missing-values experiment.
//! * `tune`       — hyperparameter sweep on full data vs coreset.
//! * `runtime`    — run kernel-backend parity checks (`--backend native|pjrt`).
//! * `help`       — this text.

use std::process::ExitCode;

use sigtree::cli::Args;
use sigtree::coreset::{CoresetConfig, SignalCoreset};
use sigtree::datasets;
use sigtree::error::{Error, Result};
use sigtree::experiments::{self, Solver};
use sigtree::pipeline::{self, PipelineConfig};
use sigtree::rng::Rng;
use sigtree::runtime::{pad_integral, KernelBackend, TiledPrefix, TILE};
use sigtree::segmentation::random_segmentation;
use sigtree::signal::{generate, PrefixStats, Rect, Signal};

fn main() -> ExitCode {
    let args = Args::from_env();
    let result = match args.command.as_str() {
        "coreset" => cmd_coreset(&args),
        "pipeline" => cmd_pipeline(&args),
        "evaluate" => cmd_evaluate(&args),
        "audit" => cmd_audit(&args),
        "experiment" => cmd_experiment(&args),
        "tune" => cmd_tune(&args),
        "runtime" => cmd_runtime(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            Err(sigtree::cli::CliError::UnknownCommand(other.to_string()).into())
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!(
        "sigtree — Coresets for Decision Trees of Signals (NeurIPS 2021)\n\
         \n\
         USAGE: sigtree <command> [--flag value ...]\n\
         \n\
         COMMANDS\n\
           coreset     --n 512 --m 512 --k 64 --eps 0.2 --seed 7 [--signal smooth|image|noise|piecewise] [--threads N]\n\
           pipeline    --n 2048 --m 512 --k 64 --eps 0.2 --band-rows 128 --workers 2 [--threads N]\n\
           evaluate    --n 256 --m 256 --k 16 --eps 0.2 --queries 100 [--threads N]\n\
           audit       --k 5 --eps 0.5 --cases 25 --seed 7 [--threads N] [--transfer-instances 4] [--json audit.json]\n\
           experiment  --dataset air|gesture --scale 0.1 --k 200 --eps 0.3 [--solver forest|gbdt]\n\
           tune        --dataset air|gesture --scale 0.1 --grid 8 --eps 0.3\n\
           runtime     [--backend native|pjrt] [--dir artifacts] [--threads N]\n\
           help\n\
         \n\
         --threads N routes coreset/evaluate construction through the sharded\n\
         parallel builder (sigtree::par) with N workers — output is identical\n\
         for every N; 0 or 'auto' = all cores. Omit the flag for the classic\n\
         monolithic build. For pipeline, --threads is an alias for --workers\n\
         (completion-order merge: fast, but not bitwise-reproducible)."
    );
}

fn make_signal(args: &Args, rng: &mut Rng) -> Result<Signal> {
    let n = args.get_usize("n", 512)?;
    let m = args.get_usize("m", 512)?;
    Ok(match args.get_str("signal", "smooth").as_str() {
        "image" => generate::image_like(n, m, 4, rng),
        "noise" => generate::noise(n, m, 1.0, rng),
        "piecewise" => generate::piecewise_constant(n, m, 32, 0.05, rng).0,
        _ => generate::smooth(n, m, 4, rng),
    })
}

/// The `--threads` convention shared by `coreset` and `evaluate`: flag
/// absent → the classic monolithic build; flag present (any value, even
/// 1) → the sharded parallel builder, a pure performance knob whose
/// output is identical for every thread count.
fn build_coreset_from_args(
    args: &Args,
    signal: &Signal,
    k: usize,
    eps: f64,
) -> Result<SignalCoreset> {
    Ok(match args.get("threads") {
        None => SignalCoreset::build(signal, k, eps),
        Some(_) => {
            SignalCoreset::build_par(signal, CoresetConfig::new(k, eps), args.get_threads(1)?)
        }
    })
}

fn cmd_coreset(args: &Args) -> Result<()> {
    let mut rng = Rng::new(args.get_usize("seed", 7)? as u64);
    let signal = make_signal(args, &mut rng)?;
    let k = args.get_usize("k", 64)?;
    let eps = args.get_f64("eps", 0.2)?;
    let engine = match args.get("threads") {
        None => "monolithic".to_string(),
        Some(_) => format!(
            "par({} threads)",
            sigtree::par::resolve_threads(args.get_threads(1)?)
        ),
    };
    let t0 = std::time::Instant::now();
    let cs = build_coreset_from_args(args, &signal, k, eps)?;
    let took = t0.elapsed();
    println!(
        "signal {}x{} ({} cells)  k={k} eps={eps}  engine={engine}",
        signal.rows(),
        signal.cols(),
        signal.len()
    );
    println!(
        "coreset: {} blocks, {} stored points ({:.2}% of present cells), sigma={:.4e}, built in {:?} ({:.2e} cells/s)",
        cs.blocks.len(),
        cs.stored_points(),
        100.0 * cs.compression_ratio(),
        cs.sigma,
        took,
        signal.len() as f64 / took.as_secs_f64()
    );
    Ok(())
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let mut rng = Rng::new(args.get_usize("seed", 7)? as u64);
    let signal = make_signal(args, &mut rng)?;
    let k = args.get_usize("k", 64)?;
    let eps = args.get_f64("eps", 0.2)?;
    let cfg = PipelineConfig::new(CoresetConfig::new(k, eps))
        .with_band_rows(args.get_usize("band-rows", 128)?);
    // `--workers` is the historical spelling, taken literally (clamped to
    // ≥ 1) as before; `--threads` follows the crate-wide convention
    // (0/auto = all cores). `--workers` wins when both are given.
    let cfg = match args.get("workers") {
        Some(_) => cfg.with_workers(args.get_usize("workers", 2)?),
        None => cfg.with_threads(args.get_threads(2)?),
    };
    let t0 = std::time::Instant::now();
    let (cs, metrics) = pipeline::run(&signal, cfg);
    println!(
        "pipeline done in {:?}: {} blocks, {:.2}% of present cells",
        t0.elapsed(),
        cs.blocks.len(),
        100.0 * cs.compression_ratio()
    );
    println!("metrics: {}", metrics.summary());
    Ok(())
}

fn cmd_evaluate(args: &Args) -> Result<()> {
    let mut rng = Rng::new(args.get_usize("seed", 7)? as u64);
    let signal = make_signal(args, &mut rng)?;
    let k = args.get_usize("k", 16)?;
    let eps = args.get_f64("eps", 0.2)?;
    let queries = args.get_usize("queries", 100)?;
    let threads = args.get_threads(1)?;
    let stats = PrefixStats::new(&signal);
    let cs = build_coreset_from_args(args, &signal, k, eps)?;
    let qs: Vec<_> = (0..queries)
        .map(|_| {
            let mut s = random_segmentation(signal.bounds(), k, &mut rng);
            s.refit_values(&stats);
            s
        })
        .collect();
    // Batch evaluation runs the queries concurrently on the par pool.
    let approxs = cs.fitting_loss_batch(&qs, threads);
    let mut worst = 0.0f64;
    let mut mean = 0.0f64;
    for (s, approx) in qs.iter().zip(approxs) {
        let exact = s.loss(&stats);
        let err = sigtree::coreset::fitting_loss::relative_error(approx, exact);
        worst = worst.max(err);
        mean += err;
    }
    mean /= queries.max(1) as f64;
    println!(
        "coreset size {:.2}%  queries={queries}  mean rel err {:.4}  worst {:.4}  (target eps {eps})",
        100.0 * cs.compression_ratio(),
        mean,
        worst
    );
    Ok(())
}

/// The empirical ε-guarantee audit (`sigtree::audit`): sweep adversarial
/// query families against freshly built coresets, run the optimal-tree-
/// transfer check on DP-feasible instances, optionally write the JSON
/// evidence trail, and exit non-zero on any violated gate.
fn cmd_audit(args: &Args) -> Result<()> {
    let config = sigtree::audit::AuditConfig::new(
        args.get_usize("k", 5)?,
        args.get_f64("eps", 0.5)?,
    )
    .with_cases(args.get_usize("cases", 25)?)
    .with_seed(args.get_u64("seed", 7)?)
    .with_threads(args.get_threads(0)?)
    .with_transfer_instances(args.get_usize("transfer-instances", 4)?);
    let t0 = std::time::Instant::now();
    let report = sigtree::audit::run_audit(&config);
    println!("{}", report.summary());
    println!("audit completed in {:?}", t0.elapsed());
    if let Some(path) = args.get("json") {
        std::fs::write(path, report.to_json().render())
            .map_err(|e| Error::msg(format!("writing {path}: {e}")))?;
        println!("evidence trail written to {path}");
    }
    if !report.pass {
        return Err(Error::msg(
            "audit FAILED: empirical guarantee violated (see report above)",
        ));
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let mut rng = Rng::new(args.get_usize("seed", 7)? as u64);
    let scale = args.get_f64("scale", 0.1)?;
    let signal = match args.get_str("dataset", "air").as_str() {
        "gesture" => datasets::gesture_phase_like(scale, &mut rng),
        _ => datasets::air_quality_like(scale, &mut rng),
    };
    let k = args.get_usize("k", 200)?;
    let eps = args.get_f64("eps", 0.3)?;
    let k_train = args.get_usize("k-train", 64)?;
    let solver = match args.get_str("solver", "forest").as_str() {
        "gbdt" => Solver::Gbdt,
        _ => Solver::RandomForest,
    };
    let (cs, us) = experiments::missing_values_experiment(&signal, k, eps, k_train, solver, 11);
    let full = experiments::full_data_baseline(&signal, k_train, solver, 11);
    for o in [&full, &cs, &us] {
        println!(
            "{:>14}  size {:>8} ({:>6.2}%)  build {:>10?}  train {:>10?}  test SSE {:.4}",
            o.scheme,
            o.size,
            100.0 * o.compression_ratio,
            o.build_time,
            o.train_time,
            o.test_sse
        );
    }
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    use sigtree::experiments::tuning;
    let mut rng = Rng::new(args.get_usize("seed", 7)? as u64);
    let scale = args.get_f64("scale", 0.1)?;
    let signal = match args.get_str("dataset", "air").as_str() {
        "gesture" => datasets::gesture_phase_like(scale, &mut rng),
        _ => datasets::air_quality_like(scale, &mut rng),
    };
    let (masked, held) = datasets::holdout_patches(&signal, 0.3, 5, &mut rng);
    let grid = tuning::log_grid(4, 256, args.get_usize("grid", 8)?);
    let eps = args.get_f64("eps", 0.3)?;
    let full = tuning::tune_full(&masked, &held, &grid, Solver::RandomForest, 3);
    let core = tuning::tune_coreset(&masked, &held, &grid, 200, eps, Solver::RandomForest, 3);
    let uni = tuning::tune_uniform(
        &masked,
        &held,
        &grid,
        core.compression_size,
        Solver::RandomForest,
        3,
    );
    for curve in [&full, &core, &uni] {
        println!(
            "{:<24} size {:>8}  time {:>10?}  best_k {}",
            curve.scheme,
            curve.compression_size,
            curve.total_time,
            curve.best_k()
        );
        for (k, l) in &curve.points {
            println!("    k={k:<6} test SSE {l:.4}");
        }
    }
    println!(
        "speedup (full/coreset tuning time): x{:.1}",
        full.total_time.as_secs_f64() / core.total_time.as_secs_f64().max(1e-9)
    );
    Ok(())
}

fn cmd_runtime(args: &Args) -> Result<()> {
    let name = args.get_str("backend", "native");
    let dir = std::path::PathBuf::from(args.get_str("dir", "artifacts"));
    let backend = sigtree::runtime::backend_from_name(&name, Some(&dir))?;
    println!("backend: {}", backend.name());

    // Parity smoke: prefix2d + block_sse against the exact f64 prefix
    // statistics on a random tile.
    let mut rng = Rng::new(1);
    let tile: Vec<f32> = (0..TILE * TILE).map(|_| rng.normal() as f32).collect();
    let (ii_y, ii_y2) = backend.prefix2d(&tile)?;
    let p_y = pad_integral(&ii_y);
    let p_y2 = pad_integral(&ii_y2);
    let rects = [[0i32, 31, 0, 31], [10, 200, 5, 250]];
    let opt1 = backend.block_sse(&p_y, &p_y2, &rects)?;
    let sig = Signal::from_fn(TILE, TILE, |r, c| tile[r * TILE + c] as f64);
    let stats = PrefixStats::new(&sig);
    for (got, r) in opt1.iter().zip(rects.iter()) {
        let rect = Rect::new(r[0] as usize, r[1] as usize, r[2] as usize, r[3] as usize);
        let exact = stats.opt1(&rect);
        let err = (*got as f64 - exact).abs() / (1.0 + exact.abs());
        println!("block_sse parity {rect:?}: kernel {got:.4} vs exact {exact:.4} (rel {err:.2e})");
        if err > 0.05 {
            return Err(Error::msg(format!(
                "block_sse parity failure on {rect:?}: {got} vs {exact}"
            )));
        }
    }

    // Tiled path over a non-TILE-aligned signal.
    let signal = generate::smooth(300, 280, 3, &mut rng);
    let tp = TiledPrefix::build(backend.as_ref(), &signal)?;
    let probe = Rect::new(0, 299, 0, 279);
    let (s, q) = tp.moments(&probe);
    let exact = PrefixStats::new(&signal).moments(&probe);
    println!(
        "tiled moments parity: sum {s:.3} vs {:.3}, sumsq {q:.3} vs {:.3}",
        exact.sum, exact.sum_sq
    );

    // Parallel-engine parity (--threads N, 0/auto = all cores): the
    // sharded builders must agree with their sequential counterparts.
    let threads = args.get_threads(1)?;
    if threads != 1 {
        let resolved = sigtree::par::resolve_threads(threads);
        let sig = generate::smooth(320, 200, 3, &mut rng);
        let seq = PrefixStats::new(&sig);
        let par = PrefixStats::new_par(&sig, threads);
        let probe = Rect::new(3, 311, 11, 189);
        let (a, b) = (seq.moments(&probe), par.moments(&probe));
        let scale = 1.0 + a.sum_sq.abs();
        if (a.sum - b.sum).abs() > 1e-9 * scale || (a.sum_sq - b.sum_sq).abs() > 1e-9 * scale {
            return Err(Error::msg(format!(
                "parallel PrefixStats parity failure: {a:?} vs {b:?}"
            )));
        }
        println!("parallel PrefixStats parity OK ({resolved} threads)");
        let cs_seq = SignalCoreset::build(&sig, 8, 0.3);
        let cs_par = SignalCoreset::build_par(&sig, CoresetConfig::new(8, 0.3), threads);
        let (w_seq, w_par) = (cs_seq.total_weight(), cs_par.total_weight());
        if (w_seq - w_par).abs() > 1e-6 * (1.0 + w_seq) {
            return Err(Error::msg(format!(
                "build_par weight parity failure: {w_par} vs {w_seq}"
            )));
        }
        println!(
            "build_par parity OK ({} blocks par vs {} seq, weight {w_par:.1})",
            cs_par.blocks.len(),
            cs_seq.blocks.len()
        );
    }
    println!("runtime OK");
    Ok(())
}
