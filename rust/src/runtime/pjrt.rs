//! PJRT backend (cargo feature `pjrt`) — loads the AOT-compiled
//! JAX/Pallas artifacts (`artifacts/*.hlo.txt`, produced once by
//! `make artifacts`) and executes them from the Rust hot path. Python
//! never runs at request time.
//!
//! Off by default so the crate builds offline with no non-std
//! dependencies; the default build uses [`super::NativeBackend`]
//! instead. The `xla` dependency resolves to the bundled compile-only
//! stub under `rust/vendor/xla` — swap in a real PJRT binding (see that
//! crate's docs) to execute on actual hardware; this module's code is
//! identical either way.

use std::collections::HashMap;
use std::path::Path;

use crate::ensure;
use crate::error::{Error, Result};

use super::{KernelBackend, RECT_BATCH, TILE};

/// The PJRT runtime: CPU client + compiled executables keyed by artifact
/// name. Compilation happens once at load; execution is pure compute.
/// Implements [`KernelBackend`], so everything downstream of the trait
/// (tiled execution, CLI, benches) is backend-agnostic.
pub struct Runtime {
    client: xla::PjRtClient,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Load every `*.hlo.txt` in `dir` and compile it on the CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| Error::msg(format!("pjrt client: {e:?}")))?;
        let mut execs = HashMap::new();
        let entries = std::fs::read_dir(dir).map_err(|e| {
            Error::msg(e).context(format!("artifacts dir {dir:?} (run `make artifacts`)"))
        })?;
        for entry in entries {
            let path = entry.map_err(Error::msg)?.path();
            let Some(name) = path.file_name().and_then(|s| s.to_str()) else { continue };
            let Some(stem) = name.strip_suffix(".hlo.txt") else { continue };
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| Error::msg(format!("parse {name}: {e:?}")))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| Error::msg(format!("compile {name}: {e:?}")))?;
            execs.insert(stem.to_string(), exe);
        }
        Ok(Self { client, execs })
    }

    /// Load from the default directory.
    pub fn load_default() -> Result<Self> {
        Self::load(&super::default_artifacts_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn has(&self, name: &str) -> bool {
        self.execs.contains_key(name)
    }

    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.execs.keys().cloned().collect();
        v.sort();
        v
    }

    fn exec(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.execs
            .get(name)
            .ok_or_else(|| Error::msg(format!("artifact '{name}' not loaded")))
    }
}

impl KernelBackend for Runtime {
    fn name(&self) -> String {
        format!("pjrt({})", self.platform())
    }

    /// `prefix2d`: inclusive 2D prefix sums of a TILE×TILE tile.
    /// Returns (Σy, Σy²) integral images (inclusive, unpadded).
    fn prefix2d(&self, tile: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        ensure!(tile.len() == TILE * TILE, "tile must be {TILE}x{TILE}");
        let exe = self.exec("prefix2d")?;
        let x = xla::Literal::vec1(tile)
            .reshape(&[TILE as i64, TILE as i64])
            .map_err(|e| Error::msg(format!("reshape: {e:?}")))?;
        let result = exe
            .execute::<xla::Literal>(&[x])
            // lint:allow(index-hot) -- PJRT returns per-device, per-output
            // buffer lists; [0][0] selects the single device's one output.
            .map_err(|e| Error::msg(format!("execute prefix2d: {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| Error::msg(format!("to_literal: {e:?}")))?;
        let (a, b) = result
            .to_tuple2()
            .map_err(|e| Error::msg(format!("tuple2: {e:?}")))?;
        Ok((
            a.to_vec::<f32>().map_err(|e| Error::msg(format!("{e:?}")))?,
            b.to_vec::<f32>().map_err(|e| Error::msg(format!("{e:?}")))?,
        ))
    }

    /// `block_sse`: batched opt₁ over rectangles, given *padded*
    /// (TILE+1)² integral images. Rects are (r0, r1, c0, c1) inclusive;
    /// entries beyond the real batch should be (0,0,0,0) (their output is
    /// ignored by the caller).
    fn block_sse(
        &self,
        padded_ii_y: &[f32],
        padded_ii_y2: &[f32],
        rects: &[[i32; 4]],
    ) -> Result<Vec<f32>> {
        let side = TILE + 1;
        ensure!(padded_ii_y.len() == side * side, "padded ii shape");
        ensure!(padded_ii_y2.len() == side * side, "padded ii shape");
        ensure!(rects.len() <= RECT_BATCH, "≤ {RECT_BATCH} rects per call");
        let exe = self.exec("block_sse")?;
        let mut flat: Vec<i32> = Vec::with_capacity(RECT_BATCH * 4);
        for r in rects {
            flat.extend_from_slice(r);
        }
        flat.resize(RECT_BATCH * 4, 0);
        let ii_y = xla::Literal::vec1(padded_ii_y)
            .reshape(&[side as i64, side as i64])
            .map_err(|e| Error::msg(format!("{e:?}")))?;
        let ii_y2 = xla::Literal::vec1(padded_ii_y2)
            .reshape(&[side as i64, side as i64])
            .map_err(|e| Error::msg(format!("{e:?}")))?;
        let r = xla::Literal::vec1(&flat)
            .reshape(&[RECT_BATCH as i64, 4])
            .map_err(|e| Error::msg(format!("{e:?}")))?;
        let result = exe
            .execute::<xla::Literal>(&[ii_y, ii_y2, r])
            // lint:allow(index-hot) -- single device, single output.
            .map_err(|e| Error::msg(format!("execute block_sse: {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| Error::msg(format!("{e:?}")))?;
        let out = result
            .to_tuple1()
            .map_err(|e| Error::msg(format!("{e:?}")))?;
        let mut v = out
            .to_vec::<f32>()
            .map_err(|e| Error::msg(format!("{e:?}")))?;
        v.truncate(rects.len());
        Ok(v)
    }

    /// `seg_loss`: SSE between a signal tile and a rendered segmentation
    /// tile (both TILE×TILE).
    fn seg_loss(&self, signal: &[f32], rendered: &[f32]) -> Result<f32> {
        ensure!(
            signal.len() == TILE * TILE && rendered.len() == TILE * TILE,
            "seg_loss tiles must be {TILE}x{TILE}"
        );
        let exe = self.exec("seg_loss")?;
        let a = xla::Literal::vec1(signal)
            .reshape(&[TILE as i64, TILE as i64])
            .map_err(|e| Error::msg(format!("{e:?}")))?;
        let b = xla::Literal::vec1(rendered)
            .reshape(&[TILE as i64, TILE as i64])
            .map_err(|e| Error::msg(format!("{e:?}")))?;
        let result = exe
            .execute::<xla::Literal>(&[a, b])
            // lint:allow(index-hot) -- single device, single output.
            .map_err(|e| Error::msg(format!("execute seg_loss: {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| Error::msg(format!("{e:?}")))?;
        let out = result
            .to_tuple1()
            .map_err(|e| Error::msg(format!("{e:?}")))?;
        let v = out
            .to_vec::<f32>()
            .map_err(|e| Error::msg(format!("{e:?}")))?;
        Ok(v[0]) // lint:allow(index-hot) -- scalar kernel output (len 1).
    }
}

#[cfg(test)]
mod tests {
    use super::super::artifacts_available;
    use super::*;
    use crate::rng::Rng;

    fn runtime_or_skip() -> Option<Runtime> {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        match Runtime::load_default() {
            Ok(rt) => Some(rt),
            Err(e) => {
                // The bundled xla stub compiles but cannot execute; a real
                // binding is needed for these tests to run.
                eprintln!("skipping: pjrt runtime unavailable ({e})");
                None
            }
        }
    }

    #[test]
    fn prefix2d_matches_native_backend() {
        let Some(rt) = runtime_or_skip() else { return };
        let native = super::super::NativeBackend::new();
        let mut rng = Rng::new(60);
        let tile: Vec<f32> = (0..TILE * TILE).map(|_| rng.normal() as f32).collect();
        let (got_y, got_y2) = rt.prefix2d(&tile).unwrap();
        let (ref_y, ref_y2) = native.prefix2d(&tile).unwrap();
        for i in (0..TILE * TILE).step_by(997) {
            let (ry, ry2) = (ref_y[i] as f64, ref_y2[i] as f64);
            assert!((got_y[i] as f64 - ry).abs() < 1e-2 * (1.0 + ry.abs()), "ii_y[{i}]");
            assert!((got_y2[i] as f64 - ry2).abs() < 1e-2 * (1.0 + ry2.abs()), "ii_y2[{i}]");
        }
    }

    #[test]
    fn runtime_lists_artifacts() {
        let Some(rt) = runtime_or_skip() else { return };
        for expected in ["block_sse", "prefix2d", "seg_loss"] {
            assert!(rt.has(expected), "{expected} missing from {:?}", rt.artifact_names());
        }
        assert!(!rt.platform().is_empty());
    }
}
