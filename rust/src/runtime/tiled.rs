//! Tiled execution of the runtime kernels over signals larger than the
//! compiled TILE — the bridge between the L3 coordinator's arbitrary
//! signal sizes and the fixed-shape kernel contract. Backend-agnostic:
//! works identically over [`super::NativeBackend`] and the PJRT runtime.
//!
//! A signal is cut into TILE×TILE tiles (zero-padded at the edges; zero
//! cells contribute nothing to Σy/Σy² so block statistics restricted to
//! the real extent are unaffected). Per-tile integral images let us
//! answer opt₁ for any rectangle *within a tile*; rectangles spanning
//! tiles are answered by summing per-tile moments (inclusion–exclusion
//! inside each covered tile).

use crate::error::Result;
use crate::signal::{Rect, Signal};

use super::{corner, pad_integral, KernelBackend, RECT_BATCH, TILE};

/// Per-tile padded integral images for a whole signal, built through any
/// [`KernelBackend`].
pub struct TiledPrefix<'b> {
    backend: &'b dyn KernelBackend,
    n: usize,
    m: usize,
    #[allow(dead_code)]
    tiles_r: usize,
    tiles_c: usize,
    /// Padded (TILE+1)² integral images per tile, row-major tile order.
    ii_y: Vec<Vec<f32>>,
    ii_y2: Vec<Vec<f32>>,
}

impl<'b> TiledPrefix<'b> {
    /// Build the per-tile integral images through the backend's
    /// `prefix2d` kernel. Masked cells are zero-filled (the f32
    /// pipeline's semantics: moments over the real extent are exact,
    /// opt₁ counts come from rectangle geometry).
    pub fn build(backend: &'b dyn KernelBackend, signal: &Signal) -> Result<Self> {
        let n = signal.rows();
        let m = signal.cols();
        let tiles_r = n.div_ceil(TILE);
        let tiles_c = m.div_ceil(TILE);
        let mut ii_y = Vec::with_capacity(tiles_r * tiles_c);
        let mut ii_y2 = Vec::with_capacity(tiles_r * tiles_c);
        let mut tile = vec![0.0f32; TILE * TILE];
        // Scratch integral images reused across every tile via
        // `prefix2d_into` — two allocations for the whole build instead
        // of two per tile (counted by bench_runtime's alloc profile).
        let mut y = Vec::new();
        let mut y2 = Vec::new();
        for tr in 0..tiles_r {
            for tc in 0..tiles_c {
                tile.iter_mut().for_each(|v| *v = 0.0);
                let r0 = tr * TILE;
                let c0 = tc * TILE;
                let height = (r0 + TILE).min(n) - r0;
                let width = (c0 + TILE).min(m) - c0;
                for (lr, dst_row) in tile.chunks_exact_mut(TILE).take(height).enumerate() {
                    let r = r0 + lr;
                    for (dst, c) in dst_row[..width].iter_mut().zip(c0..) {
                        if signal.is_present(r, c) {
                            *dst = signal.get(r, c) as f32;
                        }
                    }
                }
                backend.prefix2d_into(&tile, &mut y, &mut y2)?;
                ii_y.push(pad_integral(&y));
                ii_y2.push(pad_integral(&y2));
            }
        }
        Ok(Self { backend, n, m, tiles_r, tiles_c, ii_y, ii_y2 })
    }

    /// The backend this instance executes on.
    pub fn backend_name(&self) -> String {
        self.backend.name()
    }

    #[inline]
    fn tile_idx(&self, tr: usize, tc: usize) -> usize {
        tr * self.tiles_c + tc
    }

    /// Both padded integral images of one tile — the single O(1) lookup
    /// behind every tile query.
    #[inline]
    fn tile_images(&self, idx: usize) -> (&[f32], &[f32]) {
        // lint:allow(index-hot) -- O(1) tile lookup; idx comes from
        // rect/TILE arithmetic bounded by the build-time tile grid.
        (&self.ii_y[idx], &self.ii_y2[idx])
    }

    /// Sum and sum-of-squares of a rectangle from the padded per-tile
    /// integral images (CPU-side inclusion–exclusion; no kernel call).
    pub fn moments(&self, rect: &Rect) -> (f64, f64) {
        debug_assert!(rect.r1 < self.n && rect.c1 < self.m);
        let side = TILE + 1;
        let mut sum = 0.0f64;
        let mut sum_sq = 0.0f64;
        let tr0 = rect.r0 / TILE;
        let tr1 = rect.r1 / TILE;
        let tc0 = rect.c0 / TILE;
        let tc1 = rect.c1 / TILE;
        for tr in tr0..=tr1 {
            for tc in tc0..=tc1 {
                let idx = self.tile_idx(tr, tc);
                // Rectangle clipped to this tile, in tile-local coords.
                let lr0 = rect.r0.max(tr * TILE) - tr * TILE;
                let lr1 = rect.r1.min(tr * TILE + TILE - 1) - tr * TILE;
                let lc0 = rect.c0.max(tc * TILE) - tc * TILE;
                let lc1 = rect.c1.min(tc * TILE + TILE - 1) - tc * TILE;
                let q = |arr: &[f32]| -> f64 {
                    corner(arr, (lr1 + 1) * side + (lc1 + 1)) - corner(arr, lr0 * side + (lc1 + 1))
                        - corner(arr, (lr1 + 1) * side + lc0)
                        + corner(arr, lr0 * side + lc0)
                };
                let (iy, iy2) = self.tile_images(idx);
                sum += q(iy);
                sum_sq += q(iy2);
            }
        }
        (sum, sum_sq)
    }

    /// Batched opt₁ for rectangles that each fit inside a single tile,
    /// dispatched through the backend's `block_sse` kernel (RECT_BATCH at
    /// a time). Rects spanning tiles fall back to [`Self::moments`].
    pub fn batched_opt1(&self, rects: &[Rect]) -> Result<Vec<f64>> {
        let mut out = vec![0.0f64; rects.len()];
        // Group in-tile rects by tile.
        let mut groups: std::collections::HashMap<usize, Vec<usize>> =
            std::collections::HashMap::new();
        for (i, r) in rects.iter().enumerate() {
            let tr0 = r.r0 / TILE;
            let tr1 = r.r1 / TILE;
            let tc0 = r.c0 / TILE;
            let tc1 = r.c1 / TILE;
            if tr0 == tr1 && tc0 == tc1 {
                groups.entry(self.tile_idx(tr0, tc0)).or_default().push(i);
            } else {
                // Spanning rect: CPU inclusion–exclusion. Count comes from
                // geometry (full signals; masked cells are zero-filled,
                // matching the f32 pipeline's semantics).
                let (s, q) = self.moments(r);
                let cnt = r.area() as f64;
                // lint:allow(index-hot) -- scatter into the caller's rect
                // order; i < rects.len() by the enumerate above.
                out[i] = (q - s * s / cnt).max(0.0);
            }
        }
        for (tile_idx, members) in groups {
            for chunk in members.chunks(RECT_BATCH) {
                let batch: Vec<[i32; 4]> = chunk
                    .iter()
                    .map(|&i| {
                        // lint:allow(index-hot) -- gather by the group's
                        // stored indices, all < rects.len() by build.
                        let r = rects[i];
                        let tr = (r.r0 / TILE) * TILE;
                        let tc = (r.c0 / TILE) * TILE;
                        [
                            (r.r0 - tr) as i32,
                            (r.r1 - tr) as i32,
                            (r.c0 - tc) as i32,
                            (r.c1 - tc) as i32,
                        ]
                    })
                    .collect();
                let (iy, iy2) = self.tile_images(tile_idx);
                let res = self.backend.block_sse(iy, iy2, &batch)?;
                for (&i, v) in chunk.iter().zip(res) {
                    // lint:allow(index-hot) -- scatter back to the
                    // caller's rect order; same bound as the gather.
                    out[i] = v as f64;
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::super::NativeBackend;
    use super::*;
    use crate::rng::Rng;
    use crate::signal::{generate, PrefixStats};

    #[test]
    fn tiled_moments_match_native() {
        let backend = NativeBackend::new();
        let mut rng = Rng::new(70);
        let sig = generate::smooth(300, 280, 3, &mut rng); // spans 2x2 tiles
        let stats = PrefixStats::new(&sig);
        let tp = TiledPrefix::build(&backend, &sig).unwrap();
        for _ in 0..50 {
            let r0 = rng.usize(300);
            let r1 = rng.range(r0, 300);
            let c0 = rng.usize(280);
            let c1 = rng.range(c0, 280);
            let rect = Rect::new(r0, r1, c0, c1);
            let (s, q) = tp.moments(&rect);
            let exact = stats.moments(&rect);
            assert!(
                (s - exact.sum).abs() < 1e-2 * (1.0 + exact.sum.abs()),
                "sum {s} vs {}",
                exact.sum
            );
            assert!(
                (q - exact.sum_sq).abs() < 1e-2 * (1.0 + exact.sum_sq.abs()),
                "sumsq {q} vs {}",
                exact.sum_sq
            );
        }
    }

    #[test]
    fn tiled_batched_opt1_matches_native() {
        let backend = NativeBackend::new();
        let mut rng = Rng::new(71);
        let sig = generate::smooth(300, 300, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let tp = TiledPrefix::build(&backend, &sig).unwrap();
        let rects: Vec<Rect> = (0..100)
            .map(|_| {
                let r0 = rng.usize(300);
                let r1 = rng.range(r0, 300);
                let c0 = rng.usize(300);
                let c1 = rng.range(c0, 300);
                Rect::new(r0, r1, c0, c1)
            })
            .collect();
        let got = tp.batched_opt1(&rects).unwrap();
        for (g, r) in got.iter().zip(rects.iter()) {
            let e = stats.opt1(r);
            assert!((g - e).abs() <= 0.05 * (1.0 + e.abs()), "{g} vs {e} for {r:?}");
        }
    }
}
