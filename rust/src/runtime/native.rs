//! Pure-Rust kernel backend — a std-only implementation of the artifact
//! contract ([`super::KernelBackend`]), always available and the default
//! execution path. Shapes and output precision (f32) match the AOT
//! kernels exactly; internal accumulation is f64, which stays within the
//! f32 tolerance the contract allows (the PJRT kernels accumulate in f32,
//! so the native backend is the *more* accurate of the two).

use crate::ensure;
use crate::error::Result;

use super::{KernelBackend, RECT_BATCH, TILE};

/// The native (pure-Rust) kernel backend. Stateless; construction is
/// free, so build one wherever a [`KernelBackend`] is needed.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> Self {
        Self
    }
}

impl KernelBackend for NativeBackend {
    fn name(&self) -> String {
        "native".to_string()
    }

    /// Inclusive 2D prefix sums of y and y² over a TILE×TILE tile
    /// (row-major), returned as unpadded TILE×TILE integral images.
    fn prefix2d(&self, tile: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        ensure!(tile.len() == TILE * TILE, "tile must be {TILE}x{TILE}");
        let mut ii_y = vec![0.0f32; TILE * TILE];
        let mut ii_y2 = vec![0.0f32; TILE * TILE];
        for r in 0..TILE {
            let mut row_y = 0.0f64;
            let mut row_y2 = 0.0f64;
            for c in 0..TILE {
                let v = tile[r * TILE + c] as f64;
                row_y += v;
                row_y2 += v * v;
                let (up_y, up_y2) = if r > 0 {
                    (
                        ii_y[(r - 1) * TILE + c] as f64,
                        ii_y2[(r - 1) * TILE + c] as f64,
                    )
                } else {
                    (0.0, 0.0)
                };
                ii_y[r * TILE + c] = (up_y + row_y) as f32;
                ii_y2[r * TILE + c] = (up_y2 + row_y2) as f32;
            }
        }
        Ok((ii_y, ii_y2))
    }

    /// Batched opt₁ over tile-local rectangles from *padded* (TILE+1)²
    /// integral images. Rects are (r0, r1, c0, c1) inclusive; the count
    /// in opt₁ comes from rectangle geometry (masked cells are zero-filled
    /// upstream — the f32 pipeline's semantics).
    fn block_sse(
        &self,
        padded_ii_y: &[f32],
        padded_ii_y2: &[f32],
        rects: &[[i32; 4]],
    ) -> Result<Vec<f32>> {
        let side = TILE + 1;
        ensure!(padded_ii_y.len() == side * side, "padded ii shape");
        ensure!(padded_ii_y2.len() == side * side, "padded ii shape");
        ensure!(rects.len() <= RECT_BATCH, "≤ {RECT_BATCH} rects per call");
        let mut out = Vec::with_capacity(rects.len());
        for rect in rects {
            let (r0, r1, c0, c1) = (rect[0], rect[1], rect[2], rect[3]);
            ensure!(
                0 <= r0 && r0 <= r1 && (r1 as usize) < TILE
                    && 0 <= c0 && c0 <= c1 && (c1 as usize) < TILE,
                "rect {rect:?} out of tile bounds"
            );
            let (r0, r1, c0, c1) = (r0 as usize, r1 as usize, c0 as usize, c1 as usize);
            let q = |arr: &[f32]| -> f64 {
                arr[(r1 + 1) * side + (c1 + 1)] as f64
                    - arr[r0 * side + (c1 + 1)] as f64
                    - arr[(r1 + 1) * side + c0] as f64
                    + arr[r0 * side + c0] as f64
            };
            let moments = crate::signal::stats::Moments {
                count: ((r1 - r0 + 1) * (c1 - c0 + 1)) as f64,
                sum: q(padded_ii_y),
                sum_sq: q(padded_ii_y2),
            };
            out.push(moments.opt1() as f32);
        }
        Ok(out)
    }

    /// SSE between a signal tile and a rendered segmentation tile.
    fn seg_loss(&self, signal: &[f32], rendered: &[f32]) -> Result<f32> {
        ensure!(
            signal.len() == TILE * TILE && rendered.len() == TILE * TILE,
            "seg_loss tiles must be {TILE}x{TILE}"
        );
        let mut total = 0.0f64;
        for (a, b) in signal.iter().zip(rendered.iter()) {
            let d = (*a - *b) as f64;
            total += d * d;
        }
        Ok(total as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::runtime::pad_integral;
    use crate::signal::{PrefixStats, Rect, Signal};

    /// Reference prefix sums in f64.
    fn ref_prefix(tile: &[f32]) -> (Vec<f64>, Vec<f64>) {
        let mut py = vec![0.0f64; TILE * TILE];
        let mut py2 = vec![0.0f64; TILE * TILE];
        for r in 0..TILE {
            let mut row_y = 0.0;
            let mut row_y2 = 0.0;
            for c in 0..TILE {
                let v = tile[r * TILE + c] as f64;
                row_y += v;
                row_y2 += v * v;
                let up_y = if r > 0 { py[(r - 1) * TILE + c] } else { 0.0 };
                let up_y2 = if r > 0 { py2[(r - 1) * TILE + c] } else { 0.0 };
                py[r * TILE + c] = up_y + row_y;
                py2[r * TILE + c] = up_y2 + row_y2;
            }
        }
        (py, py2)
    }

    #[test]
    fn prefix2d_matches_f64_reference() {
        let backend = NativeBackend::new();
        let mut rng = Rng::new(60);
        let tile: Vec<f32> = (0..TILE * TILE).map(|_| rng.normal() as f32).collect();
        let (got_y, got_y2) = backend.prefix2d(&tile).unwrap();
        let (ref_y, ref_y2) = ref_prefix(&tile);
        for i in (0..TILE * TILE).step_by(997) {
            assert!(
                (got_y[i] as f64 - ref_y[i]).abs() < 1e-2 * (1.0 + ref_y[i].abs()),
                "ii_y[{i}]"
            );
            assert!(
                (got_y2[i] as f64 - ref_y2[i]).abs() < 1e-2 * (1.0 + ref_y2[i].abs()),
                "ii_y2[{i}]"
            );
        }
    }

    #[test]
    fn block_sse_matches_prefix_stats_opt1() {
        let backend = NativeBackend::new();
        let mut rng = Rng::new(61);
        let tile: Vec<f32> = (0..TILE * TILE).map(|_| rng.normal() as f32).collect();
        let (ii_y, ii_y2) = backend.prefix2d(&tile).unwrap();
        let p_y = pad_integral(&ii_y);
        let p_y2 = pad_integral(&ii_y2);
        let sig = Signal::from_fn(TILE, TILE, |r, c| tile[r * TILE + c] as f64);
        let stats = PrefixStats::new(&sig);
        let mut rects = Vec::new();
        let mut expect = Vec::new();
        for _ in 0..64 {
            let r0 = rng.usize(TILE);
            let r1 = rng.range(r0, TILE);
            let c0 = rng.usize(TILE);
            let c1 = rng.range(c0, TILE);
            rects.push([r0 as i32, r1 as i32, c0 as i32, c1 as i32]);
            expect.push(stats.opt1(&Rect::new(r0, r1, c0, c1)));
        }
        let got = backend.block_sse(&p_y, &p_y2, &rects).unwrap();
        assert_eq!(got.len(), rects.len());
        for (g, e) in got.iter().zip(expect.iter()) {
            // f32 integral images lose precision on large blocks; relative
            // tolerance scaled by the block magnitude.
            assert!((*g as f64 - e).abs() <= 5e-2 * (1.0 + e.abs()), "{g} vs {e}");
        }
    }

    #[test]
    fn seg_loss_matches_direct_sum() {
        let backend = NativeBackend::new();
        let mut rng = Rng::new(62);
        let a: Vec<f32> = (0..TILE * TILE).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..TILE * TILE).map(|_| rng.normal() as f32).collect();
        let got = backend.seg_loss(&a, &b).unwrap() as f64;
        let expect: f64 = a
            .iter()
            .zip(b.iter())
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum();
        assert!((got - expect).abs() < 1e-3 * (1.0 + expect), "{got} vs {expect}");
    }

    #[test]
    fn shape_violations_are_errors() {
        let backend = NativeBackend::new();
        assert!(backend.prefix2d(&[0.0; 4]).is_err());
        assert!(backend.seg_loss(&[0.0; 4], &[0.0; 4]).is_err());
        let side = TILE + 1;
        let padded = vec![0.0f32; side * side];
        // Out-of-tile rect rejected.
        assert!(backend
            .block_sse(&padded, &padded, &[[0, TILE as i32, 0, 0]])
            .is_err());
        // Oversized batch rejected.
        let too_many = vec![[0i32, 0, 0, 0]; RECT_BATCH + 1];
        assert!(backend.block_sse(&padded, &padded, &too_many).is_err());
    }
}
