//! Pure-Rust kernel backend — a std-only implementation of the artifact
//! contract ([`super::KernelBackend`]), always available and the default
//! execution path. Shapes and output precision (f32) match the AOT
//! kernels exactly; internal accumulation is f64 with cascaded pairwise
//! reduction for the long sums (`seg_loss`), which stays well within the
//! f32 tolerance the contract allows (the PJRT kernels accumulate in
//! f32, so the native backend is the *more* accurate of the two; the
//! tolerance policy is documented in DESIGN.md §Kernels).

use crate::ensure;
use crate::error::Result;

use super::{pairwise_sum, rect_opt1, KernelBackend, RECT_BATCH, TILE};

/// The native (pure-Rust) kernel backend. Stateless; construction is
/// free, so build one wherever a [`KernelBackend`] is needed.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> Self {
        Self
    }
}

/// The scalar one-pass integral-image fill: per row, a serial f64
/// running sum over the row, interleaved with the vertical add of the
/// stored (f32) row above. This is the reference arithmetic every other
/// in-process backend must reproduce bit-for-bit (see
/// [`super::blocked`] for the two-pass restatement).
fn fill_prefix2d(tile: &[f32], ii_y: &mut [f32], ii_y2: &mut [f32]) {
    const ZEROS: [f32; TILE] = [0.0; TILE];
    for r in 0..TILE {
        let mut row_y = 0.0f64;
        let mut row_y2 = 0.0f64;
        let row = &tile[r * TILE..(r + 1) * TILE];
        let (above_y, cur_y) = ii_y[..(r + 1) * TILE].split_at_mut(r * TILE);
        let (above_y2, cur_y2) = ii_y2[..(r + 1) * TILE].split_at_mut(r * TILE);
        let (up_y, up_y2): (&[f32], &[f32]) = if r > 0 {
            (&above_y[(r - 1) * TILE..], &above_y2[(r - 1) * TILE..])
        } else {
            (&ZEROS, &ZEROS)
        };
        let dst = cur_y.iter_mut().zip(cur_y2.iter_mut());
        let up = up_y.iter().zip(up_y2.iter());
        for ((&v, (dy, dy2)), (&uy, &uy2)) in row.iter().zip(dst).zip(up) {
            let v = v as f64;
            row_y += v;
            row_y2 += v * v;
            *dy = (uy as f64 + row_y) as f32;
            *dy2 = (uy2 as f64 + row_y2) as f32;
        }
    }
}

impl KernelBackend for NativeBackend {
    fn name(&self) -> String {
        "native".to_string()
    }

    /// Inclusive 2D prefix sums of y and y² over a TILE×TILE tile
    /// (row-major), returned as unpadded TILE×TILE integral images.
    fn prefix2d(&self, tile: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut ii_y = Vec::new();
        let mut ii_y2 = Vec::new();
        self.prefix2d_into(tile, &mut ii_y, &mut ii_y2)?;
        Ok((ii_y, ii_y2))
    }

    /// In-place [`Self::prefix2d`]: reuses the buffers' capacity, so hot
    /// callers ([`super::tiled::TiledPrefix`]) stop allocating per tile.
    fn prefix2d_into(
        &self,
        tile: &[f32],
        out_y: &mut Vec<f32>,
        out_y2: &mut Vec<f32>,
    ) -> Result<()> {
        ensure!(tile.len() == TILE * TILE, "tile must be {TILE}x{TILE}");
        out_y.clear();
        out_y.resize(TILE * TILE, 0.0);
        out_y2.clear();
        out_y2.resize(TILE * TILE, 0.0);
        fill_prefix2d(tile, out_y, out_y2);
        Ok(())
    }

    /// Batched opt₁ over tile-local rectangles from *padded* (TILE+1)²
    /// integral images. Rects are (r0, r1, c0, c1) inclusive; the count
    /// in opt₁ comes from rectangle geometry (masked cells are zero-filled
    /// upstream — the f32 pipeline's semantics).
    fn block_sse(
        &self,
        padded_ii_y: &[f32],
        padded_ii_y2: &[f32],
        rects: &[[i32; 4]],
    ) -> Result<Vec<f32>> {
        let side = TILE + 1;
        ensure!(padded_ii_y.len() == side * side, "padded ii shape");
        ensure!(padded_ii_y2.len() == side * side, "padded ii shape");
        ensure!(rects.len() <= RECT_BATCH, "≤ {RECT_BATCH} rects per call");
        let mut out = Vec::with_capacity(rects.len());
        for rect in rects {
            out.push(rect_opt1(padded_ii_y, padded_ii_y2, rect)?);
        }
        Ok(out)
    }

    /// SSE between a signal tile and a rendered segmentation tile.
    /// Cascaded pairwise summation: one serial f64 partial per row, then
    /// a pairwise (tree) reduction over the TILE row partials — rounding
    /// error O(TILE + log TILE)·ε instead of the flat scan's O(TILE²)·ε,
    /// so large-tile error stops growing linearly with the cell count.
    fn seg_loss(&self, signal: &[f32], rendered: &[f32]) -> Result<f32> {
        ensure!(
            signal.len() == TILE * TILE && rendered.len() == TILE * TILE,
            "seg_loss tiles must be {TILE}x{TILE}"
        );
        let mut partials = [0.0f64; TILE];
        let rows = signal.chunks_exact(TILE).zip(rendered.chunks_exact(TILE));
        for (p, (sig_row, ren_row)) in partials.iter_mut().zip(rows) {
            let mut acc = 0.0f64;
            for (a, b) in sig_row.iter().zip(ren_row.iter()) {
                let d = (*a - *b) as f64;
                acc += d * d;
            }
            *p = acc;
        }
        Ok(pairwise_sum(&partials) as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::runtime::pad_integral;
    use crate::signal::{PrefixStats, Rect, Signal};

    /// Reference prefix sums in f64.
    fn ref_prefix(tile: &[f32]) -> (Vec<f64>, Vec<f64>) {
        let mut py = vec![0.0f64; TILE * TILE];
        let mut py2 = vec![0.0f64; TILE * TILE];
        for r in 0..TILE {
            let mut row_y = 0.0;
            let mut row_y2 = 0.0;
            for c in 0..TILE {
                let v = tile[r * TILE + c] as f64;
                row_y += v;
                row_y2 += v * v;
                let up_y = if r > 0 { py[(r - 1) * TILE + c] } else { 0.0 };
                let up_y2 = if r > 0 { py2[(r - 1) * TILE + c] } else { 0.0 };
                py[r * TILE + c] = up_y + row_y;
                py2[r * TILE + c] = up_y2 + row_y2;
            }
        }
        (py, py2)
    }

    #[test]
    fn prefix2d_matches_f64_reference() {
        let backend = NativeBackend::new();
        let mut rng = Rng::new(60);
        let tile: Vec<f32> = (0..TILE * TILE).map(|_| rng.normal() as f32).collect();
        let (got_y, got_y2) = backend.prefix2d(&tile).unwrap();
        let (ref_y, ref_y2) = ref_prefix(&tile);
        for i in (0..TILE * TILE).step_by(997) {
            assert!(
                (got_y[i] as f64 - ref_y[i]).abs() < 1e-2 * (1.0 + ref_y[i].abs()),
                "ii_y[{i}]"
            );
            assert!(
                (got_y2[i] as f64 - ref_y2[i]).abs() < 1e-2 * (1.0 + ref_y2[i].abs()),
                "ii_y2[{i}]"
            );
        }
    }

    #[test]
    fn prefix2d_into_reuses_buffers_and_matches() {
        let backend = NativeBackend::new();
        let mut rng = Rng::new(63);
        let tile: Vec<f32> = (0..TILE * TILE).map(|_| rng.normal() as f32).collect();
        let (y, y2) = backend.prefix2d(&tile).unwrap();
        // Pre-dirtied, pre-sized buffers: contents must be fully replaced.
        let mut by = vec![7.0f32; TILE * TILE];
        let mut by2 = vec![7.0f32; 3];
        backend.prefix2d_into(&tile, &mut by, &mut by2).unwrap();
        assert_eq!(y, by);
        assert_eq!(y2, by2);
    }

    #[test]
    fn block_sse_matches_prefix_stats_opt1() {
        let backend = NativeBackend::new();
        let mut rng = Rng::new(61);
        let tile: Vec<f32> = (0..TILE * TILE).map(|_| rng.normal() as f32).collect();
        let (ii_y, ii_y2) = backend.prefix2d(&tile).unwrap();
        let p_y = pad_integral(&ii_y);
        let p_y2 = pad_integral(&ii_y2);
        let sig = Signal::from_fn(TILE, TILE, |r, c| tile[r * TILE + c] as f64);
        let stats = PrefixStats::new(&sig);
        let mut rects = Vec::new();
        let mut expect = Vec::new();
        for _ in 0..64 {
            let r0 = rng.usize(TILE);
            let r1 = rng.range(r0, TILE);
            let c0 = rng.usize(TILE);
            let c1 = rng.range(c0, TILE);
            rects.push([r0 as i32, r1 as i32, c0 as i32, c1 as i32]);
            expect.push(stats.opt1(&Rect::new(r0, r1, c0, c1)));
        }
        let got = backend.block_sse(&p_y, &p_y2, &rects).unwrap();
        assert_eq!(got.len(), rects.len());
        for (g, e) in got.iter().zip(expect.iter()) {
            // f32 integral images lose precision on large blocks; relative
            // tolerance scaled by the block magnitude.
            assert!((*g as f64 - e).abs() <= 5e-2 * (1.0 + e.abs()), "{g} vs {e}");
        }
    }

    #[test]
    fn seg_loss_matches_direct_sum() {
        let backend = NativeBackend::new();
        let mut rng = Rng::new(62);
        let a: Vec<f32> = (0..TILE * TILE).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..TILE * TILE).map(|_| rng.normal() as f32).collect();
        let got = backend.seg_loss(&a, &b).unwrap() as f64;
        let expect: f64 = a
            .iter()
            .zip(b.iter())
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum();
        // With cascaded pairwise accumulation the only budget left is the
        // final f32 cast (~6e-8 rel) — pinned at 1e-6 (was 1e-3 for the
        // flat scan).
        assert!((got - expect).abs() < 1e-6 * (1.0 + expect), "{got} vs {expect}");
    }

    #[test]
    fn shape_violations_are_errors() {
        let backend = NativeBackend::new();
        assert!(backend.prefix2d(&[0.0; 4]).is_err());
        assert!(backend.seg_loss(&[0.0; 4], &[0.0; 4]).is_err());
        let side = TILE + 1;
        let padded = vec![0.0f32; side * side];
        // Out-of-tile rect rejected.
        assert!(backend
            .block_sse(&padded, &padded, &[[0, TILE as i32, 0, 0]])
            .is_err());
        // Oversized batch rejected.
        let too_many = vec![[0i32, 0, 0, 0]; RECT_BATCH + 1];
        assert!(backend.block_sse(&padded, &padded, &too_many).is_err());
    }
}
