//! Cache-blocked, auto-vectorizable kernel backend — std-only, no
//! `unsafe`, no intrinsics. Same artifact contract as
//! [`super::NativeBackend`], restructured so rustc/LLVM can vectorize
//! the order-independent halves of each kernel:
//!
//! * **`prefix2d` — two-pass blocked prefix sum.** The scalar reference
//!   interleaves, per cell, a serial row accumulation with the vertical
//!   add of the stored row above. Here each row is processed in two
//!   passes over column blocks of width `block`:
//!
//!   1. *Per-block local scan with a carried accumulator*: the f64 row
//!      running sums (Σy, Σy²) are written to scratch rows, block by
//!      block, with the accumulator carried across block boundaries.
//!      Because the carry IS the running accumulator (not a separately
//!      re-associated block total), the addition chain is exactly the
//!      scalar recurrence's — block size cannot change a single bit.
//!   2. *Vertical block carry*: the previous output row is added
//!      elementwise in fixed-width lanes (slice patterns over
//!      `chunks_exact`). Elementwise adds are order-independent per
//!      column, so this pass is trivially bit-stable under any blocking
//!      and is the part LLVM vectorizes.
//!
//!   Net effect: `BlockedBackend::prefix2d` is **bit-identical** to
//!   `NativeBackend::prefix2d` for every block size (pinned by the unit
//!   tests below and `tests/integration_blocked.rs`).
//!
//! * **`block_sse`** — the same per-rect arithmetic as the native
//!   backend (shared [`super::rect_opt1`]), evaluated in block-sized
//!   batches so the four integral-image corner streams stay hot in L1.
//!   Bit-identical to native by construction.
//!
//! * **`seg_loss`** — blocked cascaded summation: one serial f64
//!   partial per `block`-wide lane chunk, then a pairwise (tree)
//!   reduction over the partials. Output depends on the partial layout
//!   (block size), so this kernel is pinned against the native backend
//!   at the f32-quantization tolerance instead of bit-identity (see
//!   DESIGN.md §Kernels); with `block == TILE` the partial layout
//!   matches native's per-row cascade exactly and the outputs are
//!   bit-equal.

use crate::ensure;
use crate::error::Result;

use super::{pairwise_sum, rect_opt1, KernelBackend, RECT_BATCH, TILE};

/// Default column-block width: 64 f64 scratch lanes = 512 B, so one
/// block of scratch plus the two output rows it touches stays resident
/// in L1 while pass 2 streams over it.
pub const BLOCK: usize = 64;

/// Fixed lane width of pass 2's innermost loop — 8 f32/f64 elements, one
/// AVX2 f64 register pair / half an AVX-512 register, unrolled via slice
/// patterns so the chunk size is a compile-time constant.
pub const LANES: usize = 8;

/// The cache-blocked kernel backend. `block` is runtime-tunable (CLI
/// `--block-size`, `EngineConfig::with_block_size`); [`BLOCK`] is the
/// compile-time default.
#[derive(Clone, Copy, Debug)]
pub struct BlockedBackend {
    block: usize,
}

impl Default for BlockedBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl BlockedBackend {
    /// Backend with the default [`BLOCK`] width.
    pub fn new() -> Self {
        Self::with_block(BLOCK)
    }

    /// Backend with an explicit block width (clamped to ≥ 1). Any width
    /// yields bit-identical `prefix2d`/`block_sse` results; the width
    /// only moves the cache/vectorization sweet spot.
    pub fn with_block(block: usize) -> Self {
        Self { block: block.max(1) }
    }

    /// The configured block width.
    pub fn block(&self) -> usize {
        self.block
    }
}

/// Pass 2 inner kernel: `dst[i] = (up[i] as f64 + pref[i]) as f32`,
/// elementwise over one column block, in [`LANES`]-wide exact chunks
/// with slice patterns (remainder handled scalar). The per-element
/// operation matches the scalar backend's store exactly.
fn vadd_cast(dst: &mut [f32], up: &[f32], pref: &[f64]) {
    debug_assert!(dst.len() == up.len() && dst.len() == pref.len());
    let mut d_lanes = dst.chunks_exact_mut(LANES);
    let mut u_lanes = up.chunks_exact(LANES);
    let mut p_lanes = pref.chunks_exact(LANES);
    for ((d, u), p) in (&mut d_lanes).zip(&mut u_lanes).zip(&mut p_lanes) {
        let [d0, d1, d2, d3, d4, d5, d6, d7] = d else { continue };
        let ([u0, u1, u2, u3, u4, u5, u6, u7], [p0, p1, p2, p3, p4, p5, p6, p7]) = (u, p) else {
            continue;
        };
        *d0 = (*u0 as f64 + *p0) as f32;
        *d1 = (*u1 as f64 + *p1) as f32;
        *d2 = (*u2 as f64 + *p2) as f32;
        *d3 = (*u3 as f64 + *p3) as f32;
        *d4 = (*u4 as f64 + *p4) as f32;
        *d5 = (*u5 as f64 + *p5) as f32;
        *d6 = (*u6 as f64 + *p6) as f32;
        *d7 = (*u7 as f64 + *p7) as f32;
    }
    let d_rem = d_lanes.into_remainder();
    let rem = u_lanes.remainder().iter().zip(p_lanes.remainder().iter());
    for (d, (&u, &p)) in d_rem.iter_mut().zip(rem) {
        *d = (u as f64 + p) as f32;
    }
}

impl KernelBackend for BlockedBackend {
    fn name(&self) -> String {
        "blocked".to_string()
    }

    fn prefix2d(&self, tile: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut ii_y = Vec::new();
        let mut ii_y2 = Vec::new();
        self.prefix2d_into(tile, &mut ii_y, &mut ii_y2)?;
        Ok((ii_y, ii_y2))
    }

    /// Two-pass blocked integral-image fill (module docs); bit-identical
    /// to the scalar backend for every block size.
    fn prefix2d_into(
        &self,
        tile: &[f32],
        out_y: &mut Vec<f32>,
        out_y2: &mut Vec<f32>,
    ) -> Result<()> {
        ensure!(tile.len() == TILE * TILE, "tile must be {TILE}x{TILE}");
        out_y.clear();
        out_y.resize(TILE * TILE, 0.0);
        out_y2.clear();
        out_y2.resize(TILE * TILE, 0.0);
        const ZEROS: [f32; TILE] = [0.0; TILE];
        // Scratch rows for the f64 row-prefixes (stack-resident, 2 KiB
        // each — no heap traffic on the hot path).
        let mut pref_y = [0.0f64; TILE];
        let mut pref_y2 = [0.0f64; TILE];
        let block = self.block;
        for r in 0..TILE {
            let row = &tile[r * TILE..(r + 1) * TILE];
            // Pass 1: serial row scan into the scratch rows, walked in
            // column blocks with the accumulator carried across blocks.
            let mut row_y = 0.0f64;
            let mut row_y2 = 0.0f64;
            let prefs = pref_y.chunks_mut(block).zip(pref_y2.chunks_mut(block));
            for (vals, (py, py2)) in row.chunks(block).zip(prefs) {
                for ((&v, dy), dy2) in vals.iter().zip(py.iter_mut()).zip(py2.iter_mut()) {
                    let v = v as f64;
                    row_y += v;
                    row_y2 += v * v;
                    *dy = row_y;
                    *dy2 = row_y2;
                }
            }
            // Pass 2: vertical block carry — add the stored f32 row
            // above, block by block, lane-chunked inside each block.
            let (above_y, cur_y) = out_y[..(r + 1) * TILE].split_at_mut(r * TILE);
            let (above_y2, cur_y2) = out_y2[..(r + 1) * TILE].split_at_mut(r * TILE);
            let (up_y, up_y2): (&[f32], &[f32]) = if r > 0 {
                (&above_y[(r - 1) * TILE..], &above_y2[(r - 1) * TILE..])
            } else {
                (&ZEROS, &ZEROS)
            };
            let ups = up_y.chunks(block).zip(pref_y.chunks(block));
            for ((dst, up), pref) in cur_y.chunks_mut(block).zip(ups) {
                vadd_cast(dst, up, pref);
            }
            let ups2 = up_y2.chunks(block).zip(pref_y2.chunks(block));
            for ((dst, up), pref) in cur_y2.chunks_mut(block).zip(ups2) {
                vadd_cast(dst, up, pref);
            }
        }
        Ok(())
    }

    /// Same per-rect arithmetic as the native backend (shared
    /// [`rect_opt1`]), in block-sized batches.
    fn block_sse(
        &self,
        padded_ii_y: &[f32],
        padded_ii_y2: &[f32],
        rects: &[[i32; 4]],
    ) -> Result<Vec<f32>> {
        let side = TILE + 1;
        ensure!(padded_ii_y.len() == side * side, "padded ii shape");
        ensure!(padded_ii_y2.len() == side * side, "padded ii shape");
        ensure!(rects.len() <= RECT_BATCH, "≤ {RECT_BATCH} rects per call");
        let mut out = Vec::with_capacity(rects.len());
        for batch in rects.chunks(self.block) {
            for rect in batch {
                out.push(rect_opt1(padded_ii_y, padded_ii_y2, rect)?);
            }
        }
        Ok(out)
    }

    /// Blocked cascaded SSE: one serial f64 partial per block-wide
    /// chunk, pairwise (tree) reduction over the partials.
    fn seg_loss(&self, signal: &[f32], rendered: &[f32]) -> Result<f32> {
        ensure!(
            signal.len() == TILE * TILE && rendered.len() == TILE * TILE,
            "seg_loss tiles must be {TILE}x{TILE}"
        );
        let n_parts = (TILE * TILE).div_ceil(self.block);
        let mut partials = Vec::with_capacity(n_parts);
        for (sig, ren) in signal.chunks(self.block).zip(rendered.chunks(self.block)) {
            let mut acc = 0.0f64;
            for (a, b) in sig.iter().zip(ren.iter()) {
                let d = (*a - *b) as f64;
                acc += d * d;
            }
            partials.push(acc);
        }
        Ok(pairwise_sum(&partials) as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::runtime::NativeBackend;

    fn random_tile(seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..TILE * TILE).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn prefix2d_is_bit_identical_to_native_for_every_block_size() {
        let tile = random_tile(70);
        let native = NativeBackend::new();
        let (ny, ny2) = native.prefix2d(&tile).unwrap();
        for block in [1, 8, 32, 37, 64, TILE, TILE * TILE] {
            let b = BlockedBackend::with_block(block);
            let (by, by2) = b.prefix2d(&tile).unwrap();
            assert_eq!(ny, by, "ii_y, block={block}");
            assert_eq!(ny2, by2, "ii_y2, block={block}");
        }
    }

    #[test]
    fn prefix2d_into_reuses_buffers_and_matches() {
        let tile = random_tile(71);
        let b = BlockedBackend::new();
        let (y, y2) = b.prefix2d(&tile).unwrap();
        let mut by = vec![7.0f32; 5];
        let mut by2 = vec![7.0f32; TILE * TILE + 3];
        b.prefix2d_into(&tile, &mut by, &mut by2).unwrap();
        assert_eq!(y, by);
        assert_eq!(y2, by2);
    }

    #[test]
    fn block_sse_is_bit_identical_to_native() {
        let tile = random_tile(72);
        let native = NativeBackend::new();
        let (ii_y, ii_y2) = native.prefix2d(&tile).unwrap();
        let p_y = crate::runtime::pad_integral(&ii_y);
        let p_y2 = crate::runtime::pad_integral(&ii_y2);
        let mut rng = Rng::new(73);
        let mut rects = Vec::new();
        for _ in 0..257 {
            let r0 = rng.usize(TILE);
            let r1 = rng.range(r0, TILE);
            let c0 = rng.usize(TILE);
            let c1 = rng.range(c0, TILE);
            rects.push([r0 as i32, r1 as i32, c0 as i32, c1 as i32]);
        }
        let want = native.block_sse(&p_y, &p_y2, &rects).unwrap();
        for block in [1, 37, 64] {
            let got = BlockedBackend::with_block(block).block_sse(&p_y, &p_y2, &rects).unwrap();
            assert_eq!(want, got, "block={block}");
        }
    }

    #[test]
    fn seg_loss_tracks_native_within_f32_quantization() {
        let a = random_tile(74);
        let b = random_tile(75);
        let native = NativeBackend::new().seg_loss(&a, &b).unwrap() as f64;
        for block in [8, 37, 64] {
            let got = BlockedBackend::with_block(block).seg_loss(&a, &b).unwrap() as f64;
            // Both accumulate in f64; only the partial layout differs, so
            // the results agree to the final f32 cast (~6e-8 rel).
            assert!((got - native).abs() <= 1e-6 * (1.0 + native.abs()), "block={block}");
        }
        // With block == TILE the partial layout matches native's per-row
        // cascade exactly: bit-equal.
        let same = BlockedBackend::with_block(TILE).seg_loss(&a, &b).unwrap();
        assert_eq!(same.to_bits(), (native as f32).to_bits());
    }

    #[test]
    fn shape_violations_are_errors() {
        let b = BlockedBackend::new();
        assert!(b.prefix2d(&[0.0; 4]).is_err());
        assert!(b.seg_loss(&[0.0; 4], &[0.0; 4]).is_err());
        let side = TILE + 1;
        let padded = vec![0.0f32; side * side];
        assert!(b.block_sse(&padded, &padded, &[[0, TILE as i32, 0, 0]]).is_err());
        let too_many = vec![[0i32, 0, 0, 0]; RECT_BATCH + 1];
        assert!(b.block_sse(&padded, &padded, &too_many).is_err());
    }
}
