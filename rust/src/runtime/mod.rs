//! PJRT runtime — loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and executes
//! them from the Rust hot path. Python never runs at request time.
//!
//! Artifact contract (shapes fixed at AOT time, see
//! `python/compile/aot.py`):
//!
//! | artifact            | signature |
//! |---------------------|-----------|
//! | `prefix2d.hlo.txt`  | `f32[T,T] → (f32[T,T], f32[T,T])` — inclusive 2D prefix sums of y and y² (Pallas two-pass scan) |
//! | `block_sse.hlo.txt` | `(f32[T+1,T+1], f32[T+1,T+1], i32[B,4]) → f32[B]` — batched opt₁ over rectangles via padded integral images |
//! | `seg_loss.hlo.txt`  | `(f32[T,T], f32[T,T]) → f32[1]` — SSE between a signal tile and a rendered segmentation tile |
//!
//! with `T = 256`, `B = 1024`. Larger inputs are tiled / batched by the
//! wrappers below; smaller ones are zero-padded (zero cells contribute
//! zero to every statistic, so padding is harmless by construction).

pub mod tiled;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// Fixed tile edge compiled into the artifacts.
pub const TILE: usize = 256;
/// Fixed rectangle batch size compiled into `block_sse`.
pub const RECT_BATCH: usize = 1024;

/// Default artifacts directory (relative to the crate root / CWD).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("SIGTREE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Are the artifacts present? (Lets tests skip gracefully before
/// `make artifacts`.)
pub fn artifacts_available() -> bool {
    let dir = default_artifacts_dir();
    ["prefix2d.hlo.txt", "block_sse.hlo.txt", "seg_loss.hlo.txt"]
        .iter()
        .all(|f| dir.join(f).exists())
}

/// The PJRT runtime: CPU client + compiled executables keyed by artifact
/// name. Compilation happens once at load; execution is pure compute.
pub struct Runtime {
    client: xla::PjRtClient,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Load every `*.hlo.txt` in `dir` and compile it on the CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt client: {e:?}"))?;
        let mut execs = HashMap::new();
        for entry in std::fs::read_dir(dir)
            .with_context(|| format!("artifacts dir {dir:?} (run `make artifacts`)"))?
        {
            let path = entry?.path();
            let Some(name) = path.file_name().and_then(|s| s.to_str()) else { continue };
            let Some(stem) = name.strip_suffix(".hlo.txt") else { continue };
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {name}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            execs.insert(stem.to_string(), exe);
        }
        Ok(Self { client, execs })
    }

    /// Load from the default directory.
    pub fn load_default() -> Result<Self> {
        Self::load(&default_artifacts_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn has(&self, name: &str) -> bool {
        self.execs.contains_key(name)
    }

    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.execs.keys().cloned().collect();
        v.sort();
        v
    }

    fn exec(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.execs
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded"))
    }

    /// `prefix2d`: inclusive 2D prefix sums of a TILE×TILE tile.
    /// Returns (Σy, Σy²) integral images (inclusive, unpadded).
    pub fn prefix2d(&self, tile: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(tile.len() == TILE * TILE, "tile must be {TILE}x{TILE}");
        let exe = self.exec("prefix2d")?;
        let x = xla::Literal::vec1(tile)
            .reshape(&[TILE as i64, TILE as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))?;
        let result = exe
            .execute::<xla::Literal>(&[x])
            .map_err(|e| anyhow!("execute prefix2d: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let (a, b) = result.to_tuple2().map_err(|e| anyhow!("tuple2: {e:?}"))?;
        Ok((
            a.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            b.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
        ))
    }

    /// `block_sse`: batched opt₁ over rectangles, given *padded*
    /// (TILE+1)² integral images. Rects are (r0, r1, c0, c1) inclusive;
    /// entries beyond the real batch should be (0,0,0,0) (their output is
    /// ignored by the caller).
    pub fn block_sse(
        &self,
        padded_ii_y: &[f32],
        padded_ii_y2: &[f32],
        rects: &[[i32; 4]],
    ) -> Result<Vec<f32>> {
        let side = TILE + 1;
        anyhow::ensure!(padded_ii_y.len() == side * side, "padded ii shape");
        anyhow::ensure!(padded_ii_y2.len() == side * side, "padded ii shape");
        anyhow::ensure!(rects.len() <= RECT_BATCH, "≤ {RECT_BATCH} rects per call");
        let exe = self.exec("block_sse")?;
        let mut flat: Vec<i32> = Vec::with_capacity(RECT_BATCH * 4);
        for r in rects {
            flat.extend_from_slice(r);
        }
        flat.resize(RECT_BATCH * 4, 0);
        let ii_y = xla::Literal::vec1(padded_ii_y)
            .reshape(&[side as i64, side as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let ii_y2 = xla::Literal::vec1(padded_ii_y2)
            .reshape(&[side as i64, side as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let r = xla::Literal::vec1(&flat)
            .reshape(&[RECT_BATCH as i64, 4])
            .map_err(|e| anyhow!("{e:?}"))?;
        let result = exe
            .execute::<xla::Literal>(&[ii_y, ii_y2, r])
            .map_err(|e| anyhow!("execute block_sse: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("{e:?}"))?;
        let mut v = out.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        v.truncate(rects.len());
        Ok(v)
    }

    /// `seg_loss`: SSE between a signal tile and a rendered segmentation
    /// tile (both TILE×TILE).
    pub fn seg_loss(&self, signal: &[f32], rendered: &[f32]) -> Result<f32> {
        anyhow::ensure!(signal.len() == TILE * TILE && rendered.len() == TILE * TILE);
        let exe = self.exec("seg_loss")?;
        let a = xla::Literal::vec1(signal)
            .reshape(&[TILE as i64, TILE as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let b = xla::Literal::vec1(rendered)
            .reshape(&[TILE as i64, TILE as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let result = exe
            .execute::<xla::Literal>(&[a, b])
            .map_err(|e| anyhow!("execute seg_loss: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("{e:?}"))?;
        let v = out.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok(v[0])
    }
}

/// Pad an inclusive TILE² integral image to (TILE+1)² with a zero row and
/// column in front (the layout `block_sse` consumes).
pub fn pad_integral(ii: &[f32]) -> Vec<f32> {
    let side = TILE + 1;
    let mut out = vec![0.0f32; side * side];
    for r in 0..TILE {
        let src = r * TILE;
        let dst = (r + 1) * side + 1;
        out[dst..dst + TILE].copy_from_slice(&ii[src..src + TILE]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn runtime_or_skip() -> Option<Runtime> {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(Runtime::load_default().expect("runtime load"))
    }

    /// Reference prefix sums in f64.
    fn ref_prefix(tile: &[f32]) -> (Vec<f64>, Vec<f64>) {
        let mut py = vec![0.0f64; TILE * TILE];
        let mut py2 = vec![0.0f64; TILE * TILE];
        for r in 0..TILE {
            let mut row_y = 0.0;
            let mut row_y2 = 0.0;
            for c in 0..TILE {
                let v = tile[r * TILE + c] as f64;
                row_y += v;
                row_y2 += v * v;
                let up_y = if r > 0 { py[(r - 1) * TILE + c] } else { 0.0 };
                let up_y2 = if r > 0 { py2[(r - 1) * TILE + c] } else { 0.0 };
                py[r * TILE + c] = up_y + row_y;
                py2[r * TILE + c] = up_y2 + row_y2;
            }
        }
        (py, py2)
    }

    #[test]
    fn prefix2d_matches_reference() {
        let Some(rt) = runtime_or_skip() else { return };
        let mut rng = Rng::new(60);
        let tile: Vec<f32> = (0..TILE * TILE).map(|_| rng.normal() as f32).collect();
        let (got_y, got_y2) = rt.prefix2d(&tile).unwrap();
        let (ref_y, ref_y2) = ref_prefix(&tile);
        for i in (0..TILE * TILE).step_by(997) {
            assert!(
                (got_y[i] as f64 - ref_y[i]).abs() < 1e-2 * (1.0 + ref_y[i].abs()),
                "ii_y[{i}]"
            );
            assert!(
                (got_y2[i] as f64 - ref_y2[i]).abs() < 1e-2 * (1.0 + ref_y2[i].abs()),
                "ii_y2[{i}]"
            );
        }
    }

    #[test]
    fn block_sse_matches_native_opt1() {
        let Some(rt) = runtime_or_skip() else { return };
        let mut rng = Rng::new(61);
        let tile: Vec<f32> = (0..TILE * TILE).map(|_| rng.normal() as f32).collect();
        let (ii_y, ii_y2) = rt.prefix2d(&tile).unwrap();
        let p_y = pad_integral(&ii_y);
        let p_y2 = pad_integral(&ii_y2);
        // Random rects + native check.
        let sig = crate::signal::Signal::from_fn(TILE, TILE, |r, c| tile[r * TILE + c] as f64);
        let stats = crate::signal::PrefixStats::new(&sig);
        let mut rects = Vec::new();
        let mut expect = Vec::new();
        for _ in 0..64 {
            let r0 = rng.usize(TILE);
            let r1 = rng.range(r0, TILE);
            let c0 = rng.usize(TILE);
            let c1 = rng.range(c0, TILE);
            rects.push([r0 as i32, r1 as i32, c0 as i32, c1 as i32]);
            expect.push(stats.opt1(&crate::signal::Rect::new(r0, r1, c0, c1)));
        }
        let got = rt.block_sse(&p_y, &p_y2, &rects).unwrap();
        for (g, e) in got.iter().zip(expect.iter()) {
            // f32 integral images lose precision on large blocks; relative
            // tolerance scaled by the block magnitude.
            assert!(
                (*g as f64 - e).abs() <= 5e-2 * (1.0 + e.abs()),
                "{g} vs {e}"
            );
        }
    }

    #[test]
    fn seg_loss_matches_native() {
        let Some(rt) = runtime_or_skip() else { return };
        let mut rng = Rng::new(62);
        let a: Vec<f32> = (0..TILE * TILE).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..TILE * TILE).map(|_| rng.normal() as f32).collect();
        let got = rt.seg_loss(&a, &b).unwrap() as f64;
        let expect: f64 = a
            .iter()
            .zip(b.iter())
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum();
        assert!((got - expect).abs() < 1e-3 * (1.0 + expect), "{got} vs {expect}");
    }

    #[test]
    fn pad_integral_layout() {
        let ii: Vec<f32> = (0..TILE * TILE).map(|i| i as f32).collect();
        let p = pad_integral(&ii);
        let side = TILE + 1;
        for c in 0..side {
            assert_eq!(p[c], 0.0);
        }
        for r in 0..side {
            assert_eq!(p[r * side], 0.0);
        }
        assert_eq!(p[side + 1], 0.0f32.max(ii[0]));
        assert_eq!(p[2 * side + 2], ii[TILE + 1]);
    }
}
