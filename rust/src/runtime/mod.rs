//! Kernel runtime — one artifact contract, pluggable execution backends.
//!
//! The three fixed-shape kernels (shapes fixed at AOT time, see
//! `python/compile/aot.py`):
//!
//! | kernel      | signature |
//! |-------------|-----------|
//! | `prefix2d`  | `f32[T,T] → (f32[T,T], f32[T,T])` — inclusive 2D prefix sums of y and y² |
//! | `block_sse` | `(f32[T+1,T+1], f32[T+1,T+1], i32[B,4]) → f32[B]` — batched opt₁ over rectangles via padded integral images |
//! | `seg_loss`  | `(f32[T,T], f32[T,T]) → f32[1]` — SSE between a signal tile and a rendered segmentation tile |
//!
//! with `T = 256` ([`TILE`]), `B = 1024` ([`RECT_BATCH`]). Larger inputs
//! are tiled / batched by [`tiled::TiledPrefix`]; smaller ones are
//! zero-padded (zero cells contribute zero to every statistic, so
//! padding is harmless by construction).
//!
//! Three backends implement the contract ([`KernelBackend`]):
//!
//! * [`native::NativeBackend`] — pure Rust, std-only, always available;
//!   the default. Scalar reference implementation.
//! * [`blocked::BlockedBackend`] — pure Rust, std-only: cache-blocked
//!   tiles and lane-chunked inner loops shaped for LLVM
//!   auto-vectorization (no `unsafe`, no intrinsics). Bit-identical to
//!   the native backend on `prefix2d`/`block_sse` (see the module docs
//!   for the two-pass argument).
//! * [`pjrt::Runtime`] (cargo feature `pjrt`, off by default) — PJRT
//!   execution of the AOT-compiled JAX/Pallas artifacts from
//!   `artifacts/*.hlo.txt` (produced once by `make artifacts`). Python
//!   never runs at request time.

pub mod blocked;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod tiled;

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

pub use blocked::BlockedBackend;
pub use native::NativeBackend;
pub use tiled::TiledPrefix;

/// Fixed tile edge compiled into the artifacts.
pub const TILE: usize = 256;
/// Fixed rectangle batch size compiled into `block_sse`.
pub const RECT_BATCH: usize = 1024;

/// The kernel contract every execution backend implements. Everything
/// downstream — [`tiled::TiledPrefix`], the CLI `runtime` subcommand,
/// `bench_runtime`, the integration tests — runs against this trait, so
/// swapping execution engines never touches the pipeline.
///
/// `Send + Sync` is part of the contract: one `Engine` (and therefore
/// one backend instance) is shared by every connection thread of the
/// serving daemon (`sigtree::serve`), so an implementation holding
/// non-thread-safe device handles must wrap them itself (the bundled
/// PJRT stub's handles are plain data; a real binding would typically
/// hold an `Arc`'d client).
pub trait KernelBackend: Send + Sync {
    /// Human-readable backend identifier (e.g. `"native"`, `"pjrt(cpu)"`).
    fn name(&self) -> String;

    /// Inclusive 2D prefix sums of y and y² over a row-major TILE×TILE
    /// tile. Returns unpadded TILE×TILE integral images (Σy, Σy²).
    fn prefix2d(&self, tile: &[f32]) -> Result<(Vec<f32>, Vec<f32>)>;

    /// Batched opt₁ (1-segmentation SSE) over tile-local rectangles,
    /// given *padded* (TILE+1)² integral images (see [`pad_integral`]).
    /// Rects are (r0, r1, c0, c1) inclusive; at most [`RECT_BATCH`] per
    /// call; returns one f32 per input rect.
    fn block_sse(
        &self,
        padded_ii_y: &[f32],
        padded_ii_y2: &[f32],
        rects: &[[i32; 4]],
    ) -> Result<Vec<f32>>;

    /// SSE between a signal tile and a rendered segmentation tile (both
    /// TILE×TILE).
    fn seg_loss(&self, signal: &[f32], rendered: &[f32]) -> Result<f32>;

    /// [`Self::prefix2d`] into caller-owned buffers, so hot loops
    /// ([`tiled::TiledPrefix`], repeated engine queries) reuse capacity
    /// instead of allocating two TILE² vectors per call. The default
    /// implementation falls back to [`Self::prefix2d`] (one allocation
    /// per call, then moved into the buffers), so remote backends like
    /// PJRT need not implement it; the in-process backends override it
    /// with a true in-place fill.
    fn prefix2d_into(
        &self,
        tile: &[f32],
        out_y: &mut Vec<f32>,
        out_y2: &mut Vec<f32>,
    ) -> Result<()> {
        let (y, y2) = self.prefix2d(tile)?;
        *out_y = y;
        *out_y2 = y2;
        Ok(())
    }
}

/// Pairwise (tree) summation of `terms`: splits recursively and adds the
/// halves, so the rounding error grows O(log n) instead of the serial
/// scan's O(n). Base case small enough to stay cheap, large enough that
/// the recursion never dominates.
pub(crate) fn pairwise_sum(terms: &[f64]) -> f64 {
    if terms.len() <= 32 {
        return terms.iter().sum();
    }
    let (lo, hi) = terms.split_at(terms.len() / 2);
    pairwise_sum(lo) + pairwise_sum(hi)
}

/// One O(1) corner read of a padded integral image, widened to f64. The
/// single place the 4-corner inclusion–exclusion queries index; keeping
/// it here concentrates the bounds-checked read (callers validate rect
/// bounds before querying).
#[inline]
pub(crate) fn corner(arr: &[f32], idx: usize) -> f64 {
    // lint:allow(index-hot) -- the one O(1) corner read behind every
    // 4-corner query; rect bounds are validated by the callers.
    arr[idx] as f64
}

/// opt₁ of one tile-local inclusive rect from *padded* (TILE+1)²
/// integral images. Shared by the in-process backends so their
/// `block_sse` outputs stay bit-identical by construction (same corner
/// reads, same left-associated inclusion–exclusion, same
/// [`crate::signal::stats::Moments::opt1`]).
#[inline]
pub(crate) fn rect_opt1(
    padded_ii_y: &[f32],
    padded_ii_y2: &[f32],
    rect: &[i32; 4],
) -> Result<f32> {
    let side = TILE + 1;
    let [r0, r1, c0, c1] = *rect;
    crate::ensure!(
        0 <= r0 && r0 <= r1 && (r1 as usize) < TILE && 0 <= c0 && c0 <= c1 && (c1 as usize) < TILE,
        "rect {rect:?} out of tile bounds"
    );
    let (r0, r1, c0, c1) = (r0 as usize, r1 as usize, c0 as usize, c1 as usize);
    // 4-corner inclusion–exclusion in f64 (the corners are the only
    // reads; no accumulation happens here, so the error is entirely the
    // f32 quantization of the integral images).
    let q = |arr: &[f32]| -> f64 {
        corner(arr, (r1 + 1) * side + (c1 + 1)) - corner(arr, r0 * side + (c1 + 1))
            - corner(arr, (r1 + 1) * side + c0)
            + corner(arr, r0 * side + c0)
    };
    let moments = crate::signal::stats::Moments {
        count: ((r1 - r0 + 1) * (c1 - c0 + 1)) as f64,
        sum: q(padded_ii_y),
        sum_sq: q(padded_ii_y2),
    };
    Ok(moments.opt1() as f32)
}

/// Default artifacts directory (relative to the crate root / CWD).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("SIGTREE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Are the AOT artifacts present? (Lets the PJRT path and its tests skip
/// gracefully before `make artifacts`.)
pub fn artifacts_available() -> bool {
    let dir = default_artifacts_dir();
    ["prefix2d.hlo.txt", "block_sse.hlo.txt", "seg_loss.hlo.txt"]
        .iter()
        .all(|f| dir.join(f).exists())
}

/// Construct a backend by name — the `--backend native|blocked|pjrt`
/// CLI switch. `artifacts_dir` overrides the artifact location for the
/// PJRT backend (`None` → [`default_artifacts_dir`]); the in-process
/// backends ignore it. The blocked backend is built with its default
/// block size; use [`blocked::BlockedBackend::with_block`] directly (or
/// `EngineConfig::with_block_size` through the engine) to tune it.
pub fn backend_from_name(
    name: &str,
    artifacts_dir: Option<&Path>,
) -> Result<Box<dyn KernelBackend>> {
    match name {
        "native" => Ok(Box::new(NativeBackend::new())),
        "blocked" => Ok(Box::new(BlockedBackend::new())),
        "pjrt" => load_pjrt(artifacts_dir),
        other => Err(Error::msg(format!(
            "unknown backend '{other}' (expected 'native', 'blocked', or 'pjrt')"
        ))),
    }
}

#[cfg(feature = "pjrt")]
fn load_pjrt(artifacts_dir: Option<&Path>) -> Result<Box<dyn KernelBackend>> {
    let rt = match artifacts_dir {
        Some(dir) => pjrt::Runtime::load(dir)?,
        None => pjrt::Runtime::load_default()?,
    };
    Ok(Box::new(rt))
}

#[cfg(not(feature = "pjrt"))]
fn load_pjrt(_artifacts_dir: Option<&Path>) -> Result<Box<dyn KernelBackend>> {
    Err(Error::msg(
        "backend 'pjrt' is not compiled in — rebuild with `--features pjrt` \
         (and produce the AOT artifacts via `make artifacts`)",
    ))
}

/// The best backend this build can offer: PJRT when compiled in and its
/// artifacts load, the native backend otherwise.
pub fn default_backend() -> Box<dyn KernelBackend> {
    if let Some(b) = try_pjrt_default() {
        return b;
    }
    Box::new(NativeBackend::new())
}

#[cfg(feature = "pjrt")]
fn try_pjrt_default() -> Option<Box<dyn KernelBackend>> {
    if !artifacts_available() {
        return None;
    }
    pjrt::Runtime::load_default()
        .ok()
        .map(|rt| Box::new(rt) as Box<dyn KernelBackend>)
}

#[cfg(not(feature = "pjrt"))]
fn try_pjrt_default() -> Option<Box<dyn KernelBackend>> {
    None
}

/// Pad an inclusive TILE² integral image to (TILE+1)² with a zero row and
/// column in front (the layout `block_sse` consumes).
pub fn pad_integral(ii: &[f32]) -> Vec<f32> {
    let side = TILE + 1;
    let mut out = vec![0.0f32; side * side];
    for r in 0..TILE {
        let src = r * TILE;
        let dst = (r + 1) * side + 1;
        out[dst..dst + TILE].copy_from_slice(&ii[src..src + TILE]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_integral_layout() {
        let ii: Vec<f32> = (0..TILE * TILE).map(|i| i as f32).collect();
        let p = pad_integral(&ii);
        let side = TILE + 1;
        for c in 0..side {
            assert_eq!(p[c], 0.0);
        }
        for r in 0..side {
            assert_eq!(p[r * side], 0.0);
        }
        assert_eq!(p[side + 1], ii[0]);
        assert_eq!(p[2 * side + 2], ii[TILE + 1]);
    }

    #[test]
    fn backend_from_name_resolves_native() {
        let b = backend_from_name("native", None).unwrap();
        assert_eq!(b.name(), "native");
    }

    #[test]
    fn backend_from_name_resolves_blocked() {
        let b = backend_from_name("blocked", None).unwrap();
        assert_eq!(b.name(), "blocked");
    }

    #[test]
    fn backend_from_name_rejects_unknown() {
        let err = backend_from_name("tpu9000", None).unwrap_err();
        assert!(err.to_string().contains("tpu9000"));
        assert!(err.to_string().contains("blocked"));
    }

    #[test]
    fn pairwise_sum_matches_serial_on_uniform_terms() {
        // 1.0-terms are exact under both orders; checks the recursion
        // covers every element exactly once (incl. odd splits).
        for n in [0, 1, 31, 32, 33, 100, 1023] {
            let xs = vec![1.0f64; n];
            assert_eq!(pairwise_sum(&xs), n as f64);
        }
    }

    #[test]
    fn default_prefix2d_into_fallback_matches_prefix2d() {
        // A minimal backend that only implements the required methods
        // exercises the trait's default buffer-filling fallback.
        struct Minimal;
        impl KernelBackend for Minimal {
            fn name(&self) -> String {
                "minimal".into()
            }
            fn prefix2d(&self, tile: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
                NativeBackend::new().prefix2d(tile)
            }
            fn block_sse(&self, y: &[f32], y2: &[f32], r: &[[i32; 4]]) -> Result<Vec<f32>> {
                NativeBackend::new().block_sse(y, y2, r)
            }
            fn seg_loss(&self, s: &[f32], r: &[f32]) -> Result<f32> {
                NativeBackend::new().seg_loss(s, r)
            }
        }
        let tile: Vec<f32> = (0..TILE * TILE).map(|i| (i % 97) as f32).collect();
        let (y, y2) = Minimal.prefix2d(&tile).unwrap();
        let (mut by, mut by2) = (Vec::new(), Vec::new());
        Minimal.prefix2d_into(&tile, &mut by, &mut by2).unwrap();
        assert_eq!(y, by);
        assert_eq!(y2, by2);
    }

    #[test]
    fn default_backend_always_exists() {
        // Native fallback guarantees a backend on every build.
        let b = default_backend();
        let tile = vec![1.0f32; TILE * TILE];
        let (ii_y, _) = b.prefix2d(&tile).unwrap();
        // Bottom-right corner of the integral image = sum of all cells.
        assert_eq!(ii_y[TILE * TILE - 1], (TILE * TILE) as f32);
    }
}
