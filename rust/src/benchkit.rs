//! Minimal benchmark harness (criterion is unavailable in the offline
//! registry — DESIGN.md §Substitutions). Provides warmup + repeated
//! timing with median/mean/min/p90 reporting and a tabular printer used
//! by the per-figure experiment benches and the perf regression gate.

use std::time::{Duration, Instant};

/// Timing statistics over repeated runs.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    /// 90th-percentile sample (nearest-rank; equals `max` for < 10 iters'
    /// worth of resolution). Regression gating keys on `median`; `p90`
    /// is reported so tail noise is visible in the committed baseline.
    pub p90: Duration,
    pub max: Duration,
}

impl Timing {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

/// Benchmark a closure: `warmup` untimed runs, then time until either
/// `max_iters` runs or `budget` wall-clock is consumed (at least 3 runs).
pub fn bench<T>(
    warmup: usize,
    max_iters: usize,
    budget: Duration,
    mut f: impl FnMut() -> T,
) -> Timing {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while times.len() < 3 || (times.len() < max_iters && start.elapsed() < budget) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    times.sort();
    let sum: Duration = times.iter().sum();
    Timing {
        iters: times.len(),
        mean: sum / times.len() as u32,
        median: times[times.len() / 2],
        min: times[0],
        p90: times[((times.len() * 9) / 10).min(times.len() - 1)],
        max: times.last().copied().unwrap_or_default(),
    }
}

/// Quick bench with sane defaults (1 warmup, ≤ 15 iters, ≤ 2 s budget).
pub fn quick<T>(f: impl FnMut() -> T) -> Timing {
    bench(1, 15, Duration::from_secs(2), f)
}

/// Pretty-print duration with adaptive units.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// A simple aligned table printer for bench/experiment output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        println!("\n=== {title} ===");
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (w, cell) in widths.iter().zip(cells) {
                s.push_str(&format!("{cell:>w$}  ", w = w));
            }
            println!("{s}");
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Format a float compactly for tables.
pub fn fmt_f(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1e4 || x.abs() < 1e-3 {
        format!("{x:.3e}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_at_least_three_iters() {
        let t = bench(0, 5, Duration::from_millis(10), || 1 + 1);
        assert!(t.iters >= 3);
        assert!(t.min <= t.median && t.median <= t.p90 && t.p90 <= t.max);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(Duration::from_secs(2)).ends_with(" s"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with(" ms"));
        assert!(fmt_duration(Duration::from_micros(7)).ends_with(" µs"));
        assert!(fmt_duration(Duration::from_nanos(9)).ends_with(" ns"));
    }

    #[test]
    fn table_accepts_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print("test"); // visually checked in CI logs; no panic = pass
    }

    #[test]
    fn fmt_f_ranges() {
        assert_eq!(fmt_f(0.0), "0");
        assert!(fmt_f(123456.0).contains('e'));
        assert!(!fmt_f(3.14).contains('e'));
    }
}
