//! The `lightweight` sensitivity algorithm: leverage-style per-row and
//! per-column bounds.
//!
//! When the bicriteria partition is too expensive (streaming shards,
//! serve-time budgets) a cheaper upper bound still concentrates the
//! sample where it matters. Decision-tree queries are unions of
//! axis-parallel rectangles, so a cell that is an outlier within its
//! row *or* its column can dominate some query's loss; the bound charges
//! both margins plus the uniform floor:
//!
//! ```text
//! s_i = (y_i − μ_row)² / (R_row + δ)
//!     + (y_i − ν_col)² / (C_col + δ)
//!     + 1 / N
//! ```
//!
//! where `μ_row`/`R_row` are the mean and 1-mean loss (opt₁) of cell
//! i's row, `ν_col`/`C_col` the same for its column, and N the present
//! count. This is the no-dimensional-sampling shape (Alishahi–Phillips):
//! sensitivities from one-dimensional projections, never from the full
//! partition. Cost: O(n + m) rectangle queries of precompute, O(1) per
//! cell.
//!
//! Determinism: row/column tables are filled sequentially; per-row
//! scoring fans out on the executor in row order.

use crate::par::Exec;
use crate::signal::{PrefixStats, Rect, SignalSource};

use super::{unified::rows_of, Sensitivity, DELTA};

/// Row/column leverage sensitivity. Stateless: everything comes from
/// the shared [`PrefixStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Lightweight;

impl Sensitivity for Lightweight {
    fn name(&self) -> &'static str {
        "lightweight"
    }

    fn scores<S: SignalSource>(
        &self,
        signal: &S,
        cells: &[(usize, usize)],
        stats: &PrefixStats,
        exec: Exec<'_>,
    ) -> Vec<f64> {
        let (n, m) = (signal.rows(), signal.cols());
        // Sequential precompute of the 1-d projections: (mean,
        // regularized opt₁) per row and per column. Rows/columns with no
        // present cell never appear in `cells`, so their entries are
        // inert placeholders.
        let row_stats: Vec<(f64, f64)> = (0..n)
            .map(|r| {
                let rect = Rect::new(r, r, 0, m - 1);
                if stats.count(&rect) > 0.0 {
                    (stats.mean(&rect), stats.opt1(&rect) + DELTA)
                } else {
                    (0.0, DELTA)
                }
            })
            .collect();
        let col_stats: Vec<(f64, f64)> = (0..m)
            .map(|c| {
                let rect = Rect::new(0, n - 1, c, c);
                if stats.count(&rect) > 0.0 {
                    (stats.mean(&rect), stats.opt1(&rect) + DELTA)
                } else {
                    (0.0, DELTA)
                }
            })
            .collect();
        let uniform_floor = 1.0 / cells.len().max(1) as f64;

        let per_row = rows_of(cells);
        let scored = exec.map(&per_row, |_, row_cells: &&[(usize, usize)]| {
            row_cells
                .iter()
                .map(|&(r, c)| {
                    let y = signal.get(r, c);
                    let (mu, rdenom) = row_stats[r];
                    let (nu, cdenom) = col_stats[c];
                    let dr = y - mu;
                    let dc = y - nu;
                    dr * dr / rdenom + dc * dc / cdenom + uniform_floor
                })
                .collect::<Vec<f64>>()
        });
        scored.into_iter().flatten().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::signal::{generate, PrefixStats, Signal};

    #[test]
    fn outliers_score_higher_than_background() {
        let mut sig = Signal::from_fn(12, 20, |_, _| 2.0);
        sig.set(3, 11, -180.0);
        let stats = PrefixStats::new(&sig);
        let cells = crate::sample::present_cells(&sig);
        let scores = Lightweight.scores(&sig, &cells, &stats, Exec::Spawn(1));
        let spike = cells.iter().position(|&(r, c)| (r, c) == (3, 11)).unwrap();
        let spike_score = scores[spike];
        let mean_rest: f64 = scores
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != spike)
            .map(|(_, &s)| s)
            .sum::<f64>()
            / (scores.len() - 1) as f64;
        assert!(spike_score > 10.0 * mean_rest, "{spike_score} vs {mean_rest}");
    }

    #[test]
    fn scores_are_executor_invariant() {
        let mut rng = Rng::new(10);
        let sig = generate::smooth(36, 28, 5, &mut rng);
        let stats = PrefixStats::new(&sig);
        let cells = crate::sample::present_cells(&sig);
        let reference = Lightweight.scores(&sig, &cells, &stats, Exec::Spawn(1));
        for threads in [2, 4, 8] {
            let other = Lightweight.scores(&sig, &cells, &stats, Exec::Spawn(threads));
            assert_eq!(reference, other, "{threads} threads");
        }
    }

    #[test]
    fn masked_rows_and_cols_stay_inert() {
        let mut sig = Signal::from_fn(10, 10, |r, c| (r * c) as f64);
        sig.mask_rect(crate::signal::Rect::new(4, 4, 0, 9));
        sig.mask_rect(crate::signal::Rect::new(0, 9, 7, 7));
        let stats = PrefixStats::new(&sig);
        let cells = crate::sample::present_cells(&sig);
        let scores = Lightweight.scores(&sig, &cells, &stats, Exec::Spawn(2));
        assert_eq!(scores.len(), cells.len());
        assert!(scores.iter().all(|s| s.is_finite() && *s > 0.0));
    }
}
