//! `sigtree::sample` — the sensitivity-sampling coreset family.
//!
//! The deterministic Caratheodory construction ([`crate::coreset`]) is
//! the paper's headline object, but the sensitivity/importance-sampling
//! framework (Bachem–Lucic–Krause, *Practical Coreset Constructions for
//! Machine Learning*; Alishahi–Phillips, *No-Dimensional Sampling
//! Coresets*) covers regimes the deterministic path cannot: fixed
//! sample budgets τ chosen independently of (k, ε), and classification
//! losses ([`classify`]) with no closed-form block compression.
//!
//! The family is one sampler ([`SensitivityCoreset`]) behind one trait
//! ([`Sensitivity`]): an algorithm scores every **present** cell with a
//! positive sensitivity `s_i` (an upper bound on the cell's worst-case
//! share of any query's loss), and the sampler splits the budget in
//! two. Cells whose ideal inclusion count `τ · s_i / Σs` reaches 1 are
//! **kept deterministically** with unit weight (iterated to a fixed
//! point, since removing a heavy cell raises the remaining inclusion
//! counts) — the standard variance-reduction step that makes isolated
//! high-sensitivity spikes certain picks instead of coin flips. The
//! remaining budget τ′ draws i.i.d. from the tail with probability
//! `p_i = s_i / Σs′`, merges duplicates, and weights each distinct cell
//! `w_i = mult_i · Σs′ / (τ′ · s_i)`; all weights are finally rescaled
//! so they sum **exactly** to the present-cell count — the same
//! total-weight invariant every [`crate::coreset::Coreset`] in the repo
//! carries, which is what keeps merge/reduce accounting and
//! [`crate::coreset::merge_tree::MergeTree`]-style composition working
//! (merging two sensitivity samples is plain concatenation, and the
//! merged weight is the merged present mass).
//!
//! Algorithms (see DESIGN.md §Sampling coresets for the formulas and
//! the determinism argument):
//!
//! * [`unified`] — per-cell sensitivity from the bicriteria partition's
//!   block residuals via the shared [`PrefixStats`]:
//!   `s_i = (y_i − μ_B)² / (opt₁(B) + δ) + 1/|B|` for the partition
//!   block B containing cell i.
//! * [`lightweight`] — leverage-style per-row/column bounds needing
//!   only O(n + m) statistics queries:
//!   `s_i = (y_i − μ_row)² / (R_row + δ) + (y_i − ν_col)² / (C_col + δ)
//!   + 1/N`.
//! * [`SampleAlgorithm::Uniform`] — `s_i = 1`, the
//!   [`crate::coreset::uniform`] baseline expressed in this framework
//!   (same `N/τ`-style weights, same total-weight normalization).
//!
//! **Determinism.** Scoring fans out per row on a [`crate::par::Exec`]
//! and is concatenated in row order (the executor returns results in
//! input order), and the τ draws consume one seeded [`Rng`]
//! sequentially — so the sampled coreset is bit-identical for every
//! thread count and executor, the repo's standing constraint. The
//! linter's det-* rules gate this module like the deterministic core.

pub mod classify;
pub mod lightweight;
pub mod unified;

use std::collections::BTreeMap;

use crate::coreset::{Coreset, WeightedPoint};
use crate::error::{Error, Result};
use crate::par::Exec;
use crate::rng::Rng;
use crate::segmentation::KSegmentation;
use crate::signal::{PrefixStats, SignalSource};

/// Additive regularizer in the residual denominators: keeps scores
/// finite on exactly-constant blocks/rows and bounds any single `p_i`
/// away from pathological concentration.
pub const DELTA: f64 = 1e-12;

/// A sensitivity algorithm: scores every present cell of a signal.
///
/// The contract (what the sampler and the tests rely on):
/// * `scores` returns one strictly positive, finite score per entry of
///   `cells`, in the same order;
/// * the result depends only on `(signal, cells, stats)` — never on the
///   executor's thread count (per-row fan-out concatenated in row order
///   satisfies this by construction).
pub trait Sensitivity {
    /// The CLI / JSON spelling of the algorithm.
    fn name(&self) -> &'static str;

    /// Sensitivity scores for `cells` (row-major present cells of
    /// `signal`), using the shared statistics `stats`.
    fn scores<S: SignalSource>(
        &self,
        signal: &S,
        cells: &[(usize, usize)],
        stats: &PrefixStats,
        exec: Exec<'_>,
    ) -> Vec<f64>;
}

/// The pluggable algorithms, as one enum so configs stay `Copy`,
/// serializable, and exhaustively validated. Each variant delegates to
/// its [`Sensitivity`] implementation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SampleAlgorithm {
    /// [`unified::Unified`] — partition-block residuals.
    Unified,
    /// [`lightweight::Lightweight`] — per-row/column leverage bounds.
    Lightweight,
    /// `s_i = 1`: the uniform baseline inside this framework.
    Uniform,
}

impl SampleAlgorithm {
    pub const ALL: [SampleAlgorithm; 3] =
        [SampleAlgorithm::Unified, SampleAlgorithm::Lightweight, SampleAlgorithm::Uniform];

    /// The CLI / JSON spelling.
    pub fn name(self) -> &'static str {
        match self {
            SampleAlgorithm::Unified => "unified",
            SampleAlgorithm::Lightweight => "lightweight",
            SampleAlgorithm::Uniform => "uniform",
        }
    }

    /// Parse the CLI / JSON spelling.
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "unified" => Ok(SampleAlgorithm::Unified),
            "lightweight" => Ok(SampleAlgorithm::Lightweight),
            "uniform" => Ok(SampleAlgorithm::Uniform),
            other => Err(Error::msg(format!(
                "unknown sensitivity algorithm '{other}' (expected 'unified', 'lightweight', or 'uniform')"
            ))),
        }
    }
}

impl Sensitivity for SampleAlgorithm {
    fn name(&self) -> &'static str {
        SampleAlgorithm::name(*self)
    }

    fn scores<S: SignalSource>(
        &self,
        signal: &S,
        cells: &[(usize, usize)],
        stats: &PrefixStats,
        exec: Exec<'_>,
    ) -> Vec<f64> {
        match self {
            SampleAlgorithm::Unified => {
                unified::Unified::default().scores(signal, cells, stats, exec)
            }
            SampleAlgorithm::Lightweight => {
                lightweight::Lightweight.scores(signal, cells, stats, exec)
            }
            SampleAlgorithm::Uniform => vec![1.0; cells.len()],
        }
    }
}

/// Construction parameters of a sensitivity sample. `k`/`eps` feed the
/// unified algorithm's bicriteria partition (the other algorithms
/// ignore them); `tau` is the i.i.d. draw budget; `seed` makes the
/// sample reproducible.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SampleParams {
    pub k: usize,
    pub eps: f64,
    pub tau: usize,
    pub seed: u64,
}

impl SampleParams {
    pub fn new(k: usize, eps: f64, tau: usize, seed: u64) -> Self {
        assert!(k >= 1, "k must be >= 1");
        assert!(eps > 0.0 && eps < 1.0, "eps must be in (0,1)");
        assert!(tau >= 1, "tau must be >= 1");
        Self { k, eps, tau, seed }
    }
}

/// A weighted importance sample of a signal: the sensitivity-sampling
/// counterpart of [`crate::coreset::SignalCoreset`], usable anywhere a
/// [`Coreset`] is (forest training, FITTING-LOSS-style estimation,
/// weighted union).
#[derive(Clone, Debug, PartialEq)]
pub struct SensitivityCoreset {
    /// Distinct sampled cells (row-major order), duplicates merged into
    /// the weight.
    pub points: Vec<WeightedPoint>,
    /// Full signal dimensions (the sample lives in the signal's frame).
    pub n: usize,
    pub m: usize,
    /// The algorithm that scored the cells.
    pub algorithm: SampleAlgorithm,
    /// The requested draw budget (`points.len() <= tau` after merging).
    pub tau: usize,
    /// The seed the draws consumed.
    pub seed: u64,
}

impl SensitivityCoreset {
    /// Build sequentially; see [`Self::build_exec`].
    pub fn build<S: SignalSource>(
        signal: &S,
        algorithm: SampleAlgorithm,
        params: &SampleParams,
    ) -> SensitivityCoreset {
        Self::build_exec(signal, algorithm, params, Exec::Spawn(1))
    }

    /// Build the sensitivity sample of `signal`: enumerate present
    /// cells (row-major), score them with `algorithm` (per-row fan-out
    /// on `exec`, order-preserving), spend the `params.tau` budget via
    /// [`sample_weighted`] (deterministic heavy hitters + i.i.d. tail
    /// draws from one seeded [`Rng`], duplicates merged), and normalize
    /// the weights to the exact present-cell count. Bit-identical for
    /// every executor and thread count. A fully-masked signal yields an
    /// empty sample (zero points, zero weight) instead of panicking.
    pub fn build_exec<S: SignalSource>(
        signal: &S,
        algorithm: SampleAlgorithm,
        params: &SampleParams,
        exec: Exec<'_>,
    ) -> SensitivityCoreset {
        let (n, m) = (signal.rows(), signal.cols());
        let cells = present_cells(signal);
        let empty = SensitivityCoreset {
            points: Vec::new(),
            n,
            m,
            algorithm,
            tau: params.tau,
            seed: params.seed,
        };
        if cells.is_empty() {
            return empty;
        }
        let stats = PrefixStats::new_par_exec(signal, exec);
        let scores = score_cells(signal, algorithm, &cells, &stats, params, exec);
        let points = sample_weighted(signal, &cells, &scores, params.tau, params.seed);
        SensitivityCoreset { points, ..empty }
    }

    /// Merge two samples of **disjoint** signal regions (weighted
    /// union): plain concatenation — the merged weight is the sum of
    /// the parts, so the total-weight invariant composes exactly like
    /// the deterministic family's merge step.
    pub fn merge(mut self, other: SensitivityCoreset) -> SensitivityCoreset {
        self.points.extend(other.points);
        self.n = self.n.max(other.n);
        self.m = self.m.max(other.m);
        self.tau += other.tau;
        self
    }

    pub fn rows(&self) -> usize {
        self.n
    }

    pub fn cols(&self) -> usize {
        self.m
    }

    /// Σ wᵢ — equals the present-cell count of the sampled signal
    /// exactly (the normalization contract).
    pub fn total_weight(&self) -> f64 {
        self.points.iter().map(|p| p.w).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

impl Coreset for SensitivityCoreset {
    /// The importance-sampling estimator of ℓ(D, s):
    /// Σᵢ wᵢ · (yᵢ − s(rᵢ, cᵢ))², cells outside the query contributing
    /// zero — unbiased before normalization, consistent after.
    fn fitting_loss(&self, s: &KSegmentation) -> f64 {
        self.points
            .iter()
            .filter_map(|p| s.value_at(p.row, p.col).map(|v| p.w * (p.y - v) * (p.y - v)))
            .sum()
    }

    fn weighted_points(&self) -> Vec<WeightedPoint> {
        self.points.clone()
    }

    fn size(&self) -> usize {
        self.points.len()
    }
}

/// Row-major present cells of `signal` — the sampling universe, and the
/// index space every [`Sensitivity::scores`] result aligns with.
pub fn present_cells<S: SignalSource>(signal: &S) -> Vec<(usize, usize)> {
    let mut cells = Vec::new();
    for r in 0..signal.rows() {
        match signal.row_mask(r) {
            None => cells.extend((0..signal.cols()).map(|c| (r, c))),
            Some(mask) => {
                cells.extend(mask.iter().enumerate().filter(|(_, &p)| p).map(|(c, _)| (r, c)));
            }
        }
    }
    cells
}

/// Score `cells` and sanitize: every score is forced positive and
/// finite (`max(DELTA)`), so the draw distribution is well-defined even
/// on degenerate inputs.
fn score_cells<S: SignalSource>(
    signal: &S,
    algorithm: SampleAlgorithm,
    cells: &[(usize, usize)],
    stats: &PrefixStats,
    params: &SampleParams,
    exec: Exec<'_>,
) -> Vec<f64> {
    let mut scores = match algorithm {
        SampleAlgorithm::Unified => {
            unified::Unified::new(params.k, params.eps).scores(signal, cells, stats, exec)
        }
        _ => algorithm.scores(signal, cells, stats, exec),
    };
    for s in &mut scores {
        if !s.is_finite() || *s < DELTA {
            *s = DELTA;
        }
    }
    scores
}

/// Spend a budget of `tau` on the scored cells: heavy hitters (ideal
/// inclusion count ≥ 1) are kept deterministically at unit weight, the
/// remaining budget draws i.i.d. cells from the tail with probability
/// ∝ score, duplicates merge, and each tail cell weighs
/// `w_i = mult_i · Σs′ / (τ′ · s_i)`; all weights are rescaled so Σw
/// equals the present-cell count exactly. Sequential by design: the
/// fixed point scans cells in order and one seeded [`Rng`] drives every
/// draw, so the output can never depend on a thread count.
fn sample_weighted<S: SignalSource>(
    signal: &S,
    cells: &[(usize, usize)],
    scores: &[f64],
    tau: usize,
    seed: u64,
) -> Vec<WeightedPoint> {
    debug_assert_eq!(cells.len(), scores.len());
    let mut total = 0.0f64;
    for &s in scores {
        total += s;
    }
    if !(total > 0.0) {
        return Vec::new();
    }
    // Heavy-hitter pass: a cell whose ideal inclusion count
    // `budget · s_i / Σs` reaches 1 is kept outright with unit weight,
    // and the i.i.d. draws cover only the tail. This is the standard
    // variance-reduction step for importance samplers — without it an
    // isolated spike with the maximal score is still missed with
    // probability (1 − s_i/Σs)^τ, which is what loses to uniform on
    // spike-dominated queries. Removing a heavy cell raises the tail's
    // inclusion counts, so repeat in rounds until a fixed point; each
    // round admits at most `rem_budget` cells (their scores sum to at
    // most the remaining mass), so the certain set never exceeds τ.
    let mut certain = vec![false; cells.len()];
    let mut certain_count = 0usize;
    let mut rem_total = total;
    loop {
        let rem_budget = tau - certain_count;
        if rem_budget == 0 {
            break;
        }
        let round_total = rem_total;
        let round_budget = rem_budget as f64;
        let mut changed = false;
        for i in 0..cells.len() {
            if certain_count == tau {
                break;
            }
            if !certain[i] && round_budget * scores[i] >= round_total {
                certain[i] = true;
                certain_count += 1;
                rem_total -= scores[i];
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    let mut weights: BTreeMap<usize, f64> = BTreeMap::new();
    for i in 0..cells.len() {
        if certain[i] {
            weights.insert(i, 1.0);
        }
    }
    // Prefix sums of the tail scores: draw by binary search
    // (partition_point is the first index whose cumulative mass exceeds
    // the draw), duplicates folded into multiplicities.
    let rem_budget = tau - certain_count;
    if rem_budget > 0 {
        let rest: Vec<usize> = (0..cells.len()).filter(|&i| !certain[i]).collect();
        let mut cumulative = Vec::with_capacity(rest.len());
        let mut rem_sum = 0.0f64;
        for &i in &rest {
            rem_sum += scores[i];
            cumulative.push(rem_sum);
        }
        if rem_sum > 0.0 {
            let mut rng = Rng::new(seed);
            let mut multiplicity: BTreeMap<usize, usize> = BTreeMap::new();
            for _ in 0..rem_budget {
                let u = rng.f64() * rem_sum;
                let j = cumulative.partition_point(|&c| c <= u).min(rest.len() - 1);
                *multiplicity.entry(j).or_insert(0) += 1;
            }
            for (j, mult) in multiplicity {
                let i = rest[j];
                weights.insert(i, mult as f64 * rem_sum / (rem_budget as f64 * scores[i]));
            }
        }
    }
    let mut points: Vec<WeightedPoint> = weights
        .into_iter()
        .map(|(idx, w)| {
            let (r, c) = cells[idx];
            WeightedPoint { row: r, col: c, y: signal.get(r, c), w }
        })
        .collect();
    // Exact total-weight normalization: Σw must equal the present-cell
    // count so merge/reduce accounting and the weight-parity audits see
    // the same invariant as the deterministic family.
    let raw: f64 = points.iter().map(|p| p.w).sum();
    if raw > 0.0 {
        let scale = cells.len() as f64 / raw;
        for p in &mut points {
            p.w *= scale;
        }
    }
    points
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::signal::{generate, Rect, Signal};

    fn sample_signal() -> Signal {
        let mut rng = Rng::new(21);
        generate::smooth(48, 36, 3, &mut rng)
    }

    #[test]
    fn weights_sum_to_present_count_for_every_algorithm() {
        let sig = sample_signal();
        let params = SampleParams::new(4, 0.3, 200, 9);
        for algorithm in SampleAlgorithm::ALL {
            let cs = SensitivityCoreset::build(&sig, algorithm, &params);
            let total = cs.total_weight();
            let cells = sig.present() as f64;
            assert!(
                (total - cells).abs() <= 1e-9 * cells,
                "{}: {total} vs {cells}",
                algorithm.name()
            );
            assert!(cs.size() <= 200);
            assert!(!cs.is_empty());
        }
    }

    #[test]
    fn build_is_bit_identical_across_thread_counts() {
        let sig = sample_signal();
        let params = SampleParams::new(4, 0.3, 150, 17);
        for algorithm in SampleAlgorithm::ALL {
            let reference = SensitivityCoreset::build_exec(
                &sig,
                algorithm,
                &params,
                Exec::Spawn(1),
            );
            for threads in [2, 4, 8] {
                let other = SensitivityCoreset::build_exec(
                    &sig,
                    algorithm,
                    &params,
                    Exec::Spawn(threads),
                );
                assert_eq!(reference, other, "{} at {threads} threads", algorithm.name());
            }
        }
    }

    #[test]
    fn fully_masked_signal_yields_empty_sample() {
        let mut sig = Signal::from_fn(8, 8, |r, c| (r + c) as f64);
        sig.mask_rect(Rect::new(0, 7, 0, 7));
        let params = SampleParams::new(2, 0.5, 16, 3);
        for algorithm in SampleAlgorithm::ALL {
            let cs = SensitivityCoreset::build(&sig, algorithm, &params);
            assert!(cs.is_empty(), "{}", algorithm.name());
            assert_eq!(cs.total_weight(), 0.0);
        }
    }

    #[test]
    fn masked_cells_are_never_sampled() {
        let mut sig = sample_signal();
        let dead = Rect::new(4, 20, 6, 18);
        sig.mask_rect(dead);
        let params = SampleParams::new(4, 0.3, 400, 5);
        for algorithm in SampleAlgorithm::ALL {
            let cs = SensitivityCoreset::build(&sig, algorithm, &params);
            for p in &cs.points {
                assert!(!dead.contains(p.row, p.col), "{}: {:?}", algorithm.name(), p);
            }
            let cells = sig.present() as f64;
            assert!((cs.total_weight() - cells).abs() <= 1e-9 * cells);
        }
    }

    #[test]
    fn estimator_is_consistent_at_huge_tau() {
        // With τ ≫ N the estimator concentrates: the heavy-hitter pass
        // degenerates to keeping every present cell at unit weight, so
        // the constant-fit loss estimate lands within a few percent of
        // the exact loss (here: at float-rounding distance).
        let mut rng = Rng::new(33);
        let sig = generate::piecewise_constant(24, 18, 3, 0.1, &mut rng).0;
        let stats = PrefixStats::new(&sig);
        let bounds = sig.bounds();
        let exact = KSegmentation::constant(bounds, stats.mean(&bounds)).loss(&stats);
        let params = SampleParams::new(3, 0.3, 200_000, 7);
        for algorithm in SampleAlgorithm::ALL {
            let cs = SensitivityCoreset::build(&sig, algorithm, &params);
            let approx = cs.fitting_loss(&KSegmentation::constant(bounds, stats.mean(&bounds)));
            let rel = (approx - exact).abs() / (1.0 + exact);
            assert!(rel < 0.05, "{}: {approx} vs {exact}", algorithm.name());
        }
    }

    #[test]
    fn merge_concatenates_and_preserves_weight() {
        let sig = sample_signal();
        let top = sig.view(Rect::new(0, 23, 0, 35));
        let bottom = sig.view(Rect::new(24, 47, 0, 35));
        let params = SampleParams::new(4, 0.3, 100, 11);
        let a = SensitivityCoreset::build(&top, SampleAlgorithm::Lightweight, &params);
        let b = SensitivityCoreset::build(&bottom, SampleAlgorithm::Lightweight, &params);
        let (wa, wb) = (a.total_weight(), b.total_weight());
        let merged = a.merge(b);
        assert!((merged.total_weight() - (wa + wb)).abs() <= 1e-9 * (wa + wb));
        assert_eq!(merged.tau, 200);
    }

    #[test]
    fn algorithm_names_round_trip() {
        for algorithm in SampleAlgorithm::ALL {
            assert_eq!(SampleAlgorithm::from_name(algorithm.name()).unwrap(), algorithm);
        }
        let err = SampleAlgorithm::from_name("magic").unwrap_err().to_string();
        assert!(err.contains("lightweight"), "error lists the spellings: {err}");
    }
}
