//! The `unified` sensitivity algorithm: per-cell scores from the
//! bicriteria partition's block residuals.
//!
//! This is the paper-aligned bound. The (k, ε) machinery already proves
//! that inside one balanced-partition block B, every k-segmentation is
//! near-constant, so a cell's worst-case share of any query's loss is
//! governed by its residual against its block:
//!
//! ```text
//! s_i = (y_i − μ_B)² / (opt₁(B) + δ)  +  1 / |B|
//! ```
//!
//! The first term is the classical sensitivity of a point for the 1-mean
//! (constant-fit) problem restricted to B (Bachem–Lucic–Krause §2.2);
//! the second is the uniform floor that caps the variance of the
//! estimator for cells sitting exactly on their block mean. Both terms
//! come from O(1) [`PrefixStats`] rectangle queries, so scoring is
//! O(N + blocks) after the partition.
//!
//! Determinism: the partition is a pure function of `(stats, k, eps)`;
//! the block-index table is filled sequentially; scoring fans out per
//! row on the executor and is concatenated in row order.

use crate::bicriteria::bicriteria_in;
use crate::par::Exec;
use crate::partition::partition_in;
use crate::signal::{PrefixStats, SignalSource};

use super::{Sensitivity, DELTA};

/// Block-residual sensitivity over the bicriteria partition for the
/// given `(k, eps)` target.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Unified {
    pub k: usize,
    pub eps: f64,
}

impl Unified {
    pub fn new(k: usize, eps: f64) -> Self {
        Self { k: k.max(1), eps }
    }
}

impl Default for Unified {
    fn default() -> Self {
        Self::new(8, 0.3)
    }
}

/// Per-block scoring inputs: (mean, regularized opt₁, present count).
type BlockInfo = (f64, f64, f64);

impl Sensitivity for Unified {
    fn name(&self) -> &'static str {
        "unified"
    }

    fn scores<S: SignalSource>(
        &self,
        signal: &S,
        cells: &[(usize, usize)],
        stats: &PrefixStats,
        exec: Exec<'_>,
    ) -> Vec<f64> {
        let bounds = stats.bounds();
        let bic = bicriteria_in(stats, bounds, self.k);
        let gamma = (self.eps / 2.0).clamp(1e-9, 1.0);
        let blocks = partition_in(stats, bounds, gamma, bic.sigma);

        // Sequential fill of the cell → block table plus per-block
        // moments; the partition tiles `bounds`, so every present cell
        // lands in exactly one block.
        let m = signal.cols();
        let mut block_of = vec![u32::MAX; signal.rows() * m];
        let mut info: Vec<BlockInfo> = Vec::with_capacity(blocks.len());
        for (b, rect) in blocks.iter().enumerate() {
            for r in rect.r0..=rect.r1 {
                for c in rect.c0..=rect.c1 {
                    block_of[r * m + c] = b as u32;
                }
            }
            info.push((stats.mean(rect), stats.opt1(rect) + DELTA, stats.count(rect).max(1.0)));
        }

        let per_row = rows_of(cells);
        let scored = exec.map(&per_row, |_, row_cells: &&[(usize, usize)]| {
            row_cells
                .iter()
                .map(|&(r, c)| {
                    let b = block_of[r * m + c];
                    if b == u32::MAX {
                        return DELTA;
                    }
                    let (mu, denom, count) = info[b as usize];
                    let d = signal.get(r, c) - mu;
                    d * d / denom + 1.0 / count
                })
                .collect::<Vec<f64>>()
        });
        scored.into_iter().flatten().collect()
    }
}

/// Split the row-major `cells` into per-row slices — the fan-out unit
/// that keeps executor results order-stable regardless of thread count.
pub(super) fn rows_of(cells: &[(usize, usize)]) -> Vec<&[(usize, usize)]> {
    let mut rows = Vec::new();
    let mut start = 0;
    while start < cells.len() {
        let row = cells[start].0;
        let mut end = start + 1;
        while end < cells.len() && cells[end].0 == row {
            end += 1;
        }
        rows.push(&cells[start..end]);
        start = end;
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::signal::{generate, Signal};

    #[test]
    fn outliers_score_higher_than_background() {
        // A flat signal with one huge spike: the spike's block residual
        // dominates, so its sensitivity must exceed every flat cell's.
        let mut sig = Signal::from_fn(16, 16, |_, _| 1.0);
        sig.set(7, 9, 250.0);
        let stats = crate::signal::PrefixStats::new(&sig);
        let cells = crate::sample::present_cells(&sig);
        let scores = Unified::new(3, 0.4).scores(&sig, &cells, &stats, Exec::Spawn(1));
        let spike = cells.iter().position(|&(r, c)| (r, c) == (7, 9)).unwrap();
        let spike_score = scores[spike];
        let max_flat = scores
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != spike)
            .map(|(_, &s)| s)
            .fold(0.0f64, f64::max);
        assert!(
            spike_score > 10.0 * max_flat,
            "spike {spike_score} vs flat max {max_flat}"
        );
    }

    #[test]
    fn scores_are_executor_invariant() {
        let mut rng = Rng::new(4);
        let sig = generate::smooth(40, 30, 4, &mut rng);
        let stats = crate::signal::PrefixStats::new(&sig);
        let cells = crate::sample::present_cells(&sig);
        let algo = Unified::new(5, 0.25);
        let reference = algo.scores(&sig, &cells, &stats, Exec::Spawn(1));
        for threads in [2, 4, 8] {
            let other = algo.scores(&sig, &cells, &stats, Exec::Spawn(threads));
            assert_eq!(reference, other, "{threads} threads");
        }
    }

    #[test]
    fn rows_of_partitions_in_order() {
        let cells = vec![(0, 1), (0, 3), (2, 0), (5, 2), (5, 3), (5, 4)];
        let rows = rows_of(&cells);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0], &cells[0..2]);
        assert_eq!(rows[1], &cells[2..3]);
        assert_eq!(rows[2], &cells[3..6]);
    }
}
