//! Classification-flavored sampling coresets: 0/1-label signals and
//! weighted misclassification estimation.
//!
//! The deterministic Caratheodory path compresses *squared* loss and
//! has no analogue for the 0/1 loss (no closed-form block moments), so
//! classification is where the sampling family is not just faster but
//! the only option — the `CoresetDTC` half of the dataheroes exemplar.
//!
//! Sensitivity of a labeled cell under 0/1 loss is governed by class
//! balance: any classifier that errs on class κ can be charged
//! `1/n_κ` of that class's loss, so
//!
//! ```text
//! s_i = 1 / (2 · n_{class(i)})
//! ```
//!
//! (the ½ splits the budget evenly between the two classes). Sampling τ
//! cells with these scores spends ≈ τ/2 on each class regardless of
//! imbalance — and when a class has at most τ/2 members, the sampler's
//! heavy-hitter pass keeps every one of them deterministically — so
//! rare-class structure survives compression, exactly what uniform
//! sampling destroys. Weights are normalized so Σw equals
//! the present-cell count, making the estimator
//! `Σ wᵢ · [round(pred(rᵢ,cᵢ)) ≠ yᵢ]` a consistent estimate of the
//! exact misclassification count.

use crate::coreset::WeightedPoint;
use crate::error::{Error, Result};
use crate::signal::SignalSource;

use super::{present_cells, sample_weighted};

/// A weighted importance sample of a 0/1-labeled signal, tuned for
/// misclassification estimation.
#[derive(Clone, Debug, PartialEq)]
pub struct ClassificationCoreset {
    /// Distinct sampled cells; `y` is the 0/1 label.
    pub points: Vec<WeightedPoint>,
    pub n: usize,
    pub m: usize,
    pub tau: usize,
    pub seed: u64,
}

impl ClassificationCoreset {
    /// Build a class-balanced sample of a 0/1-label signal. Errors when
    /// any present label is not exactly 0.0 or 1.0; a fully-masked
    /// signal yields an empty coreset. Scoring is a sequential O(N)
    /// class count and sampling consumes one seeded Rng, so the result
    /// is trivially identical for every thread count.
    pub fn build<S: SignalSource>(signal: &S, tau: usize, seed: u64) -> Result<Self> {
        assert!(tau >= 1, "tau must be >= 1");
        let (n, m) = (signal.rows(), signal.cols());
        let cells = present_cells(signal);
        let mut counts = [0usize; 2];
        for &(r, c) in &cells {
            let y = signal.get(r, c);
            if y == 0.0 {
                counts[0] += 1;
            } else if y == 1.0 {
                counts[1] += 1;
            } else {
                return Err(Error::msg(format!(
                    "classification coreset requires 0/1 labels; cell ({r}, {c}) has {y}"
                )));
            }
        }
        let scores: Vec<f64> = cells
            .iter()
            .map(|&(r, c)| {
                let class = signal.get(r, c) as usize;
                1.0 / (2.0 * counts[class] as f64)
            })
            .collect();
        let points = sample_weighted(signal, &cells, &scores, tau, seed);
        Ok(Self { points, n, m, tau, seed })
    }

    /// Σ wᵢ · [round(pred(rᵢ, cᵢ)) ≠ yᵢ] — the coreset estimate of the
    /// exact misclassification count of `predict` over the full signal
    /// (compare [`exact_misclassification`]).
    pub fn misclassification(&self, predict: impl Fn(usize, usize) -> f64) -> f64 {
        self.points
            .iter()
            .filter(|p| {
                let label = if predict(p.row, p.col) >= 0.5 { 1.0 } else { 0.0 };
                (label - p.y).abs() > 0.5
            })
            .map(|p| p.w)
            .sum()
    }

    /// Σ wᵢ — equals the present-cell count exactly.
    pub fn total_weight(&self) -> f64 {
        self.points.iter().map(|p| p.w).sum()
    }

    pub fn size(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

/// The exact weighted misclassification count of `predict` over every
/// present cell — the ground truth the coreset estimator approximates.
pub fn exact_misclassification<S: SignalSource>(
    signal: &S,
    predict: impl Fn(usize, usize) -> f64,
) -> f64 {
    let mut wrong = 0.0;
    for r in 0..signal.rows() {
        for c in 0..signal.cols() {
            if !signal.is_present(r, c) {
                continue;
            }
            let label = if predict(r, c) >= 0.5 { 1.0 } else { 0.0 };
            if (label - signal.get(r, c)).abs() > 0.5 {
                wrong += 1.0;
            }
        }
    }
    wrong
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::signal::{Rect, Signal};

    /// 0/1 signal with a rare positive blob in the top-left corner.
    fn labeled_signal() -> Signal {
        Signal::from_fn(40, 40, |r, c| if r < 4 && c < 4 { 1.0 } else { 0.0 })
    }

    #[test]
    fn rejects_non_binary_labels() {
        let sig = Signal::from_fn(6, 6, |r, c| (r + c) as f64 * 0.5);
        let err = ClassificationCoreset::build(&sig, 8, 1).unwrap_err().to_string();
        assert!(err.contains("0/1 labels"), "{err}");
    }

    #[test]
    fn weights_sum_to_present_count() {
        let sig = labeled_signal();
        let cs = ClassificationCoreset::build(&sig, 64, 5).unwrap();
        let cells = sig.present() as f64;
        assert!((cs.total_weight() - cells).abs() <= 1e-9 * cells);
        assert!(cs.size() <= 64);
    }

    #[test]
    fn rare_class_is_kept_deterministically() {
        // 16 positives among 1600 cells (1%): each positive's ideal
        // inclusion count is τ/(2·16) ≥ 1 at τ = 100, so the sampler's
        // heavy-hitter pass keeps the entire rare class outright —
        // uniform sampling at the same τ keeps ~1 positive in
        // expectation.
        let sig = labeled_signal();
        let cs = ClassificationCoreset::build(&sig, 100, 9).unwrap();
        let positives = cs.points.iter().filter(|p| p.y == 1.0).count();
        assert_eq!(positives, 16, "of {} points", cs.size());
    }

    #[test]
    fn misclassification_estimate_tracks_exact() {
        let sig = labeled_signal();
        // A predictor wrong on exactly the positive blob.
        let all_zero = |_r: usize, _c: usize| 0.0;
        let exact = exact_misclassification(&sig, all_zero);
        assert_eq!(exact, 16.0);
        let cs = ClassificationCoreset::build(&sig, 5_000, 13).unwrap();
        let approx = cs.misclassification(all_zero);
        let rel = (approx - exact).abs() / exact;
        assert!(rel < 0.25, "approx {approx} vs exact {exact}");
        // A perfect predictor estimates zero exactly.
        let truth = |r: usize, c: usize| if r < 4 && c < 4 { 1.0 } else { 0.0 };
        assert_eq!(cs.misclassification(truth), 0.0);
    }

    #[test]
    fn fully_masked_signal_yields_empty_ok() {
        let mut sig = Signal::from_fn(5, 5, |_, _| 1.0);
        sig.mask_rect(Rect::new(0, 4, 0, 4));
        let cs = ClassificationCoreset::build(&sig, 10, 2).unwrap();
        assert!(cs.is_empty());
        assert_eq!(cs.total_weight(), 0.0);
    }

    #[test]
    fn build_is_deterministic_for_a_seed() {
        let sig = labeled_signal();
        let a = ClassificationCoreset::build(&sig, 80, 21).unwrap();
        let b = ClassificationCoreset::build(&sig, 80, 21).unwrap();
        assert_eq!(a, b);
        let c = ClassificationCoreset::build(&sig, 80, 22).unwrap();
        assert_ne!(a, c);
    }
}
