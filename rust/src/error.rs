//! Crate-wide error type — a tiny `anyhow` substitute (the offline
//! registry has neither `anyhow` nor `thiserror`; DESIGN.md
//! §Substitutions). An [`Error`] carries a message plus a chain of
//! context frames; [`Result`] defaults its error type to it so function
//! signatures stay as terse as with anyhow.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-carrying error with optional context frames (outermost
/// frame printed first, like `anyhow`'s `{:#}` format).
#[derive(Debug)]
pub struct Error {
    msg: String,
    context: Vec<String>,
}

impl Error {
    /// Build from any displayable message.
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self { msg: msg.to_string(), context: Vec::new() }
    }

    /// Wrap with an outer context frame (builder style):
    /// `Error::msg("file not found").context("loading artifacts")`
    /// displays as `loading artifacts: file not found`.
    pub fn context(mut self, ctx: impl fmt::Display) -> Self {
        self.context.push(ctx.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for ctx in self.context.iter().rev() {
            write!(f, "{ctx}: ")?;
        }
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e)
    }
}

impl From<crate::cli::CliError> for Error {
    fn from(e: crate::cli::CliError) -> Self {
        Error::msg(e)
    }
}

/// Extension trait adding anyhow-style `.context(...)` to results.
pub trait Context<T> {
    /// Attach a context frame to the error side.
    fn context(self, ctx: impl fmt::Display) -> Result<T>;
    /// Attach a lazily-built context frame to the error side.
    fn with_context(self, ctx: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(ctx))
    }

    fn with_context(self, ctx: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(ctx()))
    }
}

/// `ensure!(cond, "message {args}")` — early-return an [`Error`] when the
/// condition fails (the `anyhow::ensure!` shape).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::error::Error::msg(format!($($arg)+)));
        }
    };
}

/// `bail!("message {args}")` — early-return an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::error::Error::msg(format!($($arg)+)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_chains_context_outermost_first() {
        let e = Error::msg("root cause").context("inner").context("outer");
        assert_eq!(e.to_string(), "outer: inner: root cause");
    }

    #[test]
    fn result_context_wraps_error_side() {
        let r: std::result::Result<(), String> = Err("boom".to_string());
        let e = r.context("stage").unwrap_err();
        assert_eq!(e.to_string(), "stage: boom");
        let ok: std::result::Result<u8, String> = Ok(7);
        assert_eq!(ok.with_context(|| "unused".into()).unwrap(), 7);
    }

    #[test]
    fn ensure_macro_returns_error() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(-1).unwrap_err().to_string().contains("-1"));
    }

    #[test]
    fn converts_from_io_and_cli_errors() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "missing");
        let e: Error = io.into();
        assert!(e.to_string().contains("missing"));
        let cli = crate::cli::CliError::Missing("k".into());
        let e: Error = cli.into();
        assert!(e.to_string().contains("--k"));
    }
}
