//! Streaming composition of signal coresets — the merge-and-reduce
//! property (§1.1, Challenge (iv)) that lets the coreset support
//! streaming, distributed construction, and dynamic row-appends.
//!
//! **Merge.** A signal streamed as horizontal row-bands admits a trivial
//! composition: build a coreset per band and take the union of the block
//! lists. Every band's balanced partition is a partition of that band, so
//! the union is a partition of the full signal; all per-block guarantees
//! (opt₁ ≤ tolerance, exact moments) are local and survive unioning. The
//! union is what `merge` returns.
//!
//! **Reduce.** Unioning alone grows linearly with the number of bands, so
//! `reduce` re-compacts: vertically adjacent blocks with identical column
//! extents are merged whenever the *union's* opt₁ — computable exactly
//! from the stored moments — stays within the tolerance. The merged
//! block's 4-point support is rebuilt by running Caratheodory over the
//! two supports (8 weighted labels → ≤ 4), so moments stay exact.

// lint:allow(det-order) -- keyed O(1) lookup only; the map is never
// iterated, so hash order cannot affect results.
use std::collections::HashMap;

use crate::signal::{Rect, SignalSource};

use super::caratheodory::CaratheodoryReducer;
use super::{BlockCoreset, CoresetConfig, SignalCoreset};

/// Union of band coresets (bands must tile the signal's rows and share
/// its width). γ of the merged coreset is the most conservative
/// (smallest) of the parts; σ is the **sum** of the parts' σ: the bands
/// are disjoint and tile the signal, so the optimal k-segmentation of
/// the union restricts to a valid ≤k-segmentation of every band and
/// Σᵢ σᵢ ≤ Σᵢ opt_k(Dᵢ) ≤ opt_k(D) — the same calibration the
/// monolithic build uses. (Taking the minimum instead would let one
/// flat or fully-masked band with σᵢ = 0 poison the merged tolerance to
/// zero and permanently disable [`reduce`] compaction.)
pub fn merge(parts: Vec<SignalCoreset>) -> SignalCoreset {
    assert!(!parts.is_empty());
    let m = parts[0].cols();
    assert!(parts.iter().all(|p| p.cols() == m), "bands must share width");
    let n: usize = parts.iter().map(|p| p.rows()).sum();
    let sigma: f64 = parts.iter().map(|p| p.sigma).sum();
    let gamma = parts.iter().map(|p| p.gamma).fold(f64::INFINITY, f64::min);
    let config = parts[0].config;
    let blocks = parts.into_iter().flat_map(|p| p.blocks).collect();
    SignalCoreset::from_blocks(n, m, config, sigma, gamma, blocks)
}

/// Re-compact a merged coreset: repeatedly merge vertically adjacent
/// blocks with matching column extents while the merged opt₁ (from
/// moments) stays ≤ `tol`. Returns the compacted coreset.
pub fn reduce(coreset: SignalCoreset, tol: f64) -> SignalCoreset {
    // Consume by move — this runs on every streaming `push_band`
    // compaction, and the block list is the bulk of the coreset.
    let n = coreset.rows();
    let m = coreset.cols();
    let SignalCoreset { blocks, config, sigma, gamma, .. } = coreset;
    // Index blocks by (c0, c1, r0): a block ending at row r merges with a
    // block starting at row r+1 with the same column span.
    // lint:allow(det-order) -- keyed lookup only, never iterated.
    let mut by_start: HashMap<(usize, usize, usize), usize> = HashMap::new();
    let mut pool: Vec<Option<BlockCoreset>> = blocks.into_iter().map(Some).collect();
    for (i, b) in pool.iter().enumerate() {
        let Some(b) = b else { continue };
        by_start.insert((b.rect.c0, b.rect.c1, b.rect.r0), i);
    }
    // Greedy single pass (repeat until no merges — bounded by pool size).
    let mut changed = true;
    while changed {
        changed = false;
        for i in 0..pool.len() {
            let Some(cur) = pool[i].clone() else { continue };
            let key = (cur.rect.c0, cur.rect.c1, cur.rect.r1 + 1);
            let Some(&j) = by_start.get(&key) else { continue };
            if i == j {
                continue;
            }
            let Some(next) = pool[j].clone() else { continue };
            // Merged opt₁ from exact moments.
            let merged_moments = cur.moments().add(&next.moments());
            if merged_moments.opt1() > tol {
                continue;
            }
            // Merge supports via Caratheodory.
            let mut red = CaratheodoryReducer::new();
            for b in [&cur, &next] {
                for idx in 0..4 {
                    red.push(b.labels[idx], b.weights[idx]);
                }
            }
            let rect = Rect::new(cur.rect.r0, next.rect.r1, cur.rect.c0, cur.rect.c1);
            let merged = BlockCoreset::from_support(rect, red.finish());
            by_start.remove(&(cur.rect.c0, cur.rect.c1, cur.rect.r0));
            by_start.remove(&key);
            pool[j] = None;
            by_start.insert((rect.c0, rect.c1, rect.r0), i);
            pool[i] = Some(merged);
            changed = true;
        }
    }
    let blocks: Vec<BlockCoreset> = pool.into_iter().flatten().collect();
    SignalCoreset::from_blocks(n, m, config, sigma, gamma, blocks)
}

/// Streaming builder: feed row-bands as they arrive; coresets are built
/// per band, merged, and periodically reduced — memory stays proportional
/// to the reduced coreset, not the stream.
///
/// Since the merge-tree refactor this is a thin facade over
/// [`super::merge_tree::MergeTree`] (one structure, not a parallel
/// implementation): the tree maintains the exact historical
/// incremental-compaction schedule for [`Self::finish`], while the
/// pushed bands stay alive as leaves with logarithmic merge height —
/// call [`Self::into_tree`] to keep them for incremental updates or a
/// root re-composition.
///
/// The lifetime parameter only matters for the pool-backed executor
/// ([`Self::with_exec`], the [`crate::engine::Engine::stream`] path);
/// plain `new`/`with_threads` streams leave it unconstrained.
pub struct StreamingCoreset<'p> {
    tree: super::merge_tree::MergeTree<'p>,
}

impl<'p> StreamingCoreset<'p> {
    pub fn new(m: usize, config: CoresetConfig) -> Self {
        Self { tree: super::merge_tree::MergeTree::for_stream(m, config) }
    }

    /// Build every incoming band through the parallel sharded builder
    /// ([`SignalCoreset::construct_sharded`]) with this many workers (`0` = all
    /// available cores), spawned per band. A pure performance knob: the
    /// streamed coreset is bit-identical for every `threads` value,
    /// though it may differ from the default sequential path (sharded
    /// vs monolithic per-band partitions).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.tree = self.tree.with_band_exec(crate::par::Exec::Spawn(threads));
        self
    }

    /// Like [`Self::with_threads`], but on an explicit executor — pass
    /// `Exec::Pool` (as [`crate::engine::Engine::stream`] does) to
    /// reuse long-lived workers across every pushed band instead of
    /// spawning threads per band. Streamed content is identical to any
    /// `with_threads` stream.
    pub fn with_exec(mut self, exec: crate::par::Exec<'p>) -> Self {
        self.tree = self.tree.with_band_exec(exec);
        self
    }

    /// Row-shard geometry for the sharded per-band path (clamped ≥ 1).
    /// Changes the streamed content for bands taller than one shard,
    /// exactly as it does on the batch build path.
    pub fn with_shard_rows(mut self, shard_rows: usize) -> Self {
        self.tree = self.tree.with_shard_rows(shard_rows);
        self
    }

    /// Ingest the next band (must have width m). Generic over
    /// [`SignalSource`]: callers that still hold the full signal can
    /// stream zero-copy [`crate::signal::SignalView`] windows; true
    /// streaming sources keep handing in owned [`crate::signal::Signal`]
    /// bands. Either way the band coreset is identical.
    pub fn push_band<S: SignalSource>(&mut self, band: &S) {
        self.tree.push_band(band);
    }

    pub fn rows_seen(&self) -> usize {
        self.tree.rows_seen()
    }

    /// Final coreset over everything ingested so far. The empty stream
    /// (no bands pushed) is a typed [`crate::error::Error`] — the old
    /// `Option` return leaked the case to every call site as `unwrap()`.
    pub fn finish(self) -> crate::error::Result<SignalCoreset> {
        self.tree.into_streamed()
    }

    /// Surrender the underlying merge tree — the pushed bands stay
    /// alive as leaves, ready for [`super::merge_tree::MergeTree::full`]
    /// / [`super::merge_tree::MergeTree::update`].
    pub fn into_tree(self) -> super::merge_tree::MergeTree<'p> {
        self.tree
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coreset::merge_tree::translate_rows;
    use crate::coreset::Coreset;
    use crate::rng::Rng;
    use crate::segmentation::random_segmentation;
    use crate::signal::{generate, PrefixStats, Signal, SignalView};

    /// Zero-copy row-bands of `sig` (the builders are generic over
    /// [`SignalSource`], so tests stream views instead of crops).
    fn band_split(sig: &Signal, bands: usize) -> Vec<SignalView<'_>> {
        let edges = crate::bicriteria::band_edges(sig.rows(), bands);
        edges
            .windows(2)
            .map(|w| sig.view(Rect::new(w[0], w[1] - 1, 0, sig.cols() - 1)))
            .collect()
    }

    #[test]
    fn merged_weight_equals_full_weight() {
        let mut rng = Rng::new(30);
        let sig = generate::smooth(48, 32, 3, &mut rng);
        let parts: Vec<SignalCoreset> = band_split(&sig, 4)
            .iter()
            .enumerate()
            .map(|(i, band)| {
                translate_rows(SignalCoreset::construct(band, 4, 0.3), i * 12)
            })
            .collect();
        let merged = merge(parts);
        assert!((merged.total_weight() - (48 * 32) as f64).abs() < 1e-6);
        assert_eq!(merged.rows(), 48);
    }

    #[test]
    fn merged_coreset_approximates_like_monolithic() {
        let mut rng = Rng::new(31);
        let sig = generate::smooth(60, 40, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let parts: Vec<SignalCoreset> = band_split(&sig, 3)
            .iter()
            .enumerate()
            .map(|(i, band)| translate_rows(SignalCoreset::construct(band, 5, 0.25), i * 20))
            .collect();
        let merged = merge(parts);
        for _ in 0..20 {
            let mut s = random_segmentation(sig.bounds(), 5, &mut rng);
            s.refit_values(&stats);
            let exact = s.loss(&stats);
            let approx = merged.fitting_loss(&s);
            assert!(
                (approx - exact).abs() <= 0.3 * exact + 1e-6,
                "{approx} vs {exact}"
            );
        }
    }

    #[test]
    fn merge_sums_sigma_and_keeps_min_gamma() {
        // A flat/fully-masked band has σ = 0; summing (not min-ing) keeps
        // the merged reduce tolerance alive (σ stays ≤ opt_k of the
        // union, which is additive over disjoint row-bands).
        let config = CoresetConfig::new(3, 0.3);
        let a = SignalCoreset::from_blocks(4, 8, config, 1.5, 0.2, Vec::new());
        let b = SignalCoreset::from_blocks(4, 8, config, 0.0, 0.1, Vec::new());
        let merged = merge(vec![a, b]);
        assert!((merged.sigma - 1.5).abs() < 1e-15);
        assert!((merged.gamma - 0.1).abs() < 1e-15);
        assert_eq!(merged.rows(), 8);
    }

    #[test]
    fn reduce_shrinks_and_preserves_moments() {
        let mut rng = Rng::new(32);
        let (sig, _) = generate::piecewise_constant(64, 24, 4, 0.01, &mut rng);
        let parts: Vec<SignalCoreset> = band_split(&sig, 8)
            .iter()
            .enumerate()
            .map(|(i, band)| translate_rows(SignalCoreset::construct(band, 4, 0.3), i * 8))
            .collect();
        let merged = merge(parts);
        let before = merged.blocks.len();
        let w_before = merged.total_weight();
        let tol = merged.gamma * merged.gamma * merged.sigma + 1.0;
        let reduced = reduce(merged, tol);
        assert!(reduced.blocks.len() < before, "{} !< {before}", reduced.blocks.len());
        assert!((reduced.total_weight() - w_before).abs() < 1e-6 * w_before);
        // Blocks still tile the signal.
        let rects: Vec<Rect> = reduced.blocks.iter().map(|b| b.rect).collect();
        assert!(crate::partition::is_exact_tiling(&rects, sig.bounds()));
    }

    #[test]
    fn streaming_matches_batch_weight_and_quality() {
        let mut rng = Rng::new(33);
        let sig = generate::smooth(80, 30, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let mut stream = StreamingCoreset::new(30, CoresetConfig::new(4, 0.3));
        for band in band_split(&sig, 10) {
            stream.push_band(&band);
        }
        assert_eq!(stream.rows_seen(), 80);
        let cs = stream.finish().unwrap();
        assert!((cs.total_weight() - 2400.0).abs() < 1e-6 * 2400.0);
        let mut s = random_segmentation(sig.bounds(), 4, &mut rng);
        s.refit_values(&stats);
        let exact = s.loss(&stats);
        let approx = cs.fitting_loss(&s);
        assert!(
            (approx - exact).abs() <= 0.35 * exact + 1e-6,
            "{approx} vs {exact}"
        );
    }
}
