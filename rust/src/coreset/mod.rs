//! The (k, ε)-coreset for decision trees of signals — Algorithm 3
//! (`SIGNAL-CORESET`) and its data structure, plus the baseline and
//! streaming compositions.
//!
//! Construction pipeline (Theorem 8):
//!
//! 1. [`crate::bicriteria::bicriteria`] → σ ≤ opt_k(D) and the nominal
//!    (α, β);
//! 2. [`crate::partition::partition`] with tolerance γ²σ → balanced
//!    partition `B`;
//! 3. [`caratheodory`] per block → 4 weighted labels matching
//!    (Σ1, Σy, Σy²) exactly, pinned to the block's corner coordinates
//!    (Algorithm 3, Line 6);
//! 4. [`fitting_loss`] (Algorithm 5) evaluates any k-segmentation against
//!    the coreset in O(k·|blocks|).
//!
//! ## Theory vs. practice (γ)
//!
//! The worst-case theory sets γ = ε²/(βk), which the paper itself calls
//! "too pessimistic in practice" (§4: a coreset of 1% of the input
//! achieves ε = 0.2 where the theory predicts a coreset *larger than the
//! input*). Like the paper's reference implementation we default to a
//! practical calibration — γ = ε/2, per-block tolerance γ²σ — found by
//! the calibration sweep recorded in EXPERIMENTS.md §Calibration, and
//! expose the theoretical rule behind [`CoresetConfig::theory`].

pub mod caratheodory;
pub mod fitting_loss;
pub mod merge_reduce;
pub mod uniform;

use crate::bicriteria;
use crate::partition;
use crate::segmentation::KSegmentation;
use crate::signal::{PrefixStats, Rect, Signal};

/// One weighted coreset point: grid coordinates, label, weight.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WeightedPoint {
    pub row: usize,
    pub col: usize,
    pub y: f64,
    pub w: f64,
}

/// Per-block compressed representation: exactly 4 (label, weight) slots
/// (zero-weight padding when Caratheodory needs fewer), with coordinates
/// pinned to the block's 4 corners.
#[derive(Clone, Debug)]
pub struct BlockCoreset {
    pub rect: Rect,
    pub labels: [f64; 4],
    pub weights: [f64; 4],
}

impl BlockCoreset {
    /// Build from a signal block via Caratheodory compression.
    /// Row-contiguous iteration over the raw value buffer (perf pass,
    /// EXPERIMENTS.md §Perf): avoids the per-cell (r, c) → index
    /// arithmetic of the generic cell iterator.
    pub fn from_block(signal: &Signal, rect: Rect) -> Self {
        let mut red = caratheodory::CaratheodoryReducer::new();
        let m = signal.cols();
        let values = signal.values();
        match signal.mask() {
            None => {
                for r in rect.r0..=rect.r1 {
                    let row = &values[r * m + rect.c0..=r * m + rect.c1];
                    for &y in row {
                        red.push(y, 1.0);
                    }
                }
            }
            Some(mask) => {
                for r in rect.r0..=rect.r1 {
                    let base = r * m;
                    for c in rect.c0..=rect.c1 {
                        if mask[base + c] {
                            red.push(values[base + c], 1.0);
                        }
                    }
                }
            }
        }
        Self::from_support(rect, red.finish())
    }

    /// Build from an explicit ≤4-point support.
    pub fn from_support(rect: Rect, support: Vec<(f64, f64)>) -> Self {
        assert!(support.len() <= 4, "Caratheodory support must be ≤ 4");
        let mut labels = [0.0f64; 4];
        let mut weights = [0.0f64; 4];
        for (i, (y, w)) in support.into_iter().enumerate() {
            labels[i] = y;
            weights[i] = w;
        }
        Self { rect, labels, weights }
    }

    /// (count, Σy, Σy²) of the represented block — exact by construction.
    pub fn moments(&self) -> crate::signal::stats::Moments {
        let mut m = crate::signal::stats::Moments::ZERO;
        for i in 0..4 {
            let w = self.weights[i];
            m.count += w;
            m.sum += w * self.labels[i];
            m.sum_sq += w * self.labels[i] * self.labels[i];
        }
        m
    }

    /// Total weight (= number of present cells in the block).
    pub fn total_weight(&self) -> f64 {
        self.weights.iter().sum()
    }

    /// The 4 weighted points with corner coordinates (zero-weight entries
    /// skipped).
    pub fn points(&self) -> impl Iterator<Item = WeightedPoint> + '_ {
        let corners = self.rect.corners();
        (0..4).filter_map(move |i| {
            (self.weights[i] > 0.0).then(|| WeightedPoint {
                row: corners[i].0,
                col: corners[i].1,
                y: self.labels[i],
                w: self.weights[i],
            })
        })
    }
}

/// Common interface shared by the paper's coreset and the baselines, so
/// the experiment harnesses treat compressions uniformly.
pub trait Coreset {
    /// Approximate ℓ(D, s) for a k-segmentation `s`.
    fn fitting_loss(&self, s: &KSegmentation) -> f64;
    /// Flatten to weighted points (the representation handed to forest
    /// trainers).
    fn weighted_points(&self) -> Vec<WeightedPoint>;
    /// Number of stored points.
    fn size(&self) -> usize;
}

/// Construction parameters; see module docs for the γ discussion.
#[derive(Clone, Copy, Debug)]
pub struct CoresetConfig {
    pub k: usize,
    pub eps: f64,
    /// Explicit γ override; `None` → practical default γ = ε/2.
    pub gamma: Option<f64>,
    /// Explicit σ override; `None` → bicriteria estimate.
    pub sigma: Option<f64>,
}

impl CoresetConfig {
    pub fn new(k: usize, eps: f64) -> Self {
        assert!(k >= 1);
        assert!(eps > 0.0 && eps < 1.0, "eps must be in (0,1)");
        Self { k, eps, gamma: None, sigma: None }
    }

    /// The worst-case theoretical calibration γ = ε²/(βk) from Theorem 8.
    pub fn theory(mut self, beta: f64) -> Self {
        self.gamma = Some((self.eps * self.eps / (beta * self.k as f64)).min(1.0));
        self
    }
}

/// The (k, ε)-coreset of an n×m signal (Definition 3 / Theorem 8).
#[derive(Clone, Debug)]
pub struct SignalCoreset {
    n: usize,
    m: usize,
    pub config: CoresetConfig,
    /// σ actually used (lower-bound estimate of opt_k).
    pub sigma: f64,
    /// γ actually used.
    pub gamma: f64,
    pub blocks: Vec<BlockCoreset>,
}

impl SignalCoreset {
    /// Algorithm 3 with the practical default calibration.
    pub fn build(signal: &Signal, k: usize, eps: f64) -> Self {
        Self::build_with(signal, CoresetConfig::new(k, eps))
    }

    /// Algorithm 3 with explicit configuration.
    pub fn build_with(signal: &Signal, config: CoresetConfig) -> Self {
        let stats = PrefixStats::new(signal);
        Self::build_with_stats(signal, &stats, config)
    }

    /// Variant reusing precomputed prefix statistics (the pipeline path).
    pub fn build_with_stats(
        signal: &Signal,
        stats: &PrefixStats,
        config: CoresetConfig,
    ) -> Self {
        let sigma = config
            .sigma
            .unwrap_or_else(|| bicriteria::bicriteria(stats, config.k).sigma);
        let gamma = config.gamma.unwrap_or(config.eps / 2.0).clamp(1e-9, 1.0);
        let rects = partition::partition(stats, gamma, sigma);
        let blocks = rects
            .into_iter()
            .map(|rect| BlockCoreset::from_block(signal, rect))
            .collect();
        Self {
            n: signal.rows(),
            m: signal.cols(),
            config,
            sigma,
            gamma,
            blocks,
        }
    }

    /// Assemble directly from blocks (merge-and-reduce path).
    pub fn from_blocks(
        n: usize,
        m: usize,
        config: CoresetConfig,
        sigma: f64,
        gamma: f64,
        blocks: Vec<BlockCoreset>,
    ) -> Self {
        Self { n, m, config, sigma, gamma, blocks }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.m
    }

    /// Number of stored points (4 per block, counting padding — this is
    /// the honest storage cost).
    pub fn stored_points(&self) -> usize {
        self.blocks.len() * 4
    }

    /// Points with non-zero weight.
    pub fn active_points(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| b.weights.iter().filter(|&&w| w > 0.0).count())
            .sum()
    }

    /// |C| / N.
    pub fn compression_ratio(&self) -> f64 {
        self.stored_points() as f64 / (self.n * self.m) as f64
    }

    /// Σ weights — equals the number of present cells (exactly, by the
    /// Caratheodory guarantee).
    pub fn total_weight(&self) -> f64 {
        self.blocks.iter().map(|b| b.total_weight()).sum()
    }

    /// The loss the coreset reports for the *optimal constant* model —
    /// exact, handy for sanity checks.
    pub fn opt1(&self) -> f64 {
        let mut m = crate::signal::stats::Moments::ZERO;
        for b in &self.blocks {
            m = m.add(&b.moments());
        }
        m.opt1()
    }
}

impl Coreset for SignalCoreset {
    fn fitting_loss(&self, s: &KSegmentation) -> f64 {
        fitting_loss::fitting_loss(self, s)
    }

    fn weighted_points(&self) -> Vec<WeightedPoint> {
        self.blocks.iter().flat_map(|b| b.points()).collect()
    }

    fn size(&self) -> usize {
        self.stored_points()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::segmentation::random_segmentation;
    use crate::signal::generate;

    #[test]
    fn block_coreset_moments_match_signal() {
        let mut rng = Rng::new(2);
        let sig = generate::smooth(20, 20, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let rect = Rect::new(2, 9, 3, 14);
        let bc = BlockCoreset::from_block(&sig, rect);
        let exact = stats.moments(&rect);
        let got = bc.moments();
        let scale = 1.0 + exact.sum_sq.abs();
        assert!((got.count - exact.count).abs() < 1e-7 * scale);
        assert!((got.sum - exact.sum).abs() < 1e-7 * scale);
        assert!((got.sum_sq - exact.sum_sq).abs() < 1e-6 * scale);
    }

    #[test]
    fn coreset_total_weight_is_cell_count() {
        let mut rng = Rng::new(3);
        let sig = generate::image_like(40, 30, 2, &mut rng);
        let cs = SignalCoreset::build(&sig, 5, 0.3);
        assert!((cs.total_weight() - 1200.0).abs() < 1e-6 * 1200.0);
    }

    #[test]
    fn coreset_opt1_matches_exact() {
        let mut rng = Rng::new(4);
        let sig = generate::smooth(30, 30, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let cs = SignalCoreset::build(&sig, 4, 0.3);
        let exact = stats.opt1(&sig.bounds());
        let approx = cs.opt1();
        assert!(
            (approx - exact).abs() <= 1e-6 * (1.0 + exact),
            "{approx} vs {exact}"
        );
    }

    #[test]
    fn piecewise_constant_gives_tiny_coreset() {
        let mut rng = Rng::new(5);
        let (sig, _) = generate::piecewise_constant(64, 64, 6, 0.0, &mut rng);
        let cs = SignalCoreset::build(&sig, 6, 0.2);
        // Noiseless piecewise constant → σ ≈ 0 → blocks = constant regions;
        // far fewer than N/16 blocks.
        assert!(
            cs.blocks.len() < 64 * 64 / 16,
            "{} blocks",
            cs.blocks.len()
        );
        // And it is loss-exact on the generating segmentation class:
        let stats = PrefixStats::new(&sig);
        for _ in 0..10 {
            let s = random_segmentation(sig.bounds(), 6, &mut rng);
            let exact = s.loss(&stats);
            let approx = Coreset::fitting_loss(&cs, &s);
            assert!(
                (approx - exact).abs() <= 0.25 * exact + 1e-6,
                "{approx} vs {exact}"
            );
        }
    }

    #[test]
    fn eps_controls_size() {
        let mut rng = Rng::new(6);
        let sig = generate::smooth(50, 50, 4, &mut rng);
        let tight = SignalCoreset::build(&sig, 4, 0.1);
        let loose = SignalCoreset::build(&sig, 4, 0.5);
        assert!(
            tight.blocks.len() >= loose.blocks.len(),
            "tight {} loose {}",
            tight.blocks.len(),
            loose.blocks.len()
        );
    }

    #[test]
    fn weighted_points_have_corner_coords() {
        let mut rng = Rng::new(7);
        let sig = generate::smooth(20, 20, 2, &mut rng);
        let cs = SignalCoreset::build(&sig, 3, 0.3);
        for b in &cs.blocks {
            let corners = b.rect.corners();
            for p in b.points() {
                assert!(corners.contains(&(p.row, p.col)));
                assert!(p.w > 0.0);
            }
        }
    }

    #[test]
    fn config_theory_shrinks_gamma() {
        let c = CoresetConfig::new(10, 0.2).theory(2.0);
        assert!(c.gamma.unwrap() < 0.2);
        assert!((c.gamma.unwrap() - 0.2 * 0.2 / 20.0).abs() < 1e-15);
    }
}
