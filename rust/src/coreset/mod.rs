//! The (k, ε)-coreset for decision trees of signals — Algorithm 3
//! (`SIGNAL-CORESET`) and its data structure, plus the baseline and
//! streaming compositions.
//!
//! Construction pipeline (Theorem 8):
//!
//! 1. [`crate::bicriteria::bicriteria`] → σ ≤ opt_k(D) and the nominal
//!    (α, β);
//! 2. [`crate::partition::partition`] with tolerance γ²σ → balanced
//!    partition `B`;
//! 3. [`caratheodory`] per block → 4 weighted labels matching
//!    (Σ1, Σy, Σy²) exactly, pinned to the block's corner coordinates
//!    (Algorithm 3, Line 6);
//! 4. [`fitting_loss`] (Algorithm 5) evaluates any k-segmentation against
//!    the coreset in O(k·|blocks|).
//!
//! The construction is band-shardable with no loss of correctness (the
//! merge-and-reduce property): [`SignalCoreset::construct_sharded`] runs
//! the pipeline per row-shard on the [`crate::par`] worker pool and
//! composes via [`merge_reduce`] — see DESIGN.md §Parallelism.
//!
//! ## API layering
//!
//! The `construct*` family below is the **low-level kernel layer**: it
//! takes explicit statistics, regions, and executors, and is what the
//! engine, the pipeline, and the streaming composition drive. Most
//! callers should go through the one front door instead —
//! [`crate::engine::Engine`], which owns the shared statistics, a
//! long-lived worker pool, and the kernel backend (DESIGN.md §Engine &
//! API layering). The historical `SignalCoreset::build*` names survive
//! as `#[deprecated]` shims for one release.
//!
//! ## Theory vs. practice (γ)
//!
//! The worst-case theory sets γ = ε²/(βk), which the paper itself calls
//! "too pessimistic in practice" (§4: a coreset of 1% of the input
//! achieves ε = 0.2 where the theory predicts a coreset *larger than the
//! input*). Like the paper's reference implementation we default to a
//! practical calibration — γ = ε/2, per-block tolerance γ²σ — found by
//! the calibration sweep recorded in EXPERIMENTS.md §Calibration, and
//! expose the theoretical rule behind [`CoresetConfig::theory`].

pub mod caratheodory;
pub mod fitting_loss;
pub mod merge_reduce;
pub mod merge_tree;
pub mod uniform;

use crate::bicriteria;
use crate::partition;
use crate::segmentation::KSegmentation;
use crate::signal::{PrefixStats, Rect, SignalSource};

/// One weighted coreset point: grid coordinates, label, weight.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WeightedPoint {
    pub row: usize,
    pub col: usize,
    pub y: f64,
    pub w: f64,
}

/// Per-block compressed representation: exactly 4 (label, weight) slots
/// (zero-weight padding when Caratheodory needs fewer), with coordinates
/// pinned to the block's 4 corners.
#[derive(Clone, Debug)]
pub struct BlockCoreset {
    pub rect: Rect,
    pub labels: [f64; 4],
    pub weights: [f64; 4],
}

impl BlockCoreset {
    /// Build from a signal block via Caratheodory compression, over any
    /// [`SignalSource`] (owned signal or zero-copy view; `rect` is in the
    /// source's coordinates). Row-contiguous iteration over the source's
    /// row slices (perf pass, EXPERIMENTS.md §Perf): avoids the per-cell
    /// (r, c) → index arithmetic of the generic cell iterator.
    pub fn from_block<S: SignalSource>(signal: &S, rect: Rect) -> Self {
        let mut red = caratheodory::CaratheodoryReducer::new();
        for r in rect.r0..=rect.r1 {
            let row = &signal.row_values(r)[rect.c0..=rect.c1];
            match signal.row_mask(r) {
                None => {
                    for &y in row {
                        red.push(y, 1.0);
                    }
                }
                Some(mask) => {
                    for (&y, &present) in row.iter().zip(&mask[rect.c0..=rect.c1]) {
                        if present {
                            red.push(y, 1.0);
                        }
                    }
                }
            }
        }
        Self::from_support(rect, red.finish())
    }

    /// Build from an explicit ≤4-point support.
    pub fn from_support(rect: Rect, support: Vec<(f64, f64)>) -> Self {
        assert!(support.len() <= 4, "Caratheodory support must be ≤ 4");
        let mut labels = [0.0f64; 4];
        let mut weights = [0.0f64; 4];
        for (i, (y, w)) in support.into_iter().enumerate() {
            labels[i] = y;
            weights[i] = w;
        }
        Self { rect, labels, weights }
    }

    /// (count, Σy, Σy²) of the represented block — exact by construction.
    pub fn moments(&self) -> crate::signal::stats::Moments {
        let mut m = crate::signal::stats::Moments::ZERO;
        for i in 0..4 {
            let w = self.weights[i];
            m.count += w;
            m.sum += w * self.labels[i];
            m.sum_sq += w * self.labels[i] * self.labels[i];
        }
        m
    }

    /// Total weight (= number of present cells in the block).
    pub fn total_weight(&self) -> f64 {
        self.weights.iter().sum()
    }

    /// True when the block carries no weight — its source cells were all
    /// masked out. Such blocks contribute nothing to any statistic or
    /// fitting loss; the build path drops them so that
    /// [`SignalCoreset::stored_points`] / `weighted_points()` accounting
    /// never counts dead storage.
    pub fn is_empty(&self) -> bool {
        self.weights.iter().all(|&w| w <= 0.0)
    }

    /// The 4 weighted points with corner coordinates (zero-weight entries
    /// skipped).
    pub fn points(&self) -> impl Iterator<Item = WeightedPoint> + '_ {
        let corners = self.rect.corners();
        (0..4).filter_map(move |i| {
            (self.weights[i] > 0.0).then(|| WeightedPoint {
                row: corners[i].0,
                col: corners[i].1,
                y: self.labels[i],
                w: self.weights[i],
            })
        })
    }
}

/// Common interface shared by the paper's coreset and the baselines, so
/// the experiment harnesses treat compressions uniformly.
pub trait Coreset {
    /// Approximate ℓ(D, s) for a k-segmentation `s`.
    fn fitting_loss(&self, s: &KSegmentation) -> f64;
    /// Flatten to weighted points (the representation handed to forest
    /// trainers).
    fn weighted_points(&self) -> Vec<WeightedPoint>;
    /// Number of stored points.
    fn size(&self) -> usize;
}

/// Construction parameters; see module docs for the γ discussion.
#[derive(Clone, Copy, Debug)]
pub struct CoresetConfig {
    pub k: usize,
    pub eps: f64,
    /// Explicit γ override; `None` → practical default γ = ε/2.
    pub gamma: Option<f64>,
    /// Explicit σ override; `None` → bicriteria estimate.
    pub sigma: Option<f64>,
}

impl CoresetConfig {
    pub fn new(k: usize, eps: f64) -> Self {
        assert!(k >= 1);
        assert!(eps > 0.0 && eps < 1.0, "eps must be in (0,1)");
        Self { k, eps, gamma: None, sigma: None }
    }

    /// The worst-case theoretical calibration γ = ε²/(βk) from Theorem 8.
    pub fn theory(mut self, beta: f64) -> Self {
        self.gamma = Some((self.eps * self.eps / (beta * self.k as f64)).min(1.0));
        self
    }
}

/// The (k, ε)-coreset of an n×m signal (Definition 3 / Theorem 8).
#[derive(Clone, Debug)]
pub struct SignalCoreset {
    n: usize,
    m: usize,
    pub config: CoresetConfig,
    /// σ actually used (lower-bound estimate of opt_k).
    pub sigma: f64,
    /// γ actually used.
    pub gamma: f64,
    pub blocks: Vec<BlockCoreset>,
}

impl SignalCoreset {
    /// Algorithm 3 with the practical default calibration — the
    /// monolithic (single-shard) construction. Generic over
    /// [`SignalSource`]: building over a zero-copy [`crate::signal::SignalView`]
    /// is bit-identical to building over the equivalent [`crate::signal::Signal::crop`]
    /// (same data, same iteration order — the view/crop differential
    /// suite in `tests/integration_views.rs` pins this down).
    pub fn construct<S: SignalSource>(signal: &S, k: usize, eps: f64) -> Self {
        Self::construct_with(signal, CoresetConfig::new(k, eps))
    }

    /// Algorithm 3 with explicit configuration.
    pub fn construct_with<S: SignalSource>(signal: &S, config: CoresetConfig) -> Self {
        let stats = PrefixStats::new(signal);
        Self::construct_with_stats(signal, &stats, config)
    }

    /// Variant reusing precomputed prefix statistics (the pipeline path).
    /// `stats` must cover `signal`'s coordinate frame.
    pub fn construct_with_stats<S: SignalSource>(
        signal: &S,
        stats: &PrefixStats,
        config: CoresetConfig,
    ) -> Self {
        Self::construct_in(signal, stats, signal.bounds(), config)
    }

    /// Region-scoped Algorithm 3 — the zero-copy shard primitive: run
    /// bicriteria → partition → per-block Caratheodory on the
    /// sub-rectangle `region` of `signal`, answering every statistics
    /// query from the one shared `stats` (built once for the whole
    /// signal). Blocks come out directly in `signal`'s coordinates, so
    /// band shards need no cropped copies, no per-shard integral images,
    /// and no row-offset fixups. For `region == signal.bounds()` this is
    /// exactly the monolithic [`Self::construct_with_stats`].
    ///
    /// **Coordinate contract.** Blocks stay in `signal`'s frame while
    /// the returned coreset's `rows()`/`cols()` are the *region's*
    /// dimensions (what [`merge_reduce::merge`] sums when composing
    /// row-bands). Consequently a partial coreset from an interior
    /// region must be queried with segmentations expressed in the
    /// signal's coordinate frame (as [`Coreset::fitting_loss`] is over
    /// the merged result), not in a region-local 0-based frame — if you
    /// want a self-contained region-local coreset instead, build over
    /// `signal.view(region)`.
    pub fn construct_in<S: SignalSource>(
        signal: &S,
        stats: &PrefixStats,
        region: Rect,
        config: CoresetConfig,
    ) -> Self {
        // Hard assert (two usize compares vs an O(area) build): mixing a
        // view with the parent signal's stats would otherwise produce a
        // silently wrong coreset or a slice panic deep in the build.
        assert!(
            stats.rows() == signal.rows() && stats.cols() == signal.cols(),
            "stats must be built over the same coordinate frame as signal"
        );
        let sigma = config
            .sigma
            .unwrap_or_else(|| bicriteria::bicriteria_in(stats, region, config.k).sigma);
        let gamma = config.gamma.unwrap_or(config.eps / 2.0).clamp(1e-9, 1.0);
        let rects = partition::partition_in(stats, region, gamma, sigma);
        // Fully-masked blocks compress to an all-zero-weight support;
        // drop them (they carry no moments and would only pad
        // `stored_points`).
        let blocks = rects
            .into_iter()
            .map(|rect| BlockCoreset::from_block(signal, rect))
            .filter(|b| !b.is_empty())
            .collect();
        Self {
            n: region.height(),
            m: region.width(),
            config,
            sigma,
            gamma,
            blocks,
        }
    }

    /// Parallel Algorithm 3 on the [`crate::par`] worker pool: build one
    /// shared [`PrefixStats`] for the whole signal (via the thread-
    /// invariant [`PrefixStats::new_par`]), row-shard into
    /// ⌊n/shard_rows⌋ near-equal bands (via
    /// [`bicriteria::band_edges`]; the default geometry is
    /// [`Self::SHARD_ROWS`] = 64, i.e. 64–127 rows per shard), run the
    /// full bicriteria → partition → per-block Caratheodory pipeline per
    /// shard through [`Self::construct_in`] — each shard an O(1)
    /// `(&PrefixStats, Rect)` window, **zero per-shard copies or
    /// integral-image rebuilds** — then compose through the existing
    /// merge-and-reduce path. Every per-block guarantee is local to its
    /// band (the merge-and-reduce property, §1.1 Challenge (iv)), so
    /// sharding never weakens the coreset — see DESIGN.md §Parallelism
    /// and §Views & Memory.
    ///
    /// The shard plan and the shared statistics depend only on the
    /// signal shape, never on `threads`, so any thread count produces
    /// the bit-identical coreset; `threads == 0` means "all available
    /// cores". Signals with fewer than two shards fall back to the
    /// sequential [`Self::construct_with`].
    pub fn construct_sharded<S: SignalSource>(
        signal: &S,
        config: CoresetConfig,
        threads: usize,
    ) -> Self {
        Self::construct_sharded_exec(
            signal,
            config,
            Self::SHARD_ROWS,
            crate::par::Exec::Spawn(threads),
        )
    }

    /// Default row-shard geometry of [`Self::construct_sharded`] (the
    /// band plan [`bicriteria::band_edges`] equalizes around it).
    pub const SHARD_ROWS: usize = 64;

    /// [`Self::construct_sharded`] with explicit shard geometry and
    /// executor ([`crate::par::Exec`]) — the engine path: shards fan out
    /// on a long-lived [`crate::par::WorkerPool`] instead of spawning
    /// scoped threads per call. The shard plan depends only on
    /// `(signal shape, shard_rows)`, so for the default geometry every
    /// executor and thread count is bit-identical to
    /// [`Self::construct_sharded`].
    pub fn construct_sharded_exec<S: SignalSource>(
        signal: &S,
        config: CoresetConfig,
        shard_rows: usize,
        exec: crate::par::Exec<'_>,
    ) -> Self {
        let shard_rows = shard_rows.max(1);
        if signal.rows() / shard_rows <= 1 {
            return Self::construct_with(signal, config);
        }
        let stats = PrefixStats::new_par_exec(signal, exec);
        Self::construct_sharded_with_stats(signal, &stats, config, shard_rows, exec)
    }

    /// The sharded construction against a caller-owned shared
    /// [`PrefixStats`] (an engine session reusing one statistics object
    /// across builds). `stats` must cover `signal`'s coordinate frame
    /// and, for bit-identity with [`Self::construct_sharded`], must come
    /// from the thread-invariant [`PrefixStats::new_par`] family.
    /// Signals with fewer than two shards fall back to the sequential
    /// [`Self::construct_with`] (fresh sequential statistics — the same
    /// fallback every sharded entry point takes, so all of them agree
    /// bitwise on short signals).
    ///
    /// Since the merge-tree refactor this builds through a transient
    /// [`merge_tree::MergeTree`] — the same shard plan, flat merge
    /// fold, and single root reduce, so the output is bit-identical to
    /// the historical fold-away composition (the tree's compatibility
    /// invariant). Callers who want to keep the per-shard leaves alive
    /// for incremental updates hold the tree itself (via
    /// [`crate::engine::Engine::edit_session`] or
    /// [`merge_tree::MergeTree::build`]).
    pub fn construct_sharded_with_stats<S: SignalSource>(
        signal: &S,
        stats: &PrefixStats,
        config: CoresetConfig,
        shard_rows: usize,
        exec: crate::par::Exec<'_>,
    ) -> Self {
        let shard_rows = shard_rows.max(1);
        if signal.rows() / shard_rows <= 1 {
            return Self::construct_with(signal, config);
        }
        merge_tree::MergeTree::build(signal, stats, config, shard_rows, exec).full()
    }

    // ------------------------------------------------------------------
    // Deprecated `build*` shims — the pre-engine public surface, kept
    // compiling for one release. Each delegates to its `construct*`
    // replacement, so behaviour (and every produced bit) is unchanged.
    // ------------------------------------------------------------------

    /// Former name of [`Self::construct`].
    #[deprecated(
        since = "0.2.0",
        note = "go through the front door — `sigtree::engine::Engine::coreset` — \
                or use the low-level `SignalCoreset::construct`"
    )]
    pub fn build<S: SignalSource>(signal: &S, k: usize, eps: f64) -> Self {
        Self::construct(signal, k, eps)
    }

    /// Former name of [`Self::construct_with`].
    #[deprecated(
        since = "0.2.0",
        note = "go through the front door — `sigtree::engine::Engine::coreset` — \
                or use the low-level `SignalCoreset::construct_with`"
    )]
    pub fn build_with<S: SignalSource>(signal: &S, config: CoresetConfig) -> Self {
        Self::construct_with(signal, config)
    }

    /// Former name of [`Self::construct_with_stats`].
    #[deprecated(
        since = "0.2.0",
        note = "use `sigtree::engine::Engine::session` (which owns the shared stats) \
                or the low-level `SignalCoreset::construct_with_stats`"
    )]
    pub fn build_with_stats<S: SignalSource>(
        signal: &S,
        stats: &PrefixStats,
        config: CoresetConfig,
    ) -> Self {
        Self::construct_with_stats(signal, stats, config)
    }

    /// Former name of [`Self::construct_in`].
    #[deprecated(
        since = "0.2.0",
        note = "use `sigtree::engine::Engine::coreset_region` \
                or the low-level `SignalCoreset::construct_in`"
    )]
    pub fn build_in<S: SignalSource>(
        signal: &S,
        stats: &PrefixStats,
        region: Rect,
        config: CoresetConfig,
    ) -> Self {
        Self::construct_in(signal, stats, region, config)
    }

    /// Former name of [`Self::construct_sharded`].
    #[deprecated(
        since = "0.2.0",
        note = "go through the front door — `sigtree::engine::Engine::coreset`, which \
                reuses one worker pool across builds — or use the low-level \
                `SignalCoreset::construct_sharded`"
    )]
    pub fn build_par<S: SignalSource>(
        signal: &S,
        config: CoresetConfig,
        threads: usize,
    ) -> Self {
        Self::construct_sharded(signal, config, threads)
    }

    /// Approximate ℓ(D, s) for many k-segmentations concurrently on the
    /// [`crate::par`] worker pool — the forest/tuning workload, where a
    /// sweep evaluates hundreds of candidate segmentations against one
    /// coreset. Results are in query order and identical to calling
    /// [`Coreset::fitting_loss`] per query; `threads == 0` means "all
    /// available cores" on the library path exactly as it does on the
    /// CLI (both normalize through [`crate::par::resolve_threads`]).
    /// Serving workloads issuing many batches should prefer
    /// [`crate::engine::Engine::fitting_loss`], which reuses one
    /// long-lived pool instead of spawning threads per call.
    pub fn fitting_loss_batch(&self, queries: &[KSegmentation], threads: usize) -> Vec<f64> {
        fitting_loss::fitting_loss_batch(self, queries, threads)
    }

    /// Assemble directly from blocks (merge-and-reduce path).
    pub fn from_blocks(
        n: usize,
        m: usize,
        config: CoresetConfig,
        sigma: f64,
        gamma: f64,
        blocks: Vec<BlockCoreset>,
    ) -> Self {
        Self { n, m, config, sigma, gamma, blocks }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.m
    }

    /// Number of stored points (4 per block, counting padding — this is
    /// the honest storage cost).
    pub fn stored_points(&self) -> usize {
        self.blocks.len() * 4
    }

    /// Points with non-zero weight.
    pub fn active_points(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| b.weights.iter().filter(|&&w| w > 0.0).count())
            .sum()
    }

    /// Number of **distinct** grid cells carrying positive weight — the
    /// coreset's true support. Thin blocks (1×1, 1×c, r×1) pin several
    /// Caratheodory slots to coincident corners, so `stored_points()`
    /// (4 × blocks, counting padding) overstates the support; merged
    /// coresets concatenate many thin shard-boundary blocks and inflate
    /// it further.
    pub fn support_cells(&self) -> usize {
        // BTreeSet, not HashSet: support_cells feeds reported sizes and
        // must stay hash-order-free like the rest of the coreset path.
        let mut cells = std::collections::BTreeSet::new();
        for b in &self.blocks {
            for p in b.points() {
                cells.insert((p.row, p.col));
            }
        }
        cells.len()
    }

    /// |C| / (number of present input cells). The numerator is
    /// [`Self::support_cells`] — the deduplicated positive-weight
    /// support, not the 4-slot storage footprint, which double-counts
    /// coincident corners of thin blocks (the accounting bug the merge
    /// tree's memoized nodes surfaced on merged coresets). The
    /// denominator is [`Self::total_weight`], which equals the
    /// present-cell count exactly by the Caratheodory guarantee —
    /// dividing by n·m would overstate compression on masked signals,
    /// where absent cells were never part of the input. Returns 0 for
    /// an empty coreset.
    pub fn compression_ratio(&self) -> f64 {
        let present = self.total_weight();
        if present <= 0.0 {
            return 0.0;
        }
        self.support_cells() as f64 / present
    }

    /// Σ weights — equals the number of present cells (exactly, by the
    /// Caratheodory guarantee).
    pub fn total_weight(&self) -> f64 {
        self.blocks.iter().map(|b| b.total_weight()).sum()
    }

    /// The partition-block boundary positions in signal coordinates:
    /// sorted, deduplicated "first row/col of a block below/right of a
    /// cut" values (`r0` and `r1 + 1` of every block, and likewise for
    /// columns). These are the positions where FITTING-LOSS switches
    /// between the exact Case (i) and the smoothed Case (ii), which makes
    /// them the natural targets for the audit engine's
    /// boundary-adversarial query family
    /// ([`crate::segmentation::boundary_adversarial_segmentation`]).
    pub fn block_edges(&self) -> (Vec<usize>, Vec<usize>) {
        let mut rows = Vec::with_capacity(self.blocks.len() * 2);
        let mut cols = Vec::with_capacity(self.blocks.len() * 2);
        for b in &self.blocks {
            rows.push(b.rect.r0);
            rows.push(b.rect.r1 + 1);
            cols.push(b.rect.c0);
            cols.push(b.rect.c1 + 1);
        }
        rows.sort_unstable();
        rows.dedup();
        cols.sort_unstable();
        cols.dedup();
        (rows, cols)
    }

    /// The loss the coreset reports for the *optimal constant* model —
    /// exact, handy for sanity checks.
    pub fn opt1(&self) -> f64 {
        let mut m = crate::signal::stats::Moments::ZERO;
        for b in &self.blocks {
            m = m.add(&b.moments());
        }
        m.opt1()
    }
}

impl Coreset for SignalCoreset {
    fn fitting_loss(&self, s: &KSegmentation) -> f64 {
        fitting_loss::fitting_loss(self, s)
    }

    fn weighted_points(&self) -> Vec<WeightedPoint> {
        self.blocks.iter().flat_map(|b| b.points()).collect()
    }

    fn size(&self) -> usize {
        self.stored_points()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::segmentation::random_segmentation;
    use crate::signal::{generate, Signal};

    #[test]
    fn block_coreset_moments_match_signal() {
        let mut rng = Rng::new(2);
        let sig = generate::smooth(20, 20, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let rect = Rect::new(2, 9, 3, 14);
        let bc = BlockCoreset::from_block(&sig, rect);
        let exact = stats.moments(&rect);
        let got = bc.moments();
        let scale = 1.0 + exact.sum_sq.abs();
        assert!((got.count - exact.count).abs() < 1e-7 * scale);
        assert!((got.sum - exact.sum).abs() < 1e-7 * scale);
        assert!((got.sum_sq - exact.sum_sq).abs() < 1e-6 * scale);
    }

    #[test]
    fn coreset_total_weight_is_cell_count() {
        let mut rng = Rng::new(3);
        let sig = generate::image_like(40, 30, 2, &mut rng);
        let cs = SignalCoreset::construct(&sig, 5, 0.3);
        assert!((cs.total_weight() - 1200.0).abs() < 1e-6 * 1200.0);
    }

    #[test]
    fn coreset_opt1_matches_exact() {
        let mut rng = Rng::new(4);
        let sig = generate::smooth(30, 30, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let cs = SignalCoreset::construct(&sig, 4, 0.3);
        let exact = stats.opt1(&sig.bounds());
        let approx = cs.opt1();
        assert!(
            (approx - exact).abs() <= 1e-6 * (1.0 + exact),
            "{approx} vs {exact}"
        );
    }

    #[test]
    fn piecewise_constant_gives_tiny_coreset() {
        let mut rng = Rng::new(5);
        let (sig, _) = generate::piecewise_constant(64, 64, 6, 0.0, &mut rng);
        let cs = SignalCoreset::construct(&sig, 6, 0.2);
        // Noiseless piecewise constant → σ ≈ 0 → blocks = constant regions;
        // far fewer than N/16 blocks.
        assert!(
            cs.blocks.len() < 64 * 64 / 16,
            "{} blocks",
            cs.blocks.len()
        );
        // And it is loss-exact on the generating segmentation class:
        let stats = PrefixStats::new(&sig);
        for _ in 0..10 {
            let s = random_segmentation(sig.bounds(), 6, &mut rng);
            let exact = s.loss(&stats);
            let approx = Coreset::fitting_loss(&cs, &s);
            assert!(
                (approx - exact).abs() <= 0.25 * exact + 1e-6,
                "{approx} vs {exact}"
            );
        }
    }

    #[test]
    fn eps_controls_size() {
        let mut rng = Rng::new(6);
        let sig = generate::smooth(50, 50, 4, &mut rng);
        let tight = SignalCoreset::construct(&sig, 4, 0.1);
        let loose = SignalCoreset::construct(&sig, 4, 0.5);
        assert!(
            tight.blocks.len() >= loose.blocks.len(),
            "tight {} loose {}",
            tight.blocks.len(),
            loose.blocks.len()
        );
    }

    #[test]
    fn weighted_points_have_corner_coords() {
        let mut rng = Rng::new(7);
        let sig = generate::smooth(20, 20, 2, &mut rng);
        let cs = SignalCoreset::construct(&sig, 3, 0.3);
        for b in &cs.blocks {
            let corners = b.rect.corners();
            for p in b.points() {
                assert!(corners.contains(&(p.row, p.col)));
                assert!(p.w > 0.0);
            }
        }
    }

    #[test]
    fn compression_ratio_counts_present_cells_only() {
        let mut rng = Rng::new(8);
        let mut sig = generate::smooth(40, 40, 3, &mut rng);
        // Mask out the left half: 800 of 1600 cells remain.
        sig.mask_rect(Rect::new(0, 39, 0, 19));
        let cs = SignalCoreset::construct(&sig, 4, 0.3);
        assert!((cs.total_weight() - 800.0).abs() < 1e-6 * 800.0);
        let expected = cs.support_cells() as f64 / cs.total_weight();
        assert!(
            (cs.compression_ratio() - expected).abs() < 1e-12,
            "ratio must divide deduplicated support by present cells"
        );
        // Dividing by n*m would halve the reported ratio here.
        let overstated = cs.support_cells() as f64 / 1600.0;
        assert!(cs.compression_ratio() > 1.5 * overstated);
    }

    #[test]
    fn compression_ratio_deduplicates_thin_block_corners() {
        // A 1-row signal forces every partition block to be 1×c or 1×1:
        // all 4 corner slots collapse onto ≤ 2 distinct cells, so the
        // old `stored_points()`-based numerator overstated the support.
        let mut rng = Rng::new(9);
        let sig = generate::smooth(1, 96, 2, &mut rng);
        let cs = SignalCoreset::construct(&sig, 3, 0.3);
        let support = cs.support_cells();
        assert!(
            support < cs.stored_points(),
            "thin blocks must dedup coincident corners ({support} vs {})",
            cs.stored_points()
        );
        // Every support cell is a real grid cell, and the ratio uses
        // the deduplicated count.
        assert!(support <= sig.len());
        let expected = support as f64 / cs.total_weight();
        assert!((cs.compression_ratio() - expected).abs() < 1e-12);

        // Merged composition: concatenating shard parts (what the merge
        // tree memoizes) must report the union's deduplicated support,
        // which can never exceed the number of present cells.
        let mut rng = Rng::new(10);
        let tall = generate::smooth(256, 8, 2, &mut rng);
        let merged = SignalCoreset::construct_sharded(&tall, CoresetConfig::new(3, 0.3), 2);
        assert!(merged.support_cells() <= tall.len());
        assert!(merged.compression_ratio() <= 1.0 + 1e-12);
    }

    #[test]
    fn fully_masked_blocks_are_dropped() {
        let mut rng = Rng::new(9);
        let mut sig = generate::smooth(20, 20, 2, &mut rng);
        // Top half fully masked → its partition blocks compress to
        // zero-weight supports and must not be stored.
        sig.mask_rect(Rect::new(0, 9, 0, 19));
        let cs = SignalCoreset::construct(&sig, 3, 0.3);
        assert!(!cs.blocks.is_empty());
        for b in &cs.blocks {
            assert!(!b.is_empty(), "zero-weight block stored: {:?}", b.rect);
            assert!(b.total_weight() > 0.0);
        }
        assert!((cs.total_weight() - 200.0).abs() < 1e-6 * 200.0);
        // weighted_points / stored_points accounting stays consistent.
        let w: f64 = cs.weighted_points().iter().map(|p| p.w).sum();
        assert!((w - cs.total_weight()).abs() < 1e-9 * 200.0);
        assert!(cs.weighted_points().len() <= cs.stored_points());
    }

    #[test]
    fn from_block_fully_masked_is_empty() {
        let mut sig = Signal::constant(8, 8, 1.0);
        sig.mask_rect(Rect::new(0, 3, 0, 7));
        let bc = BlockCoreset::from_block(&sig, Rect::new(0, 3, 0, 7));
        assert!(bc.is_empty());
        assert_eq!(bc.points().count(), 0);
        assert_eq!(bc.total_weight(), 0.0);
        let m = bc.moments();
        assert_eq!((m.count, m.sum, m.sum_sq), (0.0, 0.0, 0.0));
    }

    #[test]
    fn build_par_matches_across_thread_counts() {
        let mut rng = Rng::new(10);
        let sig = generate::smooth(192, 40, 3, &mut rng);
        let config = CoresetConfig::new(4, 0.3);
        let reference = SignalCoreset::construct_sharded(&sig, config, 1);
        assert!((reference.total_weight() - (192 * 40) as f64).abs() < 1e-6);
        for threads in [0, 2, 3, 4] {
            let cs = SignalCoreset::construct_sharded(&sig, config, threads);
            assert_eq!(cs.blocks.len(), reference.blocks.len(), "threads {threads}");
            for (a, b) in cs.blocks.iter().zip(&reference.blocks) {
                assert_eq!(a.rect, b.rect, "threads {threads}");
                assert_eq!(a.labels, b.labels, "threads {threads}");
                assert_eq!(a.weights, b.weights, "threads {threads}");
            }
        }
    }

    #[test]
    fn block_edges_are_sorted_interior_and_bounds() {
        let mut rng = Rng::new(14);
        let sig = generate::smooth(40, 32, 3, &mut rng);
        let cs = SignalCoreset::construct(&sig, 4, 0.3);
        let (rows, cols) = cs.block_edges();
        // Blocks tile the signal, so 0 and n/m are always edges.
        assert_eq!(*rows.first().unwrap(), 0);
        assert_eq!(*rows.last().unwrap(), 40);
        assert_eq!(*cols.first().unwrap(), 0);
        assert_eq!(*cols.last().unwrap(), 32);
        for w in rows.windows(2) {
            assert!(w[0] < w[1]);
        }
        // Every block boundary is present.
        for b in &cs.blocks {
            assert!(rows.binary_search(&b.rect.r0).is_ok());
            assert!(rows.binary_search(&(b.rect.r1 + 1)).is_ok());
            assert!(cols.binary_search(&b.rect.c0).is_ok());
            assert!(cols.binary_search(&(b.rect.c1 + 1)).is_ok());
        }
    }

    #[test]
    fn config_theory_shrinks_gamma() {
        let c = CoresetConfig::new(10, 0.2).theory(2.0);
        assert!(c.gamma.unwrap() < 0.2);
        assert!((c.gamma.unwrap() - 0.2 * 0.2 / 20.0).abs() < 1e-15);
    }
}
