//! FITTING-LOSS — Algorithm 5 of the paper (Lemma 14).
//!
//! Given a coreset and a k-segmentation `s`, approximate ℓ(D, s) within
//! 1±ε in O(k · |blocks|) time, never touching the original signal.
//!
//! Per block B with coreset pair (C_B, u_B):
//!
//! * `s` does **not** intersect B (assigns one value v): the loss over B
//!   equals Σ u·(v − y)² **exactly**, because (C_B, u_B) matches the
//!   (Σ1, Σy, Σy²) moments of B and (v − y)² expands into exactly those
//!   moments (Case (i) of Claim 14.1).
//! * `s` intersects B: we evaluate the loss of a *smoothed version* of
//!   (C_B, u_B) (Fig. 8): each piece of `s` claims a mass `z` equal to
//!   the weight it covers inside B, and the coreset points are consumed
//!   in order, possibly fractionally, until each piece's demand is met.
//!   Lemma 14 bounds the resulting error by ε·ℓ(B,s) + O(opt₁(B)/ε).
//!
//! Evaluation is already zero-copy end to end: it reads only the stored
//! `(Rect, moments)` per block — never the signal — and the exact-loss
//! oracle it is tested against (`KSegmentation::loss`) runs on
//! `(&PrefixStats, Rect)` queries, so no code path here materializes a
//! sub-signal (DESIGN.md §Views & Memory).

use crate::segmentation::KSegmentation;
use super::{BlockCoreset, SignalCoreset};

/// Approximate ℓ(D, s) from the coreset alone (Algorithm 5).
pub fn fitting_loss(coreset: &SignalCoreset, s: &KSegmentation) -> f64 {
    let mut total = 0.0f64;
    for block in &coreset.blocks {
        total += block_loss(block, s);
    }
    total
}

/// Loss contribution of a single block.
pub fn block_loss(block: &BlockCoreset, s: &KSegmentation) -> f64 {
    // Collect the pieces of s that overlap this block, with the covered
    // area (the paper's z; with masks the area is a proxy for the covered
    // weight — exact when the block is fully present, see DESIGN.md).
    let rect = block.rect;
    let mut overlaps: [(f64, f64); 8] = [(0.0, 0.0); 8]; // (value, area) fast path
    let mut n_overlaps = 0usize;
    let mut spill: Vec<(f64, f64)> = Vec::new();
    let mut covered_area = 0usize;
    for (prect, v) in s.pieces() {
        if let Some(inter) = prect.intersection(&rect) {
            let a = inter.area();
            covered_area += a;
            if n_overlaps < overlaps.len() {
                overlaps[n_overlaps] = (*v, a as f64);
                n_overlaps += 1;
            } else {
                spill.push((*v, a as f64));
            }
            if covered_area == rect.area() {
                break;
            }
        }
    }
    if covered_area == 0 {
        return 0.0; // block entirely outside s's support
    }
    if n_overlaps == 1 && spill.is_empty() && covered_area == rect.area() {
        // Case (i): one value over the whole block — exact via moments.
        let v = overlaps[0].0;
        let m = block.moments();
        return m.sse_to(v);
    }
    // Case (ii): smoothed allocation, pro-rata variant. Every cell of the
    // block is fractionally assigned to all 4 coreset labels with weights
    // w_i / W — a valid smoothed version per (9)–(11) of the paper (each
    // coordinate's weights sum to 1, moments preserved), chosen because it
    // is order-independent and has the closed form
    //
    //   loss(B) = Σ_pieces z_p · [ (v_p − μ_B)² + var_B ],
    //
    //   z_p = weight mass covered by piece p, μ_B / var_B the block's
    //   weighted label mean / variance (exact from the stored moments).
    let m = block.moments();
    if m.count <= 0.0 {
        return 0.0;
    }
    let mu = m.mean();
    let var = m.opt1() / m.count; // per-unit-weight variance
    let per_cell = m.count / rect.area() as f64;
    let mut loss = 0.0f64;
    for &(v, area) in overlaps[..n_overlaps].iter().chain(spill.iter()) {
        let z = area * per_cell;
        let d = v - mu;
        loss += z * (d * d + var);
    }
    loss
}

/// Batch FITTING-LOSS: evaluate many k-segmentations against one coreset
/// concurrently on the [`crate::par`] worker pool. Queries are
/// independent reads of the immutable coreset, so this is embarrassingly
/// parallel; results are in query order and identical to a sequential
/// [`fitting_loss`] loop for any thread count (`0` = all cores).
pub fn fitting_loss_batch(
    coreset: &SignalCoreset,
    queries: &[KSegmentation],
    threads: usize,
) -> Vec<f64> {
    crate::par::parallel_map(queries, threads, |_, s| fitting_loss(coreset, s))
}

/// Relative approximation error |approx − exact| / exact of the coreset
/// on a specific query — the quantity Theorem 8 bounds by ε.
pub fn relative_error(approx: f64, exact: f64) -> f64 {
    if exact.abs() < 1e-12 {
        approx.abs()
    } else {
        (approx - exact).abs() / exact.abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coreset::{Coreset, SignalCoreset};
    use crate::rng::Rng;
    use crate::segmentation::{random_segmentation, KSegmentation};
    use crate::signal::{generate, PrefixStats, Rect};

    #[test]
    fn exact_for_non_intersecting_queries() {
        // A 1-segmentation never intersects any block → FITTING-LOSS must
        // be exact (Case (i) everywhere).
        let mut rng = Rng::new(8);
        let sig = generate::smooth(40, 40, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let cs = SignalCoreset::construct(&sig, 5, 0.3);
        for v in [-2.0, 0.0, 1.5] {
            let s = KSegmentation::constant(sig.bounds(), v);
            let exact = s.loss(&stats);
            let approx = cs.fitting_loss(&s);
            assert!(
                (approx - exact).abs() <= 1e-6 * (1.0 + exact),
                "v={v}: {approx} vs {exact}"
            );
        }
    }

    #[test]
    fn eps_guarantee_on_random_queries() {
        let mut rng = Rng::new(9);
        let sig = generate::smooth(60, 60, 4, &mut rng);
        let stats = PrefixStats::new(&sig);
        let k = 8;
        let eps = 0.2;
        let cs = SignalCoreset::construct(&sig, k, eps);
        let mut worst = 0.0f64;
        for _ in 0..50 {
            let mut s = random_segmentation(sig.bounds(), k, &mut rng);
            s.refit_values(&stats);
            let exact = s.loss(&stats);
            let approx = cs.fitting_loss(&s);
            worst = worst.max(relative_error(approx, exact));
        }
        assert!(worst <= eps, "worst relative error {worst} > ε={eps}");
    }

    #[test]
    fn handles_many_piece_overlaps() {
        // Query with k > 8 pieces all slicing one block — exercises the
        // spill path.
        let mut rng = Rng::new(10);
        let sig = generate::noise(32, 32, 1.0, &mut rng);
        let stats = PrefixStats::new(&sig);
        let cs = SignalCoreset::construct(&sig, 4, 0.4);
        let s = random_segmentation(sig.bounds(), 24, &mut rng);
        let approx = cs.fitting_loss(&s);
        let exact = s.loss(&stats);
        assert!(approx.is_finite());
        // Noise is the hardest case; just require the same magnitude.
        assert!(relative_error(approx, exact) < 1.0, "{approx} vs {exact}");
    }

    #[test]
    fn partial_cover_contributes_partially() {
        let mut rng = Rng::new(11);
        let sig = generate::smooth(20, 20, 2, &mut rng);
        let cs = SignalCoreset::construct(&sig, 3, 0.3);
        // s covers only the left half.
        let s = KSegmentation::new(vec![(Rect::new(0, 19, 0, 9), 0.0)]);
        let full = KSegmentation::constant(sig.bounds(), 0.0);
        let l_half = cs.fitting_loss(&s);
        let l_full = cs.fitting_loss(&full);
        assert!(l_half > 0.0);
        assert!(l_half < l_full);
    }

    #[test]
    fn smoothed_mass_is_conserved() {
        // The consumed mass equals the block weight: evaluating the
        // 0-valued full-cover query must equal Σ w·y² exactly even when
        // the query slices the block (v = 0 → loss = Σ w y² regardless of
        // allocation order).
        let mut rng = Rng::new(12);
        let sig = generate::smooth(24, 24, 3, &mut rng);
        let cs = SignalCoreset::construct(&sig, 4, 0.25);
        let slicer = random_segmentation(sig.bounds(), 9, &mut rng);
        let zeroed = KSegmentation::new(
            slicer.pieces().iter().map(|&(r, _)| (r, 0.0)).collect(),
        );
        let approx = cs.fitting_loss(&zeroed);
        let exact_sum_sq: f64 = cs
            .blocks
            .iter()
            .map(|b| b.moments().sum_sq)
            .sum();
        assert!(
            (approx - exact_sum_sq).abs() <= 1e-6 * (1.0 + exact_sum_sq),
            "{approx} vs {exact_sum_sq}"
        );
    }
}
