//! Caratheodory compression (Theorem 16 / Corollary 17).
//!
//! Every block B of the balanced partition is replaced by at most 4
//! weighted labels whose weighted (Σ1, Σy, Σy²) moments match B exactly:
//! the points (y, y², 1) ∈ ℝ³ have mean μ inside their convex hull, so by
//! Caratheodory's theorem 4 of them suffice to express μ as a convex
//! combination; rescaling by |B| gives the weights.
//!
//! The implementation is the standard streaming reduction: maintain at
//! most d+2 = 5 weighted points; whenever a 5th arrives, find a null
//! combination (Σλᵢpᵢ = 0, Σλᵢ = 0, λ ≠ 0 — guaranteed by dimension
//! count) and walk the weights along −λ until one hits zero. O(d³) per
//! reduction, O(n·d³) per block, d = 3.

/// A weighted label: `(y, w)` with `w ≥ 0`.
pub type WeightedLabel = (f64, f64);

/// Incremental Caratheodory reducer over points (y, y², 1) ∈ ℝ³.
#[derive(Clone, Debug, Default)]
pub struct CaratheodoryReducer {
    /// Current support: at most 4 (y, weight) pairs between reductions.
    support: Vec<WeightedLabel>,
}

impl CaratheodoryReducer {
    pub fn new() -> Self {
        Self { support: Vec::with_capacity(5) }
    }

    /// Add one label with weight `w`.
    pub fn push(&mut self, y: f64, w: f64) {
        if w <= 0.0 {
            return;
        }
        // Merge duplicates aggressively — blocks from the balanced
        // partition are near-constant, so this path dominates.
        for (sy, sw) in &mut self.support {
            if *sy == y {
                *sw += w;
                return;
            }
        }
        self.support.push((y, w));
        if self.support.len() > 4 {
            self.reduce();
        }
    }

    /// Merge another reducer's support (used by merge-and-reduce).
    pub fn merge(&mut self, other: &CaratheodoryReducer) {
        for &(y, w) in &other.support {
            self.push(y, w);
        }
    }

    /// Final support: 1–4 weighted labels matching the accumulated
    /// moments exactly (up to f64 roundoff).
    pub fn finish(self) -> Vec<WeightedLabel> {
        self.support
    }

    /// Reduce a 5-point support to 4 points preserving
    /// (Σw, Σw·y, Σw·y²).
    fn reduce(&mut self) {
        debug_assert_eq!(self.support.len(), 5);
        // Find λ ∈ ℝ⁵, λ ≠ 0 with Σλᵢ·(yᵢ, yᵢ², 1) = 0. That's 3 equations
        // (the Σλᵢ = 0 is the third row, from the constant coordinate) in
        // 5 unknowns → 2-dimensional null space; Gaussian elimination on
        // the 3×5 matrix gives a basis vector. Stack arrays throughout —
        // this runs once per input cell on the build hot path
        // (EXPERIMENTS.md §Perf).
        let mut ys = [0.0f64; 5];
        for (slot, &(y, _)) in ys.iter_mut().zip(self.support.iter()) {
            *slot = y;
        }
        let lambda = null_vector_3x5(&ys);
        // Walk weights along ±λ until the first weight hits zero. Choose
        // the direction with a positive step (some λᵢ > 0 must exist in at
        // least one of ±λ).
        let step = |dir: f64| -> Option<(f64, usize)> {
            let mut best: Option<(f64, usize)> = None;
            for (i, (&(_, w), &l)) in self.support.iter().zip(lambda.iter()).enumerate() {
                let li = l * dir;
                if li > 1e-300 {
                    let t = w / li;
                    if best.map_or(true, |(bt, _)| t < bt) {
                        best = Some((t, i));
                    }
                }
            }
            best
        };
        let (t, kill, dir) = match (step(1.0), step(-1.0)) {
            (Some((tp, ip)), Some((tm, im))) => {
                // Either direction works; pick the smaller step for
                // numerical gentleness.
                if tp <= tm {
                    (tp, ip, 1.0)
                } else {
                    (tm, im, -1.0)
                }
            }
            (Some((tp, ip)), None) => (tp, ip, 1.0),
            (None, Some((tm, im))) => (tm, im, -1.0),
            (None, None) => {
                // λ numerically zero (degenerate duplicate ys that the
                // merge above should have caught) — drop the lightest
                // point into its nearest neighbour instead.
                self.merge_lightest();
                return;
            }
        };
        for ((_, w), &l) in self.support.iter_mut().zip(lambda.iter()) {
            *w -= t * l * dir;
            if *w < 0.0 {
                *w = 0.0; // clamp roundoff
            }
        }
        self.support.remove(kill);
        // Clean residual zero weights (ties in the min step).
        self.support.retain(|&(_, w)| w > 0.0);
    }

    /// Degenerate fallback: merge the lightest point into the nearest y.
    fn merge_lightest(&mut self) {
        let Some((idx, _)) = self
            .support
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .1.total_cmp(&b.1 .1))
        else {
            return; // empty support: nothing to merge
        };
        let (y, w) = self.support.remove(idx);
        if let Some((_, tgt)) = self
            .support
            .iter_mut()
            .map(|p| ((p.0 - y).abs(), p))
            .min_by(|a, b| a.0.total_cmp(&b.0))
        {
            tgt.1 += w;
        }
    }
}

/// A null vector of the 3×5 system Σλᵢ(yᵢ, yᵢ², 1) = 0 via Gaussian
/// elimination with partial pivoting.
fn null_vector_3x5(ys: &[f64]) -> [f64; 5] {
    debug_assert_eq!(ys.len(), 5);
    // Rows: y, y², 1; columns: the five points.
    let mut a = [[0.0f64; 5]; 3];
    for (j, &y) in ys.iter().enumerate() {
        a[0][j] = y;
        a[1][j] = y * y;
        a[2][j] = 1.0;
    }
    // Forward elimination, tracking pivot columns (stack-allocated).
    let mut pivot_cols = arrayvec3::ArrayVec3::new();
    let mut row = 0usize;
    for col in 0..5 {
        if row >= 3 {
            break;
        }
        // Partial pivot.
        let Some((best_r, best_v)) = (row..3)
            .map(|r| (r, a[r][col].abs()))
            .max_by(|x, y| x.1.total_cmp(&y.1))
        else {
            break; // row == 3 is caught above; defensive only
        };
        if best_v < 1e-12 {
            continue; // free column
        }
        a.swap(row, best_r);
        let inv = 1.0 / a[row][col];
        for c in col..5 {
            a[row][c] *= inv;
        }
        for r in 0..3 {
            if r != row {
                let f = a[r][col];
                if f != 0.0 {
                    for c in col..5 {
                        a[r][c] -= f * a[row][c];
                    }
                }
            }
        }
        pivot_cols.push(col);
        row += 1;
    }
    // Pick the first free column, set λ_free = 1, back-substitute pivots.
    let mut lambda = [0.0f64; 5];
    // At most 3 pivot columns exist, so a free column always does; the
    // fallback index is unreachable.
    let free = (0..5).find(|c| !pivot_cols.contains(c)).unwrap_or(4);
    lambda[free] = 1.0;
    for (r, &pc) in pivot_cols.iter().enumerate() {
        lambda[pc] = -a[r][free];
    }
    lambda
}

/// Tiny fixed-capacity (3) usize vec to keep the elimination
/// allocation-free on the hot path.
mod arrayvec3 {
    pub struct ArrayVec3 {
        data: [usize; 3],
        len: usize,
    }

    impl ArrayVec3 {
        pub fn new() -> Self {
            Self { data: [0; 3], len: 0 }
        }

        pub fn push(&mut self, v: usize) {
            debug_assert!(self.len < 3);
            self.data[self.len] = v;
            self.len += 1;
        }

        pub fn contains(&self, v: &usize) -> bool {
            self.data[..self.len].contains(v)
        }

        pub fn iter(&self) -> std::slice::Iter<'_, usize> {
            self.data[..self.len].iter()
        }
    }
}

/// Compress an iterator of (y, w) labels into ≤ 4 weighted labels with
/// identical (Σw, Σwy, Σwy²).
pub fn compress_labels(labels: impl IntoIterator<Item = WeightedLabel>) -> Vec<WeightedLabel> {
    let mut red = CaratheodoryReducer::new();
    for (y, w) in labels {
        red.push(y, w);
    }
    red.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn moments(pts: &[WeightedLabel]) -> (f64, f64, f64) {
        let mut c = 0.0;
        let mut s = 0.0;
        let mut q = 0.0;
        for &(y, w) in pts {
            c += w;
            s += w * y;
            q += w * y * y;
        }
        (c, s, q)
    }

    #[test]
    fn preserves_moments_random() {
        let mut rng = Rng::new(17);
        for trial in 0..50 {
            let n = rng.range(1, 400);
            let labels: Vec<WeightedLabel> =
                (0..n).map(|_| (rng.normal_ms(0.0, 3.0), 1.0)).collect();
            let (c0, s0, q0) = moments(&labels);
            let out = compress_labels(labels.clone());
            assert!(out.len() <= 4, "trial {trial}: {} points", out.len());
            assert!(out.iter().all(|&(_, w)| w >= 0.0));
            let (c1, s1, q1) = moments(&out);
            let scale = 1.0 + c0.abs() + s0.abs() + q0.abs();
            assert!((c0 - c1).abs() < 1e-7 * scale, "trial {trial} count");
            assert!((s0 - s1).abs() < 1e-7 * scale, "trial {trial} sum");
            assert!((q0 - q1).abs() < 1e-6 * scale, "trial {trial} sumsq");
        }
    }

    #[test]
    fn output_labels_come_from_input() {
        // C_B ⊆ B: every surviving label value appeared in the input.
        let mut rng = Rng::new(23);
        let labels: Vec<WeightedLabel> = (0..100)
            .map(|_| ((rng.usize(7) as f64) - 3.0, 1.0))
            .collect();
        let input_ys: Vec<f64> = labels.iter().map(|&(y, _)| y).collect();
        let out = compress_labels(labels);
        for (y, _) in out {
            assert!(input_ys.contains(&y));
        }
    }

    #[test]
    fn constant_block_compresses_to_one() {
        let out = compress_labels((0..1000).map(|_| (2.5, 1.0)));
        assert_eq!(out.len(), 1);
        assert!((out[0].0 - 2.5).abs() < 1e-15);
        assert!((out[0].1 - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn two_values_exact() {
        let out = compress_labels([(1.0, 3.0), (5.0, 7.0), (1.0, 2.0)]);
        let (c, s, q) = moments(&out);
        assert!((c - 12.0).abs() < 1e-12);
        assert!((s - (5.0 * 1.0 + 7.0 * 5.0)).abs() < 1e-12);
        assert!((q - (5.0 * 1.0 + 7.0 * 25.0)).abs() < 1e-12);
        assert!(out.len() <= 2);
    }

    #[test]
    fn weighted_inputs_supported() {
        let mut rng = Rng::new(31);
        let labels: Vec<WeightedLabel> = (0..200)
            .map(|_| (rng.normal(), rng.uniform(0.1, 5.0)))
            .collect();
        let (c0, s0, q0) = moments(&labels);
        let out = compress_labels(labels);
        let (c1, s1, q1) = moments(&out);
        let scale = 1.0 + c0.abs() + s0.abs() + q0.abs();
        assert!((c0 - c1).abs() < 1e-7 * scale);
        assert!((s0 - s1).abs() < 1e-7 * scale);
        assert!((q0 - q1).abs() < 1e-6 * scale);
    }

    #[test]
    fn merge_matches_sequential() {
        let mut a = CaratheodoryReducer::new();
        let mut b = CaratheodoryReducer::new();
        let mut all = CaratheodoryReducer::new();
        let mut rng = Rng::new(41);
        for i in 0..300 {
            let y = rng.normal();
            if i % 2 == 0 {
                a.push(y, 1.0);
            } else {
                b.push(y, 1.0);
            }
            all.push(y, 1.0);
        }
        a.merge(&b);
        let (c0, s0, q0) = moments(&a.finish());
        let (c1, s1, q1) = moments(&all.finish());
        assert!((c0 - c1).abs() < 1e-7 * (1.0 + c1.abs()));
        assert!((s0 - s1).abs() < 1e-6 * (1.0 + s1.abs()));
        assert!((q0 - q1).abs() < 1e-5 * (1.0 + q1.abs()));
    }

    #[test]
    fn null_vector_is_in_nullspace() {
        let mut rng = Rng::new(55);
        for _ in 0..100 {
            let ys: Vec<f64> = (0..5).map(|_| rng.normal_ms(0.0, 2.0)).collect();
            let l = null_vector_3x5(&ys);
            let norm: f64 = l.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!(norm > 1e-9);
            let r0: f64 = ys.iter().zip(&l).map(|(y, li)| y * li).sum();
            let r1: f64 = ys.iter().zip(&l).map(|(y, li)| y * y * li).sum();
            let r2: f64 = l.iter().sum();
            assert!(r0.abs() < 1e-6 * norm, "{r0}");
            let y2_max = ys.iter().map(|y| y * y).fold(0.0, f64::max);
            assert!(r1.abs() < 1e-5 * norm * (1.0 + y2_max), "{r1}");
            assert!(r2.abs() < 1e-6 * norm, "{r2}");
        }
    }
}
