//! Uniform random sampling baseline — the paper's `RandomSample(D, τ)`
//! comparison (§5). Samples τ present cells uniformly without
//! replacement, each weighted N/τ so the estimator is unbiased, and
//! evaluates losses by direct weighted summation.
//!
//! Unlike the coreset this has **no** worst-case guarantee for
//! k-segmentations (a thin rectangle can be missed entirely); Fig. 4
//! quantifies the resulting accuracy gap.

use crate::rng::Rng;
use crate::segmentation::KSegmentation;
use crate::signal::SignalSource;

use super::{Coreset, WeightedPoint};

/// A uniform sample compression of a signal.
#[derive(Clone, Debug, PartialEq)]
pub struct UniformSample {
    pub points: Vec<WeightedPoint>,
    pub n: usize,
    pub m: usize,
}

impl UniformSample {
    /// Sample `tau` present cells uniformly without replacement, from
    /// any [`SignalSource`] (views sample identically to materialized
    /// crops). A fully-masked signal yields an empty sample — the old
    /// `tau.min(present.len()).max(1)` clamp forced τ = 1 there and
    /// indexed an empty vector.
    pub fn build<S: SignalSource>(signal: &S, tau: usize, rng: &mut Rng) -> Self {
        let present: Vec<(usize, usize)> = (0..signal.rows())
            .flat_map(|r| (0..signal.cols()).map(move |c| (r, c)))
            .filter(|&(r, c)| signal.is_present(r, c))
            .collect();
        if present.is_empty() {
            return Self { points: Vec::new(), n: signal.rows(), m: signal.cols() };
        }
        let tau = tau.min(present.len()).max(1);
        let idx = rng.sample_indices(present.len(), tau);
        let w = present.len() as f64 / tau as f64;
        let points = idx
            .into_iter()
            .map(|i| {
                let (r, c) = present[i];
                WeightedPoint { row: r, col: c, y: signal.get(r, c), w }
            })
            .collect();
        Self { points, n: signal.rows(), m: signal.cols() }
    }
}

impl Coreset for UniformSample {
    fn fitting_loss(&self, s: &KSegmentation) -> f64 {
        let mut total = 0.0;
        for p in &self.points {
            if let Some(v) = s.value_at(p.row, p.col) {
                let d = v - p.y;
                total += p.w * d * d;
            }
        }
        total
    }

    fn weighted_points(&self) -> Vec<WeightedPoint> {
        self.points.clone()
    }

    fn size(&self) -> usize {
        self.points.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::segmentation::random_segmentation;
    use crate::signal::{generate, PrefixStats};

    #[test]
    fn sample_size_and_weights() {
        let mut rng = Rng::new(20);
        let sig = generate::smooth(30, 30, 2, &mut rng);
        let us = UniformSample::build(&sig, 90, &mut rng);
        assert_eq!(us.size(), 90);
        let total_w: f64 = us.points.iter().map(|p| p.w).sum();
        assert!((total_w - 900.0).abs() < 1e-9);
    }

    #[test]
    fn sample_caps_at_present_cells() {
        let sig = generate::noise(5, 5, 1.0, &mut Rng::new(1));
        let us = UniformSample::build(&sig, 1000, &mut Rng::new(2));
        assert_eq!(us.size(), 25);
    }

    #[test]
    fn estimator_is_consistent_at_full_sample() {
        // τ = N → the estimate is exact.
        let mut rng = Rng::new(21);
        let sig = generate::smooth(20, 20, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let us = UniformSample::build(&sig, 400, &mut rng);
        for _ in 0..5 {
            let s = random_segmentation(sig.bounds(), 5, &mut rng);
            let exact = s.loss(&stats);
            let est = us.fitting_loss(&s);
            assert!((est - exact).abs() < 1e-8 * (1.0 + exact));
        }
    }

    #[test]
    fn estimator_is_unbiased_in_expectation() {
        let mut rng = Rng::new(22);
        let sig = generate::smooth(30, 30, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let s = random_segmentation(sig.bounds(), 6, &mut rng);
        let exact = s.loss(&stats);
        let trials = 200;
        let mut mean = 0.0;
        for t in 0..trials {
            let mut r = Rng::new(1000 + t);
            let us = UniformSample::build(&sig, 60, &mut r);
            mean += us.fitting_loss(&s);
        }
        mean /= trials as f64;
        assert!(
            (mean - exact).abs() < 0.1 * exact,
            "mean {mean} vs exact {exact}"
        );
    }

    #[test]
    fn fully_masked_signal_yields_empty_sample() {
        // Regression: the old clamp `tau.min(present.len()).max(1)`
        // produced τ = 1 with an empty `present` vector and panicked on
        // the out-of-bounds index.
        let mut sig = generate::smooth(6, 6, 1, &mut Rng::new(7));
        sig.mask_rect(crate::signal::Rect::new(0, 5, 0, 5));
        let us = UniformSample::build(&sig, 10, &mut Rng::new(8));
        assert_eq!(us.size(), 0);
        assert_eq!(us.n, 6);
        assert_eq!(us.m, 6);
        let total_w: f64 = us.points.iter().map(|p| p.w).sum();
        assert_eq!(total_w, 0.0);
    }

    #[test]
    fn view_samples_bit_identical_to_crop() {
        // Generified build: a zero-copy view and the materialized crop
        // of the same rect consume the Rng identically.
        let sig = generate::smooth(24, 18, 3, &mut Rng::new(9));
        let rect = crate::signal::Rect::new(4, 19, 2, 15);
        let view = sig.view(rect);
        let crop = sig.crop(rect);
        let a = UniformSample::build(&view, 40, &mut Rng::new(10));
        let b = UniformSample::build(&crop, 40, &mut Rng::new(10));
        assert_eq!(a, b);
    }

    #[test]
    fn respects_mask() {
        let mut sig = generate::smooth(20, 20, 2, &mut Rng::new(3));
        sig.mask_rect(crate::signal::Rect::new(0, 9, 0, 19));
        let us = UniformSample::build(&sig, 50, &mut Rng::new(4));
        for p in &us.points {
            assert!(p.row >= 10, "sampled masked cell ({}, {})", p.row, p.col);
        }
    }
}
