//! First-class merge-tree coresets — the persistent form of the
//! merge-and-reduce property (§1.1, Challenge (iv)).
//!
//! [`super::merge_reduce`] composes per-shard coresets and immediately
//! folds them away; every one-tile change to the signal then costs a
//! full O(N·k) rebuild. A [`MergeTree`] keeps the per-shard **leaf**
//! coresets alive (keyed by their shard [`Rect`] in signal coordinates)
//! and memoizes their composition in a balanced tree of configurable
//! fanout, which buys three operations the fold-away path cannot offer:
//!
//! * [`MergeTree::full`] — the root coreset. **Compatibility
//!   invariant:** for the shard plan of
//!   [`SignalCoreset::construct_sharded_exec`] this is bit-identical to
//!   that builder's output at every thread count and fanout. The tree
//!   memoizes only block-list *concatenation* at internal nodes; the
//!   root σ/γ/row totals are folded flat over the leaves in shard order
//!   (exactly what [`merge_reduce::merge`] computes — f64 addition is
//!   not associative, so a pairwise tree fold would change the reduce
//!   tolerance bits), and a single [`merge_reduce::reduce`] runs at the
//!   root.
//! * [`MergeTree::update`] — after the signal mutated inside a dirty
//!   rectangle, rebuild **only the leaves intersecting it** (fanned out
//!   on the caller's executor) and re-mark the O(log S) ancestor path;
//!   clean leaves are reused as-is. The per-block guarantees are local
//!   (Theorem 8's merge-and-reduce argument), so the updated root is a
//!   valid (k, ε)-coreset of the mutated signal — gated empirically by
//!   the `incremental-update` family of [`crate::audit`].
//! * [`MergeTree::push_band`] — streaming-bucket appends.
//!   [`super::merge_reduce::StreamingCoreset`] is a thin facade over
//!   this: the tree maintains the classic incrementally-compacted
//!   accumulator ([`MergeTree::streamed`]) with the exact legacy
//!   schedule, while the appended leaves keep logarithmic merge height
//!   ([`MergeTree::height`]) for later [`MergeTree::full`] /
//!   [`MergeTree::update`] calls.
//!
//! Memory: leaves hold the per-shard coresets (what the fold-away path
//! materializes transiently anyway); memoized internal nodes add
//! O(S·log S) block references in the worst case, freed on
//! invalidation. See DESIGN.md §Merge tree for the structure diagram
//! and the O(dirty·k + log S·reduce) update cost model.

use crate::error::{Error, Result};
use crate::par::Exec;
use crate::signal::{PrefixStats, Rect, SignalSource};

use super::merge_reduce;
use super::{BlockCoreset, CoresetConfig, SignalCoreset};

/// Translate a band-local coreset to global row coordinates (band
/// starts at `row_offset`). Crate-internal: shard builds emit global
/// coordinates since the zero-copy refactor, so only true-streaming
/// paths (owned bands that never saw the full frame) need it.
pub(crate) fn translate_rows(mut coreset: SignalCoreset, row_offset: usize) -> SignalCoreset {
    for b in &mut coreset.blocks {
        b.rect = Rect::new(
            b.rect.r0 + row_offset,
            b.rect.r1 + row_offset,
            b.rect.c0,
            b.rect.c1,
        );
    }
    coreset
}

/// One leaf: the shard rectangle (signal coordinates) and its coreset.
struct Leaf {
    rect: Rect,
    part: SignalCoreset,
}

/// One memoized internal node: the concatenation of its children's
/// block lists (`None` = stale), plus the child count it was computed
/// for (append can grow the last node's child set without changing the
/// node count).
struct Node {
    blocks: Option<Vec<BlockCoreset>>,
    children: usize,
}

/// The persistent merge tree — see the module docs. The lifetime
/// parameter only matters for a stored band-build executor
/// ([`Self::with_band_exec`], the streaming facade's pool path); batch
/// trees leave it unconstrained.
pub struct MergeTree<'p> {
    m: usize,
    config: CoresetConfig,
    /// Children per internal node (≥ 2). A pure memoization-shape knob:
    /// [`Self::full`] is bit-identical for every fanout.
    fanout: usize,
    /// Root reduce tolerance override; `None` → the standard γ²σ of the
    /// flat-merged parts (the [`SignalCoreset::construct_sharded_exec`]
    /// tolerance — required for the compatibility invariant).
    reduce_tol: Option<f64>,
    leaves: Vec<Leaf>,
    /// `levels[0]` composes leaves, `levels[l]` composes `levels[l-1]`;
    /// the last level has exactly one node (the root) whenever there
    /// are ≥ 2 leaves.
    levels: Vec<Vec<Node>>,
    /// Memoized [`Self::full`] result.
    root: Option<SignalCoreset>,
    /// Leaf coresets built by this tree (initial build + updates +
    /// pushed bands) — the build-counter the incremental tests assert.
    leaf_builds: usize,
    /// True when the tree holds the single-leaf sequential fallback of
    /// the sharded plan (`shards <= 1` → `construct_with`); updates
    /// then rebuild through the same sequential path so short signals
    /// stay bit-identical to every sharded entry point.
    fallback: bool,
    /// Sharded-build geometry, used by [`Self::update`] re-builds and
    /// the streaming facade's per-band builds.
    shard_rows: usize,
    // --- streaming state (the legacy StreamingCoreset schedule) ---
    rows_seen: usize,
    stream_acc: Option<SignalCoreset>,
    reduce_factor: f64,
    last_reduced_len: usize,
    parts_pushed: usize,
    /// Skip compaction until ≥ 2 parts are absorbed — the pipeline
    /// reducer's degenerate-equivalence invariant (a single band's
    /// coreset is already the batch answer and passes through
    /// unchanged). The legacy streaming schedule compacts from the
    /// first band, so the facade leaves this off.
    first_part_passthrough: bool,
    /// Per-band construction engine of [`Self::push_band`]: `None` =
    /// sequential [`SignalCoreset::construct_with`]; `Some(exec)` = the
    /// sharded builder on that executor (thread/executor-invariant).
    band_exec: Option<Exec<'p>>,
}

impl<'p> MergeTree<'p> {
    /// An empty tree for streaming ingestion ([`Self::push_band`] /
    /// [`Self::push_part`]) over bands of width `m`.
    pub fn for_stream(m: usize, config: CoresetConfig) -> MergeTree<'p> {
        MergeTree {
            m,
            config,
            fanout: 2,
            reduce_tol: None,
            leaves: Vec::new(),
            levels: Vec::new(),
            root: None,
            leaf_builds: 0,
            fallback: false,
            shard_rows: SignalCoreset::SHARD_ROWS,
            rows_seen: 0,
            stream_acc: None,
            reduce_factor: 2.0,
            last_reduced_len: 64,
            parts_pushed: 0,
            first_part_passthrough: false,
            band_exec: None,
        }
    }

    /// Build the tree over `signal` with the exact shard plan of
    /// [`SignalCoreset::construct_sharded_with_stats`]: shards of
    /// `shard_rows` geometry via [`crate::bicriteria::band_edges`],
    /// leaf coresets fanned out on `exec` against the one shared
    /// `stats`. Signals with fewer than two shards take the same
    /// sequential single-leaf fallback as every sharded entry point.
    pub fn build<S: SignalSource>(
        signal: &S,
        stats: &PrefixStats,
        config: CoresetConfig,
        shard_rows: usize,
        exec: Exec<'_>,
    ) -> MergeTree<'p> {
        let shard_rows = shard_rows.max(1);
        let mut tree = Self::for_stream(signal.cols(), config);
        tree.shard_rows = shard_rows;
        tree.rows_seen = signal.rows();
        let n = signal.rows();
        let shards = n / shard_rows;
        if shards <= 1 {
            tree.fallback = true;
            tree.leaves.push(Leaf {
                rect: signal.bounds(),
                part: SignalCoreset::construct_with(signal, config),
            });
        } else {
            let edges = crate::bicriteria::band_edges(n, shards);
            let regions: Vec<Rect> = edges
                .windows(2)
                .map(|w| Rect::new(w[0], w[1] - 1, 0, signal.cols() - 1))
                .collect();
            let parts = exec.map(&regions, |_, &region| {
                SignalCoreset::construct_in(signal, stats, region, config)
            });
            tree.leaves = regions
                .into_iter()
                .zip(parts)
                .map(|(rect, part)| Leaf { rect, part })
                .collect();
        }
        tree.leaf_builds = tree.leaves.len();
        tree.sync_shape();
        tree
    }

    /// Set the internal-node fanout (clamped ≥ 2). Memoization shape
    /// only: [`Self::full`] is bit-identical for every value.
    pub fn with_fanout(mut self, fanout: usize) -> Self {
        self.fanout = fanout.max(2);
        self.levels.clear();
        self.root = None;
        self.sync_shape();
        self
    }

    /// Override the root reduce tolerance (`None` = the standard γ²σ).
    /// A real content knob: changing it changes the compacted root.
    pub fn with_reduce_tol(mut self, tol: Option<f64>) -> Self {
        self.reduce_tol = tol;
        self.root = None;
        self
    }

    /// Streaming compaction factor (the legacy `reduce_factor`).
    pub fn with_reduce_factor(mut self, factor: f64) -> Self {
        self.reduce_factor = factor;
        self
    }

    /// See [`Self::first_part_passthrough`]'s field docs: the pipeline
    /// reducer's "reduce only once composition has happened" guard.
    pub fn with_first_part_passthrough(mut self) -> Self {
        self.first_part_passthrough = true;
        self
    }

    /// Per-band executor for [`Self::push_band`] (the streaming
    /// facade's `with_threads`/`with_exec`).
    pub fn with_band_exec(mut self, exec: Exec<'p>) -> Self {
        self.band_exec = Some(exec);
        self
    }

    /// Row-shard geometry for the sharded per-band path and for
    /// [`Self::update`] re-builds (clamped ≥ 1).
    pub fn with_shard_rows(mut self, shard_rows: usize) -> Self {
        self.shard_rows = shard_rows.max(1);
        self
    }

    pub fn config(&self) -> CoresetConfig {
        self.config
    }

    pub fn cols(&self) -> usize {
        self.m
    }

    /// Rows covered (batch: the signal height; streaming: rows pushed).
    pub fn rows_seen(&self) -> usize {
        self.rows_seen
    }

    pub fn leaf_count(&self) -> usize {
        self.leaves.len()
    }

    /// Shard rectangles of the leaves, in composition order.
    pub fn leaf_rects(&self) -> Vec<Rect> {
        self.leaves.iter().map(|l| l.rect).collect()
    }

    /// Leaf coresets built by this tree so far (initial build + update
    /// re-builds + pushed bands) — the incremental suite's counter.
    pub fn leaf_builds(&self) -> usize {
        self.leaf_builds
    }

    /// Internal levels above the leaves: 0 for ≤ 1 leaf, and at most
    /// ⌈log_fanout S⌉ for S leaves — the logarithmic merge height the
    /// streaming buckets guarantee.
    pub fn height(&self) -> usize {
        self.levels.len()
    }

    /// The root coreset — memoized; see the module docs for the
    /// bit-identity argument. Panics on an empty tree (mirrors
    /// [`merge_reduce::merge`]'s non-empty contract); streaming callers
    /// go through [`Self::into_streamed`], which types the empty case.
    pub fn full(&mut self) -> SignalCoreset {
        if let Some(root) = &self.root {
            return root.clone();
        }
        assert!(!self.leaves.is_empty(), "MergeTree::full on an empty tree");
        let cs = if self.leaves.len() == 1 {
            // The single-shard plan returns the leaf untouched — both
            // the sequential fallback and a lone pushed band (no
            // composition happened, so no reduce may run: the
            // degenerate-equivalence invariant).
            self.leaves[0].part.clone()
        } else {
            let blocks = self.root_blocks();
            // Flat in-order folds over the leaves — exactly what
            // merge() computes on the part list. Folding pairwise up
            // the tree instead would re-associate the f64 σ sum and
            // shift the reduce tolerance by ULPs.
            let n: usize = self.leaves.iter().map(|l| l.part.rows()).sum();
            let sigma: f64 = self.leaves.iter().map(|l| l.part.sigma).sum();
            let gamma = self
                .leaves
                .iter()
                .map(|l| l.part.gamma)
                .fold(f64::INFINITY, f64::min);
            let config = self.leaves[0].part.config;
            let merged = SignalCoreset::from_blocks(n, self.m, config, sigma, gamma, blocks);
            let tol = self
                .reduce_tol
                .unwrap_or(merged.gamma * merged.gamma * merged.sigma);
            merge_reduce::reduce(merged, tol)
        };
        self.root = Some(cs.clone());
        cs
    }

    /// Rebuild exactly the leaves intersecting `dirty` against the
    /// *post-edit* `signal`/`stats` (the caller must refresh the shared
    /// statistics first — they are O(N) prefix sums of the mutated
    /// frame), fanned out on `exec`, then invalidate the O(log S)
    /// ancestor path. Returns the number of leaves rebuilt.
    pub fn update<S: SignalSource>(
        &mut self,
        dirty: Rect,
        signal: &S,
        stats: &PrefixStats,
        exec: Exec<'_>,
    ) -> usize {
        self.update_dirty(&[dirty], signal, stats, exec)
    }

    /// [`Self::update`] over a batch of dirty rectangles: each affected
    /// leaf is rebuilt once even when several rectangles hit it.
    pub fn update_dirty<S: SignalSource>(
        &mut self,
        dirty: &[Rect],
        signal: &S,
        stats: &PrefixStats,
        exec: Exec<'_>,
    ) -> usize {
        let hit: Vec<usize> = self
            .leaves
            .iter()
            .enumerate()
            .filter(|(_, l)| dirty.iter().any(|d| l.rect.intersects(d)))
            .map(|(i, _)| i)
            .collect();
        if hit.is_empty() {
            return 0;
        }
        if self.fallback {
            // Sequential single-leaf plan: rebuild through the same
            // fresh-sequential-stats path construct_sharded_* falls
            // back to, so the updated tree still agrees bitwise with a
            // from-scratch short-signal build.
            self.leaves[0].part = SignalCoreset::construct_with(signal, self.config);
        } else {
            let regions: Vec<Rect> = hit.iter().map(|&i| self.leaves[i].rect).collect();
            let parts = exec.map(&regions, |_, &region| {
                SignalCoreset::construct_in(signal, stats, region, self.config)
            });
            for (&i, part) in hit.iter().zip(parts) {
                self.leaves[i].part = part;
            }
        }
        self.leaf_builds += hit.len();
        // The incrementally-compacted streaming accumulator no longer
        // reflects the leaves; drop it ([`Self::into_streamed`] falls
        // back to the root view).
        self.stream_acc = None;
        self.invalidate_paths(&hit);
        hit.len()
    }

    /// Streaming append: build the band's coreset (sequentially, or
    /// sharded on [`Self::with_band_exec`]'s executor), translate it to
    /// global rows, append it as a leaf, and run the legacy
    /// incremental-compaction schedule on the streamed accumulator.
    pub fn push_band<S: SignalSource>(&mut self, band: &S) {
        assert_eq!(band.cols(), self.m, "band width must match the stream");
        let part = match self.band_exec {
            None => SignalCoreset::construct_with(band, self.config),
            Some(exec) => {
                SignalCoreset::construct_sharded_exec(band, self.config, self.shard_rows, exec)
            }
        };
        let part = translate_rows(part, self.rows_seen);
        let rect = Rect::new(
            self.rows_seen,
            self.rows_seen + band.rows() - 1,
            0,
            self.m - 1,
        );
        self.rows_seen += band.rows();
        self.leaf_builds += 1;
        self.absorb(rect, part);
    }

    /// Append an externally built part covering `rect` (global
    /// coordinates, width `m`) — the pipeline reducer's entry point.
    /// Returns true when the streamed accumulator was compacted by this
    /// push (the reducer's `record_reduce` metric).
    pub fn push_part(&mut self, rect: Rect, part: SignalCoreset) -> bool {
        self.rows_seen += part.rows();
        self.absorb(rect, part)
    }

    /// The incrementally-compacted streaming view (the legacy
    /// `StreamingCoreset` accumulator): present after pushes, dropped
    /// by [`Self::update_dirty`].
    pub fn streamed(&self) -> Option<&SignalCoreset> {
        self.stream_acc.as_ref()
    }

    /// Finish a stream: the compacted accumulator when it is current,
    /// the root view after updates, and a typed error for the empty
    /// stream (the case the old `Option` return leaked to callers).
    pub fn into_streamed(mut self) -> Result<SignalCoreset> {
        if let Some(acc) = self.stream_acc.take() {
            return Ok(acc);
        }
        if self.leaves.is_empty() {
            return Err(Error::msg("empty stream: no bands were pushed"));
        }
        Ok(self.full())
    }

    /// The shared absorb step of [`Self::push_band`] /
    /// [`Self::push_part`]: legacy accumulator schedule + leaf append.
    fn absorb(&mut self, rect: Rect, part: SignalCoreset) -> bool {
        self.parts_pushed += 1;
        let merged = match self.stream_acc.take() {
            None => part.clone(),
            Some(acc) => merge_reduce::merge(vec![acc, part.clone()]),
        };
        let gate = !self.first_part_passthrough || self.parts_pushed > 1;
        let mut compacted = false;
        let merged = if gate
            && merged.blocks.len() as f64 > self.reduce_factor * self.last_reduced_len as f64
        {
            let tol = merged.gamma * merged.gamma * merged.sigma;
            let reduced = merge_reduce::reduce(merged, tol);
            self.last_reduced_len = reduced.blocks.len().max(64);
            compacted = true;
            reduced
        } else {
            merged
        };
        self.stream_acc = Some(merged);
        self.leaves.push(Leaf { rect, part });
        let appended = self.leaves.len() - 1;
        self.invalidate_paths(&[appended]);
        compacted
    }

    /// Reconcile the level structure with the current leaf count:
    /// resize every level, and mark any node whose expected child count
    /// changed (appends grow the last node of each level) as stale.
    fn sync_shape(&mut self) {
        let mut sizes = Vec::new();
        let mut len = self.leaves.len();
        while len > 1 {
            len = len.div_ceil(self.fanout);
            sizes.push(len);
        }
        self.levels.truncate(sizes.len());
        for (lvl, &size) in sizes.iter().enumerate() {
            let prev_len = if lvl == 0 { self.leaves.len() } else { sizes[lvl - 1] };
            if self.levels.len() <= lvl {
                self.levels.push(Vec::new());
            }
            let fanout = self.fanout;
            let nodes = &mut self.levels[lvl];
            nodes.resize_with(size, || Node { blocks: None, children: 0 });
            for (i, node) in nodes.iter_mut().enumerate() {
                let kids = (prev_len - i * fanout).min(fanout);
                if node.children != kids {
                    node.children = kids;
                    node.blocks = None;
                }
            }
        }
    }

    /// Invalidate the memoized root and the ancestor path of every
    /// given leaf index — O(dirty · height) node marks.
    fn invalidate_paths(&mut self, leaf_indices: &[usize]) {
        self.root = None;
        self.sync_shape();
        for &leaf in leaf_indices {
            let mut idx = leaf;
            for lvl in 0..self.levels.len() {
                idx /= self.fanout;
                self.levels[lvl][idx].blocks = None;
            }
        }
    }

    /// Recompute every stale node bottom-up and return the root
    /// concatenation (leaf order preserved at every level).
    fn root_blocks(&mut self) -> Vec<BlockCoreset> {
        self.sync_shape();
        if self.levels.is_empty() {
            return self
                .leaves
                .first()
                .map(|l| l.part.blocks.clone())
                .unwrap_or_default();
        }
        let fanout = self.fanout;
        for lvl in 0..self.levels.len() {
            let (lower, upper) = self.levels.split_at_mut(lvl);
            let prev: &[Node] = lower.last().map(|v| v.as_slice()).unwrap_or(&[]);
            for (i, node) in upper[0].iter_mut().enumerate() {
                if node.blocks.is_some() {
                    continue;
                }
                let lo = i * fanout;
                let mut blocks = Vec::new();
                for j in lo..lo + node.children {
                    if lvl == 0 {
                        blocks.extend_from_slice(&self.leaves[j].part.blocks);
                    } else {
                        // lint:allow(panic) -- levels refresh bottom-up, so
                        // every child at lvl-1 was filled by the previous
                        // iteration of this loop.
                        blocks.extend_from_slice(prev[j].blocks.as_deref().unwrap());
                    }
                }
                node.blocks = Some(blocks);
            }
        }
        self.levels
            .last()
            .and_then(|lvl| lvl.first())
            .and_then(|n| n.blocks.clone())
            // lint:allow(panic) -- the loop above just refreshed every
            // node, including the root, and `levels` is non-empty here.
            .expect("root node refreshed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coreset::Coreset;
    use crate::rng::Rng;
    use crate::signal::{generate, Signal, SignalView};

    fn assert_bitwise(a: &SignalCoreset, b: &SignalCoreset, ctx: &str) {
        assert_eq!(a.blocks.len(), b.blocks.len(), "{ctx}: block count");
        for (x, y) in a.blocks.iter().zip(&b.blocks) {
            assert_eq!(x.rect, y.rect, "{ctx}");
            assert_eq!(x.labels, y.labels, "{ctx}");
            assert_eq!(x.weights, y.weights, "{ctx}");
        }
    }

    fn band_split(sig: &Signal, bands: usize) -> Vec<SignalView<'_>> {
        let edges = crate::bicriteria::band_edges(sig.rows(), bands);
        edges
            .windows(2)
            .map(|w| sig.view(Rect::new(w[0], w[1] - 1, 0, sig.cols() - 1)))
            .collect()
    }

    /// Folded in from the old `offset_rows` unit coverage: translation
    /// shifts every block rect by the row offset and nothing else.
    #[test]
    fn translate_rows_shifts_blocks_only() {
        let mut rng = Rng::new(60);
        let sig = generate::smooth(24, 16, 3, &mut rng);
        let cs = SignalCoreset::construct(&sig, 3, 0.3);
        let shifted = translate_rows(cs.clone(), 100);
        assert_eq!(shifted.blocks.len(), cs.blocks.len());
        for (a, b) in shifted.blocks.iter().zip(&cs.blocks) {
            assert_eq!(a.rect.r0, b.rect.r0 + 100);
            assert_eq!(a.rect.r1, b.rect.r1 + 100);
            assert_eq!(a.rect.c0, b.rect.c0);
            assert_eq!(a.rect.c1, b.rect.c1);
            assert_eq!(a.labels, b.labels);
            assert_eq!(a.weights, b.weights);
        }
        assert_eq!(shifted.rows(), cs.rows());
        assert!((shifted.total_weight() - cs.total_weight()).abs() < 1e-12);
    }

    #[test]
    fn full_matches_construct_sharded_bitwise() {
        let mut rng = Rng::new(61);
        let sig = generate::smooth(256, 40, 3, &mut rng);
        let config = CoresetConfig::new(4, 0.3);
        let reference = SignalCoreset::construct_sharded(&sig, config, 1);
        let stats = PrefixStats::new(&sig);
        for fanout in [2, 3, 5] {
            let mut tree = MergeTree::build(&sig, &stats, config, 64, Exec::Spawn(1))
                .with_fanout(fanout);
            assert_bitwise(&tree.full(), &reference, &format!("fanout {fanout}"));
            // Memoized second call is identical.
            assert_bitwise(&tree.full(), &reference, "memoized root");
        }
    }

    #[test]
    fn single_shard_fallback_matches_sequential_build() {
        let mut rng = Rng::new(62);
        let sig = generate::image_like(90, 24, 2, &mut rng);
        let config = CoresetConfig::new(3, 0.3);
        let stats = PrefixStats::new(&sig);
        let mut tree = MergeTree::build(&sig, &stats, config, 64, Exec::Spawn(1));
        assert_eq!(tree.leaf_count(), 1);
        assert_eq!(tree.height(), 0);
        let reference = SignalCoreset::construct_with(&sig, config);
        assert_bitwise(&tree.full(), &reference, "fallback");
    }

    #[test]
    fn update_rebuilds_only_intersecting_leaves() {
        let mut rng = Rng::new(63);
        let mut sig = generate::smooth(256, 32, 3, &mut rng);
        let config = CoresetConfig::new(4, 0.3);
        let stats = PrefixStats::new(&sig);
        let mut tree = MergeTree::build(&sig, &stats, config, 64, Exec::Spawn(1));
        let leaves = tree.leaf_count();
        assert!(leaves >= 4, "{leaves} leaves");
        assert_eq!(tree.leaf_builds(), leaves);
        // Edit one tile inside the first shard only.
        let dirty = Rect::new(2, 9, 3, 12);
        for (r, c) in dirty.cells() {
            sig.set(r, c, 42.0);
        }
        let stats = PrefixStats::new(&sig);
        let rebuilt = tree.update(dirty, &sig, &stats, Exec::Spawn(1));
        assert_eq!(rebuilt, 1, "one leaf intersects the dirty tile");
        assert_eq!(tree.leaf_builds(), leaves + 1);
        // The updated root still covers the mutated signal exactly.
        let cs = tree.full();
        let cells = (256 * 32) as f64;
        assert!((cs.total_weight() - cells).abs() < 1e-6 * cells);
        // A clean update is free.
        assert_eq!(tree.update(dirty, &sig, &stats, Exec::Spawn(1)), 1);
        let far = Rect::new(0, 0, 0, 0);
        let hit = tree.update(far, &sig, &stats, Exec::Spawn(2));
        assert_eq!(hit, 1, "corner cell lives in the first shard");
    }

    #[test]
    fn streamed_accumulator_matches_legacy_schedule() {
        // The tree's push_band accumulator replays the historical
        // StreamingCoreset fold bit-for-bit.
        let mut rng = Rng::new(64);
        let sig = generate::smooth(96, 20, 3, &mut rng);
        let config = CoresetConfig::new(3, 0.3);
        let mut tree = MergeTree::for_stream(20, config);
        let mut acc: Option<SignalCoreset> = None;
        let mut last_reduced = 64usize;
        let mut rows = 0usize;
        for band in band_split(&sig, 6) {
            tree.push_band(&band);
            // Inline legacy schedule.
            let part = translate_rows(SignalCoreset::construct_with(&band, config), rows);
            rows += band.rows();
            let merged = match acc.take() {
                None => part,
                Some(a) => merge_reduce::merge(vec![a, part]),
            };
            let merged = if merged.blocks.len() as f64 > 2.0 * last_reduced as f64 {
                let tol = merged.gamma * merged.gamma * merged.sigma;
                let reduced = merge_reduce::reduce(merged, tol);
                last_reduced = reduced.blocks.len().max(64);
                reduced
            } else {
                merged
            };
            acc = Some(merged);
        }
        assert_eq!(tree.rows_seen(), 96);
        assert_eq!(tree.leaf_count(), 6);
        let got = tree.into_streamed().unwrap();
        assert_bitwise(&got, &acc.unwrap(), "streamed vs legacy fold");
    }

    #[test]
    fn height_stays_logarithmic_under_pushes() {
        let mut rng = Rng::new(65);
        let sig = generate::smooth(132, 12, 2, &mut rng);
        let config = CoresetConfig::new(2, 0.4);
        let mut tree = MergeTree::for_stream(12, config);
        let mut r0 = 0;
        let mut pushes = 0usize;
        while r0 < 132 {
            let band = sig.view(Rect::new(r0, (r0 + 3).min(131), 0, 11));
            tree.push_band(&band);
            r0 += 4;
            pushes += 1;
            let bound = (0usize..)
                .find(|h| 2usize.pow(*h as u32) >= pushes)
                .unwrap();
            assert!(
                tree.height() <= bound,
                "height {} > ceil(log2 {pushes}) = {bound}",
                tree.height()
            );
        }
        assert_eq!(pushes, 33);
        assert_eq!(tree.leaf_count(), 33);
        assert_eq!(tree.height(), 6);
    }

    #[test]
    fn empty_stream_is_a_typed_error() {
        let tree: MergeTree<'_> = MergeTree::for_stream(8, CoresetConfig::new(2, 0.3));
        let err = tree.into_streamed().unwrap_err();
        assert!(err.to_string().contains("empty stream"), "{err}");
    }
}
