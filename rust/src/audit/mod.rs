//! Empirical ε-guarantee audit engine.
//!
//! The paper's headline claim (Theorem 8) is universal: the coreset C
//! approximates the loss of **every** k-segmentation of the signal D
//! within 1 ± ε, and therefore the optimal tree of C transfers to D at
//! (1 + ε)-cost. This module turns that claim from prose into an
//! executable, machine-readable gate: an [`AuditConfig`]-driven engine
//! that sweeps structured families of k-segmentations against
//! freshly built coresets, measures the empirical relative error of
//! FITTING-LOSS per family, runs the optimal-tree-transfer check on
//! DP-feasible instances, and emits an [`AuditReport`] with a hand-rolled
//! JSON evidence trail ([`json`]) plus a pass/fail verdict.
//!
//! Query families (Bachem–Lucic–Krause's point that coreset
//! implementations must be validated by empirical relative-error sweeps,
//! not spot checks):
//!
//! * **block-aligned** — one piece per coreset partition block. Every
//!   piece is Case (i) of Algorithm 5, so the evaluation must be *exact*
//!   (the accurate-coreset criterion of Jubran–Maalouf–Feldman: ε ≈ 0
//!   for within-block queries) — gated at 1e-6, not at ε.
//! * **random** — random guillotine k-trees
//!   ([`crate::segmentation::random_segmentation`]), mean-refit.
//! * **ground-truth** — the planted segmentation of
//!   [`generate::piecewise_constant`] signals, raw and refit.
//! * **degenerate** — k = 1, row strips, column strips
//!   ([`crate::segmentation::strip_segmentation`]).
//! * **boundary-adversarial** — guillotine trees whose cuts snap onto the
//!   coreset's partition-block edges and are then jittered ±1
//!   ([`crate::segmentation::boundary_adversarial_segmentation`]): thin
//!   slivers straddling block boundaries, the worst Case (ii) regime.
//! * **dp-optimal** — exact optimal trees from
//!   [`crate::segmentation::dp2d::TreeDP`] on small instances, for both D
//!   and C, plus the transfer check
//!   `loss_D(opt_C) ≤ (1+ε)/(1−ε) · loss_D(opt_D)`.
//! * **noise-informational** — the same sweeps on pure-noise signals,
//!   *measured but not gated*: the practical γ = ε/2 calibration is
//!   certified for the smooth/image/piecewise families only
//!   (EXPERIMENTS.md §Calibration); noise is the paper's own worst-case
//!   regime.
//! * **incremental-update** — coresets produced by a seeded sequence of
//!   rect edits applied through [`crate::coreset::merge_tree::MergeTree::update`]
//!   (dirty leaves rebuilt, ancestor path re-merged) must satisfy the
//!   same ε guarantee against the *mutated* signal's true losses as a
//!   from-scratch rebuild — the merge-and-reduce property under
//!   mutation, gated at ε like the main sweep.
//! * **sensitivity-sampling** — importance-sampling coresets
//!   ([`crate::sample::SensitivityCoreset`], both the `unified` and the
//!   `lightweight` sensitivity algorithms) swept against the same query
//!   classes. Their guarantee is probabilistic, not worst-case, so the
//!   family aggregate is measured-not-gated like noise-informational;
//!   each instance still carries its own *probabilistic gate* (exact
//!   weight parity plus generous error ceilings that hold with
//!   overwhelming margin at the audited τ = half the present cells) and
//!   a red instance fails the audit.
//!
//! True loss is computed from [`PrefixStats`] regions
//! (`KSegmentation::loss`), coreset loss through the batch FITTING-LOSS
//! API; cases and transfer instances fan out on the [`crate::par`] worker
//! pool, each case deriving its own seed so any thread count produces the
//! bit-identical report. A violated gate is handed to
//! [`crate::proptest::run_sized`], which greedily shrinks the failing
//! case to a minimal reproducible (signal, tree, seed) triple recorded in
//! the report.

pub use crate::json;

use crate::coreset::fitting_loss::relative_error;
use crate::coreset::SignalCoreset;
use crate::proptest;
use crate::rng::Rng;
use crate::segmentation::dp2d::{RectOracle, TreeDP};
use crate::segmentation::{
    boundary_adversarial_segmentation, random_segmentation, strip_segmentation, KSegmentation,
};
use crate::signal::stats::{self, Moments};
use crate::signal::{generate, PrefixStats, Rect, Signal};

use crate::json::Json;

/// Generator size range of the audited signals (rows; columns are ≈ ⅔):
/// small enough that a 25-case sweep stays CI-cheap, large enough that
/// partitions have non-trivial block structure.
const MIN_SIZE: usize = 12;
const MAX_SIZE: usize = 72;

/// Audit parameters. `seed` doubles as the base of the
/// [`proptest::sized_case_seed`] space, so a CLI sweep, a shrunk repro,
/// and a test-suite replay all address the same deterministic cases.
#[derive(Clone, Copy, Debug)]
pub struct AuditConfig {
    pub k: usize,
    pub eps: f64,
    /// Number of audited (signal, coreset) cases.
    pub cases: usize,
    pub seed: u64,
    /// Worker threads for the case/transfer fan-out (0 = all cores).
    pub threads: usize,
    /// DP-feasible optimal-tree-transfer instances (min 3).
    pub transfer_instances: usize,
    /// `Some(block)` builds every per-case [`PrefixStats`] through the
    /// cache-blocked fill ([`PrefixStats::new_blocked`]) — bit-identical
    /// to the scalar fill for every block width, so the evidence trail
    /// is unchanged; this is how the `blocked` engine backend audits
    /// through its own code path end-to-end.
    pub stats_block: Option<usize>,
}

impl AuditConfig {
    pub fn new(k: usize, eps: f64) -> Self {
        assert!(k >= 1);
        assert!(eps > 0.0 && eps < 1.0, "eps must be in (0,1)");
        Self { k, eps, cases: 25, seed: 7, threads: 1, transfer_instances: 4, stats_block: None }
    }

    pub fn with_cases(mut self, cases: usize) -> Self {
        self.cases = cases.max(1);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn with_transfer_instances(mut self, instances: usize) -> Self {
        self.transfer_instances = instances.max(3);
        self
    }

    pub fn with_stats_block(mut self, block: Option<usize>) -> Self {
        self.stats_block = block;
        self
    }

    /// Per-case exact statistics through the configured fill: scalar by
    /// default, cache-blocked when [`Self::stats_block`] is set. Both
    /// fills are bit-identical (DESIGN.md §Kernels), so the audit's
    /// verdicts cannot depend on the choice — but the blocked engine
    /// path genuinely executes its own kernels under audit.
    fn stats_for(&self, signal: &Signal) -> PrefixStats {
        match self.stats_block {
            None => PrefixStats::new(signal),
            Some(block) => PrefixStats::new_blocked(signal, 1, block),
        }
    }
}

/// The audited query families.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Family {
    BlockAligned,
    Random,
    GroundTruth,
    Degenerate,
    Boundary,
    DpOptimal,
    NoiseInformational,
    Incremental,
    Sensitivity,
}

impl Family {
    pub const ALL: [Family; 9] = [
        Family::BlockAligned,
        Family::Random,
        Family::GroundTruth,
        Family::Degenerate,
        Family::Boundary,
        Family::DpOptimal,
        Family::NoiseInformational,
        Family::Incremental,
        Family::Sensitivity,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Family::BlockAligned => "block-aligned",
            Family::Random => "random",
            Family::GroundTruth => "ground-truth",
            Family::Degenerate => "degenerate",
            Family::Boundary => "boundary-adversarial",
            Family::DpOptimal => "dp-optimal",
            Family::NoiseInformational => "noise-informational",
            Family::Incremental => "incremental-update",
            Family::Sensitivity => "sensitivity-sampling",
        }
    }

    /// Maximum tolerated empirical relative error; `None` = measured but
    /// not gated. Block-aligned queries are Case (i) everywhere, so they
    /// gate at the accurate-coreset bar (ε ≈ 0), not at the configured ε.
    /// Sensitivity sampling carries only a probabilistic guarantee, so
    /// its family aggregate is measured here and gated per-instance by
    /// [`SensitivityCheck`] instead.
    pub fn threshold(self, eps: f64) -> Option<f64> {
        match self {
            Family::BlockAligned => Some(1e-6),
            Family::NoiseInformational | Family::Sensitivity => None,
            _ => Some(eps),
        }
    }
}

// ---------------------------------------------------------------------------
// Coreset density oracle: the DP's view of the coreset.
// ---------------------------------------------------------------------------

/// Prefix statistics of the *smoothed coreset density*: every cell of a
/// partition block carries `count/area` weight at the block's label mean
/// μ with per-unit variance `opt₁/count`. Under this density the loss of
/// fitting a constant v to any rectangle R is exactly Algorithm 5's
/// pro-rata evaluation — Σ_B z_B(R)·[(v − μ_B)² + var_B] — so running
/// [`TreeDP`] on this oracle finds the **exact minimizer of
/// FITTING-LOSS over guillotine k-trees**: the paper's "run the
/// expensive solver on the coreset", and the `opt_C` of the audit's
/// optimal-tree-transfer check.
#[derive(Clone, Debug)]
pub struct CoresetOracle {
    n: usize,
    m: usize,
    /// (m+1)-stride padded prefix arrays, like [`PrefixStats`].
    w: Vec<f64>,
    wy: Vec<f64>,
    wy2: Vec<f64>,
    /// Per-cell irreducible loss w·var — the saturated (one leaf per
    /// cell) floor the smoothing can never go below.
    irr: Vec<f64>,
}

impl CoresetOracle {
    pub fn new(cs: &SignalCoreset) -> Self {
        let (n, m) = (cs.rows(), cs.cols());
        let mut w_cell = vec![0.0f64; n * m];
        let mut wy_cell = vec![0.0f64; n * m];
        let mut wy2_cell = vec![0.0f64; n * m];
        let mut irr_cell = vec![0.0f64; n * m];
        for b in &cs.blocks {
            let mom = b.moments();
            if mom.count <= 0.0 {
                continue;
            }
            let per_cell = mom.count / b.rect.area() as f64;
            let mu = mom.mean();
            let var = mom.opt1() / mom.count;
            // Row-range scatter: one contiguous slice per signal row
            // instead of per-cell index arithmetic — the same constants
            // land on the same cells, but each array is walked in
            // vectorizable runs (the grids then feed the blocked
            // two-pass `padded_prefix_from_cells` below).
            let wy_add = per_cell * mu;
            let wy2_add = per_cell * (mu * mu + var);
            let irr_add = per_cell * var;
            for r in b.rect.r0..=b.rect.r1 {
                let span = r * m + b.rect.c0..r * m + b.rect.c1 + 1;
                for w in &mut w_cell[span.clone()] {
                    *w += per_cell;
                }
                for wy in &mut wy_cell[span.clone()] {
                    *wy += wy_add;
                }
                for wy2 in &mut wy2_cell[span.clone()] {
                    *wy2 += wy2_add;
                }
                for irr in &mut irr_cell[span] {
                    *irr += irr_add;
                }
            }
        }
        Self {
            n,
            m,
            w: stats::padded_prefix_from_cells(n, m, &w_cell),
            wy: stats::padded_prefix_from_cells(n, m, &wy_cell),
            wy2: stats::padded_prefix_from_cells(n, m, &wy2_cell),
            irr: stats::padded_prefix_from_cells(n, m, &irr_cell),
        }
    }

    #[inline]
    fn query(&self, arr: &[f64], rect: &Rect) -> f64 {
        stats::padded_prefix_query(arr, self.m, rect)
    }

    /// The density's (mass, Σwy, Σwy²) over `rect` — what FITTING-LOSS's
    /// pro-rata Case (ii) charges a piece covering `rect`.
    pub fn moments(&self, rect: &Rect) -> Moments {
        debug_assert!(rect.r1 < self.n && rect.c1 < self.m, "rect out of bounds");
        Moments {
            count: self.query(&self.w, rect),
            sum: self.query(&self.wy, rect),
            sum_sq: self.query(&self.wy2, rect),
        }
    }
}

impl RectOracle for CoresetOracle {
    fn opt1(&self, rect: &Rect) -> f64 {
        self.moments(rect).opt1()
    }

    fn mean(&self, rect: &Rect) -> f64 {
        self.moments(rect).mean()
    }

    fn saturated(&self, rect: &Rect) -> f64 {
        self.query(&self.irr, rect).max(0.0)
    }
}

// ---------------------------------------------------------------------------
// Audited case: one signal + coreset + its query sweep.
// ---------------------------------------------------------------------------

/// One audited case: a generated signal, its coreset, and the structured
/// query sweep. Generated entirely from an `(rng, size)` pair so the
/// engine's sweep and [`proptest`]'s shrinking address identical cases.
#[derive(Debug)]
pub struct AuditCase {
    pub config: AuditConfig,
    pub kind: &'static str,
    pub signal: Signal,
    pub stats: PrefixStats,
    pub coreset: SignalCoreset,
    pub families: Vec<Family>,
    pub queries: Vec<KSegmentation>,
}

impl AuditCase {
    /// Generate the case for `(rng, size)`: the signal kind rotates with
    /// `size % 4` (piecewise / smooth / image / noise), the query sweep
    /// is drawn from `rng`. On noise signals every approximate family is
    /// tagged [`Family::NoiseInformational`] (measured, not gated); the
    /// block-aligned exactness invariant is signal-independent and stays
    /// gated.
    pub fn generate(rng: &mut Rng, size: usize, config: &AuditConfig) -> AuditCase {
        let n = size.clamp(MIN_SIZE, 4 * MAX_SIZE);
        let m = (n * 2 / 3).max(MIN_SIZE);
        let k = config.k;
        let (kind, signal, planted) = match size % 4 {
            0 => {
                let (sig, pieces) =
                    generate::piecewise_constant(n, m, k.min(n * m / 4).max(1), 0.1, rng);
                ("piecewise", sig, Some(pieces))
            }
            1 => ("smooth", generate::smooth(n, m, 3, rng), None),
            2 => ("image", generate::image_like(n, m, 2, rng), None),
            _ => ("noise", generate::noise(n, m, 1.0, rng), None),
        };
        let stats = config.stats_for(&signal);
        let coreset = SignalCoreset::construct(&signal, k, config.eps);
        let (families, queries) = build_queries(
            signal.bounds(),
            &stats,
            &coreset,
            planted.as_deref(),
            k,
            kind == "noise",
            rng,
        );
        AuditCase { config: *config, kind, signal, stats, coreset, families, queries }
    }

    /// Evaluate the sweep: (family, empirical relative error) per query.
    /// True loss from [`PrefixStats`] regions, coreset loss through the
    /// batch FITTING-LOSS API (`threads` workers on the par pool).
    pub fn samples(&self, threads: usize) -> Vec<(Family, f64)> {
        let approx = self.coreset.fitting_loss_batch(&self.queries, threads);
        self.families
            .iter()
            .zip(self.queries.iter().zip(approx))
            .map(|(&family, (q, a))| (family, relative_error(a, q.loss(&self.stats))))
            .collect()
    }

    /// The property the shrink hook minimizes: every gated family within
    /// its threshold.
    pub fn check(&self) -> crate::error::Result<()> {
        for (family, err) in self.samples(1) {
            if let Some(threshold) = family.threshold(self.config.eps) {
                if err > threshold {
                    crate::bail!(
                        "family {} rel err {err:.4} > {threshold} on {} {}x{} (k={}, eps={})",
                        family.name(),
                        self.kind,
                        self.signal.rows(),
                        self.signal.cols(),
                        self.config.k,
                        self.config.eps,
                    );
                }
            }
        }
        Ok(())
    }
}

/// The structured query sweep for one (signal, coreset) pair. Takes the
/// signal's bounding rectangle rather than the signal itself so the
/// masked-signal and zero-copy view suites can audit any
/// [`crate::signal::SignalSource`] they built stats/coresets from.
pub fn build_queries(
    bounds: Rect,
    stats: &PrefixStats,
    coreset: &SignalCoreset,
    planted: Option<&[(Rect, f64)]>,
    k: usize,
    noise_signal: bool,
    rng: &mut Rng,
) -> (Vec<Family>, Vec<KSegmentation>) {
    let mut families = Vec::new();
    let mut queries = Vec::new();
    let approx_family = |f: Family| if noise_signal { Family::NoiseInformational } else { f };
    let refit = |mut s: KSegmentation| {
        s.refit_values(stats);
        s
    };

    // Block-aligned: one piece per partition block, mean-valued — Case (i)
    // everywhere, must be exact regardless of the signal.
    families.push(Family::BlockAligned);
    queries.push(KSegmentation::new(
        coreset
            .blocks
            .iter()
            .map(|b| (b.rect, stats.mean(&b.rect)))
            .collect(),
    ));

    // Random guillotine k-trees, mean-refit (the tree-learner class).
    for _ in 0..3 {
        families.push(approx_family(Family::Random));
        queries.push(refit(random_segmentation(bounds, k, rng)));
    }

    // Ground-truth-aligned trees (piecewise signals only): the planted
    // segmentation raw and refit.
    if let Some(pieces) = planted {
        families.push(approx_family(Family::GroundTruth));
        queries.push(KSegmentation::new(pieces.to_vec()));
        families.push(approx_family(Family::GroundTruth));
        queries.push(refit(KSegmentation::new(pieces.to_vec())));
    }

    // Degenerate trees: k = 1, row strips, column strips.
    families.push(approx_family(Family::Degenerate));
    queries.push(KSegmentation::constant(bounds, stats.mean(&bounds)));
    families.push(approx_family(Family::Degenerate));
    queries.push(refit(strip_segmentation(bounds, k, true)));
    families.push(approx_family(Family::Degenerate));
    queries.push(refit(strip_segmentation(bounds, k, false)));

    // Boundary-adversarial trees: cuts snapped to the coreset's block
    // edges, jittered ±1.
    let (row_edges, col_edges) = coreset.block_edges();
    for _ in 0..2 {
        families.push(approx_family(Family::Boundary));
        queries.push(refit(boundary_adversarial_segmentation(
            bounds, k, &row_edges, &col_edges, rng,
        )));
    }

    (families, queries)
}

// ---------------------------------------------------------------------------
// Transfer check: the optimal tree of C transfers to D.
// ---------------------------------------------------------------------------

/// One DP-feasible optimal-tree-transfer instance:
/// `loss_D(opt_C) ≤ (1+ε)/(1−ε) · loss_D(opt_D)` (Theorem 8's
/// consequence, the reason a coreset is useful at all).
#[derive(Clone, Debug)]
pub struct TransferCheck {
    pub rows: usize,
    pub cols: usize,
    pub k: usize,
    pub kind: &'static str,
    pub seed: u64,
    /// loss_D(opt_D): the exact optimum of the signal.
    pub opt_d: f64,
    /// FITTING-LOSS_C(opt_C): the DP optimum over the coreset density.
    pub opt_c_fitting: f64,
    /// loss_D(opt_C): the coreset's optimal tree, evaluated on the signal.
    pub loss_d_of_opt_c: f64,
    /// (1+ε)/(1−ε) · opt_D (plus numeric slack) — the transfer bound.
    pub bound: f64,
    pub pass: bool,
    /// Empirical rel. errors of FITTING-LOSS on opt_D and opt_C — the
    /// dp-optimal query family's samples from this instance.
    pub rel_err_opt_d: f64,
    pub rel_err_opt_c: f64,
}

/// Fixed DP-feasible shapes (all ≤ 32×32 — the "run the solver on the
/// coreset" regime the DP module documents). The default 4 instances use
/// the smallest shapes so the exact DP stays cheap even in debug test
/// runs; `--transfer-instances 5+` reaches the larger ones.
const TRANSFER_SHAPES: [(usize, usize); 6] =
    [(12, 12), (14, 12), (12, 14), (14, 14), (20, 16), (24, 24)];

fn transfer_check(config: &AuditConfig, instance: usize) -> TransferCheck {
    // Distinct seed stream from the case sweep (same base seed).
    let seed = proptest::sized_case_seed(config.seed ^ 0x0D07_AB1E, instance);
    let mut rng = Rng::new(seed);
    let (n, m) = TRANSFER_SHAPES[instance % TRANSFER_SHAPES.len()];
    // DP feasibility clamp: the exact solver is exponential-ish in k on
    // these shapes. The per-instance `k` field records the value actually
    // certified, and `summary()` flags the substitution when it differs
    // from the configured k.
    let k = config.k.clamp(2, 6);
    let (kind, signal) = match instance % 3 {
        0 => ("piecewise", generate::piecewise_constant(n, m, k, 0.1, &mut rng).0),
        1 => ("smooth", generate::smooth(n, m, 3, &mut rng)),
        _ => ("image", generate::image_like(n, m, 2, &mut rng)),
    };
    let stats = config.stats_for(&signal);
    let coreset = SignalCoreset::construct(&signal, k, config.eps);
    let bounds = signal.bounds();

    let mut dp_d = TreeDP::new(&stats);
    let opt_d = dp_d.opt(bounds, k);
    let s_d = dp_d.solve(bounds, k);

    let oracle = CoresetOracle::new(&coreset);
    let mut dp_c = TreeDP::new(&oracle);
    let opt_c_fitting = dp_c.opt(bounds, k);
    let s_c = dp_c.solve(bounds, k);

    let loss_d_of_opt_c = s_c.loss(&stats);
    let slack = 1e-9 * (1.0 + stats.sum_sq(&bounds).abs());
    let bound = (1.0 + config.eps) / (1.0 - config.eps) * opt_d + slack;

    // The dp-optimal family's ε samples: FITTING-LOSS vs true loss on
    // both optimal trees — measured against each reconstructed tree's
    // own exact loss, so a numerically ambiguous reconstruction cannot
    // skew the measurement.
    let exact_d = s_d.loss(&stats);
    let fits = coreset.fitting_loss_batch(&[s_d, s_c], 1);

    TransferCheck {
        rows: n,
        cols: m,
        k,
        kind,
        seed,
        opt_d,
        opt_c_fitting,
        loss_d_of_opt_c,
        bound,
        pass: loss_d_of_opt_c <= bound,
        rel_err_opt_d: relative_error(fits[0], exact_d),
        rel_err_opt_c: relative_error(fits[1], loss_d_of_opt_c),
    }
}

// ---------------------------------------------------------------------------
// Incremental-update check: the guarantee survives tree mutation.
// ---------------------------------------------------------------------------

/// One incremental-update instance: a seeded sequence of rect edits is
/// applied to the signal, the merge tree is updated *incrementally*
/// (dirty leaves only, ancestor path re-merged), and the resulting root
/// coreset is swept against the **mutated** signal's true losses. Every
/// sample gates at ε — the same bar a from-scratch rebuild of the
/// mutated signal must clear — and the instance additionally checks
/// weight parity with that from-scratch rebuild (block moments are
/// exact, so the updated tree must carry the identical present mass).
#[derive(Clone, Debug)]
pub struct IncrementalCheck {
    pub instance: usize,
    pub rows: usize,
    pub cols: usize,
    pub kind: &'static str,
    pub seed: u64,
    /// Number of rect edits applied (each followed by one incremental
    /// `update`).
    pub edits: usize,
    /// Leaves rebuilt across the whole edit sequence (the work the
    /// incremental path actually did — strictly less than
    /// `edits × leaf_count` on local edits).
    pub leaf_rebuilds: usize,
    pub max_rel_err: f64,
    /// |w_incremental − w_scratch| / (1 + w_scratch).
    pub weight_rel_gap: f64,
    /// ε samples contributed to [`Family::Incremental`].
    pub samples: Vec<f64>,
    pub pass: bool,
}

/// Instances of the incremental-update check (fixed — the audit's
/// evidence trail must be bit-identical for every thread count, so the
/// count cannot depend on the pool).
const INCREMENTAL_INSTANCES: usize = 3;
/// Seeded rect edits per instance.
const INCREMENTAL_EDITS: usize = 8;
/// Shard rows for the audited merge trees: small enough that every
/// instance has several leaves (so the ancestor re-merge path is
/// genuinely exercised), matching no production default on purpose.
const INCREMENTAL_SHARD_ROWS: usize = 12;

fn incremental_check(config: &AuditConfig, instance: usize) -> IncrementalCheck {
    use crate::coreset::merge_tree::MergeTree;
    use crate::coreset::CoresetConfig;
    use crate::par::Exec;

    // Distinct seed stream from both the case sweep and the transfer
    // instances (same base seed).
    let seed = proptest::sized_case_seed(config.seed ^ 0x1C2E_D175, instance);
    let mut rng = Rng::new(seed);
    let n = 48 + rng.usize(25); // 48..=72 rows → 4..6 leaves at 12 shard rows
    let m = 16 + rng.usize(17); // 16..=32 cols
    let (kind, mut signal) = match instance % 3 {
        0 => ("piecewise", generate::piecewise_constant(n, m, config.k.max(2), 0.1, &mut rng).0),
        1 => ("smooth", generate::smooth(n, m, 3, &mut rng)),
        _ => ("image", generate::image_like(n, m, 2, &mut rng)),
    };

    let cfg = CoresetConfig::new(config.k, config.eps);
    let mut stats = config.stats_for(&signal);
    let mut tree = MergeTree::build(&signal, &stats, cfg, INCREMENTAL_SHARD_ROWS, Exec::Spawn(1));
    let before = tree.leaf_builds();

    // The seeded mutation sequence: bump a random small rect by a
    // Gaussian offset, rebuild the stats (prefix sums are global), and
    // update the tree incrementally. The inner executor is sequential —
    // the fan-out is at instance level, like the case sweep.
    for _ in 0..INCREMENTAL_EDITS {
        let h = 1 + rng.usize(8);
        let w = 1 + rng.usize(8);
        let r0 = rng.usize(n - h + 1);
        let c0 = rng.usize(m - w + 1);
        let rect = Rect::new(r0, r0 + h - 1, c0, c0 + w - 1);
        let delta = rng.normal();
        for (r, c) in rect.cells() {
            if signal.is_present(r, c) {
                signal.set(r, c, signal.get(r, c) + delta);
            }
        }
        stats = config.stats_for(&signal);
        tree.update(rect, &signal, &stats, Exec::Spawn(1));
    }
    let leaf_rebuilds = tree.leaf_builds() - before;
    let updated = tree.full();

    // Weight parity with a from-scratch rebuild of the mutated signal
    // (same shard plan — the compatibility reference).
    let scratch =
        SignalCoreset::construct_sharded_exec(&signal, cfg, INCREMENTAL_SHARD_ROWS, Exec::Spawn(1));
    let (w_inc, w_scr) = (updated.total_weight(), scratch.total_weight());
    let weight_rel_gap = (w_inc - w_scr).abs() / (1.0 + w_scr.abs());

    // The ε sweep: the structured query families of the main audit, all
    // evaluated on the *updated* coreset against the mutated signal's
    // exact losses, every sample attributed to Family::Incremental.
    let (_, queries) =
        build_queries(signal.bounds(), &stats, &updated, None, config.k, false, &mut rng);
    let approx = updated.fitting_loss_batch(&queries, 1);
    let samples: Vec<f64> = queries
        .iter()
        .zip(approx)
        .map(|(q, a)| relative_error(a, q.loss(&stats)))
        .collect();
    let max_rel_err = samples.iter().fold(0.0f64, |acc, &e| acc.max(e));
    let pass = max_rel_err <= config.eps && weight_rel_gap <= 1e-6;

    IncrementalCheck {
        instance,
        rows: n,
        cols: m,
        kind,
        seed,
        edits: INCREMENTAL_EDITS,
        leaf_rebuilds,
        max_rel_err,
        weight_rel_gap,
        samples,
        pass,
    }
}

// ---------------------------------------------------------------------------
// Sensitivity-sampling check: the probabilistic family.
// ---------------------------------------------------------------------------

/// One sensitivity-sampling instance: a seeded signal, an importance
/// sampling coreset ([`crate::sample::SensitivityCoreset`]) at
/// τ = half the present cells, and a structured query sweep measured
/// against the exact losses. The estimator is unbiased but only
/// probabilistically concentrated, so the per-query errors feed the
/// *measured* [`Family::Sensitivity`] aggregate, while the instance
/// gates on properties that hold with certainty or overwhelming margin:
///
/// * **weight parity** — the sampler rescales weights to the exact
///   present-cell mass, so `|Σw − present| / (1 + present)` must sit at
///   float-rounding level (≤ 1e-9);
/// * **generous error ceilings** — at τ = 50 % of the cells the
///   relative error of these query families concentrates far below 1;
///   mean ≤ 0.5 and max ≤ 1.0 leave orders-of-magnitude slack (the
///   ceilings are validated against the seeded instances in the test
///   suite, not tuned to them).
#[derive(Clone, Debug)]
pub struct SensitivityCheck {
    pub instance: usize,
    pub rows: usize,
    pub cols: usize,
    pub kind: &'static str,
    pub seed: u64,
    /// Algorithm name ([`crate::sample::SampleAlgorithm::name`]).
    pub algorithm: &'static str,
    /// Sample-size budget (distinct stored points is ≤ τ).
    pub tau: usize,
    /// Distinct stored points after multiplicity folding.
    pub size: usize,
    /// |Σw − present| / (1 + present).
    pub weight_rel_gap: f64,
    pub max_rel_err: f64,
    pub mean_rel_err: f64,
    /// ε samples contributed to [`Family::Sensitivity`].
    pub samples: Vec<f64>,
    pub pass: bool,
}

/// Seeded signal instances of the sensitivity check; each audits both
/// non-trivial sensitivity algorithms, so the fan-out runs
/// `2 × SENSITIVITY_INSTANCES` checks (fixed — the evidence trail must
/// be bit-identical for every thread count).
const SENSITIVITY_INSTANCES: usize = 3;
/// Audited sensitivity algorithms: the bicriteria-partition scores and
/// the leverage-style row/column bounds. Uniform is the baseline the
/// integration suite compares against, not an audited family member.
const SENSITIVITY_ALGORITHMS: [crate::sample::SampleAlgorithm; 2] = [
    crate::sample::SampleAlgorithm::Unified,
    crate::sample::SampleAlgorithm::Lightweight,
];

fn sensitivity_check(config: &AuditConfig, id: usize) -> SensitivityCheck {
    use crate::coreset::Coreset;
    use crate::par::Exec;
    use crate::sample::{SampleParams, SensitivityCoreset};

    let instance = id / SENSITIVITY_ALGORITHMS.len();
    let algorithm = SENSITIVITY_ALGORITHMS[id % SENSITIVITY_ALGORITHMS.len()];
    // Distinct seed stream from the case sweep, the transfer instances,
    // and the incremental checks (same base seed). Derived from the
    // *instance*, not the id, so both algorithms audit the identical
    // (signal, queries) pair.
    let seed = proptest::sized_case_seed(config.seed ^ 0x5E75_1717, instance);
    let mut rng = Rng::new(seed);
    let n = 24 + rng.usize(17); // 24..=40 rows
    let m = 16 + rng.usize(9); // 16..=24 cols
    let (kind, signal) = match instance % 3 {
        0 => ("piecewise", generate::piecewise_constant(n, m, config.k.max(2), 0.1, &mut rng).0),
        1 => ("smooth", generate::smooth(n, m, 3, &mut rng)),
        _ => ("image", generate::image_like(n, m, 2, &mut rng)),
    };
    let stats = config.stats_for(&signal);
    let bounds = signal.bounds();
    let refit = |mut s: KSegmentation| {
        s.refit_values(&stats);
        s
    };

    // The query sweep: degenerate + strip + random refit trees, drawn
    // before the coreset is built so both algorithm checks of the
    // instance sweep the identical queries.
    let mut queries = vec![KSegmentation::constant(bounds, stats.mean(&bounds))];
    queries.push(refit(strip_segmentation(bounds, config.k, true)));
    queries.push(refit(strip_segmentation(bounds, config.k, false)));
    for _ in 0..5 {
        queries.push(refit(random_segmentation(bounds, config.k, &mut rng)));
    }

    // τ = half the present mass; the sampler's own seed is decorrelated
    // from the signal/query stream.
    let present = stats.count(&bounds) as usize;
    let tau = (present / 2).max(32);
    let params = SampleParams::new(config.k, config.eps, tau, seed ^ 0x7A11_5EED);
    let coreset = SensitivityCoreset::build_exec(&signal, algorithm, &params, Exec::Spawn(1));

    let samples: Vec<f64> = queries
        .iter()
        .map(|q| relative_error(coreset.fitting_loss(q), q.loss(&stats)))
        .collect();
    let max_rel_err = samples.iter().fold(0.0f64, |acc, &e| acc.max(e));
    let mean_rel_err = samples.iter().sum::<f64>() / samples.len() as f64;
    let weight_rel_gap =
        (coreset.total_weight() - present as f64).abs() / (1.0 + present as f64);
    let pass = weight_rel_gap <= 1e-9 && mean_rel_err <= 0.5 && max_rel_err <= 1.0;

    SensitivityCheck {
        instance,
        rows: n,
        cols: m,
        kind,
        seed,
        algorithm: algorithm.name(),
        tau,
        size: coreset.size(),
        weight_rel_gap,
        max_rel_err,
        mean_rel_err,
        samples,
        pass,
    }
}

// ---------------------------------------------------------------------------
// Report.
// ---------------------------------------------------------------------------

/// Aggregated per-family empirical error.
#[derive(Clone, Debug)]
pub struct FamilyReport {
    pub family: Family,
    pub queries: usize,
    pub max_rel_err: f64,
    pub mean_rel_err: f64,
    pub threshold: Option<f64>,
    /// (index, seed) of the worst query — the replay handle. For every
    /// case-sweep family this is an audit case index + its
    /// [`proptest::sized_case_seed`]; for [`Family::DpOptimal`] (whose
    /// samples come only from the transfer instances) it is a transfer
    /// instance index + its transfer-stream seed. The JSON trail labels
    /// the provenance in `worst_source`.
    pub worst_case: Option<(usize, u64)>,
}

impl FamilyReport {
    /// A gated family passes when every observed error is within its
    /// threshold; an unpopulated family is vacuously green (it gates
    /// nothing) and informational families always pass.
    pub fn pass(&self) -> bool {
        match self.threshold {
            None => true,
            Some(t) => self.queries == 0 || self.max_rel_err <= t,
        }
    }
}

/// The audit's evidence: per-family aggregates, transfer instances, the
/// shrunk minimal repro of the first violation (if any), and the verdict.
#[derive(Clone, Debug)]
pub struct AuditReport {
    pub config: AuditConfig,
    pub families: Vec<FamilyReport>,
    pub transfers: Vec<TransferCheck>,
    pub incrementals: Vec<IncrementalCheck>,
    pub sensitivities: Vec<SensitivityCheck>,
    pub shrunk_failure: Option<String>,
    pub pass: bool,
}

impl AuditReport {
    /// Render the machine-readable evidence trail.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "audit",
                // `threads` is deliberately absent: it is a pure
                // performance knob and the evidence trail is identical
                // for every thread count (asserted by the tests).
                Json::obj(vec![
                    ("k", Json::int(self.config.k)),
                    ("eps", Json::num(self.config.eps)),
                    ("cases", Json::int(self.config.cases)),
                    // Hex string like every other seed in the trail: a
                    // u64 does not survive a round-trip through a JSON
                    // double above 2⁵³.
                    ("seed", Json::str(format!("{:#x}", self.config.seed))),
                    ("transfer_instances", Json::int(self.config.transfer_instances)),
                ]),
            ),
            (
                "families",
                Json::Arr(
                    self.families
                        .iter()
                        .map(|f| {
                            Json::obj(vec![
                                ("name", Json::str(f.family.name())),
                                ("queries", Json::int(f.queries)),
                                ("max_rel_err", Json::num(f.max_rel_err)),
                                ("mean_rel_err", Json::num(f.mean_rel_err)),
                                (
                                    "threshold",
                                    f.threshold.map_or(Json::Null, Json::num),
                                ),
                                ("gated", Json::Bool(f.threshold.is_some())),
                                (
                                    "vacuous",
                                    Json::Bool(f.queries == 0 && f.threshold.is_some()),
                                ),
                                (
                                    "worst_case",
                                    f.worst_case.map_or(Json::Null, |(c, _)| Json::int(c)),
                                ),
                                (
                                    "worst_seed",
                                    f.worst_case
                                        .map_or(Json::Null, |(_, s)| Json::str(format!("{s:#x}"))),
                                ),
                                (
                                    "worst_source",
                                    if f.worst_case.is_none() {
                                        Json::Null
                                    } else if f.family == Family::DpOptimal {
                                        Json::str("transfer-instance")
                                    } else if f.family == Family::Incremental {
                                        Json::str("incremental-instance")
                                    } else if f.family == Family::Sensitivity {
                                        Json::str("sensitivity-instance")
                                    } else {
                                        Json::str("case")
                                    },
                                ),
                                ("pass", Json::Bool(f.pass())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "transfer",
                Json::Arr(
                    self.transfers
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("rows", Json::int(t.rows)),
                                ("cols", Json::int(t.cols)),
                                ("k", Json::int(t.k)),
                                ("kind", Json::str(t.kind)),
                                ("seed", Json::str(format!("{:#x}", t.seed))),
                                ("opt_d", Json::num(t.opt_d)),
                                ("opt_c_fitting", Json::num(t.opt_c_fitting)),
                                ("loss_d_of_opt_c", Json::num(t.loss_d_of_opt_c)),
                                ("bound", Json::num(t.bound)),
                                ("rel_err_opt_d", Json::num(t.rel_err_opt_d)),
                                ("rel_err_opt_c", Json::num(t.rel_err_opt_c)),
                                ("pass", Json::Bool(t.pass)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "incremental",
                Json::Arr(
                    self.incrementals
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("rows", Json::int(t.rows)),
                                ("cols", Json::int(t.cols)),
                                ("kind", Json::str(t.kind)),
                                ("seed", Json::str(format!("{:#x}", t.seed))),
                                ("edits", Json::int(t.edits)),
                                ("leaf_rebuilds", Json::int(t.leaf_rebuilds)),
                                ("max_rel_err", Json::num(t.max_rel_err)),
                                ("weight_rel_gap", Json::num(t.weight_rel_gap)),
                                ("pass", Json::Bool(t.pass)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "sensitivity",
                Json::Arr(
                    self.sensitivities
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("instance", Json::int(t.instance)),
                                ("rows", Json::int(t.rows)),
                                ("cols", Json::int(t.cols)),
                                ("kind", Json::str(t.kind)),
                                ("seed", Json::str(format!("{:#x}", t.seed))),
                                ("algorithm", Json::str(t.algorithm)),
                                ("tau", Json::int(t.tau)),
                                ("size", Json::int(t.size)),
                                ("weight_rel_gap", Json::num(t.weight_rel_gap)),
                                ("max_rel_err", Json::num(t.max_rel_err)),
                                ("mean_rel_err", Json::num(t.mean_rel_err)),
                                ("pass", Json::Bool(t.pass)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "shrunk_failure",
                self.shrunk_failure
                    .as_deref()
                    .map_or(Json::Null, Json::str),
            ),
            ("pass", Json::Bool(self.pass)),
        ])
    }

    /// Human-readable summary (the CLI's stdout).
    pub fn summary(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "audit k={} eps={} cases={} seed={} transfer_instances={}\n",
            self.config.k,
            self.config.eps,
            self.config.cases,
            self.config.seed,
            self.config.transfer_instances
        ));
        out.push_str(&format!(
            "  {:<22} {:>7} {:>12} {:>12} {:>10}  verdict\n",
            "family", "queries", "max_rel_err", "mean_rel_err", "threshold"
        ));
        for f in &self.families {
            let verdict = if !f.pass() {
                "FAIL"
            } else if f.queries == 0 && f.threshold.is_some() {
                // Gated but never exercised this sweep — visibly vacuous,
                // not a silent green.
                "PASS (vacuous)"
            } else {
                "PASS"
            };
            out.push_str(&format!(
                "  {:<22} {:>7} {:>12.4e} {:>12.4e} {:>10}  {verdict}\n",
                f.family.name(),
                f.queries,
                f.max_rel_err,
                f.mean_rel_err,
                f.threshold
                    .map_or("-".to_string(), |t| format!("{t}")),
            ));
        }
        for t in &self.transfers {
            out.push_str(&format!(
                "  transfer {}x{} {} k={}: loss_D(opt_C) {:.4e} <= bound {:.4e} (opt_D {:.4e})  {}\n",
                t.rows,
                t.cols,
                t.kind,
                t.k,
                t.loss_d_of_opt_c,
                t.bound,
                t.opt_d,
                if t.pass { "PASS" } else { "FAIL" }
            ));
        }
        for t in &self.incrementals {
            out.push_str(&format!(
                "  incremental {}x{} {} edits={}: {} leaf rebuilds, max rel err {:.4e}, weight gap {:.2e}  {}\n",
                t.rows,
                t.cols,
                t.kind,
                t.edits,
                t.leaf_rebuilds,
                t.max_rel_err,
                t.weight_rel_gap,
                if t.pass { "PASS" } else { "FAIL" }
            ));
        }
        for t in &self.sensitivities {
            out.push_str(&format!(
                "  sensitivity {}x{} {} {} tau={}: {} points, max rel err {:.4e}, mean {:.4e}, weight gap {:.2e}  {}\n",
                t.rows,
                t.cols,
                t.kind,
                t.algorithm,
                t.tau,
                t.size,
                t.max_rel_err,
                t.mean_rel_err,
                t.weight_rel_gap,
                if t.pass { "PASS" } else { "FAIL" }
            ));
        }
        if self.transfers.iter().any(|t| t.k != self.config.k) {
            out.push_str(&format!(
                "  note: transfer instances certify k={} (configured k={} clamped to 2..=6 for DP feasibility)\n",
                self.transfers.first().map_or(0, |t| t.k),
                self.config.k
            ));
        }
        if let Some(s) = &self.shrunk_failure {
            out.push_str(&format!("  shrunk minimal repro: {s}\n"));
        }
        out.push_str(&format!(
            "audit: {}",
            if self.pass { "PASS" } else { "FAIL" }
        ));
        out
    }
}

/// Run the full audit: the per-case family sweep plus the DP transfer
/// instances, both fanned out on the [`crate::par`] pool. Deterministic
/// for any thread count (cases are self-seeded, results order-preserved).
pub fn run_audit(config: &AuditConfig) -> AuditReport {
    run_audit_exec(config, crate::par::Exec::Spawn(config.threads))
}

/// [`run_audit`] on an explicit executor ([`crate::par::Exec`]) — the
/// [`crate::engine::Engine::audit`] path, where the case and transfer
/// fan-outs run on the engine's long-lived pool instead of spawning
/// scoped threads. The evidence trail is bit-identical for every
/// executor and thread count (`config.threads` is ignored here; the
/// executor's concurrency is used).
pub fn run_audit_exec(config: &AuditConfig, exec: crate::par::Exec<'_>) -> AuditReport {
    struct CaseOutcome {
        case: usize,
        seed: u64,
        samples: Vec<(Family, f64)>,
    }

    let case_ids: Vec<usize> = (0..config.cases).collect();
    let outcomes: Vec<CaseOutcome> = exec.map(&case_ids, |_, &case| {
        let seed = proptest::sized_case_seed(config.seed, case);
        let mut rng = Rng::new(seed);
        let size = MIN_SIZE + rng.usize(MAX_SIZE - MIN_SIZE + 1);
        let audit_case = AuditCase::generate(&mut rng, size, config);
        // Inner evaluation is sequential: the fan-out is at case level.
        CaseOutcome { case, seed, samples: audit_case.samples(1) }
    });

    let transfer_ids: Vec<usize> = (0..config.transfer_instances.max(3)).collect();
    let transfers: Vec<TransferCheck> =
        exec.map(&transfer_ids, |_, &i| transfer_check(config, i));

    let incremental_ids: Vec<usize> = (0..INCREMENTAL_INSTANCES).collect();
    let incrementals: Vec<IncrementalCheck> =
        exec.map(&incremental_ids, |_, &i| incremental_check(config, i));

    let sensitivity_ids: Vec<usize> =
        (0..SENSITIVITY_INSTANCES * SENSITIVITY_ALGORITHMS.len()).collect();
    let sensitivities: Vec<SensitivityCheck> =
        exec.map(&sensitivity_ids, |_, &i| sensitivity_check(config, i));

    // Aggregate per family; transfer instances contribute the dp-optimal
    // samples, incremental instances the incremental-update samples.
    let mut families = Vec::new();
    for family in Family::ALL {
        let mut queries = 0usize;
        let mut max_rel_err = 0.0f64;
        let mut sum = 0.0f64;
        let mut worst_case: Option<(usize, u64)> = None;
        for o in &outcomes {
            for &(f, err) in &o.samples {
                if f == family {
                    queries += 1;
                    sum += err;
                    if err >= max_rel_err {
                        max_rel_err = err;
                        worst_case = Some((o.case, o.seed));
                    }
                }
            }
        }
        if family == Family::DpOptimal {
            for (i, t) in transfers.iter().enumerate() {
                for err in [t.rel_err_opt_d, t.rel_err_opt_c] {
                    queries += 1;
                    sum += err;
                    if err >= max_rel_err {
                        max_rel_err = err;
                        worst_case = Some((i, t.seed));
                    }
                }
            }
        }
        if family == Family::Incremental {
            for t in &incrementals {
                for &err in &t.samples {
                    queries += 1;
                    sum += err;
                    if err >= max_rel_err {
                        max_rel_err = err;
                        worst_case = Some((t.instance, t.seed));
                    }
                }
            }
        }
        if family == Family::Sensitivity {
            for t in &sensitivities {
                for &err in &t.samples {
                    queries += 1;
                    sum += err;
                    if err >= max_rel_err {
                        max_rel_err = err;
                        worst_case = Some((t.instance, t.seed));
                    }
                }
            }
        }
        families.push(FamilyReport {
            family,
            queries,
            max_rel_err,
            mean_rel_err: if queries == 0 { 0.0 } else { sum / queries as f64 },
            threshold: family.threshold(config.eps),
            worst_case,
        });
    }

    let families_pass = families.iter().all(FamilyReport::pass);
    let transfers_pass = transfers.iter().all(|t| t.pass);
    let incrementals_pass = incrementals.iter().all(|t| t.pass);
    let sensitivities_pass = sensitivities.iter().all(|t| t.pass);
    // A violated gate is handed to the proptest harness: re-sweep the
    // same seed space and greedily shrink the first failing case to a
    // minimal reproducible (signal, tree, seed) triple. Only families
    // populated by the case sweep can reproduce under `AuditCase::check`
    // — a dp-optimal violation is replayed from its transfer seed
    // instead, so don't burn a full re-sweep on it. (The re-sweep
    // restarts from case 0 and redoes up to `cases` builds sequentially;
    // that is deliberate — it is paid only on a red gate, and reusing
    // the proptest runner verbatim keeps the CLI repro and the test
    // suite's shrink semantics identical.)
    // Incremental violations replay from their instance seed, like
    // dp-optimal — the case-sweep shrinker cannot reproduce them.
    let case_family_failed = families
        .iter()
        .any(|f| !f.pass() && f.family != Family::DpOptimal && f.family != Family::Incremental);
    let shrunk_failure = if !case_family_failed {
        None
    } else {
        proptest::run_sized(
            "audit-eps-guarantee",
            config.seed,
            config.cases,
            MIN_SIZE,
            MAX_SIZE,
            |rng, size| AuditCase::generate(rng, size, config),
            AuditCase::check,
        )
        .err()
        .map(|f| f.to_string())
    };

    AuditReport {
        config: *config,
        families,
        transfers,
        incrementals,
        sensitivities,
        shrunk_failure,
        pass: families_pass && transfers_pass && incrementals_pass && sensitivities_pass,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coreset::Coreset;

    #[test]
    fn oracle_constant_query_matches_fitting_loss() {
        // opt₁ under the density = the minimal FITTING-LOSS of a constant:
        // evaluating the constant at the oracle mean through Algorithm 5
        // must agree exactly.
        let mut rng = Rng::new(50);
        let sig = generate::smooth(30, 24, 3, &mut rng);
        let cs = SignalCoreset::construct(&sig, 4, 0.4);
        let oracle = CoresetOracle::new(&cs);
        let bounds = sig.bounds();
        let v = oracle.mean(&bounds);
        let via_fitting = cs.fitting_loss(&KSegmentation::constant(bounds, v));
        let via_oracle = oracle.opt1(&bounds);
        assert!(
            (via_fitting - via_oracle).abs() <= 1e-7 * (1.0 + via_fitting),
            "{via_oracle} vs {via_fitting}"
        );
    }

    #[test]
    fn oracle_dp_value_equals_fitting_loss_of_its_tree() {
        // The DP on the density oracle minimizes FITTING-LOSS itself: the
        // value it reports for its own reconstructed tree must equal
        // Algorithm 5's evaluation of that tree.
        let mut rng = Rng::new(51);
        let (sig, _) = generate::piecewise_constant(14, 12, 3, 0.1, &mut rng);
        let cs = SignalCoreset::construct(&sig, 3, 0.4);
        let oracle = CoresetOracle::new(&cs);
        let mut dp = TreeDP::new(&oracle);
        let value = dp.opt(sig.bounds(), 3);
        let tree = dp.solve(sig.bounds(), 3);
        let fit = cs.fitting_loss(&tree);
        assert!(
            (fit - value).abs() <= 1e-6 * (1.0 + fit),
            "dp {value} vs fitting {fit}"
        );
        // And it never beats trees it could have chosen: random k-trees
        // evaluate no better under FITTING-LOSS.
        for _ in 0..10 {
            let mut s = random_segmentation(sig.bounds(), 3, &mut rng);
            s.refit_values(&PrefixStats::new(&sig));
            assert!(value <= cs.fitting_loss(&s) + 1e-9 * (1.0 + value));
        }
    }

    #[test]
    fn oracle_saturated_floor_is_consistent() {
        // saturated = the sum of per-cell opt₁ under the density; a
        // single cell's opt₁ must equal its saturated value.
        let mut rng = Rng::new(52);
        let sig = generate::image_like(16, 16, 2, &mut rng);
        let cs = SignalCoreset::construct(&sig, 3, 0.5);
        let oracle = CoresetOracle::new(&cs);
        let mut total = 0.0;
        for r in 0..16 {
            for c in 0..16 {
                let cell = Rect::new(r, r, c, c);
                let o = oracle.opt1(&cell);
                let s = oracle.saturated(&cell);
                assert!((o - s).abs() <= 1e-9 * (1.0 + s), "cell {r},{c}");
                total += s;
            }
        }
        let whole = oracle.saturated(&sig.bounds());
        assert!((total - whole).abs() <= 1e-7 * (1.0 + whole));
        // The DP floor: opt with k = area reaches exactly the saturated
        // loss on a small rect.
        let rect = Rect::new(0, 2, 0, 1);
        let mut dp = TreeDP::new(&oracle);
        let sat = oracle.saturated(&rect);
        assert!((dp.opt(rect, 6) - sat).abs() <= 1e-9 * (1.0 + sat));
    }

    #[test]
    fn masked_cells_contribute_zero_to_both_losses() {
        // Two signals identical except under the mask ⇒ identical
        // statistics, identical coreset, identical true and coreset loss
        // for every query — masked cells contribute exactly zero.
        let mut rng = Rng::new(53);
        let mut a = generate::smooth(32, 24, 3, &mut rng);
        generate::random_mask(&mut a, 0.2, &mut rng);
        let mut b = a.clone();
        for r in 0..b.rows() {
            for c in 0..b.cols() {
                if !b.is_present(r, c) {
                    b.set(r, c, 1e6); // garbage under the mask
                }
            }
        }
        let (sa, sb) = (PrefixStats::new(&a), PrefixStats::new(&b));
        let (ca, cb) = (SignalCoreset::construct(&a, 4, 0.4), SignalCoreset::construct(&b, 4, 0.4));
        assert_eq!(ca.blocks.len(), cb.blocks.len());
        for (x, y) in ca.blocks.iter().zip(&cb.blocks) {
            assert_eq!(x.rect, y.rect);
            assert_eq!(x.labels, y.labels);
            assert_eq!(x.weights, y.weights);
        }
        for _ in 0..5 {
            let mut s = random_segmentation(a.bounds(), 4, &mut rng);
            s.refit_values(&sa);
            assert_eq!(s.loss(&sa), s.loss(&sb));
            assert_eq!(ca.fitting_loss(&s), cb.fitting_loss(&s));
        }
    }

    /// Independent oracle for a one-piece query: Case (i) moments for
    /// fully-covered blocks, the pro-rata Case (ii) closed form for
    /// straddlers — re-derived from stored block moments, no shared code
    /// with `block_loss`.
    fn one_piece_loss_oracle(cs: &SignalCoreset, piece: Rect, v: f64) -> f64 {
        let mut total = 0.0;
        for b in &cs.blocks {
            if let Some(inter) = b.rect.intersection(&piece) {
                let m = b.moments();
                if m.count <= 0.0 {
                    continue;
                }
                if piece.contains_rect(&b.rect) {
                    total += m.sse_to(v);
                } else {
                    let z = inter.area() as f64 * m.count / b.rect.area() as f64;
                    let d = v - m.mean();
                    total += z * (d * d + m.opt1() / m.count);
                }
            }
        }
        total
    }

    #[test]
    fn masked_region_carries_zero_weight_and_zero_true_loss() {
        let mut rng = Rng::new(54);
        let mut sig = generate::smooth(24, 24, 2, &mut rng);
        let dead = Rect::new(4, 11, 6, 13);
        sig.mask_rect(dead);
        let stats = PrefixStats::new(&sig);
        let cs = SignalCoreset::construct(&sig, 3, 0.4);
        // True loss of a query supported only on the masked region is
        // zero up to prefix cancellation residue: masked cells contribute
        // nothing (count is integer-exact zero; sum/sum_sq corners cancel
        // to ~1e-13 of the surrounding magnitudes, amplified by the query
        // value in sse_to — hence a tolerance, not an exact compare).
        let s = KSegmentation::constant(dead, 123.0);
        assert_eq!(stats.count(&dead), 0.0);
        assert!(s.loss(&stats).abs() < 1e-6, "residue {}", s.loss(&stats));
        // No stored block lies inside the dead region (dropped at build),
        // so the region holds zero coreset weight.
        for b in &cs.blocks {
            assert!(!dead.contains_rect(&b.rect), "dead block stored: {:?}", b.rect);
        }
        // The coreset charges the dead query only through blocks that
        // straddle its boundary — exactly the documented area-proxy
        // smoothing (DESIGN.md §Masks), nothing else: Algorithm 5 agrees
        // with the independently derived closed form.
        let expected = one_piece_loss_oracle(&cs, dead, 123.0);
        let got = cs.fitting_loss(&s);
        assert!(
            (got - expected).abs() <= 1e-9 * (1.0 + expected),
            "{got} vs oracle {expected}"
        );
    }

    #[test]
    fn masked_audit_sweep_stays_within_eps() {
        // The audit's query builder over a masked signal: exactness of the
        // block-aligned family survives masking, and the approximate
        // families stay within the configured ε.
        let mut rng = Rng::new(55);
        let mut sig = generate::smooth(40, 30, 3, &mut rng);
        sig.mask_rect(Rect::new(8, 15, 4, 12));
        let eps = 0.5;
        let stats = PrefixStats::new(&sig);
        let cs = SignalCoreset::construct(&sig, 4, eps);
        let (families, queries) =
            build_queries(sig.bounds(), &stats, &cs, None, 4, false, &mut rng);
        let approx = cs.fitting_loss_batch(&queries, 1);
        for ((family, q), a) in families.iter().zip(&queries).zip(approx) {
            let err = relative_error(a, q.loss(&stats));
            let threshold = family.threshold(eps).unwrap();
            assert!(
                err <= threshold,
                "family {} err {err} > {threshold} on masked signal",
                family.name()
            );
        }
    }

    #[test]
    fn audit_case_generation_is_deterministic() {
        let config = AuditConfig::new(4, 0.5);
        for size in [16, 17, 18, 19] {
            let a = AuditCase::generate(&mut Rng::new(9), size, &config);
            let b = AuditCase::generate(&mut Rng::new(9), size, &config);
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.signal.values(), b.signal.values());
            assert_eq!(a.samples(1), b.samples(1));
            assert_eq!(a.queries.len(), b.queries.len());
        }
    }

    #[test]
    fn noise_cases_do_not_gate_approximate_families() {
        let config = AuditConfig::new(3, 0.3);
        // size ≡ 3 (mod 4) → pure-noise signal.
        let case = AuditCase::generate(&mut Rng::new(4), 23, &config);
        assert_eq!(case.kind, "noise");
        assert!(case.families.contains(&Family::NoiseInformational));
        assert!(case.families.contains(&Family::BlockAligned));
        for &f in &case.families {
            assert!(
                matches!(f, Family::BlockAligned | Family::NoiseInformational),
                "gated family {f:?} on a noise case"
            );
        }
        // check() ignores the informational samples entirely.
        assert!(case.check().is_ok());
    }

    #[test]
    fn run_audit_small_sweep_passes_and_serializes() {
        let config = AuditConfig::new(3, 0.5).with_cases(6).with_seed(11).with_threads(2);
        let report = run_audit(&config);
        assert!(report.pass, "\n{}", report.summary());
        assert!(report.shrunk_failure.is_none());
        assert!(report.transfers.len() >= 3);
        for t in &report.transfers {
            assert!(t.pass, "transfer {:?}", t);
            assert!(t.rows <= 32 && t.cols <= 32, "DP-feasible sizes only");
        }
        let rendered = report.to_json().render();
        for key in
            ["\"audit\"", "\"families\"", "\"transfer\"", "\"sensitivity\"", "\"pass\": true"]
        {
            assert!(rendered.contains(key), "missing {key} in\n{rendered}");
        }
        // Thread count is a pure performance knob: identical evidence.
        let report1 = run_audit(&config.with_threads(1));
        assert_eq!(rendered, report1.to_json().render());
    }

    #[test]
    fn sensitivity_family_is_measured_and_instances_gate() {
        let config = AuditConfig::new(3, 0.5).with_cases(2).with_seed(11);
        let report = run_audit(&config);
        // Both algorithms audited on every instance, all green.
        assert_eq!(
            report.sensitivities.len(),
            SENSITIVITY_INSTANCES * SENSITIVITY_ALGORITHMS.len()
        );
        for t in &report.sensitivities {
            assert!(t.pass, "sensitivity instance failed: {t:?}");
            assert!(t.size <= t.tau);
            assert!(t.weight_rel_gap <= 1e-9);
        }
        // Paired checks of one instance share the signal and queries.
        for pair in report.sensitivities.chunks(2) {
            assert_eq!(pair[0].instance, pair[1].instance);
            assert_eq!((pair[0].rows, pair[0].cols), (pair[1].rows, pair[1].cols));
            assert_ne!(pair[0].algorithm, pair[1].algorithm);
        }
        // The family aggregate is measured, never gated.
        let fam = report
            .families
            .iter()
            .find(|f| f.family == Family::Sensitivity)
            .unwrap();
        assert!(fam.threshold.is_none());
        assert!(fam.queries > 0);
        assert!(fam.pass());
    }

    #[test]
    fn blocked_stats_audit_is_byte_identical() {
        // Routing the per-case statistics through the cache-blocked fill
        // (the blocked engine backend's audit path) cannot change one
        // byte of evidence — the fills are bit-identical.
        let base = AuditConfig::new(3, 0.5).with_cases(4).with_seed(11).with_threads(1);
        let reference = run_audit(&base).to_json().render();
        let blocked = run_audit(&base.with_stats_block(Some(37))).to_json().render();
        assert_eq!(reference, blocked);
    }
}

