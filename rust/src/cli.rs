//! Hand-rolled CLI argument parsing (clap is unavailable offline —
//! DESIGN.md §Substitutions). Supports subcommands, `--flag value`,
//! `--flag=value`, and boolean flags, with typed getters and helpful
//! errors.

use std::collections::HashMap;

#[derive(Debug)]
pub enum CliError {
    Missing(String),
    Invalid(String, String),
    UnknownCommand(String),
    /// Unrecognized `--flags` for a subcommand: (flags, command, valid
    /// options). A typo'd flag must fail loudly — `--theads 4` silently
    /// running single-threaded is worse than an error.
    UnknownFlags(String, String, String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Missing(n) => write!(f, "missing required argument --{n}"),
            CliError::Invalid(n, v) => write!(f, "invalid value for --{n}: {v}"),
            CliError::UnknownCommand(c) => write!(f, "unknown subcommand '{c}'; try 'help'"),
            CliError::UnknownFlags(flags, cmd, valid) => write!(
                f,
                "unknown flag(s) {flags} for '{cmd}'; valid flags: {valid}"
            ),
        }
    }
}

impl std::error::Error for CliError {}

/// Parsed command line: subcommand + named options + positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: String,
    pub options: HashMap<String, String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    ///
    /// Without declared boolean flags this keeps the historical
    /// ambiguity: `--flag positional` reads the positional as the
    /// flag's value. Subcommands with boolean flags next to positionals
    /// must declare them via [`Args::parse_with_flags`] (the launcher
    /// does, through [`boolean_flags_for`]).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        Self::parse_with_flags(argv, &[])
    }

    /// Parse with a set of *declared boolean flags*: a flag named in
    /// `boolean_flags` never consumes the following token as its value,
    /// so `serve --foreground config.json` yields `foreground=true`
    /// plus the `config.json` positional instead of
    /// `foreground=config.json`. An explicit `--foreground=false` still
    /// works (the `=` form always wins).
    pub fn parse_with_flags(
        argv: impl IntoIterator<Item = String>,
        boolean_flags: &[&str],
    ) -> Args {
        let mut it = argv.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut options = HashMap::new();
        let mut positionals = Vec::new();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    options.insert(k.to_string(), v.to_string());
                } else if boolean_flags.contains(&name) {
                    options.insert(name.to_string(), "true".to_string());
                } else if it.peek().map_or(false, |nxt| !nxt.starts_with("--")) {
                    if let Some(v) = it.next() {
                        options.insert(name.to_string(), v);
                    }
                } else {
                    options.insert(name.to_string(), "true".to_string());
                }
            } else {
                positionals.push(arg);
            }
        }
        Args { command, options, positionals }
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        let bools = boolean_flags_for(argv.first().map_or("", String::as_str));
        Self::parse_with_flags(argv, bools)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::Invalid(name.into(), v.into())),
        }
    }

    /// 64-bit seed getter (`--seed` may exceed usize on 32-bit targets,
    /// and seeds are semantically u64 throughout `sigtree::rng`).
    /// Accepts both decimal and `0x`-prefixed hex ([`parse_u64`]) — the
    /// audit report's replay seeds (`worst_seed`, transfer seeds) and
    /// the proptest harness print seeds as `{:#x}`, and those must
    /// paste straight back into the CLI to replay a failing case.
    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => parse_u64(v).ok_or_else(|| CliError::Invalid(name.into(), v.into())),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::Invalid(name.into(), v.into())),
        }
    }

    /// The `--threads` convention shared by every subcommand: `0` (and
    /// the literal `auto`) mean "all available cores" — resolution
    /// happens downstream in `sigtree::par::resolve_threads`. `default`
    /// is used when the flag is absent.
    pub fn get_threads(&self, default: usize) -> Result<usize, CliError> {
        match self.get("threads") {
            None => Ok(default),
            Some("auto") => Ok(0),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::Invalid("threads".into(), v.into())),
        }
    }

    pub fn get_flag(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn require(&self, name: &str) -> Result<&str, CliError> {
        self.get(name).ok_or_else(|| CliError::Missing(name.into()))
    }

    /// Reject any parsed `--flag` outside `allowed`, listing the valid
    /// options for this subcommand. Every subcommand calls this before
    /// reading a single knob, so typos (`--theads`) error out instead
    /// of silently falling back to defaults. Unknown flags are reported
    /// sorted (all of them, not just the first).
    pub fn expect_only(&self, allowed: &[&str]) -> Result<(), CliError> {
        let mut unknown: Vec<&str> = self
            .options
            .keys()
            .map(String::as_str)
            .filter(|flag| !allowed.contains(flag))
            .collect();
        if unknown.is_empty() {
            return Ok(());
        }
        unknown.sort_unstable();
        let mut valid: Vec<&str> = allowed.to_vec();
        valid.sort_unstable();
        Err(CliError::UnknownFlags(
            unknown
                .iter()
                .map(|f| format!("--{f}"))
                .collect::<Vec<_>>()
                .join(", "),
            self.command.clone(),
            valid
                .iter()
                .map(|f| format!("--{f}"))
                .collect::<Vec<_>>()
                .join(", "),
        ))
    }
}

/// The per-subcommand boolean-flag registry consulted by
/// [`Args::from_env`]. Every value-less flag a subcommand consumes via
/// [`Args::get_flag`] belongs here; anything not listed keeps the
/// historical greedy parse (next non-`--` token becomes the value), so
/// adding a flag to this table is a local, per-subcommand decision that
/// cannot reinterpret another subcommand's argv.
pub fn boolean_flags_for(command: &str) -> &'static [&'static str] {
    match command {
        "lint" => &["rules"],
        "serve" => &["foreground"],
        "x10" => &["quick"],
        _ => &[],
    }
}

/// The repo-wide u64/seed spelling: decimal or `0x`/`0X`-prefixed hex.
/// Shared by [`Args::get_u64`] and the engine's JSON config reader so
/// the two surfaces can never diverge on what a seed looks like.
pub fn parse_u64(v: &str) -> Option<u64> {
    match v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16).ok(),
        None => v.parse().ok(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        // The historical ambiguity for UNDECLARED flags: `--flag
        // positional` reads the positional as the flag's value, so
        // boolean flags next to positionals either use `--flag=true`
        // or get declared in `boolean_flags_for`.
        let a = Args::parse(argv("coreset --k 10 --eps=0.2 --verbose=true input.bin"));
        assert_eq!(a.command, "coreset");
        assert_eq!(a.get("k"), Some("10"));
        assert_eq!(a.get("eps"), Some("0.2"));
        assert!(a.get_flag("verbose"));
        assert_eq!(a.positionals, vec!["input.bin"]);
    }

    #[test]
    fn undeclared_flag_still_swallows_the_positional() {
        // Regression pin for the pre-fix behavior: with no declaration,
        // the greedy parse is unchanged (back-compat for scripts that
        // rely on `--flag value`).
        let a = Args::parse(argv("coreset --verbose input.bin"));
        assert_eq!(a.get("verbose"), Some("input.bin"));
        assert!(a.positionals.is_empty());
    }

    #[test]
    fn declared_boolean_flag_does_not_consume_the_positional() {
        // The serve launch line from ISSUE/ROADMAP: `--foreground` is a
        // declared boolean, so the config path stays positional.
        let a = Args::parse_with_flags(argv("serve --foreground config.json"), &["foreground"]);
        assert!(a.get_flag("foreground"));
        assert_eq!(a.positionals, vec!["config.json"]);
        // Fails on the pre-fix parser: Args::parse has no declarations,
        // so the same argv swallows the positional.
        let pre = Args::parse(argv("serve --foreground config.json"));
        assert_eq!(pre.get("foreground"), Some("config.json"));
    }

    #[test]
    fn declared_boolean_flag_accepts_explicit_values() {
        let a = Args::parse_with_flags(
            argv("serve --foreground=false config.json"),
            &["foreground"],
        );
        assert!(!a.get_flag("foreground"));
        assert_eq!(a.get("foreground"), Some("false"));
        assert_eq!(a.positionals, vec!["config.json"]);
        // Declared booleans mixed with valued flags parse positionally.
        let b = Args::parse_with_flags(
            argv("serve --foreground --port 8080 config.json"),
            &["foreground"],
        );
        assert!(b.get_flag("foreground"));
        assert_eq!(b.get("port"), Some("8080"));
        assert_eq!(b.positionals, vec!["config.json"]);
    }

    #[test]
    fn boolean_flag_registry_covers_flag_consumers() {
        assert!(boolean_flags_for("serve").contains(&"foreground"));
        assert!(boolean_flags_for("lint").contains(&"rules"));
        assert!(boolean_flags_for("x10").contains(&"quick"));
        assert!(boolean_flags_for("coreset").is_empty());
        // And from_env's lookup composes with the parser: `lint --rules
        // extra.rs` keeps the positional.
        let a = Args::parse_with_flags(argv("lint --rules extra.rs"), boolean_flags_for("lint"));
        assert!(a.get_flag("rules"));
        assert_eq!(a.positionals, vec!["extra.rs"]);
    }

    #[test]
    fn typed_getters() {
        let a = Args::parse(argv("x --k 7 --eps 0.5"));
        assert_eq!(a.get_usize("k", 1).unwrap(), 7);
        assert_eq!(a.get_usize("missing", 3).unwrap(), 3);
        assert!((a.get_f64("eps", 0.0).unwrap() - 0.5).abs() < 1e-12);
        assert!(a.get_usize("eps", 1).is_err());
    }

    #[test]
    fn u64_getter_handles_large_seeds() {
        let a = Args::parse(argv("audit --seed 18446744073709551615"));
        assert_eq!(a.get_u64("seed", 7).unwrap(), u64::MAX);
        assert_eq!(a.get_u64("missing", 7).unwrap(), 7);
        assert!(Args::parse(argv("audit --seed x")).get_u64("seed", 7).is_err());
        // Reported seeds are printed as {:#x} and must round-trip.
        let hex = Args::parse(argv("audit --seed 0x9e3779b97f4a7c15"));
        assert_eq!(hex.get_u64("seed", 7).unwrap(), 0x9e37_79b9_7f4a_7c15);
        let upper = Args::parse(argv("audit --seed 0XFF"));
        assert_eq!(upper.get_u64("seed", 7).unwrap(), 255);
        assert!(Args::parse(argv("audit --seed 0xzz")).get_u64("seed", 7).is_err());
    }

    #[test]
    fn threads_flag_conventions() {
        assert_eq!(Args::parse(argv("x --threads 4")).get_threads(1).unwrap(), 4);
        assert_eq!(Args::parse(argv("x --threads auto")).get_threads(1).unwrap(), 0);
        assert_eq!(Args::parse(argv("x")).get_threads(2).unwrap(), 2);
        assert!(Args::parse(argv("x --threads lots")).get_threads(1).is_err());
    }

    #[test]
    fn boolean_flag_at_end() {
        let a = Args::parse(argv("run --fast"));
        assert!(a.get_flag("fast"));
    }

    #[test]
    fn require_errors_on_missing() {
        let a = Args::parse(argv("run"));
        assert!(a.require("input").is_err());
    }

    #[test]
    fn empty_argv_is_help() {
        let a = Args::parse(Vec::<String>::new());
        assert_eq!(a.command, "help");
    }

    #[test]
    fn expect_only_accepts_known_flags() {
        let a = Args::parse(argv("coreset --k 5 --eps 0.4 --threads 2"));
        a.expect_only(&["k", "eps", "threads", "seed"]).unwrap();
    }

    #[test]
    fn expect_only_rejects_typos_listing_valid_flags() {
        // The historical failure mode: `--theads 4` was silently
        // accepted and the run fell back to single-threaded defaults.
        let a = Args::parse(argv("coreset --k 5 --theads 4"));
        let err = a.expect_only(&["k", "eps", "threads"]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("--theads"), "{msg}");
        assert!(msg.contains("'coreset'"), "{msg}");
        assert!(msg.contains("--threads"), "must list valid flags: {msg}");
        assert!(msg.contains("--eps"), "{msg}");
    }

    #[test]
    fn expect_only_reports_all_unknown_flags_sorted() {
        let a = Args::parse(argv("audit --zz 1 --aa 2 --k 3"));
        let msg = a.expect_only(&["k"]).unwrap_err().to_string();
        let (aa, zz) = (msg.find("--aa").unwrap(), msg.find("--zz").unwrap());
        assert!(aa < zz, "sorted: {msg}");
    }
}
