//! Minimal property-testing harness (the `proptest` crate is unavailable
//! offline — DESIGN.md §Substitutions). Provides seeded case generation
//! with failure reporting and greedy input shrinking for the common
//! "random signal + random query" shape used by the invariant tests in
//! `rust/tests/`.
//!
//! Shipped as a normal module so both unit tests and the integration
//! tests under `rust/tests/` can use it. Two entry styles:
//!
//! * `check*` — panic on violation with the replayable (case, seed[, size])
//!   triple in the message (the test-suite path);
//! * [`run_sized`] — return the violation as a structured [`Failure`]
//!   instead of panicking, so non-test callers (the `sigtree::audit`
//!   engine's shrink hook) can embed the minimal reproducible triple in
//!   a machine-readable report.

use crate::rng::Rng;

/// Per-case seed derivation for [`check`]-style (unsized) properties.
/// `base` defaults to `0xC0FFEE` for the legacy [`check`] entry point.
pub fn case_seed(base: u64, case: usize) -> u64 {
    base ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Per-case seed derivation for [`check_sized`]-style properties.
/// `base` defaults to `0xFACADE` for the legacy [`check_sized`] entry
/// point; the audit engine passes its own `--seed` here so CLI sweeps and
/// shrunk repros share one seed space.
pub fn sized_case_seed(base: u64, case: usize) -> u64 {
    base ^ (case as u64).wrapping_mul(0x2545_F491_4F6C_DD1D)
}

/// A reproducible property violation: everything needed to replay it.
#[derive(Clone, Debug)]
pub struct Failure {
    pub name: String,
    pub case: usize,
    pub seed: u64,
    /// Smallest failing generator size found by greedy shrinking.
    pub size: usize,
    pub message: String,
}

impl std::fmt::Display for Failure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property '{}' failed on case {} (seed {:#x}, size {}): {}",
            self.name, self.case, self.seed, self.size, self.message
        )
    }
}

/// Run `cases` random trials of `prop`, which receives a per-case RNG and
/// returns `Err(description)` on violation. On failure, panics with the
/// seed so the case can be replayed exactly.
pub fn check(name: &str, cases: usize, prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    check_seeded(name, 0xC0FFEE, cases, prop);
}

/// [`check`] with an explicit base seed, so independent test sites draw
/// from distinct deterministic streams instead of all sharing `0xC0FFEE`.
pub fn check_seeded(
    name: &str,
    base: u64,
    cases: usize,
    mut prop: impl FnMut(&mut Rng) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = case_seed(base, case);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Property over a generated value with greedy shrinking: `gen` produces
/// a value from (rng, size); on failure, `size` is shrunk toward
/// `min_size` and the smallest failing size is reported. Panics with the
/// replayable triple; use [`run_sized`] for the non-panicking form.
pub fn check_sized<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    min_size: usize,
    max_size: usize,
    gen: impl Fn(&mut Rng, usize) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    if let Err(f) = run_sized(name, 0xFACADE, cases, min_size, max_size, gen, prop) {
        panic!("{f}");
    }
}

/// [`check_sized`] with an explicit base seed (panicking form).
pub fn check_sized_seeded<T: std::fmt::Debug>(
    name: &str,
    base: u64,
    cases: usize,
    min_size: usize,
    max_size: usize,
    gen: impl Fn(&mut Rng, usize) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    if let Err(f) = run_sized(name, base, cases, min_size, max_size, gen, prop) {
        panic!("{f}");
    }
}

/// Core sized runner: sweep `cases` seeded cases, greedily shrink the
/// first violation toward `min_size`, and return it as a [`Failure`]
/// instead of panicking. This is the hook the audit engine uses to turn
/// an empirical ε violation into a minimal reproducible (signal, tree,
/// seed) triple inside its JSON report.
pub fn run_sized<T: std::fmt::Debug>(
    name: &str,
    base: u64,
    cases: usize,
    min_size: usize,
    max_size: usize,
    gen: impl Fn(&mut Rng, usize) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) -> Result<(), Failure> {
    for case in 0..cases {
        let seed = sized_case_seed(base, case);
        let mut rng = Rng::new(seed);
        let size = min_size + rng.usize(max_size - min_size + 1);
        let value = gen(&mut rng, size);
        if let Err(msg) = prop(&value) {
            // Greedy shrink: halve size toward min_size while still
            // failing. Each attempt discards the size draw first so its
            // stream matches the original generation — at `s == size` it
            // regenerates the original value bit-exactly, and the
            // reported (seed, size) triple replays via the same recipe
            // (seed the RNG, discard one size draw, generate at `size`).
            let mut best_size = size;
            let mut best_msg = msg;
            let mut s = size;
            while s > min_size {
                s = (s / 2).max(min_size);
                let mut srng = Rng::new(seed);
                let _ = srng.usize(max_size - min_size + 1);
                let v = gen(&mut srng, s);
                match prop(&v) {
                    Err(m) => {
                        best_size = s;
                        best_msg = m;
                        if s == min_size {
                            break;
                        }
                    }
                    Ok(()) => break,
                }
            }
            return Err(Failure {
                name: name.to_string(),
                case,
                seed,
                size: best_size,
                message: best_msg,
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_silent() {
        check("always-true", 20, |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "always-false")]
    fn failing_property_panics_with_seed() {
        check("always-false", 5, |_| Err("nope".into()));
    }

    #[test]
    #[should_panic(expected = "size 1")]
    fn shrinking_reaches_min_size() {
        // Fails for every size → shrink must land on min_size = 1.
        check_sized(
            "shrinks",
            1,
            1,
            64,
            |rng, size| (0..size).map(|_| rng.f64()).collect::<Vec<f64>>(),
            |_| Err("always fails".into()),
        );
    }

    #[test]
    fn rng_is_deterministic_per_case() {
        let mut first = Vec::new();
        check("record", 3, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        check("record", 3, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn seeded_bases_draw_distinct_streams() {
        let mut a = Vec::new();
        check_seeded("a", 1, 3, |rng| {
            a.push(rng.next_u64());
            Ok(())
        });
        let mut b = Vec::new();
        check_seeded("b", 2, 3, |rng| {
            b.push(rng.next_u64());
            Ok(())
        });
        assert_ne!(a, b);
    }

    #[test]
    fn run_sized_returns_structured_failure() {
        let f = run_sized(
            "structured",
            0xFACADE,
            4,
            2,
            32,
            |rng, size| (0..size).map(|_| rng.f64()).collect::<Vec<f64>>(),
            |v| {
                if v.len() >= 2 {
                    Err(format!("len {}", v.len()))
                } else {
                    Ok(())
                }
            },
        )
        .unwrap_err();
        assert_eq!(f.case, 0);
        assert_eq!(f.size, 2, "shrinks to the minimal failing size");
        assert_eq!(f.seed, sized_case_seed(0xFACADE, 0));
        // The panicking wrapper and the runner agree on the message shape.
        assert!(f.to_string().contains("size 2"));
        // And a passing property returns Ok.
        assert!(run_sized("ok", 7, 3, 1, 8, |_, s| s, |_| Ok(())).is_ok()); // usize is Debug
    }
}
