//! Minimal property-testing harness (the `proptest` crate is unavailable
//! offline — DESIGN.md §Substitutions). Provides seeded case generation
//! with failure reporting and greedy input shrinking for the common
//! "random signal + random query" shape used by the invariant tests in
//! `rust/tests/`.
//!
//! Shipped as a normal module so both unit tests and the integration
//! tests under `rust/tests/` can use it.

use crate::rng::Rng;

/// Run `cases` random trials of `prop`, which receives a per-case RNG and
/// returns `Err(description)` on violation. On failure, panics with the
/// seed so the case can be replayed exactly.
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Property over a generated value with greedy shrinking: `gen` produces
/// a value from (rng, size); on failure, `size` is shrunk toward
/// `min_size` and the smallest failing size is reported.
pub fn check_sized<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    min_size: usize,
    max_size: usize,
    gen: impl Fn(&mut Rng, usize) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = 0xFACADE ^ (case as u64).wrapping_mul(0x2545_F491_4F6C_DD1D);
        let mut rng = Rng::new(seed);
        let size = min_size + rng.usize(max_size - min_size + 1);
        let value = gen(&mut rng, size);
        if let Err(msg) = prop(&value) {
            // Greedy shrink: halve size toward min_size while still failing.
            let mut best_size = size;
            let mut best_msg = msg;
            let mut s = size;
            while s > min_size {
                s = (s / 2).max(min_size);
                let mut srng = Rng::new(seed);
                let v = gen(&mut srng, s);
                match prop(&v) {
                    Err(m) => {
                        best_size = s;
                        best_msg = m;
                        if s == min_size {
                            break;
                        }
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}, size {best_size}): {best_msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_silent() {
        check("always-true", 20, |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "always-false")]
    fn failing_property_panics_with_seed() {
        check("always-false", 5, |_| Err("nope".into()));
    }

    #[test]
    #[should_panic(expected = "size 1")]
    fn shrinking_reaches_min_size() {
        // Fails for every size → shrink must land on min_size = 1.
        check_sized(
            "shrinks",
            1,
            1,
            64,
            |rng, size| (0..size).map(|_| rng.f64()).collect::<Vec<f64>>(),
            |_| Err("always fails".into()),
        );
    }

    #[test]
    fn rng_is_deterministic_per_case() {
        let mut first = Vec::new();
        check("record", 3, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        check("record", 3, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
