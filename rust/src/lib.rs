//! # sigtree — Coresets for Decision Trees of Signals
//!
//! Production-style reproduction of *Coresets for Decision Trees of
//! Signals* (Jubran, Sanches, Newman, Feldman — NeurIPS 2021).
//!
//! The library provides:
//!
//! * [`signal`] — 2D signals (matrices with a label in every cell),
//!   rectangular views, masks, and O(1) block statistics.
//! * [`segmentation`] — the k-segmentation model class (Definition 1) and
//!   exact DP solvers (1D, 2D guillotine k-tree, quadtree codec).
//! * [`bicriteria`] — the (α, β)_k rough approximation (Algorithm 4).
//! * [`partition`] — the balanced ("simplicial for SSE") partition
//!   (Algorithms 1–2).
//! * [`coreset`] — the headline (k, ε)-coreset construction (Algorithm 3),
//!   the FITTING-LOSS evaluator (Algorithm 5), Caratheodory compression,
//!   uniform-sampling baseline, and streaming merge-and-reduce.
//! * [`tree`] — weighted CART regression trees, random forests and
//!   gradient-boosted trees (the sklearn / LightGBM substitutes that
//!   consume the coreset).
//! * [`datasets`] — blobs/moons/circles and UCI-like tabular generators.
//! * [`experiments`] — the paper's evaluation harnesses (Fig. 4–7).
//! * [`pipeline`] — the L3 streaming coordinator: sharding, workers,
//!   merge-and-reduce, backpressure, metrics.
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas
//!   artifacts from `artifacts/*.hlo.txt`.

pub mod benchkit;
pub mod bicriteria;
pub mod cli;
pub mod coreset;
pub mod datasets;
pub mod experiments;
pub mod partition;
pub mod pipeline;
pub mod rng;
pub mod runtime;
pub mod segmentation;
pub mod signal;
pub mod tree;

pub mod proptest;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::coreset::{Coreset, SignalCoreset, WeightedPoint};
    pub use crate::rng::Rng;
    pub use crate::segmentation::KSegmentation;
    pub use crate::signal::{PrefixStats, Rect, Signal};
    pub use crate::tree::{forest::RandomForest, DecisionTree};
}
