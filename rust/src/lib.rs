//! # sigtree — Coresets for Decision Trees of Signals
//!
//! Production-style reproduction of *Coresets for Decision Trees of
//! Signals* (Jubran, Sanches, Newman, Feldman — NeurIPS 2021).
//!
//! The library provides:
//!
//! * [`engine`] — **the one front door**: a validated, serializable
//!   [`engine::EngineConfig`] and the long-lived [`engine::Engine`]
//!   session that owns the shared prefix statistics, the worker pool,
//!   and the kernel backend, and exposes build / region-build /
//!   stream / pipeline / batch-query / optimal-tree / audit in one
//!   place. Start here; the layers below are its plumbing.
//! * [`signal`] — 2D signals (matrices with a label in every cell),
//!   zero-copy rectangular views behind the [`signal::SignalSource`]
//!   seam, masks, and O(1) block statistics answerable for any
//!   sub-rectangle from one shared [`signal::PrefixStats`].
//! * [`segmentation`] — the k-segmentation model class (Definition 1) and
//!   exact DP solvers (1D, 2D guillotine k-tree, quadtree codec).
//! * [`bicriteria`] — the (α, β)_k rough approximation (Algorithm 4).
//! * [`partition`] — the balanced ("simplicial for SSE") partition
//!   (Algorithms 1–2).
//! * [`coreset`] — the headline (k, ε)-coreset construction (Algorithm 3),
//!   the FITTING-LOSS evaluator (Algorithm 5), Caratheodory compression,
//!   uniform-sampling baseline, and the persistent merge-and-reduce
//!   tree ([`coreset::merge_tree::MergeTree`]) behind the sharded
//!   build, streaming ingestion, and dirty-region incremental updates.
//! * [`sample`] — the sensitivity-sampling coreset family
//!   ([`sample::SensitivityCoreset`]): pluggable sensitivity algorithms
//!   (`unified` block residuals, `lightweight` row/col leverage,
//!   `uniform`) behind one [`sample::Sensitivity`] trait, deterministic
//!   seeded draws bit-identical across thread counts, plus the
//!   classification (0/1 misclassification) variant
//!   ([`sample::classify::ClassificationCoreset`]).
//! * [`tree`] — weighted CART regression trees, random forests and
//!   gradient-boosted trees (the sklearn / LightGBM substitutes that
//!   consume the coreset).
//! * [`datasets`] — blobs/moons/circles and UCI-like tabular generators.
//! * [`experiments`] — the paper's evaluation harnesses (Fig. 4–7).
//! * [`pipeline`] — the L3 streaming coordinator: sharding, workers,
//!   merge-and-reduce, backpressure, metrics.
//! * [`par`] — the std-only parallel construction engine (scoped-thread
//!   worker pool) behind [`coreset::SignalCoreset::construct_sharded`],
//!   [`signal::PrefixStats::new_par`], and the batch fitting-loss API.
//! * [`audit`] — the empirical ε-guarantee audit engine: adversarial
//!   query-family sweeps, the optimal-tree-transfer check on DP-feasible
//!   instances, and a machine-readable JSON evidence trail — the gate
//!   every perf PR must keep green.
//! * [`runtime`] — pluggable kernel backends behind one artifact
//!   contract: the pure-Rust [`runtime::NativeBackend`] (default) and,
//!   behind the off-by-default `pjrt` cargo feature, PJRT execution of
//!   the AOT-compiled JAX/Pallas artifacts from `artifacts/*.hlo.txt`.
//! * [`serve`] — the batched coreset-query daemon (`sigtree serve`):
//!   std-only HTTP/1.1 over one shared [`engine::Engine`], cross-request
//!   fitting-loss batching on the persistent worker pool (bit-identical
//!   to sequential evaluation), and an LRU coreset cache keyed by
//!   signal content digest × engine-config digest.
//! * [`error`] — the crate-wide error/result types (std-only `anyhow`
//!   substitute).
//! * [`json`] — hand-rolled JSON (the machine-readable evidence-trail
//!   format of `audit` and the benches, and the on-disk format of
//!   engine config files; std-only serde substitute).
//! * [`analysis`] — the determinism & panic-freedom static-analysis
//!   pass over the crate's own sources (`sigtree lint`): panic-freedom,
//!   deterministic-module hygiene, `// SAFETY:` discipline, error
//!   discipline, and deprecated-shim delegation, with an inline
//!   `lint:allow` escape hatch and a byte-stable JSON report.

pub mod analysis;
pub mod audit;
pub mod benchkit;
pub mod bicriteria;
pub mod cli;
pub mod coreset;
pub mod datasets;
pub mod engine;
pub mod error;
pub mod experiments;
pub mod json;
pub mod par;
pub mod partition;
pub mod pipeline;
pub mod rng;
pub mod runtime;
pub mod sample;
pub mod segmentation;
pub mod serve;
pub mod signal;
pub mod tree;

pub mod proptest;

/// Convenience re-exports for downstream users and the examples.
///
/// Doc-tested quickstart (the minimal end-to-end path every example
/// builds on — one [`engine::Engine`] front door: signal → coreset →
/// queries → kernel backend):
///
/// ```
/// use sigtree::prelude::*;
/// use sigtree::runtime::{KernelBackend, TILE};
///
/// // One validated config, one long-lived engine.
/// let engine = Engine::new(EngineConfig::new(4, 0.3).with_threads(2)).unwrap();
///
/// // A small signal, its (k, ε)-coreset, and a query — all through
/// // the engine (stats shared, worker pool reused across calls).
/// let signal = Signal::from_fn(64, 48, |r, c| ((r + 2 * c) % 7) as f64);
/// let session = engine.session(&signal);
/// let coreset = session.coreset();
/// let cells = signal.len() as f64;
/// assert!((coreset.total_weight() - cells).abs() < 1e-6 * cells);
///
/// let query = KSegmentation::constant(signal.bounds(), 1.0);
/// let approx = engine.fitting_loss(&coreset, std::slice::from_ref(&query))[0];
/// let exact = session.exact_loss(&query);
/// assert!((approx - exact).abs() <= 1e-6 * (1.0 + exact));
///
/// // The engine also owns the kernel backend ("native" by default),
/// // which answers the same block statistics in f32.
/// let mut tile = vec![0.0f32; TILE * TILE];
/// for r in 0..signal.rows() {
///     for c in 0..signal.cols() {
///         tile[r * TILE + c] = signal.get(r, c) as f32;
///     }
/// }
/// let (ii_y, _ii_y2) = engine.backend().prefix2d(&tile).unwrap();
/// let whole = Rect::new(0, signal.rows() - 1, 0, signal.cols() - 1);
/// let sum_native = session.stats().sum(&whole);
/// // Bottom-right corner of the zero-padded region's integral image.
/// let sum_kernel = ii_y[(signal.rows() - 1) * TILE + (signal.cols() - 1)] as f64;
/// assert!((sum_native - sum_kernel).abs() < 1e-3 * (1.0 + sum_native.abs()));
/// ```
pub mod prelude {
    pub use crate::audit::{run_audit, AuditConfig, AuditReport};
    pub use crate::coreset::{Coreset, SignalCoreset, WeightedPoint};
    pub use crate::engine::{BackendChoice, EditSession, Engine, EngineConfig, EngineSession};
    pub use crate::rng::Rng;
    pub use crate::sample::{SampleAlgorithm, SampleParams, SensitivityCoreset};
    pub use crate::segmentation::KSegmentation;
    pub use crate::signal::{PrefixStats, Rect, Signal, SignalSource, SignalView};
    pub use crate::tree::{forest::RandomForest, DecisionTree};
}
