//! Bi-criteria (α, β)_k approximation — Section 2 / Algorithm 4 of the
//! paper. Its only role downstream (Algorithm 3, Line 2) is to produce a
//! scalar `σ ≤ opt_k(D)` that calibrates the balanced partition's
//! per-block tolerance, plus the nominal (α, β) pair that sizes γ.
//!
//! We implement two estimators:
//!
//! * [`grid_lower_bound`] — a *certified* lower bound on opt_k(D): carve
//!   the grid into p×q equal bands; any k-segmentation has at most 2k
//!   horizontal and 2k vertical boundary lines, each crossing at most q
//!   (resp. p) grid blocks, so at least pq − 2k(p+q) blocks are assigned a
//!   single value by it; by Observation 9 the sum of the pq − 2k(p+q)
//!   smallest opt₁ values lower-bounds opt_k(D). Iterated on the
//!   still-uncovered cells this is exactly the peel-and-recurse structure
//!   of Lemma 10, specialised to full grids (our inputs are always full
//!   signals; the per-element variant in Algorithm 4 reduces to this when
//!   every coordinate is present).
//!
//! * [`greedy_upper`] — a fast O(βk)-segment greedy slice segmentation
//!   whose loss ℓ(D, s) is the paper's ℓ(D, s) for a concrete
//!   (α, β)_k-approximation s; `σ = ℓ(D, s)/α` then matches Algorithm 3
//!   literally. Used when the grid is too small for the certified bound
//!   (pq ≤ 2k(p+q), e.g. tabular matrices with few columns and large k —
//!   the paper's own experimental regime).
//!
//! [`bicriteria`] picks the certified bound when it is informative and
//! falls back to the greedy estimate otherwise; a smaller σ only makes
//! the coreset finer (never violates the ε-guarantee), see DESIGN.md.

use crate::signal::{PrefixStats, Rect};

/// Output of the bi-criteria stage: everything Algorithm 3 needs.
#[derive(Clone, Debug)]
pub struct Bicriteria {
    /// Lower-bound estimate of opt_k(D) (certified when `certified`).
    pub sigma: f64,
    /// Loss of the concrete (α, β)_k approximation (ℓ(D, s)).
    pub loss: f64,
    /// The α in the (α, β)_k guarantee (k log N flavour).
    pub alpha: f64,
    /// The β (the approximation uses up to βk segments).
    pub beta: f64,
    /// True if `sigma` is a certified lower bound on opt_k(D).
    pub certified: bool,
}

/// Certified lower bound on opt_k(D) via grid-block selection, iterated
/// `rounds` times on progressively finer grids (finer grids capture loss
/// at smaller scales; we keep the best bound). Returns `None` when no
/// grid granularity satisfies pq > 2k(p+q) (grid too small for this k).
pub fn grid_lower_bound(stats: &PrefixStats, k: usize, rounds: usize) -> Option<f64> {
    grid_lower_bound_in(stats, stats.bounds(), k, rounds)
}

/// [`grid_lower_bound`] restricted to `region` — all grid blocks are
/// sub-rectangles of `region`, answered by the same globally built
/// `stats` (shards never build their own integral images).
pub fn grid_lower_bound_in(
    stats: &PrefixStats,
    region: Rect,
    k: usize,
    rounds: usize,
) -> Option<f64> {
    let n = region.height();
    let m = region.width();
    // Shape adjustment: grow an axis until the counting argument
    // pq > 2k(p+q) holds. This is pure feasibility search and must not
    // consume `rounds` — the old accounting burned one round per
    // doubling, so small-grid/large-k shapes (several doublings away
    // from feasibility) exhausted the default 4-round budget and
    // returned `None` even though a certified bound existed.
    let mut p = (4 * k + 1).min(n);
    let mut q = (4 * k + 1).min(m);
    while p * q <= 2 * k * (p + q) {
        if p < n {
            p = (p * 2).min(n);
        } else if q < m {
            q = (q * 2).min(m);
        } else {
            // No granularity of this grid supports the argument.
            return None;
        }
    }
    // Geometric ladder of granularities; every rung is a valid lower
    // bound, keep the max. Feasibility is preserved under doubling:
    // pq > 2k(p+q) forces p > 2k and q > 2k, and the margin is then
    // monotone in each axis.
    let mut best: Option<f64> = None;
    for _ in 0..rounds.max(1) {
        let bound = grid_bound_once(stats, region, k, p, q);
        best = Some(best.map_or(bound, |b: f64| b.max(bound)));
        if p >= n && q >= m {
            break;
        }
        p = (p * 2).min(n);
        q = (q * 2).min(m);
    }
    best
}

/// One grid round: p row-bands × q col-bands of `region`, keep the
/// pq − 2k(p+q) smallest opt₁ values.
fn grid_bound_once(stats: &PrefixStats, region: Rect, k: usize, p: usize, q: usize) -> f64 {
    let row_edges = band_edges(region.height(), p);
    let col_edges = band_edges(region.width(), q);
    let mut losses: Vec<f64> = Vec::with_capacity(p * q);
    for rw in row_edges.windows(2) {
        for cw in col_edges.windows(2) {
            let rect = Rect::new(
                region.r0 + rw[0],
                region.r0 + rw[1] - 1,
                region.c0 + cw[0],
                region.c0 + cw[1] - 1,
            );
            losses.push(stats.opt1(&rect));
        }
    }
    let keep = losses.len().saturating_sub(2 * k * (p + q));
    if keep == 0 {
        return 0.0;
    }
    losses.sort_by(|a, b| a.total_cmp(b));
    losses[..keep].iter().sum()
}

/// Split `[0, n)` into `bands` near-equal contiguous intervals; returns
/// bands+1 edges.
pub fn band_edges(n: usize, bands: usize) -> Vec<usize> {
    let bands = bands.clamp(1, n);
    let mut edges = Vec::with_capacity(bands + 1);
    for i in 0..=bands {
        edges.push(i * n / bands);
    }
    edges.dedup();
    edges
}

/// Greedy (α, β)_k upper bound: the loss of a greedy βk-leaf tree
/// ([`crate::segmentation::greedy::greedy_tree`]) — a concrete
/// βk-segmentation s, so ℓ(D, s) ≥ opt_{βk}(D) and (heuristically)
/// ℓ(D, s) ≤ α · opt_k(D). O(budget · (n + m)) with O(1) opt₁ queries.
pub fn greedy_upper(stats: &PrefixStats, budget: usize) -> f64 {
    crate::segmentation::greedy::greedy_tree_loss(stats, budget.max(1))
}

/// [`greedy_upper`] restricted to `region` of the shared statistics.
pub fn greedy_upper_in(stats: &PrefixStats, region: Rect, budget: usize) -> f64 {
    crate::segmentation::greedy::greedy_tree_loss_on(stats, region, budget.max(1))
}

/// Nominal (α, β) constants used by Algorithm 3 to derive γ; kept small
/// (the paper's worst-case k^{O(1)} log² N blows γ to uselessness for any
/// real input — see the paper's own §4 "Coreset size" discussion; the
/// open-source reference code uses constant β as well).
pub fn nominal_alpha_beta(n: usize, m: usize, k: usize) -> (f64, f64) {
    let logn = ((n * m) as f64).ln().max(1.0);
    let alpha = (k as f64).max(1.0) * logn;
    let beta = 2.0; // practical constant; theory: k^{O(1)} log² N
    (alpha, beta)
}

/// The bi-criteria stage used by `SIGNAL-CORESET`: certified grid bound
/// when informative, greedy estimate otherwise; σ is their max when both
/// exist and the greedy estimate stays below the certified ceiling
/// (σ must never exceed opt_k, and certified ≤ opt_k always holds).
pub fn bicriteria(stats: &PrefixStats, k: usize) -> Bicriteria {
    bicriteria_in(stats, stats.bounds(), k)
}

/// [`bicriteria`] restricted to `region`: the estimator the sharded
/// builders run per row-band against the one shared `PrefixStats` —
/// no per-shard integral images, no cropped signals. For
/// `region == stats.bounds()` this is exactly [`bicriteria`].
pub fn bicriteria_in(stats: &PrefixStats, region: Rect, k: usize) -> Bicriteria {
    let n = region.height();
    let m = region.width();
    let (alpha, beta) = nominal_alpha_beta(n, m, k);
    // σ estimation. Theory says σ = ℓ(D,s)/α with α = k log N, but for a
    // *good* s that divisor is ~100× too conservative, driving the
    // partition tolerance to zero and the coreset to ~N points (the same
    // pessimism the paper's §4 observes in its size bound). We instead
    // estimate opt_k's noise floor directly: a greedy tree with a
    // generous 4βk leaf budget captures essentially all structure k
    // leaves could, so its loss approximates the irreducible part of opt_k; halving
    // it gives the safety margin. The certified grid bound (≤ opt_k
    // unconditionally) is used whenever it is larger.
    // Cap the budget so greedy leaves keep ≥32 cells — at small N an
    // uncapped 4βk budget overfits the noise and drives σ (hence the
    // partition tolerance) to zero, collapsing the coreset to ~N points.
    let budget = ((4.0 * beta * k as f64) as usize)
        .min((n * m / 32).max(8))
        .max(8);
    let upper = greedy_upper_in(stats, region, budget);
    let certified = grid_lower_bound_in(stats, region, k, 4);
    let floor_estimate = upper / 2.0;
    match certified {
        Some(lb) if lb > 0.0 => Bicriteria {
            sigma: lb.max(floor_estimate),
            loss: upper,
            alpha,
            beta,
            certified: true,
        },
        _ => Bicriteria {
            sigma: floor_estimate,
            loss: upper,
            alpha,
            beta,
            certified: false,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::segmentation::dp2d::opt_k_tree;
    use crate::signal::{generate, PrefixStats, Signal};

    #[test]
    fn band_edges_cover_exactly() {
        for n in [1, 5, 17, 100] {
            for b in [1, 2, 3, 7, 100] {
                let e = band_edges(n, b);
                assert_eq!(*e.first().unwrap(), 0);
                assert_eq!(*e.last().unwrap(), n);
                for w in e.windows(2) {
                    assert!(w[0] < w[1]);
                }
            }
        }
    }

    #[test]
    fn grid_bound_is_true_lower_bound_small() {
        // On instances small enough for the exact DP, the certified bound
        // must never exceed opt_k over trees (trees ⊆ segmentations means
        // opt over segmentations ≤ opt over trees; our bound must be below
        // the segmentation optimum, hence below the tree optimum too).
        let mut rng = Rng::new(42);
        for trial in 0..5 {
            let sig = generate::noise(12, 12, 1.0, &mut rng);
            let stats = PrefixStats::new(&sig);
            for k in [1, 2, 3] {
                if let Some(lb) = grid_lower_bound(&stats, k, 4) {
                    let opt = opt_k_tree(&stats, k);
                    assert!(
                        lb <= opt + 1e-9,
                        "trial {trial} k={k}: lb {lb} > opt {opt}"
                    );
                }
            }
        }
    }

    #[test]
    fn shape_adjustment_does_not_consume_rounds() {
        // Narrow-matrix shapes need several doublings of the row axis
        // before pq > 2k(p+q) holds. Those doublings used to consume
        // `rounds` iterations, so these inputs returned None even though
        // a certified bound exists.
        let mut rng = Rng::new(77);
        // Two doublings needed (p: 21 → 42 → 84 at q = 12, k = 5): with a
        // 1-round budget the old accounting never computed a bound.
        let sig = generate::noise(200, 12, 1.0, &mut rng);
        let stats = PrefixStats::new(&sig);
        let lb = grid_lower_bound(&stats, 5, 1);
        assert!(lb.is_some(), "bound must exist after shape adjustment");
        assert!(lb.unwrap() > 0.0, "multi-cell noise blocks have opt1 > 0");
        // Large-k flavour: four doublings (p: 81 → … → 1296 at q = 42,
        // k = 20) exhausted the default 4-round budget entirely.
        let sig = generate::noise(2000, 42, 1.0, &mut rng);
        let stats = PrefixStats::new(&sig);
        assert!(grid_lower_bound(&stats, 20, 4).is_some());
    }

    #[test]
    fn grid_bound_zero_for_constant() {
        let sig = Signal::constant(50, 50, 2.0);
        let stats = PrefixStats::new(&sig);
        let lb = grid_lower_bound(&stats, 2, 3).unwrap_or(0.0);
        assert!(lb.abs() < 1e-12);
    }

    #[test]
    fn greedy_upper_bounds_opt1_below() {
        // greedy with budget ≥ 1 is ≤ opt_1 (it can always return the
        // whole-signal fit), and ≥ 0.
        let mut rng = Rng::new(3);
        let sig = generate::smooth(30, 20, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let whole = sig.bounds();
        let u = greedy_upper(&stats, 16);
        assert!(u <= stats.opt1(&whole) + 1e-9);
        assert!(u >= 0.0);
    }

    #[test]
    fn greedy_upper_decreases_with_budget() {
        let mut rng = Rng::new(4);
        let sig = generate::image_like(40, 40, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let mut prev = f64::INFINITY;
        for budget in [2, 8, 32, 128] {
            let u = greedy_upper(&stats, budget);
            assert!(u <= prev + 1e-9, "budget {budget}");
            prev = u;
        }
    }

    #[test]
    fn bicriteria_sigma_below_optk_on_piecewise() {
        // Noiseless piecewise-constant with k* pieces: opt_{k*} = 0, and
        // σ for k ≥ k* must be ~0.
        let mut rng = Rng::new(11);
        let (sig, _) = generate::piecewise_constant(24, 24, 4, 0.0, &mut rng);
        let stats = PrefixStats::new(&sig);
        let bc = bicriteria(&stats, 8);
        assert!(bc.sigma < 1e-9, "sigma {}", bc.sigma);
    }

    #[test]
    fn bicriteria_sigma_positive_on_noise() {
        let mut rng = Rng::new(12);
        let sig = generate::noise(60, 60, 1.0, &mut rng);
        let stats = PrefixStats::new(&sig);
        let bc = bicriteria(&stats, 3);
        assert!(bc.sigma > 0.0);
        assert!(bc.loss > 0.0);
        assert!(bc.alpha >= 1.0 && bc.beta >= 1.0);
    }

    #[test]
    fn region_bicriteria_tracks_cropped_stats() {
        // The shard path estimates σ for a row-band against the shared
        // global statistics; it must agree with the old crop-and-rebuild
        // estimate. Exact equality is not guaranteed (global prefixes
        // subtract where local ones accumulate, and a ~1e-12 gain tie can
        // flip one greedy cut), so assert tight relative agreement.
        let mut rng = Rng::new(33);
        let sig = generate::smooth(120, 40, 3, &mut rng);
        let stats = PrefixStats::new(&sig);
        let band = Rect::new(40, 99, 0, 39);
        let shared = bicriteria_in(&stats, band, 4);
        let local = bicriteria(&PrefixStats::new(&sig.crop(band)), 4);
        assert_eq!(shared.certified, local.certified);
        assert!(
            (shared.sigma - local.sigma).abs() <= 0.02 * (1.0 + local.sigma),
            "sigma {} vs {}",
            shared.sigma,
            local.sigma
        );
        assert!(
            (shared.loss - local.loss).abs() <= 0.02 * (1.0 + local.loss),
            "loss {} vs {}",
            shared.loss,
            local.loss
        );
    }

    #[test]
    fn certified_sigma_below_exact_opt() {
        let mut rng = Rng::new(21);
        let sig = generate::smooth(14, 14, 2, &mut rng);
        let stats = PrefixStats::new(&sig);
        let k = 2;
        let bc = bicriteria(&stats, k);
        if bc.certified {
            let opt = opt_k_tree(&stats, k);
            // certified component lb ≤ opt; the max with upper/α can only
            // exceed if the greedy estimate does — tolerate small slack.
            assert!(bc.sigma <= opt.max(1e-12) * 1.5 + 1e-9);
        }
    }
}
