//! The ×10 reproduction harness (paper §5, Fig. 4 bottom): tune the
//! leaf budget on a compression instead of the full data and measure
//! both the wall-clock speedup and the held-out quality you pay for it.
//!
//! One sweep point is a `(k_coreset, ε)` pair. For each point and each
//! solver ([`Solver::RandomForest`], [`Solver::Gbdt`]) the harness
//! emits two rows at the *same* sample budget τ (the paper's fairness
//! rule, compression sizes matched):
//!
//! * `caratheodory` — [`tune_coreset`]: the deterministic
//!   bicriteria + partition + Caratheodory coreset, τ = its size;
//! * `sensitivity(unified)` — a [`SensitivityCoreset`] importance
//!   sample of exactly that τ, trained through the same grid sweep.
//!
//! Every row carries the coreset tuning time, the shared full-data
//! tuning time, their ratio (`speedup_vs_full` — the headline ×10 at
//! experiment scale), and the held-out SSE of the best tuned model on
//! compression vs. full (`sse_gap_pct`). The rows feed
//! `BENCH_forest.json` (benches/bench_forest.rs and the `x10` CLI
//! subcommand) and the bench gate's `forest` pair.

use std::time::Instant;

use crate::coreset::Coreset;
use crate::datasets;
use crate::json::Json;
use crate::rng::Rng;
use crate::sample::{SampleAlgorithm, SampleParams, SensitivityCoreset};
use crate::tree::Sample;

use super::tuning::{log_grid, tune_coreset, tune_full, TuningCurve};
use super::{test_sse, train, Solver};

/// The `(k_coreset, ε)` sweep: compression gets coarser left to right
/// while the coreset construction gets finer — the regime the paper
/// sweeps in Fig. 4.
pub const SWEEP: [(usize, f64); 3] = [(32, 0.4), (64, 0.3), (128, 0.2)];

/// Holdout protocol constants (§5: 30 % of the matrix as 5×5 patches).
pub const HOLDOUT_FRAC: f64 = 0.3;
pub const HOLDOUT_PATCH: usize = 5;

/// Harness parameters. `scale` is the generator's size knob for
/// [`datasets::air_quality_like`]; `grid` the number of candidate k
/// values on the tuning grid.
#[derive(Clone, Copy, Debug)]
pub struct X10Config {
    pub seed: u64,
    pub scale: f64,
    pub grid: usize,
    pub quick: bool,
}

impl X10Config {
    /// CI-sized: a small signal and a 3-point grid — seconds, not
    /// minutes. The JSON schema is identical to the full run.
    pub fn quick() -> Self {
        X10Config { seed: 7, scale: 0.05, grid: 3, quick: true }
    }

    /// Experiment-sized: the scale where the tuning speedup approaches
    /// the paper's headline figure.
    pub fn full() -> Self {
        X10Config { seed: 7, scale: 0.25, grid: 6, quick: false }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_scale(mut self, scale: f64) -> Self {
        assert!(scale > 0.0, "scale must be positive");
        self.scale = scale;
        self
    }

    pub fn with_grid(mut self, grid: usize) -> Self {
        self.grid = grid.max(2);
        self
    }
}

/// One emitted sweep row: a (solver, compression family, sweep point)
/// triple with its timing and quality measurements.
#[derive(Clone, Debug)]
pub struct X10Row {
    pub solver: Solver,
    /// `"caratheodory"` or `"sensitivity(unified)"`.
    pub family: &'static str,
    pub k: usize,
    pub eps: f64,
    /// Matched sample budget (the Caratheodory coreset's size).
    pub tau: usize,
    /// Tuning time on the compression (compress once + grid sweep).
    pub median_s: f64,
    /// Tuning time of the shared full-data sweep.
    pub full_median_s: f64,
    pub speedup_vs_full: f64,
    /// Held-out SSE of the best tuned model, full-data tuning.
    pub test_sse_full: f64,
    /// Held-out SSE of the best tuned model, compression tuning.
    pub test_sse_coreset: f64,
    /// 100 · (coreset − full) / full — positive means the compression
    /// paid quality for its speedup.
    pub sse_gap_pct: f64,
}

pub fn solver_name(solver: Solver) -> &'static str {
    match solver {
        Solver::RandomForest => "forest",
        Solver::Gbdt => "gbdt",
    }
}

/// Held-out SSE of the tuned (best-k) model on a curve.
fn sse_at_best(curve: &TuningCurve) -> f64 {
    let best = curve.best_k();
    curve
        .points
        .iter()
        .find(|&&(k, _)| k == best)
        .map_or(f64::INFINITY, |&(_, sse)| sse)
}

fn gap_pct(coreset_sse: f64, full_sse: f64) -> f64 {
    100.0 * (coreset_sse - full_sse) / full_sse.max(1e-12)
}

/// Tune on a sensitivity-sampling coreset of exactly `tau` budget:
/// compress once, sweep the grid on the compression — the same shape
/// as [`tune_coreset`], with the importance sampler in the compressor
/// seat.
pub fn tune_sensitivity(
    masked: &crate::signal::Signal,
    held: &[(usize, usize, f64)],
    grid: &[usize],
    k_coreset: usize,
    eps: f64,
    tau: usize,
    solver: Solver,
    seed: u64,
) -> TuningCurve {
    let mut rng = Rng::new(seed);
    let t0 = Instant::now();
    let params = SampleParams::new(k_coreset, eps, tau.max(1), seed);
    let coreset = SensitivityCoreset::build(masked, SampleAlgorithm::Unified, &params);
    let samples: Vec<Sample> = coreset
        .weighted_points()
        .iter()
        .map(Sample::from_point)
        .collect();
    let points = grid
        .iter()
        .map(|&k| {
            let model = train(solver, &samples, k, &mut rng);
            (k, test_sse(&model, held))
        })
        .collect();
    TuningCurve {
        scheme: format!("SensitivityCoreset(τ={tau})"),
        points,
        compression_size: samples.len(),
        total_time: t0.elapsed(),
    }
}

/// Run the sweep: for both solvers, one shared full-data tuning run
/// plus two compression rows per [`SWEEP`] point.
pub fn run(config: &X10Config) -> Vec<X10Row> {
    let mut rng = Rng::new(config.seed);
    let signal = datasets::air_quality_like(config.scale, &mut rng);
    let (masked, held) = datasets::holdout_patches(&signal, HOLDOUT_FRAC, HOLDOUT_PATCH, &mut rng);
    let grid = log_grid(4, 64, config.grid.max(2));

    let mut rows = Vec::new();
    for solver in [Solver::RandomForest, Solver::Gbdt] {
        let full = tune_full(&masked, &held, &grid, solver, config.seed);
        let full_secs = full.total_time.as_secs_f64();
        let full_sse = sse_at_best(&full);

        for (i, &(k, eps)) in SWEEP.iter().enumerate() {
            let point_seed = config.seed ^ (0x10 + i as u64);

            let core = tune_coreset(&masked, &held, &grid, k, eps, solver, point_seed);
            let tau = core.compression_size.max(1);
            let core_secs = core.total_time.as_secs_f64();
            let core_sse = sse_at_best(&core);
            rows.push(X10Row {
                solver,
                family: "caratheodory",
                k,
                eps,
                tau,
                median_s: core_secs,
                full_median_s: full_secs,
                speedup_vs_full: full_secs / core_secs.max(1e-12),
                test_sse_full: full_sse,
                test_sse_coreset: core_sse,
                sse_gap_pct: gap_pct(core_sse, full_sse),
            });

            let sens = tune_sensitivity(
                &masked,
                &held,
                &grid,
                k,
                eps,
                tau,
                solver,
                point_seed ^ 0x5E75,
            );
            let sens_secs = sens.total_time.as_secs_f64();
            let sens_sse = sse_at_best(&sens);
            rows.push(X10Row {
                solver,
                family: "sensitivity(unified)",
                k,
                eps,
                tau,
                median_s: sens_secs,
                full_median_s: full_secs,
                speedup_vs_full: full_secs / sens_secs.max(1e-12),
                test_sse_full: full_sse,
                test_sse_coreset: sens_sse,
                sse_gap_pct: gap_pct(sens_sse, full_sse),
            });
        }
    }
    rows
}

fn row_json(row: &X10Row) -> Json {
    Json::obj(vec![
        ("solver", Json::str(solver_name(row.solver))),
        ("family", Json::str(row.family)),
        ("k", Json::int(row.k)),
        ("eps", Json::num(row.eps)),
        ("tau", Json::int(row.tau)),
        ("median_s", Json::num(row.median_s)),
        ("full_median_s", Json::num(row.full_median_s)),
        ("speedup_vs_full", Json::num(row.speedup_vs_full)),
        ("test_sse_full", Json::num(row.test_sse_full)),
        ("test_sse_coreset", Json::num(row.test_sse_coreset)),
        ("sse_gap_pct", Json::num(row.sse_gap_pct)),
    ])
}

/// The `BENCH_forest.json` document (the bench gate's `forest` pair).
pub fn report_json(config: &X10Config, rows: &[X10Row]) -> Json {
    Json::obj(vec![
        ("bench", Json::str("forest")),
        ("provenance", Json::str("measured")),
        ("quick", Json::Bool(config.quick)),
        (
            "forest_case",
            Json::obj(vec![
                ("dataset", Json::str("air-quality-like")),
                ("scale", Json::num(config.scale)),
                ("grid", Json::int(config.grid)),
                ("seed", Json::str(format!("{:#x}", config.seed))),
                ("holdout_frac", Json::num(HOLDOUT_FRAC)),
                ("patch", Json::int(HOLDOUT_PATCH)),
                ("sweep_points", Json::int(SWEEP.len())),
            ]),
        ),
        ("forest_sweep", Json::Arr(rows.iter().map(row_json).collect())),
    ])
}

/// Human-readable table (the CLI's stdout).
pub fn summary(rows: &[X10Row]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<8} {:<22} {:>4} {:>5} {:>6} {:>9} {:>9} {:>8} {:>12}\n",
        "solver", "family", "k", "eps", "tau", "tune_s", "full_s", "speedup", "sse_gap_pct"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<8} {:<22} {:>4} {:>5} {:>6} {:>9.3} {:>9.3} {:>7.1}x {:>12.2}\n",
            solver_name(r.solver),
            r.family,
            r.k,
            r.eps,
            r.tau,
            r.median_s,
            r.full_median_s,
            r.speedup_vs_full,
            r.sse_gap_pct,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sweep_emits_both_families_for_both_solvers() {
        let config = X10Config::quick().with_scale(0.02).with_seed(5);
        let rows = run(&config);
        // 2 solvers × 3 sweep points × 2 families.
        assert_eq!(rows.len(), 2 * SWEEP.len() * 2);
        for r in &rows {
            assert!(r.tau >= 1);
            assert!(r.median_s >= 0.0 && r.full_median_s >= 0.0);
            assert!(r.speedup_vs_full.is_finite());
            assert!(r.test_sse_full.is_finite() && r.test_sse_coreset.is_finite());
        }
        // Matched budgets: the paired rows of a sweep point share τ.
        for pair in rows.chunks(2) {
            assert_eq!(pair[0].tau, pair[1].tau);
            assert_eq!(pair[0].family, "caratheodory");
            assert_eq!(pair[1].family, "sensitivity(unified)");
        }
    }

    #[test]
    fn report_schema_has_the_gate_keys() {
        let config = X10Config::quick().with_scale(0.02).with_seed(6);
        let rows = run(&config);
        let rendered = report_json(&config, &rows).render();
        for key in [
            "\"bench\": \"forest\"",
            "\"provenance\": \"measured\"",
            "\"quick\"",
            "\"forest_case\"",
            "\"forest_sweep\"",
            "\"speedup_vs_full\"",
            "\"sse_gap_pct\"",
        ] {
            assert!(rendered.contains(key), "missing {key} in\n{rendered}");
        }
        assert!(!summary(&rows).is_empty());
    }
}
