//! Experiment harnesses reproducing the paper's evaluation (§5 + App. A).
//!
//! The protocol of Fig. 4: hold out 30% of the dataset matrix as random
//! 5×5 patches; compress the remaining entries (coreset vs. uniform
//! sample of the same size); train forests on the compression; tune the
//! hyperparameter k on the compression; report test-set SSE and time.

pub mod tuning;
pub mod x10;

use std::time::{Duration, Instant};

use crate::coreset::uniform::UniformSample;
use crate::coreset::{Coreset, SignalCoreset};
use crate::datasets;
use crate::rng::Rng;
use crate::signal::Signal;
use crate::tree::forest::{ForestParams, RandomForest};
use crate::tree::gbdt::{Gbdt, GbdtParams};
use crate::tree::Sample;

/// Which forest implementation plays the "existing solver" role.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solver {
    /// Our sklearn RandomForestRegressor substitute.
    RandomForest,
    /// Our LightGBM LGBMRegressor substitute.
    Gbdt,
}

/// A trained model behind either solver.
pub enum Model {
    Forest(RandomForest),
    Gbdt(Gbdt),
}

impl Model {
    pub fn predict(&self, x: &[f64]) -> f64 {
        match self {
            Model::Forest(f) => f.predict(x),
            Model::Gbdt(g) => g.predict(x),
        }
    }
}

/// Train the chosen solver on weighted samples with `k` leaves per tree.
pub fn train(solver: Solver, samples: &[Sample], k: usize, rng: &mut Rng) -> Model {
    match solver {
        Solver::RandomForest => {
            let params = ForestParams::default().with_trees(10).with_max_leaves(k);
            Model::Forest(RandomForest::fit(samples, &params, rng))
        }
        Solver::Gbdt => {
            let params = GbdtParams::default()
                .with_stages(20)
                .with_leaves(k.clamp(2, 64));
            Model::Gbdt(Gbdt::fit(samples, &params, rng))
        }
    }
}

/// Test-set SSE of a model on held-out cells.
pub fn test_sse(model: &Model, held: &[(usize, usize, f64)]) -> f64 {
    held.iter()
        .map(|&(r, c, y)| {
            let d = model.predict(&[r as f64, c as f64]) - y;
            d * d
        })
        .sum()
}

/// One compression scheme's outcome on the missing-values task.
#[derive(Clone, Debug)]
pub struct CompressionOutcome {
    pub scheme: String,
    pub size: usize,
    pub compression_ratio: f64,
    pub build_time: Duration,
    pub train_time: Duration,
    pub test_sse: f64,
}

/// The §5 experiment for one dataset and one ε:
/// returns (coreset outcome, uniform-sample outcome at equal size).
pub fn missing_values_experiment(
    signal: &Signal,
    k_coreset: usize,
    eps: f64,
    k_train: usize,
    solver: Solver,
    seed: u64,
) -> (CompressionOutcome, CompressionOutcome) {
    let mut rng = Rng::new(seed);
    let (masked, held) = datasets::holdout_patches(signal, 0.3, 5, &mut rng);

    // Coreset.
    let t0 = Instant::now();
    let coreset = SignalCoreset::construct(&masked, k_coreset, eps);
    let cs_build = t0.elapsed();
    let cs_samples: Vec<Sample> = coreset
        .weighted_points()
        .iter()
        .map(Sample::from_point)
        .collect();
    let t0 = Instant::now();
    let cs_model = train(solver, &cs_samples, k_train, &mut rng);
    let cs_train = t0.elapsed();
    let cs_out = CompressionOutcome {
        scheme: "DT-coreset".into(),
        size: cs_samples.len(),
        compression_ratio: cs_samples.len() as f64 / masked.present() as f64,
        build_time: cs_build,
        train_time: cs_train,
        test_sse: test_sse(&cs_model, &held),
    };

    // Uniform sample of the same size (the paper's fairness rule).
    let t0 = Instant::now();
    let us = UniformSample::build(&masked, cs_samples.len().max(1), &mut rng);
    let us_build = t0.elapsed();
    let us_samples: Vec<Sample> = us.weighted_points().iter().map(Sample::from_point).collect();
    let t0 = Instant::now();
    let us_model = train(solver, &us_samples, k_train, &mut rng);
    let us_train = t0.elapsed();
    let us_out = CompressionOutcome {
        scheme: "RandomSample".into(),
        size: us_samples.len(),
        compression_ratio: us_samples.len() as f64 / masked.present() as f64,
        build_time: us_build,
        train_time: us_train,
        test_sse: test_sse(&us_model, &held),
    };
    (cs_out, us_out)
}

/// Baseline: train on the full (masked) data, report SSE and time.
pub fn full_data_baseline(
    signal: &Signal,
    k_train: usize,
    solver: Solver,
    seed: u64,
) -> CompressionOutcome {
    let mut rng = Rng::new(seed);
    let (masked, held) = datasets::holdout_patches(signal, 0.3, 5, &mut rng);
    let samples = datasets::signal_to_samples(&masked);
    let t0 = Instant::now();
    let model = train(solver, &samples, k_train, &mut rng);
    let train_time = t0.elapsed();
    CompressionOutcome {
        scheme: "FullData".into(),
        size: samples.len(),
        compression_ratio: 1.0,
        build_time: Duration::ZERO,
        train_time,
        test_sse: test_sse(&model, &held),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_values_pipeline_runs() {
        let mut rng = Rng::new(80);
        let sig = datasets::air_quality_like(0.03, &mut rng);
        let (cs, us) = missing_values_experiment(&sig, 50, 0.4, 20, Solver::RandomForest, 1);
        assert_eq!(cs.size, us.size);
        assert!(cs.test_sse.is_finite() && us.test_sse.is_finite());
        assert!(cs.compression_ratio < 1.0);
    }

    #[test]
    fn full_baseline_runs() {
        let mut rng = Rng::new(81);
        let sig = datasets::gesture_phase_like(0.02, &mut rng);
        let out = full_data_baseline(&sig, 20, Solver::RandomForest, 2);
        assert!(out.test_sse.is_finite());
        assert_eq!(out.compression_ratio, 1.0);
    }

    #[test]
    fn gbdt_solver_works_too() {
        let mut rng = Rng::new(82);
        let sig = datasets::air_quality_like(0.02, &mut rng);
        let (cs, _) = missing_values_experiment(&sig, 30, 0.4, 16, Solver::Gbdt, 3);
        assert!(cs.test_sse.is_finite());
    }
}
