//! AutoML / hyperparameter tuning on the coreset (contribution (iv) of
//! the paper, Fig. 4 bottom): sweep the leaf budget k over a logarithmic
//! grid, train on either the full data or a compression, and pick the k
//! with the best held-out loss. The coreset is built **once** and reused
//! for every candidate k — that is the source of the ×10 speedup.

use std::time::{Duration, Instant};

use crate::coreset::uniform::UniformSample;
use crate::coreset::{Coreset, SignalCoreset};
use crate::datasets;
use crate::rng::Rng;
use crate::signal::Signal;
use crate::tree::Sample;

use super::{test_sse, train, Solver};

/// A logarithmic grid of candidate k values in [lo, hi].
pub fn log_grid(lo: usize, hi: usize, count: usize) -> Vec<usize> {
    assert!(lo >= 1 && hi >= lo && count >= 1);
    let (lo_f, hi_f) = (lo as f64, hi as f64);
    let mut out: Vec<usize> = (0..count)
        .map(|i| {
            let t = i as f64 / (count.max(2) - 1) as f64;
            (lo_f * (hi_f / lo_f).powf(t)).round() as usize
        })
        .collect();
    out.dedup();
    out
}

/// The loss curve of a tuning sweep: (k, test SSE) per candidate, plus
/// the total time spent (compression + all training runs).
#[derive(Clone, Debug)]
pub struct TuningCurve {
    pub scheme: String,
    pub points: Vec<(usize, f64)>,
    pub compression_size: usize,
    pub total_time: Duration,
}

impl TuningCurve {
    /// The k minimizing the paper's regularized objective ℓ + k/10⁵.
    pub fn best_k(&self) -> usize {
        self.points
            .iter()
            .map(|&(k, l)| (k, l + k as f64 / 1e5))
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .map(|(k, _)| k)
            .unwrap_or(0)
    }
}

/// Tune on the full data (the paper's "standard tuning").
pub fn tune_full(
    masked: &Signal,
    held: &[(usize, usize, f64)],
    grid: &[usize],
    solver: Solver,
    seed: u64,
) -> TuningCurve {
    let mut rng = Rng::new(seed);
    let samples = datasets::signal_to_samples(masked);
    let t0 = Instant::now();
    let points = grid
        .iter()
        .map(|&k| {
            let model = train(solver, &samples, k, &mut rng);
            (k, test_sse(&model, held))
        })
        .collect();
    TuningCurve {
        scheme: "FullData".into(),
        points,
        compression_size: samples.len(),
        total_time: t0.elapsed(),
    }
}

/// Tune on the coreset (compress once, sweep on the compression).
pub fn tune_coreset(
    masked: &Signal,
    held: &[(usize, usize, f64)],
    grid: &[usize],
    k_coreset: usize,
    eps: f64,
    solver: Solver,
    seed: u64,
) -> TuningCurve {
    let mut rng = Rng::new(seed);
    let t0 = Instant::now();
    let coreset = SignalCoreset::construct(masked, k_coreset, eps);
    let samples: Vec<Sample> = coreset
        .weighted_points()
        .iter()
        .map(Sample::from_point)
        .collect();
    let points = grid
        .iter()
        .map(|&k| {
            let model = train(solver, &samples, k, &mut rng);
            (k, test_sse(&model, held))
        })
        .collect();
    TuningCurve {
        scheme: format!("DT-coreset(eps={eps})"),
        points,
        compression_size: samples.len(),
        total_time: t0.elapsed(),
    }
}

/// Tune on a uniform sample of `size` points.
pub fn tune_uniform(
    masked: &Signal,
    held: &[(usize, usize, f64)],
    grid: &[usize],
    size: usize,
    solver: Solver,
    seed: u64,
) -> TuningCurve {
    let mut rng = Rng::new(seed);
    let t0 = Instant::now();
    let us = UniformSample::build(masked, size.max(1), &mut rng);
    let samples: Vec<Sample> = us.weighted_points().iter().map(Sample::from_point).collect();
    let points = grid
        .iter()
        .map(|&k| {
            let model = train(solver, &samples, k, &mut rng);
            (k, test_sse(&model, held))
        })
        .collect();
    TuningCurve {
        scheme: format!("RandomSample(τ={size})"),
        points,
        compression_size: samples.len(),
        total_time: t0.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_grid_shape() {
        let g = log_grid(2, 200, 6);
        assert_eq!(*g.first().unwrap(), 2);
        assert_eq!(*g.last().unwrap(), 200);
        for w in g.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn tuning_curves_run_and_pick_k() {
        let mut rng = Rng::new(90);
        let sig = datasets::air_quality_like(0.02, &mut rng);
        let (masked, held) = datasets::holdout_patches(&sig, 0.3, 5, &mut rng);
        let grid = log_grid(4, 64, 4);
        let full = tune_full(&masked, &held, &grid, Solver::RandomForest, 1);
        let core = tune_coreset(&masked, &held, &grid, 50, 0.4, Solver::RandomForest, 1);
        assert_eq!(full.points.len(), grid.len());
        assert_eq!(core.points.len(), grid.len());
        assert!(grid.contains(&full.best_k()));
        assert!(grid.contains(&core.best_k()));
        assert!(core.compression_size < full.compression_size);
    }

    #[test]
    fn coreset_tuning_is_faster_than_full() {
        let mut rng = Rng::new(91);
        let sig = datasets::air_quality_like(0.05, &mut rng);
        let (masked, held) = datasets::holdout_patches(&sig, 0.3, 5, &mut rng);
        let grid = log_grid(4, 64, 5);
        let full = tune_full(&masked, &held, &grid, Solver::RandomForest, 2);
        let core = tune_coreset(&masked, &held, &grid, 50, 0.5, Solver::RandomForest, 2);
        // The headline claim (directional version; the ×10 figure is
        // measured at the full experiment scale in bench_fig4).
        assert!(
            core.total_time < full.total_time,
            "coreset {:?} !< full {:?}",
            core.total_time,
            full.total_time
        );
    }
}
