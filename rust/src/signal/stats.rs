//! Prefix-sum ("integral image") statistics over a signal.
//!
//! This is the O(1) `opt₁` oracle that Lemmas 12/13 of the paper rely on:
//! after an O(N) preprocessing pass we can answer, for any rectangle `B`,
//!
//! * `count(B)`  — number of *present* cells,
//! * `sum(B)`    — Σ y over present cells,
//! * `sum_sq(B)` — Σ y² over present cells,
//! * `opt1(B)`   — min_c Σ (y − c)² = Σy² − (Σy)²/count  (the 1-segmentation
//!   loss, attained by the mean),
//!
//! each in O(1) via inclusion–exclusion. All accumulators are f64; `opt1`
//! clamps at zero to absorb floating-point cancellation on near-constant
//! blocks.

use super::{Rect, SignalSource};

/// Integral images of (count, Σy, Σy²) with one row/col of zero padding so
/// that queries need no boundary branches.
#[derive(Clone, Debug)]
pub struct PrefixStats {
    n: usize,
    m: usize,
    /// (m+1)-stride arrays, entry [(r+1)*(m+1) + (c+1)] = prefix over
    /// rows 0..=r, cols 0..=c.
    count: Vec<f64>,
    sum: Vec<f64>,
    sum_sq: Vec<f64>,
}

/// Aggregate moments of a rectangle: the triple the Caratheodory step
/// must preserve exactly.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Moments {
    pub count: f64,
    pub sum: f64,
    pub sum_sq: f64,
}

impl Moments {
    pub const ZERO: Moments = Moments { count: 0.0, sum: 0.0, sum_sq: 0.0 };

    /// Mean label (0 for empty blocks).
    #[inline]
    pub fn mean(&self) -> f64 {
        if self.count <= 0.0 {
            0.0
        } else {
            self.sum / self.count
        }
    }

    /// The optimal 1-segmentation loss: Σ(y − mean)².
    #[inline]
    pub fn opt1(&self) -> f64 {
        if self.count <= 0.0 {
            return 0.0;
        }
        (self.sum_sq - self.sum * self.sum / self.count).max(0.0)
    }

    /// SSE of fitting the constant `c` to this block: Σ(y − c)².
    #[inline]
    pub fn sse_to(&self, c: f64) -> f64 {
        (self.sum_sq - 2.0 * c * self.sum + c * c * self.count).max(0.0)
    }

    #[inline]
    pub fn add(&self, other: &Moments) -> Moments {
        Moments {
            count: self.count + other.count,
            sum: self.sum + other.sum,
            sum_sq: self.sum_sq + other.sum_sq,
        }
    }
}

/// Default column-block width for the blocked fills below (matches
/// [`crate::runtime::blocked::BLOCK`]; kept as a local constant so the
/// signal layer stays independent of the runtime layer).
const BLOCK_COLS: usize = 64;

/// Lane width of the vectorizable vertical-add pass: 4 f64 = one 256-bit
/// register, unrolled via slice patterns over exact-size chunks.
const LANE_F64: usize = 4;

/// Elementwise `dst[i] = up[i] + pref[i]` over one padded row, walked in
/// `block`-wide chunks of [`LANE_F64`]-wide exact lanes (remainder
/// scalar). Elementwise adds are order-independent per column, so this
/// pass is bit-stable under **any** blocking — the carry-propagation
/// half of the two-pass prefix fills (DESIGN.md §Kernels).
fn vadd_rows(dst: &mut [f64], up: &[f64], pref: &[f64], block: usize) {
    debug_assert!(dst.len() == up.len() && dst.len() == pref.len());
    let ups = up.chunks(block).zip(pref.chunks(block));
    for ((d, u), p) in dst.chunks_mut(block).zip(ups) {
        let mut d_lanes = d.chunks_exact_mut(LANE_F64);
        let mut u_lanes = u.chunks_exact(LANE_F64);
        let mut p_lanes = p.chunks_exact(LANE_F64);
        for ((dl, ul), pl) in (&mut d_lanes).zip(&mut u_lanes).zip(&mut p_lanes) {
            let [d0, d1, d2, d3] = dl else { continue };
            let ([u0, u1, u2, u3], [p0, p1, p2, p3]) = (ul, pl) else { continue };
            *d0 = *u0 + *p0;
            *d1 = *u1 + *p1;
            *d2 = *u2 + *p2;
            *d3 = *u3 + *p3;
        }
        let rem = u_lanes.remainder().iter().zip(p_lanes.remainder().iter());
        for (dv, (&uv, &pv)) in d_lanes.into_remainder().iter_mut().zip(rem) {
            *dv = uv + pv;
        }
    }
}

/// Build a zero-padded `(m+1)`-stride integral image over a dense
/// row-major `n × m` cell grid: entry `[(r+1)*(m+1) + (c+1)]` holds the
/// prefix over rows `0..=r`, cols `0..=c`. The shared construction
/// primitive behind both [`PrefixStats`]' per-signal arrays (which use
/// the mask-aware band fillers below on signal sources) and arbitrary
/// per-cell density grids (the audit's coreset-density oracle).
///
/// Two-pass blocked fill: a serial row-prefix scan into a scratch row,
/// then a vectorizable elementwise add of the padded row above
/// ([`vadd_rows`]). Per-element operations and operand order match the
/// classic one-pass recurrence exactly, so the result is bit-identical
/// to it.
pub fn padded_prefix_from_cells(n: usize, m: usize, cells: &[f64]) -> Vec<f64> {
    assert_eq!(cells.len(), n * m, "cell grid must be n*m");
    let stride = m + 1;
    let mut out = vec![0.0f64; (n + 1) * stride];
    let mut pref = vec![0.0f64; m];
    for r in 0..n {
        // Pass 1: serial row prefix into scratch — the carry chain.
        let mut acc = 0.0;
        for (dst, &v) in pref.iter_mut().zip(&cells[r * m..(r + 1) * m]) {
            acc += v;
            *dst = acc;
        }
        // Pass 2: vertical add of the padded row above.
        let (above, cur) = out[..(r + 2) * stride].split_at_mut((r + 1) * stride);
        let up = &above[r * stride..];
        vadd_rows(&mut cur[1..], &up[1..], &pref, BLOCK_COLS);
    }
    out
}

/// O(1) inclusion–exclusion rectangle query over a zero-padded
/// `(m+1)`-stride integral image — the one canonical copy of the
/// 4-corner arithmetic every prefix consumer shares.
#[inline]
pub fn padded_prefix_query(arr: &[f64], m: usize, rect: &Rect) -> f64 {
    let stride = m + 1;
    let (r0, r1, c0, c1) = (rect.r0, rect.r1 + 1, rect.c0, rect.c1 + 1);
    // lint:allow(index-hot) -- the four O(1) corner reads behind every
    // rect query; callers validate rect bounds (debug_assert upstream).
    arr[r1 * stride + c1] - arr[r0 * stride + c1] - arr[r1 * stride + c0] + arr[r0 * stride + c0]
}

/// Fill band-local prefix rows for signal rows `r0..r1` into
/// `(r1 - r0) × (m + 1)` slices: local row `lr` (at offset
/// `lr * (m + 1)`) holds the prefix over signal rows `r0..=r0+lr`, and
/// the virtual row *above* the band is zero (the first local row is
/// written without reading a predecessor, so disjoint bands can fill
/// concurrently). Column 0 of every row stays untouched (callers pass
/// zeroed buffers).
fn fill_band_local<S: SignalSource>(
    signal: &S,
    r0: usize,
    r1: usize,
    count: &mut [f64],
    sum: &mut [f64],
    sum_sq: &mut [f64],
) {
    let m = signal.cols();
    let stride = m + 1;
    // Virtual zero row above the band: one shared source slice keeps the
    // first local row on the same code path as the rest (`0.0 + x` is
    // bitwise `x` for the running accumulators — they are never `-0.0`,
    // since IEEE round-to-nearest addition only produces `-0.0` from
    // `-0.0 + -0.0`, and every accumulator starts at `+0.0`).
    let zeros = vec![0.0f64; stride];
    for (lr, r) in (r0..r1).enumerate() {
        // Running row accumulators avoid one extra pass; the row slices
        // from the source keep the inner loop free of (r, c) → index
        // arithmetic for owned signals and views alike.
        let row = signal.row_values(r);
        let row_mask = signal.row_mask(r);
        let mut row_cnt = 0.0;
        let mut row_sum = 0.0;
        let mut row_sq = 0.0;
        let off = lr * stride;
        let (c_above, c_cur) = count[..off + stride].split_at_mut(off);
        let (s_above, s_cur) = sum[..off + stride].split_at_mut(off);
        let (q_above, q_cur) = sum_sq[..off + stride].split_at_mut(off);
        let (c_up, s_up, q_up): (&[f64], &[f64], &[f64]) = if lr == 0 {
            (&zeros, &zeros, &zeros)
        } else {
            (&c_above[off - stride..], &s_above[off - stride..], &q_above[off - stride..])
        };
        let dst = c_cur[1..]
            .iter_mut()
            .zip(s_cur[1..].iter_mut())
            .zip(q_cur[1..].iter_mut());
        let up = c_up[1..].iter().zip(s_up[1..].iter()).zip(q_up[1..].iter());
        match row_mask {
            None => {
                for (&y, (((dc, ds), dq), ((&uc, &us), &uq))) in row.iter().zip(dst.zip(up)) {
                    row_cnt += 1.0;
                    row_sum += y;
                    row_sq += y * y;
                    *dc = uc + row_cnt;
                    *ds = us + row_sum;
                    *dq = uq + row_sq;
                }
            }
            Some(mask) => {
                for ((&y, &present), (((dc, ds), dq), ((&uc, &us), &uq))) in
                    row.iter().zip(mask.iter()).zip(dst.zip(up))
                {
                    if present {
                        row_cnt += 1.0;
                        row_sum += y;
                        row_sq += y * y;
                    }
                    *dc = uc + row_cnt;
                    *ds = us + row_sum;
                    *dq = uq + row_sq;
                }
            }
        }
    }
}

/// Two-pass blocked variant of [`fill_band_local`]: pass 1 walks each
/// row in `block`-wide column chunks computing the serial row prefixes
/// into scratch rows — the accumulators are **carried** across chunk
/// boundaries, so the addition chain is exactly the scalar recurrence's
/// and no block size can change a bit — and pass 2 adds the row above
/// elementwise in vectorizable lanes ([`vadd_rows`]; order-independent
/// per column, hence bit-stable under any blocking). Per-element
/// operations and operand order match [`fill_band_local`] exactly, so
/// the output is bit-identical to it for **every** `block` (DESIGN.md
/// §Kernels).
fn fill_band_blocked<S: SignalSource>(
    signal: &S,
    r0: usize,
    r1: usize,
    block: usize,
    count: &mut [f64],
    sum: &mut [f64],
    sum_sq: &mut [f64],
) {
    let m = signal.cols();
    let stride = m + 1;
    let block = block.max(1);
    // Scratch rows: the f64 row accumulators for (count, Σy, Σy²).
    let mut pref_cnt = vec![0.0f64; m];
    let mut pref_sum = vec![0.0f64; m];
    let mut pref_sq = vec![0.0f64; m];
    let zeros = vec![0.0f64; stride];
    for (lr, r) in (r0..r1).enumerate() {
        let row = signal.row_values(r);
        let row_mask = signal.row_mask(r);
        // Pass 1: serial row scan in column blocks, accumulators carried
        // across blocks (bit-equal to the scalar scan for any block).
        let mut row_cnt = 0.0;
        let mut row_sum = 0.0;
        let mut row_sq = 0.0;
        match row_mask {
            None => {
                let prefs = pref_cnt
                    .chunks_mut(block)
                    .zip(pref_sum.chunks_mut(block))
                    .zip(pref_sq.chunks_mut(block));
                for (vals, ((pc, ps), pq)) in row.chunks(block).zip(prefs) {
                    let dst = pc.iter_mut().zip(ps.iter_mut()).zip(pq.iter_mut());
                    for (&y, ((dc, ds), dq)) in vals.iter().zip(dst) {
                        row_cnt += 1.0;
                        row_sum += y;
                        row_sq += y * y;
                        *dc = row_cnt;
                        *ds = row_sum;
                        *dq = row_sq;
                    }
                }
            }
            Some(mask) => {
                let prefs = pref_cnt
                    .chunks_mut(block)
                    .zip(pref_sum.chunks_mut(block))
                    .zip(pref_sq.chunks_mut(block));
                let src = row.chunks(block).zip(mask.chunks(block));
                for ((vals, mk), ((pc, ps), pq)) in src.zip(prefs) {
                    let dst = pc.iter_mut().zip(ps.iter_mut()).zip(pq.iter_mut());
                    for ((&y, &present), ((dc, ds), dq)) in vals.iter().zip(mk.iter()).zip(dst) {
                        if present {
                            row_cnt += 1.0;
                            row_sum += y;
                            row_sq += y * y;
                        }
                        *dc = row_cnt;
                        *ds = row_sum;
                        *dq = row_sq;
                    }
                }
            }
        }
        // Pass 2: vertical add of the row above (virtual zeros for the
        // band's first row — bitwise identity, see fill_band_local).
        let off = lr * stride;
        let (c_above, c_cur) = count[..off + stride].split_at_mut(off);
        let (s_above, s_cur) = sum[..off + stride].split_at_mut(off);
        let (q_above, q_cur) = sum_sq[..off + stride].split_at_mut(off);
        let (c_up, s_up, q_up): (&[f64], &[f64], &[f64]) = if lr == 0 {
            (&zeros, &zeros, &zeros)
        } else {
            (&c_above[off - stride..], &s_above[off - stride..], &q_above[off - stride..])
        };
        vadd_rows(&mut c_cur[1..], &c_up[1..], &pref_cnt, block);
        vadd_rows(&mut s_cur[1..], &s_up[1..], &pref_sum, block);
        vadd_rows(&mut q_cur[1..], &q_up[1..], &pref_sq, block);
    }
}

impl PrefixStats {
    /// O(N) construction over any [`SignalSource`] (owned signal or
    /// zero-copy view). Masked-out cells contribute zero to every
    /// accumulator.
    pub fn new<S: SignalSource>(signal: &S) -> Self {
        let n = signal.rows();
        let m = signal.cols();
        let stride = m + 1;
        let mut count = vec![0.0; (n + 1) * stride];
        let mut sum = vec![0.0; (n + 1) * stride];
        let mut sum_sq = vec![0.0; (n + 1) * stride];
        fill_band_local(
            signal,
            0,
            n,
            &mut count[stride..],
            &mut sum[stride..],
            &mut sum_sq[stride..],
        );
        Self { n, m, count, sum, sum_sq }
    }

    /// Parallel construction on scoped worker threads: ~64-row bands each
    /// build their local integral images concurrently — written in place
    /// into the disjoint row ranges each band owns, so peak memory equals
    /// the sequential path — then a sequential O(n·m) add-only stitch
    /// shifts every band by the final global row of the band above it.
    ///
    /// The band plan *and* the summation order depend only on the signal
    /// shape — never on `threads` — so **every** thread count (including
    /// 1, which runs the same band fills sequentially) yields
    /// bit-identical statistics; this is what lets the sharded coreset
    /// builders share one `new_par` result and stay thread-count-
    /// invariant. All results match [`Self::new`] up to f64 reassociation
    /// noise (≲ 1e-12 relative). `threads == 0` uses all available
    /// cores; single-band signals fall back to the sequential path
    /// (a shape-only decision, so still thread-invariant).
    pub fn new_par<S: SignalSource>(signal: &S, threads: usize) -> Self {
        Self::new_par_exec(signal, crate::par::Exec::Spawn(threads))
    }

    /// [`Self::new_par`] on an explicit executor
    /// ([`crate::par::Exec`]): `Exec::Spawn(t)` reproduces `new_par`'s
    /// scoped-thread path, `Exec::Pool(&pool)` runs the band fills on a
    /// long-lived [`crate::par::WorkerPool`] — the
    /// [`crate::engine::Engine`] path, no per-call thread spinup. The
    /// band plan and every per-band float are executor-independent, so
    /// all variants are bit-identical.
    pub fn new_par_exec<S: SignalSource>(signal: &S, exec: crate::par::Exec<'_>) -> Self {
        Self::new_banded_with(signal, exec, fill_band_local::<S>)
    }

    /// Cache-blocked construction: the band-parallel plan of
    /// [`Self::new_par`] with [`fill_band_blocked`] as the per-band
    /// filler, so bands × column blocks nest. The blocked filler is
    /// bit-identical to the scalar one for every `block` (carried
    /// accumulators in pass 1, elementwise adds in pass 2 — DESIGN.md
    /// §Kernels), and the band plan is thread-invariant, so the result
    /// is bit-identical to [`Self::new`]/[`Self::new_par`] across
    /// **all** thread counts × block sizes. `block == 0` falls back to
    /// the default [`BLOCK_COLS`].
    pub fn new_blocked<S: SignalSource>(signal: &S, threads: usize, block: usize) -> Self {
        Self::new_blocked_exec(signal, crate::par::Exec::Spawn(threads), block)
    }

    /// [`Self::new_blocked`] on an explicit executor — the
    /// [`crate::engine::Engine`] path when the blocked backend is
    /// selected.
    pub fn new_blocked_exec<S: SignalSource>(
        signal: &S,
        exec: crate::par::Exec<'_>,
        block: usize,
    ) -> Self {
        let block = if block == 0 { BLOCK_COLS } else { block };
        let fill =
            move |sig: &S, r0: usize, r1: usize, c: &mut [f64], s: &mut [f64], q: &mut [f64]| {
                fill_band_blocked(sig, r0, r1, block, c, s, q)
            };
        Self::new_banded_with(signal, exec, fill)
    }

    /// The shared band-parallel construction plan, generic over the
    /// per-band filler: carve the padded arrays into disjoint per-band
    /// row slices, fill them (sequentially, on a long-lived pool, or on
    /// scoped threads), then stitch sequentially. Both
    /// [`Self::new_par_exec`] (scalar filler) and
    /// [`Self::new_blocked_exec`] (blocked filler) are thin wrappers.
    fn new_banded_with<S, F>(signal: &S, exec: crate::par::Exec<'_>, fill: F) -> Self
    where
        S: SignalSource,
        F: Fn(&S, usize, usize, &mut [f64], &mut [f64], &mut [f64]) + Copy + Send + Sync,
    {
        const BAND_ROWS: usize = 64;
        let threads = exec.threads();
        let n = signal.rows();
        let m = signal.cols();
        let bands = n.div_ceil(BAND_ROWS);
        if bands <= 1 {
            // Single-band fallback: one fill over the whole row range —
            // for the scalar filler this is exactly [`Self::new`].
            let stride = m + 1;
            let mut count = vec![0.0; (n + 1) * stride];
            let mut sum = vec![0.0; (n + 1) * stride];
            let mut sum_sq = vec![0.0; (n + 1) * stride];
            fill(
                signal,
                0,
                n,
                &mut count[stride..],
                &mut sum[stride..],
                &mut sum_sq[stride..],
            );
            return Self { n, m, count, sum, sum_sq };
        }
        let stride = m + 1;
        let ranges: Vec<(usize, usize)> = (0..bands)
            .map(|b| (b * BAND_ROWS, ((b + 1) * BAND_ROWS).min(n)))
            .collect();
        let mut count = vec![0.0; (n + 1) * stride];
        let mut sum = vec![0.0; (n + 1) * stride];
        let mut sum_sq = vec![0.0; (n + 1) * stride];
        // Phase 1 (parallel): band-local prefixes, each band writing its
        // own array rows r0+1 ..= r1 (disjoint `split_at_mut` slices).
        {
            type BandJob<'a> = ((usize, usize), (&'a mut [f64], &'a mut [f64], &'a mut [f64]));
            let mut jobs: Vec<BandJob<'_>> = Vec::with_capacity(bands);
            let mut c_rest: &mut [f64] = &mut count[stride..];
            let mut s_rest: &mut [f64] = &mut sum[stride..];
            let mut q_rest: &mut [f64] = &mut sum_sq[stride..];
            for &(r0, r1) in &ranges {
                let len = (r1 - r0) * stride;
                let (c_band, c_tail) = std::mem::take(&mut c_rest).split_at_mut(len);
                let (s_band, s_tail) = std::mem::take(&mut s_rest).split_at_mut(len);
                let (q_band, q_tail) = std::mem::take(&mut q_rest).split_at_mut(len);
                c_rest = c_tail;
                s_rest = s_tail;
                q_rest = q_tail;
                jobs.push(((r0, r1), (c_band, s_band, q_band)));
            }
            if threads <= 1 {
                // Same band fills, run in band order on this thread —
                // identical floats to the multi-threaded path (each band's
                // arithmetic is independent; only scheduling differs).
                for ((r0, r1), (c, s, q)) in jobs {
                    fill(signal, r0, r1, c, s, q);
                }
            } else if let crate::par::Exec::Pool(pool) = exec {
                // Long-lived pool path: each band job is claimed exactly
                // once through a `Mutex<Option<_>>` slot (the order-
                // preserving map wants `Fn`, the job owns `&mut`
                // slices). Bands write disjoint rows, so scheduling
                // cannot change a single float.
                let slots: Vec<std::sync::Mutex<Option<BandJob<'_>>>> =
                    jobs.into_iter().map(|j| std::sync::Mutex::new(Some(j))).collect();
                pool.map(&slots, |_, slot| {
                    // Each slot is `Some` exactly once by construction;
                    // a second visit (impossible: the map visits every
                    // index once) would be a silent no-op, not a panic.
                    if let Some(((r0, r1), (c, s, q))) = crate::par::lock(slot).take() {
                        fill(signal, r0, r1, c, s, q);
                    }
                });
            } else {
                // Static round-robin assignment: bands have near-equal
                // cost by construction, and &mut slices cannot go through
                // the shared-cursor pool.
                let workers = threads.min(jobs.len()).max(1);
                let mut assigned: Vec<Vec<BandJob<'_>>> =
                    (0..workers).map(|_| Vec::new()).collect();
                for (i, job) in jobs.into_iter().enumerate() {
                    // lint:allow(index-hot) -- O(bands) scheduling setup,
                    // not a kernel inner loop; `i % workers` is in-bounds
                    // by construction.
                    assigned[i % workers].push(job);
                }
                // lint:allow(det-thread) -- the one audited exception:
                // `&mut` band slices cannot ride the shared-cursor pool,
                // and bands own disjoint row ranges, so scheduling can
                // never reorder a single float (see the note above).
                std::thread::scope(|scope| {
                    for work in assigned {
                        scope.spawn(move || {
                            for ((r0, r1), (c, s, q)) in work {
                                fill(signal, r0, r1, c, s, q);
                            }
                        });
                    }
                });
            }
        }
        // Phase 2 (sequential O(n·m) stitch): band 0 is already global;
        // every later band adds the final global row the band above it
        // produced (pure adds, no branches — cheaper per cell than the
        // accumulation pass above).
        let mut off_cnt = vec![0.0; stride];
        let mut off_sum = vec![0.0; stride];
        let mut off_sq = vec![0.0; stride];
        for &(r0, r1) in ranges.iter().skip(1) {
            let off = r0 * stride;
            off_cnt.copy_from_slice(&count[off..off + stride]);
            off_sum.copy_from_slice(&sum[off..off + stride]);
            off_sq.copy_from_slice(&sum_sq[off..off + stride]);
            for t in (r0 + 1)..=r1 {
                let base = t * stride;
                let dst_c = &mut count[base + 1..base + stride];
                for (d, &o) in dst_c.iter_mut().zip(off_cnt[1..].iter()) {
                    *d += o;
                }
                let dst_s = &mut sum[base + 1..base + stride];
                for (d, &o) in dst_s.iter_mut().zip(off_sum[1..].iter()) {
                    *d += o;
                }
                let dst_q = &mut sum_sq[base + 1..base + stride];
                for (d, &o) in dst_q.iter_mut().zip(off_sq[1..].iter()) {
                    *d += o;
                }
            }
        }
        Self { n, m, count, sum, sum_sq }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.m
    }

    /// The full rectangle these statistics cover. Every query below is
    /// already rect-parameterized, so one globally built `PrefixStats`
    /// answers moments/SSE for **any** sub-rectangle — the builders pass
    /// `(&PrefixStats, Rect)` around instead of recomputing per-shard
    /// integral images (DESIGN.md §Views & Memory).
    #[inline]
    pub fn bounds(&self) -> Rect {
        Rect::new(0, self.n - 1, 0, self.m - 1)
    }

    #[inline]
    fn query(&self, arr: &[f64], rect: &Rect) -> f64 {
        padded_prefix_query(arr, self.m, rect)
    }

    /// All three moments of a rectangle in O(1).
    #[inline]
    pub fn moments(&self, rect: &Rect) -> Moments {
        debug_assert!(rect.r1 < self.n && rect.c1 < self.m, "rect out of bounds");
        Moments {
            count: self.query(&self.count, rect),
            sum: self.query(&self.sum, rect),
            sum_sq: self.query(&self.sum_sq, rect),
        }
    }

    /// Number of present cells in `rect`.
    #[inline]
    pub fn count(&self, rect: &Rect) -> f64 {
        self.query(&self.count, rect)
    }

    /// Σ y over present cells in `rect`.
    #[inline]
    pub fn sum(&self, rect: &Rect) -> f64 {
        self.query(&self.sum, rect)
    }

    /// Σ y² over present cells in `rect`.
    #[inline]
    pub fn sum_sq(&self, rect: &Rect) -> f64 {
        self.query(&self.sum_sq, rect)
    }

    /// Mean label of `rect` (0 if the rect is empty/masked out).
    #[inline]
    pub fn mean(&self, rect: &Rect) -> f64 {
        self.moments(rect).mean()
    }

    /// `opt₁(rect)`: the 1-segmentation SSE loss, in O(1).
    #[inline]
    pub fn opt1(&self, rect: &Rect) -> f64 {
        self.moments(rect).opt1()
    }

    /// SSE of fitting constant `c` to `rect`, in O(1).
    #[inline]
    pub fn sse_to(&self, rect: &Rect, c: f64) -> f64 {
        self.moments(rect).sse_to(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::signal::Signal;

    /// Brute-force moments for cross-checking.
    fn brute(signal: &Signal, rect: &Rect) -> Moments {
        let mut m = Moments::ZERO;
        for (r, c) in rect.cells() {
            if signal.is_present(r, c) {
                let y = signal.get(r, c);
                m.count += 1.0;
                m.sum += y;
                m.sum_sq += y * y;
            }
        }
        m
    }

    fn brute_opt1(signal: &Signal, rect: &Rect) -> f64 {
        let mom = brute(signal, rect);
        if mom.count == 0.0 {
            return 0.0;
        }
        let mean = mom.sum / mom.count;
        let mut loss = 0.0;
        for (r, c) in rect.cells() {
            if signal.is_present(r, c) {
                let d = signal.get(r, c) - mean;
                loss += d * d;
            }
        }
        loss
    }

    #[test]
    fn cell_grid_prefix_matches_prefix_stats() {
        // The generic cell-grid construction and the band-filled signal
        // path answer identical queries on an unmasked signal.
        let sig = Signal::from_fn(9, 7, |r, c| ((r * 5 + c * 3) % 13) as f64 - 6.0);
        let stats = PrefixStats::new(&sig);
        let from_cells = padded_prefix_from_cells(9, 7, sig.values());
        for r0 in 0..9 {
            for c0 in 0..7 {
                let rect = Rect::new(r0, 8, c0, 6);
                let a = stats.sum(&rect);
                let b = padded_prefix_query(&from_cells, 7, &rect);
                assert!((a - b).abs() <= 1e-9 * (1.0 + a.abs()), "{rect:?}");
            }
        }
    }

    #[test]
    fn moments_match_bruteforce_random_rects() {
        let mut rng = Rng::new(2024);
        let sig = Signal::from_fn(17, 23, |r, c| ((r * 7 + c * 13) % 11) as f64 - 5.0);
        let stats = PrefixStats::new(&sig);
        for _ in 0..200 {
            let r0 = rng.usize(17);
            let r1 = rng.range(r0, 17);
            let c0 = rng.usize(23);
            let c1 = rng.range(c0, 23);
            let rect = Rect::new(r0, r1, c0, c1);
            let a = stats.moments(&rect);
            let b = brute(&sig, &rect);
            assert_eq!(a.count, b.count);
            assert!((a.sum - b.sum).abs() < 1e-9);
            assert!((a.sum_sq - b.sum_sq).abs() < 1e-9);
        }
    }

    #[test]
    fn opt1_matches_bruteforce() {
        let mut rng = Rng::new(7);
        let sig = Signal::from_fn(12, 9, |r, c| {
            ((r as f64) * 0.3 - (c as f64) * 1.7).sin() * 4.0
        });
        let stats = PrefixStats::new(&sig);
        for _ in 0..100 {
            let r0 = rng.usize(12);
            let r1 = rng.range(r0, 12);
            let c0 = rng.usize(9);
            let c1 = rng.range(c0, 9);
            let rect = Rect::new(r0, r1, c0, c1);
            let fast = stats.opt1(&rect);
            let slow = brute_opt1(&sig, &rect);
            assert!(
                (fast - slow).abs() <= 1e-8 * (1.0 + slow),
                "rect {rect:?}: {fast} vs {slow}"
            );
        }
    }

    #[test]
    fn opt1_near_zero_for_constant_blocks() {
        // Inclusion–exclusion roundoff can leave a tiny positive residue;
        // the clamp guarantees non-negativity, and the residue must be at
        // machine-epsilon scale relative to Σy².
        let sig = Signal::constant(10, 10, 3.7);
        let stats = PrefixStats::new(&sig);
        let whole = Rect::new(0, 9, 0, 9);
        assert!(stats.opt1(&whole) >= 0.0);
        assert!(stats.opt1(&whole) <= 1e-9 * stats.sum_sq(&whole));
        let cell = Rect::new(3, 3, 4, 4);
        assert!(stats.opt1(&cell) <= 1e-12 * (1.0 + stats.sum_sq(&cell)));
    }

    #[test]
    fn masked_cells_are_excluded() {
        let mut sig = Signal::from_fn(6, 6, |r, c| (r * 6 + c) as f64);
        sig.mask_rect(Rect::new(0, 2, 0, 2));
        let stats = PrefixStats::new(&sig);
        let whole = sig.bounds();
        let mom = stats.moments(&whole);
        assert_eq!(mom.count, 36.0 - 9.0);
        let b = brute(&sig, &whole);
        assert!((mom.sum - b.sum).abs() < 1e-9);
        // Fully masked rect → zero moments, zero opt1.
        let dead = Rect::new(0, 2, 0, 2);
        assert_eq!(stats.count(&dead), 0.0);
        assert_eq!(stats.opt1(&dead), 0.0);
    }

    #[test]
    fn sse_to_constant_matches_signal_sse() {
        let sig = Signal::from_fn(8, 8, |r, c| ((r + 2 * c) % 5) as f64);
        let stats = PrefixStats::new(&sig);
        let rect = Rect::new(1, 6, 2, 7);
        let c = 1.9;
        let fast = stats.sse_to(&rect, c);
        let mut slow = 0.0;
        for (r, cc) in rect.cells() {
            let d = sig.get(r, cc) - c;
            slow += d * d;
        }
        assert!((fast - slow).abs() < 1e-9);
    }

    #[test]
    fn parallel_construction_matches_sequential() {
        let mut rng = Rng::new(2026);
        // Ragged height (not a multiple of the 64-row band), masked cells.
        let mut sig = Signal::from_fn(150, 37, |r, c| ((r * 13 + c * 29) % 17) as f64 - 8.0);
        sig.mask_rect(Rect::new(40, 90, 5, 20));
        let seq = PrefixStats::new(&sig);
        for threads in [0, 1, 2, 3, 4] {
            let par = PrefixStats::new_par(&sig, threads);
            for _ in 0..100 {
                let r0 = rng.usize(150);
                let r1 = rng.range(r0, 150);
                let c0 = rng.usize(37);
                let c1 = rng.range(c0, 37);
                let rect = Rect::new(r0, r1, c0, c1);
                let a = seq.moments(&rect);
                let b = par.moments(&rect);
                assert_eq!(a.count, b.count, "threads {threads} rect {rect:?}");
                let scale = 1.0 + a.sum.abs() + a.sum_sq.abs();
                assert!((a.sum - b.sum).abs() < 1e-9 * scale, "threads {threads}");
                assert!((a.sum_sq - b.sum_sq).abs() < 1e-9 * scale, "threads {threads}");
            }
        }
    }

    #[test]
    fn parallel_construction_small_signal_falls_back() {
        // Below one band the parallel path must be the sequential one.
        let sig = Signal::from_fn(20, 8, |r, c| (r + c) as f64);
        let seq = PrefixStats::new(&sig);
        let par = PrefixStats::new_par(&sig, 4);
        let whole = sig.bounds();
        assert_eq!(seq.moments(&whole), par.moments(&whole));
    }

    #[test]
    fn parallel_construction_is_thread_invariant() {
        // Band plan and summation order depend on shape only: every
        // thread count (1 included) must produce bit-identical arrays.
        let mut sig = Signal::from_fn(200, 23, |r, c| ((r * 31 + c * 7) % 13) as f64 - 6.0);
        sig.mask_rect(Rect::new(70, 80, 2, 9));
        let reference = PrefixStats::new_par(&sig, 1);
        for threads in [2, 3, 4, 8] {
            let par = PrefixStats::new_par(&sig, threads);
            assert_eq!(par.count, reference.count, "threads {threads}");
            assert_eq!(par.sum, reference.sum, "threads {threads}");
            assert_eq!(par.sum_sq, reference.sum_sq, "threads {threads}");
        }
    }

    #[test]
    fn pool_executor_is_bit_identical_to_spawn() {
        // The engine's long-lived pool runs the same band fills as the
        // scoped-thread path; the integral arrays must match bitwise.
        let mut sig = Signal::from_fn(200, 23, |r, c| ((r * 31 + c * 7) % 13) as f64 - 6.0);
        sig.mask_rect(Rect::new(70, 80, 2, 9));
        let reference = PrefixStats::new_par(&sig, 1);
        for threads in [1, 2, 3, 4] {
            let pool = crate::par::WorkerPool::new(threads);
            let pooled = PrefixStats::new_par_exec(&sig, crate::par::Exec::Pool(&pool));
            assert_eq!(pooled.count, reference.count, "pool threads {threads}");
            assert_eq!(pooled.sum, reference.sum, "pool threads {threads}");
            assert_eq!(pooled.sum_sq, reference.sum_sq, "pool threads {threads}");
        }
    }

    #[test]
    fn blocked_construction_is_bit_identical_across_threads_and_blocks() {
        // The tentpole invariant: the blocked filler carries its row
        // accumulators across column blocks (pass 1) and adds the row
        // above elementwise (pass 2), so every thread count × block size
        // must reproduce the scalar path bit-for-bit — masked region and
        // non-divisor block width (37) included.
        let mut sig = Signal::from_fn(200, 23, |r, c| ((r * 31 + c * 7) % 13) as f64 - 6.0);
        sig.mask_rect(Rect::new(70, 80, 2, 9));
        let reference = PrefixStats::new_par(&sig, 1);
        let seq = PrefixStats::new(&sig);
        assert_eq!(seq.count, reference.count);
        assert_eq!(seq.sum, reference.sum);
        assert_eq!(seq.sum_sq, reference.sum_sq);
        for block in [1, 8, 32, 37, 64, 1024] {
            for threads in [1, 2, 4, 8] {
                let blk = PrefixStats::new_blocked(&sig, threads, block);
                assert_eq!(blk.count, reference.count, "block {block} threads {threads}");
                assert_eq!(blk.sum, reference.sum, "block {block} threads {threads}");
                assert_eq!(blk.sum_sq, reference.sum_sq, "block {block} threads {threads}");
            }
        }
    }

    #[test]
    fn blocked_pool_executor_is_bit_identical() {
        // Blocked fill on the engine's long-lived pool: still the same
        // bits as the sequential scalar build.
        let mut sig = Signal::from_fn(200, 23, |r, c| ((r * 31 + c * 7) % 13) as f64 - 6.0);
        sig.mask_rect(Rect::new(70, 80, 2, 9));
        let reference = PrefixStats::new(&sig);
        for threads in [1, 3] {
            let pool = crate::par::WorkerPool::new(threads);
            let blk = PrefixStats::new_blocked_exec(&sig, crate::par::Exec::Pool(&pool), 37);
            assert_eq!(blk.count, reference.count, "pool threads {threads}");
            assert_eq!(blk.sum, reference.sum, "pool threads {threads}");
            assert_eq!(blk.sum_sq, reference.sum_sq, "pool threads {threads}");
        }
    }

    #[test]
    fn blocked_single_band_signal_matches_sequential() {
        // Signals under one band (n < 64) take the single-band fallback;
        // the blocked filler must still match `new` bitwise, and
        // `block == 0` must resolve to the default width.
        let sig = Signal::from_fn(17, 23, |r, c| ((r * 7 + c * 13) % 11) as f64 - 5.0);
        let reference = PrefixStats::new(&sig);
        for block in [0, 5, 64] {
            let blk = PrefixStats::new_blocked(&sig, 2, block);
            assert_eq!(blk.count, reference.count, "block {block}");
            assert_eq!(blk.sum, reference.sum, "block {block}");
            assert_eq!(blk.sum_sq, reference.sum_sq, "block {block}");
        }
    }

    #[test]
    fn stats_over_view_match_stats_over_crop_bitwise() {
        // A view presents the same data in the same order as its crop, so
        // the integral images must be bit-identical.
        let mut sig = Signal::from_fn(40, 30, |r, c| ((r * 17 + c * 3) % 23) as f64 * 0.5);
        sig.mask_rect(Rect::new(5, 12, 4, 11));
        let window = Rect::new(3, 30, 2, 25);
        let from_view = PrefixStats::new(&sig.view(window));
        let from_crop = PrefixStats::new(&sig.crop(window));
        assert_eq!(from_view.count, from_crop.count);
        assert_eq!(from_view.sum, from_crop.sum);
        assert_eq!(from_view.sum_sq, from_crop.sum_sq);
    }

    #[test]
    fn rect_queries_match_cropped_stats() {
        // One global PrefixStats answers any sub-rectangle: offset rect
        // queries agree with stats freshly built over the crop (up to f64
        // reassociation noise — global prefixes subtract, local ones
        // accumulate).
        let mut rng = Rng::new(99);
        let mut sig = Signal::from_fn(64, 48, |r, c| ((r * 13 + c * 29) % 31) as f64 - 15.0);
        sig.mask_rect(Rect::new(20, 33, 10, 22));
        let global = PrefixStats::new(&sig);
        let window = Rect::new(7, 55, 5, 40);
        let local = PrefixStats::new(&sig.view(window));
        for _ in 0..100 {
            let r0 = rng.usize(window.height());
            let r1 = rng.range(r0, window.height());
            let c0 = rng.usize(window.width());
            let c1 = rng.range(c0, window.width());
            let local_rect = Rect::new(r0, r1, c0, c1);
            let global_rect = Rect::new(
                window.r0 + r0,
                window.r0 + r1,
                window.c0 + c0,
                window.c0 + c1,
            );
            let a = global.moments(&global_rect);
            let b = local.moments(&local_rect);
            let scale = 1.0 + a.sum.abs() + a.sum_sq.abs();
            assert_eq!(a.count, b.count, "{local_rect:?}");
            assert!((a.sum - b.sum).abs() < 1e-9 * scale, "{local_rect:?}");
            assert!((a.sum_sq - b.sum_sq).abs() < 1e-9 * scale, "{local_rect:?}");
            assert!(
                (a.opt1() - b.opt1()).abs() <= 1e-8 * (1.0 + a.opt1()),
                "{local_rect:?}"
            );
        }
    }

    #[test]
    fn moments_add_is_consistent() {
        let sig = Signal::from_fn(4, 8, |r, c| (r * c) as f64);
        let stats = PrefixStats::new(&sig);
        let left = Rect::new(0, 3, 0, 3);
        let right = Rect::new(0, 3, 4, 7);
        let both = Rect::new(0, 3, 0, 7);
        let sum = stats.moments(&left).add(&stats.moments(&right));
        let direct = stats.moments(&both);
        assert!((sum.sum - direct.sum).abs() < 1e-9);
        assert!((sum.sum_sq - direct.sum_sq).abs() < 1e-9);
        assert_eq!(sum.count, direct.count);
    }
}
