//! Prefix-sum ("integral image") statistics over a signal.
//!
//! This is the O(1) `opt₁` oracle that Lemmas 12/13 of the paper rely on:
//! after an O(N) preprocessing pass we can answer, for any rectangle `B`,
//!
//! * `count(B)`  — number of *present* cells,
//! * `sum(B)`    — Σ y over present cells,
//! * `sum_sq(B)` — Σ y² over present cells,
//! * `opt1(B)`   — min_c Σ (y − c)² = Σy² − (Σy)²/count  (the 1-segmentation
//!   loss, attained by the mean),
//!
//! each in O(1) via inclusion–exclusion. All accumulators are f64; `opt1`
//! clamps at zero to absorb floating-point cancellation on near-constant
//! blocks.

use super::{Rect, Signal};

/// Integral images of (count, Σy, Σy²) with one row/col of zero padding so
/// that queries need no boundary branches.
#[derive(Clone, Debug)]
pub struct PrefixStats {
    n: usize,
    m: usize,
    /// (m+1)-stride arrays, entry [(r+1)*(m+1) + (c+1)] = prefix over
    /// rows 0..=r, cols 0..=c.
    count: Vec<f64>,
    sum: Vec<f64>,
    sum_sq: Vec<f64>,
}

/// Aggregate moments of a rectangle: the triple the Caratheodory step
/// must preserve exactly.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Moments {
    pub count: f64,
    pub sum: f64,
    pub sum_sq: f64,
}

impl Moments {
    pub const ZERO: Moments = Moments { count: 0.0, sum: 0.0, sum_sq: 0.0 };

    /// Mean label (0 for empty blocks).
    #[inline]
    pub fn mean(&self) -> f64 {
        if self.count <= 0.0 {
            0.0
        } else {
            self.sum / self.count
        }
    }

    /// The optimal 1-segmentation loss: Σ(y − mean)².
    #[inline]
    pub fn opt1(&self) -> f64 {
        if self.count <= 0.0 {
            return 0.0;
        }
        (self.sum_sq - self.sum * self.sum / self.count).max(0.0)
    }

    /// SSE of fitting the constant `c` to this block: Σ(y − c)².
    #[inline]
    pub fn sse_to(&self, c: f64) -> f64 {
        (self.sum_sq - 2.0 * c * self.sum + c * c * self.count).max(0.0)
    }

    #[inline]
    pub fn add(&self, other: &Moments) -> Moments {
        Moments {
            count: self.count + other.count,
            sum: self.sum + other.sum,
            sum_sq: self.sum_sq + other.sum_sq,
        }
    }
}

impl PrefixStats {
    /// O(N) construction. Masked-out cells contribute zero to every
    /// accumulator.
    pub fn new(signal: &Signal) -> Self {
        let n = signal.rows();
        let m = signal.cols();
        let stride = m + 1;
        let mut count = vec![0.0; (n + 1) * stride];
        let mut sum = vec![0.0; (n + 1) * stride];
        let mut sum_sq = vec![0.0; (n + 1) * stride];
        for r in 0..n {
            // Running row accumulators avoid one extra pass.
            let mut row_cnt = 0.0;
            let mut row_sum = 0.0;
            let mut row_sq = 0.0;
            let up = r * stride;
            let cur = (r + 1) * stride;
            for c in 0..m {
                if signal.is_present(r, c) {
                    let y = signal.get(r, c);
                    row_cnt += 1.0;
                    row_sum += y;
                    row_sq += y * y;
                }
                count[cur + c + 1] = count[up + c + 1] + row_cnt;
                sum[cur + c + 1] = sum[up + c + 1] + row_sum;
                sum_sq[cur + c + 1] = sum_sq[up + c + 1] + row_sq;
            }
        }
        Self { n, m, count, sum, sum_sq }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.m
    }

    #[inline]
    fn query(&self, arr: &[f64], rect: &Rect) -> f64 {
        let stride = self.m + 1;
        let (r0, r1, c0, c1) = (rect.r0, rect.r1 + 1, rect.c0, rect.c1 + 1);
        arr[r1 * stride + c1] - arr[r0 * stride + c1] - arr[r1 * stride + c0]
            + arr[r0 * stride + c0]
    }

    /// All three moments of a rectangle in O(1).
    #[inline]
    pub fn moments(&self, rect: &Rect) -> Moments {
        debug_assert!(rect.r1 < self.n && rect.c1 < self.m, "rect out of bounds");
        Moments {
            count: self.query(&self.count, rect),
            sum: self.query(&self.sum, rect),
            sum_sq: self.query(&self.sum_sq, rect),
        }
    }

    /// Number of present cells in `rect`.
    #[inline]
    pub fn count(&self, rect: &Rect) -> f64 {
        self.query(&self.count, rect)
    }

    /// Σ y over present cells in `rect`.
    #[inline]
    pub fn sum(&self, rect: &Rect) -> f64 {
        self.query(&self.sum, rect)
    }

    /// Σ y² over present cells in `rect`.
    #[inline]
    pub fn sum_sq(&self, rect: &Rect) -> f64 {
        self.query(&self.sum_sq, rect)
    }

    /// Mean label of `rect` (0 if the rect is empty/masked out).
    #[inline]
    pub fn mean(&self, rect: &Rect) -> f64 {
        self.moments(rect).mean()
    }

    /// `opt₁(rect)`: the 1-segmentation SSE loss, in O(1).
    #[inline]
    pub fn opt1(&self, rect: &Rect) -> f64 {
        self.moments(rect).opt1()
    }

    /// SSE of fitting constant `c` to `rect`, in O(1).
    #[inline]
    pub fn sse_to(&self, rect: &Rect, c: f64) -> f64 {
        self.moments(rect).sse_to(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Brute-force moments for cross-checking.
    fn brute(signal: &Signal, rect: &Rect) -> Moments {
        let mut m = Moments::ZERO;
        for (r, c) in rect.cells() {
            if signal.is_present(r, c) {
                let y = signal.get(r, c);
                m.count += 1.0;
                m.sum += y;
                m.sum_sq += y * y;
            }
        }
        m
    }

    fn brute_opt1(signal: &Signal, rect: &Rect) -> f64 {
        let mom = brute(signal, rect);
        if mom.count == 0.0 {
            return 0.0;
        }
        let mean = mom.sum / mom.count;
        let mut loss = 0.0;
        for (r, c) in rect.cells() {
            if signal.is_present(r, c) {
                let d = signal.get(r, c) - mean;
                loss += d * d;
            }
        }
        loss
    }

    #[test]
    fn moments_match_bruteforce_random_rects() {
        let mut rng = Rng::new(2024);
        let sig = Signal::from_fn(17, 23, |r, c| ((r * 7 + c * 13) % 11) as f64 - 5.0);
        let stats = PrefixStats::new(&sig);
        for _ in 0..200 {
            let r0 = rng.usize(17);
            let r1 = rng.range(r0, 17);
            let c0 = rng.usize(23);
            let c1 = rng.range(c0, 23);
            let rect = Rect::new(r0, r1, c0, c1);
            let a = stats.moments(&rect);
            let b = brute(&sig, &rect);
            assert_eq!(a.count, b.count);
            assert!((a.sum - b.sum).abs() < 1e-9);
            assert!((a.sum_sq - b.sum_sq).abs() < 1e-9);
        }
    }

    #[test]
    fn opt1_matches_bruteforce() {
        let mut rng = Rng::new(7);
        let sig = Signal::from_fn(12, 9, |r, c| {
            ((r as f64) * 0.3 - (c as f64) * 1.7).sin() * 4.0
        });
        let stats = PrefixStats::new(&sig);
        for _ in 0..100 {
            let r0 = rng.usize(12);
            let r1 = rng.range(r0, 12);
            let c0 = rng.usize(9);
            let c1 = rng.range(c0, 9);
            let rect = Rect::new(r0, r1, c0, c1);
            let fast = stats.opt1(&rect);
            let slow = brute_opt1(&sig, &rect);
            assert!(
                (fast - slow).abs() <= 1e-8 * (1.0 + slow),
                "rect {rect:?}: {fast} vs {slow}"
            );
        }
    }

    #[test]
    fn opt1_near_zero_for_constant_blocks() {
        // Inclusion–exclusion roundoff can leave a tiny positive residue;
        // the clamp guarantees non-negativity, and the residue must be at
        // machine-epsilon scale relative to Σy².
        let sig = Signal::constant(10, 10, 3.7);
        let stats = PrefixStats::new(&sig);
        let whole = Rect::new(0, 9, 0, 9);
        assert!(stats.opt1(&whole) >= 0.0);
        assert!(stats.opt1(&whole) <= 1e-9 * stats.sum_sq(&whole));
        let cell = Rect::new(3, 3, 4, 4);
        assert!(stats.opt1(&cell) <= 1e-12 * (1.0 + stats.sum_sq(&cell)));
    }

    #[test]
    fn masked_cells_are_excluded() {
        let mut sig = Signal::from_fn(6, 6, |r, c| (r * 6 + c) as f64);
        sig.mask_rect(Rect::new(0, 2, 0, 2));
        let stats = PrefixStats::new(&sig);
        let whole = sig.bounds();
        let mom = stats.moments(&whole);
        assert_eq!(mom.count, 36.0 - 9.0);
        let b = brute(&sig, &whole);
        assert!((mom.sum - b.sum).abs() < 1e-9);
        // Fully masked rect → zero moments, zero opt1.
        let dead = Rect::new(0, 2, 0, 2);
        assert_eq!(stats.count(&dead), 0.0);
        assert_eq!(stats.opt1(&dead), 0.0);
    }

    #[test]
    fn sse_to_constant_matches_signal_sse() {
        let sig = Signal::from_fn(8, 8, |r, c| ((r + 2 * c) % 5) as f64);
        let stats = PrefixStats::new(&sig);
        let rect = Rect::new(1, 6, 2, 7);
        let c = 1.9;
        let fast = stats.sse_to(&rect, c);
        let mut slow = 0.0;
        for (r, cc) in rect.cells() {
            let d = sig.get(r, cc) - c;
            slow += d * d;
        }
        assert!((fast - slow).abs() < 1e-9);
    }

    #[test]
    fn moments_add_is_consistent() {
        let sig = Signal::from_fn(4, 8, |r, c| (r * c) as f64);
        let stats = PrefixStats::new(&sig);
        let left = Rect::new(0, 3, 0, 3);
        let right = Rect::new(0, 3, 4, 7);
        let both = Rect::new(0, 3, 0, 7);
        let sum = stats.moments(&left).add(&stats.moments(&right));
        let direct = stats.moments(&both);
        assert!((sum.sum - direct.sum).abs() < 1e-9);
        assert!((sum.sum_sq - direct.sum_sq).abs() < 1e-9);
        assert_eq!(sum.count, direct.count);
    }
}
