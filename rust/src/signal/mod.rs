//! Signal representation: an `n × m` grid where every cell carries a real
//! label (the paper's "2D-signal"), plus rectangular sub-signal views and
//! optional masks (for the missing-values experiment, where held-out cells
//! must not contribute to any statistic).
//!
//! Two ways to look at a sub-rectangle:
//!
//! * [`SignalView`] — a borrowed, rect-offset window into a [`Signal`]:
//!   O(1) to create, zero copies, composable (`view.view(rect)` stays a
//!   view of the root signal). This is what the sharded builders hand to
//!   workers.
//! * [`Signal::crop`] — an owned copy of the window, kept for tests,
//!   examples, and true streaming sources that hand off ownership.
//!
//! Both implement [`SignalSource`], the read-only access seam the whole
//! build stack ([`PrefixStats`], bicriteria, partition, Caratheodory
//! extraction) is generic over — and the hook later sparse/tiled/mmap
//! backends plug into (DESIGN.md §Views & Memory).

pub mod generate;
pub mod stats;

pub use stats::PrefixStats;

/// An axis-parallel rectangle of grid cells, **inclusive** on both ends,
/// using 0-based `(row, col)` coordinates: rows `r0..=r1`, cols `c0..=c1`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Rect {
    pub r0: usize,
    pub r1: usize,
    pub c0: usize,
    pub c1: usize,
}

impl Rect {
    pub fn new(r0: usize, r1: usize, c0: usize, c1: usize) -> Self {
        debug_assert!(r0 <= r1 && c0 <= c1, "degenerate rect {r0}..{r1} x {c0}..{c1}");
        Self { r0, r1, c0, c1 }
    }

    /// Number of rows spanned.
    #[inline]
    pub fn height(&self) -> usize {
        self.r1 - self.r0 + 1
    }

    /// Number of columns spanned.
    #[inline]
    pub fn width(&self) -> usize {
        self.c1 - self.c0 + 1
    }

    /// Number of cells (not accounting for masks).
    #[inline]
    pub fn area(&self) -> usize {
        self.height() * self.width()
    }

    #[inline]
    pub fn contains(&self, r: usize, c: usize) -> bool {
        r >= self.r0 && r <= self.r1 && c >= self.c0 && c <= self.c1
    }

    /// Do two rectangles share at least one cell?
    #[inline]
    pub fn intersects(&self, other: &Rect) -> bool {
        self.r0 <= other.r1 && other.r0 <= self.r1 && self.c0 <= other.c1 && other.c0 <= self.c1
    }

    /// The intersection rectangle, if non-empty.
    pub fn intersection(&self, other: &Rect) -> Option<Rect> {
        if !self.intersects(other) {
            return None;
        }
        Some(Rect::new(
            self.r0.max(other.r0),
            self.r1.min(other.r1),
            self.c0.max(other.c0),
            self.c1.min(other.c1),
        ))
    }

    /// Is `other` fully inside `self`?
    #[inline]
    pub fn contains_rect(&self, other: &Rect) -> bool {
        self.r0 <= other.r0 && other.r1 <= self.r1 && self.c0 <= other.c0 && other.c1 <= self.c1
    }

    /// Transpose (swap row/col axes) — used by SLICEPARTITION's recursive
    /// call on `B^T`.
    #[inline]
    pub fn transposed(&self) -> Rect {
        Rect::new(self.c0, self.c1, self.r0, self.r1)
    }

    /// The four corner coordinates (used by Algorithm 3 Line 6, which pins
    /// each Caratheodory point to a corner of its block).
    pub fn corners(&self) -> [(usize, usize); 4] {
        [
            (self.r0, self.c0),
            (self.r0, self.c1),
            (self.r1, self.c0),
            (self.r1, self.c1),
        ]
    }

    /// Iterate all `(r, c)` cells in row-major order.
    pub fn cells(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        let (c0, c1) = (self.c0, self.c1);
        (self.r0..=self.r1).flat_map(move |r| (c0..=c1).map(move |c| (r, c)))
    }
}

/// Read-only access to a (possibly windowed) 2D signal — the seam the
/// build stack is generic over, implemented by the owned [`Signal`] and
/// the borrowed [`SignalView`].
///
/// The contract mirrors `Signal`'s accessors: `(r, c)` are local
/// coordinates in `0..rows() × 0..cols()`, rows are contiguous `f64`
/// slices, and a `None` row mask means "every cell of that row present".
/// `view` must be O(1) — no data is copied, only offsets composed —
/// which is what keeps shards, bands, and streaming windows allocation-
/// free. `Sync` is a supertrait so sources can be shared across the
/// scoped worker pools in [`crate::par`] without extra bounds at every
/// call site.
pub trait SignalSource: Sync {
    /// Number of rows.
    fn rows(&self) -> usize;

    /// Number of columns.
    fn cols(&self) -> usize;

    /// Row `r`'s labels as a contiguous slice of length [`Self::cols`].
    fn row_values(&self, r: usize) -> &[f64];

    /// Row `r`'s presence mask (`true` = present), `None` when the whole
    /// row is present (the unmasked fast path).
    fn row_mask(&self, r: usize) -> Option<&[bool]>;

    /// O(1) sub-view of `rect` (local coordinates).
    fn view(&self, rect: Rect) -> SignalView<'_>;

    /// Label at `(r, c)`.
    #[inline]
    fn get(&self, r: usize, c: usize) -> f64 {
        self.row_values(r)[c]
    }

    /// Is the cell present (not masked out)?
    #[inline]
    fn is_present(&self, r: usize, c: usize) -> bool {
        match self.row_mask(r) {
            None => true,
            Some(mask) => mask[c],
        }
    }

    /// Total cells (present or not).
    #[inline]
    fn len(&self) -> usize {
        self.rows() * self.cols()
    }

    /// Sources are non-empty by construction (`Signal` enforces
    /// `n, m > 0`; `Rect` is never degenerate).
    #[inline]
    fn is_empty(&self) -> bool {
        false
    }

    /// The full bounding rectangle in local coordinates.
    #[inline]
    fn bounds(&self) -> Rect {
        Rect::new(0, self.rows() - 1, 0, self.cols() - 1)
    }

    /// Number of *present* cells.
    fn present(&self) -> usize {
        let mut count = 0;
        for r in 0..self.rows() {
            count += match self.row_mask(r) {
                None => self.cols(),
                Some(mask) => mask.iter().filter(|&&b| b).count(),
            };
        }
        count
    }
}

/// References delegate, so generic consumers accept `&S` and `&&S`
/// alike (generic parameters do not auto-deref the way method receivers
/// do).
impl<S: SignalSource + ?Sized> SignalSource for &S {
    #[inline]
    fn rows(&self) -> usize {
        (**self).rows()
    }

    #[inline]
    fn cols(&self) -> usize {
        (**self).cols()
    }

    #[inline]
    fn row_values(&self, r: usize) -> &[f64] {
        (**self).row_values(r)
    }

    #[inline]
    fn row_mask(&self, r: usize) -> Option<&[bool]> {
        (**self).row_mask(r)
    }

    #[inline]
    fn view(&self, rect: Rect) -> SignalView<'_> {
        (**self).view(rect)
    }

    #[inline]
    fn get(&self, r: usize, c: usize) -> f64 {
        (**self).get(r, c)
    }

    #[inline]
    fn is_present(&self, r: usize, c: usize) -> bool {
        (**self).is_present(r, c)
    }

    #[inline]
    fn present(&self) -> usize {
        (**self).present()
    }
}

/// A dense `n × m` signal. Labels are stored row-major in `values`;
/// `mask[i]` is false for cells that are *missing* (excluded from every
/// statistic). A fully-present signal has `mask == None` (fast path).
#[derive(Clone, Debug)]
pub struct Signal {
    n: usize,
    m: usize,
    values: Vec<f64>,
    mask: Option<Vec<bool>>,
}

impl Signal {
    /// Build from row-major values.
    pub fn from_values(n: usize, m: usize, values: Vec<f64>) -> Self {
        assert_eq!(values.len(), n * m, "values length must be n*m");
        assert!(n > 0 && m > 0, "signal must be non-empty");
        Self { n, m, values, mask: None }
    }

    /// Build a constant signal.
    pub fn constant(n: usize, m: usize, value: f64) -> Self {
        Self::from_values(n, m, vec![value; n * m])
    }

    /// Build from a generator function over `(row, col)`.
    pub fn from_fn(n: usize, m: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut values = Vec::with_capacity(n * m);
        for r in 0..n {
            for c in 0..m {
                values.push(f(r, c));
            }
        }
        Self::from_values(n, m, values)
    }

    /// Attach a mask (true = present). Panics on length mismatch.
    pub fn with_mask(mut self, mask: Vec<bool>) -> Self {
        assert_eq!(mask.len(), self.n * self.m);
        self.mask = Some(mask);
        self
    }

    /// Mark a rectangle of cells missing (used by the 5×5-patch holdout).
    pub fn mask_rect(&mut self, rect: Rect) {
        assert!(rect.r1 < self.n && rect.c1 < self.m, "rect out of bounds");
        let mask = self
            .mask
            .get_or_insert_with(|| vec![true; self.n * self.m]);
        for (r, c) in rect.cells() {
            mask[r * self.m + c] = false;
        }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.m
    }

    /// Total cells (present or not).
    #[inline]
    pub fn len(&self) -> usize {
        self.n * self.m
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        false // constructor enforces n, m > 0
    }

    /// Number of *present* cells.
    pub fn present(&self) -> usize {
        match &self.mask {
            None => self.len(),
            Some(m) => m.iter().filter(|&&b| b).count(),
        }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        self.values[r * self.m + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.values[r * self.m + c] = v;
    }

    /// Is the cell present (not masked out)?
    #[inline]
    pub fn is_present(&self, r: usize, c: usize) -> bool {
        match &self.mask {
            None => true,
            Some(m) => m[r * self.m + c],
        }
    }

    #[inline]
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    pub fn mask(&self) -> Option<&[bool]> {
        self.mask.as_deref()
    }

    /// The full-signal bounding rectangle.
    #[inline]
    pub fn bounds(&self) -> Rect {
        Rect::new(0, self.n - 1, 0, self.m - 1)
    }

    /// Extract the sub-signal of `rect` as an owned `Signal` (mask carried
    /// over): [`SignalView::to_signal`] on the equivalent view. Kept for
    /// tests, examples, and streaming sources that hand off ownership —
    /// builder hot paths use O(1) [`SignalSource::view`]s instead.
    pub fn crop(&self, rect: Rect) -> Signal {
        assert!(rect.r1 < self.n && rect.c1 < self.m, "crop out of bounds");
        SignalView::new(self, rect).to_signal()
    }

    /// Transposed copy.
    pub fn transposed(&self) -> Signal {
        let mut values = vec![0.0; self.len()];
        for r in 0..self.n {
            for c in 0..self.m {
                values[c * self.n + r] = self.get(r, c);
            }
        }
        let mut out = Signal::from_values(self.m, self.n, values);
        if let Some(mask) = &self.mask {
            let mut tm = vec![true; self.len()];
            for r in 0..self.n {
                for c in 0..self.m {
                    tm[c * self.n + r] = mask[r * self.m + c];
                }
            }
            out.mask = Some(tm);
        }
        out
    }

    /// Sum of squared differences between this signal's present cells and a
    /// predictor function. The ground-truth loss used all over the tests.
    pub fn sse_against(&self, mut pred: impl FnMut(usize, usize) -> f64) -> f64 {
        let mut total = 0.0;
        for r in 0..self.n {
            for c in 0..self.m {
                if self.is_present(r, c) {
                    let d = pred(r, c) - self.get(r, c);
                    total += d * d;
                }
            }
        }
        total
    }
}

impl SignalSource for Signal {
    #[inline]
    fn rows(&self) -> usize {
        self.n
    }

    #[inline]
    fn cols(&self) -> usize {
        self.m
    }

    #[inline]
    fn row_values(&self, r: usize) -> &[f64] {
        &self.values[r * self.m..(r + 1) * self.m]
    }

    #[inline]
    fn row_mask(&self, r: usize) -> Option<&[bool]> {
        self.mask
            .as_ref()
            .map(|mask| &mask[r * self.m..(r + 1) * self.m])
    }

    #[inline]
    fn view(&self, rect: Rect) -> SignalView<'_> {
        SignalView::new(self, rect)
    }

    #[inline]
    fn get(&self, r: usize, c: usize) -> f64 {
        Signal::get(self, r, c)
    }

    #[inline]
    fn is_present(&self, r: usize, c: usize) -> bool {
        Signal::is_present(self, r, c)
    }

    #[inline]
    fn present(&self) -> usize {
        Signal::present(self)
    }
}

/// A borrowed, rect-offset window into a [`Signal`]: zero-copy, O(1) to
/// create and to sub-view. Local coordinate `(r, c)` maps to the parent's
/// `(rect.r0 + r, rect.c0 + c)`; masks are inherited. Sub-views compose —
/// `view.view(inner)` borrows the *root* signal with summed offsets, so
/// arbitrarily nested windowing never chains indirections.
#[derive(Clone, Copy, Debug)]
pub struct SignalView<'a> {
    signal: &'a Signal,
    rect: Rect,
}

impl<'a> SignalView<'a> {
    /// View of `rect` (parent coordinates). Panics when out of bounds.
    pub fn new(signal: &'a Signal, rect: Rect) -> Self {
        assert!(
            rect.r1 < signal.rows() && rect.c1 < signal.cols(),
            "view out of bounds"
        );
        Self { signal, rect }
    }

    /// The window rectangle in the parent signal's coordinates.
    #[inline]
    pub fn rect(&self) -> Rect {
        self.rect
    }

    /// The backing signal.
    #[inline]
    pub fn parent(&self) -> &'a Signal {
        self.signal
    }

    /// Materialize the window as an owned [`Signal`] — per-row
    /// `copy_from_slice` into preallocated buffers (no per-cell `get`
    /// indirection, no incremental growth checks), mask carried over.
    pub fn to_signal(&self) -> Signal {
        let (h, w) = (self.rect.height(), self.rect.width());
        let mut values = vec![0.0f64; h * w];
        for (lr, dst) in values.chunks_exact_mut(w).enumerate() {
            dst.copy_from_slice(self.row_values(lr));
        }
        let mut out = Signal::from_values(h, w, values);
        if self.signal.mask.is_some() {
            let mut mask = vec![true; h * w];
            for (lr, dst) in mask.chunks_exact_mut(w).enumerate() {
                if let Some(src) = self.row_mask(lr) {
                    dst.copy_from_slice(src);
                }
            }
            out.mask = Some(mask);
        }
        out
    }
}

impl SignalSource for SignalView<'_> {
    #[inline]
    fn rows(&self) -> usize {
        self.rect.height()
    }

    #[inline]
    fn cols(&self) -> usize {
        self.rect.width()
    }

    #[inline]
    fn row_values(&self, r: usize) -> &[f64] {
        debug_assert!(r < self.rect.height());
        let row0 = (self.rect.r0 + r) * self.signal.m;
        &self.signal.values[row0 + self.rect.c0..=row0 + self.rect.c1]
    }

    #[inline]
    fn row_mask(&self, r: usize) -> Option<&[bool]> {
        debug_assert!(r < self.rect.height());
        self.signal.mask.as_ref().map(|mask| {
            let row0 = (self.rect.r0 + r) * self.signal.m;
            &mask[row0 + self.rect.c0..=row0 + self.rect.c1]
        })
    }

    #[inline]
    fn view(&self, rect: Rect) -> SignalView<'_> {
        assert!(
            rect.r1 < self.rect.height() && rect.c1 < self.rect.width(),
            "sub-view out of bounds"
        );
        SignalView::new(
            self.signal,
            Rect::new(
                self.rect.r0 + rect.r0,
                self.rect.r0 + rect.r1,
                self.rect.c0 + rect.c0,
                self.rect.c0 + rect.c1,
            ),
        )
    }

    #[inline]
    fn get(&self, r: usize, c: usize) -> f64 {
        self.signal.get(self.rect.r0 + r, self.rect.c0 + c)
    }

    #[inline]
    fn is_present(&self, r: usize, c: usize) -> bool {
        self.signal.is_present(self.rect.r0 + r, self.rect.c0 + c)
    }
}

/// Incremental 64-bit FNV-1a hasher — the crate's one content-hash
/// primitive (std's `DefaultHasher` is explicitly unstable across
/// releases, and cache keys / digests printed in wire responses must be
/// reproducible everywhere). Byte-oriented, deterministic, dependency
/// free.
#[derive(Debug, Clone)]
pub struct Fnv1a(u64);

impl Fnv1a {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    pub fn new() -> Self {
        Fnv1a(Self::OFFSET)
    }

    #[inline]
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= u64::from(b);
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
    }

    #[inline]
    pub fn write_u8(&mut self, x: u8) {
        self.write(&[x]);
    }

    #[inline]
    pub fn write_u64(&mut self, x: u64) {
        self.write(&x.to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

/// Content digest of a signal: FNV-1a over the dimensions, the presence
/// mask, and the *present* cells' exact `f64` bit patterns, in row-major
/// order. Two sources digest equal iff they are semantically the same
/// input to a coreset build:
///
/// * the value stored under a masked-out cell does **not** contribute
///   (builds never read it), so editing hidden cells keeps the digest;
/// * an absent mask and an all-`true` mask digest identically;
/// * dimensions are folded in first, so a 2×3 and a 3×2 signal with the
///   same flat values differ.
///
/// This is the cache key the serving layer uses (`sigtree::serve`, LRU
/// keyed by `(content_digest, EngineConfig)`), and the reason it lives
/// here: nothing else in the crate can name a signal without holding it.
pub fn content_digest<S: SignalSource + ?Sized>(signal: &S) -> u64 {
    let mut h = Fnv1a::new();
    h.write_u64(signal.rows() as u64);
    h.write_u64(signal.cols() as u64);
    for r in 0..signal.rows() {
        let values = signal.row_values(r);
        match signal.row_mask(r) {
            None => {
                for v in values {
                    h.write_u8(1);
                    h.write_u64(v.to_bits());
                }
            }
            Some(mask) => {
                for (v, present) in values.iter().zip(mask) {
                    if *present {
                        h.write_u8(1);
                        h.write_u64(v.to_bits());
                    } else {
                        h.write_u8(0);
                    }
                }
            }
        }
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rect_geometry() {
        let r = Rect::new(1, 3, 2, 5);
        assert_eq!(r.height(), 3);
        assert_eq!(r.width(), 4);
        assert_eq!(r.area(), 12);
        assert!(r.contains(2, 4));
        assert!(!r.contains(0, 4));
        assert_eq!(r.transposed(), Rect::new(2, 5, 1, 3));
        assert_eq!(r.cells().count(), 12);
    }

    #[test]
    fn rect_intersection() {
        let a = Rect::new(0, 4, 0, 4);
        let b = Rect::new(3, 6, 2, 8);
        assert!(a.intersects(&b));
        assert_eq!(a.intersection(&b), Some(Rect::new(3, 4, 2, 4)));
        let c = Rect::new(5, 6, 0, 4);
        assert!(!a.intersects(&c));
        assert_eq!(a.intersection(&c), None);
        assert!(a.contains_rect(&Rect::new(1, 2, 1, 2)));
        assert!(!a.contains_rect(&b));
    }

    #[test]
    fn signal_basic_accessors() {
        let s = Signal::from_fn(3, 4, |r, c| (r * 10 + c) as f64);
        assert_eq!(s.rows(), 3);
        assert_eq!(s.cols(), 4);
        assert_eq!(s.len(), 12);
        assert_eq!(s.get(2, 3), 23.0);
        assert_eq!(s.present(), 12);
        assert_eq!(s.bounds(), Rect::new(0, 2, 0, 3));
    }

    #[test]
    fn crop_matches_direct_indexing() {
        let s = Signal::from_fn(6, 7, |r, c| (r * 100 + c) as f64);
        let rect = Rect::new(1, 4, 2, 5);
        let cropped = s.crop(rect);
        assert_eq!(cropped.rows(), 4);
        assert_eq!(cropped.cols(), 4);
        for r in 0..4 {
            for c in 0..4 {
                assert_eq!(cropped.get(r, c), s.get(r + 1, c + 2));
            }
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let s = Signal::from_fn(3, 5, |r, c| (r * 31 + c * 7) as f64);
        let tt = s.transposed().transposed();
        assert_eq!(tt.values(), s.values());
    }

    #[test]
    fn mask_rect_excludes_cells() {
        let mut s = Signal::from_fn(5, 5, |r, c| (r + c) as f64);
        s.mask_rect(Rect::new(1, 2, 1, 2));
        assert_eq!(s.present(), 25 - 4);
        assert!(!s.is_present(1, 1));
        assert!(s.is_present(0, 0));
        // Crop carries the mask.
        let cropped = s.crop(Rect::new(0, 2, 0, 2));
        assert_eq!(cropped.present(), 9 - 4);
    }

    #[test]
    fn sse_against_constant() {
        let s = Signal::from_values(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        // SSE to constant 2.5 = 1.5^2+0.5^2+0.5^2+1.5^2 = 5.0
        let sse = s.sse_against(|_, _| 2.5);
        assert!((sse - 5.0).abs() < 1e-12);
    }

    #[test]
    fn view_matches_crop_cell_for_cell() {
        let mut s = Signal::from_fn(9, 11, |r, c| (r * 100 + c) as f64);
        s.mask_rect(Rect::new(2, 4, 3, 6));
        let rect = Rect::new(1, 6, 2, 9);
        let view = s.view(rect);
        let crop = s.crop(rect);
        assert_eq!(view.rows(), crop.rows());
        assert_eq!(view.cols(), crop.cols());
        assert_eq!(SignalSource::present(&view), crop.present());
        for r in 0..view.rows() {
            assert_eq!(view.row_values(r), crop.row_values(r));
            assert_eq!(view.row_mask(r), crop.row_mask(r));
            for c in 0..view.cols() {
                assert_eq!(view.get(r, c), crop.get(r, c));
                assert_eq!(view.is_present(r, c), crop.is_present(r, c));
            }
        }
    }

    #[test]
    fn views_compose_against_the_root_signal() {
        let s = Signal::from_fn(10, 10, |r, c| (r * 10 + c) as f64);
        let outer = s.view(Rect::new(2, 8, 1, 9));
        let inner = outer.view(Rect::new(1, 4, 2, 5));
        // Nested view borrows the root with summed offsets…
        assert_eq!(inner.rect(), Rect::new(3, 6, 3, 6));
        assert!(std::ptr::eq(inner.parent(), &s));
        // …and reads the same cells as composing crops.
        let twice = s.crop(Rect::new(2, 8, 1, 9)).crop(Rect::new(1, 4, 2, 5));
        for r in 0..inner.rows() {
            assert_eq!(inner.row_values(r), twice.row_values(r));
        }
    }

    #[test]
    fn to_signal_materializes_mask() {
        let mut s = Signal::from_fn(6, 6, |r, c| (r + c) as f64);
        s.mask_rect(Rect::new(0, 1, 0, 1));
        let owned = s.view(Rect::new(0, 3, 0, 3)).to_signal();
        assert_eq!(owned.present(), 16 - 4);
        assert!(!owned.is_present(1, 1));
        assert!(owned.is_present(2, 2));
    }

    #[test]
    fn unmasked_view_has_no_row_mask() {
        let s = Signal::from_fn(4, 5, |r, c| (r * c) as f64);
        let view = s.view(s.bounds());
        for r in 0..4 {
            assert!(view.row_mask(r).is_none());
        }
        assert!(view.to_signal().mask().is_none());
    }

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        let mut h = Fnv1a::new();
        assert_eq!(h.finish(), 0xcbf2_9ce4_8422_2325);
        h.write(b"a");
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
        let mut h = Fnv1a::new();
        h.write(b"foobar");
        assert_eq!(h.finish(), 0x8594_4171_f737_10b0);
    }

    #[test]
    fn content_digest_is_deterministic_and_value_sensitive() {
        let a = Signal::from_fn(7, 5, |r, c| (r * 31 + c) as f64);
        let b = Signal::from_fn(7, 5, |r, c| (r * 31 + c) as f64);
        assert_eq!(content_digest(&a), content_digest(&b));
        let mut c = Signal::from_fn(7, 5, |r, c| (r * 31 + c) as f64);
        // One ULP on one cell must change the digest (exact bit hashing).
        c.set(3, 2, f64::from_bits(c.get(3, 2).to_bits() + 1));
        assert_ne!(content_digest(&a), content_digest(&c));
    }

    #[test]
    fn content_digest_folds_in_dimensions() {
        let flat: Vec<f64> = (0..12).map(|i| i as f64).collect();
        let a = Signal::from_values(3, 4, flat.clone());
        let b = Signal::from_values(4, 3, flat);
        assert_ne!(content_digest(&a), content_digest(&b));
    }

    #[test]
    fn content_digest_ignores_hidden_values_but_not_the_mask() {
        let base = Signal::from_fn(6, 6, |r, c| (r + c) as f64);
        let mut masked = Signal::from_fn(6, 6, |r, c| (r + c) as f64);
        masked.mask_rect(Rect::new(1, 2, 1, 2));
        // Toggling presence changes identity…
        assert_ne!(content_digest(&base), content_digest(&masked));
        // …but editing a value no build can read does not.
        let mut hidden_edit = masked.clone();
        hidden_edit.set(1, 1, 999.0);
        assert_eq!(content_digest(&masked), content_digest(&hidden_edit));
        // An all-present mask is the same identity as no mask at all.
        let all_true = Signal::from_fn(6, 6, |r, c| (r + c) as f64).with_mask(vec![true; 36]);
        assert_eq!(content_digest(&base), content_digest(&all_true));
    }

    #[test]
    fn content_digest_sees_views_as_their_content() {
        let s = Signal::from_fn(10, 10, |r, c| (r * 10 + c) as f64);
        let rect = Rect::new(2, 6, 1, 8);
        // A zero-copy view and its materialized crop are the same input.
        assert_eq!(content_digest(&s.view(rect)), content_digest(&s.crop(rect)));
        assert_ne!(content_digest(&s.view(rect)), content_digest(&s));
    }
}
