//! Synthetic signal generators used by tests, examples and benchmarks.
//!
//! Real signals in the paper's motivation are images, sensor grids, and
//! z-normalized tabular matrices; the generators below cover the same
//! regimes: piecewise-constant (the model class itself), piecewise-smooth,
//! low-rank + noise (tabular-like), and pure noise (worst case).

use super::{Rect, Signal};
use crate::rng::Rng;

/// A piecewise-constant signal that *is* a k-segmentation: recursively
/// split the grid into `k` rectangles (random guillotine cuts) and assign
/// each a random level, plus optional gaussian noise. The ground-truth
/// segmentation is returned alongside so tests can verify recovery.
pub fn piecewise_constant(
    n: usize,
    m: usize,
    k: usize,
    noise_std: f64,
    rng: &mut Rng,
) -> (Signal, Vec<(Rect, f64)>) {
    assert!(k >= 1);
    let mut pieces: Vec<Rect> = vec![Rect::new(0, n - 1, 0, m - 1)];
    // Greedily split the largest piece until we have k.
    while pieces.len() < k {
        // Pick the piece with the largest area that is splittable. When
        // k exceeds the number of cells, every piece is 1×1 and we stop
        // with fewer than k pieces instead of panicking.
        let Some((idx, _)) = pieces
            .iter()
            .enumerate()
            .filter(|(_, r)| r.height() > 1 || r.width() > 1)
            .max_by_key(|(_, r)| r.area())
        else {
            break;
        };
        let rect = pieces.swap_remove(idx);
        let split_rows = rect.height() > 1 && (rect.width() <= 1 || rng.bool(0.5));
        if split_rows {
            let cut = rng.range(rect.r0, rect.r1); // split after row `cut`
            pieces.push(Rect::new(rect.r0, cut, rect.c0, rect.c1));
            pieces.push(Rect::new(cut + 1, rect.r1, rect.c0, rect.c1));
        } else {
            let cut = rng.range(rect.c0, rect.c1); // split after col `cut`
            pieces.push(Rect::new(rect.r0, rect.r1, rect.c0, cut));
            pieces.push(Rect::new(rect.r0, rect.r1, cut + 1, rect.c1));
        }
    }
    let labeled: Vec<(Rect, f64)> = pieces
        .into_iter()
        .map(|r| (r, rng.uniform(-10.0, 10.0)))
        .collect();
    let mut sig = Signal::constant(n, m, 0.0);
    for (rect, level) in &labeled {
        for (r, c) in rect.cells() {
            let noise = if noise_std > 0.0 { rng.normal_ms(0.0, noise_std) } else { 0.0 };
            sig.set(r, c, level + noise);
        }
    }
    (sig, labeled)
}

/// A smooth 2D signal: sum of a few random low-frequency sinusoids.
/// Mimics natural images / sensor fields — the regime where the balanced
/// partition produces large flat cells.
pub fn smooth(n: usize, m: usize, components: usize, rng: &mut Rng) -> Signal {
    let waves: Vec<(f64, f64, f64, f64)> = (0..components)
        .map(|_| {
            (
                rng.uniform(0.2, 2.5),               // amplitude
                rng.uniform(0.5, 3.0) / n as f64,    // row frequency
                rng.uniform(0.5, 3.0) / m as f64,    // col frequency
                rng.uniform(0.0, std::f64::consts::TAU), // phase
            )
        })
        .collect();
    Signal::from_fn(n, m, |r, c| {
        waves
            .iter()
            .map(|&(a, fr, fc, ph)| {
                a * (std::f64::consts::TAU * (fr * r as f64 + fc * c as f64) + ph).sin()
            })
            .sum()
    })
}

/// Low-rank + piecewise + noise matrix mimicking a z-normalized tabular
/// dataset (rows = instances, cols = features). This is the UCI-dataset
/// substitute documented in DESIGN.md §Substitutions: features are linear
/// combinations of a few latent factors that drift smoothly over the
/// instance axis, with regime switches (the piecewise part) and i.i.d.
/// measurement noise, then z-normalized per feature exactly like the
/// paper's preprocessing.
pub fn tabular_like(n: usize, m: usize, rank: usize, noise_std: f64, rng: &mut Rng) -> Signal {
    // Latent factors: random walks with occasional jumps.
    let mut factors = vec![vec![0.0f64; n]; rank];
    for f in factors.iter_mut() {
        let mut x = rng.normal();
        for v in f.iter_mut() {
            if rng.bool(0.002) {
                x = rng.normal_ms(0.0, 2.0); // regime switch
            }
            x += rng.normal_ms(0.0, 0.02);
            *v = x;
        }
    }
    // Feature loadings.
    let loadings: Vec<Vec<f64>> = (0..m)
        .map(|_| (0..rank).map(|_| rng.normal()).collect())
        .collect();
    let mut sig = Signal::from_fn(n, m, |r, c| {
        let mut v = 0.0;
        for (f, l) in factors.iter().zip(loadings[c].iter()) {
            v += f[r] * l;
        }
        v + rng.normal_ms(0.0, noise_std)
    });
    znormalize_columns(&mut sig);
    sig
}

/// Z-normalize every column (feature) to zero mean / unit variance —
/// the paper's preprocessing for the UCI datasets.
pub fn znormalize_columns(sig: &mut Signal) {
    let (n, m) = (sig.rows(), sig.cols());
    for c in 0..m {
        let mut sum = 0.0;
        let mut sq = 0.0;
        for r in 0..n {
            let y = sig.get(r, c);
            sum += y;
            sq += y * y;
        }
        let mean = sum / n as f64;
        let var = (sq / n as f64 - mean * mean).max(1e-12);
        let inv_std = 1.0 / var.sqrt();
        for r in 0..n {
            sig.set(r, c, (sig.get(r, c) - mean) * inv_std);
        }
    }
}

/// Mask out random rectangular patches until roughly `frac` of the cells
/// are missing (the §5 missing-values regime, generator form). Patches
/// may overlap; the loop is bounded so pathological `frac` values cannot
/// spin. Used by the guarantee audit's masked query families: masked
/// cells must contribute zero to both the true and the coreset loss.
pub fn random_mask(sig: &mut Signal, frac: f64, rng: &mut Rng) {
    let (n, m) = (sig.rows(), sig.cols());
    let target = ((n * m) as f64 * frac.clamp(0.0, 0.9)) as usize;
    let mut attempts = 0;
    while sig.len() - sig.present() < target && attempts < 16 * (target + 1) {
        let h = rng.range(1, (n / 4).max(2));
        let w = rng.range(1, (m / 4).max(2));
        let r0 = rng.usize(n - h + 1);
        let c0 = rng.usize(m - w + 1);
        sig.mask_rect(Rect::new(r0, r0 + h - 1, c0, c0 + w - 1));
        attempts += 1;
    }
}

/// Pure gaussian noise — the adversarial regime where no small coreset is
/// information-theoretically possible for *point sets*, but the signal
/// assumption still yields a valid (large-ish) coreset.
pub fn noise(n: usize, m: usize, std: f64, rng: &mut Rng) -> Signal {
    Signal::from_fn(n, m, |_, _| rng.normal_ms(0.0, std))
}

/// A synthetic "photo-like" image: smooth background + a few constant
/// rectangles (objects) + light noise. Used by the image-compression
/// example (the paper's MPEG4/quadtree motivation).
pub fn image_like(n: usize, m: usize, objects: usize, rng: &mut Rng) -> Signal {
    let mut sig = smooth(n, m, 3, rng);
    for _ in 0..objects {
        let h = rng.range(n / 8 + 1, n / 3 + 2).min(n);
        let w = rng.range(m / 8 + 1, m / 3 + 2).min(m);
        let r0 = rng.usize(n - h + 1);
        let c0 = rng.usize(m - w + 1);
        let level = rng.uniform(-8.0, 8.0);
        for r in r0..r0 + h {
            for c in c0..c0 + w {
                sig.set(r, c, level);
            }
        }
    }
    for r in 0..n {
        for c in 0..m {
            let v = sig.get(r, c) + rng.normal_ms(0.0, 0.05);
            sig.set(r, c, v);
        }
    }
    sig
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::signal::PrefixStats;

    #[test]
    fn piecewise_constant_pieces_partition_grid() {
        let mut rng = Rng::new(1);
        let (sig, pieces) = piecewise_constant(20, 30, 7, 0.0, &mut rng);
        assert_eq!(pieces.len(), 7);
        // Pieces tile the grid exactly: areas sum and no overlaps.
        let total: usize = pieces.iter().map(|(r, _)| r.area()).sum();
        assert_eq!(total, 600);
        for i in 0..pieces.len() {
            for j in (i + 1)..pieces.len() {
                assert!(!pieces[i].0.intersects(&pieces[j].0), "{i} {j}");
            }
        }
        // Noiseless: each piece is constant → opt1 = 0.
        let stats = PrefixStats::new(&sig);
        for (rect, level) in &pieces {
            assert!(stats.opt1(rect) < 1e-9);
            assert!((stats.mean(rect) - level).abs() < 1e-9);
        }
    }

    #[test]
    fn tabular_like_is_znormalized() {
        let mut rng = Rng::new(5);
        let sig = tabular_like(200, 10, 3, 0.1, &mut rng);
        for c in 0..10 {
            let mut sum = 0.0;
            let mut sq = 0.0;
            for r in 0..200 {
                sum += sig.get(r, c);
                sq += sig.get(r, c).powi(2);
            }
            let mean = sum / 200.0;
            let var = sq / 200.0 - mean * mean;
            assert!(mean.abs() < 1e-9, "col {c} mean {mean}");
            assert!((var - 1.0).abs() < 1e-6, "col {c} var {var}");
        }
    }

    #[test]
    fn smooth_is_bounded() {
        let mut rng = Rng::new(9);
        let sig = smooth(40, 40, 4, &mut rng);
        for &v in sig.values() {
            assert!(v.abs() < 11.0); // ≤ sum of amplitudes
        }
    }

    #[test]
    fn random_mask_hits_target_fraction() {
        let mut rng = Rng::new(13);
        let mut sig = smooth(40, 30, 3, &mut rng);
        random_mask(&mut sig, 0.2, &mut rng);
        let missing = sig.len() - sig.present();
        assert!(missing >= (1200.0 * 0.2) as usize, "missing {missing}");
        assert!(missing < 1200, "some cells must survive");
        // frac = 0 is a no-op.
        let mut full = smooth(10, 10, 2, &mut rng);
        random_mask(&mut full, 0.0, &mut rng);
        assert_eq!(full.present(), 100);
    }

    #[test]
    fn generators_are_deterministic() {
        let a = {
            let mut rng = Rng::new(77);
            image_like(30, 30, 3, &mut rng)
        };
        let b = {
            let mut rng = Rng::new(77);
            image_like(30, 30, 3, &mut rng)
        };
        assert_eq!(a.values(), b.values());
    }
}
