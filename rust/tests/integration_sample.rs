//! Sampling-coreset acceptance suite: quality against the uniform
//! baseline at equal budget, and the repo's standing thread-count
//! bit-identity constraint.
//!
//! The quality sweep is the sensitivity framework's reason to exist:
//! on signals whose loss is dominated by a few high-leverage cells,
//! uniform sampling misses the outliers (or catches them with wild
//! multiplicity swings) while sensitivity scores upweight them into
//! nearly every draw. Over a deterministic corpus of seeded cases the
//! sensitivity sampler's worst-case relative error must beat the
//! uniform sampler's at the same τ on at least 90 % of cases —
//! Caratheodory's deterministic error is measured alongside as the
//! reference point.

use sigtree::coreset::{Coreset, SignalCoreset};
use sigtree::par::Exec;
use sigtree::rng::Rng;
use sigtree::sample::{SampleAlgorithm, SampleParams, SensitivityCoreset};
use sigtree::segmentation::{random_segmentation, strip_segmentation, KSegmentation};
use sigtree::signal::{generate, PrefixStats, Signal};

/// A mostly-smooth signal with a few planted high-magnitude outlier
/// cells — the adversarial regime for uniform sampling.
fn spiky_signal(seed: u64) -> Signal {
    let mut rng = Rng::new(seed);
    let (n, m) = (40, 30);
    let mut sig = generate::smooth(n, m, 2, &mut rng);
    for _ in 0..10 {
        let r = rng.usize(n);
        let c = rng.usize(m);
        let spike = 40.0 + 20.0 * rng.f64();
        let sign = if rng.f64() < 0.5 { -1.0 } else { 1.0 };
        sig.set(r, c, sign * spike);
    }
    sig
}

/// The audit-style query sweep: constant fit, row/column strips, and
/// mean-refit random guillotine trees.
fn query_sweep(sig: &Signal, stats: &PrefixStats, k: usize, rng: &mut Rng) -> Vec<KSegmentation> {
    let bounds = sig.bounds();
    let refit = |mut s: KSegmentation| {
        s.refit_values(stats);
        s
    };
    let mut queries = vec![KSegmentation::constant(bounds, stats.mean(&bounds))];
    queries.push(refit(strip_segmentation(bounds, k, true)));
    queries.push(refit(strip_segmentation(bounds, k, false)));
    for _ in 0..5 {
        queries.push(refit(random_segmentation(bounds, k, rng)));
    }
    queries
}

fn max_rel_err<C: Coreset>(coreset: &C, queries: &[KSegmentation], stats: &PrefixStats) -> f64 {
    queries
        .iter()
        .map(|q| {
            let exact = q.loss(stats);
            let approx = coreset.fitting_loss(q);
            (approx - exact).abs() / (1.0 + exact)
        })
        .fold(0.0f64, f64::max)
}

#[test]
fn sensitivity_beats_uniform_at_equal_tau_on_seeded_corpus() {
    let k = 6;
    let eps = 0.3;
    let cases = 20usize;
    let mut wins = 0usize;
    for case in 0..cases as u64 {
        let sig = spiky_signal(1000 + case);
        let stats = PrefixStats::new(&sig);
        let mut qrng = Rng::new(2000 + case);
        let queries = query_sweep(&sig, &stats, k, &mut qrng);

        let tau = (sig.present() / 8).max(64);
        let params = SampleParams::new(k, eps, tau, 3000 + case);
        let sens = SensitivityCoreset::build(&sig, SampleAlgorithm::Unified, &params);
        let unif = SensitivityCoreset::build(&sig, SampleAlgorithm::Uniform, &params);

        // Both samplers carry the exact present mass at equal τ.
        let cells = sig.present() as f64;
        assert!((sens.total_weight() - cells).abs() <= 1e-9 * cells);
        assert!((unif.total_weight() - cells).abs() <= 1e-9 * cells);

        let sens_err = max_rel_err(&sens, &queries, &stats);
        let unif_err = max_rel_err(&unif, &queries, &stats);
        assert!(sens_err.is_finite() && unif_err.is_finite());
        if sens_err <= unif_err * 1.05 + 1e-9 {
            wins += 1;
        }

        // Reference point: the deterministic coreset's error on the
        // same sweep is finite and small (its guarantee is worst-case,
        // the samplers' merely probabilistic).
        let cara = SignalCoreset::construct(&sig, k, eps);
        let cara_err = max_rel_err(&cara, &queries, &stats);
        assert!(cara_err.is_finite());
    }
    let need = cases * 9 / 10;
    assert!(
        wins >= need,
        "sensitivity won {wins}/{cases} seeded cases, need >= {need}"
    );
}

#[test]
fn sampling_is_bit_identical_across_thread_counts() {
    let sig = spiky_signal(77);
    let params = SampleParams::new(5, 0.3, 180, 41);
    for algorithm in SampleAlgorithm::ALL {
        let reference = SensitivityCoreset::build_exec(&sig, algorithm, &params, Exec::Spawn(1));
        for threads in [2, 4, 8] {
            let other =
                SensitivityCoreset::build_exec(&sig, algorithm, &params, Exec::Spawn(threads));
            assert_eq!(
                reference,
                other,
                "{} sample changed at {threads} threads",
                algorithm.name()
            );
        }
        assert!(!reference.is_empty());
    }
}
