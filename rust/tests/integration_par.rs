//! Differential tests for the parallel construction engine
//! (`sigtree::par`): the sharded builders must be thread-count-invariant
//! (bit-identical output for any worker count) and agree with the
//! sequential pipeline on weight, moments, and fitting loss — on
//! aligned, ragged, and masked signals.

use sigtree::coreset::merge_reduce::StreamingCoreset;
use sigtree::coreset::{Coreset, CoresetConfig, SignalCoreset};
use sigtree::rng::Rng;
use sigtree::segmentation::random_segmentation;
use sigtree::signal::{generate, PrefixStats, Rect, Signal, SignalSource};

/// Aggregate (count, Σwy, Σwy²) over all blocks of a coreset.
fn aggregate_moments(cs: &SignalCoreset) -> (f64, f64, f64) {
    let mut c = 0.0;
    let mut s = 0.0;
    let mut q = 0.0;
    for b in &cs.blocks {
        let m = b.moments();
        c += m.count;
        s += m.sum;
        q += m.sum_sq;
    }
    (c, s, q)
}

/// Core differential check: build_par at 1..=4 threads must produce the
/// identical coreset; its weight/moments must match the sequential build;
/// its fitting loss must sit within the sequential tolerance.
fn assert_par_matches_sequential(sig: &Signal, k: usize, eps: f64, loss_tol: f64, seed: u64) {
    let config = CoresetConfig::new(k, eps);
    let stats = PrefixStats::new(sig);
    let seq = SignalCoreset::construct_with(sig, config);
    let reference = SignalCoreset::construct_sharded(sig, config, 1);

    // Thread-count invariance: bit-identical blocks for every count
    // (the shared PrefixStats and the shard plan are shape-only).
    for threads in [2, 3, 4, 8] {
        let par = SignalCoreset::construct_sharded(sig, config, threads);
        assert_eq!(
            par.blocks.len(),
            reference.blocks.len(),
            "threads {threads}: block count"
        );
        for (a, b) in par.blocks.iter().zip(&reference.blocks) {
            assert_eq!(a.rect, b.rect, "threads {threads}");
            assert_eq!(a.labels, b.labels, "threads {threads}");
            assert_eq!(a.weights, b.weights, "threads {threads}");
        }
    }

    // Weight and global moments match the sequential build exactly
    // (both are the exact moments of the present cells).
    let w_scale = 1.0 + seq.total_weight();
    assert!(
        (reference.total_weight() - seq.total_weight()).abs() <= 1e-9 * w_scale,
        "weight {} vs {}",
        reference.total_weight(),
        seq.total_weight()
    );
    let (pc, ps, pq) = aggregate_moments(&reference);
    let (sc, ss, sq) = aggregate_moments(&seq);
    let m_scale = 1.0 + sc.abs() + ss.abs() + sq.abs();
    assert!((pc - sc).abs() <= 1e-7 * m_scale, "count {pc} vs {sc}");
    assert!((ps - ss).abs() <= 1e-7 * m_scale, "sum {ps} vs {ss}");
    assert!((pq - sq).abs() <= 1e-6 * m_scale, "sum_sq {pq} vs {sq}");

    // Fitting loss within the sequential tolerance on random queries —
    // swept through the proptest harness instead of an ad-hoc loop, so a
    // violation reports a replayable (case, seed) pair and each call site
    // draws from its own deterministic stream.
    sigtree::proptest::check_seeded("par-vs-seq-fitting-loss", seed, 10, |rng| {
        let mut s = random_segmentation(sig.bounds(), k, rng);
        s.refit_values(&stats);
        let exact = s.loss(&stats);
        let par_loss = reference.fitting_loss(&s);
        let seq_loss = seq.fitting_loss(&s);
        if (par_loss - exact).abs() > loss_tol * exact + 1e-6 {
            return Err(format!("par {par_loss} vs exact {exact}"));
        }
        if (seq_loss - exact).abs() > loss_tol * exact + 1e-6 {
            return Err(format!("seq {seq_loss} vs exact {exact}"));
        }
        Ok(())
    });
}

#[test]
fn build_par_aligned_signal() {
    // Height is an exact multiple of the 64-row shard.
    let mut rng = Rng::new(300);
    let sig = generate::smooth(256, 48, 3, &mut rng);
    assert_par_matches_sequential(&sig, 4, 0.3, 0.35, 1300);
}

#[test]
fn build_par_ragged_signal() {
    // 250 rows → 3 uneven shards (83/83/84 rows).
    let mut rng = Rng::new(301);
    let sig = generate::image_like(250, 40, 3, &mut rng);
    assert_par_matches_sequential(&sig, 5, 0.3, 0.5, 1301);
}

#[test]
fn build_par_masked_signal() {
    let mut rng = Rng::new(302);
    let mut sig = generate::smooth(192, 40, 3, &mut rng);
    // A fully-masked middle shard (rows 64..=127) plus a partial patch:
    // exercises zero-weight block dropping inside the workers.
    sig.mask_rect(Rect::new(64, 127, 0, 39));
    sig.mask_rect(Rect::new(10, 20, 5, 15));
    let present = sig.present() as f64;
    let config = CoresetConfig::new(4, 0.3);
    let reference = SignalCoreset::construct_sharded(&sig, config, 1);
    for threads in 2..=4 {
        let par = SignalCoreset::construct_sharded(&sig, config, threads);
        assert_eq!(par.blocks.len(), reference.blocks.len());
        for (a, b) in par.blocks.iter().zip(&reference.blocks) {
            assert_eq!(a.rect, b.rect);
            assert_eq!(a.weights, b.weights);
        }
    }
    assert!(
        (reference.total_weight() - present).abs() <= 1e-6 * present,
        "weight {} vs present {present}",
        reference.total_weight()
    );
    for b in &reference.blocks {
        assert!(b.total_weight() > 0.0, "empty block survived: {:?}", b.rect);
    }
    // compression_ratio divides the deduplicated positive-weight support
    // by present cells — not the 4-slot storage footprint, which
    // double-counts coincident thin-block corners on merged coresets.
    let expected = reference.support_cells() as f64 / reference.total_weight();
    assert!((reference.compression_ratio() - expected).abs() < 1e-12);
    assert!(reference.support_cells() <= reference.stored_points());
}

#[test]
fn batch_fitting_loss_matches_sequential_for_any_thread_count() {
    let mut rng = Rng::new(303);
    let sig = generate::smooth(128, 64, 3, &mut rng);
    let stats = PrefixStats::new(&sig);
    let cs = SignalCoreset::construct(&sig, 6, 0.25);
    let queries: Vec<_> = (0..17)
        .map(|_| {
            let mut s = random_segmentation(sig.bounds(), 6, &mut rng);
            s.refit_values(&stats);
            s
        })
        .collect();
    let expect: Vec<f64> = queries.iter().map(|s| cs.fitting_loss(s)).collect();
    for threads in [0, 1, 2, 3, 4] {
        let got = cs.fitting_loss_batch(&queries, threads);
        assert_eq!(got, expect, "threads {threads}");
    }
}

#[test]
fn streaming_through_parallel_builder() {
    // Drive row-bands through StreamingCoreset with the parallel
    // per-band builder: weight conservation and query quality must match
    // the sequential streaming path.
    let mut rng = Rng::new(304);
    let sig = generate::smooth(320, 30, 3, &mut rng);
    let stats = PrefixStats::new(&sig);
    let config = CoresetConfig::new(4, 0.3);
    let mut stream = StreamingCoreset::new(30, config).with_threads(3);
    // 160-row bands → each band is 2 shards wide, so every push actually
    // exercises the parallel sharded builder (not its small-band
    // sequential fallback).
    let mut r0 = 0;
    while r0 < 320 {
        let r1 = (r0 + 159).min(319);
        stream.push_band(&sig.view(Rect::new(r0, r1, 0, 29)));
        r0 = r1 + 1;
    }
    assert_eq!(stream.rows_seen(), 320);
    let cs = stream.finish().unwrap();
    let cells = (320 * 30) as f64;
    assert!((cs.total_weight() - cells).abs() < 1e-6 * cells);
    // The worker count is a pure performance knob: with_threads(1) must
    // stream the bit-identical coreset.
    let mut stream1 = StreamingCoreset::new(30, config).with_threads(1);
    let mut r0 = 0;
    while r0 < 320 {
        let r1 = (r0 + 159).min(319);
        stream1.push_band(&sig.view(Rect::new(r0, r1, 0, 29)));
        r0 = r1 + 1;
    }
    let cs1 = stream1.finish().unwrap();
    assert_eq!(cs.blocks.len(), cs1.blocks.len());
    for (a, b) in cs.blocks.iter().zip(&cs1.blocks) {
        assert_eq!(a.rect, b.rect);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.weights, b.weights);
    }
    // Query quality through the proptest harness (replayable seeds
    // instead of an ad-hoc loop that panics mid-iteration).
    sigtree::proptest::check_seeded("streaming-par-query-quality", 1304, 5, |rng| {
        let mut s = random_segmentation(sig.bounds(), 4, rng);
        s.refit_values(&stats);
        let exact = s.loss(&stats);
        let approx = cs.fitting_loss(&s);
        if (approx - exact).abs() > 0.35 * exact + 1e-6 {
            return Err(format!("{approx} vs {exact}"));
        }
        Ok(())
    });
}

#[test]
fn worker_pool_supports_concurrent_map_callers() {
    // The serve daemon shares ONE engine pool across many connection
    // threads: `/optimal_tree` handlers and the fitting-loss collector
    // all call `pool.map` concurrently. Hammer that contract directly —
    // many caller threads, many rounds, varying batch shapes — and
    // require exact per-caller results (a lost task, a cross-caller
    // result leak, or a deadlock all fail loudly here).
    let pool = std::sync::Arc::new(sigtree::par::WorkerPool::new(3));
    const CALLERS: usize = 8;
    const ROUNDS: usize = 25;
    let mut handles = Vec::new();
    for caller in 0..CALLERS {
        let pool = std::sync::Arc::clone(&pool);
        handles.push(std::thread::spawn(move || {
            for round in 0..ROUNDS {
                // Mix shapes: singletons, odd lengths, and empty batches
                // all cross the pool while other callers are mid-map.
                let len = match round % 4 {
                    0 => 1,
                    1 => 7,
                    2 => 64,
                    _ => 0,
                };
                let items: Vec<u64> =
                    (0..len).map(|i| (caller * 100_000 + round * 100 + i) as u64).collect();
                let got = pool
                    .map(&items, |idx, &x| x.wrapping_mul(0x9e37_79b9).wrapping_add(idx as u64));
                let want: Vec<u64> = items
                    .iter()
                    .enumerate()
                    .map(|(idx, &x)| x.wrapping_mul(0x9e37_79b9).wrapping_add(idx as u64))
                    .collect();
                assert_eq!(got, want, "caller {caller} round {round}");
            }
        }));
    }
    for handle in handles {
        handle.join().expect("caller thread");
    }
}

#[test]
fn parallel_prefix_stats_agree_on_coreset_path() {
    // Building a coreset from parallel-constructed statistics must match
    // the sequential-statistics build (same partition decisions — the
    // stats agree to ~1e-12 relative).
    let mut rng = Rng::new(305);
    let sig = generate::smooth(200, 50, 3, &mut rng);
    let config = CoresetConfig::new(4, 0.3);
    let seq_stats = PrefixStats::new(&sig);
    let par_stats = PrefixStats::new_par(&sig, 4);
    let a = SignalCoreset::construct_with_stats(&sig, &seq_stats, config);
    let b = SignalCoreset::construct_with_stats(&sig, &par_stats, config);
    let scale = 1.0 + a.total_weight();
    assert!((a.total_weight() - b.total_weight()).abs() < 1e-9 * scale);
    assert!((a.opt1() - b.opt1()).abs() <= 1e-6 * (1.0 + a.opt1()));
}
