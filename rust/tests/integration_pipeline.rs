//! Integration tests over the L3 coordinator: pipeline vs. batch
//! equivalences, backpressure, merge-and-reduce invariants, solver
//! training on pipeline output.

use sigtree::coreset::{Coreset, CoresetConfig, SignalCoreset};
use sigtree::pipeline::{run, run_streaming, PipelineConfig};
use sigtree::rng::Rng;
use sigtree::segmentation::random_segmentation;
use sigtree::signal::{generate, PrefixStats, Signal};
use sigtree::tree::forest::{ForestParams, RandomForest};
use sigtree::tree::Sample;

#[test]
fn prop_pipeline_weight_conservation_all_shapes() {
    sigtree::proptest::check("pipeline-weight", 6, |rng| {
        let n = 32 + rng.usize(200);
        let m = 16 + rng.usize(80);
        let sig = generate::smooth(n, m, 3, rng);
        let cfg = PipelineConfig::new(CoresetConfig::new(4, 0.3))
            .with_band_rows(1 + rng.usize(64))
            .with_workers(1 + rng.usize(3));
        let (cs, _) = run(&sig, cfg);
        let w = cs.total_weight();
        if (w - (n * m) as f64).abs() > 1e-6 * (n * m) as f64 {
            return Err(format!("weight {w} != {}", n * m));
        }
        if cs.rows() != n || cs.cols() != m {
            return Err("dimension mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn pipeline_and_batch_agree_on_losses() {
    let mut rng = Rng::new(7);
    let sig = generate::image_like(256, 96, 4, &mut rng);
    let stats = PrefixStats::new(&sig);
    let cfg = PipelineConfig::new(CoresetConfig::new(8, 0.25)).with_band_rows(64);
    let (pipe, _) = run(&sig, cfg);
    let batch = SignalCoreset::construct(&sig, 8, 0.25);
    for _ in 0..20 {
        let mut s = random_segmentation(sig.bounds(), 8, &mut rng);
        s.refit_values(&stats);
        let exact = s.loss(&stats);
        let a = pipe.fitting_loss(&s);
        let b = batch.fitting_loss(&s);
        assert!((a - exact).abs() <= 0.3 * exact + 1e-6, "pipe {a} vs {exact}");
        assert!((b - exact).abs() <= 0.3 * exact + 1e-6, "batch {b} vs {exact}");
    }
}

#[test]
fn backpressure_source_blocks_with_tiny_queue() {
    // A queue of capacity 1 with a slow single worker: the source must
    // accumulate blocking time (i.e., backpressure engages).
    let mut rng = Rng::new(9);
    let sig = generate::noise(512, 64, 1.0, &mut rng);
    let mut cfg = PipelineConfig::new(CoresetConfig::new(16, 0.1))
        .with_band_rows(16)
        .with_workers(1);
    cfg.queue_capacity = 1;
    let (_, metrics) = run(&sig, cfg);
    assert_eq!(metrics.cells_processed(), 512 * 64);
    assert!(metrics.bands_built() == 32);
    // With 32 bands through a capacity-1 queue, some waiting is
    // essentially guaranteed; assert the counter moved at all.
    assert!(metrics.source_wait().as_nanos() > 0);
}

#[test]
fn streaming_generator_equivalent_to_materialized() {
    // The generator entry point must be exactly equivalent to feeding the
    // same owned bands from a materialized Vec (lazy vs eager sources).
    let mut rng = Rng::new(11);
    let sig = generate::smooth(320, 64, 3, &mut rng);
    let cfg = PipelineConfig::new(CoresetConfig::new(6, 0.3))
        .with_band_rows(80)
        .with_workers(1);
    let bands: Vec<(usize, Signal)> = sigtree::pipeline::band_rects(320, 64, 80)
        .into_iter()
        .map(|r| (r.r0, sig.crop(r)))
        .collect();
    // True generator: bands are cropped on demand as the source thread
    // pulls them, never materialized as a whole.
    let lazy = sigtree::pipeline::band_rects(320, 64, 80)
        .into_iter()
        .map(|r| (r.r0, sig.crop(r)));
    let (a, _) = run_streaming(64, lazy, cfg);
    let (b, _) = run_streaming(64, bands.into_iter(), cfg);
    assert_eq!(a.blocks.len(), b.blocks.len());
    assert!((a.total_weight() - b.total_weight()).abs() < 1e-9);

    // The in-memory shared-stats path (`run`) answers band statistics
    // from one global PrefixStats instead of band-local rebuilds, so it
    // is equivalent in weight/quality but not bitwise in block layout.
    let (c, _) = run(&sig, cfg);
    assert!((c.total_weight() - a.total_weight()).abs() < 1e-6 * a.total_weight());
    assert_eq!(c.rows(), 320);
    let stats = PrefixStats::new(&sig);
    for _ in 0..10 {
        let mut s = random_segmentation(sig.bounds(), 6, &mut rng);
        s.refit_values(&stats);
        let exact = s.loss(&stats);
        assert!(
            (c.fitting_loss(&s) - exact).abs() <= 0.35 * exact + 1e-6,
            "shared-stats pipeline off: {} vs {exact}",
            c.fitting_loss(&s)
        );
    }
}

#[test]
fn forest_trained_on_pipeline_coreset_predicts() {
    // Full-stack: stream → coreset → weighted samples → forest → predict.
    let mut rng = Rng::new(13);
    // Light noise: per-band σ estimates shrink with band size, so heavy
    // noise at small bands forces near-singleton blocks (correct but not
    // compressive) — the full-signal regime is exercised elsewhere.
    let (sig, pieces) = generate::piecewise_constant(128, 64, 6, 0.02, &mut rng);
    let cfg = PipelineConfig::new(CoresetConfig::new(12, 0.25)).with_band_rows(64);
    let (cs, _) = run(&sig, cfg);
    let samples: Vec<Sample> = cs
        .weighted_points()
        .iter()
        .map(Sample::from_point)
        .collect();
    assert!(samples.len() < sig.len() / 2, "coreset not compressive");
    let forest = RandomForest::fit(
        &samples,
        &ForestParams::default().with_trees(10).with_max_leaves(16),
        &mut rng,
    );
    // The forest must recover the piecewise structure decently: check the
    // centroid of each generating piece.
    let mut total = 0.0;
    let mut count = 0.0;
    for (rect, level) in &pieces {
        let r = (rect.r0 + rect.r1) / 2;
        let c = (rect.c0 + rect.c1) / 2;
        let pred = forest.predict(&[r as f64, c as f64]);
        total += (pred - level).abs();
        count += 1.0;
    }
    let mae = total / count;
    assert!(mae < 1.5, "forest MAE on piece centers {mae}");
}

#[test]
fn empty_stream_yields_empty_coreset() {
    let cfg = PipelineConfig::new(CoresetConfig::new(4, 0.3));
    let (cs, metrics) = run_streaming(16, std::iter::empty(), cfg);
    assert_eq!(cs.blocks.len(), 0);
    assert_eq!(metrics.bands_built(), 0);
    assert_eq!(cs.total_weight(), 0.0);
}
