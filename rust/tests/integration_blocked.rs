//! Differential suite for the cache-blocked execution paths: the
//! [`BlockedBackend`] kernel backend and the blocked prefix-statistics
//! fill ([`PrefixStats::new_blocked`]).
//!
//! Two different claims are pinned at two different strengths:
//!
//! * **Bit-identity (f64 / kernel-vs-kernel)** — the blocked stats fill
//!   must equal the scalar fill *exactly*, for every thread count and
//!   every block width (including non-divisor widths), and the blocked
//!   backend's tiled pipeline must equal the native backend's
//!   bit-for-bit (same addition chains by construction — the row carry
//!   *is* the scalar running accumulator; see DESIGN.md §Kernels).
//! * **Pinned tolerance (f32 trait path vs f64 oracle)** — both f32
//!   backends sit at the same documented distance from the exact
//!   [`PrefixStats`] oracle: 1e-2 on moments, 0.05 on opt₁ (the
//!   integral-image cancellation bound of `integration_backend.rs`).

use sigtree::engine::{BackendChoice, Engine, EngineConfig};
use sigtree::proptest;
use sigtree::rng::Rng;
use sigtree::runtime::{BlockedBackend, KernelBackend, NativeBackend, TiledPrefix, TILE};
use sigtree::signal::{generate, PrefixStats, Rect, Signal};

/// The f64 oracle for the kernel pipeline: masked cells become 0-valued
/// present cells (same convention as `integration_backend.rs`).
fn zero_filled(sig: &Signal) -> Signal {
    Signal::from_fn(sig.rows(), sig.cols(), |r, c| {
        if sig.is_present(r, c) {
            sig.get(r, c)
        } else {
            0.0
        }
    })
}

fn random_rects(n: usize, m: usize, count: usize, rng: &mut Rng) -> Vec<Rect> {
    (0..count)
        .map(|_| {
            let r0 = rng.usize(n);
            let r1 = rng.range(r0, n);
            let c0 = rng.usize(m);
            let c1 = rng.range(c0, m);
            Rect::new(r0, r1, c0, c1)
        })
        .collect()
}

#[test]
fn blocked_differential_sweep() {
    // Property sweep over the regimes the tiling must handle — aligned,
    // ragged, sub-tile, masked — with a random block width each case
    // (1 ..= 2·TILE covers sub-lane, non-divisor, and larger-than-tile).
    proptest::check_seeded("blocked-vs-native-vs-stats", 0xB10C_0001, 10, |rng| {
        let n = 1 + rng.usize(TILE + TILE / 2);
        let m = 1 + rng.usize(TILE + TILE / 2);
        let mut sig = generate::smooth(n, m, 3, rng);
        for _ in 0..rng.usize(3) {
            let r0 = rng.usize(n);
            let r1 = rng.range(r0, n);
            let c0 = rng.usize(m);
            let c1 = rng.range(c0, m);
            sig.mask_rect(Rect::new(r0, r1, c0, c1));
        }
        let block = 1 + rng.usize(2 * TILE);
        let blocked = BlockedBackend::with_block(block);
        let native = NativeBackend::new();
        let tp_b = TiledPrefix::build(&blocked, &sig).map_err(|e| e.to_string())?;
        let tp_n = TiledPrefix::build(&native, &sig).map_err(|e| e.to_string())?;
        let rects = random_rects(n, m, 20, rng);

        // Kernel vs kernel: bit-identical tiled moments and batched opt₁.
        for rect in &rects {
            let (bs, bq) = tp_b.moments(rect);
            let (ns, nq) = tp_n.moments(rect);
            if bs != ns || bq != nq {
                return Err(format!(
                    "{n}x{m} block {block} {rect:?}: blocked ({bs}, {bq}) != native ({ns}, {nq})"
                ));
            }
        }
        let ob = tp_b.batched_opt1(&rects).map_err(|e| e.to_string())?;
        let on = tp_n.batched_opt1(&rects).map_err(|e| e.to_string())?;
        if ob != on {
            return Err(format!("{n}x{m} block {block}: batched_opt1 diverged from native"));
        }

        // f32 trait path vs the exact f64 oracle, at the pinned bounds.
        let stats = PrefixStats::new(&zero_filled(&sig));
        for rect in &rects {
            let (s, q) = tp_b.moments(rect);
            let exact = stats.moments(rect);
            if (s - exact.sum).abs() >= 1e-2 * (1.0 + exact.sum.abs())
                || (q - exact.sum_sq).abs() >= 1e-2 * (1.0 + exact.sum_sq.abs())
            {
                return Err(format!("{n}x{m} {rect:?}: moments out of f32 tolerance"));
            }
        }
        for (g, rect) in ob.iter().zip(rects.iter()) {
            let e = stats.opt1(rect);
            if (g - e).abs() > 0.05 * (1.0 + e.abs()) {
                return Err(format!("{n}x{m} {rect:?}: opt1 {g} vs {e}"));
            }
        }
        Ok(())
    });
}

#[test]
fn blocked_stats_bit_identical_across_threads_and_blocks() {
    // The hard tentpole invariant: `new_blocked` returns the *same bits*
    // as the sequential scalar fill for every thread count × block width
    // combination, including the non-divisor width 37 and widths larger
    // than the column count. Checked densely through rect queries (every
    // moment is a 4-corner read of the underlying f64 arrays).
    let mut rng = Rng::new(0xB10C_0002);
    let mut sig = generate::image_like(209, 133, 4, &mut rng); // 4 ragged 64-row bands
    sig.mask_rect(Rect::new(20, 90, 10, 80));
    sig.mask_rect(Rect::new(150, 208, 100, 132));
    let reference = PrefixStats::new(&sig);
    let rects = random_rects(209, 133, 150, &mut rng);
    for &threads in &[1usize, 2, 4, 8] {
        for &block in &[8usize, 32, 64, 37, 1024] {
            let blk = PrefixStats::new_blocked(&sig, threads, block);
            for rect in &rects {
                assert_eq!(
                    reference.moments(rect),
                    blk.moments(rect),
                    "threads {threads} block {block} {rect:?}"
                );
            }
        }
    }
}

#[test]
fn audit_eps_gate_through_blocked_engine() {
    // The audit ε-gate run end-to-end through the blocked engine path:
    // every audit-internal statistics build goes through the blocked
    // fill (`AuditConfig::stats_block`), the gate must still pass, and
    // the evidence trail must be byte-identical to the native engine's —
    // backend choice is a pure execution-strategy knob.
    let blocked = Engine::new(
        EngineConfig::new(3, 0.5)
            .with_backend(BackendChoice::Blocked)
            .with_block_size(37)
            .with_threads(1)
            .with_seed(7),
    )
    .expect("valid blocked engine config");
    assert_eq!(blocked.backend().name(), "blocked");
    let report = blocked.audit(4, 3);
    assert!(report.pass, "blocked-path audit failed:\n{}", report.to_json().render());

    let native = Engine::new(EngineConfig::new(3, 0.5).with_threads(1).with_seed(7))
        .expect("valid native engine config");
    assert_eq!(report.to_json().render(), native.audit(4, 3).to_json().render());
}
