//! Integration tests for `sigtree::analysis` — the engine behind the
//! `lint` CLI subcommand — pinned against the fixture corpus in
//! `tests/lint_fixtures/` (which Cargo never compiles: it only builds
//! `.rs` files sitting directly in `tests/`) and against the crate's
//! own source tree, which must lint clean.

use std::collections::BTreeSet;

use sigtree::analysis::{self, LintConfig};

fn fixture_root() -> String {
    format!("{}/tests/lint_fixtures", env!("CARGO_MANIFEST_DIR"))
}

fn src_root() -> String {
    format!("{}/src", env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn fixture_corpus_findings_are_exact() {
    let report = analysis::run(&LintConfig::new().with_root(&fixture_root())).expect("lint runs");
    assert!(!report.pass());
    let got: Vec<(&str, &str, usize)> =
        report.findings.iter().map(|f| (f.rule, f.file.as_str(), f.line)).collect();
    let want: Vec<(&str, &str, usize)> = vec![
        ("allow-hygiene", "bad_allow.rs", 4),
        ("panic", "bad_allow.rs", 5),
        ("allow-hygiene", "bad_allow.rs", 9),
        ("panic", "bad_allow.rs", 10),
        ("allow-hygiene", "bad_allow.rs", 14),
        ("error-discipline", "bad_error.rs", 3),
        ("panic", "bad_panic.rs", 4),
        ("panic", "bad_panic.rs", 8),
        ("panic", "bad_panic.rs", 12),
        ("shim-delegation", "bad_shim.rs", 11),
        ("unsafe-safety", "bad_unsafe.rs", 9),
        ("det-order", "coreset/bad_det.rs", 3),
        ("det-order", "coreset/bad_det.rs", 6),
        ("det-clock", "coreset/bad_det.rs", 11),
        ("det-thread", "coreset/bad_det.rs", 15),
        ("index-hot", "runtime/bad_index.rs", 4),
        ("det-order", "sample/bad_det.rs", 3),
        ("det-order", "sample/bad_det.rs", 6),
        ("det-clock", "sample/bad_det.rs", 11),
        ("det-thread", "sample/bad_det.rs", 15),
    ];
    assert_eq!(got, want);
    // Exactly the two well-formed waivers in allowed.rs are honored.
    assert_eq!(report.suppressed, 2);
    assert_eq!(report.files, 10);
}

#[test]
fn crate_source_tree_lints_clean() {
    let report = analysis::run(&LintConfig::new().with_root(&src_root())).expect("lint runs");
    assert!(
        report.pass(),
        "the crate's own sources must lint clean:\n{}",
        report.summary()
    );
    assert!(report.findings.is_empty());
    // The audited escape hatches (par locks, dp2d memo, …) are real:
    // they suppress matches rather than sitting on dead lines.
    assert!(report.suppressed > 0);
}

#[test]
fn report_is_byte_identical_across_runs() {
    let config = LintConfig::new().with_root(&fixture_root());
    let a = analysis::run(&config).expect("first run").to_json().render();
    let b = analysis::run(&config).expect("second run").to_json().render();
    assert_eq!(a, b);
    assert!(a.contains("\"schema\""));
}

#[test]
fn index_hot_fires_by_default_on_hot_paths_only() {
    // On by default, scoped to the hot kernel paths (runtime/ and
    // signal/stats.rs) — the deterministic modules are no longer in its
    // scope, so the only fixture hit is the runtime/ one.
    let base = analysis::run(&LintConfig::new().with_root(&fixture_root())).expect("lint runs");
    let hot: Vec<(&str, usize)> = base
        .findings
        .iter()
        .filter(|f| f.rule == "index-hot")
        .map(|f| (f.file.as_str(), f.line))
        .collect();
    assert_eq!(hot, vec![("runtime/bad_index.rs", 4)]);

    let config = LintConfig::new().with_root(&fixture_root()).with_rule("index-hot", false);
    let report = analysis::run(&config).expect("lint runs");
    assert!(report.findings.iter().all(|f| f.rule != "index-hot"));
}

#[test]
fn disabling_a_rule_drops_its_findings() {
    let config = LintConfig::new().with_root(&fixture_root()).with_rule("panic", false);
    let report = analysis::run(&config).expect("lint runs");
    assert!(report.findings.iter().all(|f| f.rule != "panic"));
    // The other rules are untouched.
    assert!(report.findings.iter().any(|f| f.rule == "det-order"));
}

#[test]
fn deprecated_build_shims_still_delegate() {
    // The PR-4 rename contract: every `#[deprecated]` `build*` shim in
    // the real tree forwards to its `construct*` twin. Pin both the
    // clean state and the rule's ability to catch a regression.
    let path = format!("{}/coreset/mod.rs", src_root());
    let src = std::fs::read_to_string(&path).expect("read coreset/mod.rs");
    let mut enabled: BTreeSet<&'static str> = BTreeSet::new();
    enabled.insert("shim-delegation");
    let clean = analysis::lint_source("coreset/mod.rs", &src, &enabled);
    assert!(clean.findings.is_empty(), "shims must delegate: {:?}", clean.findings);

    let broken = src.replace("Self::construct_with(signal, config)", "Self::build(signal, 4, 0.3)");
    assert_ne!(broken, src, "expected the build_with shim body in coreset/mod.rs");
    let report = analysis::lint_source("coreset/mod.rs", &broken, &enabled);
    assert_eq!(report.findings.len(), 1);
    assert_eq!(report.findings[0].rule, "shim-delegation");
}
