//! The guarantee audit's own ground truth, tested — the oracle
//! differential suite plus the end-to-end audit engine.
//!
//! The audit engine treats `TreeDP` as the exact `opt_k` oracle, so this
//! suite validates the DP itself against two independent references:
//! the classical 1D segmented-least-squares DP on 1-row/1-column
//! signals, and memoization-free brute-force enumeration of *all*
//! guillotine k-trees (per-cell loss arithmetic, no prefix sums) on tiny
//! grids. Then the engine: a fixed-seed sweep must pass, be
//! thread-invariant, and expose the proptest shrink hook.

use sigtree::audit::{run_audit, AuditCase, AuditConfig};
use sigtree::segmentation::dp1d::opt_k_1d;
use sigtree::segmentation::dp2d::{opt_k_tree, TreeDP};
use sigtree::signal::{generate, PrefixStats, Rect, Signal};

// ---------------------------------------------------------------------------
// Oracle differential suite.
// ---------------------------------------------------------------------------

/// Per-cell mean-fit SSE of a rectangle — no prefix sums, no clamping.
fn brute_leaf_sse(sig: &Signal, rect: Rect) -> f64 {
    let mut count = 0.0;
    let mut sum = 0.0;
    for (r, c) in rect.cells() {
        if sig.is_present(r, c) {
            count += 1.0;
            sum += sig.get(r, c);
        }
    }
    if count == 0.0 {
        return 0.0;
    }
    let mean = sum / count;
    let mut sse = 0.0;
    for (r, c) in rect.cells() {
        if sig.is_present(r, c) {
            let d = sig.get(r, c) - mean;
            sse += d * d;
        }
    }
    sse
}

/// Brute-force optimum over ALL guillotine trees with ≤ k leaves:
/// unmemoized recursion over every cut and every leaf-budget split,
/// structurally independent of `TreeDP` (which memoizes, prunes, and
/// queries integral images). Exponential — tiny grids only.
fn brute_opt_tree(sig: &Signal, rect: Rect, k: usize) -> f64 {
    let mut best = brute_leaf_sse(sig, rect);
    if k < 2 {
        return best;
    }
    for cut in rect.r0..rect.r1 {
        let top = Rect::new(rect.r0, cut, rect.c0, rect.c1);
        let bot = Rect::new(cut + 1, rect.r1, rect.c0, rect.c1);
        for ka in 1..k {
            let cand = brute_opt_tree(sig, top, ka) + brute_opt_tree(sig, bot, k - ka);
            best = best.min(cand);
        }
    }
    for cut in rect.c0..rect.c1 {
        let left = Rect::new(rect.r0, rect.r1, rect.c0, cut);
        let right = Rect::new(rect.r0, rect.r1, cut + 1, rect.c1);
        for ka in 1..k {
            let cand = brute_opt_tree(sig, left, ka) + brute_opt_tree(sig, right, k - ka);
            best = best.min(cand);
        }
    }
    best
}

#[test]
fn tree_dp_matches_dp1d_on_single_row_signals() {
    // On a 1×n signal every guillotine k-tree is a contiguous 1D
    // k-segmentation, so the 2D DP must reproduce the classical 1D DP.
    sigtree::proptest::check_seeded("dp2d-vs-dp1d-rows", 0xD21, 8, |rng| {
        let n = 8 + rng.usize(25);
        let ys: Vec<f64> = (0..n).map(|_| rng.normal_ms(0.0, 2.0)).collect();
        let sig = Signal::from_values(1, n, ys.clone());
        let stats = PrefixStats::new(&sig);
        for k in [1, 2, 3, 5] {
            let d2 = opt_k_tree(&stats, k);
            let d1 = opt_k_1d(&ys, k);
            if (d2 - d1).abs() > 1e-8 * (1.0 + d1) {
                return Err(format!("n={n} k={k}: dp2d {d2} vs dp1d {d1}"));
            }
        }
        Ok(())
    });
}

#[test]
fn tree_dp_matches_dp1d_on_single_column_signals() {
    sigtree::proptest::check_seeded("dp2d-vs-dp1d-cols", 0xD22, 8, |rng| {
        let n = 8 + rng.usize(25);
        let ys: Vec<f64> = (0..n).map(|_| rng.normal_ms(1.0, 1.5)).collect();
        let sig = Signal::from_values(n, 1, ys.clone());
        let stats = PrefixStats::new(&sig);
        for k in [1, 2, 4] {
            let d2 = opt_k_tree(&stats, k);
            let d1 = opt_k_1d(&ys, k);
            if (d2 - d1).abs() > 1e-8 * (1.0 + d1) {
                return Err(format!("n={n} k={k}: dp2d {d2} vs dp1d {d1}"));
            }
        }
        Ok(())
    });
}

#[test]
fn tree_dp_matches_bruteforce_enumeration_on_tiny_grids() {
    // Exhaustive: every guillotine tree with ≤ 3 leaves on grids up to
    // 4×4, against the memoized DP, for several signal regimes.
    sigtree::proptest::check_seeded("dp2d-vs-bruteforce", 0xD23, 6, |rng| {
        let n = 2 + rng.usize(3); // 2..=4
        let m = 2 + rng.usize(3);
        let sig = match rng.usize(3) {
            0 => generate::noise(n, m, 1.0, rng),
            1 => generate::piecewise_constant(n, m, 2, 0.2, rng).0,
            _ => Signal::from_fn(n, m, |r, c| (r * 3 + c * 7) as f64),
        };
        let stats = PrefixStats::new(&sig);
        for k in 1..=3 {
            let dp = TreeDP::new(&stats).opt(sig.bounds(), k);
            let brute = brute_opt_tree(&sig, sig.bounds(), k);
            if (dp - brute).abs() > 1e-9 * (1.0 + brute) {
                return Err(format!("{n}x{m} k={k}: dp {dp} vs brute {brute}"));
            }
        }
        Ok(())
    });
}

#[test]
fn tree_dp_matches_bruteforce_on_masked_tiny_grids() {
    // The DP's opt₁ oracle is mask-aware; the per-cell brute force skips
    // masked cells explicitly — the two must still agree.
    sigtree::proptest::check_seeded("dp2d-vs-bruteforce-masked", 0xD24, 5, |rng| {
        let (n, m) = (4, 4);
        let mut sig = generate::noise(n, m, 1.0, rng);
        sig.mask_rect(Rect::new(rng.usize(2), 2 + rng.usize(2), rng.usize(2), 2 + rng.usize(2)));
        let stats = PrefixStats::new(&sig);
        for k in 1..=3 {
            let dp = TreeDP::new(&stats).opt(sig.bounds(), k);
            let brute = brute_opt_tree(&sig, sig.bounds(), k);
            if (dp - brute).abs() > 1e-9 * (1.0 + brute) {
                return Err(format!("k={k}: dp {dp} vs brute {brute}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// End-to-end audit engine.
// ---------------------------------------------------------------------------

#[test]
fn audit_sweep_passes_at_acceptance_settings() {
    // A scaled-down replica of the CI gate (`audit --k 5 --eps 0.5
    // --seed 7`): every gated family within ε, ≥ 3 DP-feasible transfer
    // instances all passing their (1+ε)/(1−ε) bound.
    let config = AuditConfig::new(5, 0.5).with_cases(8).with_seed(7).with_threads(2);
    let report = run_audit(&config);
    assert!(report.pass, "\n{}", report.summary());
    assert!(report.transfers.len() >= 3);
    assert!(report.transfers.iter().all(|t| t.pass));
    assert!(report.transfers.iter().all(|t| t.rows <= 32 && t.cols <= 32));
    // The evidence trail names every family.
    let rendered = report.to_json().render();
    for name in [
        "block-aligned",
        "random",
        "ground-truth",
        "degenerate",
        "boundary-adversarial",
        "dp-optimal",
        "noise-informational",
    ] {
        assert!(rendered.contains(name), "family {name} missing from JSON");
    }
}

#[test]
fn audit_report_is_thread_invariant() {
    let base = AuditConfig::new(4, 0.5).with_cases(5).with_seed(21);
    let reference = run_audit(&base.with_threads(1));
    let report = run_audit(&base.with_threads(3));
    assert_eq!(reference.to_json().render(), report.to_json().render());
}

#[test]
fn prop_audit_case_guarantee_holds_and_shrinks() {
    // The exact property `run_audit` hands to the shrink hook on
    // violation, driven through the proptest harness directly: any
    // failure here reports (and greedily shrinks to) a minimal
    // reproducible (signal, tree, seed) triple.
    let config = AuditConfig::new(4, 0.5);
    sigtree::proptest::check_sized_seeded(
        "audit-eps-guarantee",
        config.seed,
        6,
        12,
        48,
        |rng, size| AuditCase::generate(rng, size, &config),
        AuditCase::check,
    );
}

#[test]
fn dp_optimal_trees_are_within_eps_of_exact() {
    // The hardest realistic query: the exact optimal tree of the signal
    // itself, evaluated through the coreset.
    sigtree::proptest::check_seeded("dp-optimal-query-eps", 0xD25, 4, |rng| {
        let k = 3;
        let eps = 0.5;
        let (sig, _) = generate::piecewise_constant(14, 14, k, 0.1, rng);
        let stats = PrefixStats::new(&sig);
        let cs = sigtree::coreset::SignalCoreset::construct(&sig, k, eps);
        let mut dp = TreeDP::new(&stats);
        let s_d = dp.solve(sig.bounds(), k);
        let exact = s_d.loss(&stats);
        let approx = cs.fitting_loss_batch(&[s_d], 1)[0];
        let err = sigtree::coreset::fitting_loss::relative_error(approx, exact);
        if err > eps {
            return Err(format!("rel err {err} > {eps} on the DP-optimal tree"));
        }
        Ok(())
    });
}

#[test]
fn shrunk_failure_is_reported_by_a_failing_property() {
    // The shrink hook's mechanics on a property that must fail: a
    // deliberately impossible threshold. `run_sized` is the non-panicking
    // runner `run_audit` embeds in its report.
    let config = AuditConfig::new(4, 0.5);
    let failure = sigtree::proptest::run_sized(
        "audit-impossible-gate",
        config.seed,
        3,
        12,
        48,
        |rng, size| AuditCase::generate(rng, size, &config),
        |case| {
            // Every audit case carries a non-empty query sweep; demanding
            // an empty one fails deterministically for every size.
            if case.queries.is_empty() {
                Ok(())
            } else {
                Err(format!("{} queries generated", case.queries.len()))
            }
        },
    )
    .unwrap_err();
    assert_eq!(failure.name, "audit-impossible-gate");
    assert!(failure.size >= 12);
    assert!(failure.to_string().contains("seed"));
}
