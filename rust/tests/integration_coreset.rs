//! Cross-module integration tests: the full Algorithm 3 → Algorithm 5
//! path over every signal regime, driven by the in-repo property-test
//! harness (`sigtree::proptest`).

use sigtree::coreset::fitting_loss::relative_error;
use sigtree::coreset::{Coreset, SignalCoreset};
use sigtree::partition::is_exact_tiling;
use sigtree::rng::Rng;
use sigtree::segmentation::random_segmentation;
use sigtree::signal::{generate, PrefixStats, Signal};

fn random_signal(rng: &mut Rng, size: usize) -> Signal {
    let n = size.max(8);
    let m = (size / 2).max(8);
    match rng.usize(4) {
        0 => generate::smooth(n, m, 3, rng),
        1 => generate::image_like(n, m, 3, rng),
        2 => generate::piecewise_constant(n, m, 6, 0.1, rng).0,
        _ => generate::noise(n, m, 1.0, rng),
    }
}

#[test]
fn prop_coreset_blocks_tile_signal() {
    sigtree::proptest::check_sized(
        "blocks-tile-signal",
        12,
        8,
        96,
        |rng, size| random_signal(rng, size),
        |sig| {
            let cs = SignalCoreset::construct(sig, 8, 0.3);
            let rects: Vec<_> = cs.blocks.iter().map(|b| b.rect).collect();
            if !is_exact_tiling(&rects, sig.bounds()) {
                return Err(format!("{} blocks do not tile the signal", rects.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_total_weight_equals_present_cells() {
    sigtree::proptest::check_sized(
        "weight-conservation",
        12,
        8,
        96,
        |rng, size| {
            let mut sig = random_signal(rng, size);
            if rng.bool(0.5) {
                // Random mask patch.
                let r0 = rng.usize(sig.rows());
                let c0 = rng.usize(sig.cols());
                let r1 = rng.range(r0, sig.rows());
                let c1 = rng.range(c0, sig.cols());
                sig.mask_rect(sigtree::signal::Rect::new(r0, r1, c0, c1));
            }
            sig
        },
        |sig| {
            let cs = SignalCoreset::construct(sig, 6, 0.3);
            let w = cs.total_weight();
            let p = sig.present() as f64;
            if (w - p).abs() > 1e-6 * (1.0 + p) {
                return Err(format!("weight {w} != present {p}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_constant_queries_are_exact() {
    sigtree::proptest::check("constant-query-exact", 10, |rng| {
        let size = 8 + rng.usize(60);
        let sig = random_signal(rng, size);
        let stats = PrefixStats::new(&sig);
        let cs = SignalCoreset::construct(&sig, 4, 0.3);
        let v = rng.uniform(-5.0, 5.0);
        let s = sigtree::segmentation::KSegmentation::constant(sig.bounds(), v);
        let exact = s.loss(&stats);
        let approx = cs.fitting_loss(&s);
        if (approx - exact).abs() > 1e-6 * (1.0 + exact) {
            return Err(format!("{approx} vs {exact}"));
        }
        Ok(())
    });
}

#[test]
fn prop_eps_bound_on_fitted_queries() {
    // Refit (mean-valued) random segmentations — the realistic query
    // class (what tree learners produce) — must respect ~ε.
    sigtree::proptest::check("eps-bound", 8, |rng| {
        let sig = generate::smooth(64 + rng.usize(64), 48 + rng.usize(48), 3, rng);
        let stats = PrefixStats::new(&sig);
        let k = 4 + rng.usize(12);
        let eps = 0.25;
        let cs = SignalCoreset::construct(&sig, k, eps);
        for _ in 0..10 {
            let mut s = random_segmentation(sig.bounds(), k, rng);
            s.refit_values(&stats);
            let exact = s.loss(&stats);
            let approx = cs.fitting_loss(&s);
            let err = relative_error(approx, exact);
            if err > eps {
                return Err(format!("rel err {err} > ε {eps} at k={k}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_uniform_sample_same_interface() {
    sigtree::proptest::check("uniform-interface", 6, |rng| {
        let sig = random_signal(rng, 40);
        let cs = SignalCoreset::construct(&sig, 4, 0.4);
        let us = sigtree::coreset::uniform::UniformSample::build(&sig, cs.size(), rng);
        let s = random_segmentation(sig.bounds(), 4, rng);
        let a = cs.fitting_loss(&s);
        let b = us.fitting_loss(&s);
        if !(a.is_finite() && b.is_finite()) {
            return Err("non-finite loss".into());
        }
        if cs.weighted_points().is_empty() || us.weighted_points().is_empty() {
            return Err("empty point sets".into());
        }
        Ok(())
    });
}

#[test]
fn prop_thin_stripe_isolated_for_any_position() {
    // Property form of the adversarial-stripe scenario below, migrated
    // onto the proptest harness: for ANY hot-row position (edge rows
    // included) the balanced partition must isolate the stripe well
    // enough that the stripe-separating query stays accurate — and a
    // violation now reports a replayable (case, seed) pair instead of
    // panicking mid-loop.
    sigtree::proptest::check_seeded("thin-stripe-isolated", 99, 4, |rng| {
        let n = 96;
        let mut sig = generate::smooth(n, n, 2, rng);
        let hot = rng.usize(n);
        for c in 0..n {
            sig.set(hot, c, 40.0);
        }
        let stats = PrefixStats::new(&sig);
        let cs = SignalCoreset::construct(&sig, 8, 0.2);
        let mut pieces = vec![(sigtree::signal::Rect::new(hot, hot, 0, n - 1), 40.0)];
        if hot > 0 {
            pieces.push((sigtree::signal::Rect::new(0, hot - 1, 0, n - 1), 0.0));
        }
        if hot + 1 < n {
            pieces.push((sigtree::signal::Rect::new(hot + 1, n - 1, 0, n - 1), 0.0));
        }
        let s = sigtree::segmentation::KSegmentation::new(pieces);
        let exact = s.loss(&stats);
        let err = relative_error(cs.fitting_loss(&s), exact);
        if err > 0.3 {
            return Err(format!("hot row {hot}: rel err {err} > 0.3"));
        }
        Ok(())
    });
}

#[test]
fn coreset_beats_uniform_on_adversarial_thin_stripe() {
    // The regime where uniform sampling provably fails: a thin stripe of
    // outlier labels that a uniform sample of modest size misses, but the
    // balanced partition must isolate (its opt₁ forces fine blocks there).
    let mut rng = Rng::new(99);
    let n = 128;
    let mut sig = generate::smooth(n, n, 2, &mut rng);
    for c in 0..n {
        sig.set(60, c, 40.0); // one hot row
    }
    let stats = PrefixStats::new(&sig);
    let cs = SignalCoreset::construct(&sig, 8, 0.2);
    let us = sigtree::coreset::uniform::UniformSample::build(&sig, cs.size(), &mut rng);
    // Query that isolates the stripe.
    let s = sigtree::segmentation::KSegmentation::new(vec![
        (sigtree::signal::Rect::new(0, 59, 0, n - 1), 0.0),
        (sigtree::signal::Rect::new(60, 60, 0, n - 1), 40.0),
        (sigtree::signal::Rect::new(61, n - 1, 0, n - 1), 0.0),
    ]);
    let exact = s.loss(&stats);
    let cs_err = relative_error(cs.fitting_loss(&s), exact);
    let us_err = relative_error(us.fitting_loss(&s), exact);
    assert!(
        cs_err < us_err * 1.05 && cs_err < 0.25,
        "coreset err {cs_err} vs uniform err {us_err}"
    );
}

#[test]
fn theory_config_is_finer_than_practical() {
    let mut rng = Rng::new(17);
    let sig = generate::smooth(48, 48, 3, &mut rng);
    let practical = SignalCoreset::construct(&sig, 4, 0.3);
    let theory = SignalCoreset::construct_with(
        &sig,
        sigtree::coreset::CoresetConfig::new(4, 0.3).theory(2.0),
    );
    assert!(theory.blocks.len() >= practical.blocks.len());
    assert!(theory.gamma < practical.gamma);
}
