//! Merge-tree acceptance suite — the tentpole's contract, end to end.
//!
//! Four properties, each pinned at several thread counts:
//!
//! 1. **Compatibility**: `MergeTree::full()` is bit-identical to
//!    `construct_sharded_exec` for the same shard plan, for every
//!    fanout and thread count (the fanout is memoization shape only).
//! 2. **Incrementality**: `update(dirty)` rebuilds exactly the leaves
//!    intersecting the dirty region (build-counter assertion), and the
//!    updated coreset is equivalent to a from-scratch rebuild of the
//!    mutated signal at the `reduce`-tolerance level — identical
//!    present mass, both within the fitting-loss tolerance of the
//!    exact oracle.
//! 3. **Guarantee under mutation**: after a 20-edit seeded mutation
//!    sequence applied incrementally, the root coreset still passes
//!    the ε-audit query sweep against the mutated signal.
//! 4. **Streaming**: the `StreamingCoreset` facade is bit-identical to
//!    driving the tree's `push_band` directly and to its own
//!    multi-threaded configuration, and the tree's height stays
//!    logarithmic in the number of pushed bands.

use sigtree::audit::build_queries;
use sigtree::coreset::fitting_loss::relative_error;
use sigtree::coreset::merge_reduce::StreamingCoreset;
use sigtree::coreset::merge_tree::MergeTree;
use sigtree::coreset::{Coreset, CoresetConfig, SignalCoreset};
use sigtree::par::Exec;
use sigtree::rng::Rng;
use sigtree::segmentation::random_segmentation;
use sigtree::signal::{generate, PrefixStats, Rect, Signal};

/// Assert two coresets are bitwise equal (blocks, labels, weights).
fn assert_bit_identical(a: &SignalCoreset, b: &SignalCoreset, ctx: &str) {
    assert_eq!(a.blocks.len(), b.blocks.len(), "{ctx}: block count");
    for (x, y) in a.blocks.iter().zip(&b.blocks) {
        assert_eq!(x.rect, y.rect, "{ctx}");
        assert_eq!(x.labels, y.labels, "{ctx}");
        assert_eq!(x.weights, y.weights, "{ctx}");
    }
}

/// The three signal regimes the incremental contract must hold on:
/// shard-aligned rows, ragged rows, and masked cells.
fn regimes() -> Vec<(&'static str, Signal)> {
    let mut rng = Rng::new(600);
    let aligned = generate::smooth(256, 32, 3, &mut rng); // 256 = 4 × 64-row shards
    let ragged = generate::image_like(210, 28, 3, &mut rng); // 210 → ragged last shard
    let mut masked = generate::smooth(192, 24, 3, &mut rng);
    masked.mask_rect(Rect::new(40, 80, 3, 15));
    masked.mask_rect(Rect::new(130, 191, 0, 5));
    vec![("aligned", aligned), ("ragged", ragged), ("masked", masked)]
}

#[test]
fn full_is_bit_identical_to_construct_sharded_at_every_thread_count() {
    let config = CoresetConfig::new(4, 0.3);
    for (name, sig) in regimes() {
        let reference = SignalCoreset::construct_sharded_exec(&sig, config, 64, Exec::Spawn(1));
        for threads in [1, 2, 4, 8] {
            let exec = Exec::Spawn(threads);
            let sharded = SignalCoreset::construct_sharded_exec(&sig, config, 64, exec);
            assert_bit_identical(&sharded, &reference, &format!("{name} sharded {threads}T"));
            for fanout in [2, 3, 7] {
                let stats = PrefixStats::new(&sig);
                let mut tree =
                    MergeTree::build(&sig, &stats, config, 64, exec).with_fanout(fanout);
                assert_bit_identical(
                    &tree.full(),
                    &reference,
                    &format!("{name} tree {threads}T fanout {fanout}"),
                );
            }
        }
    }
}

#[test]
fn update_rebuilds_only_leaves_intersecting_dirty() {
    // The build-counter acceptance test: a one-tile edit rebuilds
    // exactly the leaves whose shard rect intersects the tile.
    let mut rng = Rng::new(601);
    let mut sig = generate::smooth(256, 32, 3, &mut rng);
    let config = CoresetConfig::new(4, 0.3);
    let stats = PrefixStats::new(&sig);
    let mut tree = MergeTree::build(&sig, &stats, config, 64, Exec::Spawn(2));
    let leaves = tree.leaf_count();
    assert!(leaves >= 4, "plan must produce several shards");
    assert_eq!(tree.leaf_builds(), leaves);

    // A tile inside the second shard (rows 64..128) only.
    let dirty = Rect::new(70, 90, 4, 20);
    let expected: usize =
        tree.leaf_rects().iter().filter(|r| r.intersects(&dirty)).count();
    assert_eq!(expected, 1, "tile chosen to hit exactly one shard");
    for (r, c) in dirty.cells() {
        sig.set(r, c, sig.get(r, c) + 3.0);
    }
    let stats = PrefixStats::new(&sig);
    let rebuilt = tree.update(dirty, &sig, &stats, Exec::Spawn(2));
    assert_eq!(rebuilt, 1);
    assert_eq!(tree.leaf_builds(), leaves + 1);

    // A shard-straddling rect rebuilds both its leaves, nothing else.
    let straddle = Rect::new(120, 135, 0, 31);
    for (r, c) in straddle.cells() {
        sig.set(r, c, sig.get(r, c) - 1.0);
    }
    let stats = PrefixStats::new(&sig);
    let rebuilt = tree.update(straddle, &sig, &stats, Exec::Spawn(2));
    assert_eq!(rebuilt, 2);
    assert_eq!(tree.leaf_builds(), leaves + 3);
}

#[test]
fn incremental_update_matches_from_scratch_within_tolerance() {
    // Incremental-vs-from-scratch equivalence at the reduce-tolerance
    // level, on all three regimes, at 1/2/4/8 threads: identical
    // present mass (block moments are exact), identical bits across
    // thread counts, and both coresets within the fitting-loss
    // tolerance of the exact oracle on a random query sweep.
    let config = CoresetConfig::new(4, 0.3);
    for (name, base) in regimes() {
        let dirty = Rect::new(33, 71, 2, base.cols() - 3);
        let mut mutated = base.clone();
        for (r, c) in dirty.cells() {
            if mutated.is_present(r, c) {
                mutated.set(r, c, mutated.get(r, c) + 2.5);
            }
        }
        let stats2 = PrefixStats::new(&mutated);
        let mut reference: Option<SignalCoreset> = None;
        for threads in [1, 2, 4, 8] {
            let exec = Exec::Spawn(threads);
            let stats = PrefixStats::new(&base);
            let mut tree = MergeTree::build(&base, &stats, config, 64, exec);
            tree.update(dirty, &mutated, &stats2, exec);
            let updated = tree.full();
            match &reference {
                None => reference = Some(updated.clone()),
                Some(r) => {
                    assert_bit_identical(&updated, r, &format!("{name} update {threads}T"))
                }
            }
            let scratch = SignalCoreset::construct_sharded_exec(&mutated, config, 64, exec);
            let (w_upd, w_scr) = (updated.total_weight(), scratch.total_weight());
            assert!(
                (w_upd - w_scr).abs() <= 1e-6 * (1.0 + w_scr),
                "{name} {threads}T: weight {w_upd} vs {w_scr}"
            );
            let mut qrng = Rng::new(602);
            for _ in 0..10 {
                let mut s = random_segmentation(mutated.bounds(), 4, &mut qrng);
                s.refit_values(&stats2);
                let exact = s.loss(&stats2);
                for (which, cs) in [("updated", &updated), ("scratch", &scratch)] {
                    let approx = cs.fitting_loss(&s);
                    assert!(
                        (approx - exact).abs() <= 0.35 * exact + 1e-6,
                        "{name} {threads}T {which}: {approx} vs {exact}"
                    );
                }
            }
        }
    }
}

#[test]
fn eps_audit_passes_after_twenty_seeded_edits() {
    // The guarantee under mutation: 20 seeded rect edits applied
    // incrementally, then the audit's structured query sweep on the
    // mutated signal — every gated family within its threshold.
    let mut rng = Rng::new(603);
    let mut sig = generate::smooth(180, 24, 3, &mut rng);
    let k = 4;
    let eps = 0.5;
    let config = CoresetConfig::new(k, eps);
    let mut stats = PrefixStats::new(&sig);
    let mut tree = MergeTree::build(&sig, &stats, config, 36, Exec::Spawn(2));
    assert!(tree.leaf_count() >= 4);
    for _ in 0..20 {
        let h = 1 + rng.usize(10);
        let w = 1 + rng.usize(10);
        let r0 = rng.usize(180 - h + 1);
        let c0 = rng.usize(24 - w + 1);
        let rect = Rect::new(r0, r0 + h - 1, c0, c0 + w - 1);
        let delta = rng.normal_ms(0.0, 1.5);
        for (r, c) in rect.cells() {
            sig.set(r, c, sig.get(r, c) + delta);
        }
        stats = PrefixStats::new(&sig);
        tree.update(rect, &sig, &stats, Exec::Spawn(2));
    }
    let updated = tree.full();
    let (families, queries) =
        build_queries(sig.bounds(), &stats, &updated, None, k, false, &mut rng);
    let approx = updated.fitting_loss_batch(&queries, 2);
    for ((family, q), a) in families.iter().zip(&queries).zip(approx) {
        let err = relative_error(a, q.loss(&stats));
        if let Some(threshold) = family.threshold(eps) {
            assert!(
                err <= threshold,
                "family {} rel err {err} > {threshold} after 20 incremental edits",
                family.name()
            );
        }
    }
}

#[test]
fn streaming_facade_is_bit_identical_across_entry_points() {
    // Band-aligned input: the facade, its multi-threaded configuration,
    // and driving the tree's push_band directly all stream the same
    // bits — StreamingCoreset really is a thin view over MergeTree.
    let mut rng = Rng::new(604);
    let sig = generate::smooth(256, 20, 3, &mut rng);
    let config = CoresetConfig::new(3, 0.3);
    let mut facade = StreamingCoreset::new(20, config);
    let mut threaded = StreamingCoreset::new(20, config).with_threads(4);
    let mut tree = MergeTree::for_stream(20, config);
    let mut r0 = 0;
    while r0 < 256 {
        let band = Rect::new(r0, (r0 + 63).min(255), 0, 19);
        facade.push_band(&sig.crop(band));
        threaded.push_band(&sig.crop(band));
        tree.push_band(&sig.crop(band));
        r0 = band.r1 + 1;
    }
    let a = facade.finish().expect("bands were pushed");
    let b = threaded.finish().expect("bands were pushed");
    let c = tree.into_streamed().expect("bands were pushed");
    assert_bit_identical(&a, &b, "facade vs threaded facade");
    assert_bit_identical(&a, &c, "facade vs raw tree");
}

#[test]
fn streamed_height_stays_logarithmic() {
    // N pushed bands memoize into a tree of height ⌈log_fanout N⌉ —
    // the unbounded-streaming shape guarantee.
    let mut rng = Rng::new(605);
    let sig = generate::smooth(320, 12, 3, &mut rng);
    let config = CoresetConfig::new(3, 0.35);
    let mut tree = MergeTree::for_stream(12, config);
    let mut pushed = 0usize;
    let mut r0 = 0;
    while r0 < 320 {
        let band = Rect::new(r0, (r0 + 9).min(319), 0, 11);
        tree.push_band(&sig.crop(band));
        pushed += 1;
        let bound = usize::BITS as usize - (pushed.max(1) - 1).leading_zeros() as usize;
        assert!(
            tree.height() <= bound.max(1),
            "height {} after {pushed} pushes exceeds ceil(log2) = {bound}",
            tree.height()
        );
        r0 = band.r1 + 1;
    }
    assert_eq!(pushed, 32);
    assert_eq!(tree.height(), 5); // ceil(log2 32)
}

#[test]
fn empty_stream_finish_is_a_typed_error() {
    let config = CoresetConfig::new(3, 0.3);
    let err = StreamingCoreset::new(16, config).finish().unwrap_err();
    assert!(
        err.to_string().contains("empty stream"),
        "unexpected error text: {err}"
    );
}
