//! Integration suite for `sigtree::engine` — the one front door.
//!
//! Pins the three contracts the API redesign rests on:
//!
//! 1. **Engine ≡ legacy, bitwise.** `Engine::coreset` (long-lived pool)
//!    and the deprecated `SignalCoreset::build_par` shim (scoped
//!    threads) produce the identical coreset on aligned / ragged /
//!    masked signals at every thread count — so migrating to the engine
//!    can never change a result.
//! 2. **Config round-trips.** `EngineConfig → JSON → EngineConfig` is
//!    lossless, and an engine built from the round-tripped config
//!    produces the identical coreset.
//! 3. **One validator.** Invalid knobs (ε ∉ (0,1), k = 0,
//!    band_rows = 0, …) are rejected by `Engine::new` with an error,
//!    never a panic — from struct, JSON, and CLI alike.

use sigtree::coreset::{Coreset, CoresetConfig, SignalCoreset};
use sigtree::engine::{BackendChoice, Engine, EngineConfig};
use sigtree::prelude::*;
use sigtree::segmentation::random_segmentation;
use sigtree::signal::generate;

fn assert_same_coreset(a: &SignalCoreset, b: &SignalCoreset, label: &str) {
    assert_eq!(a.blocks.len(), b.blocks.len(), "{label}: block count");
    for (x, y) in a.blocks.iter().zip(&b.blocks) {
        assert_eq!(x.rect, y.rect, "{label}: rect");
        assert_eq!(x.labels, y.labels, "{label}: labels");
        assert_eq!(x.weights, y.weights, "{label}: weights");
    }
    assert_eq!(a.rows(), b.rows(), "{label}: rows");
    assert_eq!(a.cols(), b.cols(), "{label}: cols");
}

/// The differential corpus: shard-aligned height, ragged height (not a
/// multiple of 64), and a masked signal.
fn corpus() -> Vec<(&'static str, Signal)> {
    let mut rng = Rng::new(90);
    let aligned = generate::smooth(192, 40, 3, &mut rng);
    let ragged = generate::image_like(200, 33, 2, &mut rng);
    let mut masked = generate::smooth(256, 48, 3, &mut rng);
    masked.mask_rect(Rect::new(30, 170, 5, 30));
    masked.mask_rect(Rect::new(200, 255, 0, 10));
    vec![("aligned", aligned), ("ragged", ragged), ("masked", masked)]
}

#[test]
fn engine_matches_legacy_build_par_bitwise_at_every_thread_count() {
    for (label, sig) in corpus() {
        #[allow(deprecated)]
        let legacy = SignalCoreset::build_par(&sig, CoresetConfig::new(4, 0.3), 1);
        for threads in [1, 2, 4, 8] {
            let engine = Engine::new(EngineConfig::new(4, 0.3).with_threads(threads)).unwrap();
            let via_engine = engine.coreset(&sig);
            assert_same_coreset(&via_engine, &legacy, &format!("{label} (threads {threads})"));
            // The legacy shim itself stays thread-invariant too.
            #[allow(deprecated)]
            let legacy_t = SignalCoreset::build_par(&sig, CoresetConfig::new(4, 0.3), threads);
            assert_same_coreset(&legacy_t, &legacy, &format!("{label} legacy t{threads}"));
        }
    }
}

#[test]
fn all_five_deprecated_shims_delegate_identically() {
    let mut rng = Rng::new(91);
    let sig = generate::smooth(150, 36, 3, &mut rng);
    let config = CoresetConfig::new(5, 0.3);
    let stats = PrefixStats::new(&sig);

    #[allow(deprecated)]
    let shims = [
        SignalCoreset::build(&sig, 5, 0.3),
        SignalCoreset::build_with(&sig, config),
        SignalCoreset::build_with_stats(&sig, &stats, config),
        SignalCoreset::build_in(&sig, &stats, sig.bounds(), config),
        SignalCoreset::build_par(&sig, config, 2),
    ];
    let replacements = [
        SignalCoreset::construct(&sig, 5, 0.3),
        SignalCoreset::construct_with(&sig, config),
        SignalCoreset::construct_with_stats(&sig, &stats, config),
        SignalCoreset::construct_in(&sig, &stats, sig.bounds(), config),
        SignalCoreset::construct_sharded(&sig, config, 2),
    ];
    for (i, (shim, new)) in shims.iter().zip(&replacements).enumerate() {
        assert_same_coreset(shim, new, &format!("shim #{i}"));
    }
}

#[test]
fn config_json_round_trip_builds_identical_coreset() {
    let mut rng = Rng::new(92);
    let sig = generate::smooth(192, 40, 3, &mut rng);
    let config = EngineConfig::new(4, 0.3).with_threads(2).with_seed(0xdead_beef);
    let rendered = config.to_json().render();
    let parsed = EngineConfig::from_json_str(&rendered).unwrap();
    assert_eq!(parsed, config, "EngineConfig -> JSON -> EngineConfig is lossless");

    let a = Engine::new(config).unwrap().coreset(&sig);
    let b = Engine::new(parsed).unwrap().coreset(&sig);
    assert_same_coreset(&a, &b, "round-tripped config");
}

#[test]
fn invalid_configs_are_rejected_not_panicked() {
    let bad = [
        EngineConfig::new(0, 0.4),                    // k = 0
        EngineConfig::new(5, 0.0),                    // eps = 0
        EngineConfig::new(5, 1.0),                    // eps = 1
        EngineConfig::new(5, -0.1),                   // eps < 0
        EngineConfig::new(5, 1.7),                    // eps > 1
        EngineConfig::new(5, 0.4).with_band_rows(0),  // band_rows = 0
        EngineConfig::new(5, 0.4).with_shard_rows(0), // shard_rows = 0
        EngineConfig::new(5, 0.4).with_beta(-1.0),    // beta <= 0
    ];
    for config in bad {
        let label = format!("{config:?}");
        assert!(Engine::new(config).is_err(), "accepted invalid {label}");
    }
    // The same validator guards the JSON path.
    assert!(EngineConfig::from_json_str("{\"k\": 0, \"eps\": 0.4}").is_err());
    assert!(EngineConfig::from_json_str("{\"k\": 4, \"eps\": 1.5}").is_err());
    assert!(EngineConfig::from_json_str("{\"k\": 4, \"eps\": 0.4, \"band_rows\": 0}").is_err());
    // Backend validation fails fast at Engine::new (not deep in a run).
    #[cfg(not(feature = "pjrt"))]
    assert!(Engine::new(EngineConfig::new(4, 0.4).with_backend(BackendChoice::Pjrt)).is_err());
    #[cfg(feature = "pjrt")]
    let _ = BackendChoice::Pjrt; // keeps the import used under --features pjrt
}

/// Regression for the threads-default inconsistency: `0` now means
/// "auto" on every path — the raw batch API, the engine, and per-query
/// sequential evaluation all agree exactly.
#[test]
fn fitting_loss_threads_zero_means_auto_everywhere() {
    let mut rng = Rng::new(93);
    let sig = generate::smooth(96, 48, 3, &mut rng);
    let stats = PrefixStats::new(&sig);
    let cs = SignalCoreset::construct(&sig, 6, 0.3);
    let queries: Vec<KSegmentation> = (0..30)
        .map(|_| {
            let mut s = random_segmentation(sig.bounds(), 6, &mut rng);
            s.refit_values(&stats);
            s
        })
        .collect();
    let sequential: Vec<f64> = queries.iter().map(|s| cs.fitting_loss(s)).collect();
    for threads in [0, 1, 2, 4, 8] {
        assert_eq!(
            cs.fitting_loss_batch(&queries, threads),
            sequential,
            "batch API, threads {threads}"
        );
        let engine = Engine::new(EngineConfig::new(6, 0.3).with_threads(threads)).unwrap();
        assert!(engine.threads() >= 1, "0 resolves to >= 1");
        assert_eq!(
            engine.fitting_loss(&cs, &queries),
            sequential,
            "engine pool, threads {threads}"
        );
    }
}

#[test]
fn engine_audit_report_is_thread_invariant() {
    let report1 = Engine::new(EngineConfig::new(3, 0.5).with_threads(1).with_seed(11))
        .unwrap()
        .audit(4, 3);
    let report3 = Engine::new(EngineConfig::new(3, 0.5).with_threads(3).with_seed(11))
        .unwrap()
        .audit(4, 3);
    assert!(report1.pass, "\n{}", report1.summary());
    assert_eq!(report1.to_json().render(), report3.to_json().render());
}

#[test]
fn engine_region_build_matches_low_level_construct_in() {
    let mut rng = Rng::new(94);
    let sig = generate::smooth(128, 40, 3, &mut rng);
    let engine = Engine::new(EngineConfig::new(4, 0.3).with_threads(2)).unwrap();
    let session = engine.session(&sig);
    let region = Rect::new(32, 95, 0, 39);
    let via_session = session.coreset_region(region);
    let direct = SignalCoreset::construct_in(
        &sig,
        session.stats(),
        region,
        CoresetConfig::new(4, 0.3),
    );
    assert_same_coreset(&via_session, &direct, "region");
    // Blocks stay in the signal's coordinate frame.
    for b in &via_session.blocks {
        assert!(b.rect.r0 >= 32 && b.rect.r1 <= 95);
    }
    // And engine.coreset_region (one-shot) agrees with the session path.
    assert_same_coreset(&engine.coreset_region(&sig, region), &via_session, "one-shot region");
}
