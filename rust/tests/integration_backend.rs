//! Differential tests: the native kernel backend (f32 artifact
//! semantics, tiled execution) against the exact f64 [`PrefixStats`]
//! oracle, across signal regimes the tiling must handle — TILE-aligned,
//! non-TILE-aligned, smaller-than-TILE, and masked.
//!
//! Masked semantics: the f32 kernel pipeline zero-fills masked cells and
//! takes opt₁ counts from rectangle geometry, so the oracle for masked
//! signals is `PrefixStats` over the zero-filled, fully-present signal
//! (see `runtime::tiled` docs).

use sigtree::rng::Rng;
use sigtree::runtime::{KernelBackend, NativeBackend, TiledPrefix, TILE};
use sigtree::signal::{generate, PrefixStats, Rect, Signal};

/// The f64 oracle for the kernel pipeline: masked cells become 0-valued
/// present cells.
fn zero_filled(sig: &Signal) -> Signal {
    Signal::from_fn(sig.rows(), sig.cols(), |r, c| {
        if sig.is_present(r, c) {
            sig.get(r, c)
        } else {
            0.0
        }
    })
}

fn random_rects(n: usize, m: usize, count: usize, rng: &mut Rng) -> Vec<Rect> {
    (0..count)
        .map(|_| {
            let r0 = rng.usize(n);
            let r1 = rng.range(r0, n);
            let c0 = rng.usize(m);
            let c1 = rng.range(c0, m);
            Rect::new(r0, r1, c0, c1)
        })
        .collect()
}

/// Assert tiled moments + batched opt₁ agree with the f64 oracle to f32
/// tolerance on `count` random rects.
fn assert_differential(sig: &Signal, seed: u64, count: usize, label: &str) {
    let backend = NativeBackend::new();
    let oracle = zero_filled(sig);
    let stats = PrefixStats::new(&oracle);
    let tp = TiledPrefix::build(&backend, sig).unwrap();
    let mut rng = Rng::new(seed);
    let rects = random_rects(sig.rows(), sig.cols(), count, &mut rng);
    for rect in &rects {
        let (s, q) = tp.moments(rect);
        let exact = stats.moments(rect);
        assert!(
            (s - exact.sum).abs() < 1e-2 * (1.0 + exact.sum.abs()),
            "{label} {rect:?}: sum {s} vs {}",
            exact.sum
        );
        assert!(
            (q - exact.sum_sq).abs() < 1e-2 * (1.0 + exact.sum_sq.abs()),
            "{label} {rect:?}: sumsq {q} vs {}",
            exact.sum_sq
        );
    }
    let got = tp.batched_opt1(&rects).unwrap();
    for (g, rect) in got.iter().zip(rects.iter()) {
        let e = stats.opt1(rect);
        assert!(
            (g - e).abs() <= 0.05 * (1.0 + e.abs()),
            "{label} {rect:?}: opt1 {g} vs {e}"
        );
    }
}

#[test]
fn differential_tile_aligned_signal() {
    // Exactly 1×1 tiles — no edge padding in play.
    let mut rng = Rng::new(201);
    let sig = generate::image_like(TILE, TILE, 4, &mut rng);
    assert_differential(&sig, 2011, 60, "aligned-256x256");
}

#[test]
fn differential_non_tile_aligned_signal() {
    // 300×280 spans 2×2 tiles with ragged edges on both axes.
    let mut rng = Rng::new(202);
    let sig = generate::smooth(300, 280, 3, &mut rng);
    assert_differential(&sig, 2021, 60, "ragged-300x280");
}

#[test]
fn differential_smaller_than_tile_signal() {
    // Whole signal fits in one zero-padded tile.
    let mut rng = Rng::new(203);
    let sig = generate::noise(190, 70, 1.0, &mut rng);
    assert_differential(&sig, 2031, 60, "small-190x70");
}

#[test]
fn differential_masked_signal() {
    // Masked patches across a tile boundary: the kernel path must treat
    // them as zeros everywhere, bit-consistently with the oracle.
    let mut rng = Rng::new(204);
    let mut sig = generate::smooth(300, 120, 3, &mut rng);
    sig.mask_rect(Rect::new(10, 40, 5, 60));
    sig.mask_rect(Rect::new(250, 299, 100, 119));
    sig.mask_rect(Rect::new(120, 180, 30, 90));
    assert_differential(&sig, 2041, 60, "masked-300x120");
}

#[test]
fn differential_prefix2d_raw_tile() {
    // The raw kernel (no tiling): f32 integral images vs f64 prefix sums.
    let backend = NativeBackend::new();
    let mut rng = Rng::new(205);
    let sig = generate::piecewise_constant(TILE, TILE, 9, 0.05, &mut rng).0;
    let tile: Vec<f32> = sig.values().iter().map(|&v| v as f32).collect();
    let (ii_y, ii_y2) = backend.prefix2d(&tile).unwrap();
    let stats = PrefixStats::new(&sig);
    let mut checked = 0;
    for r in (0..TILE).step_by(37) {
        for c in (0..TILE).step_by(41) {
            let rect = Rect::new(0, r, 0, c);
            let exact = stats.moments(&rect);
            let gy = ii_y[r * TILE + c] as f64;
            let gy2 = ii_y2[r * TILE + c] as f64;
            assert!(
                (gy - exact.sum).abs() < 1e-2 * (1.0 + exact.sum.abs()),
                "({r},{c}) sum"
            );
            assert!(
                (gy2 - exact.sum_sq).abs() < 1e-2 * (1.0 + exact.sum_sq.abs()),
                "({r},{c}) sumsq"
            );
            checked += 1;
        }
    }
    assert!(checked > 20);
}

#[test]
fn differential_seg_loss_vs_exact() {
    // Pinned tolerance, decomposed: casting each input image to f32
    // perturbs a cell by ≤ ε_f32 ≈ 6e-8 relative (the dominant term, ~1e-6
    // relative on the summed loss for O(1) values); squared differences
    // accumulate in f64 with cascaded-pairwise error O((TILE + log TILE)·
    // ε_f64) ≈ 1e-13 relative; the final f32 cast adds one more ε_f32.
    // 1e-4 leaves ~two orders of margin over the input-cast floor while
    // still rejecting any naive single-precision running-sum regression.
    // (opt1 checks elsewhere keep the looser 0.05 gate: they subtract
    // S²/area from S₂ — catastrophic cancellation the f32 integral-image
    // path genuinely incurs, unlike this direct sum of squares.)
    let backend = NativeBackend::new();
    let mut rng = Rng::new(206);
    let sig = generate::smooth(TILE, TILE, 4, &mut rng);
    let stats = PrefixStats::new(&sig);
    for k in [1, 7, 23] {
        let mut seg = sigtree::segmentation::random_segmentation(sig.bounds(), k, &mut rng);
        seg.refit_values(&stats);
        let rendered = seg.render(TILE, TILE);
        let a: Vec<f32> = sig.values().iter().map(|&v| v as f32).collect();
        let b: Vec<f32> = rendered.values().iter().map(|&v| v as f32).collect();
        let got = backend.seg_loss(&a, &b).unwrap() as f64;
        let exact = seg.loss(&stats);
        assert!(
            (got - exact).abs() <= 1e-4 * (1.0 + exact),
            "k={k}: {got} vs {exact}"
        );
    }
}

#[test]
fn differential_block_sse_batching_boundaries() {
    // Batch sizes around RECT_BATCH exercise the chunking path.
    use sigtree::runtime::RECT_BATCH;
    let backend = NativeBackend::new();
    let mut rng = Rng::new(207);
    let sig = generate::smooth(TILE, TILE, 3, &mut rng);
    let stats = PrefixStats::new(&sig);
    let tp = TiledPrefix::build(&backend, &sig).unwrap();
    let rects = random_rects(TILE, TILE, RECT_BATCH + 17, &mut rng);
    let got = tp.batched_opt1(&rects).unwrap();
    assert_eq!(got.len(), rects.len());
    for (g, rect) in got.iter().zip(rects.iter()).step_by(97) {
        let e = stats.opt1(rect);
        assert!((g - e).abs() <= 0.05 * (1.0 + e.abs()), "{rect:?}: {g} vs {e}");
    }
}

#[test]
fn prelude_surface_smoke() {
    // The example/doctest surface in one tiny end-to-end pass (the
    // `cargo build --examples` smoke companion; the examples themselves
    // are built by scripts/verify.sh).
    use sigtree::prelude::*;
    let mut rng = Rng::new(208);
    let signal = Signal::from_fn(40, 30, |r, c| ((r * 3 + c) % 5) as f64);
    let stats = PrefixStats::new(&signal);
    let coreset = SignalCoreset::construct(&signal, 4, 0.3);
    assert!(coreset.stored_points() > 0);
    let forest = RandomForest::fit(
        &coreset
            .blocks
            .iter()
            .flat_map(|b| b.points())
            .map(|p| sigtree::tree::Sample::from_point(&p))
            .collect::<Vec<_>>(),
        &sigtree::tree::forest::ForestParams::default().with_trees(3),
        &mut rng,
    );
    let pred = forest.predict(&[2.0, 2.0]);
    assert!(pred.is_finite());
    assert!(stats.opt1(&Rect::new(0, 39, 0, 29)) >= 0.0);
}
